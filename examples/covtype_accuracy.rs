//! Figure 3 (left) scenario: classification accuracy vs time on
//! covtype-like data with M=50 machines (paper section 8.1.2).
//!
//!     cargo run --release --example covtype_accuracy -- [--quick]
//!
//! The real covtype dataset (581k × 54) is substituted with a
//! correlated synthetic generator at the same dimensionality (DESIGN.md
//! §3); the protocol is identical: sample the posterior in parallel,
//! classify a held-out test set with the posterior predictive at
//! increasing time budgets, and compare against the single full-data
//! chain. Output: `results/fig3_covtype.csv`.

use std::path::Path;

use repro::combine::CombineMethod;
use repro::config::PipelineConfig;
use repro::coordinator::pipeline;
use repro::coordinator::timing::draws_within;
use repro::data::{io, synth, Dataset};
use repro::evaluation::classification_accuracy;
use repro::sampler::SamplerKind;
use repro::types::SampleMatrix;

fn main() -> repro::error::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick");
    let (n, d, machines, t) =
        if quick { (10_000, 20, 10, 400) } else { (100_000, 54, 50, 1_000) };

    let full = synth::covtype_like(n, d, 2024);
    let (train_idx, test_idx) = synth::train_test_split(n, 0.2, 7);
    let (x_all, y_all, prior_prec) = match &full {
        Dataset::Logistic { x, y, prior_prec } => (x, y, *prior_prec),
        _ => unreachable!(),
    };
    let x_train = repro::data::select_rows(x_all, &train_idx)?;
    let y_train: Vec<f64> = train_idx.iter().map(|&i| y_all[i]).collect();
    let x_test = repro::data::select_rows(x_all, &test_idx)?;
    let y_test: Vec<f64> = test_idx.iter().map(|&i| y_all[i]).collect();
    let train =
        Dataset::Logistic { x: x_train, y: y_train, prior_prec };

    println!(
        "covtype-like: {} train / {} test, d={d}, M={machines}",
        train.len(),
        y_test.len()
    );

    // Parallel run.
    let cfg = PipelineConfig::builder("logistic")
        .machines(machines)
        .samples_per_machine(t)
        .sampler(SamplerKind::Hmc { step: 0.05, n_leapfrog: 10 })
        .method(CombineMethod::Parametric)
        .seed(31)
        .build();
    let out = pipeline::run_native(&cfg, &train)?;

    // Single-chain baseline.
    let single_cfg = PipelineConfig::builder("logistic")
        .machines(1)
        .samples_per_machine(t)
        .sampler(SamplerKind::Hmc { step: 0.01, n_leapfrog: 10 })
        .seed(32)
        .build();
    let single = pipeline::run_single_chain(&single_cfg, &train)?;

    // Accuracy vs time: at each budget, combine the draws available so
    // far (parallel methods) or take the single chain's prefix.
    let horizon = out
        .timing
        .sampling_secs
        .max(single.wall_secs);
    let budgets: Vec<f64> =
        (1..=10).map(|i| horizon * i as f64 / 10.0).collect();
    let mut table =
        io::Table::new(&["budget_secs", "accuracy", "draws_used"]);
    for &b in &budgets {
        // Parallel: prefix of each machine's stream.
        let prefixes: Vec<SampleMatrix> = out
            .subposteriors
            .iter()
            .map(|s| draws_within(s, b))
            .collect();
        if prefixes.iter().all(|p| p.len() >= 10) {
            let refs: Vec<&SampleMatrix> = prefixes.iter().collect();
            let combined = repro::combine::combine_sets(
                CombineMethod::Parametric,
                &refs,
                500,
                9,
            )?;
            let acc = classification_accuracy(&combined, &x_test, &y_test);
            table.push(
                "parallel_parametric",
                vec![b, acc, prefixes[0].len() as f64],
            );
        }
        // Single chain prefix.
        let prefix = draws_within(&single, b);
        if prefix.len() >= 10 {
            let acc = classification_accuracy(&prefix, &x_test, &y_test);
            table.push("regularChain", vec![b, acc, prefix.len() as f64]);
        }
    }
    println!("{}", table.to_markdown());
    table.write_csv(Path::new("results/fig3_covtype.csv"))?;
    println!("wrote results/fig3_covtype.csv");
    println!(
        "expected shape (paper Fig. 3-left): the parallel method reaches \
         high accuracy at small budgets; the full-data chain needs far \
         longer per draw."
    );
    Ok(())
}
