//! Quickstart: the 60-second tour of the public API.
//!
//!     cargo run --release --example quickstart
//!
//! Partitions a conjugate Gaussian problem onto 4 machines, samples each
//! subposterior independently with HMC, combines with all three of the
//! paper's estimators, and compares every result against the closed-form
//! posterior.

use repro::combine::CombineMethod;
use repro::config::PipelineConfig;
use repro::coordinator::pipeline;
use repro::data::synth;
use repro::evaluation::mean_l2_error;
use repro::model::GaussianMean;
use repro::types::SampleMatrix;

fn main() -> repro::error::Result<()> {
    // 1. A dataset: 20k observations of a 2-d Gaussian with unknown mean.
    let data = synth::gaussian(20_000, 2, 42);

    // 2. Configure the embarrassingly parallel run: M=4 machines,
    //    2000 post-burn-in draws each, HMC workers.
    let cfg = PipelineConfig::builder("gaussian")
        .machines(4)
        .samples_per_machine(2_000)
        .method(CombineMethod::Semiparametric)
        .seed(42)
        .build();

    // 3. Run: partition → parallel sample (zero communication) →
    //    stream → combine.
    let out = pipeline::run_native(&cfg, &data)?;
    println!("== run metrics ==\n{}", out.metrics);

    // 4. Ground truth for this conjugate model is available in closed
    //    form — build it from the full dataset.
    let full = match &data {
        repro::data::Dataset::Gaussian { x, lik_prec, prior_prec } => {
            GaussianMean::new(x.clone(), *lik_prec, *prior_prec, 1.0)
        }
        _ => unreachable!(),
    };
    let exact = full.exact_posterior();
    let mut rng = repro::rng::Pcg64::seed_from(7);
    let exact_draws: SampleMatrix = exact.sample_n(4_000, &mut rng);

    // 5. Compare all combination strategies.
    println!("\n== posterior mean error vs closed form ==");
    for &method in CombineMethod::all() {
        let combined = repro::combine::combine(
            method,
            &out.subposteriors,
            2_000,
            99,
        )?;
        let err = mean_l2_error(&combined, &exact_draws);
        println!("  {:20} {:.5}", method.name(), err);
    }
    println!("\nexact posterior mean: {:?}", exact.mean());
    Ok(())
}
