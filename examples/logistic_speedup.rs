//! Figures 1 & 2 scenario: Bayesian logistic regression on synthetic
//! data (paper section 8.1.1).
//!
//!     cargo run --release --example logistic_speedup -- [--fig1] [--quick]
//!
//! Runs the embarrassingly parallel pipeline for M ∈ {10, 20}, then:
//!  * fig1 mode — writes the 2-d marginal draws of each subposterior,
//!    the parametric density-product combination, and the subpostAvg
//!    baseline to `results/fig1/` (the data behind the posterior ovals).
//!  * default — prints the posterior L2 error of every combination
//!    method against a long single-chain groundtruth and writes
//!    `results/fig2_summary.csv`.

use std::path::Path;

use repro::combine::CombineMethod;
use repro::config::PipelineConfig;
use repro::coordinator::pipeline;
use repro::data::{io, synth};
use repro::evaluation::l2_distance_subsampled;
use repro::sampler::SamplerKind;

fn main() -> repro::error::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let fig1 = args.iter().any(|a| a == "--fig1");
    let quick = args.iter().any(|a| a == "--quick");

    // Paper scale: 50k × 50. Quick mode for smoke runs.
    let (n, d, t) = if quick { (5_000, 10, 600) } else { (50_000, 50, 1_500) };
    let data = synth::logistic(n, d, 1234);

    // Groundtruth: long full-data chain (the paper uses 500k iterations;
    // we use a long NUTS-free HMC chain scaled to this testbed).
    println!("sampling groundtruth (full-data chain)…");
    let gt_cfg = PipelineConfig::builder("logistic")
        .machines(1)
        .samples_per_machine(if quick { 1_500 } else { 4_000 })
        .sampler(SamplerKind::Hmc { step: 0.02, n_leapfrog: 12 })
        .seed(7)
        .build();
    let groundtruth = pipeline::run_single_chain(&gt_cfg, &data)?;
    println!(
        "  groundtruth: {} draws, accept={:.2}",
        groundtruth.samples.len(),
        groundtruth.accept_rate
    );

    let mut summary = io::Table::new(&["machines", "l2_error", "secs"]);
    for &machines in &[10usize, 20] {
        println!("== M = {machines} ==");
        let cfg = PipelineConfig::builder("logistic")
            .machines(machines)
            .samples_per_machine(t)
            .sampler(SamplerKind::Hmc { step: 0.05, n_leapfrog: 12 })
            .method(CombineMethod::Parametric)
            .seed(99)
            .build();
        let out = pipeline::run_native(&cfg, &data)?;
        println!(
            "  sampling={:.2}s (max worker), accept(mean)={:.2}",
            out.timing.sampling_secs,
            out.metrics.mean_accept_rate()
        );

        if fig1 {
            // Dump the 2-d marginals that Figure 1 plots.
            let dir = Path::new("results/fig1");
            for sub in &out.subposteriors {
                let marg = sub.samples.select_dims(&[0, 1])?;
                io::write_samples_csv(
                    &dir.join(format!("m{machines}_sub{}.csv", sub.machine)),
                    &marg,
                )?;
            }
            for &(method, name) in &[
                (CombineMethod::Parametric, "product"),
                (CombineMethod::SubpostAvg, "subpostAvg"),
            ] {
                let c = repro::combine::combine(
                    method,
                    &out.subposteriors,
                    t,
                    5,
                )?;
                io::write_samples_csv(
                    &dir.join(format!("m{machines}_{name}.csv")),
                    &c.select_dims(&[0, 1])?,
                )?;
            }
            io::write_samples_csv(
                &dir.join(format!("m{machines}_truth.csv")),
                &groundtruth.samples.select_dims(&[0, 1])?,
            )?;
            println!("  wrote results/fig1/ for M={machines}");
            continue;
        }

        // Score on the first 2-d marginal (full-dimensional KDE-L2
        // saturates for concentrated posteriors at d ≳ 10).
        let truth_marg = groundtruth.samples.select_dims(&[0, 1])?;
        for &method in CombineMethod::all() {
            let t0 = std::time::Instant::now();
            let combined =
                repro::combine::combine(method, &out.subposteriors, t, 5)?;
            let secs = t0.elapsed().as_secs_f64();
            let err = l2_distance_subsampled(
                &combined.select_dims(&[0, 1])?,
                &truth_marg,
                400,
            );
            println!("  {:20} L2={:.4}  combine={:.2}s", method.name(), err, secs);
            summary.push(
                &format!("{}_M{machines}", method.name()),
                vec![machines as f64, err, secs],
            );
        }
    }
    if !fig1 {
        summary.write_csv(Path::new("results/fig2_summary.csv"))?;
        println!("wrote results/fig2_summary.csv");
    }
    Ok(())
}
