//! Figure 4 scenario: multimodal Gaussian-mixture posterior
//! (paper section 8.2).
//!
//!     cargo run --release --example multimodal_gmm -- [--quick]
//!
//! Samples the posterior over mixture component means with
//! permutation-augmented MCMC on M=10 machines, combines with every
//! method, and reports how many of the label-permutation modes each
//! method's μ₀-marginal recovers. The asymptotically biased methods
//! (parametric, subpostAvg) collapse the modes; the nonparametric and
//! semiparametric procedures preserve them. Draws for plotting land in
//! `results/fig4/`.

use std::path::Path;

use repro::combine::CombineMethod;
use repro::config::PipelineConfig;
use repro::coordinator::pipeline;
use repro::data::{io, synth};
use repro::sampler::SamplerKind;
use repro::types::SampleMatrix;

/// Count which of the K true component locations the 2-d marginal draws
/// visit (a mode is "recovered" when ≥ 2% of draws land within r of it).
fn modes_recovered(
    draws2d: &SampleMatrix,
    centers: &[Vec<f64>],
    r: f64,
) -> usize {
    let t = draws2d.len() as f64;
    centers
        .iter()
        .filter(|c| {
            let hits = draws2d
                .rows()
                .filter(|row| {
                    repro::math::linalg::sq_dist(row, &c[..2]) < r * r
                })
                .count();
            hits as f64 / t >= 0.02
        })
        .count()
}

fn main() -> repro::error::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick");
    let (n, k, t) = if quick { (5_000, 4, 1_000) } else { (50_000, 10, 2_000) };
    let sep = 5.0;
    let data = synth::gmm(n, k, 2, sep, 77);
    let centers = synth::gmm_true_means(k, 2, sep);

    // RWM with label-permutation symmetry moves, as in the paper.
    let cfg = PipelineConfig::builder("gmm")
        .machines(10)
        .samples_per_machine(t)
        .sampler(SamplerKind::Rwm { scale: 0.05 })
        .method(CombineMethod::Nonparametric)
        .seed(3)
        .build();
    println!("sampling {} machines (K={k} components)…", cfg.machines);
    let out = pipeline::run_native(&cfg, &data)?;
    println!(
        "  accept(mean)={:.2}, sampling={:.1}s",
        out.metrics.mean_accept_rate(),
        out.timing.sampling_secs
    );

    let dir = Path::new("results/fig4");
    // Overlaid subposterior draws (μ₀ marginal), as in Fig 4 top-middle.
    let mut pooled = SampleMatrix::new(2);
    for sub in &out.subposteriors {
        pooled.extend(&sub.samples.select_dims(&[0, 1])?)?;
    }
    io::write_samples_csv(&dir.join("subposteriors.csv"), &pooled)?;

    println!("\nμ₀-marginal modes recovered (of {k} permutation modes):");
    let methods = [
        CombineMethod::Nonparametric,
        CombineMethod::Semiparametric,
        CombineMethod::SemiparametricNw,
        CombineMethod::Parametric,
        CombineMethod::SubpostAvg,
    ];
    for &method in &methods {
        let combined =
            repro::combine::combine(method, &out.subposteriors, t, 11)?;
        let marg = combined.select_dims(&[0, 1])?;
        let modes = modes_recovered(&marg, &centers, 1.5);
        println!("  {:20} {modes}/{k}", method.name());
        io::write_samples_csv(
            &dir.join(format!("{}.csv", method.name())),
            &marg,
        )?;
    }
    println!("\nwrote results/fig4/*.csv");
    println!(
        "expected shape (paper Fig. 4): nonparametric/semiparametric keep \
         all modes; parametric and subpostAvg collapse to one blob."
    );
    Ok(())
}
