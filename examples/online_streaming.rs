//! Online combination demo (paper section 4): the leader combines
//! *while* workers are still sampling, so full-posterior estimates are
//! available mid-run and sharpen as draws stream in.
//!
//!     cargo run --release --example online_streaming

use std::sync::mpsc::channel;

use repro::combine::CombineMethod;
use repro::coordinator::partition::Partitioner;
use repro::coordinator::worker::{run_worker, DrawMsg};
use repro::coordinator::Leader;
use repro::data::synth;
use repro::rng::Pcg64;
use repro::sampler::SamplerKind;

fn main() -> repro::error::Result<()> {
    let (n, machines, t) = (20_000, 5, 3_000);
    let data = synth::gaussian(n, 2, 11);
    let shards = Partitioner::Contiguous.split(n, machines, 0)?;
    let prior_w = 1.0 / machines as f64;

    let (tx, rx) = channel::<DrawMsg>();
    let mut root = Pcg64::seed_from(42);
    let rngs: Vec<Pcg64> =
        (0..machines).map(|m| root.split(m as u64)).collect();

    std::thread::scope(|scope| -> repro::error::Result<()> {
        for (m, rng) in rngs.into_iter().enumerate() {
            let tx = tx.clone();
            let data = &data;
            let shards = &shards;
            scope.spawn(move || {
                let target = data.subposterior(&shards[m], prior_w).unwrap();
                run_worker(
                    m,
                    target.as_ref(),
                    SamplerKind::Hmc { step: 0.3, n_leapfrog: 8 }.build(2),
                    t,
                    t / 5,
                    1,
                    rng,
                    Some(&tx),
                );
            });
        }
        drop(tx);

        // The leader reports a posterior estimate every time another 20%
        // of the stream arrives — no worker ever waits for it.
        let mut leader = Leader::new(machines, 2);
        let total = machines * t;
        let mut next_report = total / 5;
        println!("streaming {total} draws from {machines} workers…\n");
        println!("{:>8} {:>12} {:>24}", "draws", "min-buffer", "online parametric mean");
        for msg in rx.iter() {
            leader.ingest(&msg)?;
            if leader.combiner().total_received() >= next_report {
                let est = leader.combiner().parametric_draws(500, 1)?;
                let mean = est.mean();
                println!(
                    "{:>8} {:>12} [{:>8.4}, {:>8.4}]",
                    leader.combiner().total_received(),
                    leader.combiner().min_buffer_len(),
                    mean[0],
                    mean[1]
                );
                next_report += total / 5;
            }
            if leader.all_finished() {
                break;
            }
        }

        // Final asymptotically exact draws from the buffered streams.
        let exact =
            leader.draws(CombineMethod::Semiparametric, 2_000, 3)?;
        let mean = exact.mean();
        println!(
            "\nfinal semiparametric mean: [{:.4}, {:.4}] (true ≈ [1.0, 1.1])",
            mean[0], mean[1]
        );
        println!(
            "scalars transferred: {} (= d·T·M = {})",
            leader.scalars_received,
            2 * t * machines
        );
        Ok(())
    })
}
