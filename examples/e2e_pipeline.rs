//! END-TO-END DRIVER: the full three-layer stack on a real workload.
//!
//!     make artifacts && cargo run --release --example e2e_pipeline -- [--quick]
//!
//! Proves all layers compose:
//!   L1  Pallas logistic kernel  → lowered inside the L2 graphs
//!   L2  JAX subposterior + fused 10-step leapfrog → HLO text artifacts
//!   L3  rust coordinator: partition → M HMC workers evaluating the
//!       subposterior THROUGH PJRT (python is not running) → streaming →
//!       combination → evaluation
//!
//! Workload: Bayesian logistic regression, N=50k observations, d=50,
//! M=10 machines (the paper's section 8.1.1 setup; --quick runs d=8,
//! N=4k, M=8 on the small artifacts). Reports:
//!   * native-vs-runtime log-density parity on random θ,
//!   * posterior L2 error vs a native groundtruth chain, per method,
//!   * fused-trajectory telemetry and wall-clock breakdown.
//! The run is recorded in EXPERIMENTS.md §E2E.

use std::path::Path;
use std::time::Instant;

use repro::combine::CombineMethod;
use repro::config::PipelineConfig;
use repro::coordinator::partition::Partitioner;
use repro::coordinator::pipeline;
use repro::data::{io, synth};
use repro::evaluation::l2_distance_subsampled;
use repro::model::LogDensity;
use repro::rng::Pcg64;
use repro::runtime::{RuntimeClient, XlaDensity};
use repro::sampler::SamplerKind;

fn main() -> repro::error::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick");
    let (n, d, machines, t) =
        if quick { (4_000, 8, 8, 400) } else { (50_000, 50, 10, 1_200) };

    println!("=== E2E: logistic N={n} d={d} M={machines} (PJRT runtime) ===");
    let data = synth::logistic(n, d, 1234);

    // --- Runtime setup: load + compile artifacts once. -----------------
    let t_setup = Instant::now();
    let client = RuntimeClient::cpu(Path::new("artifacts"))?;
    println!("PJRT platform: {}", client.platform());
    let shards = Partitioner::Contiguous.split(n, machines, 0)?;
    let prior_w = 1.0 / machines as f64;
    let models: Vec<XlaDensity> = shards
        .iter()
        .map(|idx| XlaDensity::from_shard(&client, &data, idx, prior_w))
        .collect::<repro::error::Result<_>>()?;
    println!(
        "loaded {} shard models ({}, fused_hmc={}) in {:.2}s",
        models.len(),
        models[0].artifact_name(),
        models[0].has_fused_hmc(),
        t_setup.elapsed().as_secs_f64()
    );

    // --- Layer-parity check: runtime vs native on random θ. ------------
    let native0 = data.subposterior(&shards[0], prior_w)?;
    let mut rng = Pcg64::seed_from(5);
    let mut max_rel = 0.0f64;
    for _ in 0..5 {
        let theta: Vec<f64> = (0..d).map(|_| 0.3 * rng.normal()).collect();
        let (lp_n, g_n) = native0.logp_grad(&theta);
        let (lp_x, g_x) = models[0].logp_grad(&theta);
        max_rel = max_rel.max((lp_n - lp_x).abs() / lp_n.abs().max(1.0));
        for j in 0..d {
            max_rel =
                max_rel.max((g_n[j] - g_x[j]).abs() / g_n[j].abs().max(1.0));
        }
    }
    println!("native↔runtime max relative diff: {max_rel:.2e}");
    assert!(max_rel < 1e-3, "runtime/native parity violated");

    // --- Parallel sampling through the runtime. -------------------------
    let cfg = PipelineConfig::builder("logistic")
        .machines(machines)
        .samples_per_machine(t)
        .sampler(SamplerKind::Hmc { step: 0.05, n_leapfrog: 10 })
        .method(CombineMethod::Semiparametric)
        .seed(99)
        .build();
    let boxed: Vec<Box<dyn LogDensity>> = models
        .into_iter()
        .map(|m| Box::new(m) as Box<dyn LogDensity>)
        .collect();
    let t_sample = Instant::now();
    let out = pipeline::run_sequential(&cfg, boxed)?;
    println!(
        "sampled {}×{} draws through PJRT in {:.1}s \
         (cluster-model sampling time: {:.2}s = max worker)",
        machines,
        t,
        t_sample.elapsed().as_secs_f64(),
        out.timing.sampling_secs
    );
    println!("{}", out.metrics);

    // --- Groundtruth: long native full-data chain. ----------------------
    println!("sampling native groundtruth chain…");
    let gt_cfg = PipelineConfig::builder("logistic")
        .machines(1)
        .samples_per_machine(if quick { 1_200 } else { 3_000 })
        .sampler(SamplerKind::Hmc { step: 0.02, n_leapfrog: 12 })
        .seed(7)
        .build();
    let groundtruth = pipeline::run_single_chain(&gt_cfg, &data)?;

    // --- Score every combination method. --------------------------------
    let mut table = io::Table::new(&["l2_error", "combine_secs"]);
    println!("\nposterior L2 error vs groundtruth (2-d marginal):");
    let truth_marg = groundtruth.samples.select_dims(&[0, 1])?;
    for &method in CombineMethod::all() {
        let t0 = Instant::now();
        let combined =
            repro::combine::combine(method, &out.subposteriors, t, 17)?;
        let secs = t0.elapsed().as_secs_f64();
        let err = l2_distance_subsampled(
            &combined.select_dims(&[0, 1])?,
            &truth_marg,
            300,
        );
        println!("  {:20} L2={err:.4}  ({secs:.2}s)", method.name());
        table.push(method.name(), vec![err, secs]);
    }
    table.write_csv(Path::new("results/e2e_logistic.csv"))?;
    println!("\nwrote results/e2e_logistic.csv — record in EXPERIMENTS.md §E2E");
    Ok(())
}
