//! Leader-daemon integration: concurrent RPJOB1 jobs through an
//! in-process `leaderd`, checked for the subsystem's one hard promise
//! — every job's retained draws are byte-identical to the solo run of
//! the same spec, at any concurrency, interleaving, io-driver, or
//! failure policy — plus the scheduling behaviors around it (FIFO run
//! slots, per-job endpoint lists over a shared worker fleet, chaos +
//! retry, graceful drain).

use std::io::{BufRead, BufReader, Write};
use std::process::{Child, Command, Stdio};
use std::sync::mpsc;
use std::thread::JoinHandle;
use std::time::Duration;

use repro::config::PipelineConfig;
use repro::coordinator::pipeline;
use repro::coordinator::server::client;
use repro::coordinator::server::{
    leaderd, DaemonSummary, JobSpec, JobState, LeaderdOptions, Shutdown,
};
use repro::data::synth;
use repro::error::Result;
use repro::types::SampleMatrix;

/// Captures the daemon's `LISTENING <addr>` announce line (which
/// `writeln!` may deliver across several `write` calls) and hands the
/// bound address to the test thread once it is complete.
struct Announcer {
    buf: Vec<u8>,
    tx: mpsc::Sender<String>,
    sent: bool,
}

impl Announcer {
    fn channel() -> (Announcer, mpsc::Receiver<String>) {
        let (tx, rx) = mpsc::channel();
        (Announcer { buf: Vec::new(), tx, sent: false }, rx)
    }
}

impl Write for Announcer {
    fn write(&mut self, b: &[u8]) -> std::io::Result<usize> {
        self.buf.extend_from_slice(b);
        if !self.sent {
            if let Some(pos) = self.buf.iter().position(|&c| c == b'\n') {
                let line = String::from_utf8_lossy(&self.buf[..pos]);
                if let Some(rest) = line.trim().strip_prefix("LISTENING") {
                    let _ = self.tx.send(rest.trim().to_string());
                    self.sent = true;
                }
            }
        }
        Ok(b.len())
    }

    fn flush(&mut self) -> std::io::Result<()> {
        Ok(())
    }
}

/// Boot an in-process leader daemon on an ephemeral port; returns its
/// bound address, the shutdown handle, and the summary-bearing join
/// handle.
fn boot(
    opts: LeaderdOptions,
) -> (String, Shutdown, JoinHandle<Result<DaemonSummary>>) {
    let (mut ann, rx) = Announcer::channel();
    let shutdown = Shutdown::new();
    let sd = shutdown.clone();
    let handle = std::thread::spawn(move || {
        leaderd("127.0.0.1:0", &opts, &sd, &mut ann)
    });
    let addr = rx
        .recv_timeout(Duration::from_secs(10))
        .expect("leaderd must announce LISTENING");
    (addr, shutdown, handle)
}

/// One real `repro serve` worker daemon with extra flags; killed on
/// drop.
struct Daemon {
    child: Child,
    addr: String,
}

impl Daemon {
    fn spawn(extra: &[&str]) -> Daemon {
        let mut child = Command::new(env!("CARGO_BIN_EXE_repro"))
            .args(["serve", "--listen", "127.0.0.1:0"])
            .args(extra)
            .stdin(Stdio::null())
            .stdout(Stdio::piped())
            .stderr(Stdio::null())
            .spawn()
            .expect("spawning repro serve");
        let stdout = child.stdout.take().unwrap();
        let mut line = String::new();
        BufReader::new(stdout).read_line(&mut line).unwrap();
        let addr = line
            .trim()
            .strip_prefix("LISTENING ")
            .unwrap_or_else(|| panic!("bad announce line {line:?}"))
            .to_string();
        Daemon { child, addr }
    }
}

impl Drop for Daemon {
    fn drop(&mut self) {
        self.child.kill().ok();
        self.child.wait().ok();
    }
}

fn assert_bit_identical(a: &SampleMatrix, b: &SampleMatrix) {
    assert_eq!(a.len(), b.len(), "draw count");
    assert_eq!(a.dim(), b.dim(), "dim");
    for i in 0..a.len() {
        let (ra, rb) = (a.row(i), b.row(i));
        for j in 0..a.dim() {
            assert_eq!(
                ra[j].to_bits(),
                rb[j].to_bits(),
                "draw {i} coordinate {j} diverged"
            );
        }
    }
}

/// Two same-spec jobs submitted concurrently both come back
/// byte-identical to the solo in-thread run — the determinism-under-
/// multiplexing contract — and the daemon's exit summary accounts for
/// both.
#[test]
fn concurrent_same_spec_jobs_match_solo_native_run() {
    let cfg = PipelineConfig::builder("gaussian")
        .machines(4)
        .samples_per_machine(300)
        .seed(4242)
        .build();
    let (n, d) = (1200, 3);
    let data = synth::by_name(&cfg.model, n, d, cfg.seed).unwrap();
    let solo = pipeline::run_native(&cfg, &data).unwrap();

    let opts = LeaderdOptions {
        max_concurrent_jobs: 2,
        max_jobs: Some(2),
        ..Default::default()
    };
    let (addr, _shutdown, daemon) = boot(opts);
    let spec = JobSpec::from_config(&cfg, n, d);
    let outcomes: Vec<_> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..2)
            .map(|_| {
                let (spec, addr) = (spec.clone(), addr.clone());
                s.spawn(move || {
                    client::submit(&addr, &spec, &mut |_| {}).unwrap()
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    let summary = daemon.join().unwrap().unwrap();
    for outcome in &outcomes {
        assert_bit_identical(&outcome.combined, &solo.combined);
    }
    assert_eq!(summary.metrics.jobs_accepted, 2);
    assert_eq!(summary.metrics.jobs_failed, 0);
    assert_eq!(summary.metrics.job_queue_wait_ms.len(), 2);
    assert!(summary.jobs.iter().all(|j| j.state == JobState::Done));
}

/// Two different-seed jobs forced through a single run slot stay
/// isolated: each matches its own solo run (no RNG or combiner state
/// bleeds across jobs), and both queue-wait rows are reported.
#[test]
fn single_slot_daemon_serializes_jobs_without_cross_talk() {
    let (n, d) = (900, 2);
    let cfgs: Vec<PipelineConfig> = [11u64, 22]
        .iter()
        .map(|&seed| {
            PipelineConfig::builder("gaussian")
                .machines(3)
                .samples_per_machine(250)
                .seed(seed)
                .build()
        })
        .collect();
    let solos: Vec<SampleMatrix> = cfgs
        .iter()
        .map(|cfg| {
            let data = synth::by_name(&cfg.model, n, d, cfg.seed).unwrap();
            pipeline::run_native(cfg, &data).unwrap().combined
        })
        .collect();

    let opts = LeaderdOptions {
        max_concurrent_jobs: 1,
        max_jobs: Some(2),
        ..Default::default()
    };
    let (addr, _shutdown, daemon) = boot(opts);
    let outcomes: Vec<_> = std::thread::scope(|s| {
        let handles: Vec<_> = cfgs
            .iter()
            .map(|cfg| {
                let spec = JobSpec::from_config(cfg, n, d);
                let addr = addr.clone();
                s.spawn(move || {
                    client::submit(&addr, &spec, &mut |_| {}).unwrap()
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    let summary = daemon.join().unwrap().unwrap();
    for (outcome, solo) in outcomes.iter().zip(&solos) {
        assert_bit_identical(&outcome.combined, solo);
    }
    assert_eq!(summary.metrics.jobs_accepted, 2);
    assert_eq!(summary.metrics.jobs_failed, 0);
    assert_eq!(summary.jobs.len(), 2);
}

/// Socket jobs with *per-job endpoint lists* over a shared fleet —
/// overlapping on one worker, one endpoint chaos-delayed, retry policy
/// armed, and (on unix) one job under the reactor io-driver — all
/// byte-identical to their solo in-thread runs.
#[test]
fn socket_jobs_with_per_job_endpoints_and_chaos_match_native() {
    use repro::config::FailurePolicy;
    let fleet = [
        Daemon::spawn(&[]),
        Daemon::spawn(&[]),
        Daemon::spawn(&["--fault", "delay-ms:2"]),
    ];
    let (n, d) = (800, 2);
    // Job 1: threads driver over workers {0, 1}, retry armed.
    let cfg1 = PipelineConfig::builder("gaussian")
        .machines(4)
        .samples_per_machine(150)
        .seed(7)
        .workers(&format!("{},{}", fleet[0].addr, fleet[1].addr))
        .failure_policy(FailurePolicy::Retry)
        .build();
    // Job 2: workers {1, 2} — sharing worker 1 with job 1, plus the
    // chaos-delayed endpoint — under the reactor driver where the host
    // has one, the threads driver elsewhere.
    let mut b2 = PipelineConfig::builder("gaussian")
        .machines(4)
        .samples_per_machine(150)
        .seed(31)
        .workers(&format!("{},{}", fleet[1].addr, fleet[2].addr))
        .failure_policy(FailurePolicy::Retry);
    #[cfg(unix)]
    {
        b2 = b2.io_driver(repro::config::IoDriver::Reactor);
    }
    let cfg2 = b2.build();

    let solos: Vec<SampleMatrix> = [&cfg1, &cfg2]
        .iter()
        .map(|cfg| {
            let data = synth::by_name(&cfg.model, n, d, cfg.seed).unwrap();
            pipeline::run_native(cfg, &data).unwrap().combined
        })
        .collect();

    let opts = LeaderdOptions {
        max_concurrent_jobs: 2,
        max_jobs: Some(2),
        ..Default::default()
    };
    let (addr, _shutdown, daemon) = boot(opts);
    let outcomes: Vec<_> = std::thread::scope(|s| {
        let handles: Vec<_> = [&cfg1, &cfg2]
            .iter()
            .map(|cfg| {
                let spec = JobSpec::from_config(cfg, n, d);
                let addr = addr.clone();
                s.spawn(move || {
                    client::submit(&addr, &spec, &mut |_| {}).unwrap()
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    let summary = daemon.join().unwrap().unwrap();
    for (outcome, solo) in outcomes.iter().zip(&solos) {
        assert_bit_identical(&outcome.combined, solo);
    }
    assert_eq!(summary.metrics.jobs_accepted, 2);
    assert_eq!(summary.metrics.jobs_failed, 0);
}

/// Graceful drain: triggering shutdown mid-job lets the in-flight job
/// finish normally, refuses a late submission with an in-band error,
/// and the daemon returns its summary (exit 0 at the CLI).
#[test]
fn drain_finishes_inflight_job_and_refuses_new_submissions() {
    // A chaos-delayed worker gives job 1 a guaranteed-long runtime
    // (every frame write sleeps 25 ms), so the drain provably overlaps
    // a running job instead of racing a fast one.
    let worker = Daemon::spawn(&["--fault", "delay-ms:25"]);
    let cfg = PipelineConfig::builder("gaussian")
        .machines(3)
        .samples_per_machine(120)
        .seed(99)
        .workers(&worker.addr)
        .build();
    let (n, d) = (600, 2);
    let opts =
        LeaderdOptions { max_concurrent_jobs: 1, ..Default::default() };
    let (addr, shutdown, daemon) = boot(opts);
    let spec = JobSpec::from_config(&cfg, n, d);

    let (state_tx, state_rx) = mpsc::channel();
    let job1 = {
        let (addr, spec) = (addr.clone(), spec.clone());
        std::thread::spawn(move || {
            client::submit(&addr, &spec, &mut |u| {
                let _ = state_tx.send(u.state);
            })
        })
    };
    // Wait until job 1 is actually running, then pull the plug.
    loop {
        match state_rx.recv_timeout(Duration::from_secs(20)).unwrap() {
            JobState::Running => break,
            _ => continue,
        }
    }
    shutdown.trigger();
    // Give the accept loop (25 ms poll) time to flip into draining.
    std::thread::sleep(Duration::from_millis(300));
    let refused = client::submit(&addr, &spec, &mut |_| {}).unwrap_err();
    assert!(
        refused.to_string().contains("refused"),
        "late submission must be refused in-band, got: {refused}"
    );
    let outcome = job1
        .join()
        .unwrap()
        .expect("in-flight job must finish during drain");
    assert!(!outcome.combined.is_empty());
    let summary = daemon
        .join()
        .unwrap()
        .expect("daemon must exit cleanly after drain");
    assert_eq!(summary.metrics.jobs_accepted, 1);
    assert_eq!(summary.metrics.jobs_failed, 0);
    assert_eq!(summary.jobs[0].state, JobState::Done);
}
