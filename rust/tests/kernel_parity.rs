//! Kernel-backend parity suite — the tentpole's acceptance gate.
//!
//! The blocked CPU kernel reorganizes the combine stage's dense loops
//! for ILP/SIMD but must never change a bit of output: for a fixed
//! seed, retained draws are **byte-identical** across
//! `--combine-backend naive` and `blocked`, at any thread count, for
//! every IMG-based combiner (semiparametric full/nw weights,
//! nonparametric, pairwise tree). The device backend is required to
//! fail *structurally* offline (no panics, no silent fallback).
//!
//! CI runs this file in the `kernel-parity` job.

use repro::combine::{
    combine_sets_with, CombineMethod, CombineTuning,
    DEFAULT_ANNEAL_CACHE_BUDGET,
};
use repro::error::Error;
use repro::kernel::{
    BlockedCpuKernel, CombineKernel, CombineKernelKind, NaiveKernel,
};
use repro::math::linalg::Mat;
use repro::math::mvn::Mvn;
use repro::rng::Pcg64;
use repro::types::SampleMatrix;

fn gaussian_sets(
    seed: u64,
    mus: &[Vec<f64>],
    var: f64,
    t: usize,
) -> Vec<SampleMatrix> {
    let mut rng = Pcg64::seed_from(seed);
    mus.iter()
        .map(|mu| {
            Mvn::new(mu.clone(), Mat::scaled_identity(mu.len(), var))
                .unwrap()
                .sample_n(t, &mut rng)
        })
        .collect()
}

fn tuning(kernel: CombineKernelKind, threads: usize) -> CombineTuning {
    CombineTuning {
        threads,
        cache_budget_bytes: DEFAULT_ANNEAL_CACHE_BUDGET,
        kernel,
    }
}

/// Run one method under both CPU backends at 1/2/4 threads and demand
/// byte-identity everywhere (including across thread counts, which
/// pins the kernel seam against scheduling effects).
fn assert_backend_parity(method: CombineMethod, sets: &[SampleMatrix]) {
    let refs: Vec<&SampleMatrix> = sets.iter().collect();
    let base = combine_sets_with(
        method,
        &refs,
        900,
        13,
        &tuning(CombineKernelKind::Naive, 1),
    )
    .unwrap();
    assert_eq!(base.len(), 900);
    for threads in [1usize, 2, 4] {
        for kernel in [CombineKernelKind::Naive, CombineKernelKind::Blocked]
        {
            let out = combine_sets_with(
                method,
                &refs,
                900,
                13,
                &tuning(kernel, threads),
            )
            .unwrap();
            assert_eq!(
                base.as_slice(),
                out.as_slice(),
                "{} diverged under backend {} at {} threads",
                method.name(),
                kernel.name(),
                threads
            );
        }
    }
}

#[test]
fn semiparametric_blocked_matches_naive_at_any_thread_count() {
    let mus = vec![vec![0.3, -0.1, 0.2], vec![0.7, 0.1, 0.4]];
    let sets = gaussian_sets(101, &mus, 1.0, 300);
    assert_backend_parity(CombineMethod::Semiparametric, &sets);
}

#[test]
fn semiparametric_nw_blocked_matches_naive_at_any_thread_count() {
    let mus = vec![vec![0.2, -0.2], vec![0.5, 0.1], vec![0.4, 0.0]];
    let sets = gaussian_sets(103, &mus, 1.0, 250);
    assert_backend_parity(CombineMethod::SemiparametricNw, &sets);
}

#[test]
fn nonparametric_blocked_matches_naive_at_any_thread_count() {
    let mus = vec![vec![0.5, -0.5], vec![1.0, 0.0]];
    let sets = gaussian_sets(105, &mus, 1.0, 300);
    assert_backend_parity(CombineMethod::Nonparametric, &sets);
}

#[test]
fn pairwise_blocked_matches_naive_at_any_thread_count() {
    // Five machines: an odd carry plus two tree levels.
    let mus: Vec<Vec<f64>> =
        [0.6, 0.8, 1.0, 1.2, 1.4].iter().map(|&m| vec![m, -m]).collect();
    let sets = gaussian_sets(107, &mus, 1.0, 200);
    assert_backend_parity(CombineMethod::Pairwise, &sets);
}

/// The table kernels agree bit-for-bit even when a machine's draws
/// contain non-finite values (a diverged worker chain): ∞ and NaN
/// propagate through the blocked panels exactly as through the scalar
/// loop — weight-table corruption must be *identical*, not merely
/// similar, or backend choice would change downstream accept
/// decisions.
#[test]
fn nonfinite_table_entries_are_bitwise_identical_across_cpu_backends() {
    let mvn = Mvn::new(
        vec![0.1, -0.4, 0.3],
        Mat::from_vec(
            vec![2.0, 0.5, 0.1, 0.5, 1.5, 0.2, 0.1, 0.2, 1.1],
            3,
            3,
        )
        .unwrap(),
    )
    .unwrap();
    let mut rng = Pcg64::seed_from(109);
    let mut set = mvn.sample_n(40, &mut rng);
    set.push(&[f64::INFINITY, 0.0, 1.0]);
    set.push(&[f64::NEG_INFINITY, f64::NAN, -2.0]);
    set.push(&[f64::MAX, -f64::MAX, 0.5]);
    let naive = NaiveKernel.logpdf_table(&mvn, &set).unwrap();
    let blocked =
        BlockedCpuKernel::default().logpdf_table(&mvn, &set).unwrap();
    assert!(
        naive.iter().any(|v| !v.is_finite()),
        "the poisoned rows must actually produce non-finite entries"
    );
    assert_eq!(naive.len(), blocked.len());
    for (t, (a, b)) in naive.iter().zip(&blocked).enumerate() {
        assert_eq!(
            a.to_bits(),
            b.to_bits(),
            "table entry {t}: naive {a} vs blocked {b}"
        );
    }
}

/// `--combine-backend device` offline: a structured
/// `Error::KernelUnavailable` naming the backend, surfaced before any
/// combine work runs — never a panic, never a silent fallback to CPU.
#[test]
fn device_backend_offline_is_a_structured_error() {
    let sets = gaussian_sets(111, &[vec![0.0], vec![0.5]], 1.0, 50);
    let refs: Vec<&SampleMatrix> = sets.iter().collect();
    let err = combine_sets_with(
        CombineMethod::Semiparametric,
        &refs,
        100,
        7,
        &tuning(CombineKernelKind::Device, 2),
    )
    .unwrap_err();
    match &err {
        Error::KernelUnavailable { backend, reason } => {
            assert_eq!(*backend, "device");
            assert!(!reason.is_empty());
        }
        other => panic!("expected KernelUnavailable, got {other:?}"),
    }
}
