//! Runtime integration: PJRT artifacts vs the native backend, and the
//! full sequential pipeline through compiled HLO.
//!
//! These tests require `make artifacts`; they skip (with a note) when
//! the artifact directory is absent so `cargo test` stays runnable on a
//! fresh checkout.

use std::path::{Path, PathBuf};

use repro::config::PipelineConfig;
use repro::coordinator::partition::Partitioner;
use repro::coordinator::pipeline;
use repro::data::synth;
use repro::model::LogDensity;
use repro::rng::Pcg64;
use repro::runtime::{RuntimeClient, XlaDensity};
use repro::sampler::SamplerKind;

fn artifacts() -> Option<PathBuf> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("skipping runtime test: run `make artifacts` first");
        None
    }
}

/// Native and PJRT log-densities agree on random θ for every model that
/// has artifacts (gaussian, logistic, gmm, poisson_gamma).
#[test]
fn native_runtime_parity_all_models() {
    let Some(dir) = artifacts() else { return };
    let client = RuntimeClient::cpu(&dir).unwrap();
    let mut rng = Pcg64::seed_from(1);

    let cases = vec![
        ("gaussian", synth::gaussian(400, 2, 5), 0.3),
        ("logistic", synth::logistic(400, 8, 6), 0.3),
        ("gmm", synth::gmm(4_000, 10, 2, 5.0, 7), 0.5),
        ("poisson_gamma", synth::poisson_gamma(4_000, 8), 0.2),
    ];
    for (name, data, scale) in cases {
        let idx: Vec<usize> = (0..data.len().min(380)).collect();
        let native = data.subposterior(&idx, 0.25).unwrap();
        let xla =
            XlaDensity::from_shard(&client, &data, &idx, 0.25).unwrap();
        assert_eq!(native.dim(), xla.dim(), "{name} dim");
        for trial in 0..4 {
            let theta: Vec<f64> = match name {
                // GMM θ must sit near data scale for finite f32 logliks.
                "gmm" => {
                    let centers = synth::gmm_true_means(10, 2, 5.0);
                    let mut theta = Vec::with_capacity(20);
                    for c in &centers {
                        for v in c {
                            theta.push(v + scale * rng.normal());
                        }
                    }
                    theta
                }
                _ => (0..native.dim())
                    .map(|_| scale * rng.normal())
                    .collect(),
            };
            let (lp_n, g_n) = native.logp_grad(&theta);
            let (lp_x, g_x) = xla.logp_grad(&theta);
            let tol = 2e-3 * lp_n.abs().max(100.0);
            assert!(
                (lp_n - lp_x).abs() < tol,
                "{name} trial {trial}: logp {lp_n} vs {lp_x}"
            );
            for j in 0..native.dim() {
                let gtol = 2e-3 * g_n[j].abs().max(50.0);
                assert!(
                    (g_n[j] - g_x[j]).abs() < gtol,
                    "{name} grad[{j}]: {} vs {}",
                    g_n[j],
                    g_x[j]
                );
            }
        }
    }
}

/// The fused 10-step leapfrog artifact must match the native leapfrog
/// trajectory step for step (same θ, p, ε).
#[test]
fn fused_trajectory_matches_native_leapfrog() {
    let Some(dir) = artifacts() else { return };
    let client = RuntimeClient::cpu(&dir).unwrap();
    let data = synth::gaussian(300, 2, 9);
    let idx: Vec<usize> = (0..300).collect();
    let native = data.subposterior(&idx, 0.5).unwrap();
    let xla = XlaDensity::from_shard(&client, &data, &idx, 0.5).unwrap();
    assert!(xla.has_fused_hmc());

    let theta = vec![0.9, 1.2];
    let p = vec![0.4, -0.7];
    let eps = 0.05;
    // Native reference trajectory via small manual leapfrog.
    let (mut lp, mut grad) = native.logp_grad(&theta);
    let lp0_native = lp;
    let mut th = theta.clone();
    let mut mom = p.clone();
    for _ in 0..10 {
        for i in 0..2 {
            mom[i] += 0.5 * eps * grad[i];
        }
        for i in 0..2 {
            th[i] += eps * mom[i];
        }
        let (l, g) = native.logp_grad(&th);
        lp = l;
        grad = g;
        for i in 0..2 {
            mom[i] += 0.5 * eps * grad[i];
        }
    }
    let traj = xla.fused_trajectory(&theta, &p, eps, 10).unwrap();
    assert!((traj.logp0 - lp0_native).abs() < 0.05, "logp0");
    assert!((traj.logp - lp).abs() < 0.05, "logp end");
    for i in 0..2 {
        assert!((traj.theta[i] - th[i]).abs() < 1e-3, "theta[{i}]");
        assert!((traj.p[i] - mom[i]).abs() < 1e-3, "p[{i}]");
    }
    // Wrong trajectory length → fused path must refuse (falls back).
    assert!(xla.fused_trajectory(&theta, &p, eps, 7).is_none());
}

/// HMC driven entirely through the runtime recovers the conjugate
/// posterior — the full L1→L2→L3 stack in one assertion.
#[test]
fn runtime_hmc_recovers_exact_posterior() {
    let Some(dir) = artifacts() else { return };
    let client = RuntimeClient::cpu(&dir).unwrap();
    let data = synth::gaussian(2_000, 2, 13);
    let machines = 4;
    let shards = Partitioner::Contiguous.split(2_000, machines, 0).unwrap();
    let models: Vec<Box<dyn LogDensity>> = shards
        .iter()
        .map(|idx| {
            Box::new(
                XlaDensity::from_shard(
                    &client,
                    &data,
                    idx,
                    1.0 / machines as f64,
                )
                .unwrap(),
            ) as Box<dyn LogDensity>
        })
        .collect();
    let cfg = PipelineConfig::builder("gaussian")
        .machines(machines)
        .samples_per_machine(500)
        .sampler(SamplerKind::Hmc { step: 0.1, n_leapfrog: 10 })
        .method(repro::combine::CombineMethod::Parametric)
        .seed(21)
        .build();
    let out = pipeline::run_sequential(&cfg, models).unwrap();

    // Closed-form truth.
    let full = match &data {
        repro::data::Dataset::Gaussian { x, lik_prec, prior_prec } => {
            repro::model::GaussianMean::new(
                x.clone(),
                *lik_prec,
                *prior_prec,
                1.0,
            )
        }
        _ => unreachable!(),
    };
    let exact = full.exact_posterior();
    let mean = out.combined.mean();
    for j in 0..2 {
        assert!(
            (mean[j] - exact.mean()[j]).abs() < 0.05,
            "dim {j}: {} vs {}",
            mean[j],
            exact.mean()[j]
        );
    }
}
