//! Socket-transport integration: spawns real `repro serve` worker
//! daemons on localhost (cargo builds the binary and exports its path
//! as `CARGO_BIN_EXE_repro`) and pins the acceptance criterion that
//! for a fixed seed the retained draws are **byte-identical** across
//! thread mode, pipe-transport process mode, and socket mode at any
//! worker count W ∈ {1, M/2, M}.

use std::io::{BufRead, BufReader};
use std::process::{Child, Command, Stdio};

use repro::combine::CombineMethod;
use repro::config::PipelineConfig;
use repro::coordinator::pipeline;
use repro::data::io::ShardFormat;
use repro::data::synth;

/// One `repro serve` daemon on an ephemeral localhost port; killed on
/// drop so failing tests never leak daemons.
struct Daemon {
    child: Child,
    addr: String,
}

impl Daemon {
    fn spawn() -> Daemon {
        let mut child = Command::new(env!("CARGO_BIN_EXE_repro"))
            .args(["serve", "--listen", "127.0.0.1:0"])
            .stdin(Stdio::null())
            .stdout(Stdio::piped())
            .stderr(Stdio::null())
            .spawn()
            .expect("spawning repro serve");
        let stdout = child.stdout.take().unwrap();
        let mut line = String::new();
        BufReader::new(stdout).read_line(&mut line).unwrap();
        let addr = line
            .trim()
            .strip_prefix("LISTENING ")
            .unwrap_or_else(|| panic!("bad announce line {line:?}"))
            .to_string();
        Daemon { child, addr }
    }

    fn fleet(n: usize) -> (Vec<Daemon>, String) {
        let daemons: Vec<Daemon> = (0..n).map(|_| Daemon::spawn()).collect();
        let spec = daemons
            .iter()
            .map(|d| d.addr.as_str())
            .collect::<Vec<_>>()
            .join(",");
        (daemons, spec)
    }
}

impl Drop for Daemon {
    fn drop(&mut self) {
        self.child.kill().ok();
        self.child.wait().ok();
    }
}

fn assert_byte_identical(
    a: &pipeline::PipelineOutput,
    b: &pipeline::PipelineOutput,
    label: &str,
) {
    assert_eq!(a.subposteriors.len(), b.subposteriors.len());
    for (sa, sb) in a.subposteriors.iter().zip(&b.subposteriors) {
        assert_eq!(
            sa.samples.as_slice(),
            sb.samples.as_slice(),
            "{label}: machine {} draws diverged",
            sa.machine
        );
        assert_eq!(sa.draw_times.len(), sa.samples.len());
        assert!(sa.accept_rate.is_finite());
    }
    assert_eq!(
        a.combined.as_slice(),
        b.combined.as_slice(),
        "{label}: combined output diverged"
    );
    assert_eq!(
        a.metrics.scalars_transferred, b.metrics.scalars_transferred,
        "{label}: leader must stream-ingest the same O(dTM) scalars"
    );
}

/// The acceptance matrix: socket mode at W ∈ {1, M/2, M} for M = 4
/// machines, each fleet compared byte-for-byte against thread mode and
/// against pipe-transport process mode.
#[test]
fn socket_mode_is_byte_identical_at_any_worker_count() {
    let data = synth::gaussian(1_600, 2, 23);
    let base = PipelineConfig::builder("gaussian")
        .machines(4)
        .samples_per_machine(120)
        .method(CombineMethod::Semiparametric)
        .seed(41)
        .build();

    let thread_out = pipeline::run_native(&base, &data).unwrap();
    let mut pc = base.clone();
    pc.process_mode = true;
    pc.worker_bin = env!("CARGO_BIN_EXE_repro").to_string();
    let pipe_out = pipeline::run_process(&pc, &data).unwrap();
    assert_byte_identical(&pipe_out, &thread_out, "pipe vs thread");

    for w in [1usize, 2, 4] {
        let (_daemons, spec) = Daemon::fleet(w);
        let mut sc = base.clone();
        sc.workers = spec;
        let socket_out = pipeline::run_process(&sc, &data).unwrap();
        assert_byte_identical(
            &socket_out,
            &thread_out,
            &format!("socket W={w} vs thread"),
        );
    }
}

/// Socket mode with binary shard spills (the daemons autodetect the
/// format from the magic) — also at W < M, so oversubscription and the
/// binary format compose.
#[test]
fn socket_mode_with_binary_shards_matches_thread_mode() {
    let data = synth::logistic(1_000, 2, 37);
    let base = PipelineConfig::builder("logistic")
        .machines(3)
        .samples_per_machine(100)
        .method(CombineMethod::Parametric)
        .seed(53)
        .shard_format(ShardFormat::Binary)
        .build();
    let thread_out = pipeline::run_native(&base, &data).unwrap();
    let (_daemons, spec) = Daemon::fleet(2);
    let mut sc = base.clone();
    sc.workers = spec;
    let socket_out = pipeline::run_process(&sc, &data).unwrap();
    assert_byte_identical(&socket_out, &thread_out, "socket binary shards");
}

/// Inline shard delivery (`shard_inline = true`): shards ride the
/// socket as binary frames after the manifest, daemons never resolve
/// `shard_path`, and the pipeline output stays byte-identical to
/// thread mode — at W < M so oversubscription and inline delivery
/// compose, and in both spill formats (the daemon autodetects from the
/// inline bytes exactly as it would from a file).
#[test]
fn inline_shards_are_byte_identical_to_thread_mode() {
    let data = synth::gaussian(1_200, 2, 61);
    for format in [ShardFormat::Json, ShardFormat::Binary] {
        let base = PipelineConfig::builder("gaussian")
            .machines(4)
            .samples_per_machine(100)
            .method(CombineMethod::Semiparametric)
            .seed(47)
            .shard_format(format)
            .build();
        let thread_out = pipeline::run_native(&base, &data).unwrap();
        let (_daemons, spec) = Daemon::fleet(2);
        let mut sc = base.clone();
        sc.workers = spec;
        sc.shard_inline = true;
        let socket_out = pipeline::run_process(&sc, &data).unwrap();
        assert_byte_identical(
            &socket_out,
            &thread_out,
            &format!("inline {} shards vs thread", format.name()),
        );
    }
}

/// Tentpole gate over real daemons: the binary draw plane at
/// draw_batch ∈ {1, 7, 64} is byte-identical to thread mode — at
/// W < M so chunked streams and oversubscription compose, with binary
/// shard spills so the daemons take the mmap ingest path too.
#[test]
fn binary_wire_is_byte_identical_over_sockets_at_any_batch() {
    use repro::coordinator::transport::WireFormat;
    let data = synth::gaussian(1_200, 2, 67);
    let base = PipelineConfig::builder("gaussian")
        .machines(4)
        .samples_per_machine(110)
        .method(CombineMethod::Semiparametric)
        .seed(59)
        .shard_format(ShardFormat::Binary)
        .build();
    let thread_out = pipeline::run_native(&base, &data).unwrap();
    let (_daemons, spec) = Daemon::fleet(2);
    for batch in [1usize, 7, 64] {
        let mut sc = base.clone();
        sc.workers = spec.clone();
        sc.wire_format = WireFormat::Binary;
        sc.draw_batch = batch;
        let socket_out = pipeline::run_process(&sc, &data).unwrap();
        assert_byte_identical(
            &socket_out,
            &thread_out,
            &format!("binary wire batch={batch} vs thread"),
        );
    }
}

/// Dialing an endpoint nobody listens on must surface a connect error
/// naming the address, not hang or panic.
#[test]
fn dead_socket_endpoint_surfaces_connect_error() {
    let data = synth::gaussian(400, 1, 3);
    // Bind-then-drop: a localhost port that (very likely) has no
    // listener by the time the pipeline dials it.
    let dead = {
        let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        l.local_addr().unwrap().to_string()
    };
    let mut c = PipelineConfig::builder("gaussian")
        .machines(2)
        .samples_per_machine(40)
        .method(CombineMethod::Parametric)
        .seed(5)
        .build();
    c.workers = dead.clone();
    let err = pipeline::run_process(&c, &data).unwrap_err();
    let text = err.to_string();
    assert!(
        text.contains("connecting to worker") && text.contains(&dead),
        "error should name the dead endpoint, got: {text}"
    );
}
