//! Determinism contract of the parallel combination runtime: for a
//! fixed seed the combined draws are byte-identical at any thread
//! count (1, 4, and auto), and the allocation-free refactors left the
//! reference implementations exactly in agreement with the fast paths.

use repro::combine::nonparametric::{
    nonparametric_naive, nonparametric_threaded, nonparametric_with_context,
    Img,
};
use repro::combine::pairwise::pairwise_threaded;
use repro::combine::semiparametric::{
    semiparametric_nw_threaded, semiparametric_nw_threaded_uncached,
    semiparametric_threaded, semiparametric_threaded_uncached,
};
use repro::combine::{self, CombineMethod, OnlineCombiner};
use repro::math::linalg::Mat;
use repro::math::mvn::Mvn;
use repro::rng::Pcg64;
use repro::types::SampleMatrix;

fn gaussian_sets(
    seed: u64,
    machines: usize,
    dim: usize,
    t: usize,
) -> Vec<SampleMatrix> {
    let mut rng = Pcg64::seed_from(seed);
    (0..machines)
        .map(|m| {
            let mu = vec![0.1 * m as f64; dim];
            Mvn::new(mu, Mat::scaled_identity(dim, 1.0))
                .unwrap()
                .sample_n(t, &mut rng)
        })
        .collect()
}

/// Seed-determinism across thread counts for every IMG-based combiner.
/// `0` asks for all available cores, so this also covers whatever the
/// host machine resolves "auto" to.
#[test]
fn parallel_combiners_are_thread_count_invariant() {
    let sets = gaussian_sets(42, 4, 3, 500);
    let refs: Vec<&SampleMatrix> = sets.iter().collect();
    let t_out = 1600; // several restart chunks
    type Combiner =
        fn(&[&SampleMatrix], usize, u64, usize) -> repro::error::Result<SampleMatrix>;
    let combiners: &[(&str, Combiner)] = &[
        ("nonparametric", nonparametric_threaded),
        ("semiparametric", semiparametric_threaded),
        ("semiparametricNW", semiparametric_nw_threaded),
        ("pairwise", pairwise_threaded),
    ];
    for (name, f) in combiners {
        let base = f(&refs, t_out, 7, 1).unwrap();
        assert_eq!(base.len(), t_out, "{name} draw count");
        for threads in [4usize, 0] {
            let out = f(&refs, t_out, 7, threads).unwrap();
            assert_eq!(
                base.as_slice(),
                out.as_slice(),
                "{name} diverged at threads={threads}"
            );
        }
    }
}

/// The `combine_sets` / `combine_sets_threaded` dispatch pair agree:
/// the single-thread entry point is the threads=1 case of the same
/// runtime, not a separate code path.
#[test]
fn dispatch_single_thread_matches_threaded() {
    let sets = gaussian_sets(5, 3, 2, 400);
    let refs: Vec<&SampleMatrix> = sets.iter().collect();
    for &method in CombineMethod::all() {
        let a = combine::combine_sets(method, &refs, 600, 11).unwrap();
        let b =
            combine::combine_sets_threaded(method, &refs, 600, 11, 1)
                .unwrap();
        assert_eq!(a.as_slice(), b.as_slice(), "{}", method.name());
    }
}

/// Regression guard for the scratch-buffer refactor of the naive
/// reference: the O(d) fast path and the O(dM) naive implementation
/// still produce identical accept decisions and draws from the same
/// RNG stream (complements the module-level test at different sizes).
#[test]
fn fast_path_still_matches_naive_after_refactor() {
    let sets = gaussian_sets(9, 3, 4, 250);
    let refs: Vec<&SampleMatrix> = sets.iter().collect();
    let naive = nonparametric_naive(&refs, 350, 23).unwrap();

    // Reproduce via the public Img fast path over whitened inputs.
    let ctx = combine::CombineContext::prepare(&refs, 1);
    let wsets = ctx.sets().to_vec();
    let wrefs: Vec<&SampleMatrix> = wsets.iter().collect();
    let mut img = Img::new(&wrefs);
    let fast = img.run(350, &mut Pcg64::seed_from(23));
    // Unwhiten the fast draws with the shared scales.
    let mut fast_un = SampleMatrix::new(fast.dim());
    let mut buf = vec![0.0; fast.dim()];
    for row in fast.rows() {
        for (j, (&v, &s)) in row.iter().zip(ctx.scales()).enumerate() {
            buf[j] = v * s;
        }
        fast_un.push(&buf);
    }

    assert_eq!(fast_un.len(), naive.len());
    for i in 0..fast_un.len() {
        for j in 0..fast_un.dim() {
            let a = fast_un.row(i)[j];
            let b = naive.row(i)[j];
            assert!(
                (a - b).abs() < 1e-8,
                "draw {i} dim {j}: fast {a} vs naive {b}"
            );
        }
    }
}

/// Regression pin for the annealed-schedule factorization cache: the
/// cached semiparametric paths (both weight variants) are byte-identical
/// to the uncached reference — which recomputes every per-iteration
/// factorization exactly as the pre-cache implementation did — for a
/// fixed seed at 1, 2 and 4 threads.
#[test]
fn factorization_cache_is_byte_identical_to_uncached() {
    let sets = gaussian_sets(57, 3, 4, 350);
    let refs: Vec<&SampleMatrix> = sets.iter().collect();
    let t_out = 1200; // several restart chunks, annealed schedules shared
    let ref_full = semiparametric_threaded_uncached(&refs, t_out, 19, 1)
        .unwrap();
    let ref_nw = semiparametric_nw_threaded_uncached(&refs, t_out, 19, 1)
        .unwrap();
    for threads in [1usize, 2, 4] {
        let full = semiparametric_threaded(&refs, t_out, 19, threads)
            .unwrap();
        let nw = semiparametric_nw_threaded(&refs, t_out, 19, threads)
            .unwrap();
        assert_eq!(
            ref_full.as_slice(),
            full.as_slice(),
            "cached semiparametric diverged at threads={threads}"
        );
        assert_eq!(
            ref_nw.as_slice(),
            nw.as_slice(),
            "cached semiparametricNW diverged at threads={threads}"
        );
    }
}

/// The pairwise tree's per-level context path: running the
/// nonparametric combiner over a pre-built context equals the plain
/// entry point, and the context build itself is thread-count invariant.
#[test]
fn per_level_context_matches_plain_entry_point() {
    let sets = gaussian_sets(61, 2, 3, 400);
    let refs: Vec<&SampleMatrix> = sets.iter().collect();
    let want = nonparametric_threaded(&refs, 900, 29, 1).unwrap();
    for ctx_threads in [1usize, 3] {
        let ctx = combine::CombineContext::prepare(&refs, ctx_threads);
        for run_threads in [1usize, 4] {
            let got =
                nonparametric_with_context(&ctx, 900, 29, run_threads)
                    .unwrap();
            assert_eq!(
                want.as_slice(),
                got.as_slice(),
                "ctx_threads={ctx_threads} run_threads={run_threads}"
            );
        }
    }
}

/// The context entry point keeps the plain entry point's
/// degenerate-input policy: an empty machine is an error, not a silent
/// empty result.
#[test]
fn with_context_rejects_empty_machine() {
    let a = SampleMatrix::from_rows(vec![1.0, 2.0], 2).unwrap();
    let b = SampleMatrix::new(2);
    let refs = vec![&a, &b];
    let ctx = combine::CombineContext::prepare(&refs, 1);
    assert!(nonparametric_with_context(&ctx, 10, 1, 1).is_err());
}

/// The streaming combiner's threaded path obeys the same determinism
/// contract as the batch combiners, for every IMG-based method.
#[test]
fn online_combiner_threaded_is_thread_count_invariant() {
    let sets = gaussian_sets(63, 3, 2, 300);
    let mut oc = OnlineCombiner::new(3, 2);
    for i in 0..300 {
        for (m, s) in sets.iter().enumerate() {
            oc.push(m, s.row(i)).unwrap();
        }
    }
    for &method in &[
        CombineMethod::Nonparametric,
        CombineMethod::Semiparametric,
        CombineMethod::SemiparametricNw,
        CombineMethod::Pairwise,
    ] {
        let base = oc.combined_draws(method, 700, 31).unwrap();
        for threads in [4usize, 0] {
            let out = oc
                .combined_draws_threaded(method, 700, 31, threads)
                .unwrap();
            assert_eq!(
                base.as_slice(),
                out.as_slice(),
                "{} diverged at threads={threads}",
                method.name()
            );
        }
    }
}

/// Thread-count invariance must also hold when the subposteriors have
/// very different scales (whitening active) and M is odd (pairwise
/// carry path).
#[test]
fn invariance_with_heterogeneous_scales_and_odd_m() {
    let mut rng = Pcg64::seed_from(77);
    let sets: Vec<SampleMatrix> = (0..5)
        .map(|m| {
            let scale = 10f64.powi(m as i32 - 2); // 0.01 … 100
            let mut s = SampleMatrix::new(2);
            for _ in 0..300 {
                s.push(&[scale * rng.normal(), 1.0 + rng.normal()]);
            }
            s
        })
        .collect();
    let refs: Vec<&SampleMatrix> = sets.iter().collect();
    for &method in &[CombineMethod::Nonparametric, CombineMethod::Pairwise] {
        let a = combine::combine_sets_threaded(method, &refs, 800, 3, 1)
            .unwrap();
        let b = combine::combine_sets_threaded(method, &refs, 800, 3, 4)
            .unwrap();
        assert_eq!(a.as_slice(), b.as_slice(), "{}", method.name());
        assert!(a.as_slice().iter().all(|v| v.is_finite()));
    }
}
