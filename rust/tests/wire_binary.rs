//! End-to-end fidelity of the binary draw plane over *real* transports:
//! scripted worker byte streams carrying NaN (with a nonstandard bit
//! payload), ±Inf, and -0.0 are shipped through an actual OS pipe (a
//! fake worker process) and an actual TCP socket (a scripted daemon),
//! and must decode bit-exactly on the leader side. The same streams
//! carry a JSON draw frame whose NaN payload is canonicalized in
//! transit — the documented-lossy JSON contract, pinned here over the
//! wire (unit-pinned in `coordinator::transport`).

use std::io::{BufReader, Write};
use std::path::{Path, PathBuf};

use repro::coordinator::transport::{
    encode_draw, encode_summary, write_frame, write_frame_bytes, DrawChunk,
    FrameReader, PipeTransport, SocketTransport, Transport, WireFormat,
    WireMsg, WorkerConnection, WorkerManifest, WorkerSummary,
};
use repro::coordinator::worker::DrawMsg;

/// A NaN with a distinctive payload: survives binary framing verbatim,
/// canonicalized by the JSON path.
const NAN_PAYLOAD: u64 = 0x7ff8_dead_beef_cafe;

/// 3 rows × dim 2 of adversarial values.
fn weird_thetas() -> Vec<f64> {
    vec![
        f64::from_bits(NAN_PAYLOAD),
        f64::INFINITY,
        f64::NEG_INFINITY,
        -0.0,
        f64::MIN_POSITIVE,
        1.5,
    ]
}

/// The exact bytes a binary-wire worker would put on its stream for
/// this job: one JSON draw frame (mixed streams are legal — the leader
/// sniffs the magic per frame), one binary chunk frame carrying
/// [`weird_thetas`], then the JSON summary frame.
fn scripted_wire_bytes() -> Vec<u8> {
    let mut buf = Vec::new();
    write_frame(
        &mut buf,
        &encode_draw(&DrawMsg {
            machine: 0,
            theta: vec![f64::from_bits(NAN_PAYLOAD), 1.0],
            elapsed: 0.5,
            last: false,
        }),
    )
    .unwrap();
    let chunk = DrawChunk {
        machine: 0,
        dim: 2,
        thetas: weird_thetas(),
        elapsed: vec![0.1, 0.2, 0.3],
        last: true,
    };
    let mut frame = Vec::new();
    chunk.encode_into(&mut frame);
    write_frame_bytes(&mut buf, &frame).unwrap();
    write_frame(
        &mut buf,
        &encode_summary(&WorkerSummary {
            machine: 0,
            accept_rate: 0.5,
            wall_secs: 0.25,
        }),
    )
    .unwrap();
    buf
}

/// A binary-wire manifest for the scripted job. Nothing resolves
/// `shard_path` — the fake endpoints never load a shard.
fn manifest(dir: &Path) -> WorkerManifest {
    WorkerManifest {
        machine: 0,
        machines: 1,
        seed: 7,
        samples: 4,
        burn_in: 0,
        thin: 1,
        prior_weight: 1.0,
        sampler: "rwm:1".into(),
        shard_path: dir.join("unused.bin").to_string_lossy().into_owned(),
        dim: 2,
        shard_inline: false,
        wire_format: WireFormat::Binary,
        draw_batch: 3,
    }
}

/// Drain the connection and assert the scripted stream decoded
/// faithfully: JSON draw (NaN-ness kept, payload canonicalized), then
/// the chunk bit-exact, then the summary, then clean EOF.
fn assert_scripted_stream(conn: &mut dyn WorkerConnection) {
    match conn.recv().unwrap().expect("missing JSON draw frame") {
        WireMsg::Draw(d) => {
            assert!(d.theta[0].is_nan(), "NaN-ness must survive JSON");
            assert_ne!(
                d.theta[0].to_bits(),
                NAN_PAYLOAD,
                "JSON canonicalizes NaN payloads — documented-lossy"
            );
            assert_eq!(d.theta[1], 1.0);
        }
        other => panic!("expected a draw, got {other:?}"),
    }
    match conn.recv().unwrap().expect("missing binary chunk frame") {
        WireMsg::Chunk(c) => {
            assert_eq!(c.machine, 0);
            assert_eq!(c.dim, 2);
            assert_eq!(c.count(), 3);
            assert!(c.last);
            let want: Vec<u64> =
                weird_thetas().iter().map(|v| v.to_bits()).collect();
            let got: Vec<u64> =
                c.thetas.iter().map(|v| v.to_bits()).collect();
            assert_eq!(
                got, want,
                "binary chunk must carry non-finite values bit-exactly"
            );
            assert_eq!(c.elapsed, vec![0.1, 0.2, 0.3]);
        }
        other => panic!("expected a chunk, got {other:?}"),
    }
    assert!(matches!(
        conn.recv().unwrap().expect("missing summary frame"),
        WireMsg::Summary(WorkerSummary { machine: 0, .. })
    ));
    assert!(conn.recv().unwrap().is_none(), "stream must end cleanly");
}

/// Pipe transport: a fake worker process (`exec cat <fixture>`) ships
/// the scripted bytes through a real stdout pipe; the leader-side
/// [`PipeTransport`] connection must decode them bit-exactly.
#[cfg(unix)]
#[test]
fn nonfinite_draws_bit_exact_over_pipe_binary_wire() {
    use std::os::unix::fs::PermissionsExt;
    let dir = std::env::temp_dir().join("repro_wire_binary_pipe");
    std::fs::create_dir_all(&dir).unwrap();
    let fixture = dir.join("frames.bin");
    std::fs::write(&fixture, scripted_wire_bytes()).unwrap();
    let script = dir.join("fake_worker.sh");
    std::fs::write(
        &script,
        format!("#!/bin/sh\nexec cat '{}'\n", fixture.display()),
    )
    .unwrap();
    std::fs::set_permissions(
        &script,
        std::fs::Permissions::from_mode(0o755),
    )
    .unwrap();

    let wm = manifest(&dir);
    let manifest_path = dir.join("worker_0.json");
    wm.save(&manifest_path).unwrap();
    let transport = PipeTransport::new(PathBuf::from(&script), 1);
    let mut conn = transport.connect(0, &wm, &manifest_path).unwrap();
    assert_scripted_stream(conn.as_mut());
    conn.finish().unwrap();
    std::fs::remove_dir_all(&dir).ok();
}

/// Socket transport: a scripted daemon thread accepts one connection,
/// reads the manifest frame (asserting the binary wire was actually
/// negotiated across the socket), and ships the scripted bytes back;
/// the leader-side [`SocketTransport`] connection must decode them
/// bit-exactly.
#[test]
fn nonfinite_draws_bit_exact_over_socket_binary_wire() {
    let dir = std::env::temp_dir().join("repro_wire_binary_socket");
    std::fs::create_dir_all(&dir).unwrap();
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let server = std::thread::spawn(move || -> String {
        let (stream, _) = listener.accept().unwrap();
        let mut frames =
            FrameReader::new(BufReader::new(stream.try_clone().unwrap()));
        let manifest_text = frames
            .read_frame()
            .unwrap()
            .expect("client must send a manifest frame first");
        let mut writer = stream;
        writer.write_all(&scripted_wire_bytes()).unwrap();
        writer.flush().unwrap();
        manifest_text
        // Dropping the stream sends FIN: clean end-of-stream.
    });

    let transport = SocketTransport::from_spec(&addr.to_string()).unwrap();
    let wm = manifest(&dir);
    let mut conn = transport
        .connect(0, &wm, Path::new("unused-manifest-path"))
        .unwrap();
    assert_scripted_stream(conn.as_mut());
    conn.finish().unwrap();
    let manifest_text = server.join().unwrap();
    assert!(
        manifest_text.contains("\"wire_format\":\"binary\"")
            && manifest_text.contains("\"draw_batch\":3"),
        "wire negotiation must cross the socket: {manifest_text}"
    );
    std::fs::remove_dir_all(&dir).ok();
}
