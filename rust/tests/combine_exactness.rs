//! Exactness anchor (DESIGN.md §6): on the conjugate Gaussian model the
//! subposterior product is available in closed form, so every combiner
//! can be scored against mathematical truth rather than another sampler.

use repro::combine::{self, CombineMethod};
use repro::config::PipelineConfig;
use repro::coordinator::pipeline;
use repro::data::{synth, Dataset};
use repro::evaluation::{l2_distance_subsampled, mean_l2_error};
use repro::model::GaussianMean;
use repro::rng::Pcg64;
use repro::sampler::SamplerKind;
use repro::types::SampleMatrix;

fn exact_draws(data: &Dataset, t: usize, seed: u64) -> SampleMatrix {
    let (x, lik_prec, prior_prec) = match data {
        Dataset::Gaussian { x, lik_prec, prior_prec } => {
            (x.clone(), *lik_prec, *prior_prec)
        }
        _ => unreachable!(),
    };
    let full = GaussianMean::new(x, lik_prec, prior_prec, 1.0);
    let mut rng = Pcg64::seed_from(seed);
    full.exact_posterior().sample_n(t, &mut rng)
}

fn run(machines: usize, t: usize) -> (Vec<repro::types::SubposteriorSamples>, Dataset) {
    let data = synth::gaussian(20_000, 2, 101);
    let cfg = PipelineConfig::builder("gaussian")
        .machines(machines)
        .samples_per_machine(t)
        .sampler(SamplerKind::Hmc { step: 0.3, n_leapfrog: 8 })
        .seed(55)
        .build();
    let out = pipeline::run_native(&cfg, &data).unwrap();
    (out.subposteriors, data)
}

/// The product of the M exact subposteriors equals the full posterior —
/// verify the identity the whole method rests on (Eq. 2.1).
#[test]
fn subposterior_product_identity() {
    let data = synth::gaussian(5_000, 2, 7);
    let (x, lik_prec, prior_prec) = match &data {
        Dataset::Gaussian { x, lik_prec, prior_prec } => {
            (x, *lik_prec, *prior_prec)
        }
        _ => unreachable!(),
    };
    let m = 4;
    let shards = repro::coordinator::partition::Partitioner::Contiguous
        .split(x.len(), m, 0)
        .unwrap();
    // Product of subposterior precisions & precision-weighted means.
    let mut prec_sum = 0.0;
    let mut mean_num = vec![0.0; 2];
    for idx in &shards {
        let shard = repro::data::select_rows(x, idx).unwrap();
        let sub = GaussianMean::new(shard, lik_prec, prior_prec, 1.0 / m as f64);
        let post = sub.exact_posterior();
        // Recover precision from the closed form: P = n·λ + w·τ.
        let p = idx.len() as f64 * lik_prec + prior_prec / m as f64;
        prec_sum += p;
        for j in 0..2 {
            mean_num[j] += p * post.mean()[j];
        }
    }
    let full = GaussianMean::new(x.clone(), lik_prec, prior_prec, 1.0)
        .exact_posterior();
    let full_prec = x.len() as f64 * lik_prec + prior_prec;
    assert!((prec_sum - full_prec).abs() < 1e-6 * full_prec);
    for j in 0..2 {
        assert!(
            (mean_num[j] / prec_sum - full.mean()[j]).abs() < 1e-10,
            "dim {j}"
        );
    }
}

/// Parametric combination is (asymptotically in T) exact on Gaussians:
/// with T=4000 draws/machine its mean error must be tiny.
#[test]
fn parametric_exact_on_gaussian() {
    let (subs, data) = run(8, 4_000);
    let exact = exact_draws(&data, 4_000, 1);
    let combined =
        combine::combine(CombineMethod::Parametric, &subs, 4_000, 2).unwrap();
    let err = mean_l2_error(&combined, &exact);
    assert!(err < 0.02, "mean error {err}");
    // Density-L2 self-noise floor: two INDEPENDENT samplings of the
    // closed-form posterior (the posterior is very concentrated at
    // N=20k, so absolute density-L2 values are large — compare ratios).
    let exact2 = exact_draws(&data, 4_000, 77);
    let l2 = l2_distance_subsampled(&combined, &exact, 400);
    let noise = l2_distance_subsampled(&exact2, &exact, 400).max(1e-9);
    assert!(l2 < 5.0 * noise, "l2 {l2} vs self-noise {noise}");
}

/// The asymptotically exact combiners must approach the closed form and
/// IMPROVE as T grows (consistency, Theorem 5.3).
#[test]
fn exact_combiners_converge_with_t() {
    for method in [
        CombineMethod::Nonparametric,
        CombineMethod::Semiparametric,
        CombineMethod::SemiparametricNw,
        CombineMethod::Pairwise,
    ] {
        let (subs_small, data) = run(4, 400);
        let (subs_large, _) = run(4, 6_000);
        let exact = exact_draws(&data, 4_000, 3);
        let small = combine::combine(method, &subs_small, 400, 4).unwrap();
        let large = combine::combine(method, &subs_large, 6_000, 4)
            .unwrap()
            .split_off_burnin(1_000);
        let e_small = mean_l2_error(&small, &exact);
        let e_large = mean_l2_error(&large, &exact);
        assert!(
            e_large < e_small.max(0.06) + 0.02,
            "{}: {e_small} → {e_large} (should shrink)",
            method.name()
        );
        assert!(e_large < 0.12, "{}: final err {e_large}", method.name());
    }
}

/// subpostAvg must be measurably WORSE than the product-based methods on
/// heteroscedastic subposteriors (the paper's Fig. 1 bias).
#[test]
fn averaging_is_biased_where_product_is_not() {
    // Unequal shard sizes → unequal subposterior covariances.
    let data = synth::gaussian(10_000, 2, 33);
    let (x, lik_prec, prior_prec) = match &data {
        Dataset::Gaussian { x, lik_prec, prior_prec } => {
            (x, *lik_prec, *prior_prec)
        }
        _ => unreachable!(),
    };
    // Hand-build shards: 100, 900, 9000 rows.
    let sizes = [100usize, 900, 9_000];
    let mut start = 0;
    let mut subs = Vec::new();
    let mut rng = Pcg64::seed_from(8);
    for (m, &sz) in sizes.iter().enumerate() {
        let idx: Vec<usize> = (start..start + sz).collect();
        start += sz;
        let shard = repro::data::select_rows(x, &idx).unwrap();
        let sub = GaussianMean::new(shard, lik_prec, prior_prec, 1.0 / 3.0);
        let draws = sub.exact_posterior().sample_n(3_000, &mut rng);
        subs.push(repro::types::SubposteriorSamples::new(m, draws));
    }
    let exact = exact_draws(&data, 3_000, 9);
    let avg = combine::combine(CombineMethod::SubpostAvg, &subs, 3_000, 10)
        .unwrap();
    let par = combine::combine(CombineMethod::Parametric, &subs, 3_000, 10)
        .unwrap();
    let e_avg = l2_distance_subsampled(&avg, &exact, 400);
    let e_par = l2_distance_subsampled(&par, &exact, 400);
    assert!(
        e_avg > 2.0 * e_par,
        "subpostAvg {e_avg} should be ≫ parametric {e_par}"
    );
}

/// Increasing M must not break correctness (paper: error grows for
/// averaging, stays controlled for the product estimators).
#[test]
fn parametric_stable_as_m_grows() {
    for &machines in &[2usize, 5, 10, 20] {
        let (subs, data) = run(machines, 1_500);
        let exact = exact_draws(&data, 2_000, 11);
        let combined =
            combine::combine(CombineMethod::Parametric, &subs, 1_500, 12)
                .unwrap();
        let err = mean_l2_error(&combined, &exact);
        assert!(err < 0.05, "M={machines}: err {err}");
    }
}
