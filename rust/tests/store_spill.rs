//! Out-of-core draw plane, end to end: the leader's per-machine
//! [`DrawStore`]s must be a pure memory knob. For a fixed seed the
//! retained combined draws are **byte-identical** across every point of
//! the chunk-size × spill-budget × kernel-backend matrix — dense
//! in-memory storage, partially spilled, and "spill everything" are the
//! same distribution estimator down to the last bit. Budget edge cases
//! and non-finite payload round-trips are pinned on the public store
//! API.

use repro::combine::CombineMethod;
use repro::config::PipelineConfig;
use repro::coordinator::pipeline::run_native;
use repro::data::synth;
use repro::kernel::CombineKernelKind;
use repro::types::{DrawStore, DrawStoreConfig};

const T: usize = 120;

fn cfg() -> PipelineConfig {
    PipelineConfig::builder("gaussian")
        .machines(3)
        .samples_per_machine(T)
        .method(CombineMethod::Semiparametric)
        .seed(41)
        .build()
}

/// The acceptance matrix: chunk size {1, 7, 64, T} × spill budget
/// {0 MiB, 1 MiB, default-dense} × backend {naive, blocked}. Every
/// cell must reproduce the dense/naive baseline byte-for-byte — the
/// subposterior streams and the combined draws alike.
#[test]
fn spill_matrix_is_byte_identical_through_pipeline() {
    let data = synth::gaussian(900, 2, 17);
    let run = |chunk: usize,
               budget_mb: Option<usize>,
               backend: CombineKernelKind| {
        let mut c = cfg();
        c.chunk_rows = chunk;
        c.draw_spill_budget_mb = budget_mb;
        c.combine_backend = backend;
        run_native(&c, &data).unwrap()
    };
    let base = run(
        repro::data::store::DEFAULT_CHUNK_ROWS,
        None,
        CombineKernelKind::Naive,
    );
    assert_eq!(base.metrics.draw_spilled_bytes, 0);
    for chunk in [1usize, 7, 64, T] {
        for budget_mb in [Some(0), Some(1), None] {
            for backend in
                [CombineKernelKind::Naive, CombineKernelKind::Blocked]
            {
                let out = run(chunk, budget_mb, backend);
                assert_eq!(
                    base.combined.as_slice(),
                    out.combined.as_slice(),
                    "combined draws diverged at chunk {chunk}, budget \
                     {budget_mb:?}, backend {backend:?}"
                );
                for (a, b) in
                    base.subposteriors.iter().zip(&out.subposteriors)
                {
                    assert_eq!(
                        a.samples.as_slice(),
                        b.samples.as_slice(),
                        "machine {} diverged at chunk {chunk}, budget \
                         {budget_mb:?}, backend {backend:?}",
                        a.machine
                    );
                }
                if budget_mb == Some(0) {
                    // Every sealed chunk spills; 3 machines × T rows
                    // of dim 2 comfortably exceed one chunk.
                    assert!(
                        out.metrics.draw_spilled_bytes > 0,
                        "budget 0 must spill (chunk {chunk})"
                    );
                }
            }
        }
    }
}

/// The pairwise tree densifies store chunks per merge group — the
/// spill path must feed it the same bytes as the dense plane.
#[test]
fn pairwise_through_spilled_stores_matches_dense() {
    let data = synth::gaussian(800, 2, 19);
    let run = |budget_mb: Option<usize>| {
        let mut c = cfg();
        c.machines = 4;
        c.method = CombineMethod::Pairwise;
        c.draw_spill_budget_mb = budget_mb;
        c.chunk_rows = 7;
        run_native(&c, &data).unwrap()
    };
    let dense = run(None);
    let spill = run(Some(0));
    assert!(spill.metrics.draw_spilled_bytes > 0);
    assert_eq!(dense.combined.as_slice(), spill.combined.as_slice());
}

/// Budget edges on the store itself: a budget exactly equal to the
/// sealed bytes keeps everything resident; one byte less spills
/// exactly one chunk (the coldest). The tail never spills.
#[test]
fn budget_edge_spills_exactly_one_chunk() {
    let rows: Vec<[f64; 2]> =
        (0..12).map(|i| [i as f64, 0.5 * i as f64]).collect();
    let fill = |budget: usize| {
        let mut store = DrawStore::with_config(
            2,
            DrawStoreConfig {
                chunk_rows: 4,
                spill_budget_bytes: Some(budget),
            },
        );
        for r in &rows {
            store.push(r).unwrap();
        }
        store
    };
    // 12 rows × dim 2 → 3 sealed chunks of 64 bytes each, empty tail.
    let exact = fill(192);
    assert_eq!(exact.stats().spilled_bytes, 0);
    assert_eq!(exact.stats().resident_bytes, 192);
    let under = fill(191);
    assert_eq!(under.stats().spilled_bytes, 64, "exactly one chunk");
    assert_eq!(under.stats().resident_bytes, 128);
    for store in [&exact, &under] {
        let back = store.to_matrix().unwrap();
        for (i, r) in rows.iter().enumerate() {
            assert_eq!(back.row(i), r);
        }
    }
}

/// Non-finite draws (NaN with a nonstandard payload, ±Inf, -0.0,
/// subnormals) must survive the spill round-trip bit-exactly — the
/// disk segments are raw little-endian f64, not a lossy text format.
#[test]
fn nonfinite_payloads_survive_spill_bit_exactly() {
    let weird = [
        f64::from_bits(0x7ff8_dead_beef_cafe),
        f64::INFINITY,
        f64::NEG_INFINITY,
        -0.0,
        f64::MIN_POSITIVE / 4.0,
        f64::MAX,
    ];
    let mut store = DrawStore::with_config(
        3,
        DrawStoreConfig { chunk_rows: 1, spill_budget_bytes: Some(0) },
    );
    store.push_rows(&weird).unwrap();
    assert_eq!(store.stats().spilled_bytes, 2 * 3 * 8);
    let back = store.to_matrix().unwrap();
    let got: Vec<u64> = back.as_slice().iter().map(|v| v.to_bits()).collect();
    let want: Vec<u64> = weird.iter().map(|v| v.to_bits()).collect();
    assert_eq!(got, want, "spill must be bit-exact for non-finite values");
}
