//! Fault injection on the binary draw plane: a worker that dies
//! mid-stream or ships a truncated `RPDRAW1` chunk must surface a
//! *structured* diagnostic, fail fast (no hang), and land no partial
//! rows — never a panic, never a silently short draw matrix. The
//! no-partial-rows half is unit-pinned on the leader
//! (`coordinator::leader`); these tests drive the same failures
//! through real OS pipes, real TCP sockets, and the full transport
//! scheduler.

use std::io::Write;
use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

use repro::combine::CombineMethod;
use repro::config::{FailurePolicy, IoDriver, PipelineConfig};
use repro::coordinator::pipeline::{
    run_native, run_process, run_with_transport, PipelineOutput,
};
use repro::coordinator::transport::{
    encode_summary, write_frame, write_frame_bytes, DrawChunk,
    PipeTransport, SocketTransport, Transport, WireFormat, WorkerManifest,
    WorkerSummary,
};
use repro::data::synth;
use repro::error::{Error, FrameError};

fn manifest(dir: &Path, machine: usize) -> WorkerManifest {
    WorkerManifest {
        machine,
        machines: 1,
        seed: 7,
        samples: 4,
        burn_in: 0,
        thin: 1,
        prior_weight: 1.0,
        sampler: "rwm:1".into(),
        shard_path: dir.join("unused.bin").to_string_lossy().into_owned(),
        dim: 2,
        shard_inline: false,
        wire_format: WireFormat::Binary,
        draw_batch: 3,
        heartbeat_secs: 0,
    }
}

/// Byte-identity across the retry path: retained draws, combined
/// output, and leader-ingested scalar counts must all match the
/// unfaulted reference run exactly.
fn assert_identical(a: &PipelineOutput, b: &PipelineOutput, label: &str) {
    assert_eq!(a.subposteriors.len(), b.subposteriors.len());
    for (sa, sb) in a.subposteriors.iter().zip(&b.subposteriors) {
        assert_eq!(
            sa.samples.as_slice(),
            sb.samples.as_slice(),
            "{label}: machine {} draws diverged",
            sa.machine
        );
    }
    assert_eq!(
        a.combined.as_slice(),
        b.combined.as_slice(),
        "{label}: combined output diverged"
    );
    assert_eq!(
        a.metrics.scalars_transferred, b.metrics.scalars_transferred,
        "{label}: leader must retain the same scalar count"
    );
}

/// One well-formed RPDRAW1 chunk frame's payload bytes.
fn chunk_payload() -> Vec<u8> {
    let chunk = DrawChunk {
        machine: 0,
        dim: 2,
        thetas: vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0],
        elapsed: vec![0.1, 0.2, 0.3],
        last: true,
    };
    let mut payload = Vec::new();
    chunk.encode_into(&mut payload);
    payload
}

/// A chunk whose payload was cut mid-float but re-framed consistently
/// (the frame grammar holds; the *chunk header's* promised length does
/// not) must decode to a structured parse error naming the mismatch —
/// over a real pipe, from a real child process.
#[cfg(unix)]
#[test]
fn truncated_chunk_payload_is_structured_parse_error_over_pipe() {
    use std::os::unix::fs::PermissionsExt;
    let dir = std::env::temp_dir().join("repro_fault_pipe_truncchunk");
    std::fs::create_dir_all(&dir).unwrap();
    let mut payload = chunk_payload();
    payload.truncate(payload.len() - 8); // drop the last f64
    let mut bytes = Vec::new();
    write_frame_bytes(&mut bytes, &payload).unwrap();
    let fixture = dir.join("frames.bin");
    std::fs::write(&fixture, &bytes).unwrap();
    let script = dir.join("fake_worker.sh");
    std::fs::write(
        &script,
        format!("#!/bin/sh\nexec cat '{}'\n", fixture.display()),
    )
    .unwrap();
    std::fs::set_permissions(
        &script,
        std::fs::Permissions::from_mode(0o755),
    )
    .unwrap();

    let wm = manifest(&dir, 0);
    let manifest_path = dir.join("worker_0.json");
    wm.save(&manifest_path).unwrap();
    let transport = PipeTransport::new(PathBuf::from(&script), 1);
    let mut conn = transport.connect(0, &wm, &manifest_path).unwrap();
    let err = conn.recv().unwrap_err();
    assert!(
        matches!(err, Error::Parse(_)),
        "expected a structured parse error, got {err:?}"
    );
    assert!(
        err.to_string().contains("promises"),
        "error must name the header/payload length mismatch: {err}"
    );
    std::fs::remove_dir_all(&dir).ok();
}

/// A daemon killed mid-frame (TCP FIN inside a chunk's payload) must
/// surface as [`FrameError::TruncatedPayload`] on the very next recv —
/// after the preceding complete frame decoded fine.
#[test]
fn daemon_killed_mid_stream_is_truncated_payload_over_socket() {
    let dir = std::env::temp_dir().join("repro_fault_socket_kill");
    std::fs::create_dir_all(&dir).unwrap();
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let server = std::thread::spawn(move || {
        let (stream, _) = listener.accept().unwrap();
        // Ignore the manifest frame; script the reply: one good chunk
        // frame, then a second frame cut off mid-payload, then FIN
        // (the daemon "dies" here).
        let mut good = Vec::new();
        write_frame_bytes(&mut good, &chunk_payload()).unwrap();
        let mut partial = Vec::new();
        write_frame_bytes(&mut partial, &chunk_payload()).unwrap();
        partial.truncate(good.len() / 2);
        let mut writer = stream;
        writer.write_all(&good).unwrap();
        writer.write_all(&partial).unwrap();
        writer.flush().unwrap();
    });

    let transport = SocketTransport::from_spec(&addr.to_string()).unwrap();
    let wm = manifest(&dir, 0);
    let mut conn = transport
        .connect(0, &wm, Path::new("unused-manifest-path"))
        .unwrap();
    let first = conn.recv().unwrap().expect("good chunk must decode");
    match first {
        repro::coordinator::transport::WireMsg::Chunk(c) => {
            assert_eq!(c.count(), 3);
        }
        other => panic!("expected the good chunk, got {other:?}"),
    }
    let err = conn.recv().unwrap_err();
    assert!(
        matches!(
            err,
            Error::Frame(FrameError::TruncatedPayload { .. })
        ),
        "expected TruncatedPayload, got {err:?}"
    );
    server.join().unwrap();
    std::fs::remove_dir_all(&dir).ok();
}

/// Full scheduler fail-fast: a pipeline whose worker dies mid-stream
/// (its byte stream ends inside a frame) must fail the run promptly
/// with the frame diagnostic as the root cause — the draw plane never
/// hangs waiting for the missing bytes and never fabricates a result
/// from the partial stream.
#[cfg(unix)]
#[test]
fn pipeline_fails_fast_on_worker_killed_mid_stream() {
    use std::os::unix::fs::PermissionsExt;
    let dir = std::env::temp_dir().join("repro_fault_pipeline_kill");
    std::fs::create_dir_all(&dir).unwrap();
    // The fake worker ships one summary frame (proving frames were
    // flowing) and then dies mid-way through a chunk frame.
    let mut bytes = Vec::new();
    write_frame(
        &mut bytes,
        &encode_summary(&WorkerSummary {
            machine: 0,
            accept_rate: 0.5,
            wall_secs: 0.25,
        }),
    )
    .unwrap();
    let mut partial = Vec::new();
    write_frame_bytes(&mut partial, &chunk_payload()).unwrap();
    partial.truncate(partial.len() - 5);
    bytes.extend_from_slice(&partial);
    let fixture = dir.join("frames.bin");
    std::fs::write(&fixture, &bytes).unwrap();
    let script = dir.join("fake_worker.sh");
    std::fs::write(
        &script,
        format!("#!/bin/sh\nexec cat '{}'\n", fixture.display()),
    )
    .unwrap();
    std::fs::set_permissions(
        &script,
        std::fs::Permissions::from_mode(0o755),
    )
    .unwrap();

    let data = synth::gaussian(200, 2, 3);
    let cfg = PipelineConfig::builder("gaussian")
        .machines(1)
        .samples_per_machine(4)
        .method(CombineMethod::Parametric)
        .seed(7)
        .build();
    let transport = PipeTransport::new(PathBuf::from(&script), 1);
    let t0 = Instant::now();
    let err = run_with_transport(&cfg, &data, &transport).unwrap_err();
    assert!(
        t0.elapsed() < Duration::from_secs(30),
        "fail-fast contract: the run must not hang on a dead worker"
    );
    let text = err.to_string();
    assert!(
        text.contains("bad frame") && text.contains("truncated mid-payload"),
        "root cause must be the structured frame diagnostic: {text}"
    );
    std::fs::remove_dir_all(&dir).ok();
}

/// One `repro serve` daemon with optional extra flags (notably
/// `--fault SPEC` to arm the deterministic chaos layer); killed on
/// drop so failing tests never leak daemons.
struct Daemon {
    child: std::process::Child,
    addr: String,
}

impl Daemon {
    fn spawn(extra: &[&str]) -> Daemon {
        use std::io::{BufRead, BufReader};
        use std::process::{Command, Stdio};
        let mut child = Command::new(env!("CARGO_BIN_EXE_repro"))
            .args(["serve", "--listen", "127.0.0.1:0"])
            .args(extra)
            .stdin(Stdio::null())
            .stdout(Stdio::piped())
            .stderr(Stdio::null())
            .spawn()
            .expect("spawning repro serve");
        let stdout = child.stdout.take().unwrap();
        let mut line = String::new();
        BufReader::new(stdout).read_line(&mut line).unwrap();
        let addr = line
            .trim()
            .strip_prefix("LISTENING ")
            .unwrap_or_else(|| panic!("bad announce line {line:?}"))
            .to_string();
        Daemon { child, addr }
    }
}

impl Drop for Daemon {
    fn drop(&mut self) {
        self.child.kill().ok();
        self.child.wait().ok();
    }
}

/// Tentpole pin over a real pipe: machine 0's first worker process
/// dies before emitting a single frame; under `--failure-policy
/// retry` the scheduler discards the dead attempt, re-dispatches the
/// shard, and the retained draws are byte-identical to thread mode.
/// The determinism contract — worker RNG derived from (seed, machine),
/// never the endpoint — is what makes the replay free.
#[cfg(unix)]
#[test]
fn retry_replays_killed_pipe_worker_byte_identically() {
    use std::os::unix::fs::PermissionsExt;
    let dir = std::env::temp_dir().join("repro_fault_pipe_retry");
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).unwrap();
    let marker = dir.join("died_once");
    let script = dir.join("flaky_worker.sh");
    std::fs::write(
        &script,
        format!(
            "#!/bin/sh\n\
             if [ ! -e '{marker}' ]; then\n\
               : > '{marker}'\n\
               exit 1\n\
             fi\n\
             exec '{real}' \"$@\"\n",
            marker = marker.display(),
            real = env!("CARGO_BIN_EXE_repro"),
        ),
    )
    .unwrap();
    std::fs::set_permissions(
        &script,
        std::fs::Permissions::from_mode(0o755),
    )
    .unwrap();

    let data = synth::gaussian(600, 2, 19);
    let cfg = PipelineConfig::builder("gaussian")
        .machines(2)
        .samples_per_machine(50)
        .method(CombineMethod::Parametric)
        .seed(29)
        .failure_policy(FailurePolicy::Retry)
        .max_retries(2)
        .build();
    let clean = run_native(&cfg, &data).unwrap();
    let transport = PipeTransport::new(PathBuf::from(&script), 1);
    let out = run_with_transport(&cfg, &data, &transport).unwrap();
    assert_identical(&out, &clean, "pipe retry vs thread");
    assert_eq!(
        out.metrics.shard_retries, 1,
        "exactly one shard re-dispatch"
    );
    assert_eq!(out.metrics.endpoints_quarantined, 0);
    std::fs::remove_dir_all(&dir).ok();
}

/// Tentpole pin over real sockets: one daemon in the fleet hard-kills
/// every stream after 2 frames (`--fault drop-after:2`). Under retry
/// the scheduler re-dispatches each killed shard, benches the flaky
/// endpoint once it keeps failing, and the retained draws stay
/// byte-identical to thread mode.
#[test]
fn retry_over_sockets_survives_a_flaky_daemon_byte_identically() {
    let flaky = Daemon::spawn(&["--fault", "drop-after:2"]);
    let clean = Daemon::spawn(&[]);
    let data = synth::gaussian(1_200, 2, 31);
    let base = PipelineConfig::builder("gaussian")
        .machines(4)
        .samples_per_machine(80)
        .method(CombineMethod::Semiparametric)
        .seed(43)
        .failure_policy(FailurePolicy::Retry)
        .max_retries(5)
        .build();
    let thread_out = run_native(&base, &data).unwrap();
    let mut sc = base.clone();
    sc.workers = format!("{},{}", flaky.addr, clean.addr);
    let socket_out = run_process(&sc, &data).unwrap();
    assert_identical(&socket_out, &thread_out, "socket retry vs thread");
    assert!(
        socket_out.metrics.shard_retries >= 1,
        "the killed shard must have been re-dispatched: {}",
        socket_out.metrics
    );
    assert!(
        socket_out.metrics.endpoints_quarantined <= 1,
        "only the flaky endpoint may be benched: {}",
        socket_out.metrics
    );
}

/// The same kill-mid-stream fault under the default fail-fast policy
/// stays the existing structured error: the run fails promptly naming
/// the frame-level root cause, with no retry and no hang.
#[test]
fn failfast_on_flaky_daemon_is_a_structured_error() {
    let flaky = Daemon::spawn(&["--fault", "drop-after:2"]);
    let data = synth::gaussian(600, 2, 13);
    let mut cfg = PipelineConfig::builder("gaussian")
        .machines(2)
        .samples_per_machine(60)
        .method(CombineMethod::Parametric)
        .seed(17)
        .build();
    cfg.workers = flaky.addr.clone();
    let t0 = Instant::now();
    let err = run_process(&cfg, &data).unwrap_err();
    assert!(
        t0.elapsed() < Duration::from_secs(30),
        "fail-fast contract: the run must not hang on a killed stream"
    );
    let text = err.to_string().to_lowercase();
    assert!(
        text.contains("frame")
            || text.contains("connection")
            || text.contains("reset"),
        "root cause must name the stream failure: {text}"
    );
}

/// The drop-after chaos spec re-run under `--io-driver reactor`: the
/// poll(2) leader must drive the same retry scheduler — re-dispatch
/// the killed shards, bench the flaky endpoint, and retain draws
/// byte-identical to thread mode. Same scenario as
/// [`retry_over_sockets_survives_a_flaky_daemon_byte_identically`],
/// different leader I/O plane.
#[cfg(unix)]
#[test]
fn reactor_retry_survives_a_flaky_daemon_byte_identically() {
    let flaky = Daemon::spawn(&["--fault", "drop-after:2"]);
    let clean = Daemon::spawn(&[]);
    let data = synth::gaussian(1_200, 2, 31);
    let base = PipelineConfig::builder("gaussian")
        .machines(4)
        .samples_per_machine(80)
        .method(CombineMethod::Semiparametric)
        .seed(43)
        .failure_policy(FailurePolicy::Retry)
        .max_retries(5)
        .build();
    let thread_out = run_native(&base, &data).unwrap();
    let mut sc = base.clone();
    sc.workers = format!("{},{}", flaky.addr, clean.addr);
    sc.io_driver = IoDriver::Reactor;
    let reactor_out = run_process(&sc, &data).unwrap();
    assert_identical(&reactor_out, &thread_out, "reactor retry vs thread");
    assert!(
        reactor_out.metrics.shard_retries >= 1,
        "the killed shard must have been re-dispatched: {}",
        reactor_out.metrics
    );
    assert!(
        reactor_out.metrics.endpoints_quarantined <= 1,
        "only the flaky endpoint may be benched: {}",
        reactor_out.metrics
    );
    assert!(
        reactor_out.metrics.reactor_wakeups > 0,
        "a reactor run must report poll wakeups: {}",
        reactor_out.metrics
    );
}

/// The corrupt chaos spec under the reactor: one daemon flips a byte
/// in frame 1 of every stream, so every attempt on that endpoint dies
/// in decode. Retry must re-dispatch, quarantine the corrupting
/// endpoint, finish on the clean one — and the surviving draws carry
/// no trace of the corruption (byte-identical to thread mode, never a
/// silently wrong float).
#[cfg(unix)]
#[test]
fn reactor_retry_survives_a_corrupting_daemon_byte_identically() {
    let corrupting = Daemon::spawn(&["--fault", "corrupt:1"]);
    let clean = Daemon::spawn(&[]);
    let data = synth::gaussian(900, 2, 37);
    let base = PipelineConfig::builder("gaussian")
        .machines(3)
        .samples_per_machine(60)
        .method(CombineMethod::Parametric)
        .seed(53)
        .failure_policy(FailurePolicy::Retry)
        .max_retries(5)
        .build();
    let thread_out = run_native(&base, &data).unwrap();
    let mut sc = base.clone();
    sc.workers = format!("{},{}", corrupting.addr, clean.addr);
    sc.io_driver = IoDriver::Reactor;
    let reactor_out = run_process(&sc, &data).unwrap();
    assert_identical(
        &reactor_out,
        &thread_out,
        "reactor corrupt-retry vs thread",
    );
    assert!(
        reactor_out.metrics.shard_retries >= 1,
        "corrupted attempts must have been re-dispatched: {}",
        reactor_out.metrics
    );
    assert!(
        reactor_out.metrics.endpoints_quarantined <= 1,
        "only the corrupting endpoint may be benched: {}",
        reactor_out.metrics
    );
}

/// The delay-ms chaos spec under the reactor: slow-but-alive daemons
/// are not failures. With per-frame delay on every endpoint the
/// reactor's poll-timeout liveness wheel must stay quiet (no missed
/// heartbeats, no quarantine) and the draws stay byte-identical.
#[cfg(unix)]
#[test]
fn reactor_delay_faults_are_slow_but_alive_and_byte_identical() {
    let daemons: Vec<Daemon> =
        (0..2).map(|_| Daemon::spawn(&["--fault", "delay-ms:2"])).collect();
    let data = synth::gaussian(800, 2, 41);
    let base = PipelineConfig::builder("gaussian")
        .machines(4)
        .samples_per_machine(40)
        .method(CombineMethod::Parametric)
        .seed(59)
        .failure_policy(FailurePolicy::Retry)
        .max_retries(2)
        .heartbeat_secs(1)
        .liveness_timeout_secs(20)
        .build();
    let thread_out = run_native(&base, &data).unwrap();
    let mut sc = base.clone();
    sc.workers = daemons
        .iter()
        .map(|d| d.addr.as_str())
        .collect::<Vec<_>>()
        .join(",");
    sc.io_driver = IoDriver::Reactor;
    let reactor_out = run_process(&sc, &data).unwrap();
    assert_identical(
        &reactor_out,
        &thread_out,
        "reactor delay-ms vs thread",
    );
    assert_eq!(
        reactor_out.metrics.heartbeats_missed, 0,
        "delayed-but-alive daemons must never trip the liveness wheel"
    );
    assert_eq!(reactor_out.metrics.endpoints_quarantined, 0);
    assert_eq!(reactor_out.metrics.shard_retries, 0);
}

/// Fail-fast under the reactor: the kill-mid-stream fault must abort
/// the whole event loop promptly — the abort flag wakes every poller
/// mid-wait — with the same structured frame diagnostic thread mode
/// reports, and no hang.
#[cfg(unix)]
#[test]
fn reactor_failfast_on_flaky_daemon_is_a_structured_error() {
    let flaky = Daemon::spawn(&["--fault", "drop-after:2"]);
    let data = synth::gaussian(600, 2, 13);
    let mut cfg = PipelineConfig::builder("gaussian")
        .machines(2)
        .samples_per_machine(60)
        .method(CombineMethod::Parametric)
        .seed(17)
        .build();
    cfg.workers = flaky.addr.clone();
    cfg.io_driver = IoDriver::Reactor;
    let t0 = Instant::now();
    let err = run_process(&cfg, &data).unwrap_err();
    assert!(
        t0.elapsed() < Duration::from_secs(30),
        "fail-fast contract: the reactor must not hang on a killed stream"
    );
    let text = err.to_string().to_lowercase();
    assert!(
        text.contains("frame")
            || text.contains("connection")
            || text.contains("reset"),
        "root cause must name the stream failure: {text}"
    );
}
