//! Fault injection on the binary draw plane: a worker that dies
//! mid-stream or ships a truncated `RPDRAW1` chunk must surface a
//! *structured* diagnostic, fail fast (no hang), and land no partial
//! rows — never a panic, never a silently short draw matrix. The
//! no-partial-rows half is unit-pinned on the leader
//! (`coordinator::leader`); these tests drive the same failures
//! through real OS pipes, real TCP sockets, and the full transport
//! scheduler.

use std::io::Write;
use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

use repro::combine::CombineMethod;
use repro::config::PipelineConfig;
use repro::coordinator::pipeline::run_with_transport;
use repro::coordinator::transport::{
    encode_summary, write_frame, write_frame_bytes, DrawChunk,
    PipeTransport, SocketTransport, Transport, WireFormat, WorkerManifest,
    WorkerSummary,
};
use repro::data::synth;
use repro::error::{Error, FrameError};

fn manifest(dir: &Path, machine: usize) -> WorkerManifest {
    WorkerManifest {
        machine,
        machines: 1,
        seed: 7,
        samples: 4,
        burn_in: 0,
        thin: 1,
        prior_weight: 1.0,
        sampler: "rwm:1".into(),
        shard_path: dir.join("unused.bin").to_string_lossy().into_owned(),
        dim: 2,
        shard_inline: false,
        wire_format: WireFormat::Binary,
        draw_batch: 3,
    }
}

/// One well-formed RPDRAW1 chunk frame's payload bytes.
fn chunk_payload() -> Vec<u8> {
    let chunk = DrawChunk {
        machine: 0,
        dim: 2,
        thetas: vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0],
        elapsed: vec![0.1, 0.2, 0.3],
        last: true,
    };
    let mut payload = Vec::new();
    chunk.encode_into(&mut payload);
    payload
}

/// A chunk whose payload was cut mid-float but re-framed consistently
/// (the frame grammar holds; the *chunk header's* promised length does
/// not) must decode to a structured parse error naming the mismatch —
/// over a real pipe, from a real child process.
#[cfg(unix)]
#[test]
fn truncated_chunk_payload_is_structured_parse_error_over_pipe() {
    use std::os::unix::fs::PermissionsExt;
    let dir = std::env::temp_dir().join("repro_fault_pipe_truncchunk");
    std::fs::create_dir_all(&dir).unwrap();
    let mut payload = chunk_payload();
    payload.truncate(payload.len() - 8); // drop the last f64
    let mut bytes = Vec::new();
    write_frame_bytes(&mut bytes, &payload).unwrap();
    let fixture = dir.join("frames.bin");
    std::fs::write(&fixture, &bytes).unwrap();
    let script = dir.join("fake_worker.sh");
    std::fs::write(
        &script,
        format!("#!/bin/sh\nexec cat '{}'\n", fixture.display()),
    )
    .unwrap();
    std::fs::set_permissions(
        &script,
        std::fs::Permissions::from_mode(0o755),
    )
    .unwrap();

    let wm = manifest(&dir, 0);
    let manifest_path = dir.join("worker_0.json");
    wm.save(&manifest_path).unwrap();
    let transport = PipeTransport::new(PathBuf::from(&script), 1);
    let mut conn = transport.connect(0, &wm, &manifest_path).unwrap();
    let err = conn.recv().unwrap_err();
    assert!(
        matches!(err, Error::Parse(_)),
        "expected a structured parse error, got {err:?}"
    );
    assert!(
        err.to_string().contains("promises"),
        "error must name the header/payload length mismatch: {err}"
    );
    std::fs::remove_dir_all(&dir).ok();
}

/// A daemon killed mid-frame (TCP FIN inside a chunk's payload) must
/// surface as [`FrameError::TruncatedPayload`] on the very next recv —
/// after the preceding complete frame decoded fine.
#[test]
fn daemon_killed_mid_stream_is_truncated_payload_over_socket() {
    let dir = std::env::temp_dir().join("repro_fault_socket_kill");
    std::fs::create_dir_all(&dir).unwrap();
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let server = std::thread::spawn(move || {
        let (stream, _) = listener.accept().unwrap();
        // Ignore the manifest frame; script the reply: one good chunk
        // frame, then a second frame cut off mid-payload, then FIN
        // (the daemon "dies" here).
        let mut good = Vec::new();
        write_frame_bytes(&mut good, &chunk_payload()).unwrap();
        let mut partial = Vec::new();
        write_frame_bytes(&mut partial, &chunk_payload()).unwrap();
        partial.truncate(good.len() / 2);
        let mut writer = stream;
        writer.write_all(&good).unwrap();
        writer.write_all(&partial).unwrap();
        writer.flush().unwrap();
    });

    let transport = SocketTransport::from_spec(&addr.to_string()).unwrap();
    let wm = manifest(&dir, 0);
    let mut conn = transport
        .connect(0, &wm, Path::new("unused-manifest-path"))
        .unwrap();
    let first = conn.recv().unwrap().expect("good chunk must decode");
    match first {
        repro::coordinator::transport::WireMsg::Chunk(c) => {
            assert_eq!(c.count(), 3);
        }
        other => panic!("expected the good chunk, got {other:?}"),
    }
    let err = conn.recv().unwrap_err();
    assert!(
        matches!(
            err,
            Error::Frame(FrameError::TruncatedPayload { .. })
        ),
        "expected TruncatedPayload, got {err:?}"
    );
    server.join().unwrap();
    std::fs::remove_dir_all(&dir).ok();
}

/// Full scheduler fail-fast: a pipeline whose worker dies mid-stream
/// (its byte stream ends inside a frame) must fail the run promptly
/// with the frame diagnostic as the root cause — the draw plane never
/// hangs waiting for the missing bytes and never fabricates a result
/// from the partial stream.
#[cfg(unix)]
#[test]
fn pipeline_fails_fast_on_worker_killed_mid_stream() {
    use std::os::unix::fs::PermissionsExt;
    let dir = std::env::temp_dir().join("repro_fault_pipeline_kill");
    std::fs::create_dir_all(&dir).unwrap();
    // The fake worker ships one summary frame (proving frames were
    // flowing) and then dies mid-way through a chunk frame.
    let mut bytes = Vec::new();
    write_frame(
        &mut bytes,
        &encode_summary(&WorkerSummary {
            machine: 0,
            accept_rate: 0.5,
            wall_secs: 0.25,
        }),
    )
    .unwrap();
    let mut partial = Vec::new();
    write_frame_bytes(&mut partial, &chunk_payload()).unwrap();
    partial.truncate(partial.len() - 5);
    bytes.extend_from_slice(&partial);
    let fixture = dir.join("frames.bin");
    std::fs::write(&fixture, &bytes).unwrap();
    let script = dir.join("fake_worker.sh");
    std::fs::write(
        &script,
        format!("#!/bin/sh\nexec cat '{}'\n", fixture.display()),
    )
    .unwrap();
    std::fs::set_permissions(
        &script,
        std::fs::Permissions::from_mode(0o755),
    )
    .unwrap();

    let data = synth::gaussian(200, 2, 3);
    let cfg = PipelineConfig::builder("gaussian")
        .machines(1)
        .samples_per_machine(4)
        .method(CombineMethod::Parametric)
        .seed(7)
        .build();
    let transport = PipeTransport::new(PathBuf::from(&script), 1);
    let t0 = Instant::now();
    let err = run_with_transport(&cfg, &data, &transport).unwrap_err();
    assert!(
        t0.elapsed() < Duration::from_secs(30),
        "fail-fast contract: the run must not hang on a dead worker"
    );
    let text = err.to_string();
    assert!(
        text.contains("bad frame") && text.contains("truncated mid-payload"),
        "root cause must be the structured frame diagnostic: {text}"
    );
    std::fs::remove_dir_all(&dir).ok();
}
