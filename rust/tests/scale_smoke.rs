//! Scale smoke over the fault-tolerant socket plane: M = 64 logical
//! machines oversubscribed onto W = 8 real `repro serve` daemons, every
//! daemon armed with a per-frame delay fault (`--fault delay-ms:2`) and
//! the leader holding heartbeat + liveness deadlines. The run must
//! finish inside the liveness budget (slow-but-alive peers are *not*
//! failures), miss zero heartbeats, and stay byte-identical to thread
//! mode — the scale, chaos, and liveness layers compose without
//! touching a draw.

use std::io::{BufRead, BufReader};
use std::process::{Child, Command, Stdio};
use std::time::{Duration, Instant};

use repro::combine::CombineMethod;
use repro::config::{FailurePolicy, PipelineConfig};
use repro::coordinator::pipeline;
use repro::coordinator::transport::WireFormat;
use repro::data::synth;

/// One `repro serve` daemon with extra flags; killed on drop.
struct Daemon {
    child: Child,
    addr: String,
}

impl Daemon {
    fn spawn(extra: &[&str]) -> Daemon {
        let mut child = Command::new(env!("CARGO_BIN_EXE_repro"))
            .args(["serve", "--listen", "127.0.0.1:0"])
            .args(extra)
            .stdin(Stdio::null())
            .stdout(Stdio::piped())
            .stderr(Stdio::null())
            .spawn()
            .expect("spawning repro serve");
        let stdout = child.stdout.take().unwrap();
        let mut line = String::new();
        BufReader::new(stdout).read_line(&mut line).unwrap();
        let addr = line
            .trim()
            .strip_prefix("LISTENING ")
            .unwrap_or_else(|| panic!("bad announce line {line:?}"))
            .to_string();
        Daemon { child, addr }
    }
}

impl Drop for Daemon {
    fn drop(&mut self) {
        self.child.kill().ok();
        self.child.wait().ok();
    }
}

#[test]
fn m64_over_w8_delayed_daemons_is_byte_identical_within_liveness_budget() {
    const MACHINES: usize = 64;
    const WORKERS: usize = 8;
    let data = synth::gaussian(6_400, 2, 71);
    let base = PipelineConfig::builder("gaussian")
        .machines(MACHINES)
        .samples_per_machine(30)
        .method(CombineMethod::Parametric)
        .seed(97)
        .wire_format(WireFormat::Binary)
        .draw_batch(64)
        .failure_policy(FailurePolicy::Retry)
        .max_retries(2)
        .heartbeat_secs(1)
        .liveness_timeout_secs(20)
        .build();
    let thread_out = pipeline::run_native(&base, &data).unwrap();

    let daemons: Vec<Daemon> = (0..WORKERS)
        .map(|_| Daemon::spawn(&["--fault", "delay-ms:2"]))
        .collect();
    let spec = daemons
        .iter()
        .map(|d| d.addr.as_str())
        .collect::<Vec<_>>()
        .join(",");
    let mut sc = base.clone();
    sc.workers = spec;

    let t0 = Instant::now();
    let socket_out = pipeline::run_process(&sc, &data).unwrap();
    let elapsed = t0.elapsed();

    // Liveness budget: 2 ms/frame of injected delay across ~2 frames ×
    // 64 jobs is well under the 20 s per-read deadline; the whole run
    // has to land inside a few deadline windows, not wander off.
    assert!(
        elapsed < Duration::from_secs(120),
        "M={MACHINES} over W={WORKERS} delayed daemons took {elapsed:?}"
    );
    assert_eq!(
        socket_out.metrics.heartbeats_missed, 0,
        "delayed-but-alive daemons must never trip the liveness deadline"
    );
    assert_eq!(
        socket_out.metrics.endpoints_quarantined, 0,
        "delay faults are not failures; no endpoint may be benched"
    );

    assert_eq!(socket_out.subposteriors.len(), MACHINES);
    for (sa, sb) in
        socket_out.subposteriors.iter().zip(&thread_out.subposteriors)
    {
        assert_eq!(
            sa.samples.as_slice(),
            sb.samples.as_slice(),
            "machine {} draws diverged under delay faults",
            sa.machine
        );
    }
    assert_eq!(
        socket_out.combined.as_slice(),
        thread_out.combined.as_slice(),
        "combined output diverged under delay faults"
    );
    assert_eq!(
        socket_out.metrics.scalars_transferred,
        thread_out.metrics.scalars_transferred
    );
}
