//! Scale smoke over the fault-tolerant socket plane: M = 64 logical
//! machines oversubscribed onto W = 8 real `repro serve` daemons, every
//! daemon armed with a per-frame delay fault (`--fault delay-ms:2`) and
//! the leader holding heartbeat + liveness deadlines. The run must
//! finish inside the liveness budget (slow-but-alive peers are *not*
//! failures), miss zero heartbeats, and stay byte-identical to thread
//! mode — the scale, chaos, and liveness layers compose without
//! touching a draw.

use std::io::{BufRead, BufReader};
use std::process::{Child, Command, Stdio};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use repro::combine::CombineMethod;
use repro::config::{FailurePolicy, IoDriver, PipelineConfig};
use repro::coordinator::pipeline;
use repro::coordinator::transport::WireFormat;
use repro::data::synth;

/// Serializes the scale tests within this binary: the reactor test
/// samples the process-wide thread count, which only means anything
/// while no sibling test is spawning its own workers.
static SCALE_LOCK: Mutex<()> = Mutex::new(());

/// One `repro serve` daemon with extra flags; killed on drop.
struct Daemon {
    child: Child,
    addr: String,
}

impl Daemon {
    fn spawn(extra: &[&str]) -> Daemon {
        let mut child = Command::new(env!("CARGO_BIN_EXE_repro"))
            .args(["serve", "--listen", "127.0.0.1:0"])
            .args(extra)
            .stdin(Stdio::null())
            .stdout(Stdio::piped())
            .stderr(Stdio::null())
            .spawn()
            .expect("spawning repro serve");
        let stdout = child.stdout.take().unwrap();
        let mut line = String::new();
        BufReader::new(stdout).read_line(&mut line).unwrap();
        let addr = line
            .trim()
            .strip_prefix("LISTENING ")
            .unwrap_or_else(|| panic!("bad announce line {line:?}"))
            .to_string();
        Daemon { child, addr }
    }
}

impl Drop for Daemon {
    fn drop(&mut self) {
        self.child.kill().ok();
        self.child.wait().ok();
    }
}

/// Current thread count of this process (linux: `/proc/self/status`).
/// `None` where the proc filesystem is unavailable — callers skip the
/// thread-count assertions there.
fn process_threads() -> Option<usize> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    status
        .lines()
        .find_map(|l| l.strip_prefix("Threads:"))
        .and_then(|v| v.trim().parse().ok())
}

#[test]
fn m64_over_w8_delayed_daemons_is_byte_identical_within_liveness_budget() {
    let _guard =
        SCALE_LOCK.lock().unwrap_or_else(|poison| poison.into_inner());
    const MACHINES: usize = 64;
    const WORKERS: usize = 8;
    let data = synth::gaussian(6_400, 2, 71);
    let base = PipelineConfig::builder("gaussian")
        .machines(MACHINES)
        .samples_per_machine(30)
        .method(CombineMethod::Parametric)
        .seed(97)
        .wire_format(WireFormat::Binary)
        .draw_batch(64)
        .failure_policy(FailurePolicy::Retry)
        .max_retries(2)
        .heartbeat_secs(1)
        .liveness_timeout_secs(20)
        .build();
    let thread_out = pipeline::run_native(&base, &data).unwrap();

    let daemons: Vec<Daemon> = (0..WORKERS)
        .map(|_| Daemon::spawn(&["--fault", "delay-ms:2"]))
        .collect();
    let spec = daemons
        .iter()
        .map(|d| d.addr.as_str())
        .collect::<Vec<_>>()
        .join(",");
    let mut sc = base.clone();
    sc.workers = spec;

    let t0 = Instant::now();
    let socket_out = pipeline::run_process(&sc, &data).unwrap();
    let elapsed = t0.elapsed();

    // Liveness budget: 2 ms/frame of injected delay across ~2 frames ×
    // 64 jobs is well under the 20 s per-read deadline; the whole run
    // has to land inside a few deadline windows, not wander off.
    assert!(
        elapsed < Duration::from_secs(120),
        "M={MACHINES} over W={WORKERS} delayed daemons took {elapsed:?}"
    );
    assert_eq!(
        socket_out.metrics.heartbeats_missed, 0,
        "delayed-but-alive daemons must never trip the liveness deadline"
    );
    assert_eq!(
        socket_out.metrics.endpoints_quarantined, 0,
        "delay faults are not failures; no endpoint may be benched"
    );

    assert_eq!(socket_out.subposteriors.len(), MACHINES);
    for (sa, sb) in
        socket_out.subposteriors.iter().zip(&thread_out.subposteriors)
    {
        assert_eq!(
            sa.samples.as_slice(),
            sb.samples.as_slice(),
            "machine {} draws diverged under delay faults",
            sa.machine
        );
    }
    assert_eq!(
        socket_out.combined.as_slice(),
        thread_out.combined.as_slice(),
        "combined output diverged under delay faults"
    );
    assert_eq!(
        socket_out.metrics.scalars_transferred,
        thread_out.metrics.scalars_transferred
    );
}

/// The ROADMAP's "hundreds of machines" rung: M = 256 over W = 16 real
/// daemons under `--io-driver reactor`, heartbeat + liveness armed and
/// a few endpoints injecting per-frame delay. Byte-identical to thread
/// mode, zero missed heartbeats — and the leader's thread count stays
/// independent of W: one reactor poller multiplexes all 16 sockets
/// where the threads driver would hold 16 blocking threads.
#[test]
fn m256_over_w16_reactor_is_byte_identical_with_flat_thread_count() {
    let _guard =
        SCALE_LOCK.lock().unwrap_or_else(|poison| poison.into_inner());
    const MACHINES: usize = 256;
    const WORKERS: usize = 16;
    let data = synth::gaussian(12_800, 2, 73);
    let base = PipelineConfig::builder("gaussian")
        .machines(MACHINES)
        .samples_per_machine(20)
        .method(CombineMethod::Parametric)
        .seed(101)
        .wire_format(WireFormat::Binary)
        .draw_batch(64)
        .failure_policy(FailurePolicy::Retry)
        .max_retries(2)
        .heartbeat_secs(1)
        .liveness_timeout_secs(30)
        .build();
    let thread_out = pipeline::run_native(&base, &data).unwrap();

    // A few delayed endpoints among the healthy pool: slow-but-alive
    // peers must not trip the liveness deadline under the reactor
    // either.
    let daemons: Vec<Daemon> = (0..WORKERS)
        .map(|w| {
            if w % 5 == 0 {
                Daemon::spawn(&["--fault", "delay-ms:2"])
            } else {
                Daemon::spawn(&[])
            }
        })
        .collect();
    let spec = daemons
        .iter()
        .map(|d| d.addr.as_str())
        .collect::<Vec<_>>()
        .join(",");
    let mut sc = base.clone();
    sc.workers = spec;
    sc.io_driver = IoDriver::Reactor;
    sc.reactor_threads = 1;

    // Thread-count watcher: sample the process-wide peak while the
    // reactor run is in flight. `run_native` above already joined its
    // workers, so the baseline is this test plus cargo's harness.
    let baseline = process_threads();
    let stop = std::sync::Arc::new(std::sync::atomic::AtomicBool::new(
        false,
    ));
    let watcher = baseline.map(|_| {
        let stop = stop.clone();
        std::thread::spawn(move || {
            let mut peak = 0usize;
            while !stop.load(std::sync::atomic::Ordering::SeqCst) {
                if let Some(n) = process_threads() {
                    peak = peak.max(n);
                }
                std::thread::sleep(Duration::from_millis(5));
            }
            peak
        })
    });

    let t0 = Instant::now();
    let reactor_out = pipeline::run_process(&sc, &data).unwrap();
    let elapsed = t0.elapsed();
    stop.store(true, std::sync::atomic::Ordering::SeqCst);
    let peak = watcher.map(|w| w.join().unwrap());

    assert!(
        elapsed < Duration::from_secs(240),
        "M={MACHINES} over W={WORKERS} reactor daemons took {elapsed:?}"
    );
    assert_eq!(
        reactor_out.metrics.heartbeats_missed, 0,
        "delayed-but-alive daemons must never trip the liveness deadline"
    );
    assert_eq!(reactor_out.metrics.endpoints_quarantined, 0);
    assert!(
        reactor_out.metrics.reactor_wakeups > 0,
        "the reactor run must report poll wakeups"
    );
    assert!(reactor_out.metrics.time_to_first_draw_ms > 0.0);
    assert_eq!(reactor_out.metrics.endpoint_busy.len(), WORKERS);

    // Leader thread count independent of W: the reactor run adds one
    // poller + the scheduler spawn + this watcher — nowhere near the
    // W=16 blocking readers thread mode would hold open.
    if let (Some(base_threads), Some(peak)) = (baseline, peak) {
        let delta = peak.saturating_sub(base_threads);
        assert!(
            delta <= 6,
            "reactor leader grew by {delta} threads over W={WORKERS} \
             endpoints (baseline {base_threads}, peak {peak}) — the \
             poller must multiplex, not spawn per endpoint"
        );
    }

    assert_eq!(reactor_out.subposteriors.len(), MACHINES);
    for (sa, sb) in
        reactor_out.subposteriors.iter().zip(&thread_out.subposteriors)
    {
        assert_eq!(
            sa.samples.as_slice(),
            sb.samples.as_slice(),
            "machine {} draws diverged under the reactor driver",
            sa.machine
        );
    }
    assert_eq!(
        reactor_out.combined.as_slice(),
        thread_out.combined.as_slice(),
        "combined output diverged under the reactor driver"
    );
    assert_eq!(
        reactor_out.metrics.scalars_transferred,
        thread_out.metrics.scalars_transferred
    );
}
