//! End-to-end integration over the native pipeline: every model × the
//! main combiners, plus the burn-in parallelization claim.

use repro::combine::CombineMethod;
use repro::config::PipelineConfig;
use repro::coordinator::pipeline;
use repro::data::synth;
use repro::evaluation::mean_l2_error;
use repro::sampler::SamplerKind;

#[test]
fn logistic_pipeline_recovers_generating_beta_direction() {
    let d = 8;
    let data = synth::logistic(20_000, d, 42);
    let beta_true = synth::logistic_truth(d, 42);
    let cfg = PipelineConfig::builder("logistic")
        .machines(5)
        .samples_per_machine(800)
        .sampler(SamplerKind::Hmc { step: 0.05, n_leapfrog: 10 })
        .method(CombineMethod::Parametric)
        .seed(1)
        .build();
    let out = pipeline::run_native(&cfg, &data).unwrap();
    let mean = out.combined.mean();
    // With N=20k the posterior concentrates near β*: check cosine
    // similarity rather than absolute values (finite-sample shrinkage).
    let dot: f64 = mean.iter().zip(&beta_true).map(|(a, b)| a * b).sum();
    let na: f64 = mean.iter().map(|v| v * v).sum::<f64>().sqrt();
    let nb: f64 = beta_true.iter().map(|v| v * v).sum::<f64>().sqrt();
    let cos = dot / (na * nb);
    assert!(cos > 0.98, "cosine {cos}, mean {mean:?}");
}

#[test]
fn gmm_pipeline_exact_methods_keep_mass_on_modes() {
    // Paper Fig. 4 claim: the asymptotically exact combiners keep the
    // posterior's multimodal structure (draws concentrate ON the
    // permutation modes), while the parametric estimator smears mass
    // into the empty region between them. (Visiting *every* mode in a
    // short IMG run is not guaranteed — the index chain can dwell.)
    let k = 2;
    let sep = 5.0;
    let data = synth::gmm(6_000, k, 2, sep, 7);
    let centers = synth::gmm_true_means(k, 2, sep);
    let cfg = PipelineConfig::builder("gmm")
        .machines(4)
        .samples_per_machine(1_500)
        .sampler(SamplerKind::Rwm { scale: 0.1 })
        .method(CombineMethod::Nonparametric)
        .seed(2)
        .build();
    let out = pipeline::run_native(&cfg, &data).unwrap();

    let near_mode_frac = |s: &repro::types::SampleMatrix| -> f64 {
        let marg = s.select_dims(&[0, 1]).unwrap();
        let hits = marg
            .rows()
            .filter(|r| {
                centers.iter().any(|c| {
                    repro::math::linalg::sq_dist(r, &c[..2]) < 2.25
                })
            })
            .count();
        hits as f64 / marg.len() as f64
    };

    let nonpar = near_mode_frac(&out.combined);
    let par = near_mode_frac(
        &repro::combine::combine(
            CombineMethod::Parametric,
            &out.subposteriors,
            1_500,
            5,
        )
        .unwrap(),
    );
    assert!(nonpar > 0.8, "nonparametric near-mode mass {nonpar}");
    // Each subposterior hops between ±modes, so the Gaussian fit centers
    // between them → most parametric draws live off-mode.
    assert!(
        par < 0.5 && par < nonpar,
        "parametric should smear: {par} vs nonparametric {nonpar}"
    );
}

#[test]
fn poisson_gamma_pipeline_recovers_hyperparameters() {
    let data = synth::poisson_gamma(30_000, 9);
    let cfg = PipelineConfig::builder("poisson_gamma")
        .machines(5)
        .samples_per_machine(1_000)
        .sampler(SamplerKind::Hmc { step: 0.02, n_leapfrog: 10 })
        .method(CombineMethod::Semiparametric)
        .seed(3)
        .build();
    let out = pipeline::run_native(&cfg, &data).unwrap();
    let mean = out.combined.mean();
    // θ = (log a, log b); generated with a=2, b=1.5.
    assert!((mean[0] - 2.0f64.ln()).abs() < 0.3, "log a {}", mean[0]);
    assert!((mean[1] - 1.5f64.ln()).abs() < 0.3, "log b {}", mean[1]);
}

/// The burn-in parallelization claim (paper section 8.1, Fig. 2 right):
/// a subposterior worker takes its steps ~M× faster than a full-data
/// chain, so the parallel setup finishes burn-in + sampling in a
/// fraction of the single-chain wall-clock.
#[test]
fn workers_burn_in_faster_than_full_chain() {
    let data = synth::logistic(20_000, 5, 11);
    let t = 300;
    let machines = 10;
    let par_cfg = PipelineConfig::builder("logistic")
        .machines(machines)
        .samples_per_machine(t)
        .sampler(SamplerKind::Hmc { step: 0.05, n_leapfrog: 10 })
        .threads(1) // sequential workers → comparable per-step cost
        .seed(4)
        .build();
    let single_cfg = PipelineConfig::builder("logistic")
        .machines(1)
        .samples_per_machine(t)
        .sampler(SamplerKind::Hmc { step: 0.05, n_leapfrog: 10 })
        .seed(4)
        .build();
    let par = pipeline::run_native(&par_cfg, &data).unwrap();
    let single = pipeline::run_single_chain(&single_cfg, &data).unwrap();
    // Cluster-model time = max worker (each sees N/M data) must beat the
    // full chain by a wide margin; allow 2× slack for constant overhead.
    assert!(
        par.timing.sampling_secs < single.wall_secs / (machines as f64 / 2.0),
        "parallel {}s vs single {}s",
        par.timing.sampling_secs,
        single.wall_secs
    );
}

#[test]
fn duplicate_chains_pool_is_unbiased_but_not_faster() {
    let data = synth::gaussian(4_000, 2, 21);
    let cfg = PipelineConfig::builder("gaussian")
        .machines(1)
        .samples_per_machine(600)
        .seed(5)
        .build();
    // Three duplicate full-data chains with different seeds.
    let mut pools = Vec::new();
    for s in 0..3u64 {
        let mut c = cfg.clone();
        c.seed = 100 + s;
        pools.push(pipeline::run_single_chain(&c, &data).unwrap().samples);
    }
    let refs: Vec<&repro::types::SampleMatrix> = pools.iter().collect();
    let pooled = repro::combine::duplicate_chains_pool(&refs).unwrap();
    assert_eq!(pooled.len(), 3 * 600);
    // Unbiased: close to a parallel-combined estimate of the posterior.
    let par_cfg = PipelineConfig::builder("gaussian")
        .machines(4)
        .samples_per_machine(600)
        .method(CombineMethod::Parametric)
        .seed(6)
        .build();
    let par = pipeline::run_native(&par_cfg, &data).unwrap();
    let err = mean_l2_error(&pooled, &par.combined);
    assert!(err < 0.1, "pooled vs parallel mean gap {err}");
}

#[test]
fn online_leader_matches_batch_combination() {
    use repro::coordinator::worker::run_worker;
    use repro::coordinator::Leader;
    use std::sync::mpsc::channel;

    let data = synth::gaussian(5_000, 2, 31);
    let shards = repro::coordinator::partition::Partitioner::Contiguous
        .split(5_000, 3, 0)
        .unwrap();
    let (tx, rx) = channel();
    let mut root = repro::rng::Pcg64::seed_from(77);
    let mut batch_subs = Vec::new();
    for m in 0..3 {
        let target = data.subposterior(&shards[m], 1.0 / 3.0).unwrap();
        let out = run_worker(
            m,
            target.as_ref(),
            SamplerKind::Hmc { step: 0.3, n_leapfrog: 8 }.build(2),
            500,
            100,
            1,
            root.split(m as u64),
            Some(&tx),
        );
        batch_subs.push(out);
    }
    drop(tx);
    let mut leader = Leader::new(3, 2);
    leader.drain(&rx).unwrap();
    assert!(leader.all_finished());
    assert_eq!(leader.combiner().total_received(), 1_500);

    let online = leader
        .draws(CombineMethod::Parametric, 1_000, 9)
        .unwrap();
    let batch = repro::combine::combine(
        CombineMethod::Parametric,
        &batch_subs,
        1_000,
        9,
    )
    .unwrap();
    // Identical inputs + seed → identical draws.
    assert_eq!(online.as_slice(), batch.as_slice());
}
