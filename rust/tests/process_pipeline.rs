//! Process-mode pipeline integration: spawns real worker OS processes
//! through the `repro` binary (cargo builds it for us and exports its
//! path as `CARGO_BIN_EXE_repro`) and checks byte-identity with the
//! in-thread path — the whole-pipeline extension of PR 1's combine
//! determinism guarantee.

use repro::combine::CombineMethod;
use repro::config::PipelineConfig;
use repro::coordinator::pipeline;
use repro::data::io::ShardFormat;
use repro::data::synth;

fn process_cfg(
    model: &str,
    machines: usize,
    t: usize,
    method: CombineMethod,
) -> PipelineConfig {
    let mut c = PipelineConfig::builder(model)
        .machines(machines)
        .samples_per_machine(t)
        .method(method)
        .seed(17)
        .build();
    c.process_mode = true;
    c.worker_bin = env!("CARGO_BIN_EXE_repro").to_string();
    c
}

fn assert_byte_identical(
    proc_out: &pipeline::PipelineOutput,
    thread_out: &pipeline::PipelineOutput,
) {
    assert_eq!(proc_out.subposteriors.len(), thread_out.subposteriors.len());
    for (a, b) in proc_out.subposteriors.iter().zip(&thread_out.subposteriors)
    {
        assert_eq!(
            a.samples.as_slice(),
            b.samples.as_slice(),
            "machine {} draws diverged across the process boundary",
            a.machine
        );
        // The stream-rebuilt telemetry is complete on the process side.
        assert_eq!(a.draw_times.len(), a.samples.len());
        assert!(a.accept_rate.is_finite());
    }
    assert_eq!(
        proc_out.combined.as_slice(),
        thread_out.combined.as_slice(),
        "combined output diverged between process and thread mode"
    );
    assert_eq!(
        proc_out.metrics.scalars_transferred,
        thread_out.metrics.scalars_transferred,
        "leader must stream-ingest the same O(dTM) scalars in both modes"
    );
}

#[test]
fn process_mode_is_byte_identical_to_thread_mode() {
    let data = synth::gaussian(1_500, 2, 3);
    let pc = process_cfg("gaussian", 3, 200, CombineMethod::Semiparametric);
    let proc_out = pipeline::run_process(&pc, &data).unwrap();
    let mut tc = pc.clone();
    tc.process_mode = false;
    let thread_out = pipeline::run_native(&tc, &data).unwrap();
    assert_byte_identical(&proc_out, &thread_out);
}

/// A second model family exercises the logistic shard serde path and a
/// different combiner.
#[test]
fn process_mode_logistic_matches_thread_mode() {
    let data = synth::logistic(1_200, 3, 9);
    let pc = process_cfg("logistic", 2, 150, CombineMethod::Parametric);
    let proc_out = pipeline::run_process(&pc, &data).unwrap();
    let mut tc = pc.clone();
    tc.process_mode = false;
    let thread_out = pipeline::run_native(&tc, &data).unwrap();
    assert_byte_identical(&proc_out, &thread_out);
}

/// The adaptation-freeze regression interacts with process mode too:
/// with `burn_in = 0` both paths must freeze before the first retained
/// draw and still agree byte-for-byte.
#[test]
fn process_mode_with_zero_burnin_matches_thread_mode() {
    let data = synth::gaussian(800, 1, 5);
    let mut pc = process_cfg("gaussian", 2, 120, CombineMethod::Parametric);
    pc.burn_in = 0;
    let proc_out = pipeline::run_process(&pc, &data).unwrap();
    let mut tc = pc.clone();
    tc.process_mode = false;
    let thread_out = pipeline::run_native(&tc, &data).unwrap();
    assert_byte_identical(&proc_out, &thread_out);
}

#[test]
fn process_mode_off_degrades_to_thread_path() {
    let data = synth::gaussian(600, 1, 5);
    let mut c = process_cfg("gaussian", 2, 100, CombineMethod::Parametric);
    c.process_mode = false;
    let out = pipeline::run_process(&c, &data).unwrap();
    assert_eq!(out.subposteriors.len(), 2);
    assert_eq!(out.combined.len(), 100);
}

/// Oversubscription: with fewer worker processes than machines
/// (W ∈ {1, M/2}) the M manifests queue onto the W slots — and because
/// machine m's RNG stream is `root.split(m)` regardless of which slot
/// runs it, the output stays byte-identical to thread mode.
#[test]
fn oversubscribed_process_mode_is_byte_identical_to_thread_mode() {
    let data = synth::gaussian(1_600, 2, 13);
    let base = process_cfg("gaussian", 4, 150, CombineMethod::Semiparametric);
    let mut tc = base.clone();
    tc.process_mode = false;
    let thread_out = pipeline::run_native(&tc, &data).unwrap();
    for slots in [1usize, 2] {
        let mut pc = base.clone();
        pc.worker_slots = slots;
        let proc_out = pipeline::run_process(&pc, &data).unwrap();
        assert_byte_identical(&proc_out, &thread_out);
    }
}

/// The binary shard spill format must be invisible to the output:
/// workers autodetect it, and the draws stay byte-identical to thread
/// mode (which never spills at all).
#[test]
fn binary_shard_format_is_byte_identical_to_thread_mode() {
    let data = synth::logistic(1_000, 2, 29);
    let mut pc = process_cfg("logistic", 3, 120, CombineMethod::Parametric);
    pc.shard_format = ShardFormat::Binary;
    pc.worker_slots = 2; // oversubscribe while we're at it
    let proc_out = pipeline::run_process(&pc, &data).unwrap();
    let mut tc = pc.clone();
    tc.process_mode = false;
    let thread_out = pipeline::run_native(&tc, &data).unwrap();
    assert_byte_identical(&proc_out, &thread_out);
}

/// Tentpole gate: the binary draw plane is invisible to the output.
/// For every draw_batch ∈ {1, 7, 64} the binary-wire process run must
/// be byte-identical to thread mode (which never frames a single draw)
/// — including a batch size (7) that leaves a short tail chunk and one
/// (64) larger than some chunks' draw counts.
#[test]
fn binary_wire_format_is_byte_identical_to_thread_mode() {
    use repro::coordinator::transport::WireFormat;
    let data = synth::gaussian(1_200, 2, 41);
    let base = process_cfg("gaussian", 3, 130, CombineMethod::Semiparametric);
    let mut tc = base.clone();
    tc.process_mode = false;
    let thread_out = pipeline::run_native(&tc, &data).unwrap();
    for batch in [1usize, 7, 64] {
        let mut pc = base.clone();
        pc.wire_format = WireFormat::Binary;
        pc.draw_batch = batch;
        pc.shard_format = ShardFormat::Binary; // mmap ingest on the workers
        let proc_out = pipeline::run_process(&pc, &data).unwrap();
        assert_byte_identical(&proc_out, &thread_out);
    }
}

/// The run's scratch directory (shard + manifest spills) is owned by
/// the output and removed when it drops — the tempdir contract.
#[test]
fn run_dir_spills_cleaned_up_with_output() {
    let data = synth::gaussian(600, 1, 7);
    let pc = process_cfg("gaussian", 2, 60, CombineMethod::Parametric);
    let out = pipeline::run_process(&pc, &data).unwrap();
    let dir = out
        .run_dir
        .as_ref()
        .expect("process-mode output owns its run dir")
        .path()
        .to_path_buf();
    assert!(dir.join("shard_0.json").is_file());
    assert!(dir.join("worker_1.json").is_file());
    drop(out);
    assert!(!dir.exists(), "run dir must be removed with the output");
}

#[test]
fn missing_worker_binary_surfaces_spawn_error() {
    let data = synth::gaussian(600, 1, 5);
    let mut c = process_cfg("gaussian", 2, 50, CombineMethod::Parametric);
    c.worker_bin = "/nonexistent/repro-worker-binary".into();
    let err = pipeline::run_process(&c, &data).unwrap_err();
    let text = err.to_string();
    assert!(
        text.contains("spawning worker"),
        "error should name the spawn failure, got: {text}"
    );
}
