//! Property-based tests on coordinator/combiner invariants.
//!
//! `proptest` is not available in this offline environment (DESIGN.md
//! §3), so this file ships a minimal random-case harness with the same
//! discipline: N randomized cases per property, deterministic seeds, and
//! failing inputs printed for reproduction.

use repro::combine::{self, CombineMethod};
use repro::coordinator::partition::Partitioner;
use repro::math::linalg::{self, Mat};
use repro::rng::Pcg64;
use repro::types::SampleMatrix;

/// Run `cases` randomized instances of a property.
fn forall(name: &str, cases: u64, mut prop: impl FnMut(&mut Pcg64)) {
    for case in 0..cases {
        let mut rng = Pcg64::new(0xC0FFEE ^ case, case);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
            || prop(&mut rng),
        ));
        if let Err(e) = result {
            eprintln!("property '{name}' failed at case {case}");
            std::panic::resume_unwind(e);
        }
    }
}

fn random_spd(rng: &mut Pcg64, d: usize) -> Mat {
    // B Bᵀ + d·I — always SPD and decently conditioned.
    let mut b = Mat::zeros(d, d);
    for i in 0..d {
        for j in 0..d {
            b[(i, j)] = rng.normal();
        }
    }
    let mut a = b.matmul(&b.transpose()).unwrap();
    for i in 0..d {
        a[(i, i)] += d as f64;
    }
    a
}

fn random_samples(rng: &mut Pcg64, t: usize, d: usize, scale: f64) -> SampleMatrix {
    let mut s = SampleMatrix::new(d);
    let offset: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
    for _ in 0..t {
        let row: Vec<f64> =
            offset.iter().map(|o| o + scale * rng.normal()).collect();
        s.push(&row);
    }
    s
}

#[test]
fn prop_partition_is_exact_cover() {
    forall("partition_exact_cover", 50, |rng| {
        let n = 1 + rng.uniform_usize(5_000);
        let m = 1 + rng.uniform_usize(n.min(64));
        let strategy = [
            Partitioner::Contiguous,
            Partitioner::Random,
            Partitioner::RoundRobin,
        ][rng.uniform_usize(3)];
        let shards = strategy.split(n, m, rng.next_u64()).unwrap();
        assert_eq!(shards.len(), m);
        let mut seen = vec![false; n];
        for s in &shards {
            assert!(!s.is_empty(), "empty shard (n={n}, m={m})");
            for &i in s {
                assert!(!seen[i], "dup index {i}");
                seen[i] = true;
            }
        }
        assert!(seen.iter().all(|&b| b), "missing indices (n={n}, m={m})");
        let max = shards.iter().map(Vec::len).max().unwrap();
        let min = shards.iter().map(Vec::len).min().unwrap();
        assert!(max - min <= 1, "imbalance {min}..{max}");
    });
}

#[test]
fn prop_cholesky_solve_roundtrip() {
    forall("cholesky_roundtrip", 60, |rng| {
        let d = 1 + rng.uniform_usize(10);
        let a = random_spd(rng, d);
        let l = linalg::cholesky(&a).unwrap();
        let b: Vec<f64> = (0..d).map(|_| 3.0 * rng.normal()).collect();
        let x = linalg::chol_solve(&l, &b);
        let back = a.matvec(&x).unwrap();
        for i in 0..d {
            assert!(
                (back[i] - b[i]).abs() < 1e-7 * b[i].abs().max(1.0),
                "d={d} i={i}: {} vs {}",
                back[i],
                b[i]
            );
        }
        // logdet consistency with the inverse: logdet(A) = -logdet(A⁻¹).
        let inv = linalg::chol_inverse(&l);
        let linv = linalg::cholesky(&inv).unwrap();
        assert!(
            (linalg::chol_logdet(&l) + linalg::chol_logdet(&linv)).abs() < 1e-6,
            "logdet inconsistency (d={d})"
        );
    });
}

#[test]
fn prop_gaussian_product_precision_adds() {
    forall("gaussian_product_precision", 40, |rng| {
        use repro::combine::gaussian_product::{
            gaussian_product, GaussianEstimate,
        };
        let d = 1 + rng.uniform_usize(5);
        let m = 2 + rng.uniform_usize(6);
        let mut prec_sum = Mat::zeros(d, d);
        let mut ests = Vec::new();
        for _ in 0..m {
            let cov = random_spd(rng, d);
            let prec = linalg::spd_inverse_jittered(&cov).unwrap();
            prec_sum = prec_sum.add(&prec).unwrap();
            let mean: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
            ests.push(GaussianEstimate { mean, cov, prec });
        }
        let product = gaussian_product(&ests).unwrap();
        // The product's density must integrate information: its logpdf
        // curvature along each axis equals the summed precision.
        let mu = product.mean().to_vec();
        for j in 0..d {
            let h = 1e-4;
            let mut up = mu.clone();
            up[j] += h;
            let mut dn = mu.clone();
            dn[j] -= h;
            let second = (product.logpdf(&up) - 2.0 * product.logpdf(&mu)
                + product.logpdf(&dn))
                / (h * h);
            assert!(
                (second + prec_sum[(j, j)]).abs()
                    < 1e-2 * prec_sum[(j, j)].abs().max(1.0),
                "axis {j}: curvature {second} vs -{}",
                prec_sum[(j, j)]
            );
        }
    });
}

#[test]
fn prop_combiners_preserve_dim_and_count() {
    forall("combiner_shape", 30, |rng| {
        let d = 1 + rng.uniform_usize(4);
        let m = 1 + rng.uniform_usize(5);
        let t = 50 + rng.uniform_usize(150);
        let sets: Vec<SampleMatrix> =
            (0..m).map(|_| random_samples(rng, t, d, 0.8)).collect();
        let refs: Vec<&SampleMatrix> = sets.iter().collect();
        let t_out = 1 + rng.uniform_usize(2 * t);
        for &method in CombineMethod::all() {
            let out =
                combine::combine_sets(method, &refs, t_out, rng.next_u64())
                    .unwrap();
            assert_eq!(out.dim(), d, "{} dim", method.name());
            let expect = match method {
                CombineMethod::SubpostPool => t_out.min(m * t),
                // With a single machine, pairwise is a pass-through of
                // that machine's draws (no pair to combine).
                CombineMethod::Pairwise if m == 1 => t_out.min(t),
                _ => t_out,
            };
            assert_eq!(out.len(), expect, "{} count", method.name());
            assert!(
                out.as_slice().iter().all(|v| v.is_finite()),
                "{} produced non-finite draws",
                method.name()
            );
        }
    });
}

#[test]
fn prop_img_accept_state_consistent() {
    // The IMG fast path's cached (S, Q) must always equal a fresh
    // recomputation — run the chain then audit the invariant.
    forall("img_cache_consistency", 20, |rng| {
        use repro::combine::nonparametric::Img;
        let d = 1 + rng.uniform_usize(3);
        let m = 2 + rng.uniform_usize(4);
        let t = 30 + rng.uniform_usize(100);
        let sets: Vec<SampleMatrix> =
            (0..m).map(|_| random_samples(rng, t, d, 1.0)).collect();
        let refs: Vec<&SampleMatrix> = sets.iter().collect();
        let mut img = Img::new(&refs);
        let mut chain_rng = Pcg64::seed_from(rng.next_u64());
        let out = img.run(200, &mut chain_rng);
        assert_eq!(out.len(), 200);
        assert!(img.accept_rate() > 0.0);
        // Every combined draw is finite and near the convex hull of the
        // subposterior draws (θ̄ is an average + O(h) noise).
        let bound = 20.0;
        for row in out.rows() {
            for v in row {
                assert!(v.is_finite() && v.abs() < bound, "draw {v}");
            }
        }
    });
}

#[test]
fn prop_running_moments_match_batch() {
    forall("running_moments", 40, |rng| {
        use repro::math::running::RunningMoments;
        let d = 1 + rng.uniform_usize(4);
        let t = 2 + rng.uniform_usize(200);
        let s = random_samples(rng, t, d, 2.0);
        let mut rm = RunningMoments::new(d);
        for row in s.rows() {
            rm.push(row);
        }
        let bm = s.mean();
        let bc = s.covariance();
        let rc = rm.covariance();
        for i in 0..d {
            assert!((rm.mean()[i] - bm[i]).abs() < 1e-9);
            for j in 0..d {
                assert!(
                    (rc[(i, j)] - bc[(i, j)]).abs()
                        < 1e-8 * bc[(i, j)].abs().max(1.0),
                    "cov[{i}{j}]"
                );
            }
        }
    });
}
