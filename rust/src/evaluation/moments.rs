//! Moment-error summaries (secondary metrics; the paper's primary metric
//! is the L₂ density distance, which moments cannot replace for
//! multimodal posteriors — section 8, footnote 5).

use crate::types::SampleMatrix;

/// ‖mean(a) − mean(b)‖₂.
pub fn mean_l2_error(a: &SampleMatrix, b: &SampleMatrix) -> f64 {
    assert_eq!(a.dim(), b.dim());
    let ma = a.mean();
    let mb = b.mean();
    ma.iter()
        .zip(&mb)
        .map(|(x, y)| (x - y) * (x - y))
        .sum::<f64>()
        .sqrt()
}

/// Frobenius norm of the covariance difference.
pub fn cov_frobenius_error(a: &SampleMatrix, b: &SampleMatrix) -> f64 {
    assert_eq!(a.dim(), b.dim());
    let ca = a.covariance();
    let cb = b.covariance();
    let d = a.dim();
    let mut acc = 0.0;
    for i in 0..d {
        for j in 0..d {
            let r = ca[(i, j)] - cb[(i, j)];
            acc += r * r;
        }
    }
    acc.sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::math::linalg::Mat;
    use crate::math::mvn::Mvn;
    use crate::rng::Pcg64;

    #[test]
    fn zero_for_identical() {
        let mut rng = Pcg64::seed_from(1);
        let s = Mvn::new(vec![0.0, 1.0], Mat::identity(2))
            .unwrap()
            .sample_n(500, &mut rng);
        assert_eq!(mean_l2_error(&s, &s), 0.0);
        assert_eq!(cov_frobenius_error(&s, &s), 0.0);
    }

    #[test]
    fn detects_mean_shift_and_scale() {
        let mut rng = Pcg64::seed_from(2);
        let a = Mvn::new(vec![0.0], Mat::diag(&[1.0]))
            .unwrap()
            .sample_n(20_000, &mut rng);
        let b = Mvn::new(vec![2.0], Mat::diag(&[1.0]))
            .unwrap()
            .sample_n(20_000, &mut rng);
        let c = Mvn::new(vec![0.0], Mat::diag(&[4.0]))
            .unwrap()
            .sample_n(20_000, &mut rng);
        assert!((mean_l2_error(&a, &b) - 2.0).abs() < 0.05);
        assert!((cov_frobenius_error(&a, &c) - 3.0).abs() < 0.2);
    }
}
