//! Evaluation: the paper's L₂ posterior-error metric, posterior
//! predictive classification accuracy, and moment-error summaries.

pub mod accuracy;
pub mod l2;
pub mod moments;

pub use accuracy::classification_accuracy;
pub use l2::{l2_distance, l2_distance_subsampled};
pub use moments::{cov_frobenius_error, mean_l2_error};
