//! Posterior-predictive classification accuracy (paper section 8.1.2).
//!
//! `P(y | x, data) ≈ (1/S) Σ_s P(y | x, β_s)` over posterior draws β_s;
//! a point is classified 1 when the predictive probability exceeds 1/2.

use crate::math::linalg::dot;
use crate::math::special::sigmoid;
use crate::types::SampleMatrix;

/// Mean predictive probability `P(y=1|x)` for each test row.
pub fn predictive_probs(
    draws: &SampleMatrix,
    x_test: &SampleMatrix,
) -> Vec<f64> {
    assert_eq!(draws.dim(), x_test.dim(), "β/x dim mismatch");
    let s = draws.len().max(1) as f64;
    x_test
        .rows()
        .map(|x| {
            draws.rows().map(|b| sigmoid(dot(x, b))).sum::<f64>() / s
        })
        .collect()
}

/// Classification accuracy of the posterior predictive on a test set.
pub fn classification_accuracy(
    draws: &SampleMatrix,
    x_test: &SampleMatrix,
    y_test: &[f64],
) -> f64 {
    assert_eq!(x_test.len(), y_test.len());
    let probs = predictive_probs(draws, x_test);
    let correct = probs
        .iter()
        .zip(y_test)
        .filter(|(&p, &y)| (p > 0.5) == (y == 1.0))
        .count();
    correct as f64 / y_test.len().max(1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{synth, Dataset};

    #[test]
    fn true_beta_scores_high_accuracy() {
        let ds = synth::logistic(4000, 6, 1);
        let beta = synth::logistic_truth(6, 1);
        if let Dataset::Logistic { x, y, .. } = &ds {
            let mut draws = SampleMatrix::new(6);
            draws.push(&beta);
            let acc = classification_accuracy(&draws, x, y);
            assert!(acc > 0.75, "accuracy {acc}");
        } else {
            panic!()
        }
    }

    #[test]
    fn zero_beta_is_chance_level() {
        let ds = synth::logistic(4000, 6, 2);
        if let Dataset::Logistic { x, y, .. } = &ds {
            let mut draws = SampleMatrix::new(6);
            draws.push(&vec![0.0; 6]);
            let acc = classification_accuracy(&draws, x, y);
            assert!((acc - 0.5).abs() < 0.15, "accuracy {acc}");
        } else {
            panic!()
        }
    }

    #[test]
    fn averaging_over_draws_smooths_probs() {
        let mut draws = SampleMatrix::new(1);
        draws.push(&[10.0]);
        draws.push(&[-10.0]);
        let mut x = SampleMatrix::new(1);
        x.push(&[1.0]);
        let p = predictive_probs(&draws, &x);
        assert!((p[0] - 0.5).abs() < 1e-3);
    }
}
