//! L₂ distance between two sampled densities (paper section 8).
//!
//! The paper scores every method by `d₂(p, p̂) = ‖p − p̂‖₂` between the
//! groundtruth posterior density `p` (long full-data chain) and the
//! method's density `p̂`, both represented by samples. With Gaussian-KDE
//! representations this integral has a *closed form*: for mixtures
//! `p̂ = (1/T_a) Σ_i N(·|a_i, h_a² I)` and `q̂ = (1/T_b) Σ_j N(·|b_j, h_b² I)`,
//!
//!   ‖p̂ − q̂‖₂² = S_aa + S_bb − 2 S_ab,
//!   S_xy = (1/(T_x T_y)) Σ_ij N(x_i | y_j, (h_x² + h_y²) I),
//!
//! because ∫ N(x|a,A) N(x|b,B) dx = N(a | b, A+B). The three double sums
//! are evaluated in log-space (log-sum-exp) so the `h^{-d}` factors never
//! overflow even at d = 50+.

use crate::math::mvn::iso_logpdf;
use crate::math::special::log_sum_exp;
use crate::stats::kde::scott_bandwidth;
use crate::types::SampleMatrix;

/// log of S_xy (the cross term above), computed stably.
fn log_cross_term(a: &SampleMatrix, b: &SampleMatrix, var: f64) -> f64 {
    let mut logs = Vec::with_capacity(a.len() * b.len());
    for ra in a.rows() {
        for rb in b.rows() {
            logs.push(iso_logpdf(ra, rb, var));
        }
    }
    log_sum_exp(&logs) - ((a.len() * b.len()) as f64).ln()
}

/// Exact (up to KDE) L₂ distance between two sample sets with explicit
/// bandwidths. O(T_a·T_b + T_a² + T_b²).
pub fn l2_distance_with(
    a: &SampleMatrix,
    b: &SampleMatrix,
    h_a: f64,
    h_b: f64,
) -> f64 {
    assert_eq!(a.dim(), b.dim(), "dim mismatch");
    assert!(h_a > 0.0 && h_b > 0.0);
    let log_saa = log_cross_term(a, a, 2.0 * h_a * h_a);
    let log_sbb = log_cross_term(b, b, 2.0 * h_b * h_b);
    let log_sab = log_cross_term(a, b, h_a * h_a + h_b * h_b);
    // Combine in linear space after factoring out the max exponent.
    let m = log_saa.max(log_sbb).max(log_sab + std::f64::consts::LN_2);
    let val = (log_saa - m).exp() + (log_sbb - m).exp()
        - 2.0 * (log_sab - m).exp();
    (val.max(0.0) * m.exp()).sqrt()
}

/// L₂ distance with Scott-rule bandwidths fit per set.
pub fn l2_distance(a: &SampleMatrix, b: &SampleMatrix) -> f64 {
    l2_distance_with(a, b, scott_bandwidth(a), scott_bandwidth(b))
}

/// L₂ distance over deterministic stride subsamples capped at
/// `max_each` draws per set — the evaluation used by the timing
/// experiments (keeps scoring cost flat as T grows).
pub fn l2_distance_subsampled(
    a: &SampleMatrix,
    b: &SampleMatrix,
    max_each: usize,
) -> f64 {
    let sub = |s: &SampleMatrix| -> SampleMatrix {
        if s.len() <= max_each {
            s.clone()
        } else {
            s.thin(s.len().div_ceil(max_each))
        }
    };
    let (sa, sb) = (sub(a), sub(b));
    l2_distance(&sa, &sb)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::math::linalg::Mat;
    use crate::math::mvn::Mvn;
    use crate::rng::Pcg64;

    fn draws(seed: u64, mu: f64, var: f64, d: usize, t: usize) -> SampleMatrix {
        let mut rng = Pcg64::seed_from(seed);
        Mvn::new(vec![mu; d], Mat::scaled_identity(d, var))
            .unwrap()
            .sample_n(t, &mut rng)
    }

    #[test]
    fn identical_sets_have_zero_distance() {
        let a = draws(1, 0.0, 1.0, 2, 300);
        let d = l2_distance(&a, &a);
        assert!(d < 1e-8, "distance {d}");
    }

    #[test]
    fn same_distribution_small_distance() {
        let a = draws(2, 0.0, 1.0, 1, 800);
        let b = draws(3, 0.0, 1.0, 1, 800);
        let d = l2_distance(&a, &b);
        assert!(d < 0.08, "distance {d}");
    }

    #[test]
    fn distance_grows_with_separation() {
        let a = draws(4, 0.0, 1.0, 1, 500);
        let near = draws(5, 0.5, 1.0, 1, 500);
        let far = draws(6, 3.0, 1.0, 1, 500);
        let dn = l2_distance(&a, &near);
        let df = l2_distance(&a, &far);
        assert!(dn < df, "{dn} vs {df}");
        assert!(dn > 0.01);
    }

    #[test]
    fn known_value_two_point_masses() {
        // Two singleton "samples" with equal bandwidth h: the distance
        // between N(0,h²) and N(δ,h²) has closed form
        //   √(2/(2√π h) (1 - e^{-δ²/(4h²)})).
        let mut a = SampleMatrix::new(1);
        a.push(&[0.0]);
        let mut b = SampleMatrix::new(1);
        b.push(&[2.0]);
        let h = 0.7;
        let got = l2_distance_with(&a, &b, h, h);
        let saa = 1.0 / (2.0 * std::f64::consts::PI.sqrt() * h);
        let sab = saa * (-4.0f64 / (4.0 * h * h)).exp();
        let want = (2.0 * (saa - sab)).sqrt();
        assert!((got - want).abs() < 1e-10, "{got} vs {want}");
    }

    #[test]
    fn stable_in_high_dimension() {
        // d = 40: naive linear-space evaluation overflows; the log-space
        // path must stay finite. (Ordering in d=40 from 200 draws is
        // noise-dominated — the KDE metric saturates, which is why the
        // paper's Fig. 3-right reports *relative* error; ordering is
        // asserted at the d=10 scale used there.)
        let a = draws(7, 0.0, 1.0, 40, 200);
        let b = draws(8, 0.0, 1.0, 40, 200);
        assert!(l2_distance(&a, &b).is_finite());

        let a10 = draws(7, 0.0, 1.0, 10, 400);
        let b10 = draws(8, 0.0, 1.0, 10, 400);
        let c10 = draws(9, 2.0, 1.0, 10, 400);
        let dab = l2_distance(&a10, &b10);
        let dac = l2_distance(&a10, &c10);
        assert!(dab.is_finite() && dac.is_finite());
        assert!(dab < dac, "{dab} vs {dac}");
    }

    #[test]
    fn subsampling_approximates_full() {
        let a = draws(10, 0.0, 1.0, 1, 2000);
        let b = draws(11, 1.0, 1.0, 1, 2000);
        let full = l2_distance(&a, &b);
        let sub = l2_distance_subsampled(&a, &b, 400);
        // Subsampling changes the Scott bandwidth too; allow ~15%.
        assert!((full - sub).abs() < 0.15 * full.max(0.1), "{full} vs {sub}");
    }
}
