//! Datasets: synthetic generators for every paper experiment, a
//! covtype-like generator (substitute for the real 581k×54 dataset — see
//! DESIGN.md §3), and CSV I/O for experiment outputs.

pub mod io;
pub mod store;
pub mod synth;

use crate::error::{Error, Result};
use crate::model::{
    GaussianMean, GmmMeans, LinearRegression, LogDensity, LogisticRegression,
    PoissonGamma,
};
use crate::types::SampleMatrix;

/// A dataset plus the metadata needed to build subposterior models.
#[derive(Debug, Clone)]
pub enum Dataset {
    /// Gaussian mean estimation: observations + known likelihood precision.
    Gaussian { x: SampleMatrix, lik_prec: f64, prior_prec: f64 },
    /// Logistic regression: design matrix + labels.
    Logistic { x: SampleMatrix, y: Vec<f64>, prior_prec: f64 },
    /// GMM over means: observations + known log-weights and 1/σ².
    Gmm {
        x: SampleMatrix,
        logw: Vec<f64>,
        inv_var: f64,
        prior_prec: f64,
    },
    /// Poisson-gamma: counts + exposures + prior hyperparameters.
    PoissonGamma {
        xs: Vec<f64>,
        ts: Vec<f64>,
        lam: f64,
        alpha: f64,
        beta_p: f64,
    },
    /// Linear regression: design + responses + known noise precision.
    LinReg {
        x: SampleMatrix,
        y: Vec<f64>,
        lik_prec: f64,
        prior_prec: f64,
    },
}

impl Dataset {
    /// Number of observations.
    pub fn len(&self) -> usize {
        match self {
            Dataset::Gaussian { x, .. } => x.len(),
            Dataset::Logistic { x, .. } => x.len(),
            Dataset::Gmm { x, .. } => x.len(),
            Dataset::PoissonGamma { xs, .. } => xs.len(),
            Dataset::LinReg { x, .. } => x.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Dimension of the *parameter* θ.
    pub fn param_dim(&self) -> usize {
        match self {
            Dataset::Gaussian { x, .. } => x.dim(),
            Dataset::Logistic { x, .. } => x.dim(),
            Dataset::Gmm { x, logw, .. } => x.dim() * logw.len(),
            Dataset::PoissonGamma { .. } => 2,
            Dataset::LinReg { x, .. } => x.dim(),
        }
    }

    /// Model name matching [`crate::config::PipelineConfig::model`].
    pub fn model_name(&self) -> &'static str {
        match self {
            Dataset::Gaussian { .. } => "gaussian",
            Dataset::Logistic { .. } => "logistic",
            Dataset::Gmm { .. } => "gmm",
            Dataset::PoissonGamma { .. } => "poisson_gamma",
            Dataset::LinReg { .. } => "linreg",
        }
    }

    /// Build the subposterior model for the observation subset `idx`
    /// with prior weight `prior_w = 1/M` (Eq. 2.1). `prior_w = 1` with
    /// all indices gives the full-data posterior.
    pub fn subposterior(
        &self,
        idx: &[usize],
        prior_w: f64,
    ) -> Result<Box<dyn LogDensity>> {
        if idx.is_empty() {
            return Err(Error::Config("empty shard".into()));
        }
        match self {
            Dataset::Gaussian { x, lik_prec, prior_prec } => {
                let shard = select_rows(x, idx)?;
                Ok(Box::new(GaussianMean::new(
                    shard, *lik_prec, *prior_prec, prior_w,
                )))
            }
            Dataset::Logistic { x, y, prior_prec } => {
                let xs = select_rows(x, idx)?;
                let ys = idx.iter().map(|&i| y[i]).collect();
                Ok(Box::new(LogisticRegression::new(
                    xs, ys, *prior_prec, prior_w,
                )))
            }
            Dataset::Gmm { x, logw, inv_var, prior_prec } => {
                let shard = select_rows(x, idx)?;
                Ok(Box::new(GmmMeans::new(
                    shard,
                    logw.clone(),
                    *inv_var,
                    *prior_prec,
                    prior_w,
                )))
            }
            Dataset::PoissonGamma { xs, ts, lam, alpha, beta_p } => {
                let xsub = idx.iter().map(|&i| xs[i]).collect();
                let tsub = idx.iter().map(|&i| ts[i]).collect();
                Ok(Box::new(PoissonGamma::new(
                    xsub, tsub, prior_w, *lam, *alpha, *beta_p,
                )))
            }
            Dataset::LinReg { x, y, lik_prec, prior_prec } => {
                let xs = select_rows(x, idx)?;
                let ys = idx.iter().map(|&i| y[i]).collect();
                Ok(Box::new(LinearRegression::new(
                    xs, ys, *lik_prec, *prior_prec, prior_w,
                )))
            }
        }
    }

    /// Full-data posterior model (all observations, unpowered prior).
    pub fn full_posterior(&self) -> Result<Box<dyn LogDensity>> {
        let idx: Vec<usize> = (0..self.len()).collect();
        self.subposterior(&idx, 1.0)
    }

    /// Extract the observation subset `idx` as a standalone dataset
    /// with the same model metadata — the shard a process-mode worker
    /// receives. `select(idx).subposterior(0..len, w)` builds the
    /// identical model to `self.subposterior(idx, w)`, which is what
    /// lets a worker process reproduce its in-thread twin bit-exactly.
    pub fn select(&self, idx: &[usize]) -> Result<Dataset> {
        if idx.is_empty() {
            return Err(Error::Config("empty shard".into()));
        }
        match self {
            Dataset::Gaussian { x, lik_prec, prior_prec } => {
                Ok(Dataset::Gaussian {
                    x: select_rows(x, idx)?,
                    lik_prec: *lik_prec,
                    prior_prec: *prior_prec,
                })
            }
            Dataset::Logistic { x, y, prior_prec } => {
                let xs = select_rows(x, idx)?;
                Ok(Dataset::Logistic {
                    x: xs,
                    y: idx.iter().map(|&i| y[i]).collect(),
                    prior_prec: *prior_prec,
                })
            }
            Dataset::Gmm { x, logw, inv_var, prior_prec } => {
                Ok(Dataset::Gmm {
                    x: select_rows(x, idx)?,
                    logw: logw.clone(),
                    inv_var: *inv_var,
                    prior_prec: *prior_prec,
                })
            }
            Dataset::PoissonGamma { xs, ts, lam, alpha, beta_p } => {
                if let Some(&bad) = idx.iter().find(|&&i| i >= xs.len()) {
                    return Err(Error::Shape(format!(
                        "row index {bad} out of range ({})",
                        xs.len()
                    )));
                }
                Ok(Dataset::PoissonGamma {
                    xs: idx.iter().map(|&i| xs[i]).collect(),
                    ts: idx.iter().map(|&i| ts[i]).collect(),
                    lam: *lam,
                    alpha: *alpha,
                    beta_p: *beta_p,
                })
            }
            Dataset::LinReg { x, y, lik_prec, prior_prec } => {
                let xs = select_rows(x, idx)?;
                Ok(Dataset::LinReg {
                    x: xs,
                    y: idx.iter().map(|&i| y[i]).collect(),
                    lik_prec: *lik_prec,
                    prior_prec: *prior_prec,
                })
            }
        }
    }
}

/// Extract rows by index.
pub fn select_rows(x: &SampleMatrix, idx: &[usize]) -> Result<SampleMatrix> {
    let mut out = SampleMatrix::with_capacity(x.dim(), idx.len());
    for &i in idx {
        if i >= x.len() {
            return Err(Error::Shape(format!(
                "row index {i} out of range ({})",
                x.len()
            )));
        }
        out.push(x.row(i));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn subposterior_factory_all_models() {
        let g = synth::gaussian(100, 2, 1);
        let l = synth::logistic(100, 3, 2);
        let m = synth::gmm(100, 3, 2, 3.0, 3);
        let p = synth::poisson_gamma(100, 4);
        let r = synth::linreg(100, 2, 5);
        let idx: Vec<usize> = (0..50).collect();
        for ds in [&g, &l, &m, &p, &r] {
            let sub = ds.subposterior(&idx, 0.5).unwrap();
            assert_eq!(sub.dim(), ds.param_dim());
            let mut rng = crate::rng::Pcg64::seed_from(9);
            let theta = sub.init_point(&mut rng);
            let (lp, grad) = sub.logp_grad(&theta);
            assert!(lp.is_finite(), "{}", ds.model_name());
            assert!(grad.iter().all(|v| v.is_finite()));
        }
    }

    #[test]
    fn empty_shard_rejected() {
        let g = synth::gaussian(10, 2, 1);
        assert!(g.subposterior(&[], 0.5).is_err());
    }

    #[test]
    fn select_subset_builds_identical_subposterior() {
        let g = synth::gaussian(120, 2, 1);
        let l = synth::logistic(120, 3, 2);
        let m = synth::gmm(120, 3, 2, 3.0, 3);
        let p = synth::poisson_gamma(120, 4);
        let r = synth::linreg(120, 2, 5);
        let idx: Vec<usize> = (17..93).collect();
        for ds in [&g, &l, &m, &p, &r] {
            let direct = ds.subposterior(&idx, 0.25).unwrap();
            let shard = ds.select(&idx).unwrap();
            assert_eq!(shard.len(), idx.len(), "{}", ds.model_name());
            let all: Vec<usize> = (0..shard.len()).collect();
            let via = shard.subposterior(&all, 0.25).unwrap();
            let theta = vec![0.3; direct.dim()];
            let (lp_a, g_a) = direct.logp_grad(&theta);
            let (lp_b, g_b) = via.logp_grad(&theta);
            assert_eq!(lp_a.to_bits(), lp_b.to_bits(), "{}", ds.model_name());
            for (a, b) in g_a.iter().zip(&g_b) {
                assert_eq!(a.to_bits(), b.to_bits(), "{}", ds.model_name());
            }
        }
    }

    #[test]
    fn select_bounds_and_empty_checked() {
        let g = synth::gaussian(10, 2, 1);
        assert!(g.select(&[]).is_err());
        assert!(g.select(&[99]).is_err());
        let p = synth::poisson_gamma(10, 2);
        assert!(p.select(&[11]).is_err());
    }

    #[test]
    fn select_rows_bounds_checked() {
        let g = match synth::gaussian(10, 2, 1) {
            Dataset::Gaussian { x, .. } => x,
            _ => unreachable!(),
        };
        assert!(select_rows(&g, &[99]).is_err());
        let s = select_rows(&g, &[0, 5, 9]).unwrap();
        assert_eq!(s.len(), 3);
    }
}
