//! Synthetic dataset generators matching the paper's experiments.

use super::Dataset;
use crate::error::{Error, Result};
use crate::math::special::sigmoid;
use crate::rng::Pcg64;
use crate::types::SampleMatrix;

/// Build a dataset from the CLI/job-spec model name. This is the
/// single name→generator mapping shared by `repro pipeline` and the
/// `leaderd` job runner, so a job spec resolves to exactly the data a
/// solo CLI run would draw: same generator, same `(n, d, seed)`
/// arguments (the GMM fixes `k = 10`, `dim = 2`, `sep = 5.0` as in the
/// paper's mixture experiment; `poisson_gamma` ignores `d`).
pub fn by_name(model: &str, n: usize, d: usize, seed: u64) -> Result<Dataset> {
    Ok(match model {
        "gaussian" => gaussian(n, d, seed),
        "logistic" => logistic(n, d, seed),
        "covtype" => covtype_like(n, d, seed),
        "gmm" => gmm(n, 10, 2, 5.0, seed),
        "poisson_gamma" => poisson_gamma(n, seed),
        "linreg" => linreg(n, d, seed),
        other => {
            return Err(Error::Config(format!("unknown model '{other}'")))
        }
    })
}

/// Gaussian mean-estimation data: `x_i ~ N(μ*, I)` with
/// `μ*_j = 1 + j/10`. Known `lik_prec = 1`, prior `N(0, I/0.1)`.
pub fn gaussian(n: usize, d: usize, seed: u64) -> Dataset {
    let mut rng = Pcg64::seed_from(seed);
    let mu: Vec<f64> = (0..d).map(|j| 1.0 + j as f64 / 10.0).collect();
    let mut x = SampleMatrix::with_capacity(d, n);
    let mut row = vec![0.0; d];
    for _ in 0..n {
        for j in 0..d {
            row[j] = mu[j] + rng.normal();
        }
        x.push(&row);
    }
    Dataset::Gaussian { x, lik_prec: 1.0, prior_prec: 0.1 }
}

/// The paper's synthetic logistic regression (section 8.1.1): every
/// element of β and X drawn from a standard normal,
/// `y_i ~ Bernoulli(logit⁻¹(x_i·β))`. Returns the dataset; the
/// generating β is deterministic in `seed` via [`logistic_truth`].
pub fn logistic(n: usize, d: usize, seed: u64) -> Dataset {
    let (x, y, _) = logistic_raw(n, d, seed);
    Dataset::Logistic { x, y, prior_prec: 0.01 }
}

/// Generating parameter of [`logistic`] for the same seed.
pub fn logistic_truth(d: usize, seed: u64) -> Vec<f64> {
    let mut rng = Pcg64::seed_from(seed);
    (0..d).map(|_| rng.normal()).collect()
}

fn logistic_raw(
    n: usize,
    d: usize,
    seed: u64,
) -> (SampleMatrix, Vec<f64>, Vec<f64>) {
    let mut rng = Pcg64::seed_from(seed);
    let beta: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
    let mut x = SampleMatrix::with_capacity(d, n);
    let mut y = Vec::with_capacity(n);
    let mut row = vec![0.0; d];
    for _ in 0..n {
        let mut z = 0.0;
        for j in 0..d {
            row[j] = rng.normal();
            z += row[j] * beta[j];
        }
        y.push(if rng.bernoulli(sigmoid(z)) { 1.0 } else { 0.0 });
        x.push(&row);
    }
    (x, y, beta)
}

/// Covtype-like logistic data (substitute for the real 581k×54 forest
/// cover dataset): correlated mixed-scale features — a few dominant
/// directions plus noise dimensions, mimicking cartographic variables —
/// and labels from a sparse-ish generating β. Same protocol as the
/// paper's section 8.1.2 (classification accuracy vs time).
pub fn covtype_like(n: usize, d: usize, seed: u64) -> Dataset {
    let mut rng = Pcg64::seed_from(seed);
    // Sparse generating β: ~25% of coordinates active.
    let beta: Vec<f64> = (0..d)
        .map(|_| if rng.bernoulli(0.25) { 2.0 * rng.normal() } else { 0.0 })
        .collect();
    // Low-rank factor loadings to correlate features.
    let rank = (d / 8).max(2);
    let loadings: Vec<Vec<f64>> = (0..d)
        .map(|_| (0..rank).map(|_| 0.6 * rng.normal()).collect())
        .collect();
    let scales: Vec<f64> =
        (0..d).map(|_| rng.uniform() * 2.0 + 0.2).collect();
    let mut x = SampleMatrix::with_capacity(d, n);
    let mut y = Vec::with_capacity(n);
    let mut row = vec![0.0; d];
    let mut factors = vec![0.0; rank];
    for _ in 0..n {
        for f in factors.iter_mut() {
            *f = rng.normal();
        }
        let mut z = 0.0;
        for j in 0..d {
            let common: f64 =
                loadings[j].iter().zip(&factors).map(|(l, f)| l * f).sum();
            row[j] = scales[j] * (common + 0.8 * rng.normal());
            z += row[j] * beta[j];
        }
        // Scale logits to keep classes balanced but separable.
        y.push(if rng.bernoulli(sigmoid(0.5 * z)) { 1.0 } else { 0.0 });
        x.push(&row);
    }
    Dataset::Logistic { x, y, prior_prec: 0.01 }
}

/// The paper's GMM experiment (section 8.2): `n` draws from a
/// `k`-component mixture of `dim`-d Gaussians with equal weights,
/// isotropic unit-ish variance and well-separated means on a circle of
/// radius `sep`.
pub fn gmm(n: usize, k: usize, dim: usize, sep: f64, seed: u64) -> Dataset {
    let mut rng = Pcg64::seed_from(seed);
    let means = gmm_true_means(k, dim, sep);
    let sigma2: f64 = 1.0;
    let mut x = SampleMatrix::with_capacity(dim, n);
    let mut row = vec![0.0; dim];
    for _ in 0..n {
        let c = rng.uniform_usize(k);
        for j in 0..dim {
            row[j] = means[c][j] + sigma2.sqrt() * rng.normal();
        }
        x.push(&row);
    }
    Dataset::Gmm {
        x,
        logw: vec![-(k as f64).ln(); k],
        inv_var: 1.0 / sigma2,
        prior_prec: 0.01,
    }
}

/// True component means used by [`gmm`] (circle layout in the first two
/// coordinates, zeros beyond).
pub fn gmm_true_means(k: usize, dim: usize, sep: f64) -> Vec<Vec<f64>> {
    (0..k)
        .map(|c| {
            let angle = 2.0 * std::f64::consts::PI * c as f64 / k as f64;
            let mut mu = vec![0.0; dim];
            mu[0] = sep * angle.cos();
            if dim > 1 {
                mu[1] = sep * angle.sin();
            }
            mu
        })
        .collect()
}

/// The paper's hierarchical Poisson-gamma data (section 8.3):
/// `a* = 2, b* = 1.5`, exposures `t_i ~ U(0.5, 1.5)`,
/// `q_i ~ Gamma(a*, b*)`, `x_i ~ Poisson(q_i t_i)`.
pub fn poisson_gamma(n: usize, seed: u64) -> Dataset {
    let mut rng = Pcg64::seed_from(seed);
    let (a, b) = (2.0, 1.5);
    let mut xs = Vec::with_capacity(n);
    let mut ts = Vec::with_capacity(n);
    for _ in 0..n {
        let t = rng.uniform_range(0.5, 1.5);
        let q = rng.gamma(a, b);
        xs.push(rng.poisson(q * t) as f64);
        ts.push(t);
    }
    Dataset::PoissonGamma { xs, ts, lam: 1.0, alpha: 2.0, beta_p: 1.0 }
}

/// Linear regression with known noise: X ~ N(0, I) with mild
/// collinearity, `y = Xβ* + ε`, `ε ~ N(0, 0.5²)`.
pub fn linreg(n: usize, d: usize, seed: u64) -> Dataset {
    let mut rng = Pcg64::seed_from(seed);
    let beta: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
    let mut x = SampleMatrix::with_capacity(d, n);
    let mut y = Vec::with_capacity(n);
    let mut row = vec![0.0; d];
    for _ in 0..n {
        let shared = rng.normal();
        let mut z = 0.0;
        for j in 0..d {
            row[j] = 0.3 * shared + rng.normal();
            z += row[j] * beta[j];
        }
        y.push(z + 0.5 * rng.normal());
        x.push(&row);
    }
    Dataset::LinReg { x, y, lik_prec: 4.0, prior_prec: 1.0 }
}

/// Train/test split by index (deterministic shuffle).
pub fn train_test_split(
    n: usize,
    test_frac: f64,
    seed: u64,
) -> (Vec<usize>, Vec<usize>) {
    assert!((0.0..1.0).contains(&test_frac));
    let mut rng = Pcg64::seed_from(seed);
    let perm = rng.permutation(n);
    let n_test = (n as f64 * test_frac) as usize;
    let test = perm[..n_test].to_vec();
    let train = perm[n_test..].to_vec();
    (train, test)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn logistic_labels_binary_and_balanced_ish() {
        let ds = logistic(5000, 10, 1);
        if let Dataset::Logistic { y, .. } = &ds {
            assert!(y.iter().all(|&v| v == 0.0 || v == 1.0));
            let ones = y.iter().sum::<f64>() / y.len() as f64;
            assert!((0.3..0.7).contains(&ones), "ones frac {ones}");
        } else {
            panic!()
        }
    }

    #[test]
    fn logistic_truth_matches_generation_seed() {
        let ds = logistic(2000, 4, 9);
        let beta = logistic_truth(4, 9);
        // Labels must correlate with x·β sign.
        if let Dataset::Logistic { x, y, .. } = &ds {
            let mut agree = 0usize;
            for (row, &yi) in x.rows().zip(y) {
                let z: f64 = row.iter().zip(&beta).map(|(a, b)| a * b).sum();
                if (z > 0.0) == (yi == 1.0) {
                    agree += 1;
                }
            }
            let frac = agree as f64 / y.len() as f64;
            // Bernoulli noise caps attainable agreement well below 1.
            assert!(frac > 0.6, "agreement {frac}");
        } else {
            panic!()
        }
    }

    #[test]
    fn gmm_data_clusters_near_true_means() {
        let ds = gmm(3000, 4, 2, 6.0, 2);
        let means = gmm_true_means(4, 2, 6.0);
        if let Dataset::Gmm { x, .. } = &ds {
            // Every point should be within ~4σ of some component mean.
            let mut far = 0usize;
            for row in x.rows() {
                let near = means.iter().any(|mu| {
                    crate::math::linalg::sq_dist(row, mu) < 16.0
                });
                if !near {
                    far += 1;
                }
            }
            assert!(far < 30, "{far} far points");
        } else {
            panic!()
        }
    }

    #[test]
    fn poisson_gamma_counts_nonnegative() {
        let ds = poisson_gamma(2000, 3);
        if let Dataset::PoissonGamma { xs, ts, .. } = &ds {
            assert!(xs.iter().all(|&x| x >= 0.0 && x.fract() == 0.0));
            assert!(ts.iter().all(|&t| (0.5..1.5).contains(&t)));
            // Mean count ≈ E[q]·E[t] = (a/b)·1 = 4/3.
            let mean = xs.iter().sum::<f64>() / xs.len() as f64;
            assert!((mean - 4.0 / 3.0).abs() < 0.15, "mean {mean}");
        } else {
            panic!()
        }
    }

    #[test]
    fn covtype_like_shapes() {
        let ds = covtype_like(1000, 54, 4);
        assert_eq!(ds.len(), 1000);
        assert_eq!(ds.param_dim(), 54);
        if let Dataset::Logistic { y, .. } = &ds {
            let ones = y.iter().sum::<f64>() / y.len() as f64;
            assert!((0.2..0.8).contains(&ones), "ones frac {ones}");
        }
    }

    #[test]
    fn split_is_partition() {
        let (train, test) = train_test_split(100, 0.2, 5);
        assert_eq!(train.len() + test.len(), 100);
        let mut seen = vec![false; 100];
        for &i in train.iter().chain(&test) {
            assert!(!seen[i]);
            seen[i] = true;
        }
    }

    #[test]
    fn generators_are_deterministic() {
        let a = gaussian(50, 2, 7);
        let b = gaussian(50, 2, 7);
        if let (Dataset::Gaussian { x: xa, .. }, Dataset::Gaussian { x: xb, .. }) =
            (&a, &b)
        {
            assert_eq!(xa.as_slice(), xb.as_slice());
        }
    }
}
