//! CSV I/O for sample matrices and experiment result tables.

use crate::error::{Error, Result};
use crate::types::SampleMatrix;
use std::io::Write;
use std::path::Path;

/// Write a sample matrix as CSV with `d0,d1,...` headers.
pub fn write_samples_csv(path: &Path, samples: &SampleMatrix) -> Result<()> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    let header: Vec<String> =
        (0..samples.dim()).map(|j| format!("d{j}")).collect();
    writeln!(f, "{}", header.join(","))?;
    for row in samples.rows() {
        let line: Vec<String> = row.iter().map(|v| format!("{v:.9e}")).collect();
        writeln!(f, "{}", line.join(","))?;
    }
    Ok(())
}

/// Read a CSV written by [`write_samples_csv`] (header required).
pub fn read_samples_csv(path: &Path) -> Result<SampleMatrix> {
    let text = std::fs::read_to_string(path)?;
    let mut lines = text.lines();
    let header = lines
        .next()
        .ok_or_else(|| Error::Parse("empty csv".into()))?;
    let dim = header.split(',').count();
    let mut out = SampleMatrix::new(dim);
    let mut buf = vec![0.0; dim];
    for (ln, line) in lines.enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let mut count = 0;
        for (j, tok) in line.split(',').enumerate() {
            if j >= dim {
                return Err(Error::Parse(format!("line {}: too many fields", ln + 2)));
            }
            buf[j] = tok.trim().parse().map_err(|_| {
                Error::Parse(format!("line {}: bad float '{tok}'", ln + 2))
            })?;
            count += 1;
        }
        if count != dim {
            return Err(Error::Parse(format!("line {}: expected {dim} fields", ln + 2)));
        }
        out.push(&buf);
    }
    Ok(out)
}

/// Generic row-oriented results table (e.g. error-vs-time curves).
#[derive(Debug, Clone, Default)]
pub struct Table {
    pub columns: Vec<String>,
    pub rows: Vec<Vec<f64>>,
    /// Optional string tag per row (e.g. method name).
    pub tags: Vec<String>,
}

impl Table {
    pub fn new(columns: &[&str]) -> Self {
        Table {
            columns: columns.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            tags: Vec::new(),
        }
    }

    pub fn push(&mut self, tag: &str, row: Vec<f64>) {
        assert_eq!(row.len(), self.columns.len());
        self.rows.push(row);
        self.tags.push(tag.to_string());
    }

    pub fn write_csv(&self, path: &Path) -> Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        writeln!(f, "tag,{}", self.columns.join(","))?;
        for (tag, row) in self.tags.iter().zip(&self.rows) {
            let line: Vec<String> =
                row.iter().map(|v| format!("{v:.6e}")).collect();
            writeln!(f, "{tag},{}", line.join(","))?;
        }
        Ok(())
    }

    /// Render as an aligned markdown table (for EXPERIMENTS.md).
    pub fn to_markdown(&self) -> String {
        let mut s = String::new();
        s.push_str("| tag |");
        for c in &self.columns {
            s.push_str(&format!(" {c} |"));
        }
        s.push('\n');
        s.push_str("|---|");
        for _ in &self.columns {
            s.push_str("---|");
        }
        s.push('\n');
        for (tag, row) in self.tags.iter().zip(&self.rows) {
            s.push_str(&format!("| {tag} |"));
            for v in row {
                s.push_str(&format!(" {v:.4} |"));
            }
            s.push('\n');
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn samples_csv_roundtrip() {
        let dir = std::env::temp_dir().join("repro_io_test");
        let path = dir.join("s.csv");
        let mut s = SampleMatrix::new(3);
        s.push(&[1.0, -2.5, 3.25]);
        s.push(&[0.125, 7.0, -0.0625]);
        write_samples_csv(&path, &s).unwrap();
        let back = read_samples_csv(&path).unwrap();
        assert_eq!(back.len(), 2);
        for i in 0..2 {
            for j in 0..3 {
                assert!((back.row(i)[j] - s.row(i)[j]).abs() < 1e-12);
            }
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn csv_rejects_bad_rows() {
        let dir = std::env::temp_dir().join("repro_io_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.csv");
        std::fs::write(&path, "d0,d1\n1.0,2.0\n3.0\n").unwrap();
        assert!(read_samples_csv(&path).is_err());
        std::fs::write(&path, "d0\nnot_a_number\n").unwrap();
        assert!(read_samples_csv(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn table_markdown_and_csv() {
        let mut t = Table::new(&["time", "error"]);
        t.push("parametric", vec![1.0, 0.25]);
        t.push("nonparametric", vec![2.0, 0.125]);
        let md = t.to_markdown();
        assert!(md.contains("| parametric |"));
        assert!(md.contains("error"));
        let dir = std::env::temp_dir().join("repro_io_test3");
        let path = dir.join("t.csv");
        t.write_csv(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.starts_with("tag,time,error"));
        std::fs::remove_dir_all(&dir).ok();
    }
}
