//! CSV I/O for sample matrices and experiment result tables, plus the
//! shard spill/load pair process- and socket-mode workers exchange with
//! the leader — in two formats:
//!
//! * **JSON** ([`write_shard_json`]): human-readable, shortest-
//!   round-trip float rendering (PR 2's format).
//! * **Binary** ([`write_shard_bin`]): 8-byte magic, a one-byte model
//!   tag, little-endian `u64` dims header, then raw little-endian `f64`
//!   rows — no float↔decimal conversion at all, so very large N shards
//!   spill and load at memcpy speed and round-trip trivially
//!   bit-exactly (including non-finite values).
//!
//! [`read_shard`] autodetects the format from the magic, so workers
//! never need to be told which one the leader chose
//! (`shard_format` config key).
//!
//! On unix, binary (`RPSHRD1`) shards are ingested through a read-only
//! **memory mapping** instead of a heap read: the bounds-checked cursor
//! decodes straight out of the page cache, so daemon-side shard load
//! never double-buffers the dataset (mapping + decoded rows, instead of
//! read buffer + decoded rows). JSON shards, empty files, and platforms
//! without `mmap` fall back to the buffered whole-file read
//! ([`read_shard_buffered`]), which is bit-identical by construction.

use crate::data::Dataset;
use crate::error::{Error, Result};
use crate::runtime::json::{self, Json};
use crate::types::SampleMatrix;
use std::io::Write;
use std::path::Path;

/// Magic prefix of the binary shard format, version 1. Also the
/// autodetection token: JSON shards start with `{`, never `R`.
pub const SHARD_MAGIC: &[u8; 8] = b"RPSHRD1\n";

/// Write a sample matrix as CSV with `d0,d1,...` headers.
pub fn write_samples_csv(path: &Path, samples: &SampleMatrix) -> Result<()> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    let header: Vec<String> =
        (0..samples.dim()).map(|j| format!("d{j}")).collect();
    writeln!(f, "{}", header.join(","))?;
    for row in samples.rows() {
        let line: Vec<String> = row.iter().map(|v| format!("{v:.9e}")).collect();
        writeln!(f, "{}", line.join(","))?;
    }
    Ok(())
}

/// Read a CSV written by [`write_samples_csv`] (header required).
pub fn read_samples_csv(path: &Path) -> Result<SampleMatrix> {
    let text = std::fs::read_to_string(path)?;
    let mut lines = text.lines();
    let header = lines
        .next()
        .ok_or_else(|| Error::Parse("empty csv".into()))?;
    let dim = header.split(',').count();
    let mut out = SampleMatrix::new(dim);
    let mut buf = vec![0.0; dim];
    for (ln, line) in lines.enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let mut count = 0;
        for (j, tok) in line.split(',').enumerate() {
            if j >= dim {
                return Err(Error::Parse(format!("line {}: too many fields", ln + 2)));
            }
            buf[j] = tok.trim().parse().map_err(|_| {
                Error::Parse(format!("line {}: bad float '{tok}'", ln + 2))
            })?;
            count += 1;
        }
        if count != dim {
            return Err(Error::Parse(format!("line {}: expected {dim} fields", ln + 2)));
        }
        out.push(&buf);
    }
    Ok(out)
}

/// Spill a dataset (typically one machine's shard, built with
/// [`Dataset::select`]) to a single JSON file: the model kind, its
/// scalar metadata, and the flat row-major observation buffer. Floats
/// cross the file through [`Json::render`]'s shortest-round-trip
/// formatting, so [`read_shard_json`] reproduces every value
/// bit-exactly — the foundation of the process-mode byte-identity
/// guarantee.
pub fn write_shard_json(path: &Path, data: &Dataset) -> Result<()> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    std::fs::write(path, shard_to_json(data).render())?;
    Ok(())
}

/// Load a dataset spilled by [`write_shard_json`].
pub fn read_shard_json(path: &Path) -> Result<Dataset> {
    let text = std::fs::read_to_string(path)?;
    shard_from_json(&Json::parse(&text)?)
}

/// On-disk shard spill format (`shard_format` config key).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ShardFormat {
    /// Human-readable JSON with shortest-round-trip floats.
    #[default]
    Json,
    /// Magic + dims header + raw little-endian `f64` payload.
    Binary,
}

impl ShardFormat {
    pub fn parse(s: &str) -> Result<ShardFormat> {
        match s.trim() {
            "json" => Ok(ShardFormat::Json),
            "binary" | "bin" => Ok(ShardFormat::Binary),
            other => Err(Error::Config(format!(
                "unknown shard format '{other}' (expected json | binary)"
            ))),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            ShardFormat::Json => "json",
            ShardFormat::Binary => "binary",
        }
    }

    /// File extension used for spills in this format.
    pub fn extension(&self) -> &'static str {
        match self {
            ShardFormat::Json => "json",
            ShardFormat::Binary => "bin",
        }
    }
}

/// Spill a shard in the requested format.
pub fn write_shard(
    path: &Path,
    data: &Dataset,
    format: ShardFormat,
) -> Result<()> {
    match format {
        ShardFormat::Json => write_shard_json(path, data),
        ShardFormat::Binary => write_shard_bin(path, data),
    }
}

/// Encode a shard in the requested format as an in-memory byte buffer
/// — exactly the bytes [`write_shard`] would spill to disk (pinned by
/// `shard_bytes_match_file_spill_both_formats`). The inline-shard path
/// ships the spill *file's* bytes (the leader has already spilled by
/// dispatch time, and the file doubles as the inspectable copy), so
/// this encoder is the contract's executable spec — and the encode
/// half for callers that want to skip the disk round-trip.
pub fn shard_to_bytes(data: &Dataset, format: ShardFormat) -> Vec<u8> {
    match format {
        ShardFormat::Json => shard_to_json(data).render().into_bytes(),
        ShardFormat::Binary => shard_to_bin(data),
    }
}

/// Decode a shard from in-memory bytes, format autodetected from the
/// magic — the single decode path behind [`read_shard`] and the socket
/// daemons' inline-shard frames, so file and wire delivery are
/// bit-identical by construction.
pub fn shard_from_bytes(bytes: &[u8]) -> Result<Dataset> {
    if bytes.starts_with(SHARD_MAGIC) {
        shard_from_bin(bytes)
    } else {
        let text = std::str::from_utf8(bytes).map_err(|_| {
            Error::Parse(
                "shard is neither binary (bad magic) nor JSON (not utf-8)"
                    .into(),
            )
        })?;
        shard_from_json(&Json::parse(text)?)
    }
}

/// Load a shard spilled in either format, autodetected from the magic.
///
/// Binary shards decode straight out of a read-only memory mapping
/// where the platform supports it (the spill is written once by the
/// leader before dispatch, so the mapping is stable for its lifetime);
/// everything else takes the buffered path. Both paths are bit-exact —
/// pinned by `mmap_and_buffered_ingest_are_bit_identical`.
pub fn read_shard(path: &Path) -> Result<Dataset> {
    #[cfg(unix)]
    {
        let file = std::fs::File::open(path)?;
        if let Some(map) = mmap::Map::of(&file) {
            let bytes = map.bytes();
            if bytes.starts_with(SHARD_MAGIC) {
                return shard_from_bin(bytes)
                    .map_err(|e| decorate_shard_err(path, e));
            }
            // JSON shard: parsing wants a &str anyway, so drop the
            // mapping and take the buffered path below.
        }
    }
    read_shard_buffered(path)
}

/// [`read_shard`] without the mmap fast path: one whole-file read into
/// a heap buffer, then the same autodetecting decoder. Public so tests
/// (and callers on exotic filesystems where mappings misbehave) can pin
/// the two ingest paths against each other.
pub fn read_shard_buffered(path: &Path) -> Result<Dataset> {
    let bytes = std::fs::read(path)?;
    shard_from_bytes(&bytes).map_err(|e| decorate_shard_err(path, e))
}

/// Prefix parse failures with the shard path (I/O errors already carry
/// it via the OS message).
fn decorate_shard_err(path: &Path, e: Error) -> Error {
    match e {
        Error::Parse(m) => {
            Error::Parse(format!("shard {}: {m}", path.display()))
        }
        other => other,
    }
}

/// Minimal read-only `mmap` binding — no libc crate (the repo is
/// dependency-free by design), just the two syscall wrappers every unix
/// libc exports with this exact C signature.
#[cfg(unix)]
mod mmap {
    use std::ffi::c_void;
    use std::fs::File;
    use std::os::unix::io::AsRawFd;

    // POSIX values, identical on linux and the BSDs (incl. macOS).
    const PROT_READ: i32 = 1;
    const MAP_PRIVATE: i32 = 2;

    extern "C" {
        fn mmap(
            addr: *mut c_void,
            len: usize,
            prot: i32,
            flags: i32,
            fd: i32,
            offset: i64,
        ) -> *mut c_void;
        fn munmap(addr: *mut c_void, len: usize) -> i32;
    }

    /// A read-only private mapping of one whole file, unmapped on drop.
    pub struct Map {
        ptr: *mut c_void,
        len: usize,
    }

    impl Map {
        /// Map the file, or `None` when mapping is impossible (empty
        /// file — `mmap` rejects zero lengths — an oversized file on a
        /// 32-bit target, or any syscall failure). Callers must treat
        /// `None` as "use the buffered path", never as an error.
        pub fn of(file: &File) -> Option<Map> {
            let len = usize::try_from(file.metadata().ok()?.len()).ok()?;
            if len == 0 {
                return None;
            }
            let ptr = unsafe {
                mmap(
                    std::ptr::null_mut(),
                    len,
                    PROT_READ,
                    MAP_PRIVATE,
                    file.as_raw_fd(),
                    0,
                )
            };
            // MAP_FAILED is (void*)-1; a null return would be a libc
            // bug but refuse it too rather than fabricate a mapping.
            if ptr.is_null() || ptr as isize == -1 {
                return None;
            }
            Some(Map { ptr, len })
        }

        pub fn bytes(&self) -> &[u8] {
            // Safe: the mapping is PROT_READ over `len` bytes and
            // lives until drop; spill files are written once before
            // any reader opens them, so the pages are stable.
            unsafe {
                std::slice::from_raw_parts(self.ptr as *const u8, self.len)
            }
        }
    }

    impl Drop for Map {
        fn drop(&mut self) {
            unsafe {
                munmap(self.ptr, self.len);
            }
        }
    }
}

/// Spill a dataset in the binary shard format (see the module docs for
/// the layout). Bit-exact by construction: every `f64` is written as
/// its little-endian bytes.
pub fn write_shard_bin(path: &Path, data: &Dataset) -> Result<()> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    std::fs::write(path, shard_to_bin(data))?;
    Ok(())
}

/// Load a dataset spilled by [`write_shard_bin`].
pub fn read_shard_bin(path: &Path) -> Result<Dataset> {
    shard_from_bin(&std::fs::read(path)?)
}

/// Binary model tags (byte 8 of the file). Append-only: new models get
/// new tags, existing tags never change meaning.
const TAG_GAUSSIAN: u8 = 0;
const TAG_LOGISTIC: u8 = 1;
const TAG_GMM: u8 = 2;
const TAG_POISSON_GAMMA: u8 = 3;
const TAG_LINREG: u8 = 4;

/// Tag of a spilled draw-plane row-chunk segment
/// ([`crate::data::store::DrawStore`]'s on-disk unit). Deliberately at
/// the far end of the tag space so a draw segment can never be
/// mistaken for a model shard as new models are appended.
const TAG_DRAW_SEGMENT: u8 = 255;

/// Spill one draw-store row chunk: [`SHARD_MAGIC`] + the draw-segment
/// tag + `dim`/`rows` little-endian `u64` header + the flat row-major
/// `f64` payload as raw little-endian bytes. Same fidelity rules as
/// binary shards: every value crosses the file through
/// `f64::to_le_bytes`, so NaN bit-payloads, ±Inf, and -0.0 round-trip
/// verbatim.
pub fn write_draw_segment(
    path: &Path,
    dim: usize,
    flat: &[f64],
) -> Result<()> {
    debug_assert!(dim > 0 && flat.len() % dim == 0, "whole rows only");
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let mut buf =
        Vec::with_capacity(SHARD_MAGIC.len() + 1 + 16 + 8 * flat.len());
    buf.extend_from_slice(SHARD_MAGIC);
    buf.push(TAG_DRAW_SEGMENT);
    put_u64(&mut buf, dim as u64);
    put_u64(&mut buf, (flat.len() / dim) as u64);
    for &v in flat {
        put_f64(&mut buf, v);
    }
    std::fs::write(path, buf)?;
    Ok(())
}

/// Read back a segment spilled by [`write_draw_segment`] into `out`
/// (cleared first), validating the header against the shape the store
/// recorded at spill time. Decodes straight out of a read-only memory
/// mapping where the platform supports it (segments are written once
/// before any reader opens them), with a bit-identical buffered
/// fallback — the same two-path contract as [`read_shard`].
pub fn read_draw_segment_into(
    path: &Path,
    dim: usize,
    rows: usize,
    out: &mut Vec<f64>,
) -> Result<()> {
    #[cfg(unix)]
    {
        let file = std::fs::File::open(path)?;
        if let Some(map) = mmap::Map::of(&file) {
            return draw_segment_from_bin(map.bytes(), dim, rows, out)
                .map_err(|e| decorate_shard_err(path, e));
        }
    }
    let bytes = std::fs::read(path)?;
    draw_segment_from_bin(&bytes, dim, rows, out)
        .map_err(|e| decorate_shard_err(path, e))
}

fn draw_segment_from_bin(
    bytes: &[u8],
    dim: usize,
    rows: usize,
    out: &mut Vec<f64>,
) -> Result<()> {
    let mut cur = Cur { buf: bytes, pos: 0 };
    if cur.take(SHARD_MAGIC.len())? != SHARD_MAGIC {
        return Err(Error::Parse(
            "draw segment: bad magic (not a spill segment)".into(),
        ));
    }
    let tag = cur.u8()?;
    if tag != TAG_DRAW_SEGMENT {
        return Err(Error::Parse(format!(
            "draw segment: unexpected tag {tag}"
        )));
    }
    let file_dim = cur.u64()?;
    let file_rows = cur.u64()?;
    if file_dim != dim || file_rows != rows {
        return Err(Error::Parse(format!(
            "draw segment: header says {file_rows} rows × dim {file_dim}, \
             the store expects {rows} × {dim}"
        )));
    }
    let n = dim.checked_mul(rows).ok_or_else(|| {
        Error::Parse("draw segment: size overflow".into())
    })?;
    let payload = cur.take(n.checked_mul(8).ok_or_else(|| {
        Error::Parse("draw segment: size overflow".into())
    })?)?;
    out.clear();
    out.reserve(n);
    out.extend(
        payload
            .chunks_exact(8)
            .map(|c| f64::from_le_bytes(c.try_into().unwrap())),
    );
    cur.done()
}

fn put_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_f64(buf: &mut Vec<u8>, v: f64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

/// Length-prefixed f64 vector.
fn put_f64s(buf: &mut Vec<u8>, vs: &[f64]) {
    put_u64(buf, vs.len() as u64);
    for &v in vs {
        put_f64(buf, v);
    }
}

/// Matrix header is `dim, rows` (so a reader can size-check the payload
/// before allocating), then the flat row-major buffer.
fn put_matrix(buf: &mut Vec<u8>, x: &SampleMatrix) {
    put_u64(buf, x.dim() as u64);
    put_u64(buf, x.len() as u64);
    for &v in x.as_slice() {
        put_f64(buf, v);
    }
}

fn shard_to_bin(data: &Dataset) -> Vec<u8> {
    let mut buf = Vec::new();
    buf.extend_from_slice(SHARD_MAGIC);
    match data {
        Dataset::Gaussian { x, lik_prec, prior_prec } => {
            buf.push(TAG_GAUSSIAN);
            put_matrix(&mut buf, x);
            put_f64(&mut buf, *lik_prec);
            put_f64(&mut buf, *prior_prec);
        }
        Dataset::Logistic { x, y, prior_prec } => {
            buf.push(TAG_LOGISTIC);
            put_matrix(&mut buf, x);
            put_f64s(&mut buf, y);
            put_f64(&mut buf, *prior_prec);
        }
        Dataset::Gmm { x, logw, inv_var, prior_prec } => {
            buf.push(TAG_GMM);
            put_matrix(&mut buf, x);
            put_f64s(&mut buf, logw);
            put_f64(&mut buf, *inv_var);
            put_f64(&mut buf, *prior_prec);
        }
        Dataset::PoissonGamma { xs, ts, lam, alpha, beta_p } => {
            buf.push(TAG_POISSON_GAMMA);
            put_f64s(&mut buf, xs);
            put_f64s(&mut buf, ts);
            put_f64(&mut buf, *lam);
            put_f64(&mut buf, *alpha);
            put_f64(&mut buf, *beta_p);
        }
        Dataset::LinReg { x, y, lik_prec, prior_prec } => {
            buf.push(TAG_LINREG);
            put_matrix(&mut buf, x);
            put_f64s(&mut buf, y);
            put_f64(&mut buf, *lik_prec);
            put_f64(&mut buf, *prior_prec);
        }
    }
    buf
}

/// Bounds-checked cursor over a binary shard. Every length is verified
/// against the remaining bytes *before* any allocation, so a corrupt
/// header cannot trigger a huge `Vec` reservation.
struct Cur<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cur<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        let end = self.pos.checked_add(n).ok_or_else(|| {
            Error::Parse("binary shard: length overflow".into())
        })?;
        if end > self.buf.len() {
            return Err(Error::Parse(format!(
                "binary shard truncated: wanted {n} bytes at offset {}, \
                 have {}",
                self.pos,
                self.buf.len() - self.pos
            )));
        }
        let out = &self.buf[self.pos..end];
        self.pos = end;
        Ok(out)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u64(&mut self) -> Result<usize> {
        let b: [u8; 8] = self.take(8)?.try_into().unwrap();
        let v = u64::from_le_bytes(b);
        usize::try_from(v).map_err(|_| {
            Error::Parse(format!("binary shard: count {v} exceeds usize"))
        })
    }

    fn f64(&mut self) -> Result<f64> {
        let b: [u8; 8] = self.take(8)?.try_into().unwrap();
        Ok(f64::from_le_bytes(b))
    }

    fn f64_vec(&mut self, n: usize) -> Result<Vec<f64>> {
        let bytes = n.checked_mul(8).ok_or_else(|| {
            Error::Parse("binary shard: length overflow".into())
        })?;
        let raw = self.take(bytes)?;
        Ok(raw
            .chunks_exact(8)
            .map(|c| f64::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    fn f64s(&mut self) -> Result<Vec<f64>> {
        let n = self.u64()?;
        self.f64_vec(n)
    }

    fn matrix(&mut self) -> Result<SampleMatrix> {
        let dim = self.u64()?;
        let rows = self.u64()?;
        let n = dim.checked_mul(rows).ok_or_else(|| {
            Error::Parse("binary shard: matrix size overflow".into())
        })?;
        if dim == 0 {
            return Err(Error::Parse(
                "binary shard: zero-dim matrix".into(),
            ));
        }
        SampleMatrix::from_rows(self.f64_vec(n)?, dim)
    }

    fn done(&self) -> Result<()> {
        if self.pos != self.buf.len() {
            return Err(Error::Parse(format!(
                "binary shard: {} trailing bytes after payload",
                self.buf.len() - self.pos
            )));
        }
        Ok(())
    }
}

fn shard_from_bin(bytes: &[u8]) -> Result<Dataset> {
    let mut cur = Cur { buf: bytes, pos: 0 };
    if cur.take(SHARD_MAGIC.len())? != SHARD_MAGIC {
        return Err(Error::Parse(
            "binary shard: bad magic (not a shard file, or an \
             unsupported version)"
                .into(),
        ));
    }
    let tag = cur.u8()?;
    let data = match tag {
        TAG_GAUSSIAN => Dataset::Gaussian {
            x: cur.matrix()?,
            lik_prec: cur.f64()?,
            prior_prec: cur.f64()?,
        },
        TAG_LOGISTIC => {
            let x = cur.matrix()?;
            let y = cur.f64s()?;
            check_len("y", y.len(), x.len())?;
            Dataset::Logistic { x, y, prior_prec: cur.f64()? }
        }
        TAG_GMM => Dataset::Gmm {
            x: cur.matrix()?,
            logw: cur.f64s()?,
            inv_var: cur.f64()?,
            prior_prec: cur.f64()?,
        },
        TAG_POISSON_GAMMA => {
            let xs = cur.f64s()?;
            let ts = cur.f64s()?;
            check_len("ts", ts.len(), xs.len())?;
            Dataset::PoissonGamma {
                xs,
                ts,
                lam: cur.f64()?,
                alpha: cur.f64()?,
                beta_p: cur.f64()?,
            }
        }
        TAG_LINREG => {
            let x = cur.matrix()?;
            let y = cur.f64s()?;
            check_len("y", y.len(), x.len())?;
            Dataset::LinReg {
                x,
                y,
                lik_prec: cur.f64()?,
                prior_prec: cur.f64()?,
            }
        }
        other => {
            return Err(Error::Parse(format!(
                "binary shard: unknown model tag {other}"
            )))
        }
    };
    cur.done()?;
    Ok(data)
}

fn matrix_to_json(x: &SampleMatrix) -> Json {
    json::obj(vec![
        ("dim", Json::Num(x.dim() as f64)),
        ("data", json::num_arr(x.as_slice())),
    ])
}

fn matrix_from_json(j: &Json) -> Result<SampleMatrix> {
    SampleMatrix::from_rows(
        json::f64_vec(j.get("data")?)?,
        j.get("dim")?.as_usize()?,
    )
}

fn shard_to_json(data: &Dataset) -> Json {
    let kind = ("kind", Json::Str(data.model_name().into()));
    match data {
        Dataset::Gaussian { x, lik_prec, prior_prec } => json::obj(vec![
            kind,
            ("x", matrix_to_json(x)),
            ("lik_prec", Json::Num(*lik_prec)),
            ("prior_prec", Json::Num(*prior_prec)),
        ]),
        Dataset::Logistic { x, y, prior_prec } => json::obj(vec![
            kind,
            ("x", matrix_to_json(x)),
            ("y", json::num_arr(y)),
            ("prior_prec", Json::Num(*prior_prec)),
        ]),
        Dataset::Gmm { x, logw, inv_var, prior_prec } => json::obj(vec![
            kind,
            ("x", matrix_to_json(x)),
            ("logw", json::num_arr(logw)),
            ("inv_var", Json::Num(*inv_var)),
            ("prior_prec", Json::Num(*prior_prec)),
        ]),
        Dataset::PoissonGamma { xs, ts, lam, alpha, beta_p } => {
            json::obj(vec![
                kind,
                ("xs", json::num_arr(xs)),
                ("ts", json::num_arr(ts)),
                ("lam", Json::Num(*lam)),
                ("alpha", Json::Num(*alpha)),
                ("beta_p", Json::Num(*beta_p)),
            ])
        }
        Dataset::LinReg { x, y, lik_prec, prior_prec } => json::obj(vec![
            kind,
            ("x", matrix_to_json(x)),
            ("y", json::num_arr(y)),
            ("lik_prec", Json::Num(*lik_prec)),
            ("prior_prec", Json::Num(*prior_prec)),
        ]),
    }
}

fn check_len(name: &str, got: usize, want: usize) -> Result<()> {
    if got != want {
        return Err(Error::Parse(format!(
            "shard field '{name}' has {got} entries, expected {want}"
        )));
    }
    Ok(())
}

fn shard_from_json(j: &Json) -> Result<Dataset> {
    match j.get("kind")?.as_str()? {
        "gaussian" => Ok(Dataset::Gaussian {
            x: matrix_from_json(j.get("x")?)?,
            lik_prec: j.get("lik_prec")?.as_f64()?,
            prior_prec: j.get("prior_prec")?.as_f64()?,
        }),
        "logistic" => {
            let x = matrix_from_json(j.get("x")?)?;
            let y = json::f64_vec(j.get("y")?)?;
            check_len("y", y.len(), x.len())?;
            Ok(Dataset::Logistic {
                x,
                y,
                prior_prec: j.get("prior_prec")?.as_f64()?,
            })
        }
        "gmm" => Ok(Dataset::Gmm {
            x: matrix_from_json(j.get("x")?)?,
            logw: json::f64_vec(j.get("logw")?)?,
            inv_var: j.get("inv_var")?.as_f64()?,
            prior_prec: j.get("prior_prec")?.as_f64()?,
        }),
        "poisson_gamma" => {
            let xs = json::f64_vec(j.get("xs")?)?;
            let ts = json::f64_vec(j.get("ts")?)?;
            check_len("ts", ts.len(), xs.len())?;
            Ok(Dataset::PoissonGamma {
                xs,
                ts,
                lam: j.get("lam")?.as_f64()?,
                alpha: j.get("alpha")?.as_f64()?,
                beta_p: j.get("beta_p")?.as_f64()?,
            })
        }
        "linreg" => {
            let x = matrix_from_json(j.get("x")?)?;
            let y = json::f64_vec(j.get("y")?)?;
            check_len("y", y.len(), x.len())?;
            Ok(Dataset::LinReg {
                x,
                y,
                lik_prec: j.get("lik_prec")?.as_f64()?,
                prior_prec: j.get("prior_prec")?.as_f64()?,
            })
        }
        other => Err(Error::Parse(format!("unknown dataset kind '{other}'"))),
    }
}

/// Generic row-oriented results table (e.g. error-vs-time curves).
#[derive(Debug, Clone, Default)]
pub struct Table {
    pub columns: Vec<String>,
    pub rows: Vec<Vec<f64>>,
    /// Optional string tag per row (e.g. method name).
    pub tags: Vec<String>,
}

impl Table {
    pub fn new(columns: &[&str]) -> Self {
        Table {
            columns: columns.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            tags: Vec::new(),
        }
    }

    pub fn push(&mut self, tag: &str, row: Vec<f64>) {
        assert_eq!(row.len(), self.columns.len());
        self.rows.push(row);
        self.tags.push(tag.to_string());
    }

    pub fn write_csv(&self, path: &Path) -> Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        writeln!(f, "tag,{}", self.columns.join(","))?;
        for (tag, row) in self.tags.iter().zip(&self.rows) {
            let line: Vec<String> =
                row.iter().map(|v| format!("{v:.6e}")).collect();
            writeln!(f, "{tag},{}", line.join(","))?;
        }
        Ok(())
    }

    /// Render as an aligned markdown table (for EXPERIMENTS.md).
    pub fn to_markdown(&self) -> String {
        let mut s = String::new();
        s.push_str("| tag |");
        for c in &self.columns {
            s.push_str(&format!(" {c} |"));
        }
        s.push('\n');
        s.push_str("|---|");
        for _ in &self.columns {
            s.push_str("---|");
        }
        s.push('\n');
        for (tag, row) in self.tags.iter().zip(&self.rows) {
            s.push_str(&format!("| {tag} |"));
            for v in row {
                s.push_str(&format!(" {v:.4} |"));
            }
            s.push('\n');
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn samples_csv_roundtrip() {
        let dir = std::env::temp_dir().join("repro_io_test");
        let path = dir.join("s.csv");
        let mut s = SampleMatrix::new(3);
        s.push(&[1.0, -2.5, 3.25]);
        s.push(&[0.125, 7.0, -0.0625]);
        write_samples_csv(&path, &s).unwrap();
        let back = read_samples_csv(&path).unwrap();
        assert_eq!(back.len(), 2);
        for i in 0..2 {
            for j in 0..3 {
                assert!((back.row(i)[j] - s.row(i)[j]).abs() < 1e-12);
            }
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn csv_rejects_bad_rows() {
        let dir = std::env::temp_dir().join("repro_io_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.csv");
        std::fs::write(&path, "d0,d1\n1.0,2.0\n3.0\n").unwrap();
        assert!(read_samples_csv(&path).is_err());
        std::fs::write(&path, "d0\nnot_a_number\n").unwrap();
        assert!(read_samples_csv(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn shard_json_roundtrips_every_model_bit_exactly() {
        use crate::data::synth;
        let dir = std::env::temp_dir().join("repro_shard_io_test");
        let idx: Vec<usize> = (5..37).collect();
        let datasets = [
            synth::gaussian(60, 2, 1),
            synth::logistic(60, 3, 2),
            synth::gmm(60, 2, 2, 4.0, 3),
            synth::poisson_gamma(60, 4),
            synth::linreg(60, 2, 5),
        ];
        for (i, ds) in datasets.iter().enumerate() {
            let shard = ds.select(&idx).unwrap();
            let path = dir.join(format!("shard_{i}.json"));
            write_shard_json(&path, &shard).unwrap();
            let back = read_shard_json(&path).unwrap();
            // Debug formatting prints floats with shortest-round-trip
            // digits, so equal strings ⇔ bit-identical contents.
            assert_eq!(
                format!("{shard:?}"),
                format!("{back:?}"),
                "{} shard diverged through JSON",
                ds.model_name()
            );
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn shard_json_rejects_malformed() {
        let dir = std::env::temp_dir().join("repro_shard_io_bad");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.json");
        std::fs::write(&path, "{\"kind\":\"warp\"}").unwrap();
        assert!(read_shard_json(&path).is_err());
        // Mismatched label length must be caught at load, not at panic.
        std::fs::write(
            &path,
            "{\"kind\":\"logistic\",\"x\":{\"dim\":1,\"data\":[1,2]},\
             \"y\":[1],\"prior_prec\":1}",
        )
        .unwrap();
        assert!(read_shard_json(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    /// Acceptance gate for the binary spill format: `write_shard_bin →
    /// read_shard` reproduces every model's shard bit-exactly, and the
    /// same loader autodetects JSON spills of the same shard.
    #[test]
    fn shard_bin_roundtrips_every_model_and_autodetects() {
        use crate::data::synth;
        let dir = std::env::temp_dir().join("repro_shard_bin_test");
        let idx: Vec<usize> = (5..37).collect();
        let datasets = [
            synth::gaussian(60, 2, 1),
            synth::logistic(60, 3, 2),
            synth::gmm(60, 2, 2, 4.0, 3),
            synth::poisson_gamma(60, 4),
            synth::linreg(60, 2, 5),
        ];
        for (i, ds) in datasets.iter().enumerate() {
            let shard = ds.select(&idx).unwrap();
            let bin_path = dir.join(format!("shard_{i}.bin"));
            write_shard(&bin_path, &shard, ShardFormat::Binary).unwrap();
            let back = read_shard(&bin_path).unwrap();
            // Debug formatting prints floats with shortest-round-trip
            // digits, so equal strings ⇔ bit-identical contents.
            assert_eq!(
                format!("{shard:?}"),
                format!("{back:?}"),
                "{} shard diverged through the binary format",
                ds.model_name()
            );
            // The JSON spill of the same shard loads through the same
            // autodetecting entry point.
            let json_path = dir.join(format!("shard_{i}.json"));
            write_shard(&json_path, &shard, ShardFormat::Json).unwrap();
            let back = read_shard(&json_path).unwrap();
            assert_eq!(format!("{shard:?}"), format!("{back:?}"));
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    /// Non-finite values have no JSON number form but are ordinary bit
    /// patterns in the binary format.
    #[test]
    fn shard_bin_preserves_nonfinite_values() {
        let dir = std::env::temp_dir().join("repro_shard_bin_nonfinite");
        let mut x = SampleMatrix::new(2);
        x.push(&[f64::INFINITY, -0.0]);
        x.push(&[f64::NEG_INFINITY, f64::NAN]);
        let shard = Dataset::Gaussian { x, lik_prec: 1.0, prior_prec: 0.5 };
        let path = dir.join("weird.bin");
        write_shard_bin(&path, &shard).unwrap();
        let back = read_shard_bin(&path).unwrap();
        let Dataset::Gaussian { x, .. } = &back else {
            panic!("wrong model")
        };
        assert_eq!(x.row(0)[0], f64::INFINITY);
        assert_eq!(x.row(0)[1].to_bits(), (-0.0f64).to_bits());
        assert_eq!(x.row(1)[0], f64::NEG_INFINITY);
        assert!(x.row(1)[1].is_nan());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn shard_bin_rejects_corruption_without_overallocating() {
        let dir = std::env::temp_dir().join("repro_shard_bin_bad");
        std::fs::create_dir_all(&dir).unwrap();
        let mut x = SampleMatrix::new(1);
        x.push(&[1.0]);
        let shard = Dataset::Gaussian { x, lik_prec: 1.0, prior_prec: 1.0 };
        let path = dir.join("s.bin");
        write_shard_bin(&path, &shard).unwrap();
        let good = std::fs::read(&path).unwrap();

        // Truncated mid-payload.
        assert!(shard_from_bin(&good[..good.len() - 4]).is_err());
        // Unknown model tag.
        let mut bad = good.clone();
        bad[SHARD_MAGIC.len()] = 99;
        assert!(shard_from_bin(&bad).is_err());
        // Trailing bytes.
        let mut bad = good.clone();
        bad.push(0);
        assert!(shard_from_bin(&bad).is_err());
        // A row count claiming far more data than the file holds must
        // fail the bounds check before allocating.
        let mut bad = good.clone();
        let rows_off = SHARD_MAGIC.len() + 1 + 8; // magic + tag + dim
        bad[rows_off..rows_off + 8]
            .copy_from_slice(&u64::MAX.to_le_bytes());
        let err = shard_from_bin(&bad).unwrap_err();
        assert!(err.to_string().contains("overflow"), "{err}");
        // Bad magic routes JSON-ish text to the JSON parser, which
        // rejects it too.
        std::fs::write(&path, b"not a shard at all").unwrap();
        assert!(read_shard(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    /// The in-memory encode/decode pair is bit-identical to the file
    /// spill path in both formats — the inline-shard wire contract.
    #[test]
    fn shard_bytes_match_file_spill_both_formats() {
        use crate::data::synth;
        let dir = std::env::temp_dir().join("repro_shard_bytes_test");
        let ds = synth::logistic(50, 3, 8);
        let idx: Vec<usize> = (0..50).collect();
        let shard = ds.select(&idx).unwrap();
        for format in [ShardFormat::Json, ShardFormat::Binary] {
            let path = dir.join(format!("s.{}", format.extension()));
            write_shard(&path, &shard, format).unwrap();
            let file_bytes = std::fs::read(&path).unwrap();
            let mem_bytes = shard_to_bytes(&shard, format);
            assert_eq!(
                file_bytes, mem_bytes,
                "{} in-memory encoding diverged from the file spill",
                format.name()
            );
            let back = shard_from_bytes(&mem_bytes).unwrap();
            assert_eq!(format!("{shard:?}"), format!("{back:?}"));
        }
        assert!(shard_from_bytes(&[0xFF, 0xFE, 0x00]).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    /// Tentpole gate for the mmap rung: the memory-mapped ingest path
    /// ([`read_shard`] on a binary shard) and the buffered path must be
    /// bit-identical for every model — and the JSON fallback must keep
    /// working through the same entry point.
    #[test]
    fn mmap_and_buffered_ingest_are_bit_identical() {
        use crate::data::synth;
        let dir = std::env::temp_dir().join("repro_shard_mmap_test");
        let idx: Vec<usize> = (3..41).collect();
        let datasets = [
            synth::gaussian(60, 2, 1),
            synth::logistic(60, 3, 2),
            synth::gmm(60, 2, 2, 4.0, 3),
            synth::poisson_gamma(60, 4),
            synth::linreg(60, 2, 5),
        ];
        for (i, ds) in datasets.iter().enumerate() {
            let shard = ds.select(&idx).unwrap();
            for format in [ShardFormat::Json, ShardFormat::Binary] {
                let path =
                    dir.join(format!("shard_{i}.{}", format.extension()));
                write_shard(&path, &shard, format).unwrap();
                let mapped = read_shard(&path).unwrap();
                let buffered = read_shard_buffered(&path).unwrap();
                // Debug formatting prints shortest-round-trip floats,
                // so equal strings ⇔ bit-identical contents.
                assert_eq!(
                    format!("{mapped:?}"),
                    format!("{buffered:?}"),
                    "{} {} shard diverged between mmap and buffered ingest",
                    ds.model_name(),
                    format.name()
                );
                assert_eq!(format!("{mapped:?}"), format!("{shard:?}"));
            }
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    /// Degenerate inputs must take the fallback, not crash the mapper:
    /// an empty file (unmappable) and a corrupt binary shard (mapped,
    /// then rejected by the bounds-checked cursor with the path in the
    /// message).
    #[test]
    fn mmap_path_handles_empty_and_corrupt_files() {
        let dir = std::env::temp_dir().join("repro_shard_mmap_edge");
        std::fs::create_dir_all(&dir).unwrap();
        let empty = dir.join("empty.bin");
        std::fs::write(&empty, b"").unwrap();
        assert!(read_shard(&empty).is_err());

        let corrupt = dir.join("corrupt.bin");
        let mut bytes = SHARD_MAGIC.to_vec();
        bytes.push(99); // unknown model tag
        std::fs::write(&corrupt, &bytes).unwrap();
        let err = read_shard(&corrupt).unwrap_err();
        assert!(
            err.to_string().contains("corrupt.bin"),
            "parse errors must name the shard file: {err}"
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn shard_format_parsing() {
        assert_eq!(ShardFormat::parse("json").unwrap(), ShardFormat::Json);
        assert_eq!(
            ShardFormat::parse("binary").unwrap(),
            ShardFormat::Binary
        );
        assert_eq!(ShardFormat::parse("bin").unwrap(), ShardFormat::Binary);
        assert!(ShardFormat::parse("yaml").is_err());
        assert_eq!(ShardFormat::Binary.extension(), "bin");
        assert_eq!(ShardFormat::default(), ShardFormat::Json);
    }

    /// Draw segments (the `DrawStore` spill unit) round-trip bit-exactly
    /// through both ingest paths, including non-finite payloads, and a
    /// shape mismatch against the store's record is a structured error.
    #[test]
    fn draw_segment_roundtrips_bit_exactly() {
        let dir = std::env::temp_dir().join("repro_draw_segment_test");
        let path = dir.join("seg_0.bin");
        let nan_payload = f64::from_bits(0x7ff8_dead_beef_1234);
        let flat = [
            1.5,
            -0.0,
            f64::INFINITY,
            f64::NEG_INFINITY,
            nan_payload,
            3.25,
        ];
        write_draw_segment(&path, 2, &flat).unwrap();
        let mut out = Vec::new();
        read_draw_segment_into(&path, 2, 3, &mut out).unwrap();
        assert_eq!(out.len(), flat.len());
        for (a, b) in flat.iter().zip(&out) {
            assert_eq!(
                a.to_bits(),
                b.to_bits(),
                "draw segment payload diverged"
            );
        }
        // Wrong expected shape: structured error naming both shapes.
        let err =
            read_draw_segment_into(&path, 2, 4, &mut out).unwrap_err();
        assert!(err.to_string().contains("expects 4"), "{err}");
        let err =
            read_draw_segment_into(&path, 3, 3, &mut out).unwrap_err();
        assert!(err.to_string().contains("dim 2"), "{err}");
        // A truncated segment fails the bounds check, never panics.
        let good = std::fs::read(&path).unwrap();
        std::fs::write(&path, &good[..good.len() - 5]).unwrap();
        assert!(read_draw_segment_into(&path, 2, 3, &mut out).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn table_markdown_and_csv() {
        let mut t = Table::new(&["time", "error"]);
        t.push("parametric", vec![1.0, 0.25]);
        t.push("nonparametric", vec![2.0, 0.125]);
        let md = t.to_markdown();
        assert!(md.contains("| parametric |"));
        assert!(md.contains("error"));
        let dir = std::env::temp_dir().join("repro_io_test3");
        let path = dir.join("t.csv");
        t.write_csv(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.starts_with("tag,time,error"));
        std::fs::remove_dir_all(&dir).ok();
    }
}
