//! CSV I/O for sample matrices and experiment result tables, plus the
//! JSON shard spill/load pair process-mode workers exchange with the
//! leader.

use crate::data::Dataset;
use crate::error::{Error, Result};
use crate::runtime::json::{self, Json};
use crate::types::SampleMatrix;
use std::io::Write;
use std::path::Path;

/// Write a sample matrix as CSV with `d0,d1,...` headers.
pub fn write_samples_csv(path: &Path, samples: &SampleMatrix) -> Result<()> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    let header: Vec<String> =
        (0..samples.dim()).map(|j| format!("d{j}")).collect();
    writeln!(f, "{}", header.join(","))?;
    for row in samples.rows() {
        let line: Vec<String> = row.iter().map(|v| format!("{v:.9e}")).collect();
        writeln!(f, "{}", line.join(","))?;
    }
    Ok(())
}

/// Read a CSV written by [`write_samples_csv`] (header required).
pub fn read_samples_csv(path: &Path) -> Result<SampleMatrix> {
    let text = std::fs::read_to_string(path)?;
    let mut lines = text.lines();
    let header = lines
        .next()
        .ok_or_else(|| Error::Parse("empty csv".into()))?;
    let dim = header.split(',').count();
    let mut out = SampleMatrix::new(dim);
    let mut buf = vec![0.0; dim];
    for (ln, line) in lines.enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let mut count = 0;
        for (j, tok) in line.split(',').enumerate() {
            if j >= dim {
                return Err(Error::Parse(format!("line {}: too many fields", ln + 2)));
            }
            buf[j] = tok.trim().parse().map_err(|_| {
                Error::Parse(format!("line {}: bad float '{tok}'", ln + 2))
            })?;
            count += 1;
        }
        if count != dim {
            return Err(Error::Parse(format!("line {}: expected {dim} fields", ln + 2)));
        }
        out.push(&buf);
    }
    Ok(out)
}

/// Spill a dataset (typically one machine's shard, built with
/// [`Dataset::select`]) to a single JSON file: the model kind, its
/// scalar metadata, and the flat row-major observation buffer. Floats
/// cross the file through [`Json::render`]'s shortest-round-trip
/// formatting, so [`read_shard_json`] reproduces every value
/// bit-exactly — the foundation of the process-mode byte-identity
/// guarantee.
pub fn write_shard_json(path: &Path, data: &Dataset) -> Result<()> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    std::fs::write(path, shard_to_json(data).render())?;
    Ok(())
}

/// Load a dataset spilled by [`write_shard_json`].
pub fn read_shard_json(path: &Path) -> Result<Dataset> {
    let text = std::fs::read_to_string(path)?;
    shard_from_json(&Json::parse(&text)?)
}

fn matrix_to_json(x: &SampleMatrix) -> Json {
    json::obj(vec![
        ("dim", Json::Num(x.dim() as f64)),
        ("data", json::num_arr(x.as_slice())),
    ])
}

fn matrix_from_json(j: &Json) -> Result<SampleMatrix> {
    SampleMatrix::from_rows(
        json::f64_vec(j.get("data")?)?,
        j.get("dim")?.as_usize()?,
    )
}

fn shard_to_json(data: &Dataset) -> Json {
    let kind = ("kind", Json::Str(data.model_name().into()));
    match data {
        Dataset::Gaussian { x, lik_prec, prior_prec } => json::obj(vec![
            kind,
            ("x", matrix_to_json(x)),
            ("lik_prec", Json::Num(*lik_prec)),
            ("prior_prec", Json::Num(*prior_prec)),
        ]),
        Dataset::Logistic { x, y, prior_prec } => json::obj(vec![
            kind,
            ("x", matrix_to_json(x)),
            ("y", json::num_arr(y)),
            ("prior_prec", Json::Num(*prior_prec)),
        ]),
        Dataset::Gmm { x, logw, inv_var, prior_prec } => json::obj(vec![
            kind,
            ("x", matrix_to_json(x)),
            ("logw", json::num_arr(logw)),
            ("inv_var", Json::Num(*inv_var)),
            ("prior_prec", Json::Num(*prior_prec)),
        ]),
        Dataset::PoissonGamma { xs, ts, lam, alpha, beta_p } => {
            json::obj(vec![
                kind,
                ("xs", json::num_arr(xs)),
                ("ts", json::num_arr(ts)),
                ("lam", Json::Num(*lam)),
                ("alpha", Json::Num(*alpha)),
                ("beta_p", Json::Num(*beta_p)),
            ])
        }
        Dataset::LinReg { x, y, lik_prec, prior_prec } => json::obj(vec![
            kind,
            ("x", matrix_to_json(x)),
            ("y", json::num_arr(y)),
            ("lik_prec", Json::Num(*lik_prec)),
            ("prior_prec", Json::Num(*prior_prec)),
        ]),
    }
}

fn check_len(name: &str, got: usize, want: usize) -> Result<()> {
    if got != want {
        return Err(Error::Parse(format!(
            "shard field '{name}' has {got} entries, expected {want}"
        )));
    }
    Ok(())
}

fn shard_from_json(j: &Json) -> Result<Dataset> {
    match j.get("kind")?.as_str()? {
        "gaussian" => Ok(Dataset::Gaussian {
            x: matrix_from_json(j.get("x")?)?,
            lik_prec: j.get("lik_prec")?.as_f64()?,
            prior_prec: j.get("prior_prec")?.as_f64()?,
        }),
        "logistic" => {
            let x = matrix_from_json(j.get("x")?)?;
            let y = json::f64_vec(j.get("y")?)?;
            check_len("y", y.len(), x.len())?;
            Ok(Dataset::Logistic {
                x,
                y,
                prior_prec: j.get("prior_prec")?.as_f64()?,
            })
        }
        "gmm" => Ok(Dataset::Gmm {
            x: matrix_from_json(j.get("x")?)?,
            logw: json::f64_vec(j.get("logw")?)?,
            inv_var: j.get("inv_var")?.as_f64()?,
            prior_prec: j.get("prior_prec")?.as_f64()?,
        }),
        "poisson_gamma" => {
            let xs = json::f64_vec(j.get("xs")?)?;
            let ts = json::f64_vec(j.get("ts")?)?;
            check_len("ts", ts.len(), xs.len())?;
            Ok(Dataset::PoissonGamma {
                xs,
                ts,
                lam: j.get("lam")?.as_f64()?,
                alpha: j.get("alpha")?.as_f64()?,
                beta_p: j.get("beta_p")?.as_f64()?,
            })
        }
        "linreg" => {
            let x = matrix_from_json(j.get("x")?)?;
            let y = json::f64_vec(j.get("y")?)?;
            check_len("y", y.len(), x.len())?;
            Ok(Dataset::LinReg {
                x,
                y,
                lik_prec: j.get("lik_prec")?.as_f64()?,
                prior_prec: j.get("prior_prec")?.as_f64()?,
            })
        }
        other => Err(Error::Parse(format!("unknown dataset kind '{other}'"))),
    }
}

/// Generic row-oriented results table (e.g. error-vs-time curves).
#[derive(Debug, Clone, Default)]
pub struct Table {
    pub columns: Vec<String>,
    pub rows: Vec<Vec<f64>>,
    /// Optional string tag per row (e.g. method name).
    pub tags: Vec<String>,
}

impl Table {
    pub fn new(columns: &[&str]) -> Self {
        Table {
            columns: columns.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            tags: Vec::new(),
        }
    }

    pub fn push(&mut self, tag: &str, row: Vec<f64>) {
        assert_eq!(row.len(), self.columns.len());
        self.rows.push(row);
        self.tags.push(tag.to_string());
    }

    pub fn write_csv(&self, path: &Path) -> Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        writeln!(f, "tag,{}", self.columns.join(","))?;
        for (tag, row) in self.tags.iter().zip(&self.rows) {
            let line: Vec<String> =
                row.iter().map(|v| format!("{v:.6e}")).collect();
            writeln!(f, "{tag},{}", line.join(","))?;
        }
        Ok(())
    }

    /// Render as an aligned markdown table (for EXPERIMENTS.md).
    pub fn to_markdown(&self) -> String {
        let mut s = String::new();
        s.push_str("| tag |");
        for c in &self.columns {
            s.push_str(&format!(" {c} |"));
        }
        s.push('\n');
        s.push_str("|---|");
        for _ in &self.columns {
            s.push_str("---|");
        }
        s.push('\n');
        for (tag, row) in self.tags.iter().zip(&self.rows) {
            s.push_str(&format!("| {tag} |"));
            for v in row {
                s.push_str(&format!(" {v:.4} |"));
            }
            s.push('\n');
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn samples_csv_roundtrip() {
        let dir = std::env::temp_dir().join("repro_io_test");
        let path = dir.join("s.csv");
        let mut s = SampleMatrix::new(3);
        s.push(&[1.0, -2.5, 3.25]);
        s.push(&[0.125, 7.0, -0.0625]);
        write_samples_csv(&path, &s).unwrap();
        let back = read_samples_csv(&path).unwrap();
        assert_eq!(back.len(), 2);
        for i in 0..2 {
            for j in 0..3 {
                assert!((back.row(i)[j] - s.row(i)[j]).abs() < 1e-12);
            }
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn csv_rejects_bad_rows() {
        let dir = std::env::temp_dir().join("repro_io_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.csv");
        std::fs::write(&path, "d0,d1\n1.0,2.0\n3.0\n").unwrap();
        assert!(read_samples_csv(&path).is_err());
        std::fs::write(&path, "d0\nnot_a_number\n").unwrap();
        assert!(read_samples_csv(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn shard_json_roundtrips_every_model_bit_exactly() {
        use crate::data::synth;
        let dir = std::env::temp_dir().join("repro_shard_io_test");
        let idx: Vec<usize> = (5..37).collect();
        let datasets = [
            synth::gaussian(60, 2, 1),
            synth::logistic(60, 3, 2),
            synth::gmm(60, 2, 2, 4.0, 3),
            synth::poisson_gamma(60, 4),
            synth::linreg(60, 2, 5),
        ];
        for (i, ds) in datasets.iter().enumerate() {
            let shard = ds.select(&idx).unwrap();
            let path = dir.join(format!("shard_{i}.json"));
            write_shard_json(&path, &shard).unwrap();
            let back = read_shard_json(&path).unwrap();
            // Debug formatting prints floats with shortest-round-trip
            // digits, so equal strings ⇔ bit-identical contents.
            assert_eq!(
                format!("{shard:?}"),
                format!("{back:?}"),
                "{} shard diverged through JSON",
                ds.model_name()
            );
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn shard_json_rejects_malformed() {
        let dir = std::env::temp_dir().join("repro_shard_io_bad");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.json");
        std::fs::write(&path, "{\"kind\":\"warp\"}").unwrap();
        assert!(read_shard_json(&path).is_err());
        // Mismatched label length must be caught at load, not at panic.
        std::fs::write(
            &path,
            "{\"kind\":\"logistic\",\"x\":{\"dim\":1,\"data\":[1,2]},\
             \"y\":[1],\"prior_prec\":1}",
        )
        .unwrap();
        assert!(read_shard_json(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn table_markdown_and_csv() {
        let mut t = Table::new(&["time", "error"]);
        t.push("parametric", vec![1.0, 0.25]);
        t.push("nonparametric", vec![2.0, 0.125]);
        let md = t.to_markdown();
        assert!(md.contains("| parametric |"));
        assert!(md.contains("error"));
        let dir = std::env::temp_dir().join("repro_io_test3");
        let path = dir.join("t.csv");
        t.write_csv(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.starts_with("tag,time,error"));
        std::fs::remove_dir_all(&dir).ok();
    }
}
