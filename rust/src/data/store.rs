//! Chunked draw-plane storage with optional disk spill.
//!
//! A [`DrawStore`] holds one machine's retained draws as a sequence of
//! fixed-size row-chunk **segments** plus an in-progress tail. With no
//! spill budget configured every segment stays in memory and the store
//! is a bit-exact wrapper over today's dense
//! [`SampleMatrix`] behavior; with a budget, sealed segments spill
//! coldest-first to `RPSHRD1`-layout files
//! ([`crate::data::io::write_draw_segment`]) and are read back through
//! the mmap ingest path when a consumer iterates.
//!
//! Determinism contract: the flat row stream a store yields — via
//! [`DrawStore::for_each_chunk`] or [`DrawStore::to_matrix`] — is a
//! function of the pushed rows only. Chunk size, spill budget, and how
//! pushes were batched change *where* the bytes live, never *what*
//! they are; spilled values round-trip through `f64::to_le_bytes`
//! verbatim, so NaN payloads, ±Inf, and -0.0 survive bit-exactly.

use crate::data::io;
use crate::error::Result;
use crate::types::SampleMatrix;
use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};

/// Default rows per sealed chunk (`chunk_rows` config key).
pub const DEFAULT_CHUNK_ROWS: usize = 512;

/// Shape of a [`DrawStore`]: chunking granularity and spill policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DrawStoreConfig {
    /// Rows per sealed segment. Boundaries fall at fixed row indices
    /// (multiples of `chunk_rows`) regardless of how pushes were
    /// batched, so the segment layout is deterministic per machine.
    pub chunk_rows: usize,
    /// `None` ⇒ dense, never spill (today's behavior). `Some(0)` ⇒
    /// every sealed segment spills immediately. `Some(b)` ⇒ sealed
    /// segments spill coldest-first while their resident bytes exceed
    /// `b`. The in-progress tail (< `chunk_rows` rows) never spills.
    pub spill_budget_bytes: Option<usize>,
}

impl Default for DrawStoreConfig {
    fn default() -> Self {
        DrawStoreConfig {
            chunk_rows: DEFAULT_CHUNK_ROWS,
            spill_budget_bytes: None,
        }
    }
}

/// Memory accounting for one store (or a sum over stores): what is
/// resident now, what sits on disk, and the high-water mark.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct DrawStoreStats {
    /// Payload bytes currently held in memory (sealed segments + tail).
    pub resident_bytes: usize,
    /// Payload bytes currently spilled to disk.
    pub spilled_bytes: usize,
    /// Highest resident-bytes value ever observed.
    pub peak_resident_bytes: usize,
}

impl DrawStoreStats {
    /// Accumulate another store's stats (peaks add conservatively:
    /// the stores coexist, so the plane's peak is at most the sum).
    pub fn absorb(&mut self, other: &DrawStoreStats) {
        self.resident_bytes += other.resident_bytes;
        self.spilled_bytes += other.spilled_bytes;
        self.peak_resident_bytes += other.peak_resident_bytes;
    }
}

/// One sealed row chunk: resident, or spilled to a segment file.
#[derive(Debug)]
enum Segment {
    Mem(Vec<f64>),
    Disk { path: PathBuf, rows: usize },
}

/// Spill-directory sequence number: keeps concurrent stores in one
/// process (every leader holds M of them) from colliding.
static STORE_SEQ: AtomicUsize = AtomicUsize::new(0);

/// Chunked storage for one machine's draws. See the module docs.
#[derive(Debug)]
pub struct DrawStore {
    dim: usize,
    cfg: DrawStoreConfig,
    /// Sealed segments in row order. Spill is strictly coldest-first
    /// (front to back), so `segments[..spilled]` are all on disk.
    segments: Vec<Segment>,
    /// Count of leading `Disk` segments.
    spilled: usize,
    /// In-progress rows (< `chunk_rows`), never spilled.
    tail: Vec<f64>,
    rows: usize,
    /// Payload bytes of sealed `Mem` segments.
    sealed_resident: usize,
    spilled_bytes: usize,
    peak_resident: usize,
    /// Lazily created on first spill; removed on drop.
    spill_dir: Option<PathBuf>,
    seq: usize,
}

impl DrawStore {
    /// Dense store (default chunking, no spill) — bit-exact stand-in
    /// for a `SampleMatrix` accumulator.
    pub fn new(dim: usize) -> DrawStore {
        DrawStore::with_config(dim, DrawStoreConfig::default())
    }

    /// Store with an explicit chunk size and spill policy.
    pub fn with_config(dim: usize, cfg: DrawStoreConfig) -> DrawStore {
        assert!(dim > 0, "dim must be positive");
        assert!(cfg.chunk_rows > 0, "chunk_rows must be positive");
        DrawStore {
            dim,
            cfg,
            segments: Vec::new(),
            spilled: 0,
            tail: Vec::new(),
            rows: 0,
            sealed_resident: 0,
            spilled_bytes: 0,
            peak_resident: 0,
            spill_dir: None,
            seq: STORE_SEQ.fetch_add(1, Ordering::Relaxed),
        }
    }

    /// Build a store holding the matrix's rows (used by the dense →
    /// store adapters and tests).
    pub fn from_matrix(
        samples: &SampleMatrix,
        cfg: DrawStoreConfig,
    ) -> Result<DrawStore> {
        let mut store = DrawStore::with_config(samples.dim(), cfg);
        store.push_rows(samples.as_slice())?;
        Ok(store)
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Number of draws held (resident + spilled + tail).
    pub fn len(&self) -> usize {
        self.rows
    }

    pub fn is_empty(&self) -> bool {
        self.rows == 0
    }

    pub fn config(&self) -> &DrawStoreConfig {
        &self.cfg
    }

    /// Append one draw. May spill a newly sealed segment.
    pub fn push(&mut self, theta: &[f64]) -> Result<()> {
        assert_eq!(theta.len(), self.dim, "draw has wrong dimension");
        self.tail.extend_from_slice(theta);
        self.rows += 1;
        self.note_peak();
        self.seal_full_chunks()
    }

    /// Append draws from a flat row-major buffer (a whole number of
    /// rows) — the bulk landing path for decoded `RPDRAW1` chunks: one
    /// copy into the tail, then sealing at the fixed chunk boundaries.
    pub fn push_rows(&mut self, flat: &[f64]) -> Result<()> {
        assert_eq!(
            flat.len() % self.dim,
            0,
            "flat buffer of {} is not whole rows of dim {}",
            flat.len(),
            self.dim
        );
        self.tail.extend_from_slice(flat);
        self.rows += flat.len() / self.dim;
        self.note_peak();
        self.seal_full_chunks()
    }

    /// Visit every chunk of rows in order, each as one flat row-major
    /// slice of whole rows. Sealed in-memory segments are borrowed
    /// directly; spilled segments are read back through one reused
    /// buffer, so at most one disk chunk is resident at a time.
    pub fn for_each_chunk<F>(&self, mut f: F) -> Result<()>
    where
        F: FnMut(&[f64]) -> Result<()>,
    {
        let mut buf: Vec<f64> = Vec::new();
        for seg in &self.segments {
            match seg {
                Segment::Mem(data) => f(data)?,
                Segment::Disk { path, rows } => {
                    io::read_draw_segment_into(
                        path, self.dim, *rows, &mut buf,
                    )?;
                    f(&buf)?;
                }
            }
        }
        if !self.tail.is_empty() {
            f(&self.tail)?;
        }
        Ok(())
    }

    /// Densify into a [`SampleMatrix`] — byte-identical to the matrix a
    /// dense accumulator would hold after the same pushes, whatever the
    /// chunk size or spill policy.
    pub fn to_matrix(&self) -> Result<SampleMatrix> {
        let mut out = SampleMatrix::with_capacity(self.dim, self.rows);
        self.for_each_chunk(|block| {
            out.push_rows(block);
            Ok(())
        })?;
        Ok(out)
    }

    /// Current memory accounting.
    pub fn stats(&self) -> DrawStoreStats {
        DrawStoreStats {
            resident_bytes: self.resident_bytes(),
            spilled_bytes: self.spilled_bytes,
            peak_resident_bytes: self.peak_resident,
        }
    }

    fn resident_bytes(&self) -> usize {
        self.sealed_resident + self.tail.len() * 8
    }

    fn note_peak(&mut self) {
        self.peak_resident = self.peak_resident.max(self.resident_bytes());
    }

    /// Move full chunks out of the tail, then enforce the spill budget.
    /// Sealing drains exactly `chunk_rows` rows at a time so segment
    /// boundaries fall at fixed row indices regardless of push batching.
    fn seal_full_chunks(&mut self) -> Result<()> {
        let chunk_scalars = self.cfg.chunk_rows * self.dim;
        while self.tail.len() >= chunk_scalars {
            let seg: Vec<f64> = if self.tail.len() == chunk_scalars {
                std::mem::take(&mut self.tail)
            } else {
                self.tail.drain(..chunk_scalars).collect()
            };
            self.sealed_resident += seg.len() * 8;
            self.segments.push(Segment::Mem(seg));
        }
        self.enforce_budget()
    }

    fn enforce_budget(&mut self) -> Result<()> {
        let Some(budget) = self.cfg.spill_budget_bytes else {
            return Ok(());
        };
        while self.sealed_resident > budget
            && self.spilled < self.segments.len()
        {
            self.spill_segment(self.spilled)?;
            self.spilled += 1;
        }
        Ok(())
    }

    fn spill_segment(&mut self, i: usize) -> Result<()> {
        if self.spill_dir.is_none() {
            let dir = std::env::temp_dir().join(format!(
                "repro_draws_{}_{}",
                std::process::id(),
                self.seq
            ));
            std::fs::create_dir_all(&dir)?;
            self.spill_dir = Some(dir);
        }
        let dir = self.spill_dir.as_ref().unwrap();
        let Segment::Mem(data) = &self.segments[i] else {
            unreachable!("spill cursor always points at a Mem segment");
        };
        let path = dir.join(format!("seg_{i}.bin"));
        io::write_draw_segment(&path, self.dim, data)?;
        let bytes = data.len() * 8;
        let rows = data.len() / self.dim;
        self.sealed_resident -= bytes;
        self.spilled_bytes += bytes;
        self.segments[i] = Segment::Disk { path, rows };
        Ok(())
    }
}

impl Drop for DrawStore {
    fn drop(&mut self) {
        if let Some(dir) = &self.spill_dir {
            std::fs::remove_dir_all(dir).ok();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rows(n: usize, d: usize) -> Vec<Vec<f64>> {
        (0..n)
            .map(|i| {
                (0..d).map(|j| (i * d + j) as f64 * 0.5 - 3.0).collect()
            })
            .collect()
    }

    fn filled(
        n: usize,
        d: usize,
        cfg: DrawStoreConfig,
    ) -> (DrawStore, SampleMatrix) {
        let mut store = DrawStore::with_config(d, cfg);
        let mut dense = SampleMatrix::new(d);
        for r in rows(n, d) {
            store.push(&r).unwrap();
            dense.push(&r);
        }
        (store, dense)
    }

    #[test]
    fn dense_default_matches_sample_matrix() {
        let (store, dense) = filled(37, 3, DrawStoreConfig::default());
        assert_eq!(store.len(), 37);
        assert_eq!(store.dim(), 3);
        let back = store.to_matrix().unwrap();
        assert_eq!(back.as_slice(), dense.as_slice());
        assert_eq!(store.stats().spilled_bytes, 0);
        assert_eq!(store.stats().resident_bytes, 37 * 3 * 8);
    }

    #[test]
    fn chunk_boundaries_are_push_batch_invariant() {
        let d = 2;
        let all: Vec<f64> =
            rows(23, d).into_iter().flatten().collect();
        let cfg = DrawStoreConfig { chunk_rows: 5, spill_budget_bytes: None };
        // One bulk push vs ragged bulk pushes vs per-row pushes.
        let mut a = DrawStore::with_config(d, cfg);
        a.push_rows(&all).unwrap();
        let mut b = DrawStore::with_config(d, cfg);
        for part in all.chunks(7 * d) {
            b.push_rows(part).unwrap();
        }
        let mut c = DrawStore::with_config(d, cfg);
        for r in all.chunks(d) {
            c.push(r).unwrap();
        }
        for store in [&a, &b, &c] {
            let mut sizes = Vec::new();
            store
                .for_each_chunk(|block| {
                    sizes.push(block.len() / d);
                    Ok(())
                })
                .unwrap();
            assert_eq!(sizes, vec![5, 5, 5, 5, 3]);
            assert_eq!(store.to_matrix().unwrap().as_slice(), &all[..]);
        }
    }

    #[test]
    fn budget_zero_spills_every_sealed_chunk() {
        let cfg = DrawStoreConfig {
            chunk_rows: 4,
            spill_budget_bytes: Some(0),
        };
        let (store, dense) = filled(18, 2, cfg);
        let stats = store.stats();
        // 4 sealed chunks of 4 rows spilled; 2 tail rows resident.
        assert_eq!(stats.spilled_bytes, 16 * 2 * 8);
        assert_eq!(stats.resident_bytes, 2 * 2 * 8);
        assert!(stats.peak_resident_bytes >= 4 * 2 * 8);
        assert_eq!(
            store.to_matrix().unwrap().as_slice(),
            dense.as_slice(),
            "spilled store diverged from dense"
        );
    }

    #[test]
    fn huge_budget_never_spills() {
        let cfg = DrawStoreConfig {
            chunk_rows: 4,
            spill_budget_bytes: Some(usize::MAX),
        };
        let (store, dense) = filled(18, 2, cfg);
        assert_eq!(store.stats().spilled_bytes, 0);
        assert_eq!(store.to_matrix().unwrap().as_slice(), dense.as_slice());
    }

    #[test]
    fn nonfinite_payloads_roundtrip_spill_bit_exactly() {
        let cfg = DrawStoreConfig {
            chunk_rows: 1,
            spill_budget_bytes: Some(0),
        };
        let mut store = DrawStore::with_config(2, cfg);
        let nan_payload = f64::from_bits(0x7ff8_0000_dead_beef);
        let weird = [
            [f64::INFINITY, -0.0],
            [f64::NEG_INFINITY, nan_payload],
            [f64::MIN_POSITIVE / 2.0, f64::MAX],
        ];
        for r in &weird {
            store.push(r).unwrap();
        }
        assert!(store.stats().spilled_bytes > 0);
        let back = store.to_matrix().unwrap();
        let flat: Vec<f64> =
            weird.iter().flat_map(|r| r.iter().copied()).collect();
        for (a, b) in flat.iter().zip(back.as_slice()) {
            assert_eq!(
                a.to_bits(),
                b.to_bits(),
                "spill round-trip changed a bit pattern"
            );
        }
    }

    #[test]
    fn spill_dir_removed_on_drop() {
        let cfg = DrawStoreConfig {
            chunk_rows: 1,
            spill_budget_bytes: Some(0),
        };
        let mut store = DrawStore::with_config(1, cfg);
        store.push(&[1.0]).unwrap();
        store.push(&[2.0]).unwrap();
        let dir = store.spill_dir.clone().expect("spill dir created");
        assert!(dir.is_dir());
        drop(store);
        assert!(!dir.exists(), "spill dir must clean up after itself");
    }

    #[test]
    fn peak_tracks_high_water_mark() {
        let cfg = DrawStoreConfig {
            chunk_rows: 8,
            spill_budget_bytes: Some(0),
        };
        let (store, _) = filled(32, 1, cfg);
        let stats = store.stats();
        // Residency peaks just as a chunk seals: 8 rows × 8 bytes.
        assert_eq!(stats.peak_resident_bytes, 8 * 8);
        assert_eq!(stats.spilled_bytes, 32 * 8);
        assert_eq!(stats.resident_bytes, 0);
    }
}
