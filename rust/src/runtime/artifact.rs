//! Artifact manifest: the contract between `python/compile/aot.py` and
//! the rust runtime.

use super::json::Json;
use crate::error::{Error, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// Shape+dtype of one artifact input/output.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorSpec {
    pub fn element_count(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }

    fn from_json(v: &Json) -> Result<TensorSpec> {
        Ok(TensorSpec {
            name: v.get("name")?.as_str()?.to_string(),
            shape: v
                .get("shape")?
                .as_arr()?
                .iter()
                .map(|s| s.as_usize())
                .collect::<Result<_>>()?,
            dtype: v.get("dtype")?.as_str()?.to_string(),
        })
    }
}

/// One compiled-computation artifact.
#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    pub name: String,
    /// "logp_grad" or "hmc".
    pub kind: String,
    /// Model name: logistic | gmm | poisson_gamma | gaussian.
    pub model: String,
    /// Baked lowering constants (n, d, block_n, n_steps, …).
    pub params: BTreeMap<String, usize>,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    /// HLO text file, relative to the manifest directory.
    pub file: String,
}

impl ArtifactMeta {
    fn from_json(v: &Json) -> Result<ArtifactMeta> {
        let params = v
            .get("params")?
            .as_obj()?
            .iter()
            .map(|(k, pv)| Ok((k.clone(), pv.as_usize()?)))
            .collect::<Result<_>>()?;
        Ok(ArtifactMeta {
            name: v.get("name")?.as_str()?.to_string(),
            kind: v.get("kind")?.as_str()?.to_string(),
            model: v.get("model")?.as_str()?.to_string(),
            params,
            inputs: v
                .get("inputs")?
                .as_arr()?
                .iter()
                .map(TensorSpec::from_json)
                .collect::<Result<_>>()?,
            outputs: v
                .get("outputs")?
                .as_arr()?
                .iter()
                .map(TensorSpec::from_json)
                .collect::<Result<_>>()?,
            file: v.get("file")?.as_str()?.to_string(),
        })
    }

    /// Position of a named input.
    pub fn input_index(&self, name: &str) -> Result<usize> {
        self.inputs
            .iter()
            .position(|s| s.name == name)
            .ok_or_else(|| {
                Error::Runtime(format!(
                    "artifact {} has no input '{name}'",
                    self.name
                ))
            })
    }

    pub fn param(&self, key: &str) -> Result<usize> {
        self.params.get(key).copied().ok_or_else(|| {
            Error::Runtime(format!("artifact {} missing param '{key}'", self.name))
        })
    }
}

/// The full artifact directory manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: Vec<ArtifactMeta>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            Error::Runtime(format!(
                "cannot read {} (run `make artifacts`): {e}",
                path.display()
            ))
        })?;
        Self::parse(dir, &text)
    }

    pub fn parse(dir: &Path, text: &str) -> Result<Manifest> {
        let v = Json::parse(text)?;
        let artifacts = v
            .as_arr()?
            .iter()
            .map(ArtifactMeta::from_json)
            .collect::<Result<Vec<_>>>()?;
        // Names must be unique.
        let mut names: Vec<&str> =
            artifacts.iter().map(|a| a.name.as_str()).collect();
        names.sort_unstable();
        names.dedup();
        if names.len() != artifacts.len() {
            return Err(Error::Runtime("duplicate artifact names".into()));
        }
        Ok(Manifest { dir: dir.to_path_buf(), artifacts })
    }

    pub fn get(&self, name: &str) -> Result<&ArtifactMeta> {
        self.artifacts
            .iter()
            .find(|a| a.name == name)
            .ok_or_else(|| Error::Runtime(format!("no artifact '{name}'")))
    }

    /// Find an artifact by model/kind and minimum padded shard size.
    /// Returns the smallest artifact whose padded `n` fits `n_rows`.
    pub fn find(
        &self,
        model: &str,
        kind: &str,
        n_rows: usize,
    ) -> Result<&ArtifactMeta> {
        self.artifacts
            .iter()
            .filter(|a| a.model == model && a.kind == kind)
            .filter(|a| a.param("n").map(|n| n >= n_rows).unwrap_or(false))
            .min_by_key(|a| a.param("n").unwrap_or(usize::MAX))
            .ok_or_else(|| {
                Error::Runtime(format!(
                    "no {model}/{kind} artifact with n >= {n_rows}"
                ))
            })
    }

    /// Absolute HLO path of an artifact.
    pub fn hlo_path(&self, meta: &ArtifactMeta) -> PathBuf {
        self.dir.join(&meta.file)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"[
      {"name": "gauss_lpg_n512_d2", "kind": "logp_grad", "model": "gaussian",
       "params": {"n": 512, "d": 2},
       "inputs": [
         {"name": "x", "shape": [512, 2], "dtype": "f32"},
         {"name": "mask", "shape": [512], "dtype": "f32"},
         {"name": "theta", "shape": [2], "dtype": "f32"}],
       "outputs": [
         {"name": "logp", "shape": [], "dtype": "f32"},
         {"name": "grad", "shape": [2], "dtype": "f32"}],
       "file": "gauss_lpg_n512_d2.hlo.txt"},
      {"name": "gauss_lpg_n2048_d2", "kind": "logp_grad", "model": "gaussian",
       "params": {"n": 2048, "d": 2},
       "inputs": [], "outputs": [], "file": "x.hlo.txt"}
    ]"#;

    #[test]
    fn parse_and_lookup() {
        let m = Manifest::parse(Path::new("/tmp/a"), SAMPLE).unwrap();
        assert_eq!(m.artifacts.len(), 2);
        let a = m.get("gauss_lpg_n512_d2").unwrap();
        assert_eq!(a.param("d").unwrap(), 2);
        assert_eq!(a.input_index("theta").unwrap(), 2);
        assert!(a.input_index("nope").is_err());
        assert_eq!(a.inputs[0].element_count(), 1024);
        assert_eq!(
            m.hlo_path(a),
            PathBuf::from("/tmp/a/gauss_lpg_n512_d2.hlo.txt")
        );
    }

    #[test]
    fn find_picks_smallest_fitting() {
        let m = Manifest::parse(Path::new("."), SAMPLE).unwrap();
        let a = m.find("gaussian", "logp_grad", 100).unwrap();
        assert_eq!(a.param("n").unwrap(), 512);
        let b = m.find("gaussian", "logp_grad", 1000).unwrap();
        assert_eq!(b.param("n").unwrap(), 2048);
        assert!(m.find("gaussian", "logp_grad", 5000).is_err());
        assert!(m.find("bogus", "logp_grad", 1).is_err());
    }

    #[test]
    fn duplicate_names_rejected() {
        let dup = format!(
            "[{0},{0}]",
            r#"{"name": "a", "kind": "k", "model": "m", "params": {},
                "inputs": [], "outputs": [], "file": "f"}"#
        );
        assert!(Manifest::parse(Path::new("."), &dup).is_err());
    }

    #[test]
    fn real_manifest_loads_if_present() {
        // Exercises the actual artifacts/ directory when it exists (CI
        // runs `make artifacts` first).
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("manifest.json").exists() {
            let m = Manifest::load(&dir).unwrap();
            assert!(!m.artifacts.is_empty());
            for a in &m.artifacts {
                assert!(m.hlo_path(a).exists(), "{} missing", a.file);
                assert!(a.kind == "logp_grad" || a.kind == "hmc");
            }
        }
    }
}
