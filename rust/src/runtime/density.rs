//! [`XlaDensity`]: a subposterior evaluated through compiled PJRT
//! artifacts — the production hot path.
//!
//! The shard's data tensors (and all constant scalars) are uploaded to
//! device buffers once at construction; each `logp_grad` call uploads
//! only θ (d floats). When an `hmc` artifact for the same model/shape is
//! available, [`crate::model::LogDensity::fused_trajectory`] advances a
//! whole L-step leapfrog trajectory in ONE artifact execution instead of
//! `2L+1` — the L2-layer optimization measured in EXPERIMENTS.md §Perf.

use std::rc::Rc;

use super::artifact::ArtifactMeta;
use super::client::RuntimeClient;
// Offline stub standing in for the real PJRT bindings (see
// `runtime/xla_shim.rs` for how to swap in the vendored crate).
use super::xla_shim as xla;
use crate::data::Dataset;
use crate::error::{Error, Result};
use crate::model::{LogDensity, Trajectory};

/// Device-resident constant inputs keyed by input name.
struct StaticInput {
    name: String,
    buffer: xla::PjRtBuffer,
}

/// A PJRT-backed subposterior.
pub struct XlaDensity<'c> {
    client: &'c RuntimeClient,
    lpg: ArtifactMeta,
    hmc: Option<ArtifactMeta>,
    statics: Vec<StaticInput>,
    dim: usize,
}

impl<'c> XlaDensity<'c> {
    /// Build from a dataset shard. Finds the smallest fitting artifacts
    /// in the manifest, pads the shard with zero-mask rows, uploads all
    /// static inputs, and (if present) wires up the fused-HMC artifact.
    ///
    /// `prior_w` is 1/M per Eq. 2.1.
    pub fn from_shard(
        client: &'c RuntimeClient,
        data: &Dataset,
        idx: &[usize],
        prior_w: f64,
    ) -> Result<Self> {
        let model = data.model_name();
        let lpg = client
            .manifest()
            .find(model, "logp_grad", idx.len())?
            .clone();
        let hmc = client.manifest().find(model, "hmc", idx.len()).ok().cloned();
        // The hmc artifact must share the padded shape with the lpg one.
        let hmc = hmc.filter(|h| h.param("n").ok() == lpg.param("n").ok());
        let n_pad = lpg.param("n")?;
        if idx.len() > n_pad {
            return Err(Error::Runtime(format!(
                "shard of {} exceeds artifact capacity {n_pad}",
                idx.len()
            )));
        }

        let mut statics: Vec<StaticInput> = Vec::new();
        let mut push = |name: &str, data: &[f32], dims: &[usize]| -> Result<()> {
            statics.push(StaticInput {
                name: name.to_string(),
                buffer: client.upload(data, dims)?,
            });
            Ok(())
        };

        // Mask: 1 for real rows, 0 for padding.
        let mut mask = vec![0.0f32; n_pad];
        for i in 0..idx.len() {
            mask[i] = 1.0;
        }

        match data {
            Dataset::Gaussian { x, lik_prec, prior_prec } => {
                let d = x.dim();
                let mut xs = vec![0.0f32; n_pad * d];
                for (r, &i) in idx.iter().enumerate() {
                    for (j, &v) in x.row(i).iter().enumerate() {
                        xs[r * d + j] = v as f32;
                    }
                }
                push("x", &xs, &[n_pad, d])?;
                push("mask", &mask, &[n_pad])?;
                push("lik_prec", &[*lik_prec as f32], &[])?;
                push("prior_w", &[prior_w as f32], &[])?;
                push("prior_prec", &[*prior_prec as f32], &[])?;
            }
            Dataset::Logistic { x, y, prior_prec } => {
                let d = x.dim();
                let mut xs = vec![0.0f32; n_pad * d];
                let mut ys = vec![0.0f32; n_pad];
                for (r, &i) in idx.iter().enumerate() {
                    for (j, &v) in x.row(i).iter().enumerate() {
                        xs[r * d + j] = v as f32;
                    }
                    ys[r] = y[i] as f32;
                }
                push("x", &xs, &[n_pad, d])?;
                push("y", &ys, &[n_pad])?;
                push("mask", &mask, &[n_pad])?;
                push("prior_w", &[prior_w as f32], &[])?;
                push("prior_prec", &[*prior_prec as f32], &[])?;
            }
            Dataset::Gmm { x, logw, inv_var, prior_prec } => {
                let d = x.dim();
                let mut xs = vec![0.0f32; n_pad * d];
                for (r, &i) in idx.iter().enumerate() {
                    for (j, &v) in x.row(i).iter().enumerate() {
                        xs[r * d + j] = v as f32;
                    }
                }
                let lw: Vec<f32> = logw.iter().map(|&v| v as f32).collect();
                push("x", &xs, &[n_pad, d])?;
                push("mask", &mask, &[n_pad])?;
                push("logw", &lw, &[lw.len()])?;
                push("inv_var", &[*inv_var as f32], &[])?;
                push("prior_w", &[prior_w as f32], &[])?;
                push("prior_prec", &[*prior_prec as f32], &[])?;
            }
            Dataset::PoissonGamma { xs, ts, lam, alpha, beta_p } => {
                let mut xv = vec![0.0f32; n_pad];
                let mut tv = vec![1.0f32; n_pad]; // pad t=1 avoids log(0)
                for (r, &i) in idx.iter().enumerate() {
                    xv[r] = xs[i] as f32;
                    tv[r] = ts[i] as f32;
                }
                push("xs", &xv, &[n_pad])?;
                push("ts", &tv, &[n_pad])?;
                push("mask", &mask, &[n_pad])?;
                push("prior_w", &[prior_w as f32], &[])?;
                push("lam", &[*lam as f32], &[])?;
                push("alpha", &[*alpha as f32], &[])?;
                push("beta_p", &[*beta_p as f32], &[])?;
            }
            Dataset::LinReg { .. } => {
                return Err(Error::Runtime(
                    "no linreg artifact (native-only model)".into(),
                ));
            }
        }

        // θ dimension from the artifact spec.
        let ti = lpg.input_index("theta")?;
        let dim = lpg.inputs[ti].element_count();

        Ok(XlaDensity { client, lpg, hmc, statics, dim })
    }

    /// Whether the fused-HMC fast path is wired up.
    pub fn has_fused_hmc(&self) -> bool {
        self.hmc.is_some()
    }

    pub fn artifact_name(&self) -> &str {
        &self.lpg.name
    }

    /// Assemble the input buffer list for `meta`, pulling static inputs
    /// by name and dynamic ones from `dynamic` (name → buffer).
    fn assemble<'b>(
        &'b self,
        meta: &ArtifactMeta,
        dynamic: &'b [(&str, xla::PjRtBuffer)],
    ) -> Result<Vec<&'b xla::PjRtBuffer>> {
        meta.inputs
            .iter()
            .map(|spec| {
                if let Some((_, b)) =
                    dynamic.iter().find(|(n, _)| *n == spec.name)
                {
                    return Ok(b);
                }
                self.statics
                    .iter()
                    .find(|s| s.name == spec.name)
                    .map(|s| &s.buffer)
                    .ok_or_else(|| {
                        Error::Runtime(format!(
                            "no binding for input '{}'",
                            spec.name
                        ))
                    })
            })
            .collect()
    }

    fn upload_theta(&self, theta: &[f64]) -> Result<xla::PjRtBuffer> {
        let t32: Vec<f32> = theta.iter().map(|&v| v as f32).collect();
        self.client.upload(&t32, &[self.dim])
    }
}

impl LogDensity for XlaDensity<'_> {
    fn dim(&self) -> usize {
        self.dim
    }

    fn logp_grad(&self, theta: &[f64]) -> (f64, Vec<f64>) {
        // The sampler API is infallible; runtime faults (device OOM,
        // artifact mismatch) are programming/config errors → panic with
        // context rather than silently corrupting the chain.
        let run = || -> Result<(f64, Vec<f64>)> {
            let tb = self.upload_theta(theta)?;
            let dynamic = [("theta", tb)];
            let inputs = self.assemble(&self.lpg, &dynamic)?;
            let out = self.client.execute(&self.lpg, &inputs)?;
            let lp = out[0][0] as f64;
            let grad = out[1].iter().map(|&v| v as f64).collect();
            Ok((lp, grad))
        };
        run().unwrap_or_else(|e| panic!("xla logp_grad failed: {e}"))
    }

    fn fused_trajectory(
        &self,
        theta: &[f64],
        p: &[f64],
        eps: f64,
        n_steps: usize,
    ) -> Option<Trajectory> {
        let hmc = self.hmc.as_ref()?;
        if hmc.param("n_steps").ok()? != n_steps {
            return None; // trajectory length is baked at lowering time
        }
        let run = || -> Result<Trajectory> {
            let tb = self.upload_theta(theta)?;
            let p32: Vec<f32> = p.iter().map(|&v| v as f32).collect();
            let pb = self.client.upload(&p32, &[self.dim])?;
            let eb = self.client.upload_scalar(eps as f32)?;
            let dynamic = [("theta", tb), ("p", pb), ("eps", eb)];
            let inputs = self.assemble(hmc, &dynamic)?;
            let out = self.client.execute(hmc, &inputs)?;
            // outputs: theta_out, p_out, logp_out, grad_out, logp_in
            Ok(Trajectory {
                theta: out[0].iter().map(|&v| v as f64).collect(),
                p: out[1].iter().map(|&v| v as f64).collect(),
                logp: out[2][0] as f64,
                grad: out[3].iter().map(|&v| v as f64).collect(),
                logp0: out[4][0] as f64,
            })
        };
        Some(run().unwrap_or_else(|e| panic!("xla fused_trajectory failed: {e}")))
    }

    fn init_point(&self, rng: &mut crate::rng::Pcg64) -> Vec<f64> {
        (0..self.dim).map(|_| 0.1 * rng.normal()).collect()
    }
}

impl std::fmt::Debug for XlaDensity<'_> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "XlaDensity({}, fused_hmc={}, dim={})",
            self.lpg.name,
            self.hmc.is_some(),
            self.dim
        )
    }
}

// Tests for XlaDensity live in rust/tests/integration_runtime.rs (they
// need generated artifacts and a PJRT client).
// Silence dead-code warnings for Rc when artifacts are absent.
#[allow(unused)]
fn _rc_marker(_: Rc<()>) {}
