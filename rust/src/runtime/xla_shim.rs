//! Offline stub of the `xla` PJRT bindings.
//!
//! The crate is dependency-free by design (see Cargo.toml): no PJRT
//! bindings exist offline, yet [`super::client`] and [`super::density`]
//! are written against the real `xla` crate's API so they can bind to
//! it when it is vendored. This shim provides the same surface with
//! every fallible entry point failing fast, so the whole crate — in
//! particular the native sampling/combination paths, which never touch
//! PJRT — builds and tests everywhere. With the shim in place,
//! `RuntimeClient::cpu` returns a clear "runtime unavailable" error at
//! run time instead of the build failing to resolve `xla::*`.
//!
//! To enable the real runtime, vendor the bindings and swap the
//! `use crate::runtime::xla_shim as xla;` aliases in
//! `error.rs` / `runtime/client.rs` / `runtime/density.rs` for
//! `use xla;`.

use std::fmt;

/// Mirrors the real bindings' `xla::Error`.
#[derive(Debug)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

fn unavailable<T>() -> Result<T, Error> {
    Err(Error(
        "PJRT/XLA runtime not available in this build (offline stub; \
         vendor the xla bindings to enable --use-runtime)"
            .to_string(),
    ))
}

/// PJRT client handle (stub: construction always fails).
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, Error> {
        unavailable()
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(
        &self,
        _comp: &XlaComputation,
    ) -> Result<PjRtLoadedExecutable, Error> {
        unavailable()
    }

    pub fn buffer_from_host_buffer(
        &self,
        _data: &[f32],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer, Error> {
        unavailable()
    }
}

/// Compiled executable handle.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute_b(
        &self,
        _inputs: &[&PjRtBuffer],
    ) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        unavailable()
    }
}

/// Device buffer handle.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        unavailable()
    }
}

/// Host-side literal value.
pub struct Literal;

impl Literal {
    pub fn to_tuple(&self) -> Result<Vec<Literal>, Error> {
        unavailable()
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>, Error> {
        unavailable()
    }
}

/// Parsed HLO module.
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto, Error> {
        unavailable()
    }
}

/// XLA computation graph.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_fails_fast_with_clear_message() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.to_string().contains("not available"));
        let err2 = HloModuleProto::from_text_file("x.hlo").unwrap_err();
        assert!(err2.to_string().contains("stub"));
    }
}
