//! PJRT runtime: load and execute the AOT artifacts produced by
//! `python/compile/aot.py` (HLO text → compile once → execute on the
//! request path). Python is never invoked here.
//!
//! * [`json`] — minimal dependency-free JSON parser (the manifest format).
//! * [`artifact`] — `artifacts/manifest.json` schema + loading.
//! * [`client`] — PJRT CPU client wrapper + compiled-executable cache.
//! * [`density`] — [`XlaDensity`]: a [`crate::model::LogDensity`] backed
//!   by compiled artifacts, with the shard data pre-uploaded to device
//!   buffers and the fused L-step HMC trajectory exposed through
//!   [`crate::model::LogDensity::fused_trajectory`].

pub mod artifact;
pub mod client;
pub mod density;
pub mod json;
pub mod xla_shim;

pub use artifact::{ArtifactMeta, Manifest, TensorSpec};
pub use client::RuntimeClient;
pub use density::XlaDensity;
