//! PJRT client wrapper + compiled-executable cache.
//!
//! One [`RuntimeClient`] per process (or per thread — the underlying
//! `xla::PjRtClient` is `Rc`-based and not `Send`). HLO text artifacts
//! compile once and are cached by artifact name; compilation is the
//! expensive step (~tens of ms), execution is the hot path.

use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;

use super::artifact::{ArtifactMeta, Manifest};
// Offline stub standing in for the real PJRT bindings (see
// `runtime/xla_shim.rs` for how to swap in the vendored crate).
use super::xla_shim as xla;
use crate::error::{Error, Result};

/// PJRT CPU client with a compile cache over a manifest.
pub struct RuntimeClient {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
}

impl RuntimeClient {
    /// Create a CPU client over an artifact directory.
    pub fn cpu(artifact_dir: &std::path::Path) -> Result<Self> {
        let manifest = Manifest::load(artifact_dir)?;
        let client = xla::PjRtClient::cpu()?;
        Ok(RuntimeClient { client, manifest, cache: RefCell::new(HashMap::new()) })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn xla_client(&self) -> &xla::PjRtClient {
        &self.client
    }

    /// Compile (or fetch from cache) an artifact's executable.
    pub fn executable(
        &self,
        meta: &ArtifactMeta,
    ) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.borrow().get(&meta.name) {
            return Ok(exe.clone());
        }
        let path = self.manifest.hlo_path(meta);
        let path_str = path.to_str().ok_or_else(|| {
            Error::Runtime(format!("non-utf8 path {}", path.display()))
        })?;
        let proto = xla::HloModuleProto::from_text_file(path_str)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Rc::new(self.client.compile(&comp)?);
        self.cache.borrow_mut().insert(meta.name.clone(), exe.clone());
        Ok(exe)
    }

    /// Upload an f32 tensor to the device.
    pub fn upload(&self, data: &[f32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer(data, dims, None)?)
    }

    /// Upload a scalar.
    pub fn upload_scalar(&self, v: f32) -> Result<xla::PjRtBuffer> {
        self.upload(&[v], &[])
    }

    /// Execute an artifact with device-resident inputs; returns the flat
    /// f32 contents of each output in order (artifacts are lowered with
    /// `return_tuple=True`, so the single result is a tuple literal).
    pub fn execute(
        &self,
        meta: &ArtifactMeta,
        inputs: &[&xla::PjRtBuffer],
    ) -> Result<Vec<Vec<f32>>> {
        if inputs.len() != meta.inputs.len() {
            return Err(Error::Runtime(format!(
                "artifact {} expects {} inputs, got {}",
                meta.name,
                meta.inputs.len(),
                inputs.len()
            )));
        }
        let exe = self.executable(meta)?;
        let result = exe.execute_b(inputs)?;
        let first = result
            .first()
            .and_then(|r| r.first())
            .ok_or_else(|| Error::Runtime("no execution output".into()))?;
        let literal = first.to_literal_sync()?;
        let parts = literal.to_tuple()?;
        if parts.len() != meta.outputs.len() {
            return Err(Error::Runtime(format!(
                "artifact {} returned {} outputs, expected {}",
                meta.name,
                parts.len(),
                meta.outputs.len()
            )));
        }
        parts
            .into_iter()
            .map(|p| Ok(p.to_vec::<f32>()?))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::Path;

    fn artifacts_dir() -> std::path::PathBuf {
        Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    /// Full load→compile→execute round trip on the smallest artifact.
    /// Skipped when artifacts/ has not been generated.
    #[test]
    fn execute_gaussian_lpg_artifact() {
        let dir = artifacts_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let rt = RuntimeClient::cpu(&dir).unwrap();
        let meta = rt.manifest().get("gauss_lpg_n512_d2").unwrap().clone();
        // inputs: x (512,2), mask (512), theta (2), lik_prec, prior_w, prior_prec
        let n = 512;
        let x = vec![0.5f32; n * 2];
        let mask: Vec<f32> =
            (0..n).map(|i| if i < 10 { 1.0 } else { 0.0 }).collect();
        let theta = vec![0.0f32, 0.0f32];
        let bufs = vec![
            rt.upload(&x, &[n, 2]).unwrap(),
            rt.upload(&mask, &[n]).unwrap(),
            rt.upload(&theta, &[2]).unwrap(),
            rt.upload_scalar(1.0).unwrap(),
            rt.upload_scalar(0.5).unwrap(),
            rt.upload_scalar(1.0).unwrap(),
        ];
        let refs: Vec<&xla::PjRtBuffer> = bufs.iter().collect();
        let out = rt.execute(&meta, &refs).unwrap();
        assert_eq!(out.len(), 2);
        assert_eq!(out[0].len(), 1); // scalar logp
        assert_eq!(out[1].len(), 2); // grad
        // Compare against the native model.
        let mut data = crate::types::SampleMatrix::new(2);
        for _ in 0..10 {
            data.push(&[0.5, 0.5]);
        }
        let native = crate::model::GaussianMean::new(data, 1.0, 1.0, 0.5);
        use crate::model::LogDensity;
        let (lp, grad) = native.logp_grad(&[0.0, 0.0]);
        assert!(
            (out[0][0] as f64 - lp).abs() < 1e-3 * lp.abs().max(1.0),
            "logp {} vs native {lp}",
            out[0][0]
        );
        for j in 0..2 {
            assert!(
                (out[1][j] as f64 - grad[j]).abs() < 1e-3 * grad[j].abs().max(1.0),
                "grad[{j}] {} vs native {}",
                out[1][j],
                grad[j]
            );
        }
    }

    #[test]
    fn execute_rejects_wrong_arity() {
        let dir = artifacts_dir();
        if !dir.join("manifest.json").exists() {
            return;
        }
        let rt = RuntimeClient::cpu(&dir).unwrap();
        let meta = rt.manifest().get("gauss_lpg_n512_d2").unwrap().clone();
        let b = rt.upload_scalar(1.0).unwrap();
        assert!(rt.execute(&meta, &[&b]).is_err());
    }

    #[test]
    fn executable_cache_reuses_compilation() {
        let dir = artifacts_dir();
        if !dir.join("manifest.json").exists() {
            return;
        }
        let rt = RuntimeClient::cpu(&dir).unwrap();
        let meta = rt.manifest().get("gauss_lpg_n512_d2").unwrap().clone();
        let a = rt.executable(&meta).unwrap();
        let b = rt.executable(&meta).unwrap();
        assert!(Rc::ptr_eq(&a, &b));
    }
}
