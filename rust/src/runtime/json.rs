//! Minimal JSON parser and emitter — enough for
//! `artifacts/manifest.json` plus the process-mode wire format (draw
//! frames, worker manifests, shard spills).
//!
//! No external crates are available offline, so this implements the JSON
//! grammar (RFC 8259 minus `\u` surrogate pairs beyond the BMP) in ~200
//! lines. Numbers parse as f64; integer access checks convertibility.
//! [`Json::render`] emits floats with Rust's shortest-round-trip
//! formatting, so `parse(render(x))` reproduces every finite f64
//! bit-exactly — the property the process-mode byte-identity guarantee
//! rests on.

use crate::error::{Error, Result};
use std::collections::BTreeMap;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            other => Err(Error::Parse(format!("expected string, got {other:?}"))),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(v) => Ok(*v),
            other => Err(Error::Parse(format!("expected number, got {other:?}"))),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let v = self.as_f64()?;
        if v < 0.0 || v.fract() != 0.0 || v > usize::MAX as f64 {
            return Err(Error::Parse(format!("expected usize, got {v}")));
        }
        Ok(v as usize)
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(a) => Ok(a),
            other => Err(Error::Parse(format!("expected array, got {other:?}"))),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Ok(o),
            other => Err(Error::Parse(format!("expected object, got {other:?}"))),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            other => Err(Error::Parse(format!("expected bool, got {other:?}"))),
        }
    }

    /// Object field access with a helpful error.
    pub fn get(&self, key: &str) -> Result<&Json> {
        self.as_obj()?
            .get(key)
            .ok_or_else(|| Error::Parse(format!("missing field '{key}'")))
    }

    /// Serialize back to JSON text (no insignificant whitespace).
    ///
    /// Numbers use Rust's shortest-round-trip float formatting
    /// (integer-valued magnitudes below 2^53 print as plain integers,
    /// everything else as `{:e}`), so parsing the output reproduces
    /// every finite f64 bit-exactly. Non-finite numbers have no JSON
    /// representation and render as `null`.
    pub fn render(&self) -> String {
        let mut out = String::new();
        self.render_into(&mut out);
        out
    }

    fn render_into(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(v) => render_num(*v, out),
            Json::Str(s) => render_str(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.render_into(out);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    render_str(k, out);
                    out.push(':');
                    v.render_into(out);
                }
                out.push('}');
            }
        }
    }
}

fn render_num(v: f64, out: &mut String) {
    use std::fmt::Write;
    if !v.is_finite() {
        out.push_str("null");
    } else if v.fract() == 0.0 && v.abs() < 9.007_199_254_740_992e15 {
        let _ = write!(out, "{v:.0}");
    } else {
        let _ = write!(out, "{v:e}");
    }
}

fn render_str(s: &str, out: &mut String) {
    use std::fmt::Write;
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Build an object from `(key, value)` pairs.
pub fn obj(fields: Vec<(&str, Json)>) -> Json {
    Json::Obj(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

/// Number array from an f64 slice.
pub fn num_arr(values: &[f64]) -> Json {
    Json::Arr(values.iter().map(|&v| Json::Num(v)).collect())
}

/// Extract a `Vec<f64>` from a JSON array of numbers.
pub fn f64_vec(j: &Json) -> Result<Vec<f64>> {
    j.as_arr()?.iter().map(Json::as_f64).collect()
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> Error {
        Error::Parse(format!("json at byte {}: {msg}", self.pos))
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut arr = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(arr));
        }
        loop {
            arr.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(arr)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self
                                .bump()
                                .ok_or_else(|| self.err("bad \\u escape"))?;
                            let d = (c as char)
                                .to_digit(16)
                                .ok_or_else(|| self.err("bad hex digit"))?;
                            code = code * 16 + d;
                        }
                        out.push(
                            char::from_u32(code)
                                .ok_or_else(|| self.err("bad codepoint"))?,
                        );
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x20 => {
                    return Err(self.err("control char in string"))
                }
                Some(c) => {
                    // Re-assemble UTF-8 multibyte sequences.
                    if c < 0x80 {
                        out.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let len = if c >= 0xF0 {
                            4
                        } else if c >= 0xE0 {
                            3
                        } else {
                            2
                        };
                        if start + len > self.bytes.len() {
                            return Err(self.err("truncated utf-8"));
                        }
                        let s = std::str::from_utf8(&self.bytes[start..start + len])
                            .map_err(|_| self.err("invalid utf-8"))?;
                        out.push_str(s);
                        self.pos = start + len;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| self.err("bad number"))?;
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_like_document() {
        let text = r#"[
          {"name": "gauss_lpg", "kind": "logp_grad",
           "params": {"n": 512, "d": 2},
           "inputs": [{"name": "x", "shape": [512, 2], "dtype": "f32"}],
           "file": "gauss_lpg.hlo.txt"}
        ]"#;
        let v = Json::parse(text).unwrap();
        let arr = v.as_arr().unwrap();
        assert_eq!(arr.len(), 1);
        assert_eq!(arr[0].get("name").unwrap().as_str().unwrap(), "gauss_lpg");
        assert_eq!(
            arr[0].get("params").unwrap().get("n").unwrap().as_usize().unwrap(),
            512
        );
        let shape = arr[0].get("inputs").unwrap().as_arr().unwrap()[0]
            .get("shape")
            .unwrap()
            .as_arr()
            .unwrap();
        assert_eq!(shape[0].as_usize().unwrap(), 512);
    }

    #[test]
    fn scalar_values() {
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("-12.5e2").unwrap(), Json::Num(-1250.0));
        assert_eq!(
            Json::parse(r#""a\nbA""#).unwrap(),
            Json::Str("a\nbA".into())
        );
    }

    #[test]
    fn utf8_and_escapes() {
        let v = Json::parse(r#""héllo → ∞""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo → ∞");
    }

    #[test]
    fn rejects_malformed() {
        for bad in [
            "{", "[1,", "\"abc", "{\"a\" 1}", "01x", "[1 2]", "{} extra",
            "{\"a\":}",
        ] {
            assert!(Json::parse(bad).is_err(), "should reject {bad:?}");
        }
    }

    #[test]
    fn render_roundtrips_floats_bit_exactly() {
        for v in [
            0.1,
            1.0 / 3.0,
            -0.0,
            2.0,
            1e-300,
            -1.234_567_890_123_456_7e108,
            9.007_199_254_740_993e15, // 2^53 + 1-ish: forced to {:e}
            f64::MAX,
            f64::MIN_POSITIVE,
        ] {
            let text = Json::Num(v).render();
            let back = Json::parse(&text).unwrap().as_f64().unwrap();
            assert_eq!(v.to_bits(), back.to_bits(), "{v} → {text} → {back}");
        }
    }

    #[test]
    fn render_nonfinite_as_null() {
        assert_eq!(Json::Num(f64::NAN).render(), "null");
        assert_eq!(Json::Num(f64::INFINITY).render(), "null");
    }

    #[test]
    fn render_parse_roundtrip_structures() {
        let v = obj(vec![
            ("name", Json::Str("a\"b\\c\nd → ∞".into())),
            ("flag", Json::Bool(true)),
            ("nothing", Json::Null),
            ("xs", num_arr(&[1.0, 0.25, -3.5])),
            ("nested", obj(vec![("k", Json::Num(7.0))])),
        ]);
        let back = Json::parse(&v.render()).unwrap();
        assert_eq!(v, back);
        assert_eq!(f64_vec(back.get("xs").unwrap()).unwrap(), vec![
            1.0, 0.25, -3.5
        ]);
        assert!(back.get("flag").unwrap().as_bool().unwrap());
        assert!(f64_vec(back.get("name").unwrap()).is_err());
    }

    #[test]
    fn integer_valued_floats_render_plain() {
        assert_eq!(Json::Num(42.0).render(), "42");
        assert_eq!(Json::Num(-7.0).render(), "-7");
        assert_eq!(Json::parse("42").unwrap().as_usize().unwrap(), 42);
    }

    #[test]
    fn nested_structures() {
        let v = Json::parse(r#"{"a": [[1, 2], [3, 4]], "b": {"c": []}}"#)
            .unwrap();
        let a = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(a[1].as_arr().unwrap()[0].as_f64().unwrap(), 3.0);
        assert!(v.get("b").unwrap().get("c").unwrap().as_arr().unwrap().is_empty());
        assert!(v.get("zzz").is_err());
    }
}
