//! Minimal JSON parser — just enough for `artifacts/manifest.json`.
//!
//! No external crates are available offline, so this implements the JSON
//! grammar (RFC 8259 minus `\u` surrogate pairs beyond the BMP) in ~200
//! lines. Numbers parse as f64; integer access checks convertibility.

use crate::error::{Error, Result};
use std::collections::BTreeMap;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            other => Err(Error::Parse(format!("expected string, got {other:?}"))),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(v) => Ok(*v),
            other => Err(Error::Parse(format!("expected number, got {other:?}"))),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let v = self.as_f64()?;
        if v < 0.0 || v.fract() != 0.0 || v > usize::MAX as f64 {
            return Err(Error::Parse(format!("expected usize, got {v}")));
        }
        Ok(v as usize)
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(a) => Ok(a),
            other => Err(Error::Parse(format!("expected array, got {other:?}"))),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Ok(o),
            other => Err(Error::Parse(format!("expected object, got {other:?}"))),
        }
    }

    /// Object field access with a helpful error.
    pub fn get(&self, key: &str) -> Result<&Json> {
        self.as_obj()?
            .get(key)
            .ok_or_else(|| Error::Parse(format!("missing field '{key}'")))
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> Error {
        Error::Parse(format!("json at byte {}: {msg}", self.pos))
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut arr = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(arr));
        }
        loop {
            arr.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(arr)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self
                                .bump()
                                .ok_or_else(|| self.err("bad \\u escape"))?;
                            let d = (c as char)
                                .to_digit(16)
                                .ok_or_else(|| self.err("bad hex digit"))?;
                            code = code * 16 + d;
                        }
                        out.push(
                            char::from_u32(code)
                                .ok_or_else(|| self.err("bad codepoint"))?,
                        );
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x20 => {
                    return Err(self.err("control char in string"))
                }
                Some(c) => {
                    // Re-assemble UTF-8 multibyte sequences.
                    if c < 0x80 {
                        out.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let len = if c >= 0xF0 {
                            4
                        } else if c >= 0xE0 {
                            3
                        } else {
                            2
                        };
                        if start + len > self.bytes.len() {
                            return Err(self.err("truncated utf-8"));
                        }
                        let s = std::str::from_utf8(&self.bytes[start..start + len])
                            .map_err(|_| self.err("invalid utf-8"))?;
                        out.push_str(s);
                        self.pos = start + len;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| self.err("bad number"))?;
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_like_document() {
        let text = r#"[
          {"name": "gauss_lpg", "kind": "logp_grad",
           "params": {"n": 512, "d": 2},
           "inputs": [{"name": "x", "shape": [512, 2], "dtype": "f32"}],
           "file": "gauss_lpg.hlo.txt"}
        ]"#;
        let v = Json::parse(text).unwrap();
        let arr = v.as_arr().unwrap();
        assert_eq!(arr.len(), 1);
        assert_eq!(arr[0].get("name").unwrap().as_str().unwrap(), "gauss_lpg");
        assert_eq!(
            arr[0].get("params").unwrap().get("n").unwrap().as_usize().unwrap(),
            512
        );
        let shape = arr[0].get("inputs").unwrap().as_arr().unwrap()[0]
            .get("shape")
            .unwrap()
            .as_arr()
            .unwrap();
        assert_eq!(shape[0].as_usize().unwrap(), 512);
    }

    #[test]
    fn scalar_values() {
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("-12.5e2").unwrap(), Json::Num(-1250.0));
        assert_eq!(
            Json::parse(r#""a\nbA""#).unwrap(),
            Json::Str("a\nbA".into())
        );
    }

    #[test]
    fn utf8_and_escapes() {
        let v = Json::parse(r#""héllo → ∞""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo → ∞");
    }

    #[test]
    fn rejects_malformed() {
        for bad in [
            "{", "[1,", "\"abc", "{\"a\" 1}", "01x", "[1 2]", "{} extra",
            "{\"a\":}",
        ] {
            assert!(Json::parse(bad).is_err(), "should reject {bad:?}");
        }
    }

    #[test]
    fn nested_structures() {
        let v = Json::parse(r#"{"a": [[1, 2], [3, 4]], "b": {"c": []}}"#)
            .unwrap();
        let a = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(a[1].as_arr().unwrap()[0].as_f64().unwrap(), 3.0);
        assert!(v.get("b").unwrap().get("c").unwrap().as_arr().unwrap().is_empty());
        assert!(v.get("zzz").is_err());
    }
}
