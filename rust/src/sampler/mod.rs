//! The MCMC substrate each worker runs on its subposterior.
//!
//! The paper's criterion (3) is that *any* MCMC method may run on each
//! machine; this module provides four: random-walk Metropolis ([`Rwm`]),
//! Metropolis-adjusted Langevin ([`Mala`]), Hamiltonian Monte Carlo
//! ([`Hmc`]) with dual-averaging step-size adaptation, and No-U-Turn
//! ([`Nuts`]). All operate through [`crate::model::LogDensity`], so they
//! are oblivious to whether the density is evaluated natively or through
//! a PJRT-loaded artifact.

pub mod adapt;
pub mod chain;
pub mod gibbs;
pub mod hmc;
pub mod mala;
pub mod nuts;
pub mod rwm;

pub use chain::{Chain, ChainConfig};
pub use hmc::Hmc;
pub use mala::Mala;
pub use nuts::Nuts;
pub use rwm::Rwm;

use crate::model::LogDensity;
use crate::rng::Pcg64;

/// Mutable chain state threaded through sampler steps.
///
/// `grad` is kept current by gradient-based samplers (MALA/HMC/NUTS);
/// [`Rwm`] leaves it stale and only maintains `logp`.
#[derive(Debug, Clone)]
pub struct State {
    pub theta: Vec<f64>,
    pub logp: f64,
    pub grad: Vec<f64>,
}

impl State {
    /// Initialize from a starting point (one target evaluation).
    pub fn init(target: &dyn LogDensity, theta: Vec<f64>) -> Self {
        let (logp, grad) = target.logp_grad(&theta);
        State { theta, logp, grad }
    }
}

/// One-step transition kernel preserving the target.
pub trait Sampler: Send {
    fn name(&self) -> &'static str;

    /// Advance the state by one step. Returns whether the proposal was
    /// accepted. Implementations adapt internal tuning parameters while
    /// [`Sampler::adapting`] is true.
    fn step(
        &mut self,
        target: &dyn LogDensity,
        state: &mut State,
        rng: &mut Pcg64,
    ) -> bool;

    /// Freeze adaptation (called by the chain runner at burn-in end).
    fn finalize_adaptation(&mut self) {}

    /// Whether the sampler is still adapting.
    fn adapting(&self) -> bool {
        false
    }
}

/// Factory used by the coordinator to give each worker its own sampler.
#[derive(Debug, Clone)]
pub enum SamplerKind {
    Rwm { scale: f64 },
    Mala { step: f64 },
    Hmc { step: f64, n_leapfrog: usize },
    Nuts { step: f64, max_depth: usize },
}

impl SamplerKind {
    pub fn build(&self, dim: usize) -> Box<dyn Sampler> {
        match *self {
            SamplerKind::Rwm { scale } => Box::new(Rwm::new(scale, dim)),
            SamplerKind::Mala { step } => Box::new(Mala::new(step)),
            SamplerKind::Hmc { step, n_leapfrog } => {
                Box::new(Hmc::new(step, n_leapfrog))
            }
            SamplerKind::Nuts { step, max_depth } => {
                Box::new(Nuts::new(step, max_depth))
            }
        }
    }

    /// Sensible defaults for a given model dimension.
    pub fn default_hmc(dim: usize) -> SamplerKind {
        let _ = dim;
        SamplerKind::Hmc { step: 0.1, n_leapfrog: 10 }
    }
}
