//! Chain runner: burn-in, thinning, symmetry moves, timing telemetry.

use std::time::Instant;

use super::{Sampler, State};
use crate::model::LogDensity;
use crate::rng::Pcg64;
use crate::types::{SampleMatrix, SubposteriorSamples};

/// Configuration for one MCMC chain.
#[derive(Debug, Clone)]
pub struct ChainConfig {
    /// Post-burn-in draws to keep.
    pub n_samples: usize,
    /// Burn-in iterations (discarded; sampler adapts during these).
    pub burn_in: usize,
    /// Keep every `thin`-th draw.
    pub thin: usize,
}

impl ChainConfig {
    pub fn new(n_samples: usize) -> Self {
        // The paper's fixed rule: discard the first 1/6 of draws; we
        // default burn-in to n/5 (equivalent to 1/6 of the total run).
        ChainConfig { n_samples, burn_in: n_samples / 5, thin: 1 }
    }

    pub fn with_burn_in(mut self, burn_in: usize) -> Self {
        self.burn_in = burn_in;
        self
    }

    pub fn with_thin(mut self, thin: usize) -> Self {
        self.thin = thin.max(1);
        self
    }
}

/// A single MCMC chain over a target density.
pub struct Chain<'a> {
    pub target: &'a dyn LogDensity,
    pub sampler: Box<dyn Sampler>,
    pub config: ChainConfig,
}

impl<'a> Chain<'a> {
    pub fn new(
        target: &'a dyn LogDensity,
        sampler: Box<dyn Sampler>,
        config: ChainConfig,
    ) -> Self {
        Chain { target, sampler, config }
    }

    /// Run the chain to completion, returning post-burn-in draws with
    /// per-draw availability times (for the error-vs-time protocol).
    pub fn run(mut self, machine: usize, rng: &mut Pcg64) -> SubposteriorSamples {
        let start = Instant::now();
        let dim = self.target.dim();
        let mut state = State::init(self.target, self.target.init_point(rng));
        let total = self.config.burn_in
            + self.config.n_samples * self.config.thin;
        let mut samples =
            SampleMatrix::with_capacity(dim, self.config.n_samples);
        let mut draw_times = Vec::with_capacity(self.config.n_samples);
        let mut accepts = 0usize;
        let mut post_steps = 0usize;

        for i in 0..total {
            // Posterior-invariant symmetry move (label permutation for
            // mixtures) — the paper applies it before each MH step.
            self.target.symmetry_move(&mut state.theta, rng);
            let accepted = self.sampler.step(self.target, &mut state, rng);
            if i + 1 == self.config.burn_in {
                self.sampler.finalize_adaptation();
            }
            if i >= self.config.burn_in {
                post_steps += 1;
                if accepted {
                    accepts += 1;
                }
                if (i - self.config.burn_in) % self.config.thin == 0
                    && samples.len() < self.config.n_samples
                {
                    samples.push(&state.theta);
                    draw_times.push(start.elapsed().as_secs_f64());
                }
            }
        }

        SubposteriorSamples {
            machine,
            samples,
            accept_rate: if post_steps > 0 {
                accepts as f64 / post_steps as f64
            } else {
                f64::NAN
            },
            wall_secs: start.elapsed().as_secs_f64(),
            draw_times,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{GaussianMean, GmmMeans, LogDensity};
    use crate::sampler::{Hmc, Rwm};
    use crate::types::SampleMatrix;

    #[test]
    fn chain_produces_requested_draws() {
        let data = SampleMatrix::new(2);
        let target = GaussianMean::new(data, 1.0, 1.0, 1.0);
        let mut rng = Pcg64::seed_from(1);
        let chain = Chain::new(
            &target,
            Box::new(Hmc::new(0.2, 5)),
            ChainConfig::new(500).with_burn_in(100),
        );
        let out = chain.run(3, &mut rng);
        assert_eq!(out.samples.len(), 500);
        assert_eq!(out.machine, 3);
        assert_eq!(out.draw_times.len(), 500);
        assert!(out.wall_secs > 0.0);
        assert!(out.accept_rate > 0.2);
        // Times must be nondecreasing.
        assert!(out.draw_times.windows(2).all(|w| w[0] <= w[1]));
    }

    #[test]
    fn thinning_reduces_autocorrelation() {
        let data = SampleMatrix::new(1);
        let target = GaussianMean::new(data, 1.0, 1.0, 1.0);
        let mut rng = Pcg64::seed_from(2);
        let thin = Chain::new(
            &target,
            Box::new(Rwm::new(0.3, 1)),
            ChainConfig::new(2000).with_burn_in(500).with_thin(10),
        )
        .run(0, &mut rng);
        let mut rng2 = Pcg64::seed_from(2);
        let unthinned = Chain::new(
            &target,
            Box::new(Rwm::new(0.3, 1)),
            ChainConfig::new(2000).with_burn_in(500),
        )
        .run(0, &mut rng2);
        let rho_thin =
            crate::stats::diagnostics::autocorrelation(&thin.samples, 0, 1)[1];
        let rho_raw = crate::stats::diagnostics::autocorrelation(
            &unthinned.samples,
            0,
            1,
        )[1];
        assert!(rho_thin < rho_raw, "{rho_thin} vs {rho_raw}");
    }

    #[test]
    fn gmm_chain_visits_permutation_modes() {
        // 2-component GMM with well-separated means: with permutation
        // moves, the marginal of μ₀ must visit both modes.
        let mut rng = Pcg64::seed_from(3);
        let mut x = SampleMatrix::new(1);
        for i in 0..60 {
            let c = if i % 2 == 0 { -4.0 } else { 4.0 };
            x.push(&[c + 0.3 * rng.normal()]);
        }
        let target = GmmMeans::new(
            x,
            vec![-(2f64.ln()), -(2f64.ln())],
            1.0 / 0.09,
            0.05,
            1.0,
        );
        let chain = Chain::new(
            &target,
            Box::new(Rwm::new(0.5, target.dim())),
            ChainConfig::new(4000).with_burn_in(1000),
        );
        let out = chain.run(0, &mut rng);
        // μ₀ coordinate should have draws near both -4 and +4.
        let mu0: Vec<f64> = out.samples.rows().map(|r| r[0]).collect();
        let lows = mu0.iter().filter(|&&v| v < -2.0).count();
        let highs = mu0.iter().filter(|&&v| v > 2.0).count();
        assert!(
            lows > 100 && highs > 100,
            "modes not both visited: {lows} lows, {highs} highs"
        );
    }
}
