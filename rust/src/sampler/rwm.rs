//! Random-walk Metropolis with adaptive isotropic proposal scale.

use super::adapt::ScaleAdapter;
use super::{Sampler, State};
use crate::model::LogDensity;
use crate::rng::Pcg64;

/// Gaussian random-walk Metropolis.
pub struct Rwm {
    adapter: ScaleAdapter,
    /// Scratch proposal buffer (avoids per-step allocation).
    proposal: Vec<f64>,
}

impl Rwm {
    pub fn new(scale: f64, dim: usize) -> Self {
        // 2.38/√d is the classic optimal-scaling prefactor.
        let s = scale * 2.38 / (dim.max(1) as f64).sqrt();
        Rwm { adapter: ScaleAdapter::new(s, 0.234), proposal: vec![0.0; dim] }
    }
}

impl Sampler for Rwm {
    fn name(&self) -> &'static str {
        "rwm"
    }

    fn step(
        &mut self,
        target: &dyn LogDensity,
        state: &mut State,
        rng: &mut Pcg64,
    ) -> bool {
        let scale = self.adapter.scale();
        for (p, t) in self.proposal.iter_mut().zip(&state.theta) {
            *p = t + scale * rng.normal();
        }
        let logp_new = target.logp(&self.proposal);
        let accepted = (logp_new - state.logp) >= rng.uniform().ln();
        if accepted {
            state.theta.copy_from_slice(&self.proposal);
            state.logp = logp_new;
        }
        self.adapter.update(accepted);
        accepted
    }

    fn finalize_adaptation(&mut self) {
        self.adapter.freeze();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::math::linalg::Mat;
    use crate::math::mvn::Mvn;
    use crate::model::GaussianMean;
    use crate::types::SampleMatrix;

    /// RWM on a standard normal target recovers its moments.
    #[test]
    fn recovers_standard_normal() {
        // Zero-data Gaussian model: posterior == prior == N(0, I).
        let data = SampleMatrix::new(2);
        let target = GaussianMean::new(data, 1.0, 1.0, 1.0);
        let mut rng = Pcg64::seed_from(1);
        let mut state = State::init(&target, vec![0.0, 0.0]);
        let mut sampler = Rwm::new(1.0, 2);
        let mut draws = SampleMatrix::new(2);
        for i in 0..30_000 {
            sampler.step(&target, &mut state, &mut rng);
            if i == 2_000 {
                sampler.finalize_adaptation();
            }
            if i >= 2_000 {
                draws.push(&state.theta);
            }
        }
        let mean = draws.mean();
        let cov = draws.covariance();
        assert!(mean.iter().all(|m| m.abs() < 0.1), "mean {mean:?}");
        assert!((cov[(0, 0)] - 1.0).abs() < 0.2, "var {}", cov[(0, 0)]);
        assert!(cov[(0, 1)].abs() < 0.1);
        let _ = Mvn::new(vec![0.0; 2], Mat::identity(2)); // silence unused import warnings
    }

    #[test]
    fn acceptance_rate_reasonable_after_adaptation() {
        let data = SampleMatrix::new(3);
        let target = GaussianMean::new(data, 1.0, 1.0, 1.0);
        let mut rng = Pcg64::seed_from(2);
        let mut state = State::init(&target, vec![0.0; 3]);
        let mut sampler = Rwm::new(1.0, 3);
        for _ in 0..3_000 {
            sampler.step(&target, &mut state, &mut rng);
        }
        sampler.finalize_adaptation();
        let mut acc = 0usize;
        let total = 4_000;
        for _ in 0..total {
            if sampler.step(&target, &mut state, &mut rng) {
                acc += 1;
            }
        }
        let rate = acc as f64 / total as f64;
        assert!((0.1..0.5).contains(&rate), "rate {rate}");
    }
}
