//! No-U-Turn Sampler (Hoffman & Gelman 2014, Algorithm 6 — the
//! efficient formulation with multinomial-style slice sampling and
//! dual-averaging adaptation).
//!
//! The paper samples with Stan's NUTS; this is the equivalent substrate
//! so subposterior workers need no hand-tuned trajectory length.

use super::adapt::DualAveraging;
use super::{Sampler, State};
use crate::model::LogDensity;
use crate::rng::Pcg64;

const DELTA_MAX: f64 = 1000.0;

/// One endpoint of the NUTS trajectory tree.
#[derive(Clone)]
struct Endpoint {
    theta: Vec<f64>,
    p: Vec<f64>,
    grad: Vec<f64>,
}

struct BuildResult {
    minus: Endpoint,
    plus: Endpoint,
    /// Proposed state sampled uniformly from the valid subtree.
    proposal: Option<(Vec<f64>, f64, Vec<f64>)>,
    n_valid: f64,
    no_uturn: bool,
    /// Σ min(1, e^{ΔH}) and count, for dual averaging.
    alpha_sum: f64,
    n_alpha: f64,
}

/// No-U-Turn sampler.
pub struct Nuts {
    da: DualAveraging,
    pub max_depth: usize,
    /// Mean tree depth of the most recent steps (telemetry).
    last_depth: usize,
}

impl Nuts {
    pub fn new(step: f64, max_depth: usize) -> Self {
        Nuts { da: DualAveraging::new(step, 0.8), max_depth, last_depth: 0 }
    }

    pub fn eps(&self) -> f64 {
        self.da.eps()
    }

    pub fn last_depth(&self) -> usize {
        self.last_depth
    }

    fn leapfrog_one(
        target: &dyn LogDensity,
        end: &Endpoint,
        dir: f64,
        eps: f64,
    ) -> (Endpoint, f64) {
        let d = end.theta.len();
        let e = dir * eps;
        let mut p = end.p.clone();
        let mut theta = end.theta.clone();
        for i in 0..d {
            p[i] += 0.5 * e * end.grad[i];
        }
        for i in 0..d {
            theta[i] += e * p[i];
        }
        let (logp, grad) = target.logp_grad(&theta);
        for i in 0..d {
            p[i] += 0.5 * e * grad[i];
        }
        (Endpoint { theta, p, grad }, logp)
    }

    fn joint(logp: f64, p: &[f64]) -> f64 {
        logp - 0.5 * p.iter().map(|v| v * v).sum::<f64>()
    }

    fn uturn(minus: &Endpoint, plus: &Endpoint) -> bool {
        let d = minus.theta.len();
        let mut dot_minus = 0.0;
        let mut dot_plus = 0.0;
        for i in 0..d {
            let dt = plus.theta[i] - minus.theta[i];
            dot_minus += dt * minus.p[i];
            dot_plus += dt * plus.p[i];
        }
        dot_minus < 0.0 || dot_plus < 0.0
    }

    #[allow(clippy::too_many_arguments)]
    fn build_tree(
        target: &dyn LogDensity,
        end: &Endpoint,
        log_u: f64,
        dir: f64,
        depth: usize,
        eps: f64,
        h0: f64,
        rng: &mut Pcg64,
    ) -> BuildResult {
        if depth == 0 {
            let (e1, logp1) = Self::leapfrog_one(target, end, dir, eps);
            let joint = Self::joint(logp1, &e1.p);
            let n_valid = if log_u <= joint { 1.0 } else { 0.0 };
            let no_uturn = log_u < joint + DELTA_MAX;
            let alpha = (joint - h0).exp().min(1.0);
            let proposal = if n_valid > 0.0 {
                Some((e1.theta.clone(), logp1, e1.grad.clone()))
            } else {
                None
            };
            return BuildResult {
                minus: e1.clone(),
                plus: e1,
                proposal,
                n_valid,
                no_uturn,
                alpha_sum: if alpha.is_finite() { alpha } else { 0.0 },
                n_alpha: 1.0,
            };
        }
        // Recurse: build left half then extend.
        let mut first = Self::build_tree(
            target, end, log_u, dir, depth - 1, eps, h0, rng,
        );
        if !first.no_uturn {
            return first;
        }
        let from = if dir < 0.0 { first.minus.clone() } else { first.plus.clone() };
        let second = Self::build_tree(
            target, &from, log_u, dir, depth - 1, eps, h0, rng,
        );
        let n_total = first.n_valid + second.n_valid;
        // Uniform subtree proposal swap.
        if second.n_valid > 0.0
            && rng.uniform() < second.n_valid / n_total.max(1e-300)
        {
            if let Some(p) = second.proposal {
                first.proposal = Some(p);
            }
        }
        let (minus, plus) = if dir < 0.0 {
            (second.minus, first.plus.clone())
        } else {
            (first.minus.clone(), second.plus)
        };
        let no_uturn = second.no_uturn && !Self::uturn(&minus, &plus);
        BuildResult {
            minus,
            plus,
            proposal: first.proposal,
            n_valid: n_total,
            no_uturn,
            alpha_sum: first.alpha_sum + second.alpha_sum,
            n_alpha: first.n_alpha + second.n_alpha,
        }
    }
}

impl Sampler for Nuts {
    fn name(&self) -> &'static str {
        "nuts"
    }

    fn step(
        &mut self,
        target: &dyn LogDensity,
        state: &mut State,
        rng: &mut Pcg64,
    ) -> bool {
        let d = state.theta.len();
        let eps = self.da.eps();
        let mut p0 = vec![0.0; d];
        rng.fill_normal(&mut p0);
        let h0 = Self::joint(state.logp, &p0);
        // Slice variable: log u = h0 - Exp(1).
        let log_u = h0 - rng.exponential(1.0);

        let mut minus = Endpoint {
            theta: state.theta.clone(),
            p: p0.clone(),
            grad: state.grad.clone(),
        };
        let mut plus = minus.clone();
        let mut n_valid = 1.0f64;
        let mut accepted = false;
        let mut alpha_sum = 0.0;
        let mut n_alpha = 0.0;
        let mut depth = 0usize;

        while depth < self.max_depth {
            let dir = if rng.bernoulli(0.5) { 1.0 } else { -1.0 };
            let from = if dir < 0.0 { minus.clone() } else { plus.clone() };
            let result = Self::build_tree(
                target, &from, log_u, dir, depth, eps, h0, rng,
            );
            alpha_sum += result.alpha_sum;
            n_alpha += result.n_alpha;
            if dir < 0.0 {
                minus = result.minus;
            } else {
                plus = result.plus;
            }
            if !result.no_uturn {
                break;
            }
            if let Some((theta, logp, grad)) = result.proposal {
                if rng.uniform() < (result.n_valid / n_valid).min(1.0) {
                    state.theta = theta;
                    state.logp = logp;
                    state.grad = grad;
                    accepted = true;
                }
            }
            n_valid += result.n_valid;
            if Self::uturn(&minus, &plus) {
                depth += 1;
                break;
            }
            depth += 1;
        }
        self.last_depth = depth;
        let mean_alpha = if n_alpha > 0.0 { alpha_sum / n_alpha } else { 0.0 };
        self.da.update(mean_alpha);
        accepted
    }

    fn finalize_adaptation(&mut self) {
        self.da.freeze();
    }

    fn adapting(&self) -> bool {
        !self.da.frozen()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{GaussianMean, LinearRegression};
    use crate::types::SampleMatrix;

    #[test]
    fn recovers_standard_normal() {
        let data = SampleMatrix::new(2);
        let target = GaussianMean::new(data, 1.0, 1.0, 1.0); // N(0, I)
        let mut rng = Pcg64::seed_from(7);
        let mut state = State::init(&target, vec![3.0, -3.0]);
        let mut sampler = Nuts::new(0.2, 8);
        let mut draws = SampleMatrix::new(2);
        for i in 0..6_000 {
            sampler.step(&target, &mut state, &mut rng);
            if i == 1_000 {
                sampler.finalize_adaptation();
            }
            if i >= 1_000 {
                draws.push(&state.theta);
            }
        }
        let mean = draws.mean();
        let cov = draws.covariance();
        assert!(mean.iter().all(|m| m.abs() < 0.1), "mean {mean:?}");
        assert!((cov[(0, 0)] - 1.0).abs() < 0.2, "var {}", cov[(0, 0)]);
    }

    #[test]
    fn recovers_correlated_posterior() {
        // Linear regression posterior with correlated coordinates.
        let mut rng = Pcg64::seed_from(9);
        let mut x = SampleMatrix::new(2);
        let mut y = Vec::new();
        for _ in 0..100 {
            let a = rng.normal();
            let b = 0.9 * a + 0.3 * rng.normal(); // collinear design
            y.push(1.5 * a - 0.7 * b + 0.5 * rng.normal());
            x.push(&[a, b]);
        }
        let target = LinearRegression::new(x, y, 4.0, 1.0, 1.0);
        let exact = target.exact_posterior();
        let mut state = State::init(&target, vec![0.0, 0.0]);
        let mut sampler = Nuts::new(0.1, 10);
        let mut draws = SampleMatrix::new(2);
        for i in 0..8_000 {
            sampler.step(&target, &mut state, &mut rng);
            if i == 1_500 {
                sampler.finalize_adaptation();
            }
            if i >= 1_500 {
                draws.push(&state.theta);
            }
        }
        let mean = draws.mean();
        for j in 0..2 {
            assert!(
                (mean[j] - exact.mean()[j]).abs() < 0.08,
                "dim {j}: {} vs {}",
                mean[j],
                exact.mean()[j]
            );
        }
    }

    #[test]
    fn tree_depth_bounded() {
        let data = SampleMatrix::new(1);
        let target = GaussianMean::new(data, 1.0, 1.0, 1.0);
        let mut rng = Pcg64::seed_from(10);
        let mut state = State::init(&target, vec![0.0]);
        let mut sampler = Nuts::new(0.5, 4);
        for _ in 0..200 {
            sampler.step(&target, &mut state, &mut rng);
            assert!(sampler.last_depth() <= 4);
        }
    }
}
