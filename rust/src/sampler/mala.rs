//! Metropolis-adjusted Langevin algorithm (MALA).

use super::adapt::DualAveraging;
use super::{Sampler, State};
use crate::math::linalg;
use crate::model::LogDensity;
use crate::rng::Pcg64;

/// MALA: proposal `θ' = θ + (ε²/2)∇log p(θ) + ε ξ`, ξ ~ N(0, I), with the
/// exact MH correction including the asymmetric proposal densities.
pub struct Mala {
    da: DualAveraging,
}

impl Mala {
    pub fn new(step: f64) -> Self {
        // MALA's optimal acceptance rate is 0.574.
        Mala { da: DualAveraging::new(step, 0.574) }
    }

    /// log q(to | from) for the Langevin proposal.
    fn log_q(eps: f64, to: &[f64], from: &[f64], grad_from: &[f64]) -> f64 {
        let e2 = eps * eps;
        let mut sq = 0.0;
        for i in 0..to.len() {
            let mean = from[i] + 0.5 * e2 * grad_from[i];
            let r = to[i] - mean;
            sq += r * r;
        }
        -sq / (2.0 * e2)
    }
}

impl Sampler for Mala {
    fn name(&self) -> &'static str {
        "mala"
    }

    fn step(
        &mut self,
        target: &dyn LogDensity,
        state: &mut State,
        rng: &mut Pcg64,
    ) -> bool {
        let eps = self.da.eps();
        let e2 = eps * eps;
        let d = state.theta.len();
        let mut proposal = vec![0.0; d];
        for i in 0..d {
            proposal[i] =
                state.theta[i] + 0.5 * e2 * state.grad[i] + eps * rng.normal();
        }
        let (logp_new, grad_new) = target.logp_grad(&proposal);
        let log_alpha = logp_new - state.logp
            + Self::log_q(eps, &state.theta, &proposal, &grad_new)
            - Self::log_q(eps, &proposal, &state.theta, &state.grad);
        let accept_prob = log_alpha.exp().min(1.0);
        let accepted =
            logp_new.is_finite() && log_alpha >= rng.uniform().ln();
        if accepted {
            state.theta = proposal;
            state.logp = logp_new;
            state.grad = grad_new;
        }
        self.da.update(if accept_prob.is_finite() { accept_prob } else { 0.0 });
        let _ = linalg::dot(&state.theta, &state.theta); // keep import used
        accepted
    }

    fn finalize_adaptation(&mut self) {
        self.da.freeze();
    }

    fn adapting(&self) -> bool {
        !self.da.frozen()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::GaussianMean;
    use crate::types::SampleMatrix;

    #[test]
    fn recovers_gaussian_moments() {
        let data = SampleMatrix::new(2);
        let target = GaussianMean::new(data, 1.0, 1.0, 1.0); // N(0, I)
        let mut rng = Pcg64::seed_from(3);
        let mut state = State::init(&target, vec![1.0, -1.0]);
        let mut sampler = Mala::new(0.5);
        let mut draws = SampleMatrix::new(2);
        for i in 0..20_000 {
            sampler.step(&target, &mut state, &mut rng);
            if i == 2_000 {
                sampler.finalize_adaptation();
            }
            if i >= 2_000 {
                draws.push(&state.theta);
            }
        }
        let mean = draws.mean();
        let cov = draws.covariance();
        assert!(mean.iter().all(|m| m.abs() < 0.08), "mean {mean:?}");
        assert!((cov[(0, 0)] - 1.0).abs() < 0.15, "var {}", cov[(0, 0)]);
    }

    #[test]
    fn detailed_balance_on_symmetric_target() {
        // On a symmetric target started at the mode, the chain stays in
        // the typical set and acceptance stays high after adaptation.
        let data = SampleMatrix::new(1);
        let target = GaussianMean::new(data, 1.0, 4.0, 1.0);
        let mut rng = Pcg64::seed_from(4);
        let mut state = State::init(&target, vec![0.0]);
        let mut sampler = Mala::new(0.2);
        for _ in 0..2_000 {
            sampler.step(&target, &mut state, &mut rng);
        }
        sampler.finalize_adaptation();
        let mut acc = 0;
        for _ in 0..2_000 {
            if sampler.step(&target, &mut state, &mut rng) {
                acc += 1;
            }
        }
        let rate = acc as f64 / 2_000.0;
        assert!(rate > 0.4, "rate {rate}");
    }
}
