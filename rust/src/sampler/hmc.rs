//! Hamiltonian Monte Carlo with fixed trajectory length and
//! dual-averaging step-size adaptation.
//!
//! The leapfrog trajectory is delegated to
//! [`LogDensity::fused_trajectory`] when the backend provides one (the
//! PJRT runtime evaluates all `L` steps in a single artifact execution);
//! otherwise it falls back to `2L+1` native gradient evaluations.

use super::adapt::DualAveraging;
use super::{Sampler, State};
use crate::model::{LogDensity, Trajectory};
use crate::rng::Pcg64;

/// Fixed-length HMC.
pub struct Hmc {
    da: DualAveraging,
    pub n_leapfrog: usize,
    /// Unit-diagonal mass matrix (inverse mass per dimension), adapted
    /// from burn-in draw variances by the chain runner if desired.
    inv_mass: Option<Vec<f64>>,
}

impl Hmc {
    pub fn new(step: f64, n_leapfrog: usize) -> Self {
        assert!(n_leapfrog > 0);
        Hmc { da: DualAveraging::new(step, 0.65), n_leapfrog, inv_mass: None }
    }

    pub fn with_inv_mass(mut self, inv_mass: Vec<f64>) -> Self {
        self.inv_mass = Some(inv_mass);
        self
    }

    pub fn eps(&self) -> f64 {
        self.da.eps()
    }

    /// Native leapfrog fallback: mirrors
    /// `python/compile/model.py::leapfrog` exactly for unit mass;
    /// `inv_mass` scales the position update (dθ/dt = M⁻¹p).
    #[allow(clippy::too_many_arguments)]
    fn leapfrog(
        target: &dyn LogDensity,
        theta0: &[f64],
        p0: &[f64],
        grad0: &[f64],
        logp0: f64,
        eps: f64,
        n_steps: usize,
        inv_mass: Option<&[f64]>,
    ) -> Trajectory {
        let d = theta0.len();
        let mut theta = theta0.to_vec();
        let mut p = p0.to_vec();
        let mut grad = grad0.to_vec();
        let mut logp = logp0;
        for _ in 0..n_steps {
            for i in 0..d {
                p[i] += 0.5 * eps * grad[i];
            }
            match inv_mass {
                None => {
                    for i in 0..d {
                        theta[i] += eps * p[i];
                    }
                }
                Some(im) => {
                    for i in 0..d {
                        theta[i] += eps * im[i] * p[i];
                    }
                }
            }
            let (lp, g) = target.logp_grad(&theta);
            logp = lp;
            grad = g;
            for i in 0..d {
                p[i] += 0.5 * eps * grad[i];
            }
        }
        Trajectory { theta, p, logp, grad, logp0 }
    }

    fn kinetic(&self, p: &[f64]) -> f64 {
        match &self.inv_mass {
            None => 0.5 * p.iter().map(|v| v * v).sum::<f64>(),
            Some(im) => {
                0.5 * p.iter().zip(im).map(|(v, m)| v * v * m).sum::<f64>()
            }
        }
    }
}

impl Sampler for Hmc {
    fn name(&self) -> &'static str {
        "hmc"
    }

    fn step(
        &mut self,
        target: &dyn LogDensity,
        state: &mut State,
        rng: &mut Pcg64,
    ) -> bool {
        let d = state.theta.len();
        let eps = self.da.eps();
        // Momentum refresh: p ~ N(0, M) with M = diag(1/inv_mass).
        let mut p = vec![0.0; d];
        match &self.inv_mass {
            None => rng.fill_normal(&mut p),
            Some(im) => {
                for (pi, m) in p.iter_mut().zip(im) {
                    *pi = rng.normal() / m.sqrt().max(1e-12);
                }
            }
        }
        let k0 = self.kinetic(&p);
        // The fused artifact integrates with unit mass; only use it when
        // no mass matrix is configured.
        let fused = if self.inv_mass.is_none() {
            target.fused_trajectory(&state.theta, &p, eps, self.n_leapfrog)
        } else {
            None
        };
        let traj = fused.unwrap_or_else(|| {
            Self::leapfrog(
                target,
                &state.theta,
                &p,
                &state.grad,
                state.logp,
                eps,
                self.n_leapfrog,
                self.inv_mass.as_deref(),
            )
        });
        let k1 = self.kinetic(&traj.p);
        let log_alpha = traj.logp - k1 - (state.logp - k0);
        let accept_prob = if log_alpha.is_finite() {
            log_alpha.exp().min(1.0)
        } else {
            0.0
        };
        let accepted =
            traj.logp.is_finite() && log_alpha >= rng.uniform().ln();
        if accepted {
            state.theta = traj.theta;
            state.logp = traj.logp;
            state.grad = traj.grad;
        }
        self.da.update(accept_prob);
        accepted
    }

    fn finalize_adaptation(&mut self) {
        self.da.freeze();
    }

    fn adapting(&self) -> bool {
        !self.da.frozen()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::GaussianMean;
    use crate::types::SampleMatrix;

    fn run_on_gaussian(seed: u64, n_iter: usize) -> SampleMatrix {
        let data = SampleMatrix::new(2);
        let target = GaussianMean::new(data, 1.0, 1.0, 1.0); // N(0, I)
        let mut rng = Pcg64::seed_from(seed);
        let mut state = State::init(&target, vec![2.0, -2.0]);
        let mut sampler = Hmc::new(0.2, 8);
        let mut draws = SampleMatrix::new(2);
        for i in 0..n_iter {
            sampler.step(&target, &mut state, &mut rng);
            if i == n_iter / 5 {
                sampler.finalize_adaptation();
            }
            if i >= n_iter / 5 {
                draws.push(&state.theta);
            }
        }
        draws
    }

    #[test]
    fn recovers_standard_normal() {
        let draws = run_on_gaussian(5, 8_000);
        let mean = draws.mean();
        let cov = draws.covariance();
        assert!(mean.iter().all(|m| m.abs() < 0.08), "mean {mean:?}");
        assert!((cov[(0, 0)] - 1.0).abs() < 0.15, "var00 {}", cov[(0, 0)]);
        assert!((cov[(1, 1)] - 1.0).abs() < 0.15, "var11 {}", cov[(1, 1)]);
    }

    #[test]
    fn high_acceptance_after_adaptation() {
        let data = SampleMatrix::new(3);
        let target = GaussianMean::new(data, 1.0, 1.0, 1.0);
        let mut rng = Pcg64::seed_from(6);
        let mut state = State::init(&target, vec![0.0; 3]);
        let mut sampler = Hmc::new(0.3, 10);
        for _ in 0..1_500 {
            sampler.step(&target, &mut state, &mut rng);
        }
        sampler.finalize_adaptation();
        let mut acc = 0;
        for _ in 0..1_500 {
            if sampler.step(&target, &mut state, &mut rng) {
                acc += 1;
            }
        }
        let rate = acc as f64 / 1_500.0;
        assert!(rate > 0.5, "rate {rate}");
    }

    #[test]
    fn leapfrog_matches_energy_conservation() {
        let data = SampleMatrix::new(2);
        let target = GaussianMean::new(data, 1.0, 1.0, 1.0);
        let theta = vec![1.0, 0.5];
        let p = vec![0.2, -0.4];
        let (lp, g) = target.logp_grad(&theta);
        let traj = Hmc::leapfrog(&target, &theta, &p, &g, lp, 0.01, 100, None);
        let h0 = -lp + 0.5 * (0.2f64 * 0.2 + 0.4 * 0.4);
        let h1 = -traj.logp
            + 0.5 * traj.p.iter().map(|v| v * v).sum::<f64>();
        assert!((h1 - h0).abs() < 1e-4, "ΔH = {}", (h1 - h0).abs());
    }

    #[test]
    fn diag_mass_matrix_still_correct() {
        let data = SampleMatrix::new(2);
        let target = GaussianMean::new(data, 1.0, 1.0, 1.0);
        let mut rng = Pcg64::seed_from(8);
        let mut state = State::init(&target, vec![0.0, 0.0]);
        let mut sampler = Hmc::new(0.2, 8).with_inv_mass(vec![0.5, 2.0]);
        let mut draws = SampleMatrix::new(2);
        for i in 0..10_000 {
            sampler.step(&target, &mut state, &mut rng);
            if i == 2_000 {
                sampler.finalize_adaptation();
            }
            if i >= 2_000 {
                draws.push(&state.theta);
            }
        }
        let cov = draws.covariance();
        assert!((cov[(0, 0)] - 1.0).abs() < 0.2, "var00 {}", cov[(0, 0)]);
        assert!((cov[(1, 1)] - 1.0).abs() < 0.2, "var11 {}", cov[(1, 1)]);
    }
}
