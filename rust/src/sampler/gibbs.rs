//! Blocked Gibbs sampler for the latent Poisson-gamma model — the
//! "any MCMC method per machine" demonstration (paper criterion 3).
//!
//! Alternates (i) the conjugate latent update
//! `q_i | a, b ~ Gamma(a + x_i, b + t_i)` with (ii) a random-walk MH
//! step on the hyperparameters `(log a, log b) | q`. Only the 2-d
//! hyperparameter block is emitted to the coordinator; the `n` latents
//! never leave the machine.

use crate::model::poisson_gamma_latent::PoissonGammaLatent;
use crate::rng::Pcg64;
use crate::sampler::adapt::ScaleAdapter;
use crate::types::{SampleMatrix, SubposteriorSamples};
use std::time::Instant;

/// Gibbs chain over a [`PoissonGammaLatent`] subposterior.
pub struct PgGibbs<'a> {
    model: &'a PoissonGammaLatent,
    adapter: ScaleAdapter,
    /// MH sub-steps on the hyperparameters per latent sweep.
    pub hyper_steps: usize,
}

impl<'a> PgGibbs<'a> {
    pub fn new(model: &'a PoissonGammaLatent) -> Self {
        PgGibbs {
            model,
            adapter: ScaleAdapter::new(0.2, 0.35),
            hyper_steps: 3,
        }
    }

    /// Run the chain: `n_samples` post-burn-in draws of (log a, log b).
    pub fn run(
        mut self,
        machine: usize,
        n_samples: usize,
        burn_in: usize,
        rng: &mut Pcg64,
    ) -> SubposteriorSamples {
        let start = Instant::now();
        let (mut log_a, mut log_b, mut q) = self.model.init(rng);
        let mut logp;
        let mut samples = SampleMatrix::with_capacity(2, n_samples);
        let mut draw_times = Vec::with_capacity(n_samples);
        let mut accepts = 0usize;
        let mut proposals = 0usize;
        let total = burn_in + n_samples;
        for i in 0..total {
            // (i) conjugate latent sweep — changes the conditional, so
            // refresh the cached hyper log-density.
            self.model.resample_latents(log_a, log_b, &mut q, rng);
            logp = self.model.hyper_logp(log_a, log_b, &q);
            // (ii) MH on (log a, log b).
            for _ in 0..self.hyper_steps {
                let s = self.adapter.scale();
                let prop_a = log_a + s * rng.normal();
                let prop_b = log_b + s * rng.normal();
                let lp_new = self.model.hyper_logp(prop_a, prop_b, &q);
                let accepted = (lp_new - logp) >= rng.uniform().ln();
                if accepted {
                    log_a = prop_a;
                    log_b = prop_b;
                    logp = lp_new;
                }
                self.adapter.update(accepted);
                if i >= burn_in {
                    proposals += 1;
                    accepts += usize::from(accepted);
                }
            }
            if i + 1 == burn_in {
                self.adapter.freeze();
            }
            if i >= burn_in {
                samples.push(&[log_a, log_b]);
                draw_times.push(start.elapsed().as_secs_f64());
            }
        }
        SubposteriorSamples {
            machine,
            samples,
            accept_rate: if proposals > 0 {
                accepts as f64 / proposals as f64
            } else {
                f64::NAN
            },
            wall_secs: start.elapsed().as_secs_f64(),
            draw_times,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{LogDensity, PoissonGamma};

    fn toy(seed: u64, n: usize, prior_w: f64) -> PoissonGammaLatent {
        let mut rng = Pcg64::seed_from(seed);
        let (a, b) = (2.0, 1.5);
        let mut xs = Vec::new();
        let mut ts = Vec::new();
        for _ in 0..n {
            let t = 0.5 + rng.uniform();
            let qv = rng.gamma(a, b);
            xs.push(rng.poisson(qv * t) as f64);
            ts.push(t);
        }
        PoissonGammaLatent::new(xs, ts, prior_w, 1.0, 2.0, 1.0)
    }

    #[test]
    fn gibbs_recovers_hyperparameters() {
        let m = toy(1, 4_000, 1.0);
        let mut rng = Pcg64::seed_from(2);
        let out = PgGibbs::new(&m).run(0, 2_500, 500, &mut rng);
        let mean = out.samples.mean();
        assert!((mean[0] - 2.0f64.ln()).abs() < 0.3, "log a {}", mean[0]);
        assert!((mean[1] - 1.5f64.ln()).abs() < 0.4, "log b {}", mean[1]);
        assert!(out.accept_rate > 0.05 && out.accept_rate < 0.95);
    }

    /// Gibbs (latent) and HMC (marginalized) target the same marginal:
    /// their posterior means must agree.
    #[test]
    fn gibbs_matches_marginalized_hmc() {
        let m_lat = toy(3, 3_000, 0.5);
        let m_marg = PoissonGamma::new(
            m_lat.xs.clone(),
            m_lat.ts.clone(),
            0.5,
            1.0,
            2.0,
            1.0,
        );
        let mut rng = Pcg64::seed_from(4);
        let gibbs = PgGibbs::new(&m_lat).run(0, 2_500, 500, &mut rng);

        let mut rng2 = Pcg64::seed_from(5);
        let mut state = crate::sampler::State::init(
            &m_marg,
            m_marg.init_point(&mut rng2),
        );
        let mut hmc = crate::sampler::Hmc::new(0.02, 10);
        use crate::sampler::Sampler;
        let mut draws = SampleMatrix::new(2);
        for i in 0..3_000 {
            hmc.step(&m_marg, &mut state, &mut rng2);
            if i == 500 {
                hmc.finalize_adaptation();
            }
            if i >= 500 {
                draws.push(&state.theta);
            }
        }
        let mg = gibbs.samples.mean();
        let mh = draws.mean();
        for j in 0..2 {
            assert!(
                (mg[j] - mh[j]).abs() < 0.15,
                "dim {j}: gibbs {} vs hmc {}",
                mg[j],
                mh[j]
            );
        }
    }

    /// Gibbs subposterior draws combine like any other sampler's
    /// (criterion 3 end-to-end).
    #[test]
    fn gibbs_subposteriors_combine() {
        let mut subs = Vec::new();
        let full = toy(7, 3_000, 1.0);
        for mach in 0..3usize {
            let lo = mach * 1_000;
            let shard = PoissonGammaLatent::new(
                full.xs[lo..lo + 1_000].to_vec(),
                full.ts[lo..lo + 1_000].to_vec(),
                1.0 / 3.0,
                1.0,
                2.0,
                1.0,
            );
            let mut rng = Pcg64::seed_from(10 + mach as u64);
            subs.push(PgGibbs::new(&shard).run(mach, 1_500, 300, &mut rng));
        }
        let combined = crate::combine::combine(
            crate::combine::CombineMethod::Semiparametric,
            &subs,
            1_500,
            9,
        )
        .unwrap();
        let mean = combined.mean();
        assert!((mean[0] - 2.0f64.ln()).abs() < 0.35, "log a {}", mean[0]);
        assert!((mean[1] - 1.5f64.ln()).abs() < 0.45, "log b {}", mean[1]);
    }
}
