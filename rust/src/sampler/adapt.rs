//! Step-size adaptation: Nesterov dual averaging (Hoffman & Gelman 2014,
//! Algorithm 5) plus a simple Robbins-Monro scale adapter for RWM.

/// Dual-averaging adaptation of a log step size toward a target
/// acceptance statistic.
#[derive(Debug, Clone)]
pub struct DualAveraging {
    mu: f64,
    log_eps: f64,
    log_eps_bar: f64,
    h_bar: f64,
    t: f64,
    gamma: f64,
    t0: f64,
    kappa: f64,
    target_accept: f64,
    frozen: bool,
}

impl DualAveraging {
    pub fn new(eps0: f64, target_accept: f64) -> Self {
        assert!(eps0 > 0.0);
        DualAveraging {
            mu: (10.0 * eps0).ln(),
            log_eps: eps0.ln(),
            log_eps_bar: 0.0,
            h_bar: 0.0,
            t: 0.0,
            gamma: 0.05,
            t0: 10.0,
            kappa: 0.75,
            target_accept,
            frozen: false,
        }
    }

    /// Current step size.
    pub fn eps(&self) -> f64 {
        if self.frozen {
            self.log_eps_bar.exp()
        } else {
            self.log_eps.exp()
        }
    }

    /// Fold in an observed acceptance probability.
    pub fn update(&mut self, accept_prob: f64) {
        if self.frozen {
            return;
        }
        self.t += 1.0;
        let eta = 1.0 / (self.t + self.t0);
        self.h_bar = (1.0 - eta) * self.h_bar
            + eta * (self.target_accept - accept_prob);
        self.log_eps = self.mu - self.t.sqrt() / self.gamma * self.h_bar;
        let w = self.t.powf(-self.kappa);
        self.log_eps_bar = w * self.log_eps + (1.0 - w) * self.log_eps_bar;
    }

    /// Switch to the averaged step size permanently.
    pub fn freeze(&mut self) {
        self.frozen = true;
    }

    pub fn frozen(&self) -> bool {
        self.frozen
    }
}

/// Robbins-Monro proposal-scale adapter for random-walk Metropolis,
/// targeting the classic 0.234 acceptance rate.
#[derive(Debug, Clone)]
pub struct ScaleAdapter {
    log_scale: f64,
    t: f64,
    target: f64,
    frozen: bool,
}

impl ScaleAdapter {
    pub fn new(scale0: f64, target: f64) -> Self {
        assert!(scale0 > 0.0);
        ScaleAdapter { log_scale: scale0.ln(), t: 0.0, target, frozen: false }
    }

    pub fn scale(&self) -> f64 {
        self.log_scale.exp()
    }

    pub fn update(&mut self, accepted: bool) {
        if self.frozen {
            return;
        }
        self.t += 1.0;
        let step = 1.0 / self.t.powf(0.6).max(1.0);
        let a = if accepted { 1.0 } else { 0.0 };
        self.log_scale += step * (a - self.target);
    }

    pub fn freeze(&mut self) {
        self.frozen = true;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dual_averaging_raises_eps_when_accepting() {
        let mut da = DualAveraging::new(0.1, 0.65);
        for _ in 0..200 {
            da.update(1.0); // always accepting → step too small
        }
        assert!(da.eps() > 0.1, "eps {}", da.eps());
    }

    #[test]
    fn dual_averaging_lowers_eps_when_rejecting() {
        let mut da = DualAveraging::new(0.1, 0.65);
        for _ in 0..200 {
            da.update(0.0);
        }
        assert!(da.eps() < 0.1, "eps {}", da.eps());
    }

    #[test]
    fn freeze_stops_updates() {
        let mut da = DualAveraging::new(0.1, 0.65);
        for _ in 0..50 {
            da.update(0.9);
        }
        da.freeze();
        let e = da.eps();
        for _ in 0..50 {
            da.update(0.0);
        }
        assert_eq!(da.eps(), e);
    }

    #[test]
    fn scale_adapter_converges_direction() {
        let mut sa = ScaleAdapter::new(1.0, 0.234);
        for _ in 0..300 {
            sa.update(true); // always accepted → scale should grow
        }
        assert!(sa.scale() > 1.0);
        let mut sb = ScaleAdapter::new(1.0, 0.234);
        for _ in 0..300 {
            sb.update(false);
        }
        assert!(sb.scale() < 1.0);
    }
}
