//! Semiparametric combination (paper section 3.3).
//!
//! Each subposterior is estimated by the Hjort-Glad product of a
//! parametric start `N(μ̂_m, Σ̂_m)` and a nonparametric correction. The
//! density product is then a mixture of `T^M` Gaussians with components
//! `N(μ_t, Σ_t)`,
//!
//!   Σ_t = (M/h² I + Σ̂_M⁻¹)⁻¹,
//!   μ_t = Σ_t (M/h² θ̄_t + Σ̂_M⁻¹ μ̂_M),
//!
//! and unnormalized weights
//!
//!   W_t = w_t · N(θ̄_t | μ̂_M, Σ̂_M + (h²/M) I) / Π_m N(θ^m_{t_m} | μ̂_m, Σ̂_m),
//!
//! sampled with the same IMG scheme as Algorithm 1. The second variant
//! ([`semiparametric_nw`]) keeps the nonparametric weights `w_t` (higher
//! IMG acceptance) but draws from the semiparametric components; it
//! tends to the nonparametric procedure as h → 0 and is likewise
//! asymptotically exact.
//!
//! The per-machine parametric log-densities `log N(θ^m_t | μ̂_m, Σ̂_m)`
//! are precomputed once (O(TMd²)), so an IMG proposal costs O(d) for the
//! `w` part + O(1) for the denominator + O(d²) for the numerator term.

use super::gaussian_product::{fit_and_product, GaussianEstimate};
use crate::error::Result;
use crate::math::linalg::{self, Mat};
use crate::math::mvn::Mvn;
use crate::rng::Pcg64;
use crate::stats::kde::annealed_bandwidth;
use crate::types::SampleMatrix;

/// Draw `t_out` samples from the semiparametric density-product estimate
/// (full weights `W_t`).
pub fn semiparametric(
    sets: &[&SampleMatrix],
    t_out: usize,
    seed: u64,
) -> Result<SampleMatrix> {
    run_semiparametric(sets, t_out, seed, true)
}

/// Variant 2: nonparametric weights `w_t`, semiparametric components.
pub fn semiparametric_nw(
    sets: &[&SampleMatrix],
    t_out: usize,
    seed: u64,
) -> Result<SampleMatrix> {
    run_semiparametric(sets, t_out, seed, false)
}

fn run_semiparametric(
    sets: &[&SampleMatrix],
    t_out: usize,
    seed: u64,
    full_weights: bool,
) -> Result<SampleMatrix> {
    // Whitened coordinates (bandwidth relative to subposterior scale;
    // see super::whitening_scales). The estimator is equivariant under
    // this diagonal map, including its parametric factor.
    let scales = super::whitening_scales(sets);
    let whitened = super::whiten(sets, &scales);
    let sets_w: Vec<&SampleMatrix> = whitened.iter().collect();
    let sets = &sets_w[..];
    let mut rng = Pcg64::seed_from(seed);
    let m_count = sets.len();
    let m = m_count as f64;
    let dim = sets[0].dim();

    // Parametric fits + product Gaussian N(μ̂_M, Σ̂_M).
    let (estimates, _product) = fit_and_product(sets)?;
    let mut prec_sum = Mat::zeros(dim, dim);
    for est in &estimates {
        prec_sum = prec_sum.add(&est.prec)?;
    }
    let cov_m = linalg::spd_inverse_jittered(&prec_sum)?; // Σ̂_M
    let mu_m = cov_m.matvec(&{
        let mut acc = vec![0.0; dim];
        for est in &estimates {
            let pm = est.prec.matvec(&est.mean)?;
            for j in 0..dim {
                acc[j] += pm[j];
            }
        }
        acc
    })?; // μ̂_M
    let prec_mu = prec_sum.matvec(&mu_m)?; // Σ̂_M⁻¹ μ̂_M

    // Precompute log N(θ^m_t | μ̂_m, Σ̂_m) per machine per draw.
    let param_lp: Vec<Vec<f64>> = sets
        .iter()
        .zip(&estimates)
        .map(|(s, est)| {
            let mvn = est.mvn()?;
            Ok(s.rows().map(|r| mvn.logpdf(r)).collect())
        })
        .collect::<Result<_>>()?;

    // Squared norms for the O(d) w_t updates (as in Algorithm 1).
    let norms: Vec<Vec<f64>> = sets
        .iter()
        .map(|s| s.rows().map(|r| r.iter().map(|v| v * v).sum()).collect())
        .collect();

    // IMG state (initialized per restart chunk below).
    let mut indices: Vec<usize> = vec![0; sets.len()];
    let mut sum = vec![0.0; dim];
    let mut sq_sum;
    let mut lp_denom; // Σ_m log N(θ^m | μ̂_m, Σ̂_m)

    let scatter = |sq: f64, s: &[f64]| -> f64 {
        let s2: f64 = s.iter().map(|v| v * v).sum();
        (sq - s2 / m).max(0.0)
    };

    let mut out = SampleMatrix::with_capacity(dim, t_out);
    let mut theta_bar = vec![0.0; dim];
    // Restart schedule mirroring Img::run_restarts: geometric chunks
    // with fresh t· and per-chunk warmup, bounding the annealed index
    // chain's freeze while keeping asymptotic exactness.
    let mut chunk = 500usize.clamp(1, t_out.max(1));
    let sweeps = 3usize;
    'outer: loop {
        let n = chunk.min(t_out - out.len());
        let warmup = n / 5;
        // Fresh t· for this chunk.
        for (mach, s) in sets.iter().enumerate() {
            indices[mach] = rng.uniform_usize(s.len());
        }
        sum.iter_mut().for_each(|v| *v = 0.0);
        sq_sum = 0.0;
        lp_denom = 0.0;
        for (mach, s) in sets.iter().enumerate() {
            for (j, v) in s.row(indices[mach]).iter().enumerate() {
                sum[j] += v;
            }
            sq_sum += norms[mach][indices[mach]];
            lp_denom += param_lp[mach][indices[mach]];
        }
    for i in 1..=(n + warmup) {
        let h = annealed_bandwidth(i, dim);
        let h2 = h * h;

        // Per-iteration factorizations (h is fixed within the sweep):
        // numerator Gaussian N(· | μ̂_M, Σ̂_M + h²/M I) and component
        // covariance Σ_t = (M/h² I + Σ̂_M⁻¹)⁻¹.
        let mut num_cov = cov_m.clone();
        for j in 0..dim {
            num_cov[(j, j)] += h2 / m;
        }
        let num_mvn = Mvn::new(mu_m.clone(), num_cov)?;
        let mut comp_prec = prec_sum.clone();
        for j in 0..dim {
            comp_prec[(j, j)] += m / h2;
        }
        let comp_cov = linalg::spd_inverse_jittered(&comp_prec)?;

        let mut d_cur = scatter(sq_sum, &sum);
        for j in 0..dim {
            theta_bar[j] = sum[j] / m;
        }
        // Current total log weight pieces.
        let mut log_num_cur = if full_weights {
            num_mvn.logpdf(&theta_bar)
        } else {
            0.0
        };

        for mach_sweep in 0..(m_count * sweeps) {
            let mach = mach_sweep % m_count;
            let set = sets[mach];
            let old_idx = indices[mach];
            let new_idx = rng.uniform_usize(set.len());
            if new_idx == old_idx {
                continue;
            }
            let old_row = set.row(old_idx);
            let new_row = set.row(new_idx);
            let mut s2_new = 0.0;
            for j in 0..dim {
                let sj = sum[j] - old_row[j] + new_row[j];
                s2_new += sj * sj;
            }
            let q_new =
                sq_sum - norms[mach][old_idx] + norms[mach][new_idx];
            let d_new = (q_new - s2_new / m).max(0.0);
            // log w ratio (nonparametric part).
            let mut log_ratio = -(d_new - d_cur) / (2.0 * h2);
            let mut log_num_new = 0.0;
            if full_weights {
                // Numerator: N(θ̄_c | μ̂_M, Σ̂_M + h²/M I).
                let mut bar_new = vec![0.0; dim];
                for j in 0..dim {
                    bar_new[j] = (sum[j] - old_row[j] + new_row[j]) / m;
                }
                log_num_new = num_mvn.logpdf(&bar_new);
                log_ratio += log_num_new - log_num_cur;
                // Denominator (inverted): - [lp(new) - lp(old)].
                log_ratio -=
                    param_lp[mach][new_idx] - param_lp[mach][old_idx];
            }
            if log_ratio >= 0.0 || rng.uniform().ln() < log_ratio {
                for j in 0..dim {
                    sum[j] += new_row[j] - old_row[j];
                }
                sq_sum = q_new;
                lp_denom +=
                    param_lp[mach][new_idx] - param_lp[mach][old_idx];
                indices[mach] = new_idx;
                d_cur = d_new;
                if full_weights {
                    log_num_cur = log_num_new;
                }
            }
        }

        // Draw θ_i ~ N(μ_t, Σ_t) for the current component.
        for j in 0..dim {
            theta_bar[j] = sum[j] / m;
        }
        let mut mean_vec = vec![0.0; dim];
        for j in 0..dim {
            mean_vec[j] = m / h2 * theta_bar[j] + prec_mu[j];
        }
        let comp_mean = comp_cov.matvec(&mean_vec)?;
        let comp = Mvn::new(comp_mean, comp_cov.clone())?;
        if i > warmup {
            out.push(&comp.sample(&mut rng));
        } else {
            // Keep the RNG stream advancing uniformly through warmup.
            let _ = comp.sample(&mut rng);
        }
    }
        if out.len() >= t_out {
            break 'outer;
        }
        chunk = chunk.saturating_mul(2);
    }
    let _ = lp_denom; // maintained for clarity; ratio uses increments
    let _: &[GaussianEstimate] = &estimates;
    super::unwhiten(&mut out, &scales);
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::math::linalg::Mat;

    fn gaussian_sets(
        seed: u64,
        mus: &[Vec<f64>],
        var: f64,
        t: usize,
    ) -> Vec<SampleMatrix> {
        let mut rng = Pcg64::seed_from(seed);
        mus.iter()
            .map(|mu| {
                Mvn::new(mu.clone(), Mat::scaled_identity(mu.len(), var))
                    .unwrap()
                    .sample_n(t, &mut rng)
            })
            .collect()
    }

    #[test]
    fn recovers_gaussian_product() {
        let mus = vec![vec![0.5, -0.5], vec![1.0, 0.0], vec![1.5, 0.5]];
        let sets = gaussian_sets(1, &mus, 1.0, 6000);
        let refs: Vec<&SampleMatrix> = sets.iter().collect();
        let out =
            semiparametric(&refs, 6000, 2).unwrap().split_off_burnin(1500);
        let mean = out.mean();
        assert!((mean[0] - 1.0).abs() < 0.15, "mean0 {}", mean[0]);
        assert!((mean[1] - 0.0).abs() < 0.15, "mean1 {}", mean[1]);
        let v = out.covariance()[(0, 0)];
        assert!((v - 1.0 / 3.0).abs() < 0.15, "var {v}");
    }

    #[test]
    fn nw_variant_recovers_gaussian_product() {
        let mus = vec![vec![0.8], vec![1.2]];
        let sets = gaussian_sets(3, &mus, 1.0, 3000);
        let refs: Vec<&SampleMatrix> = sets.iter().collect();
        let out = semiparametric_nw(&refs, 3000, 4).unwrap();
        // IMG autocorrelation: cross-seed sd of this mean ≈ 0.05.
        assert!((out.mean()[0] - 1.0).abs() < 0.15, "{}", out.mean()[0]);
        let v = out.covariance()[(0, 0)];
        assert!((v - 0.5).abs() < 0.15, "var {v}");
    }

    #[test]
    fn single_machine_reproduces_input_moments() {
        let sets = gaussian_sets(5, &[vec![-1.5, 2.0]], 2.0, 5000);
        let refs: Vec<&SampleMatrix> = sets.iter().collect();
        let out = semiparametric(&refs, 5000, 6).unwrap();
        let mean = out.mean();
        assert!((mean[0] + 1.5).abs() < 0.1, "{:?}", mean);
        assert!((mean[1] - 2.0).abs() < 0.1, "{:?}", mean);
        let c = out.covariance();
        assert!((c[(0, 0)] - 2.0).abs() < 0.25, "var {}", c[(0, 0)]);
    }

    #[test]
    fn both_variants_agree_on_gaussian_targets() {
        let mus = vec![vec![0.0, 1.0], vec![0.4, 0.6]];
        let sets = gaussian_sets(7, &mus, 1.0, 4000);
        let refs: Vec<&SampleMatrix> = sets.iter().collect();
        let a = semiparametric(&refs, 5000, 8).unwrap().split_off_burnin(1000);
        let b =
            semiparametric_nw(&refs, 5000, 8).unwrap().split_off_burnin(1000);
        for j in 0..2 {
            assert!(
                (a.mean()[j] - b.mean()[j]).abs() < 0.2,
                "dim {j}: {} vs {}",
                a.mean()[j],
                b.mean()[j]
            );
        }
    }
}
