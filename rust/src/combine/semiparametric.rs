//! Semiparametric combination (paper section 3.3).
//!
//! Each subposterior is estimated by the Hjort-Glad product of a
//! parametric start `N(μ̂_m, Σ̂_m)` and a nonparametric correction. The
//! density product is then a mixture of `T^M` Gaussians with components
//! `N(μ_t, Σ_t)`,
//!
//!   Σ_t = (M/h² I + Σ̂_M⁻¹)⁻¹,
//!   μ_t = Σ_t (M/h² θ̄_t + Σ̂_M⁻¹ μ̂_M),
//!
//! and unnormalized weights
//!
//!   W_t = w_t · N(θ̄_t | μ̂_M, Σ̂_M + (h²/M) I) / Π_m N(θ^m_{t_m} | μ̂_m, Σ̂_m),
//!
//! sampled with the same IMG scheme as Algorithm 1. The second variant
//! ([`semiparametric_nw`]) keeps the nonparametric weights `w_t` (higher
//! IMG acceptance) but draws from the semiparametric components; it
//! tends to the nonparametric procedure as h → 0 and is likewise
//! asymptotically exact.
//!
//! ## Setup and runtime parallelism
//!
//! The per-machine parametric log-densities `log N(θ^m_t | μ̂_m, Σ̂_m)`
//! are precomputed once — this O(TMd²) table is the single most
//! expensive setup step and fans out trivially one machine per task, as
//! do the per-machine Gaussian fits and the whitening/norm caches
//! ([`super::CombineContext`]). The restart chunks of the IMG chain are
//! then independent chains with split RNG streams, exactly as in
//! [`super::nonparametric`]: shared read-only state by borrow,
//! byte-identical output for a fixed seed at any thread count. An IMG
//! proposal costs O(d) for the `w` part + O(1) for the denominator +
//! O(d²) for the numerator term, with zero heap allocation.
//!
//! ## Annealed-schedule factorization cache
//!
//! The bandwidth schedule `h_i = i^{-1/(4+d)}` depends only on the
//! local iteration index, so the per-iteration dense factorizations —
//! the numerator Gaussian `N(μ̂_M, Σ̂_M + h²/M I)` (Cholesky) and the
//! component covariance `Σ_t = (M/h² I + Σ̂_M⁻¹)⁻¹` (inverse +
//! Cholesky) — are identical across every restart chain. The
//! [`AnnealCache`] computes them once per combine call, in parallel
//! across iteration indices, and every chain reads them back as O(d²)
//! lookups; without the cache each chain paid O(d³) plus several d×d
//! heap allocations per iteration. The cache is a pure function of the
//! iteration index and the Gaussian product pieces, so cached and
//! uncached runs ([`semiparametric_threaded_uncached`]) are
//! byte-identical; a memory budget caps the number of cached
//! iterations, and iterations past the cap transparently fall back to
//! the same per-iteration computation.

use std::sync::Arc;

use super::gaussian_product::GaussianEstimate;
use super::CombineContext;
use crate::error::Result;
use crate::kernel::{default_kernel, CombineKernel};
use crate::math::linalg::{self, Mat};
use crate::math::mvn::{self, Mvn};
use crate::rng::Pcg64;
use crate::stats::kde::AnnealSchedule;
use crate::types::SampleMatrix;

/// Default memory budget for the [`AnnealCache`], in bytes. Each cached
/// iteration holds two (three with full weights) d×d matrices, so the
/// budget caps the cache at `budget / (≈3·8·d²)` iterations; chains
/// longer than that recompute the tail iterations in place, exactly as
/// the uncached path does. Overridable per run via the
/// `combine_cache_budget_mb` config key / CLI flag (ROADMAP rung (b):
/// d ≳ 100 workloads want a bigger budget, memory-tight leaders a
/// smaller one; output is byte-identical at any value).
pub const DEFAULT_ANNEAL_CACHE_BUDGET: usize = 256 << 20;

/// Draw `t_out` samples from the semiparametric density-product estimate
/// (full weights `W_t`) on a single thread.
pub fn semiparametric(
    sets: &[&SampleMatrix],
    t_out: usize,
    seed: u64,
) -> Result<SampleMatrix> {
    semiparametric_with(
        sets,
        t_out,
        seed,
        true,
        1,
        Some(DEFAULT_ANNEAL_CACHE_BUDGET),
        &default_kernel(),
    )
}

/// [`semiparametric`] with setup and restart chains fanned across
/// `threads` workers (`0` = all cores). Deterministic at any count.
pub fn semiparametric_threaded(
    sets: &[&SampleMatrix],
    t_out: usize,
    seed: u64,
    threads: usize,
) -> Result<SampleMatrix> {
    semiparametric_threaded_budgeted(
        sets,
        t_out,
        seed,
        threads,
        DEFAULT_ANNEAL_CACHE_BUDGET,
    )
}

/// [`semiparametric_threaded`] with an explicit [`AnnealCache`] memory
/// budget in bytes. Byte-identical to the default-budget (and the
/// uncached) path at any value — a tiny budget only shrinks the cache
/// and recomputes the tail in place.
pub fn semiparametric_threaded_budgeted(
    sets: &[&SampleMatrix],
    t_out: usize,
    seed: u64,
    threads: usize,
    cache_budget_bytes: usize,
) -> Result<SampleMatrix> {
    semiparametric_with(
        sets,
        t_out,
        seed,
        true,
        threads,
        Some(cache_budget_bytes),
        &default_kernel(),
    )
}

/// [`semiparametric_threaded`] with the annealed factorization cache
/// disabled: every restart chain recomputes the per-iteration
/// factorizations, exactly as the pre-cache implementation did.
/// Byte-identical to the cached path for a fixed seed — kept as the
/// perf baseline for `benches/micro_hotpath.rs` and as the reference
/// in the cache regression tests.
pub fn semiparametric_threaded_uncached(
    sets: &[&SampleMatrix],
    t_out: usize,
    seed: u64,
    threads: usize,
) -> Result<SampleMatrix> {
    semiparametric_with(
        sets,
        t_out,
        seed,
        true,
        threads,
        None,
        &default_kernel(),
    )
}

/// Variant 2: nonparametric weights `w_t`, semiparametric components.
pub fn semiparametric_nw(
    sets: &[&SampleMatrix],
    t_out: usize,
    seed: u64,
) -> Result<SampleMatrix> {
    semiparametric_with(
        sets,
        t_out,
        seed,
        false,
        1,
        Some(DEFAULT_ANNEAL_CACHE_BUDGET),
        &default_kernel(),
    )
}

/// [`semiparametric_nw`] with a combine-stage thread count.
pub fn semiparametric_nw_threaded(
    sets: &[&SampleMatrix],
    t_out: usize,
    seed: u64,
    threads: usize,
) -> Result<SampleMatrix> {
    semiparametric_nw_threaded_budgeted(
        sets,
        t_out,
        seed,
        threads,
        DEFAULT_ANNEAL_CACHE_BUDGET,
    )
}

/// [`semiparametric_nw_threaded`] with an explicit cache budget — see
/// [`semiparametric_threaded_budgeted`].
pub fn semiparametric_nw_threaded_budgeted(
    sets: &[&SampleMatrix],
    t_out: usize,
    seed: u64,
    threads: usize,
    cache_budget_bytes: usize,
) -> Result<SampleMatrix> {
    semiparametric_with(
        sets,
        t_out,
        seed,
        false,
        threads,
        Some(cache_budget_bytes),
        &default_kernel(),
    )
}

/// [`semiparametric_nw_threaded`] without the factorization cache —
/// see [`semiparametric_threaded_uncached`].
pub fn semiparametric_nw_threaded_uncached(
    sets: &[&SampleMatrix],
    t_out: usize,
    seed: u64,
    threads: usize,
) -> Result<SampleMatrix> {
    semiparametric_with(
        sets,
        t_out,
        seed,
        false,
        threads,
        None,
        &default_kernel(),
    )
}

/// Read-only state shared by every restart chain of one combine call.
struct SemiShared<'a> {
    ctx: &'a CombineContext,
    /// log N(θ^m_t | μ̂_m, Σ̂_m) per machine per draw (O(TMd²) table).
    param_lp: Vec<Vec<f64>>,
    /// Σ̂_M.
    cov_m: Mat,
    /// μ̂_M.
    mu_m: Vec<f64>,
    /// Σ̂_M⁻¹ μ̂_M.
    prec_mu: Vec<f64>,
    /// Σ̂_M⁻¹ = Σ_m Σ̂_m⁻¹.
    prec_sum: Mat,
    /// Tabulated `h_i` schedule (ROADMAP rung (c)): one `powf` series
    /// per combine call, shared by every chain, bit-identical to
    /// computing `annealed_bandwidth` inline.
    schedule: AnnealSchedule,
    full_weights: bool,
}

/// Factorizations for one annealed iteration `i` — everything in the
/// per-iteration prologue and draw of [`run_chain`] that depends only
/// on `h_i` and the shared Gaussian product pieces, never on chain
/// state.
#[derive(Debug)]
pub(crate) struct IterFactors {
    /// Numerator Gaussian `N(· | μ̂_M, Σ̂_M + h²/M I)`, pre-factored.
    /// `None` for the nonparametric-weight variant, which never
    /// evaluates it (the pre-cache code built it anyway — pure waste).
    num_mvn: Option<Mvn>,
    /// Component covariance `Σ_t = (M/h² I + Σ̂_M⁻¹)⁻¹`.
    comp_cov: Mat,
    /// Lower Cholesky factor of `Σ_t` (via [`mvn::covariance_cholesky`],
    /// i.e. exactly the factor `Mvn::new` would compute per draw).
    comp_chol: Mat,
}

/// Compute [`IterFactors`] for bandwidth `h` (iteration `i`'s schedule
/// value) — the single copy of the per-iteration arithmetic, used both
/// to build the [`AnnealCache`] and as the in-place fallback for
/// uncached runs or iterations past the cache's memory budget.
/// Bit-identical either way: same diagonal bumps, same jittered
/// inverse (through the run's [`CombineKernel`], whose CPU backends
/// are bit-identical by contract), same covariance Cholesky the
/// pre-cache `Mvn::new` calls performed.
fn iter_factors(
    cov_m: &Mat,
    prec_sum: &Mat,
    mu_m: &[f64],
    m: f64,
    full_weights: bool,
    h: f64,
    kernel: &dyn CombineKernel,
) -> Result<IterFactors> {
    let h2 = h * h;
    // Numerator Gaussian N(· | μ̂_M, Σ̂_M + h²/M I).
    let num_mvn = if full_weights {
        let mut num_cov = cov_m.clone();
        num_cov.add_diagonal(h2 / m);
        Some(Mvn::new(mu_m.to_vec(), num_cov)?)
    } else {
        None
    };
    // Component covariance Σ_t = (M/h² I + Σ̂_M⁻¹)⁻¹, inverted in place
    // on the selected backend (ROADMAP rung (d): the blocked kernel
    // batches the column solves).
    let mut comp_cov = prec_sum.clone();
    comp_cov.add_diagonal(m / h2);
    kernel.spd_inverse_in_place(&mut comp_cov)?;
    let comp_chol = mvn::covariance_cholesky(comp_cov.clone())?;
    Ok(IterFactors { num_mvn, comp_cov, comp_chol })
}

/// Shared per-iteration factorization table over the annealed bandwidth
/// schedule (see the module docs). Built once per combine call — in
/// parallel across iteration indices, under the combine-stage thread
/// count — then installed into the [`CombineContext`] and read by every
/// restart chain.
#[derive(Debug)]
pub struct AnnealCache {
    /// Slot `i - 1` holds iteration `i`'s factorizations.
    factors: Vec<IterFactors>,
    full_weights: bool,
}

impl AnnealCache {
    /// Factor the first `iters` iterations of the annealed schedule,
    /// truncated to `budget_bytes` of cached matrices, fanning the
    /// per-iteration O(d³) work across `threads` workers on the
    /// selected kernel backend.
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn build(
        cov_m: &Mat,
        prec_sum: &Mat,
        mu_m: &[f64],
        m: f64,
        full_weights: bool,
        iters: usize,
        budget_bytes: usize,
        threads: usize,
        schedule: &AnnealSchedule,
        kernel: &dyn CombineKernel,
    ) -> Result<AnnealCache> {
        let dim = mu_m.len();
        let mats = if full_weights { 3 } else { 2 };
        let per_entry =
            (mats * dim * dim + 2 * dim) * std::mem::size_of::<f64>();
        let n = iters.min((budget_bytes / per_entry.max(1)).max(1));
        let factors = super::par_map_indexed(n, threads, |k| {
            iter_factors(
                cov_m,
                prec_sum,
                mu_m,
                m,
                full_weights,
                schedule.h(k + 1),
                kernel,
            )
        })
        .into_iter()
        .collect::<Result<_>>()?;
        Ok(AnnealCache { factors, full_weights })
    }

    /// Cached factorizations for iteration `i` (1-based), or `None`
    /// past the budget cap — callers fall back to [`iter_factors`].
    pub(crate) fn entry(&self, i: usize) -> Option<&IterFactors> {
        self.factors.get(i.wrapping_sub(1))
    }

    /// Number of cached iterations.
    pub fn len(&self) -> usize {
        self.factors.len()
    }

    pub fn is_empty(&self) -> bool {
        self.factors.is_empty()
    }

    /// Whether the cache carries the full-weight numerator Gaussians.
    pub fn full_weights(&self) -> bool {
        self.full_weights
    }
}

/// The full semiparametric driver, parameterized over the compute
/// kernel backend — every public entry point above delegates here with
/// the reference kernel; the combine dispatch
/// ([`super::combine_sets_with`]) passes the configured one. CPU
/// backends are bit-identical, so the kernel choice never changes the
/// retained draws (`rust/tests/kernel_parity.rs`).
#[allow(clippy::too_many_arguments)]
pub(crate) fn semiparametric_with(
    sets: &[&SampleMatrix],
    t_out: usize,
    seed: u64,
    full_weights: bool,
    threads: usize,
    cache_budget: Option<usize>,
    kernel: &Arc<dyn CombineKernel>,
) -> Result<SampleMatrix> {
    // Whitened coordinates (bandwidth relative to subposterior scale;
    // see super::whitening_scales). The estimator is equivariant under
    // this diagonal map, including its parametric factor.
    super::validate_sets(sets)?;
    let threads = super::resolve_threads(threads);
    let ctx =
        CombineContext::prepare_with(sets, threads, Arc::clone(kernel))?;
    semiparametric_with_context(
        ctx,
        t_out,
        seed,
        full_weights,
        threads,
        cache_budget,
    )
}

/// Everything after whitening: the context-driven driver, shared by the
/// dense path above and the store-backed path
/// ([`super::combine_stores_with`], whose contexts come from
/// [`CombineContext::prepare_from_stores`]). Takes the context by value
/// — it installs the annealed factorization cache before the chains fan
/// out — and runs every dense op on the context's kernel backend. The
/// fits, product pieces and log-density table all read the *whitened*
/// sets, so a context is the complete input state.
pub(crate) fn semiparametric_with_context(
    mut ctx: CombineContext,
    t_out: usize,
    seed: u64,
    full_weights: bool,
    threads: usize,
    cache_budget: Option<usize>,
) -> Result<SampleMatrix> {
    let threads = super::resolve_threads(threads);
    let dim = ctx.dim();
    let m_count = ctx.machines();

    // Parametric fits N(μ̂_m, Σ̂_m) — O(Td²) per machine, one task each.
    let estimates: Vec<GaussianEstimate> =
        super::par_map_indexed(m_count, threads, |m| {
            GaussianEstimate::fit(&ctx.sets()[m])
        })
        .into_iter()
        .collect::<Result<_>>()?;

    // Product Gaussian N(μ̂_M, Σ̂_M) pieces (small, sequential).
    let mut prec_sum = Mat::zeros(dim, dim);
    for est in &estimates {
        prec_sum.add_assign(&est.prec)?;
    }
    let cov_m = linalg::spd_inverse_jittered(&prec_sum)?; // Σ̂_M
    let mut acc = vec![0.0; dim];
    for est in &estimates {
        let pm = est.prec.matvec(&est.mean)?;
        for j in 0..dim {
            acc[j] += pm[j];
        }
    }
    let mu_m = cov_m.matvec(&acc)?; // μ̂_M
    let prec_mu = prec_sum.matvec(&mu_m)?; // Σ̂_M⁻¹ μ̂_M

    // The O(TMd²) parametric log-density table — the single most
    // expensive setup step — one machine per task, each column streamed
    // chunk-at-a-time through the selected kernel backend
    // ([`CombineKernel::logpdf_table_block`]; bit-identical to the
    // whole-set op at any chunk width by the block-boundary contract).
    let param_lp: Vec<Vec<f64>> =
        super::par_map_indexed(m_count, threads, |m| -> Result<Vec<f64>> {
            let mvn = estimates[m].mvn()?;
            let set = &ctx.sets()[m];
            let mut col = Vec::with_capacity(set.len());
            for block in
                set.rows_chunked(crate::data::store::DEFAULT_CHUNK_ROWS)
            {
                ctx.kernel().logpdf_table_block(&mvn, block, &mut col)?;
            }
            Ok(col)
        })
        .into_iter()
        .collect::<Result<_>>()?;

    // Shared h_i table: long enough for the longest restart chain, so
    // every chain (and the cache build) reads its bandwidth as a
    // lookup instead of a powf.
    let schedule = AnnealSchedule::new(
        dim,
        super::max_chain_len(t_out, super::RESTART_CHUNK0),
    );

    // Annealed-schedule factorization cache: one entry per iteration of
    // the longest restart chain, built in parallel, shared read-only by
    // every chain. `None` budget = the uncached reference path.
    if let Some(budget) = cache_budget {
        let iters = super::max_chain_len(t_out, super::RESTART_CHUNK0);
        let cache = AnnealCache::build(
            &cov_m,
            &prec_sum,
            &mu_m,
            m_count as f64,
            full_weights,
            iters,
            budget,
            threads,
            &schedule,
            ctx.kernel(),
        )?;
        ctx.install_anneal_cache(cache);
    }

    let shared = SemiShared {
        ctx: &ctx,
        param_lp,
        cov_m,
        mu_m,
        prec_mu,
        prec_sum,
        schedule,
        full_weights,
    };

    // Independent restart chains with split RNG streams — the same
    // schedule, and the same single copy of the orchestration
    // (`super::run_restart_chains`), as the nonparametric combiner.
    let mut out = super::run_restart_chains(
        dim,
        t_out,
        super::RESTART_CHUNK0,
        seed,
        threads,
        |keep, warmup, rng| run_chain(&shared, keep, warmup, rng),
    )?;
    super::unwhiten(&mut out, ctx.scales());
    Ok(out)
}

/// One restart chain: `keep + warmup` annealed IMG iterations over the
/// shared state, first `warmup` draws discarded. All per-proposal work
/// runs on reused scratch buffers — no heap traffic in the inner loop —
/// and the per-iteration dense factorizations come from the shared
/// [`AnnealCache`] as O(d²) lookups (recomputed in place only on an
/// uncached run or past the cache's memory budget).
fn run_chain(
    sh: &SemiShared<'_>,
    keep: usize,
    warmup: usize,
    mut rng: Pcg64,
) -> Result<SampleMatrix> {
    let dim = sh.ctx.dim();
    let m_count = sh.ctx.machines();
    let m = m_count as f64;
    let sets = sh.ctx.sets();
    let norms = sh.ctx.norms();
    let sweeps = super::RESTART_SWEEPS;
    let cache = sh.ctx.anneal_cache();
    if let Some(c) = cache {
        debug_assert_eq!(
            c.full_weights(),
            sh.full_weights,
            "anneal cache variant mismatch"
        );
    }

    // IMG state.
    let mut indices: Vec<usize> = vec![0; m_count];
    let mut sum = vec![0.0; dim];
    let mut sq_sum = 0.0;
    // Scratch buffers reused across all proposals and draws.
    let mut theta_bar = vec![0.0; dim];
    let mut bar_new = vec![0.0; dim];
    let mut mean_vec = vec![0.0; dim];
    let mut lp_scratch = vec![0.0; dim];
    let mut comp_mean = vec![0.0; dim];
    let mut z_scratch = vec![0.0; dim];
    let mut draw = vec![0.0; dim];

    // Fresh t· for this chain.
    for (mach, s) in sets.iter().enumerate() {
        indices[mach] = rng.uniform_usize(s.len());
    }
    for (mach, s) in sets.iter().enumerate() {
        for (j, v) in s.row(indices[mach]).iter().enumerate() {
            sum[j] += v;
        }
        sq_sum += norms[mach][indices[mach]];
    }

    let mut out = SampleMatrix::with_capacity(dim, keep);
    for i in 1..=(keep + warmup) {
        // Shared schedule table: bit-identical to the inline powf.
        let h = sh.schedule.h(i);
        let h2 = h * h;

        // Per-iteration factorizations (h is fixed within the sweep):
        // cache hit → O(d²) of lookups; miss → the pre-cache O(d³)
        // computation, bit-identical (single copy in `iter_factors`,
        // on the context's kernel backend).
        let mut fresh = None;
        let factors: &IterFactors = match cache.and_then(|c| c.entry(i)) {
            Some(f) => f,
            None => fresh.insert(iter_factors(
                &sh.cov_m,
                &sh.prec_sum,
                &sh.mu_m,
                m,
                sh.full_weights,
                h,
                sh.ctx.kernel(),
            )?),
        };
        // `full_weights` ⟺ the numerator Gaussian was built.
        let num_mvn = factors.num_mvn.as_ref();

        let mut d_cur = super::scatter(sq_sum, &sum, m);
        for j in 0..dim {
            theta_bar[j] = sum[j] / m;
        }
        // Current total log weight pieces.
        let mut log_num_cur = match num_mvn {
            Some(nm) => nm.logpdf_with(&theta_bar, &mut lp_scratch),
            None => 0.0,
        };

        for mach_sweep in 0..(m_count * sweeps) {
            let mach = mach_sweep % m_count;
            let set = &sets[mach];
            let old_idx = indices[mach];
            let new_idx = rng.uniform_usize(set.len());
            if new_idx == old_idx {
                continue;
            }
            let old_row = set.row(old_idx);
            let new_row = set.row(new_idx);
            let mut s2_new = 0.0;
            for j in 0..dim {
                let sj = sum[j] - old_row[j] + new_row[j];
                s2_new += sj * sj;
            }
            let q_new = sq_sum - norms[mach][old_idx] + norms[mach][new_idx];
            let d_new = (q_new - s2_new / m).max(0.0);
            // log w ratio (nonparametric part).
            let mut log_ratio = -(d_new - d_cur) / (2.0 * h2);
            let mut log_num_new = 0.0;
            if let Some(nm) = num_mvn {
                // Numerator: N(θ̄_c | μ̂_M, Σ̂_M + h²/M I).
                for j in 0..dim {
                    bar_new[j] = (sum[j] - old_row[j] + new_row[j]) / m;
                }
                log_num_new = nm.logpdf_with(&bar_new, &mut lp_scratch);
                log_ratio += log_num_new - log_num_cur;
                // Denominator (inverted): - [lp(new) - lp(old)].
                log_ratio -=
                    sh.param_lp[mach][new_idx] - sh.param_lp[mach][old_idx];
            }
            if log_ratio >= 0.0 || rng.uniform().ln() < log_ratio {
                for j in 0..dim {
                    sum[j] += new_row[j] - old_row[j];
                }
                sq_sum = q_new;
                indices[mach] = new_idx;
                d_cur = d_new;
                if num_mvn.is_some() {
                    log_num_cur = log_num_new;
                }
            }
        }

        // Draw θ_i ~ N(μ_t, Σ_t) for the current component, through the
        // pre-factored Σ_t Cholesky — allocation-free, and during
        // warmup the RNG stream still advances uniformly (same d
        // normals as an emitted draw).
        for j in 0..dim {
            mean_vec[j] = m / h2 * (sum[j] / m) + sh.prec_mu[j];
        }
        factors.comp_cov.matvec_into(&mean_vec, &mut comp_mean)?;
        mvn::chol_sample_into(
            &comp_mean,
            &factors.comp_chol,
            &mut rng,
            &mut z_scratch,
            &mut draw,
        );
        if i > warmup {
            out.push(&draw);
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::math::linalg::Mat;

    fn gaussian_sets(
        seed: u64,
        mus: &[Vec<f64>],
        var: f64,
        t: usize,
    ) -> Vec<SampleMatrix> {
        let mut rng = Pcg64::seed_from(seed);
        mus.iter()
            .map(|mu| {
                Mvn::new(mu.clone(), Mat::scaled_identity(mu.len(), var))
                    .unwrap()
                    .sample_n(t, &mut rng)
            })
            .collect()
    }

    #[test]
    fn recovers_gaussian_product() {
        let mus = vec![vec![0.5, -0.5], vec![1.0, 0.0], vec![1.5, 0.5]];
        let sets = gaussian_sets(1, &mus, 1.0, 6000);
        let refs: Vec<&SampleMatrix> = sets.iter().collect();
        let out =
            semiparametric(&refs, 6000, 2).unwrap().split_off_burnin(1500);
        let mean = out.mean();
        assert!((mean[0] - 1.0).abs() < 0.15, "mean0 {}", mean[0]);
        assert!((mean[1] - 0.0).abs() < 0.15, "mean1 {}", mean[1]);
        let v = out.covariance()[(0, 0)];
        assert!((v - 1.0 / 3.0).abs() < 0.15, "var {v}");
    }

    #[test]
    fn nw_variant_recovers_gaussian_product() {
        let mus = vec![vec![0.8], vec![1.2]];
        let sets = gaussian_sets(3, &mus, 1.0, 3000);
        let refs: Vec<&SampleMatrix> = sets.iter().collect();
        let out = semiparametric_nw(&refs, 3000, 4).unwrap();
        // IMG autocorrelation: cross-seed sd of this mean ≈ 0.05.
        assert!((out.mean()[0] - 1.0).abs() < 0.15, "{}", out.mean()[0]);
        let v = out.covariance()[(0, 0)];
        assert!((v - 0.5).abs() < 0.15, "var {v}");
    }

    #[test]
    fn single_machine_reproduces_input_moments() {
        let sets = gaussian_sets(5, &[vec![-1.5, 2.0]], 2.0, 5000);
        let refs: Vec<&SampleMatrix> = sets.iter().collect();
        let out = semiparametric(&refs, 5000, 6).unwrap();
        let mean = out.mean();
        assert!((mean[0] + 1.5).abs() < 0.1, "{:?}", mean);
        assert!((mean[1] - 2.0).abs() < 0.1, "{:?}", mean);
        let c = out.covariance();
        assert!((c[(0, 0)] - 2.0).abs() < 0.25, "var {}", c[(0, 0)]);
    }

    #[test]
    fn both_variants_agree_on_gaussian_targets() {
        let mus = vec![vec![0.0, 1.0], vec![0.4, 0.6]];
        let sets = gaussian_sets(7, &mus, 1.0, 4000);
        let refs: Vec<&SampleMatrix> = sets.iter().collect();
        let a = semiparametric(&refs, 5000, 8).unwrap().split_off_burnin(1000);
        let b =
            semiparametric_nw(&refs, 5000, 8).unwrap().split_off_burnin(1000);
        for j in 0..2 {
            assert!(
                (a.mean()[j] - b.mean()[j]).abs() < 0.2,
                "dim {j}: {} vs {}",
                a.mean()[j],
                b.mean()[j]
            );
        }
    }

    /// Cached and uncached paths are byte-identical — the cache only
    /// moves the per-iteration factorizations, never changes them.
    #[test]
    fn cache_matches_uncached_reference() {
        let mus = vec![vec![0.3, -0.1, 0.2], vec![0.7, 0.1, 0.4]];
        let sets = gaussian_sets(31, &mus, 1.0, 300);
        let refs: Vec<&SampleMatrix> = sets.iter().collect();
        let cached = semiparametric_threaded(&refs, 900, 5, 2).unwrap();
        let uncached =
            semiparametric_threaded_uncached(&refs, 900, 5, 2).unwrap();
        assert_eq!(cached.as_slice(), uncached.as_slice());
        let cached_nw = semiparametric_nw_threaded(&refs, 900, 5, 2).unwrap();
        let uncached_nw =
            semiparametric_nw_threaded_uncached(&refs, 900, 5, 2).unwrap();
        assert_eq!(cached_nw.as_slice(), uncached_nw.as_slice());
    }

    /// A cache capped far below the chain length (1-entry budget) falls
    /// back to in-place recomputation past the cap with identical
    /// output — the budget is a memory knob, never a result knob.
    #[test]
    fn tiny_cache_budget_falls_back_identically() {
        let mus = vec![vec![0.2, -0.2], vec![0.5, 0.1]];
        let sets = gaussian_sets(33, &mus, 1.0, 250);
        let refs: Vec<&SampleMatrix> = sets.iter().collect();
        let k = default_kernel();
        let full = semiparametric_with(
            &refs,
            800,
            9,
            true,
            2,
            Some(usize::MAX),
            &k,
        )
        .unwrap();
        let tiny =
            semiparametric_with(&refs, 800, 9, true, 2, Some(1), &k)
                .unwrap();
        let none =
            semiparametric_with(&refs, 800, 9, true, 2, None, &k).unwrap();
        assert_eq!(full.as_slice(), tiny.as_slice());
        assert_eq!(full.as_slice(), none.as_slice());
    }

    /// Budget arithmetic: the cache covers the longest chain when the
    /// budget allows, truncates (but stays non-empty) when it doesn't,
    /// and skips the numerator Gaussian for the nw variant.
    #[test]
    fn cache_build_respects_budget_and_variant() {
        let iters = crate::combine::max_chain_len(800, 500);
        assert!(iters > 0);
        let dim = 2;
        let prec_sum = Mat::scaled_identity(dim, 2.0);
        let cov_m = Mat::scaled_identity(dim, 0.5);
        let mu_m = vec![0.1, -0.3];
        let sched = AnnealSchedule::new(dim, iters);
        let k = default_kernel();
        let full = AnnealCache::build(
            &cov_m, &prec_sum, &mu_m, 2.0, true, iters, usize::MAX, 2,
            &sched, k.as_ref(),
        )
        .unwrap();
        assert_eq!(full.len(), iters);
        assert!(full.full_weights());
        assert!(full.factors[0].num_mvn.is_some());
        assert!(full.entry(iters).is_some());
        assert!(full.entry(iters + 1).is_none());
        assert!(full.entry(0).is_none(), "iterations are 1-based");

        let capped = AnnealCache::build(
            &cov_m, &prec_sum, &mu_m, 2.0, true, iters, 1, 1, &sched,
            k.as_ref(),
        )
        .unwrap();
        assert_eq!(capped.len(), 1, "1-byte budget still caches entry 1");

        let nw = AnnealCache::build(
            &cov_m, &prec_sum, &mu_m, 2.0, false, 4, usize::MAX, 1,
            &sched, k.as_ref(),
        )
        .unwrap();
        assert!(!nw.full_weights());
        assert!(nw.factors.iter().all(|f| f.num_mvn.is_none()));
    }

    /// Byte-identical output for a fixed seed at 1, 2 and 4 threads,
    /// for both weight variants.
    #[test]
    fn threaded_output_independent_of_thread_count() {
        let mus = vec![vec![0.2, -0.2], vec![0.6, 0.2]];
        let sets = gaussian_sets(9, &mus, 1.0, 400);
        let refs: Vec<&SampleMatrix> = sets.iter().collect();
        let base_full = semiparametric_threaded(&refs, 1200, 17, 1).unwrap();
        let base_nw = semiparametric_nw_threaded(&refs, 1200, 17, 1).unwrap();
        assert_eq!(base_full.len(), 1200);
        for threads in [2usize, 4] {
            let full =
                semiparametric_threaded(&refs, 1200, 17, threads).unwrap();
            let nw =
                semiparametric_nw_threaded(&refs, 1200, 17, threads).unwrap();
            assert_eq!(base_full.as_slice(), full.as_slice());
            assert_eq!(base_nw.as_slice(), nw.as_slice());
        }
    }
}
