//! Semiparametric combination (paper section 3.3).
//!
//! Each subposterior is estimated by the Hjort-Glad product of a
//! parametric start `N(μ̂_m, Σ̂_m)` and a nonparametric correction. The
//! density product is then a mixture of `T^M` Gaussians with components
//! `N(μ_t, Σ_t)`,
//!
//!   Σ_t = (M/h² I + Σ̂_M⁻¹)⁻¹,
//!   μ_t = Σ_t (M/h² θ̄_t + Σ̂_M⁻¹ μ̂_M),
//!
//! and unnormalized weights
//!
//!   W_t = w_t · N(θ̄_t | μ̂_M, Σ̂_M + (h²/M) I) / Π_m N(θ^m_{t_m} | μ̂_m, Σ̂_m),
//!
//! sampled with the same IMG scheme as Algorithm 1. The second variant
//! ([`semiparametric_nw`]) keeps the nonparametric weights `w_t` (higher
//! IMG acceptance) but draws from the semiparametric components; it
//! tends to the nonparametric procedure as h → 0 and is likewise
//! asymptotically exact.
//!
//! ## Setup and runtime parallelism
//!
//! The per-machine parametric log-densities `log N(θ^m_t | μ̂_m, Σ̂_m)`
//! are precomputed once — this O(TMd²) table is the single most
//! expensive setup step and fans out trivially one machine per task, as
//! do the per-machine Gaussian fits and the whitening/norm caches
//! ([`super::CombineContext`]). The restart chunks of the IMG chain are
//! then independent chains with split RNG streams, exactly as in
//! [`super::nonparametric`]: shared read-only state by borrow,
//! byte-identical output for a fixed seed at any thread count. An IMG
//! proposal costs O(d) for the `w` part + O(1) for the denominator +
//! O(d²) for the numerator term, with zero heap allocation.

use super::gaussian_product::GaussianEstimate;
use super::CombineContext;
use crate::error::Result;
use crate::math::linalg::{self, Mat};
use crate::math::mvn::Mvn;
use crate::rng::Pcg64;
use crate::stats::kde::annealed_bandwidth;
use crate::types::SampleMatrix;

/// Draw `t_out` samples from the semiparametric density-product estimate
/// (full weights `W_t`) on a single thread.
pub fn semiparametric(
    sets: &[&SampleMatrix],
    t_out: usize,
    seed: u64,
) -> Result<SampleMatrix> {
    run_semiparametric(sets, t_out, seed, true, 1)
}

/// [`semiparametric`] with setup and restart chains fanned across
/// `threads` workers (`0` = all cores). Deterministic at any count.
pub fn semiparametric_threaded(
    sets: &[&SampleMatrix],
    t_out: usize,
    seed: u64,
    threads: usize,
) -> Result<SampleMatrix> {
    run_semiparametric(sets, t_out, seed, true, threads)
}

/// Variant 2: nonparametric weights `w_t`, semiparametric components.
pub fn semiparametric_nw(
    sets: &[&SampleMatrix],
    t_out: usize,
    seed: u64,
) -> Result<SampleMatrix> {
    run_semiparametric(sets, t_out, seed, false, 1)
}

/// [`semiparametric_nw`] with a combine-stage thread count.
pub fn semiparametric_nw_threaded(
    sets: &[&SampleMatrix],
    t_out: usize,
    seed: u64,
    threads: usize,
) -> Result<SampleMatrix> {
    run_semiparametric(sets, t_out, seed, false, threads)
}

/// Read-only state shared by every restart chain of one combine call.
struct SemiShared<'a> {
    ctx: &'a CombineContext,
    /// log N(θ^m_t | μ̂_m, Σ̂_m) per machine per draw (O(TMd²) table).
    param_lp: Vec<Vec<f64>>,
    /// Σ̂_M.
    cov_m: Mat,
    /// μ̂_M.
    mu_m: Vec<f64>,
    /// Σ̂_M⁻¹ μ̂_M.
    prec_mu: Vec<f64>,
    /// Σ̂_M⁻¹ = Σ_m Σ̂_m⁻¹.
    prec_sum: Mat,
    full_weights: bool,
}

fn run_semiparametric(
    sets: &[&SampleMatrix],
    t_out: usize,
    seed: u64,
    full_weights: bool,
    threads: usize,
) -> Result<SampleMatrix> {
    // Whitened coordinates (bandwidth relative to subposterior scale;
    // see super::whitening_scales). The estimator is equivariant under
    // this diagonal map, including its parametric factor.
    super::validate_sets(sets)?;
    let threads = super::resolve_threads(threads);
    let ctx = CombineContext::prepare(sets, threads);
    let dim = ctx.dim();
    let m_count = ctx.machines();

    // Parametric fits N(μ̂_m, Σ̂_m) — O(Td²) per machine, one task each.
    let estimates: Vec<GaussianEstimate> =
        super::par_map_indexed(m_count, threads, |m| {
            GaussianEstimate::fit(&ctx.sets()[m])
        })
        .into_iter()
        .collect::<Result<_>>()?;

    // Product Gaussian N(μ̂_M, Σ̂_M) pieces (small, sequential).
    let mut prec_sum = Mat::zeros(dim, dim);
    for est in &estimates {
        prec_sum = prec_sum.add(&est.prec)?;
    }
    let cov_m = linalg::spd_inverse_jittered(&prec_sum)?; // Σ̂_M
    let mut acc = vec![0.0; dim];
    for est in &estimates {
        let pm = est.prec.matvec(&est.mean)?;
        for j in 0..dim {
            acc[j] += pm[j];
        }
    }
    let mu_m = cov_m.matvec(&acc)?; // μ̂_M
    let prec_mu = prec_sum.matvec(&mu_m)?; // Σ̂_M⁻¹ μ̂_M

    // The O(TMd²) parametric log-density table, one machine per task.
    let param_lp: Vec<Vec<f64>> =
        super::par_map_indexed(m_count, threads, |m| -> Result<Vec<f64>> {
            let mvn = estimates[m].mvn()?;
            let mut scratch = vec![0.0; dim];
            Ok(ctx.sets()[m]
                .rows()
                .map(|r| mvn.logpdf_with(r, &mut scratch))
                .collect())
        })
        .into_iter()
        .collect::<Result<_>>()?;

    let shared = SemiShared {
        ctx: &ctx,
        param_lp,
        cov_m,
        mu_m,
        prec_mu,
        prec_sum,
        full_weights,
    };

    // Independent restart chains with split RNG streams — the same
    // schedule, and the same single copy of the orchestration
    // (`super::run_restart_chains`), as the nonparametric combiner.
    let mut out = super::run_restart_chains(
        dim,
        t_out,
        super::RESTART_CHUNK0,
        seed,
        threads,
        |keep, warmup, rng| run_chain(&shared, keep, warmup, rng),
    )?;
    super::unwhiten(&mut out, ctx.scales());
    Ok(out)
}

/// One restart chain: `keep + warmup` annealed IMG iterations over the
/// shared state, first `warmup` draws discarded. All per-proposal work
/// runs on reused scratch buffers — no heap traffic in the inner loop.
fn run_chain(
    sh: &SemiShared<'_>,
    keep: usize,
    warmup: usize,
    mut rng: Pcg64,
) -> Result<SampleMatrix> {
    let dim = sh.ctx.dim();
    let m_count = sh.ctx.machines();
    let m = m_count as f64;
    let sets = sh.ctx.sets();
    let norms = sh.ctx.norms();
    let sweeps = super::RESTART_SWEEPS;

    // IMG state.
    let mut indices: Vec<usize> = vec![0; m_count];
    let mut sum = vec![0.0; dim];
    let mut sq_sum = 0.0;
    // Scratch buffers reused across all proposals and draws.
    let mut theta_bar = vec![0.0; dim];
    let mut bar_new = vec![0.0; dim];
    let mut mean_vec = vec![0.0; dim];
    let mut lp_scratch = vec![0.0; dim];

    // Fresh t· for this chain.
    for (mach, s) in sets.iter().enumerate() {
        indices[mach] = rng.uniform_usize(s.len());
    }
    for (mach, s) in sets.iter().enumerate() {
        for (j, v) in s.row(indices[mach]).iter().enumerate() {
            sum[j] += v;
        }
        sq_sum += norms[mach][indices[mach]];
    }

    let mut out = SampleMatrix::with_capacity(dim, keep);
    for i in 1..=(keep + warmup) {
        let h = annealed_bandwidth(i, dim);
        let h2 = h * h;

        // Per-iteration factorizations (h is fixed within the sweep):
        // numerator Gaussian N(· | μ̂_M, Σ̂_M + h²/M I) and component
        // covariance Σ_t = (M/h² I + Σ̂_M⁻¹)⁻¹.
        let mut num_cov = sh.cov_m.clone();
        for j in 0..dim {
            num_cov[(j, j)] += h2 / m;
        }
        let num_mvn = Mvn::new(sh.mu_m.clone(), num_cov)?;
        let mut comp_prec = sh.prec_sum.clone();
        for j in 0..dim {
            comp_prec[(j, j)] += m / h2;
        }
        let comp_cov = linalg::spd_inverse_jittered(&comp_prec)?;

        let mut d_cur = super::scatter(sq_sum, &sum, m);
        for j in 0..dim {
            theta_bar[j] = sum[j] / m;
        }
        // Current total log weight pieces.
        let mut log_num_cur = if sh.full_weights {
            num_mvn.logpdf_with(&theta_bar, &mut lp_scratch)
        } else {
            0.0
        };

        for mach_sweep in 0..(m_count * sweeps) {
            let mach = mach_sweep % m_count;
            let set = &sets[mach];
            let old_idx = indices[mach];
            let new_idx = rng.uniform_usize(set.len());
            if new_idx == old_idx {
                continue;
            }
            let old_row = set.row(old_idx);
            let new_row = set.row(new_idx);
            let mut s2_new = 0.0;
            for j in 0..dim {
                let sj = sum[j] - old_row[j] + new_row[j];
                s2_new += sj * sj;
            }
            let q_new = sq_sum - norms[mach][old_idx] + norms[mach][new_idx];
            let d_new = (q_new - s2_new / m).max(0.0);
            // log w ratio (nonparametric part).
            let mut log_ratio = -(d_new - d_cur) / (2.0 * h2);
            let mut log_num_new = 0.0;
            if sh.full_weights {
                // Numerator: N(θ̄_c | μ̂_M, Σ̂_M + h²/M I).
                for j in 0..dim {
                    bar_new[j] = (sum[j] - old_row[j] + new_row[j]) / m;
                }
                log_num_new = num_mvn.logpdf_with(&bar_new, &mut lp_scratch);
                log_ratio += log_num_new - log_num_cur;
                // Denominator (inverted): - [lp(new) - lp(old)].
                log_ratio -=
                    sh.param_lp[mach][new_idx] - sh.param_lp[mach][old_idx];
            }
            if log_ratio >= 0.0 || rng.uniform().ln() < log_ratio {
                for j in 0..dim {
                    sum[j] += new_row[j] - old_row[j];
                }
                sq_sum = q_new;
                indices[mach] = new_idx;
                d_cur = d_new;
                if sh.full_weights {
                    log_num_cur = log_num_new;
                }
            }
        }

        // Draw θ_i ~ N(μ_t, Σ_t) for the current component.
        for j in 0..dim {
            mean_vec[j] = m / h2 * (sum[j] / m) + sh.prec_mu[j];
        }
        let comp_mean = comp_cov.matvec(&mean_vec)?;
        let comp = Mvn::new(comp_mean, comp_cov)?;
        if i > warmup {
            out.push(&comp.sample(&mut rng));
        } else {
            // Keep the RNG stream advancing uniformly through warmup.
            let _ = comp.sample(&mut rng);
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::math::linalg::Mat;

    fn gaussian_sets(
        seed: u64,
        mus: &[Vec<f64>],
        var: f64,
        t: usize,
    ) -> Vec<SampleMatrix> {
        let mut rng = Pcg64::seed_from(seed);
        mus.iter()
            .map(|mu| {
                Mvn::new(mu.clone(), Mat::scaled_identity(mu.len(), var))
                    .unwrap()
                    .sample_n(t, &mut rng)
            })
            .collect()
    }

    #[test]
    fn recovers_gaussian_product() {
        let mus = vec![vec![0.5, -0.5], vec![1.0, 0.0], vec![1.5, 0.5]];
        let sets = gaussian_sets(1, &mus, 1.0, 6000);
        let refs: Vec<&SampleMatrix> = sets.iter().collect();
        let out =
            semiparametric(&refs, 6000, 2).unwrap().split_off_burnin(1500);
        let mean = out.mean();
        assert!((mean[0] - 1.0).abs() < 0.15, "mean0 {}", mean[0]);
        assert!((mean[1] - 0.0).abs() < 0.15, "mean1 {}", mean[1]);
        let v = out.covariance()[(0, 0)];
        assert!((v - 1.0 / 3.0).abs() < 0.15, "var {v}");
    }

    #[test]
    fn nw_variant_recovers_gaussian_product() {
        let mus = vec![vec![0.8], vec![1.2]];
        let sets = gaussian_sets(3, &mus, 1.0, 3000);
        let refs: Vec<&SampleMatrix> = sets.iter().collect();
        let out = semiparametric_nw(&refs, 3000, 4).unwrap();
        // IMG autocorrelation: cross-seed sd of this mean ≈ 0.05.
        assert!((out.mean()[0] - 1.0).abs() < 0.15, "{}", out.mean()[0]);
        let v = out.covariance()[(0, 0)];
        assert!((v - 0.5).abs() < 0.15, "var {v}");
    }

    #[test]
    fn single_machine_reproduces_input_moments() {
        let sets = gaussian_sets(5, &[vec![-1.5, 2.0]], 2.0, 5000);
        let refs: Vec<&SampleMatrix> = sets.iter().collect();
        let out = semiparametric(&refs, 5000, 6).unwrap();
        let mean = out.mean();
        assert!((mean[0] + 1.5).abs() < 0.1, "{:?}", mean);
        assert!((mean[1] - 2.0).abs() < 0.1, "{:?}", mean);
        let c = out.covariance();
        assert!((c[(0, 0)] - 2.0).abs() < 0.25, "var {}", c[(0, 0)]);
    }

    #[test]
    fn both_variants_agree_on_gaussian_targets() {
        let mus = vec![vec![0.0, 1.0], vec![0.4, 0.6]];
        let sets = gaussian_sets(7, &mus, 1.0, 4000);
        let refs: Vec<&SampleMatrix> = sets.iter().collect();
        let a = semiparametric(&refs, 5000, 8).unwrap().split_off_burnin(1000);
        let b =
            semiparametric_nw(&refs, 5000, 8).unwrap().split_off_burnin(1000);
        for j in 0..2 {
            assert!(
                (a.mean()[j] - b.mean()[j]).abs() < 0.2,
                "dim {j}: {} vs {}",
                a.mean()[j],
                b.mean()[j]
            );
        }
    }

    /// Byte-identical output for a fixed seed at 1, 2 and 4 threads,
    /// for both weight variants.
    #[test]
    fn threaded_output_independent_of_thread_count() {
        let mus = vec![vec![0.2, -0.2], vec![0.6, 0.2]];
        let sets = gaussian_sets(9, &mus, 1.0, 400);
        let refs: Vec<&SampleMatrix> = sets.iter().collect();
        let base_full = semiparametric_threaded(&refs, 1200, 17, 1).unwrap();
        let base_nw = semiparametric_nw_threaded(&refs, 1200, 17, 1).unwrap();
        assert_eq!(base_full.len(), 1200);
        for threads in [2usize, 4] {
            let full =
                semiparametric_threaded(&refs, 1200, 17, threads).unwrap();
            let nw =
                semiparametric_nw_threaded(&refs, 1200, 17, threads).unwrap();
            assert_eq!(base_full.as_slice(), full.as_slice());
            assert_eq!(base_nw.as_slice(), nw.as_slice());
        }
    }
}
