//! Online combination (paper section 4).
//!
//! Workers stream draws to the leader as they are produced; the leader
//! folds each into per-machine draw stores and online Gaussian moment
//! accumulators. At any time it can emit (a) parametric product draws in
//! O(d³ + t·d²) using only the running moments — no buffer pass — or (b)
//! asymptotically exact draws by running the IMG combiner over the
//! stores collected so far.
//!
//! The per-machine buffers are chunked [`DrawStore`]s, so a leader
//! configured with a spill budget (`draw_spill_budget_mb`) keeps only
//! the hottest chunks of each machine's draw plane resident — the
//! combiners consume the stores chunk-at-a-time
//! ([`combine::combine_stores_with`]) and the retained draws stay
//! byte-identical to the dense path at any chunk size or budget.

use crate::combine::{self, CombineMethod};
use crate::error::{Error, Result};
use crate::math::running::RunningMoments;
use crate::types::{DrawStore, DrawStoreConfig, DrawStoreStats, SampleMatrix};

/// Streaming leader-side combiner.
#[derive(Debug)]
pub struct OnlineCombiner {
    dim: usize,
    buffers: Vec<DrawStore>,
    moments: Vec<RunningMoments>,
    total_received: usize,
}

impl OnlineCombiner {
    /// Dense stores (default chunking, no spill) — today's behavior.
    pub fn new(machines: usize, dim: usize) -> Self {
        OnlineCombiner::with_store_config(
            machines,
            dim,
            DrawStoreConfig::default(),
        )
    }

    /// Combiner whose per-machine draw plane uses an explicit
    /// [`DrawStoreConfig`] (chunk size + spill budget; the budget
    /// applies per machine store).
    pub fn with_store_config(
        machines: usize,
        dim: usize,
        store_cfg: DrawStoreConfig,
    ) -> Self {
        assert!(machines > 0 && dim > 0);
        OnlineCombiner {
            dim,
            buffers: (0..machines)
                .map(|_| DrawStore::with_config(dim, store_cfg))
                .collect(),
            moments: (0..machines).map(|_| RunningMoments::new(dim)).collect(),
            total_received: 0,
        }
    }

    pub fn machines(&self) -> usize {
        self.buffers.len()
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Draws received so far (all machines).
    pub fn total_received(&self) -> usize {
        self.total_received
    }

    /// Smallest per-machine buffer length — combination quality is
    /// limited by the slowest machine.
    pub fn min_buffer_len(&self) -> usize {
        self.buffers.iter().map(|b| b.len()).min().unwrap_or(0)
    }

    /// Ingest one draw from `machine`.
    pub fn push(&mut self, machine: usize, theta: &[f64]) -> Result<()> {
        if machine >= self.buffers.len() {
            return Err(Error::Config(format!(
                "machine {machine} out of range ({})",
                self.buffers.len()
            )));
        }
        if theta.len() != self.dim {
            return Err(Error::Shape(format!(
                "draw dim {} != {}",
                theta.len(),
                self.dim
            )));
        }
        self.buffers[machine].push(theta)?;
        self.moments[machine].push(theta);
        self.total_received += 1;
        Ok(())
    }

    /// Ingest a decoded `RPDRAW1` chunk from `machine` — a flat
    /// row-major buffer of whole rows — as one bulk landing: a single
    /// copy into the machine's store, then the moment accumulators
    /// folded per row *in draw order* (the same per-row updates, in the
    /// same order, as pushing each row through
    /// [`OnlineCombiner::push`]). Validation runs before anything
    /// lands, so a bad chunk leaves the store without partial rows.
    pub fn push_rows(&mut self, machine: usize, flat: &[f64]) -> Result<()> {
        if machine >= self.buffers.len() {
            return Err(Error::Config(format!(
                "machine {machine} out of range ({})",
                self.buffers.len()
            )));
        }
        if flat.len() % self.dim != 0 {
            return Err(Error::Shape(format!(
                "draw chunk of {} scalars is not whole rows of dim {}",
                flat.len(),
                self.dim
            )));
        }
        self.buffers[machine].push_rows(flat)?;
        for row in flat.chunks_exact(self.dim) {
            self.moments[machine].push(row);
        }
        self.total_received += flat.len() / self.dim;
        Ok(())
    }

    /// Discard everything received from `machine` — draw store and
    /// moment accumulator — returning how many rows were dropped. The
    /// fault-tolerant scheduler calls this before re-dispatching a
    /// failed shard: every machine's RNG stream is `root.split(m)`, so
    /// the retried run regenerates the discarded prefix bit-identically
    /// and the combine stage never sees duplicate or partial draws.
    pub fn reset_machine(&mut self, machine: usize) -> Result<usize> {
        if machine >= self.buffers.len() {
            return Err(Error::Config(format!(
                "machine {machine} out of range ({})",
                self.buffers.len()
            )));
        }
        let cfg = *self.buffers[machine].config();
        let dropped = self.buffers[machine].len();
        self.buffers[machine] = DrawStore::with_config(self.dim, cfg);
        self.moments[machine] = RunningMoments::new(self.dim);
        self.total_received -= dropped;
        Ok(dropped)
    }

    /// Aggregate memory accounting across every machine's draw store:
    /// resident and spilled payload bytes, plus the (conservatively
    /// summed) peak — the pipeline summary's `draw_peak_bytes` /
    /// `draw_spilled_bytes` source.
    pub fn draw_stats(&self) -> DrawStoreStats {
        let mut total = DrawStoreStats::default();
        for b in &self.buffers {
            total.absorb(&b.stats());
        }
        total
    }

    /// Parametric product from the *running* moments (footnote 3 of the
    /// paper: online mean/covariance updates) — O(d³) regardless of how
    /// many draws have streamed in.
    pub fn parametric_draws(
        &self,
        t_out: usize,
        seed: u64,
    ) -> Result<SampleMatrix> {
        use crate::combine::gaussian_product::{
            gaussian_product, GaussianEstimate,
        };
        let estimates: Vec<GaussianEstimate> = self
            .moments
            .iter()
            .map(|rm| {
                if rm.count() < 2 {
                    return Err(Error::Config(
                        "need ≥ 2 draws per machine".into(),
                    ));
                }
                let cov = rm.covariance();
                let prec = crate::math::linalg::spd_inverse_jittered(&cov)?;
                Ok(GaussianEstimate { mean: rm.mean().to_vec(), cov, prec })
            })
            .collect::<Result<_>>()?;
        let product = gaussian_product(&estimates)?;
        let mut rng = crate::rng::Pcg64::seed_from(seed);
        Ok(product.sample_n(t_out, &mut rng))
    }

    /// Run any batch combiner over the buffered draws so far.
    pub fn combined_draws(
        &self,
        method: CombineMethod,
        t_out: usize,
        seed: u64,
    ) -> Result<SampleMatrix> {
        self.combined_draws_threaded(method, t_out, seed, 1)
    }

    /// [`OnlineCombiner::combined_draws`] with a combine-stage thread
    /// count (`0` = all cores) — the streaming leader gets the same
    /// threaded/cached combine runtime as the batch path, with the same
    /// contract: byte-identical draws for a fixed seed at any count.
    pub fn combined_draws_threaded(
        &self,
        method: CombineMethod,
        t_out: usize,
        seed: u64,
        threads: usize,
    ) -> Result<SampleMatrix> {
        self.combined_draws_tuned(
            method,
            t_out,
            seed,
            threads,
            combine::DEFAULT_ANNEAL_CACHE_BUDGET,
        )
    }

    /// [`OnlineCombiner::combined_draws_threaded`] with an explicit
    /// annealed-factorization-cache budget in bytes — same guarantee:
    /// byte-identical draws at any thread count and budget.
    pub fn combined_draws_tuned(
        &self,
        method: CombineMethod,
        t_out: usize,
        seed: u64,
        threads: usize,
        cache_budget_bytes: usize,
    ) -> Result<SampleMatrix> {
        self.combined_draws_with(
            method,
            t_out,
            seed,
            &combine::CombineTuning {
                threads,
                cache_budget_bytes,
                ..Default::default()
            },
        )
    }

    /// [`OnlineCombiner::combined_draws_tuned`] over a full
    /// [`combine::CombineTuning`] — the streaming leader's path to a
    /// non-default compute-kernel backend (`combine_backend` config
    /// key). CPU backends are bit-identical, so the guarantee is
    /// unchanged: byte-identical draws for a fixed seed at any thread
    /// count, budget, and CPU backend.
    pub fn combined_draws_with(
        &self,
        method: CombineMethod,
        t_out: usize,
        seed: u64,
        tuning: &combine::CombineTuning,
    ) -> Result<SampleMatrix> {
        let refs: Vec<&DrawStore> = self.buffers.iter().collect();
        combine::combine_stores_with(method, &refs, t_out, seed, tuning)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::math::linalg::Mat;
    use crate::math::mvn::Mvn;
    use crate::rng::Pcg64;

    fn feed(oc: &mut OnlineCombiner, seed: u64, mus: &[f64], n: usize) {
        let mut rng = Pcg64::seed_from(seed);
        let gens: Vec<Mvn> = mus
            .iter()
            .map(|&mu| Mvn::new(vec![mu], Mat::diag(&[1.0])).unwrap())
            .collect();
        for _ in 0..n {
            for (m, g) in gens.iter().enumerate() {
                oc.push(m, &g.sample(&mut rng)).unwrap();
            }
        }
    }

    #[test]
    fn online_parametric_matches_batch() {
        let mut oc = OnlineCombiner::new(2, 1);
        feed(&mut oc, 1, &[0.5, 1.5], 5000);
        let online = oc.parametric_draws(5000, 2).unwrap();
        let batch = oc
            .combined_draws(CombineMethod::Parametric, 5000, 2)
            .unwrap();
        assert!((online.mean()[0] - batch.mean()[0]).abs() < 0.05);
        assert!((online.mean()[0] - 1.0).abs() < 0.05);
    }

    #[test]
    fn online_exact_combiner_runs_midstream() {
        let mut oc = OnlineCombiner::new(3, 1);
        feed(&mut oc, 3, &[0.8, 1.0, 1.2], 400);
        // Combine midstream…
        let first = oc
            .combined_draws(CombineMethod::Nonparametric, 400, 4)
            .unwrap();
        // …then stream more and combine again: error should not grow.
        feed(&mut oc, 5, &[0.8, 1.0, 1.2], 3600);
        let second = oc
            .combined_draws(CombineMethod::Nonparametric, 3000, 4)
            .unwrap();
        let e1 = (first.mean()[0] - 1.0).abs();
        let e2 = (second.mean()[0] - 1.0).abs();
        assert!(e2 < e1 + 0.05, "e1={e1} e2={e2}");
    }

    /// The streaming leader's threaded combine path is byte-identical
    /// to the serial one at any thread count, for an IMG-based method.
    #[test]
    fn threaded_draws_match_serial() {
        let mut oc = OnlineCombiner::new(3, 1);
        feed(&mut oc, 11, &[0.8, 1.0, 1.2], 400);
        let base = oc
            .combined_draws(CombineMethod::Semiparametric, 900, 6)
            .unwrap();
        for threads in [2usize, 4, 0] {
            let out = oc
                .combined_draws_threaded(
                    CombineMethod::Semiparametric,
                    900,
                    6,
                    threads,
                )
                .unwrap();
            assert_eq!(
                base.as_slice(),
                out.as_slice(),
                "threads {threads} diverged"
            );
        }
    }

    /// Bulk chunk landing is equivalent to per-row pushes — same store
    /// contents, same moment folds — and a spill-configured combiner
    /// emits byte-identical draws to the dense one.
    #[test]
    fn push_rows_and_spill_match_dense_per_row() {
        let mut rng = Pcg64::seed_from(7);
        let machines: Vec<Vec<f64>> = [0.7, 1.3]
            .iter()
            .map(|&mu| (0..300).map(|_| mu + rng.normal()).collect())
            .collect();
        let mut dense = OnlineCombiner::new(2, 1);
        for (m, draws) in machines.iter().enumerate() {
            for &v in draws {
                dense.push(m, &[v]).unwrap();
            }
        }
        let cfg = DrawStoreConfig {
            chunk_rows: 7,
            spill_budget_bytes: Some(0),
        };
        let mut spill = OnlineCombiner::with_store_config(2, 1, cfg);
        for (m, draws) in machines.iter().enumerate() {
            for chunk in draws.chunks(64) {
                spill.push_rows(m, chunk).unwrap();
            }
        }
        assert_eq!(spill.total_received(), 600);
        assert_eq!(spill.min_buffer_len(), 300);
        assert!(spill.draw_stats().spilled_bytes > 0);
        assert_eq!(dense.draw_stats().spilled_bytes, 0);
        let online = spill.parametric_draws(100, 3).unwrap();
        let online_dense = dense.parametric_draws(100, 3).unwrap();
        assert_eq!(online.as_slice(), online_dense.as_slice());
        for method in
            [CombineMethod::Semiparametric, CombineMethod::Pairwise]
        {
            let a = dense.combined_draws(method, 400, 9).unwrap();
            let b = spill.combined_draws(method, 400, 9).unwrap();
            assert_eq!(
                a.as_slice(),
                b.as_slice(),
                "{} diverged through spill",
                method.name()
            );
        }
    }

    /// A bad chunk is rejected before anything lands: no partial rows
    /// in the store, no moment updates.
    #[test]
    fn push_rows_validates_before_landing() {
        let mut oc = OnlineCombiner::new(2, 2);
        assert!(oc.push_rows(9, &[0.0, 0.0]).is_err());
        let err = oc.push_rows(0, &[0.0, 0.0, 0.0]).unwrap_err();
        assert!(err.to_string().contains("whole rows"), "{err}");
        assert_eq!(oc.total_received(), 0);
        assert_eq!(oc.min_buffer_len(), 0);
        oc.push_rows(0, &[1.0, 2.0, 3.0, 4.0]).unwrap();
        assert_eq!(oc.total_received(), 2);
    }

    /// Reset-then-refeed is indistinguishable from never having
    /// failed: the combined draws (both moment-based and buffer-based
    /// paths) are byte-identical, which is the correctness core of the
    /// shard-retry scheduler.
    #[test]
    fn reset_then_refeed_matches_never_failed() {
        let mut rng = Pcg64::seed_from(13);
        let streams: Vec<Vec<f64>> = [0.6, 1.4]
            .iter()
            .map(|&mu| (0..200).map(|_| mu + rng.normal()).collect())
            .collect();
        let mut clean = OnlineCombiner::new(2, 1);
        for (m, draws) in streams.iter().enumerate() {
            for &v in draws {
                clean.push(m, &[v]).unwrap();
            }
        }
        // Faulted replica: machine 1 delivers a partial stream, dies,
        // is reset, then replays its full stream from the start.
        let mut faulted = OnlineCombiner::new(2, 1);
        for &v in &streams[0] {
            faulted.push(0, &[v]).unwrap();
        }
        for &v in &streams[1][..77] {
            faulted.push(1, &[v]).unwrap();
        }
        assert_eq!(faulted.reset_machine(1).unwrap(), 77);
        assert_eq!(faulted.total_received(), 200);
        assert_eq!(faulted.min_buffer_len(), 0);
        for &v in &streams[1] {
            faulted.push(1, &[v]).unwrap();
        }
        assert_eq!(faulted.total_received(), clean.total_received());
        let a = clean.parametric_draws(100, 5).unwrap();
        let b = faulted.parametric_draws(100, 5).unwrap();
        assert_eq!(a.as_slice(), b.as_slice(), "moments diverged");
        let a = clean
            .combined_draws(CombineMethod::Semiparametric, 300, 8)
            .unwrap();
        let b = faulted
            .combined_draws(CombineMethod::Semiparametric, 300, 8)
            .unwrap();
        assert_eq!(a.as_slice(), b.as_slice(), "buffers diverged");
        assert!(faulted.reset_machine(9).is_err());
    }

    #[test]
    fn push_validates() {
        let mut oc = OnlineCombiner::new(2, 2);
        assert!(oc.push(5, &[0.0, 0.0]).is_err());
        assert!(oc.push(0, &[0.0]).is_err());
        assert!(oc.push(0, &[0.0, 1.0]).is_ok());
        assert_eq!(oc.total_received(), 1);
        assert_eq!(oc.min_buffer_len(), 0);
    }

    #[test]
    fn parametric_needs_two_draws_per_machine() {
        let mut oc = OnlineCombiner::new(2, 1);
        oc.push(0, &[1.0]).unwrap();
        oc.push(1, &[1.0]).unwrap();
        assert!(oc.parametric_draws(10, 1).is_err());
    }
}
