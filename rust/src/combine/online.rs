//! Online combination (paper section 4).
//!
//! Workers stream draws to the leader as they are produced; the leader
//! folds each into per-machine buffers and online Gaussian moment
//! accumulators. At any time it can emit (a) parametric product draws in
//! O(d³ + t·d²) using only the running moments — no buffer pass — or (b)
//! asymptotically exact draws by running the IMG combiner over the
//! buffers collected so far.

use crate::combine::{self, CombineMethod};
use crate::error::{Error, Result};
use crate::math::running::RunningMoments;
use crate::types::SampleMatrix;

/// Streaming leader-side combiner.
#[derive(Debug)]
pub struct OnlineCombiner {
    dim: usize,
    buffers: Vec<SampleMatrix>,
    moments: Vec<RunningMoments>,
    total_received: usize,
}

impl OnlineCombiner {
    pub fn new(machines: usize, dim: usize) -> Self {
        assert!(machines > 0 && dim > 0);
        OnlineCombiner {
            dim,
            buffers: (0..machines).map(|_| SampleMatrix::new(dim)).collect(),
            moments: (0..machines).map(|_| RunningMoments::new(dim)).collect(),
            total_received: 0,
        }
    }

    pub fn machines(&self) -> usize {
        self.buffers.len()
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Draws received so far (all machines).
    pub fn total_received(&self) -> usize {
        self.total_received
    }

    /// Smallest per-machine buffer length — combination quality is
    /// limited by the slowest machine.
    pub fn min_buffer_len(&self) -> usize {
        self.buffers.iter().map(|b| b.len()).min().unwrap_or(0)
    }

    /// Ingest one draw from `machine`.
    pub fn push(&mut self, machine: usize, theta: &[f64]) -> Result<()> {
        if machine >= self.buffers.len() {
            return Err(Error::Config(format!(
                "machine {machine} out of range ({})",
                self.buffers.len()
            )));
        }
        if theta.len() != self.dim {
            return Err(Error::Shape(format!(
                "draw dim {} != {}",
                theta.len(),
                self.dim
            )));
        }
        self.buffers[machine].push(theta);
        self.moments[machine].push(theta);
        self.total_received += 1;
        Ok(())
    }

    /// Parametric product from the *running* moments (footnote 3 of the
    /// paper: online mean/covariance updates) — O(d³) regardless of how
    /// many draws have streamed in.
    pub fn parametric_draws(
        &self,
        t_out: usize,
        seed: u64,
    ) -> Result<SampleMatrix> {
        use crate::combine::gaussian_product::{
            gaussian_product, GaussianEstimate,
        };
        let estimates: Vec<GaussianEstimate> = self
            .moments
            .iter()
            .map(|rm| {
                if rm.count() < 2 {
                    return Err(Error::Config(
                        "need ≥ 2 draws per machine".into(),
                    ));
                }
                let cov = rm.covariance();
                let prec = crate::math::linalg::spd_inverse_jittered(&cov)?;
                Ok(GaussianEstimate { mean: rm.mean().to_vec(), cov, prec })
            })
            .collect::<Result<_>>()?;
        let product = gaussian_product(&estimates)?;
        let mut rng = crate::rng::Pcg64::seed_from(seed);
        Ok(product.sample_n(t_out, &mut rng))
    }

    /// Run any batch combiner over the buffered draws so far.
    pub fn combined_draws(
        &self,
        method: CombineMethod,
        t_out: usize,
        seed: u64,
    ) -> Result<SampleMatrix> {
        self.combined_draws_threaded(method, t_out, seed, 1)
    }

    /// [`OnlineCombiner::combined_draws`] with a combine-stage thread
    /// count (`0` = all cores) — the streaming leader gets the same
    /// threaded/cached combine runtime as the batch path, with the same
    /// contract: byte-identical draws for a fixed seed at any count.
    pub fn combined_draws_threaded(
        &self,
        method: CombineMethod,
        t_out: usize,
        seed: u64,
        threads: usize,
    ) -> Result<SampleMatrix> {
        self.combined_draws_tuned(
            method,
            t_out,
            seed,
            threads,
            combine::DEFAULT_ANNEAL_CACHE_BUDGET,
        )
    }

    /// [`OnlineCombiner::combined_draws_threaded`] with an explicit
    /// annealed-factorization-cache budget in bytes — same guarantee:
    /// byte-identical draws at any thread count and budget.
    pub fn combined_draws_tuned(
        &self,
        method: CombineMethod,
        t_out: usize,
        seed: u64,
        threads: usize,
        cache_budget_bytes: usize,
    ) -> Result<SampleMatrix> {
        self.combined_draws_with(
            method,
            t_out,
            seed,
            &combine::CombineTuning {
                threads,
                cache_budget_bytes,
                ..Default::default()
            },
        )
    }

    /// [`OnlineCombiner::combined_draws_tuned`] over a full
    /// [`combine::CombineTuning`] — the streaming leader's path to a
    /// non-default compute-kernel backend (`combine_backend` config
    /// key). CPU backends are bit-identical, so the guarantee is
    /// unchanged: byte-identical draws for a fixed seed at any thread
    /// count, budget, and CPU backend.
    pub fn combined_draws_with(
        &self,
        method: CombineMethod,
        t_out: usize,
        seed: u64,
        tuning: &combine::CombineTuning,
    ) -> Result<SampleMatrix> {
        let refs: Vec<&SampleMatrix> = self.buffers.iter().collect();
        combine::combine_sets_with(method, &refs, t_out, seed, tuning)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::math::linalg::Mat;
    use crate::math::mvn::Mvn;
    use crate::rng::Pcg64;

    fn feed(oc: &mut OnlineCombiner, seed: u64, mus: &[f64], n: usize) {
        let mut rng = Pcg64::seed_from(seed);
        let gens: Vec<Mvn> = mus
            .iter()
            .map(|&mu| Mvn::new(vec![mu], Mat::diag(&[1.0])).unwrap())
            .collect();
        for _ in 0..n {
            for (m, g) in gens.iter().enumerate() {
                oc.push(m, &g.sample(&mut rng)).unwrap();
            }
        }
    }

    #[test]
    fn online_parametric_matches_batch() {
        let mut oc = OnlineCombiner::new(2, 1);
        feed(&mut oc, 1, &[0.5, 1.5], 5000);
        let online = oc.parametric_draws(5000, 2).unwrap();
        let batch = oc
            .combined_draws(CombineMethod::Parametric, 5000, 2)
            .unwrap();
        assert!((online.mean()[0] - batch.mean()[0]).abs() < 0.05);
        assert!((online.mean()[0] - 1.0).abs() < 0.05);
    }

    #[test]
    fn online_exact_combiner_runs_midstream() {
        let mut oc = OnlineCombiner::new(3, 1);
        feed(&mut oc, 3, &[0.8, 1.0, 1.2], 400);
        // Combine midstream…
        let first = oc
            .combined_draws(CombineMethod::Nonparametric, 400, 4)
            .unwrap();
        // …then stream more and combine again: error should not grow.
        feed(&mut oc, 5, &[0.8, 1.0, 1.2], 3600);
        let second = oc
            .combined_draws(CombineMethod::Nonparametric, 3000, 4)
            .unwrap();
        let e1 = (first.mean()[0] - 1.0).abs();
        let e2 = (second.mean()[0] - 1.0).abs();
        assert!(e2 < e1 + 0.05, "e1={e1} e2={e2}");
    }

    /// The streaming leader's threaded combine path is byte-identical
    /// to the serial one at any thread count, for an IMG-based method.
    #[test]
    fn threaded_draws_match_serial() {
        let mut oc = OnlineCombiner::new(3, 1);
        feed(&mut oc, 11, &[0.8, 1.0, 1.2], 400);
        let base = oc
            .combined_draws(CombineMethod::Semiparametric, 900, 6)
            .unwrap();
        for threads in [2usize, 4, 0] {
            let out = oc
                .combined_draws_threaded(
                    CombineMethod::Semiparametric,
                    900,
                    6,
                    threads,
                )
                .unwrap();
            assert_eq!(
                base.as_slice(),
                out.as_slice(),
                "threads {threads} diverged"
            );
        }
    }

    #[test]
    fn push_validates() {
        let mut oc = OnlineCombiner::new(2, 2);
        assert!(oc.push(5, &[0.0, 0.0]).is_err());
        assert!(oc.push(0, &[0.0]).is_err());
        assert!(oc.push(0, &[0.0, 1.0]).is_ok());
        assert_eq!(oc.total_received(), 1);
        assert_eq!(oc.min_buffer_len(), 0);
    }

    #[test]
    fn parametric_needs_two_draws_per_machine() {
        let mut oc = OnlineCombiner::new(2, 1);
        oc.push(0, &[1.0]).unwrap();
        oc.push(1, &[1.0]).unwrap();
        assert!(oc.parametric_draws(10, 1).is_err());
    }
}
