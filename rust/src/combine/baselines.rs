//! Baseline combination strategies from the paper's empirical study
//! (section 8) and related work (section 7).

use super::gaussian_product::GaussianEstimate;
use crate::error::Result;
use crate::math::linalg::{self, Mat};
use crate::rng::Pcg64;
use crate::types::SampleMatrix;

/// subpostAvg: each combined draw is the plain average of one sample
/// from each machine (indices drawn independently). The paper shows this
/// is systematically biased, with error growing in M (Fig. 1).
pub fn subpost_avg(
    sets: &[&SampleMatrix],
    t_out: usize,
    seed: u64,
) -> Result<SampleMatrix> {
    super::validate_sets(sets)?;
    let mut rng = Pcg64::seed_from(seed);
    let dim = sets[0].dim();
    let m = sets.len() as f64;
    let mut out = SampleMatrix::with_capacity(dim, t_out);
    let mut acc = vec![0.0; dim];
    for _ in 0..t_out {
        acc.iter_mut().for_each(|v| *v = 0.0);
        for s in sets {
            let row = s.row(rng.uniform_usize(s.len()));
            for j in 0..dim {
                acc[j] += row[j];
            }
        }
        for j in 0..dim {
            acc[j] /= m;
        }
        out.push(&acc);
    }
    Ok(out)
}

/// Per-chunk draw count of the threaded consensus combiner. The
/// per-draw loop is embarrassingly parallel, so draws are emitted in
/// fixed chunks, each with its own RNG stream split off the root seed:
/// the chunk plan is a pure function of `t_out`, never of the thread
/// count, which makes the output byte-identical at any parallelism.
const CONSENSUS_CHUNK: usize = 1024;

/// Consensus Monte Carlo (Scott et al. 2013): covariance-weighted
/// averaging, `θ = (Σ W_m)⁻¹ Σ W_m θ^m` with `W_m = Σ̂_m⁻¹`.
pub fn consensus_weighted(
    sets: &[&SampleMatrix],
    t_out: usize,
    seed: u64,
) -> Result<SampleMatrix> {
    consensus_weighted_threaded(sets, t_out, seed, 1)
}

/// [`consensus_weighted`] with the per-draw loop fanned over `threads`
/// workers ([`super::par_map_indexed`]). Deterministic for a fixed seed
/// at any thread count.
pub fn consensus_weighted_threaded(
    sets: &[&SampleMatrix],
    t_out: usize,
    seed: u64,
    threads: usize,
) -> Result<SampleMatrix> {
    super::validate_sets(sets)?;
    let dim = sets[0].dim();
    let estimates: Vec<GaussianEstimate> = sets
        .iter()
        .map(|s| GaussianEstimate::fit(s))
        .collect::<Result<_>>()?;
    let mut w_sum = Mat::zeros(dim, dim);
    for est in &estimates {
        w_sum = w_sum.add(&est.prec)?;
    }
    let w_sum_inv = linalg::spd_inverse_jittered(&w_sum)?;

    let n_chunks = (t_out + CONSENSUS_CHUNK - 1) / CONSENSUS_CHUNK;
    let mut root = Pcg64::seed_from(seed);
    let rngs = root.split_n(n_chunks);
    let parts = super::par_map_indexed(n_chunks, threads.max(1), |c| {
        let n = CONSENSUS_CHUNK.min(t_out - c * CONSENSUS_CHUNK);
        consensus_chunk(sets, &estimates, &w_sum_inv, n, rngs[c].clone())
    })
    .into_iter()
    .collect::<Result<Vec<SampleMatrix>>>()?;

    let mut out = SampleMatrix::with_capacity(dim, t_out);
    for part in &parts {
        out.push_rows(part.as_slice());
    }
    Ok(out)
}

/// One chunk of consensus draws with its own RNG stream.
fn consensus_chunk(
    sets: &[&SampleMatrix],
    estimates: &[GaussianEstimate],
    w_sum_inv: &Mat,
    n: usize,
    mut rng: Pcg64,
) -> Result<SampleMatrix> {
    let dim = sets[0].dim();
    let mut out = SampleMatrix::with_capacity(dim, n);
    let mut acc = vec![0.0; dim];
    // Scratch buffers reused across draws (no per-draw heap traffic).
    let mut wr = vec![0.0; dim];
    let mut combined = vec![0.0; dim];
    for _ in 0..n {
        acc.iter_mut().for_each(|v| *v = 0.0);
        for (s, est) in sets.iter().zip(estimates) {
            let row = s.row(rng.uniform_usize(s.len()));
            est.prec.matvec_into(row, &mut wr)?;
            for j in 0..dim {
                acc[j] += wr[j];
            }
        }
        w_sum_inv.matvec_into(&acc, &mut combined)?;
        out.push(&combined);
    }
    Ok(out)
}

/// subpostPool: union of all subposterior draws (biased — it represents
/// the *mixture*, not the product, of the subposteriors).
pub fn subpost_pool(sets: &[&SampleMatrix]) -> Result<SampleMatrix> {
    super::validate_sets(sets)?;
    let mut out = SampleMatrix::new(sets[0].dim());
    for s in sets {
        out.extend(s)?;
    }
    Ok(out)
}

/// duplicateChainsPool: union of M full-data chains' draws. Numerically
/// identical to pooling, but the inputs are full-posterior chains so the
/// result is unbiased — it just cannot parallelize burn-in (section 8.1).
pub fn duplicate_chains_pool(
    chains: &[&SampleMatrix],
) -> Result<SampleMatrix> {
    subpost_pool(chains)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::math::mvn::Mvn;

    fn gaussian_sets(
        seed: u64,
        mus: &[Vec<f64>],
        var: f64,
        t: usize,
    ) -> Vec<SampleMatrix> {
        let mut rng = Pcg64::seed_from(seed);
        mus.iter()
            .map(|mu| {
                Mvn::new(mu.clone(), Mat::scaled_identity(mu.len(), var))
                    .unwrap()
                    .sample_n(t, &mut rng)
            })
            .collect()
    }

    /// For Gaussian subposteriors with EQUAL covariances, averaging is
    /// actually unbiased in the mean but has variance var/M — which is
    /// correct here; the bias appears under unequal covariance.
    #[test]
    fn subpost_avg_moments_on_symmetric_gaussians() {
        let sets = gaussian_sets(1, &[vec![0.5], vec![1.5]], 1.0, 8000);
        let refs: Vec<&SampleMatrix> = sets.iter().collect();
        let out = subpost_avg(&refs, 8000, 2).unwrap();
        assert!((out.mean()[0] - 1.0).abs() < 0.05);
        let v = out.covariance()[(0, 0)];
        assert!((v - 0.5).abs() < 0.05, "var {v}");
    }

    /// Unequal covariances: the plain average lands at the arithmetic
    /// mean of the μ_m, but the true product mean is precision-weighted —
    /// the paper's systematic bias, growing with the covariance spread.
    #[test]
    fn subpost_avg_bias_vs_product_mean() {
        let mut rng = Pcg64::seed_from(3);
        let tight = Mvn::new(vec![0.0], Mat::diag(&[0.1]))
            .unwrap()
            .sample_n(8000, &mut rng);
        let wide = Mvn::new(vec![4.0], Mat::diag(&[10.0]))
            .unwrap()
            .sample_n(8000, &mut rng);
        let refs: Vec<&SampleMatrix> = vec![&tight, &wide];
        let avg = subpost_avg(&refs, 8000, 4).unwrap();
        // Product mean ≈ (0/0.1 + 4/10)/(1/0.1 + 1/10) ≈ 0.0396.
        // Plain average mean = 2.0 — strongly biased.
        assert!((avg.mean()[0] - 2.0).abs() < 0.1);
        let cw = consensus_weighted(&refs, 8000, 5).unwrap();
        assert!(
            (cw.mean()[0] - 0.0396).abs() < 0.1,
            "consensus mean {}",
            cw.mean()[0]
        );
    }

    #[test]
    fn pool_is_union() {
        let sets = gaussian_sets(6, &[vec![0.0], vec![1.0]], 1.0, 100);
        let refs: Vec<&SampleMatrix> = sets.iter().collect();
        let pooled = subpost_pool(&refs).unwrap();
        assert_eq!(pooled.len(), 200);
        // Pooling a bimodal pair has variance > either component.
        let v = pooled.covariance()[(0, 0)];
        assert!(v > 1.0, "var {v}");
    }

    /// The chunked per-draw fan-out must be byte-identical at any
    /// thread count (including a `t_out` that is not a multiple of the
    /// chunk size, exercising the ragged tail chunk).
    #[test]
    fn consensus_threaded_is_thread_count_invariant() {
        let sets = gaussian_sets(9, &[vec![0.0, 1.0], vec![2.0, -1.0]], 1.0, 400);
        let refs: Vec<&SampleMatrix> = sets.iter().collect();
        for t_out in [500usize, 2048, 2500] {
            let base =
                consensus_weighted_threaded(&refs, t_out, 11, 1).unwrap();
            assert_eq!(base.len(), t_out);
            for threads in [2usize, 4, 16] {
                let out = consensus_weighted_threaded(&refs, t_out, 11, threads)
                    .unwrap();
                assert_eq!(
                    base.as_slice(),
                    out.as_slice(),
                    "threads {threads}, t_out {t_out} diverged"
                );
            }
        }
    }

    #[test]
    fn consensus_on_equal_covariances_matches_avg() {
        let sets = gaussian_sets(7, &[vec![0.0], vec![2.0]], 1.0, 10_000);
        let refs: Vec<&SampleMatrix> = sets.iter().collect();
        let avg = subpost_avg(&refs, 10_000, 8).unwrap();
        let cw = consensus_weighted(&refs, 10_000, 8).unwrap();
        assert!((avg.mean()[0] - cw.mean()[0]).abs() < 0.06);
    }
}
