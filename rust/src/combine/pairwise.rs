//! Pairwise tree reduction (paper sections 3.2 and 4).
//!
//! Applying Algorithm 1 directly to M machines costs O(dTM²) and its
//! acceptance rate decays with M (each sweep perturbs one of M indices
//! of a product of M kernels). The paper's remedy: combine subposteriors
//! in pairs, then combine the pair-outputs in pairs, and so on —
//! ⌈log₂ M⌉ rounds, O(dTM) total work, and each IMG run only ever sees
//! M̃ = 2 components.
//!
//! ## Parallel reduction
//!
//! The merges within one tree level are independent, so
//! [`pairwise_threaded`] runs them concurrently and splits any leftover
//! workers into each merge's own restart-chain pool (Wang et al.'s
//! partition-tree recombination parallelizes the same structure). Merge
//! seeds are drawn from the root stream *before* the level fans out, so
//! the reduction is byte-identical for a fixed seed at any thread
//! count.
//!
//! Each merge's [`super::CombineContext`] (whitened copies + norm
//! caches) is built *when its worker picks the merge up*
//! ([`super::prepare_contexts`] over that one group, fanned across the
//! merge's inner chain pool) and dropped before the worker moves on —
//! so at most `outer` merge groups' whitened copies are alive at any
//! instant (exactly one on a single worker), never a whole level's.
//! That bound is what lets the out-of-core leader run the tree over
//! spilled draw stores without densifying a level at a time, and it is
//! observable: thread a [`super::MemGauge`] through
//! [`pairwise_threaded_gauged`] and `peak_bytes` reports the high-water
//! mark of live context bytes. The contexts themselves are
//! bit-identical to the ones a level-wide hoist (or each merge's own
//! in-line whitening) would build, so the tree's output is unchanged.

use std::sync::Arc;

use super::nonparametric::nonparametric_with_context;
use super::MemGauge;
use crate::error::Result;
use crate::kernel::{default_kernel, CombineKernel};
use crate::rng::Pcg64;
use crate::types::SampleMatrix;

/// Combine M subposterior sample sets by repeated pairing, single
/// threaded.
pub fn pairwise(
    sets: &[&SampleMatrix],
    t_out: usize,
    seed: u64,
) -> Result<SampleMatrix> {
    pairwise_threaded(sets, t_out, seed, 1)
}

/// [`pairwise`] with each tree level's merges (and their restart
/// chains) fanned across `threads` workers (`0` = all cores).
pub fn pairwise_threaded(
    sets: &[&SampleMatrix],
    t_out: usize,
    seed: u64,
    threads: usize,
) -> Result<SampleMatrix> {
    reduce_tree(sets, 2, t_out, seed, threads, &default_kernel(), None)
}

/// [`pairwise_threaded`] with a [`MemGauge`] observing how many
/// whitened-context bytes the tree holds at once — each merge registers
/// its context for exactly the context's lifetime. With one thread the
/// reported peak is the largest single merge group's
/// [`super::CombineContext::resident_bytes`]; the draws are
/// byte-identical to the ungauged call.
pub fn pairwise_threaded_gauged(
    sets: &[&SampleMatrix],
    t_out: usize,
    seed: u64,
    threads: usize,
    gauge: &MemGauge,
) -> Result<SampleMatrix> {
    reduce_tree(sets, 2, t_out, seed, threads, &default_kernel(), Some(gauge))
}

/// [`pairwise_threaded`] on an explicit compute-kernel backend — the
/// combine dispatch's entry point. The kernel runs every merge's norm
/// pass ([`super::prepare_contexts`]); CPU backends are bit-identical,
/// so the tree's output doesn't depend on which one ran.
pub(crate) fn pairwise_with(
    sets: &[&SampleMatrix],
    t_out: usize,
    seed: u64,
    threads: usize,
    kernel: &Arc<dyn CombineKernel>,
) -> Result<SampleMatrix> {
    reduce_tree(sets, 2, t_out, seed, threads, kernel, None)
}

/// Number of pair-combination invocations performed for M machines
/// (M - 1, matching the paper's O(dTM) complexity claim).
pub fn pair_combinations(m: usize) -> usize {
    m.saturating_sub(1)
}

/// Generalized tree reduction over groups of `group_size` (the paper's
/// "groups of M̃ < M subposteriors", section 3.2). `group_size = 2`
/// recovers [`pairwise`]; larger groups trade IMG acceptance rate for
/// fewer reduction rounds.
pub fn grouped(
    sets: &[&SampleMatrix],
    group_size: usize,
    t_out: usize,
    seed: u64,
) -> Result<SampleMatrix> {
    reduce_tree(sets, group_size, t_out, seed, 1, &default_kernel(), None)
}

/// [`grouped`] with a combine-stage thread count.
pub fn grouped_threaded(
    sets: &[&SampleMatrix],
    group_size: usize,
    t_out: usize,
    seed: u64,
    threads: usize,
) -> Result<SampleMatrix> {
    reduce_tree(
        sets,
        group_size,
        t_out,
        seed,
        threads,
        &default_kernel(),
        None,
    )
}

fn reduce_tree(
    sets: &[&SampleMatrix],
    group_size: usize,
    t_out: usize,
    seed: u64,
    threads: usize,
    kernel: &Arc<dyn CombineKernel>,
    gauge: Option<&MemGauge>,
) -> Result<SampleMatrix> {
    super::validate_sets(sets)?;
    assert!(group_size >= 2, "group size must be >= 2");
    let threads = super::resolve_threads(threads);
    let mut rng = Pcg64::seed_from(seed);
    let mut current: Vec<SampleMatrix> =
        sets.iter().map(|s| (*s).clone()).collect();
    while current.len() > 1 {
        let chunks: Vec<&[SampleMatrix]> =
            current.chunks(group_size).collect();
        // Merge seeds come off the root stream sequentially, before any
        // merge runs — the schedule is scheduling-independent. Odd
        // leftovers carry to the next round unchanged and draw no seed.
        let seeds: Vec<Option<u64>> = chunks
            .iter()
            .map(|c| if c.len() >= 2 { Some(rng.next_u64()) } else { None })
            .collect();
        let merges = seeds.iter().filter(|s| s.is_some()).count();
        // Split workers: up to `merges` concurrent merges at this
        // level, remaining parallelism goes into each merge's own
        // restart-chain pool. Round the inner pool up so no worker
        // idles when `merges` does not divide `threads` (e.g. M=10,
        // threads=8 → 5 merges × 2 chain workers, not 5 × 1); the
        // slight oversubscription is cheaper than idle cores.
        let outer = threads.clamp(1, merges.max(1));
        let inner = threads.div_ceil(outer).max(1);
        let next: Vec<Result<SampleMatrix>> =
            super::par_map_indexed(chunks.len(), outer, |i| match seeds[i] {
                Some(merge_seed) => {
                    // Per-outer-batch context: the merge whitens its own
                    // group — the per-set passes fanned across its inner
                    // chain pool — when a worker picks it up, and the
                    // whitened copies drop before the worker moves on.
                    // At most `outer` groups' contexts are ever alive at
                    // once (exactly one single-threaded), instead of a
                    // whole level's; content is bit-identical to a
                    // level-wide hoist.
                    let group: Vec<&SampleMatrix> =
                        chunks[i].iter().collect();
                    let ctx =
                        super::prepare_contexts(&[group], inner, kernel)?
                            .pop()
                            .expect("one context per group");
                    let bytes = ctx.resident_bytes();
                    if let Some(g) = gauge {
                        g.add(bytes);
                    }
                    let out = nonparametric_with_context(
                        &ctx, t_out, merge_seed, inner,
                    );
                    drop(ctx);
                    if let Some(g) = gauge {
                        g.sub(bytes);
                    }
                    out
                }
                None => Ok(chunks[i][0].clone()),
            });
        current = next.into_iter().collect::<Result<Vec<SampleMatrix>>>()?;
    }
    Ok(current.pop().unwrap().take(t_out))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::math::linalg::Mat;
    use crate::math::mvn::Mvn;

    fn gaussian_sets(
        seed: u64,
        mus: &[f64],
        var: f64,
        t: usize,
    ) -> Vec<SampleMatrix> {
        let mut rng = Pcg64::seed_from(seed);
        mus.iter()
            .map(|&mu| {
                Mvn::new(vec![mu], Mat::diag(&[var]))
                    .unwrap()
                    .sample_n(t, &mut rng)
            })
            .collect()
    }

    #[test]
    fn pairwise_recovers_gaussian_product_m4() {
        // Four N(μ_m, 1): product = N(mean, 1/4).
        let sets = gaussian_sets(1, &[0.7, 0.9, 1.1, 1.3], 1.0, 3000);
        let refs: Vec<&SampleMatrix> = sets.iter().collect();
        let out = pairwise(&refs, 3000, 2).unwrap();
        assert!((out.mean()[0] - 1.0).abs() < 0.1, "{}", out.mean()[0]);
        let v = out.covariance()[(0, 0)];
        assert!((v - 0.25).abs() < 0.12, "var {v}");
    }

    #[test]
    fn pairwise_handles_odd_m() {
        let sets = gaussian_sets(3, &[0.8, 1.0, 1.2], 1.0, 2000);
        let refs: Vec<&SampleMatrix> = sets.iter().collect();
        let out = pairwise(&refs, 2000, 4).unwrap();
        assert_eq!(out.len(), 2000);
        // IMG chains are autocorrelated; cross-seed sd of this mean is
        // ~0.07, so allow 3σ.
        assert!((out.mean()[0] - 1.0).abs() < 0.25, "{}", out.mean()[0]);
    }

    #[test]
    fn pairwise_single_set_is_passthrough_kde() {
        let sets = gaussian_sets(5, &[2.0], 1.0, 2000);
        let refs: Vec<&SampleMatrix> = sets.iter().collect();
        let out = pairwise(&refs, 1500, 6).unwrap();
        assert_eq!(out.len(), 1500);
        assert!((out.mean()[0] - 2.0).abs() < 0.15);
    }

    #[test]
    fn pair_combination_count() {
        assert_eq!(pair_combinations(1), 0);
        assert_eq!(pair_combinations(2), 1);
        assert_eq!(pair_combinations(10), 9);
    }

    #[test]
    fn grouped_matches_pairwise_quality() {
        // Groups of 3 over 6 gaussians: same product target.
        let sets =
            gaussian_sets(9, &[0.7, 0.8, 0.9, 1.1, 1.2, 1.3], 1.0, 2500);
        let refs: Vec<&SampleMatrix> = sets.iter().collect();
        let out = grouped(&refs, 3, 2500, 10).unwrap();
        assert_eq!(out.len(), 2500);
        assert!((out.mean()[0] - 1.0).abs() < 0.12, "{}", out.mean()[0]);
        // Product of 6 unit-variance gaussians → var 1/6.
        let v = out.covariance()[(0, 0)];
        assert!((v - 1.0 / 6.0).abs() < 0.12, "var {v}");
    }

    /// Whole-tree determinism: the reduction is byte-identical at 1, 2
    /// and 4 threads (merges reordered across workers, same seeds).
    #[test]
    fn threaded_tree_independent_of_thread_count() {
        let sets =
            gaussian_sets(11, &[0.6, 0.8, 1.0, 1.2, 1.4], 1.0, 500);
        let refs: Vec<&SampleMatrix> = sets.iter().collect();
        let base = pairwise_threaded(&refs, 900, 13, 1).unwrap();
        for threads in [2usize, 4] {
            let out = pairwise_threaded(&refs, 900, 13, threads).unwrap();
            assert_eq!(
                base.as_slice(),
                out.as_slice(),
                "threads {threads} diverged"
            );
        }
        let gbase = grouped_threaded(&refs, 3, 900, 14, 1).unwrap();
        let gpar = grouped_threaded(&refs, 3, 900, 14, 4).unwrap();
        assert_eq!(gbase.as_slice(), gpar.as_slice());
    }

    /// Per-outer-batch context prep: with one worker the tree never
    /// holds more than one merge group's whitened context at a time —
    /// the gauge's peak is exactly the largest single group's bytes,
    /// not a level's worth — and gauging changes no draw.
    #[test]
    fn single_worker_tree_holds_one_context_at_a_time() {
        let sets = gaussian_sets(21, &[0.7, 0.9, 1.1, 1.3], 1.0, 100);
        let refs: Vec<&SampleMatrix> = sets.iter().collect();
        let gauge = MemGauge::default();
        let out =
            pairwise_threaded_gauged(&refs, 50, 23, 1, &gauge).unwrap();
        assert_eq!(out.len(), 50);
        let f = std::mem::size_of::<f64>();
        // Largest merge group: two 100-draw d=1 leaf sets — whitened
        // copies + norm caches + the scale vector. (The root merge's
        // two 50-draw inputs are smaller.)
        let expect = 2 * (100 + 100) * f + f;
        assert_eq!(gauge.peak_bytes(), expect);
        let plain = pairwise_threaded(&refs, 50, 23, 1).unwrap();
        assert_eq!(out.as_slice(), plain.as_slice());
    }

    #[test]
    #[should_panic(expected = "group size")]
    fn grouped_rejects_degenerate_group() {
        let sets = gaussian_sets(1, &[0.0], 1.0, 10);
        let refs: Vec<&SampleMatrix> = sets.iter().collect();
        let _ = grouped(&refs, 1, 10, 0);
    }
}
