//! Pairwise tree reduction (paper sections 3.2 and 4).
//!
//! Applying Algorithm 1 directly to M machines costs O(dTM²) and its
//! acceptance rate decays with M (each sweep perturbs one of M indices
//! of a product of M kernels). The paper's remedy: combine subposteriors
//! in pairs, then combine the pair-outputs in pairs, and so on —
//! ⌈log₂ M⌉ rounds, O(dTM) total work, and each IMG run only ever sees
//! M̃ = 2 components.

use super::nonparametric::nonparametric;
use crate::error::Result;
use crate::rng::Pcg64;
use crate::types::SampleMatrix;

/// Combine M subposterior sample sets by repeated pairing.
pub fn pairwise(
    sets: &[&SampleMatrix],
    t_out: usize,
    seed: u64,
) -> Result<SampleMatrix> {
    super::validate_sets(sets)?;
    let mut rng = Pcg64::seed_from(seed);
    let mut current: Vec<SampleMatrix> =
        sets.iter().map(|s| (*s).clone()).collect();
    while current.len() > 1 {
        let mut next = Vec::with_capacity(current.len().div_ceil(2));
        let mut iter = current.chunks(2);
        for chunk in &mut iter {
            if chunk.len() == 2 {
                let pair: Vec<&SampleMatrix> = vec![&chunk[0], &chunk[1]];
                next.push(nonparametric(&pair, t_out, rng.next_u64())?);
            } else {
                // Odd one out: carried to the next round unchanged.
                next.push(chunk[0].clone());
            }
        }
        current = next;
    }
    Ok(current.pop().unwrap().take(t_out))
}

/// Number of pair-combination invocations performed for M machines
/// (M - 1, matching the paper's O(dTM) complexity claim).
pub fn pair_combinations(m: usize) -> usize {
    m.saturating_sub(1)
}

/// Generalized tree reduction over groups of `group_size` (the paper's
/// "groups of M̃ < M subposteriors", section 3.2). `group_size = 2`
/// recovers [`pairwise`]; larger groups trade IMG acceptance rate for
/// fewer reduction rounds.
pub fn grouped(
    sets: &[&SampleMatrix],
    group_size: usize,
    t_out: usize,
    seed: u64,
) -> Result<SampleMatrix> {
    super::validate_sets(sets)?;
    assert!(group_size >= 2, "group size must be >= 2");
    let mut rng = Pcg64::seed_from(seed);
    let mut current: Vec<SampleMatrix> =
        sets.iter().map(|s| (*s).clone()).collect();
    while current.len() > 1 {
        let mut next = Vec::with_capacity(current.len().div_ceil(group_size));
        for chunk in current.chunks(group_size) {
            if chunk.len() >= 2 {
                let group: Vec<&SampleMatrix> = chunk.iter().collect();
                next.push(nonparametric(&group, t_out, rng.next_u64())?);
            } else {
                next.push(chunk[0].clone());
            }
        }
        current = next;
    }
    Ok(current.pop().unwrap().take(t_out))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::math::linalg::Mat;
    use crate::math::mvn::Mvn;

    fn gaussian_sets(
        seed: u64,
        mus: &[f64],
        var: f64,
        t: usize,
    ) -> Vec<SampleMatrix> {
        let mut rng = Pcg64::seed_from(seed);
        mus.iter()
            .map(|&mu| {
                Mvn::new(vec![mu], Mat::diag(&[var]))
                    .unwrap()
                    .sample_n(t, &mut rng)
            })
            .collect()
    }

    #[test]
    fn pairwise_recovers_gaussian_product_m4() {
        // Four N(μ_m, 1): product = N(mean, 1/4).
        let sets = gaussian_sets(1, &[0.7, 0.9, 1.1, 1.3], 1.0, 3000);
        let refs: Vec<&SampleMatrix> = sets.iter().collect();
        let out = pairwise(&refs, 3000, 2).unwrap();
        assert!((out.mean()[0] - 1.0).abs() < 0.1, "{}", out.mean()[0]);
        let v = out.covariance()[(0, 0)];
        assert!((v - 0.25).abs() < 0.12, "var {v}");
    }

    #[test]
    fn pairwise_handles_odd_m() {
        let sets = gaussian_sets(3, &[0.8, 1.0, 1.2], 1.0, 2000);
        let refs: Vec<&SampleMatrix> = sets.iter().collect();
        let out = pairwise(&refs, 2000, 4).unwrap();
        assert_eq!(out.len(), 2000);
        // IMG chains are autocorrelated; cross-seed sd of this mean is
        // ~0.07, so allow 3σ.
        assert!((out.mean()[0] - 1.0).abs() < 0.25, "{}", out.mean()[0]);
    }

    #[test]
    fn pairwise_single_set_is_passthrough_kde() {
        let sets = gaussian_sets(5, &[2.0], 1.0, 2000);
        let refs: Vec<&SampleMatrix> = sets.iter().collect();
        let out = pairwise(&refs, 1500, 6).unwrap();
        assert_eq!(out.len(), 1500);
        assert!((out.mean()[0] - 2.0).abs() < 0.15);
    }

    #[test]
    fn pair_combination_count() {
        assert_eq!(pair_combinations(1), 0);
        assert_eq!(pair_combinations(2), 1);
        assert_eq!(pair_combinations(10), 9);
    }

    #[test]
    fn grouped_matches_pairwise_quality() {
        // Groups of 3 over 6 gaussians: same product target.
        let sets =
            gaussian_sets(9, &[0.7, 0.8, 0.9, 1.1, 1.2, 1.3], 1.0, 2500);
        let refs: Vec<&SampleMatrix> = sets.iter().collect();
        let out = grouped(&refs, 3, 2500, 10).unwrap();
        assert_eq!(out.len(), 2500);
        assert!((out.mean()[0] - 1.0).abs() < 0.12, "{}", out.mean()[0]);
        // Product of 6 unit-variance gaussians → var 1/6.
        let v = out.covariance()[(0, 0)];
        assert!((v - 1.0 / 6.0).abs() < 0.12, "var {v}");
    }

    #[test]
    #[should_panic(expected = "group size")]
    fn grouped_rejects_degenerate_group() {
        let sets = gaussian_sets(1, &[0.0], 1.0, 10);
        let refs: Vec<&SampleMatrix> = sets.iter().collect();
        let _ = grouped(&refs, 1, 10, 0);
    }
}
