//! Parametric combination (paper section 3.1).
//!
//! Fit `N(μ̂_m, Σ̂_m)` to each machine's draws, form the product Gaussian
//! (Eqs. 3.1-3.2) and sample from it. Asymptotically biased (exactly
//! Gaussian by construction) but converges fastest — the paper's Fig. 3
//! (right) shows it scaling best with dimension.

use super::gaussian_product::fit_and_product;
use crate::error::Result;
use crate::rng::Pcg64;
use crate::types::SampleMatrix;

/// Draw `t_out` samples from the parametric density-product estimate.
pub fn parametric(
    sets: &[&SampleMatrix],
    t_out: usize,
    seed: u64,
) -> Result<SampleMatrix> {
    let (_, product) = fit_and_product(sets)?;
    let mut rng = Pcg64::seed_from(seed);
    Ok(product.sample_n(t_out, &mut rng))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::math::linalg::Mat;
    use crate::math::mvn::Mvn;

    /// Conjugate check: subposteriors N(μ_m, Σ) with equal covariance →
    /// product N(mean of information-weighted μ_m, Σ/M).
    #[test]
    fn parametric_combines_gaussian_subposteriors_exactly() {
        let mut rng = Pcg64::seed_from(3);
        let cov = Mat::diag(&[1.0, 0.5]);
        let mus = [[0.8, -0.2], [1.2, 0.2], [1.0, 0.1], [0.9, -0.1]];
        let sets: Vec<SampleMatrix> = mus
            .iter()
            .map(|mu| {
                Mvn::new(mu.to_vec(), cov.clone())
                    .unwrap()
                    .sample_n(20_000, &mut rng)
            })
            .collect();
        let refs: Vec<&SampleMatrix> = sets.iter().collect();
        let combined = parametric(&refs, 20_000, 7).unwrap();
        let mean = combined.mean();
        let want0 = mus.iter().map(|m| m[0]).sum::<f64>() / 4.0;
        let want1 = mus.iter().map(|m| m[1]).sum::<f64>() / 4.0;
        assert!((mean[0] - want0).abs() < 0.03, "{} vs {want0}", mean[0]);
        assert!((mean[1] - want1).abs() < 0.03, "{} vs {want1}", mean[1]);
        let c = combined.covariance();
        assert!((c[(0, 0)] - 0.25).abs() < 0.02, "var0 {}", c[(0, 0)]);
        assert!((c[(1, 1)] - 0.125).abs() < 0.01, "var1 {}", c[(1, 1)]);
    }

    #[test]
    fn single_machine_is_identity_in_distribution() {
        let mut rng = Pcg64::seed_from(4);
        let gen = Mvn::new(vec![2.0], Mat::diag(&[3.0])).unwrap();
        let s = gen.sample_n(30_000, &mut rng);
        let combined = parametric(&[&s], 30_000, 5).unwrap();
        assert!((combined.mean()[0] - 2.0).abs() < 0.06);
        let v = combined.covariance()[(0, 0)];
        assert!((v - 3.0).abs() < 0.15, "var {v}");
    }

    #[test]
    fn requested_count_respected() {
        let mut rng = Pcg64::seed_from(5);
        let s = Mvn::new(vec![0.0], Mat::diag(&[1.0]))
            .unwrap()
            .sample_n(100, &mut rng);
        let out = parametric(&[&s], 42, 6).unwrap();
        assert_eq!(out.len(), 42);
    }
}
