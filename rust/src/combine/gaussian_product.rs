//! Product of M Gaussian densities (paper Eqs. 3.1-3.2).
//!
//! If `p̂_m = N(μ̂_m, Σ̂_m)`, their product is proportional to
//! `N(μ̂_M, Σ̂_M)` with
//!
//!   Σ̂_M = (Σ_m Σ̂_m⁻¹)⁻¹,   μ̂_M = Σ̂_M (Σ_m Σ̂_m⁻¹ μ̂_m).

use crate::error::Result;
use crate::math::linalg::{spd_inverse_jittered, Mat};
use crate::math::mvn::Mvn;
use crate::types::SampleMatrix;

/// Per-machine Gaussian estimate (sample mean + covariance + cached
/// precision).
#[derive(Debug, Clone)]
pub struct GaussianEstimate {
    pub mean: Vec<f64>,
    pub cov: Mat,
    pub prec: Mat,
}

impl GaussianEstimate {
    /// Fit from one machine's draws.
    pub fn fit(samples: &SampleMatrix) -> Result<Self> {
        let mean = samples.mean();
        let cov = samples.covariance();
        let prec = spd_inverse_jittered(&cov)?;
        Ok(GaussianEstimate { mean, cov, prec })
    }

    /// The fitted `N(μ̂_m, Σ̂_m)` as a sampleable distribution.
    pub fn mvn(&self) -> Result<Mvn> {
        Mvn::new(self.mean.clone(), self.cov.clone())
    }
}

/// Combine per-machine Gaussian estimates into the product Gaussian
/// `N(μ̂_M, Σ̂_M)` (Eqs. 3.1-3.2).
pub fn gaussian_product(estimates: &[GaussianEstimate]) -> Result<Mvn> {
    assert!(!estimates.is_empty());
    let d = estimates[0].mean.len();
    let mut prec_sum = Mat::zeros(d, d);
    let mut weighted_mean_sum = vec![0.0; d];
    for est in estimates {
        prec_sum.add_assign(&est.prec)?;
        let pm = est.prec.matvec(&est.mean)?;
        for j in 0..d {
            weighted_mean_sum[j] += pm[j];
        }
    }
    let cov = spd_inverse_jittered(&prec_sum)?;
    let mean = cov.matvec(&weighted_mean_sum)?;
    Mvn::new(mean, cov)
}

/// Fit all machines and form the product in one call.
pub fn fit_and_product(sets: &[&SampleMatrix]) -> Result<(Vec<GaussianEstimate>, Mvn)> {
    let estimates: Vec<GaussianEstimate> = sets
        .iter()
        .map(|s| GaussianEstimate::fit(s))
        .collect::<Result<_>>()?;
    let product = gaussian_product(&estimates)?;
    Ok((estimates, product))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    /// Two 1-d Gaussians: product precision/mean has the textbook form.
    #[test]
    fn product_of_two_scalars() {
        let a = GaussianEstimate {
            mean: vec![1.0],
            cov: Mat::diag(&[2.0]),
            prec: Mat::diag(&[0.5]),
        };
        let b = GaussianEstimate {
            mean: vec![3.0],
            cov: Mat::diag(&[1.0]),
            prec: Mat::diag(&[1.0]),
        };
        let prod = gaussian_product(&[a, b]).unwrap();
        // prec = 1.5, mean = (0.5·1 + 1·3)/1.5 = 3.5/1.5.
        assert!((prod.mean()[0] - 3.5 / 1.5).abs() < 1e-12);
        let lp0 = prod.logpdf(&[3.5 / 1.5]);
        let lp1 = prod.logpdf(&[3.5 / 1.5 + 0.1]);
        // Curvature implies var = 1/1.5: logpdf drop = 0.1²·1.5/2.
        assert!(((lp0 - lp1) - 0.5 * 0.01 * 1.5).abs() < 1e-10);
    }

    /// Product of M identical Gaussians: same mean, covariance / M.
    #[test]
    fn product_of_identical() {
        let est = GaussianEstimate {
            mean: vec![2.0, -1.0],
            cov: Mat::diag(&[4.0, 9.0]),
            prec: Mat::diag(&[0.25, 1.0 / 9.0]),
        };
        let prod =
            gaussian_product(&[est.clone(), est.clone(), est.clone(), est])
                .unwrap();
        assert!((prod.mean()[0] - 2.0).abs() < 1e-12);
        assert!((prod.mean()[1] + 1.0).abs() < 1e-12);
        // Sample and check variance ≈ diag(1, 2.25).
        let mut rng = Pcg64::seed_from(1);
        let s = prod.sample_n(40_000, &mut rng);
        let c = s.covariance();
        assert!((c[(0, 0)] - 1.0).abs() < 0.05, "{}", c[(0, 0)]);
        assert!((c[(1, 1)] - 2.25).abs() < 0.1, "{}", c[(1, 1)]);
    }

    /// Fitting recovers the generating Gaussian.
    #[test]
    fn fit_recovers_moments() {
        let mut rng = Pcg64::seed_from(2);
        let gen = Mvn::new(
            vec![1.0, -2.0],
            Mat::from_vec(vec![2.0, 0.6, 0.6, 1.0], 2, 2).unwrap(),
        )
        .unwrap();
        let s = gen.sample_n(30_000, &mut rng);
        let est = GaussianEstimate::fit(&s).unwrap();
        assert!((est.mean[0] - 1.0).abs() < 0.05);
        assert!((est.cov[(0, 1)] - 0.6).abs() < 0.05);
        // prec · cov ≈ I.
        let prod = est.prec.matmul(&est.cov).unwrap();
        assert!((prod[(0, 0)] - 1.0).abs() < 1e-8);
        assert!(prod[(0, 1)].abs() < 1e-8);
    }
}
