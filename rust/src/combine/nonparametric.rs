//! Nonparametric combination — Algorithm 1 of the paper.
//!
//! The KDE product of the M subposteriors is a mixture of `T^M`
//! Gaussians; component `t· = (t_1 … t_M)` has mean `θ̄_t = mean_m
//! θ^m_{t_m}`, covariance `(h²/M) I` and unnormalized weight
//!
//!   w_t = Π_m N(θ^m_{t_m} | θ̄_t, h² I).
//!
//! Components are sampled by Independent Metropolis within Gibbs: each
//! inner step redraws one machine's index uniformly and accepts with
//! probability `min(1, w_c / w_t)`; the bandwidth anneals as
//! `h_i = i^{-1/(4+d)}`.
//!
//! ## O(d) proposal evaluation
//!
//! `log w_t = -(Md/2)·log(2πh²) - D_t/(2h²)` with the scatter
//! `D_t = Σ_m |θ^m_{t_m} - θ̄_t|² = Q_t - |S_t|²/M`, where
//! `S_t = Σ_m θ^m_{t_m}` and `Q_t = Σ_m |θ^m_{t_m}|²`. Swapping one
//! index updates `S_t` in O(d) and `Q_t` in O(1) (per-draw squared norms
//! are precomputed), so an IMG sweep costs O(dM) instead of the naive
//! O(dM²) — this is the L3 hot-path optimization measured in
//! EXPERIMENTS.md §Perf. The scatter is recomputed exactly every few
//! hundred accepted swaps to stop fp drift.
//!
//! ## Parallel runtime
//!
//! The restart chunks of the annealed chain are *independent* IMG
//! chains: each gets a fresh `t·`, its own bandwidth schedule and — in
//! [`nonparametric_threaded`] — its own [`Pcg64`] stream split off the
//! root seed ([`Pcg64::split_n`]). Chains share one read-only
//! [`CombineContext`] (whitening + squared-norm cache, built once in
//! parallel across machines) by borrow and run concurrently on a
//! scoped worker pool; outputs are concatenated in chunk order. Because
//! both the restart plan and the per-chunk streams are pure functions of
//! `(t_out, seed)`, the combined draws are byte-identical for a fixed
//! seed at any thread count.

use std::borrow::Cow;
use std::sync::Arc;

use super::CombineContext;
use crate::error::Result;
use crate::kernel::{default_kernel, CombineKernel};
use crate::rng::Pcg64;
use crate::stats::kde::{annealed_bandwidth, AnnealSchedule};
use crate::types::SampleMatrix;

/// Draw `t_out` samples from the nonparametric density-product estimate
/// (Algorithm 1) on a single thread. Runs in whitened coordinates (see
/// [`super::whitening_scales`]) so the annealed bandwidth is relative to
/// the subposterior scale.
pub fn nonparametric(
    sets: &[&SampleMatrix],
    t_out: usize,
    seed: u64,
) -> Result<SampleMatrix> {
    nonparametric_threaded(sets, t_out, seed, 1)
}

/// [`nonparametric`] with the restart chains fanned across `threads`
/// workers (`0` = all cores). Byte-identical output for a fixed seed at
/// any thread count.
pub fn nonparametric_threaded(
    sets: &[&SampleMatrix],
    t_out: usize,
    seed: u64,
    threads: usize,
) -> Result<SampleMatrix> {
    nonparametric_with(sets, t_out, seed, threads, &default_kernel())
}

/// [`nonparametric_threaded`] on an explicit compute-kernel backend
/// ([`crate::kernel`]) — the combine dispatch's entry point. The
/// kernel builds the context's norm cache; CPU backends are
/// bit-identical, so the draws don't depend on which one ran.
pub(crate) fn nonparametric_with(
    sets: &[&SampleMatrix],
    t_out: usize,
    seed: u64,
    threads: usize,
    kernel: &Arc<dyn CombineKernel>,
) -> Result<SampleMatrix> {
    super::validate_sets(sets)?;
    let threads = super::resolve_threads(threads);
    let ctx =
        CombineContext::prepare_with(sets, threads, Arc::clone(kernel))?;
    nonparametric_with_context(&ctx, t_out, seed, threads)
}

/// Run the nonparametric combiner over an already-prepared
/// [`CombineContext`] — the per-level entry point of the pairwise tree,
/// which whitens all of a level's merge groups up front and then runs
/// each merge over its prepared context. Byte-identical to
/// [`nonparametric_threaded`] over the same sets: the context build is
/// itself thread-count invariant, so only where it happens moves.
pub fn nonparametric_with_context(
    ctx: &CombineContext,
    t_out: usize,
    seed: u64,
    threads: usize,
) -> Result<SampleMatrix> {
    // Same degenerate-input policy as the plain entry point's
    // validate_sets: an empty machine must stay an error, not a silent
    // empty result.
    ctx.validate_non_empty()?;
    let threads = super::resolve_threads(threads);
    let mut out = run_restarts_parallel(
        ctx,
        t_out,
        super::RESTART_CHUNK0,
        super::RESTART_SWEEPS,
        seed,
        threads,
    )?;
    super::unwhiten(&mut out, ctx.scales());
    Ok(out)
}

/// Algorithm 1 exactly as printed (absolute bandwidth, no whitening) —
/// kept for the ablation bench; use [`nonparametric`] in practice.
pub fn nonparametric_absolute_h(
    sets: &[&SampleMatrix],
    t_out: usize,
    seed: u64,
) -> Result<SampleMatrix> {
    let mut img = Img::new(sets);
    Ok(img.run(t_out, &mut Pcg64::seed_from(seed)))
}

/// Run the restart plan for `t_out` draws as independent IMG chains
/// over a shared [`CombineContext`], `threads`-wide. Returns draws in
/// whitened coordinates (callers unwhiten).
///
/// Restarting and extra sweeps both leave each chain's target
/// unchanged; they counter the freeze of the annealed index chain on
/// well-separated subposteriors (the paper's own low-acceptance caveat,
/// section 3.2). Chunk sizes follow [`super::restart_plan`]: geometric
/// growth capped at `t_out/8` so the longest chain never dominates
/// wall-clock, with the first 20% of each chunk discarded as
/// per-restart warmup. The cap grows linearly in `t_out`, so every
/// non-tail chunk's annealed bandwidth still → 0 as `t_out` → ∞:
/// asymptotic exactness is preserved.
pub fn run_restarts_parallel(
    ctx: &CombineContext,
    t_out: usize,
    chunk0: usize,
    sweeps: usize,
    seed: u64,
    threads: usize,
) -> Result<SampleMatrix> {
    // One shared h_i table per combine call (ROADMAP rung (c)): long
    // enough for the longest chain in the plan, read by every chain —
    // bit-identical to each chain computing its own powf series.
    let schedule = AnnealSchedule::new(
        ctx.dim(),
        super::max_chain_len(t_out, chunk0),
    );
    super::run_restart_chains(
        ctx.dim(),
        t_out,
        chunk0,
        seed,
        threads,
        |keep, warmup, mut rng| {
            let mut img = Img::with_context(ctx);
            Ok(img
                .run_sweeps_scheduled(
                    keep + warmup,
                    sweeps,
                    &mut rng,
                    &schedule,
                )
                .split_off_burnin(warmup))
        },
    )
}

/// IMG sampler state over M subposterior sample sets.
///
/// Holds only the per-chain mutable state (indices, running sums,
/// telemetry); the sample sets and the squared-norm cache are borrowed,
/// so many chains can share one [`CombineContext`] without copying.
pub struct Img<'a> {
    sets: Vec<&'a SampleMatrix>,
    dim: usize,
    /// Current component indices t_m.
    indices: Vec<usize>,
    /// S_t = Σ_m θ^m_{t_m}.
    sum: Vec<f64>,
    /// Q_t = Σ_m |θ^m_{t_m}|².
    sq_sum: f64,
    /// Precomputed |θ^m_t|² per machine per draw — borrowed from a
    /// shared [`CombineContext`], or owned when built standalone.
    norms: Cow<'a, [Vec<f64>]>,
    /// Accepted swaps since the last exact recompute.
    since_recompute: usize,
    /// Telemetry: proposals and acceptances.
    pub proposals: usize,
    pub accepts: usize,
}

impl<'a> Img<'a> {
    /// Standalone chain over caller-provided sets (norms computed here).
    pub fn new(sets: &'a [&'a SampleMatrix]) -> Self {
        assert!(!sets.is_empty());
        let norms: Vec<Vec<f64>> =
            sets.iter().map(|s| super::row_norms(s)).collect();
        Self::from_parts(sets.to_vec(), Cow::Owned(norms))
    }

    /// Chain sharing a precomputed read-only [`CombineContext`] — the
    /// multi-chain path; no per-chain norm recomputation.
    pub fn with_context(ctx: &'a CombineContext) -> Self {
        Self::from_parts(
            ctx.sets().iter().collect(),
            Cow::Borrowed(ctx.norms()),
        )
    }

    fn from_parts(
        sets: Vec<&'a SampleMatrix>,
        norms: Cow<'a, [Vec<f64>]>,
    ) -> Self {
        assert!(!sets.is_empty());
        let dim = sets[0].dim();
        let machines = sets.len();
        let mut img = Img {
            sets,
            dim,
            indices: vec![0; machines],
            sum: vec![0.0; dim],
            sq_sum: 0.0,
            norms,
            since_recompute: 0,
            proposals: 0,
            accepts: 0,
        };
        img.recompute();
        img
    }

    /// Number of machines.
    pub fn machines(&self) -> usize {
        self.sets.len()
    }

    /// Exactly recompute S_t and Q_t from the current indices.
    fn recompute(&mut self) {
        self.sum.iter_mut().for_each(|v| *v = 0.0);
        self.sq_sum = 0.0;
        for (m, s) in self.sets.iter().enumerate() {
            let row = s.row(self.indices[m]);
            for j in 0..self.dim {
                self.sum[j] += row[j];
            }
            self.sq_sum += self.norms[m][self.indices[m]];
        }
        self.since_recompute = 0;
    }

    /// Run Algorithm 1 for `t_out` outer iterations, drawing one
    /// combined sample per iteration.
    pub fn run(&mut self, t_out: usize, rng: &mut Pcg64) -> SampleMatrix {
        self.run_sweeps(t_out, 1, rng)
    }

    /// [`Img::run`] with `sweeps` index sweeps per emitted draw.
    ///
    /// The inner loop is allocation-free: proposal evaluation works on
    /// the cached `(S_t, Q_t)` pair and the shared norm table, and the
    /// emitted draw reuses one scratch vector.
    pub fn run_sweeps(
        &mut self,
        t_out: usize,
        sweeps: usize,
        rng: &mut Pcg64,
    ) -> SampleMatrix {
        // Standalone chains tabulate their own schedule; the parallel
        // restart runtime shares one table across all chains
        // ([`run_restarts_parallel`]). Same values either way.
        let schedule = AnnealSchedule::new(self.dim, t_out);
        self.run_sweeps_scheduled(t_out, sweeps, rng, &schedule)
    }

    /// [`Img::run_sweeps`] over a caller-provided bandwidth schedule
    /// table — bit-identical (the table is filled by the same
    /// `annealed_bandwidth`), but the `powf` series is paid once per
    /// combine call instead of once per chain.
    pub fn run_sweeps_scheduled(
        &mut self,
        t_out: usize,
        sweeps: usize,
        rng: &mut Pcg64,
        schedule: &AnnealSchedule,
    ) -> SampleMatrix {
        let m = self.sets.len() as f64;
        // Line 1: draw t· uniformly.
        for (idx, s) in self.indices.iter_mut().zip(&self.sets) {
            *idx = rng.uniform_usize(s.len());
        }
        self.recompute();

        let mut out = SampleMatrix::with_capacity(self.dim, t_out);
        let mut theta = vec![0.0; self.dim];
        for i in 1..=t_out {
            // Line 3: anneal the bandwidth (shared table lookup).
            let h = schedule.h(i);
            let h2 = h * h;
            let mut d_cur = super::scatter(self.sq_sum, &self.sum, m);
            // Lines 4-11: `sweeps` IMG sweeps over machines.
            for mach_sweep in 0..(self.sets.len() * sweeps.max(1)) {
                let mach = mach_sweep % self.sets.len();
                let set = self.sets[mach];
                let old_idx = self.indices[mach];
                let new_idx = rng.uniform_usize(set.len());
                self.proposals += 1;
                if new_idx == old_idx {
                    self.accepts += 1;
                    continue;
                }
                let old_row = set.row(old_idx);
                let new_row = set.row(new_idx);
                // O(d): proposed S', Q' and scatter.
                let mut s2_new = 0.0;
                for j in 0..self.dim {
                    let sj = self.sum[j] - old_row[j] + new_row[j];
                    s2_new += sj * sj;
                }
                let q_new = self.sq_sum - self.norms[mach][old_idx]
                    + self.norms[mach][new_idx];
                let d_new = (q_new - s2_new / m).max(0.0);
                // log w_c - log w_t = -(D_c - D_t)/(2h²).
                let log_ratio = -(d_new - d_cur) / (2.0 * h2);
                if log_ratio >= 0.0 || rng.uniform().ln() < log_ratio {
                    // Accept: commit the swap.
                    for j in 0..self.dim {
                        self.sum[j] += new_row[j] - old_row[j];
                    }
                    self.sq_sum = q_new;
                    self.indices[mach] = new_idx;
                    d_cur = d_new;
                    self.accepts += 1;
                    self.since_recompute += 1;
                    if self.since_recompute >= 512 {
                        self.recompute();
                        d_cur = super::scatter(self.sq_sum, &self.sum, m);
                    }
                }
            }
            // Line 12: θ_i ~ N(θ̄_t, (h²/M) I).
            let sd = (h2 / m).sqrt();
            for j in 0..self.dim {
                theta[j] = self.sum[j] / m + sd * rng.normal();
            }
            out.push(&theta);
        }
        out
    }

    /// Acceptance rate so far.
    pub fn accept_rate(&self) -> f64 {
        if self.proposals == 0 {
            f64::NAN
        } else {
            self.accepts as f64 / self.proposals as f64
        }
    }
}

/// Naive reference implementation of Algorithm 1 with O(dM) weight
/// evaluation per proposal (recomputes θ̄ and the full product). Used by
/// tests to validate the O(d) fast path and by the perf ablation bench.
///
/// Proposals swap the candidate index in place and restore it on reject
/// (no `indices.clone()` per proposal), and the scatter evaluation uses
/// a reusable mean buffer — the reference stays O(dM) per proposal but
/// heap-allocation-free, so the ablation bench isolates the algorithmic
/// O(dM) → O(d) gap rather than allocator traffic.
pub fn nonparametric_naive(
    sets: &[&SampleMatrix],
    t_out: usize,
    seed: u64,
) -> Result<SampleMatrix> {
    // Same whitening as the fast path so outputs are comparable 1:1.
    let scales = super::whitening_scales(sets);
    let whitened = super::whiten(sets, &scales);
    let sets: Vec<&SampleMatrix> = whitened.iter().collect();
    let sets = &sets[..];
    let mut rng = Pcg64::seed_from(seed);
    let m_count = sets.len();
    let m = m_count as f64;
    let dim = sets[0].dim();
    let mut indices: Vec<usize> =
        sets.iter().map(|s| rng.uniform_usize(s.len())).collect();

    // Full O(dM) scatter: D_t = Σ_m |θ^m - θ̄|², via a scratch mean.
    fn scatter_full(
        sets: &[&SampleMatrix],
        idx: &[usize],
        mean: &mut [f64],
        m: f64,
    ) -> f64 {
        mean.iter_mut().for_each(|v| *v = 0.0);
        for (mach, s) in sets.iter().enumerate() {
            for (j, v) in s.row(idx[mach]).iter().enumerate() {
                mean[j] += v / m;
            }
        }
        let mut d = 0.0;
        for (mach, s) in sets.iter().enumerate() {
            d += crate::math::linalg::sq_dist(s.row(idx[mach]), mean);
        }
        d
    }

    let mut mean = vec![0.0; dim];
    let mut out = SampleMatrix::with_capacity(dim, t_out);
    let mut theta = vec![0.0; dim];
    let mut d_cur = scatter_full(sets, &indices, &mut mean, m);
    for i in 1..=t_out {
        let h = annealed_bandwidth(i, dim);
        let h2 = h * h;
        for mach in 0..m_count {
            let old_idx = indices[mach];
            // Swap the candidate in, evaluate, restore on reject.
            indices[mach] = rng.uniform_usize(sets[mach].len());
            let d_new = scatter_full(sets, &indices, &mut mean, m);
            let log_ratio = -(d_new - d_cur) / (2.0 * h2);
            if log_ratio >= 0.0 || rng.uniform().ln() < log_ratio {
                d_cur = d_new;
            } else {
                indices[mach] = old_idx;
            }
        }
        mean.iter_mut().for_each(|v| *v = 0.0);
        for (mach, s) in sets.iter().enumerate() {
            for (j, v) in s.row(indices[mach]).iter().enumerate() {
                mean[j] += v / m;
            }
        }
        let sd = (h2 / m).sqrt();
        for j in 0..dim {
            theta[j] = mean[j] + sd * rng.normal();
        }
        out.push(&theta);
    }
    super::unwhiten(&mut out, &scales);
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::math::linalg::Mat;
    use crate::math::mvn::Mvn;

    fn gaussian_sets(
        seed: u64,
        mus: &[Vec<f64>],
        var: f64,
        t: usize,
    ) -> Vec<SampleMatrix> {
        let mut rng = Pcg64::seed_from(seed);
        mus.iter()
            .map(|mu| {
                Mvn::new(mu.clone(), Mat::scaled_identity(mu.len(), var))
                    .unwrap()
                    .sample_n(t, &mut rng)
            })
            .collect()
    }

    /// Product of Gaussian subposteriors: nonparametric combiner must
    /// recover mean ≈ average of means, var ≈ var/M.
    #[test]
    fn recovers_gaussian_product() {
        let mus = vec![vec![0.6, -0.4], vec![1.0, 0.0], vec![1.4, 0.4]];
        let sets = gaussian_sets(1, &mus, 1.0, 8000);
        let refs: Vec<&SampleMatrix> = sets.iter().collect();
        // The IMG index chain mixes slowly at first (large annealed h);
        // discard its transient like any MCMC output.
        let out = nonparametric(&refs, 8000, 2).unwrap().split_off_burnin(2000);
        let mean = out.mean();
        assert!((mean[0] - 1.0).abs() < 0.15, "mean0 {}", mean[0]);
        assert!((mean[1] - 0.0).abs() < 0.15, "mean1 {}", mean[1]);
        let c = out.covariance();
        // True product variance = 1/3 per dim (KDE widens it by ~h²).
        assert!((c[(0, 0)] - 1.0 / 3.0).abs() < 0.15, "var {}", c[(0, 0)]);
    }

    /// The O(d) fast path and the naive O(dM) implementation follow the
    /// same distribution of outputs (identical RNG stream → identical
    /// accept decisions → identical draws). Compare single plain runs
    /// (no restarts/extra sweeps) over identically whitened inputs.
    #[test]
    fn fast_path_matches_naive_exactly() {
        let mus = vec![vec![0.0, 0.0], vec![0.5, -0.5]];
        let sets = gaussian_sets(3, &mus, 0.5, 300);
        let refs: Vec<&SampleMatrix> = sets.iter().collect();
        let scales = crate::combine::whitening_scales(&refs);
        let whitened = crate::combine::whiten(&refs, &scales);
        let wrefs: Vec<&SampleMatrix> = whitened.iter().collect();
        let mut img = Img::new(&wrefs);
        let mut fast = img.run(400, &mut Pcg64::seed_from(11));
        crate::combine::unwhiten(&mut fast, &scales);
        let naive = nonparametric_naive(&refs, 400, 11).unwrap();
        assert_eq!(fast.len(), naive.len());
        for i in 0..fast.len() {
            for j in 0..2 {
                assert!(
                    (fast.row(i)[j] - naive.row(i)[j]).abs() < 1e-8,
                    "draw {i} dim {j}: {} vs {}",
                    fast.row(i)[j],
                    naive.row(i)[j]
                );
            }
        }
    }

    /// A chain sharing a [`CombineContext`] is bit-identical to a
    /// standalone chain over the same whitened sets — the context cache
    /// only moves work, never changes it.
    #[test]
    fn context_chain_matches_standalone() {
        let mus = vec![vec![0.0; 3], vec![0.3; 3], vec![-0.3; 3]];
        let sets = gaussian_sets(12, &mus, 1.0, 250);
        let refs: Vec<&SampleMatrix> = sets.iter().collect();
        let ctx = crate::combine::CombineContext::prepare(&refs, 2);
        let wsets = ctx.sets().to_vec();
        let wrefs: Vec<&SampleMatrix> = wsets.iter().collect();

        let mut a = Img::with_context(&ctx);
        let out_a = a.run_sweeps(300, 2, &mut Pcg64::seed_from(44));
        let mut b = Img::new(&wrefs);
        let out_b = b.run_sweeps(300, 2, &mut Pcg64::seed_from(44));
        assert_eq!(out_a.as_slice(), out_b.as_slice());
        assert_eq!(a.proposals, b.proposals);
        assert_eq!(a.accepts, b.accepts);
    }

    /// Parallel restart runtime: byte-identical output for a fixed seed
    /// at 1, 2, and 4 threads.
    #[test]
    fn threaded_output_independent_of_thread_count() {
        let mus = vec![vec![0.5, -0.5], vec![1.0, 0.0]];
        let sets = gaussian_sets(21, &mus, 1.0, 400);
        let refs: Vec<&SampleMatrix> = sets.iter().collect();
        let base = nonparametric_threaded(&refs, 1500, 7, 1).unwrap();
        assert_eq!(base.len(), 1500);
        for threads in [2usize, 4] {
            let out =
                nonparametric_threaded(&refs, 1500, 7, threads).unwrap();
            assert_eq!(
                base.as_slice(),
                out.as_slice(),
                "threads {threads} diverged"
            );
        }
    }

    /// Single machine: the estimate is that machine's KDE, so the
    /// combined draws must match its moments.
    #[test]
    fn single_machine_reproduces_input() {
        let sets = gaussian_sets(4, &[vec![2.0]], 1.5, 6000);
        let refs: Vec<&SampleMatrix> = sets.iter().collect();
        let out = nonparametric(&refs, 6000, 5).unwrap();
        assert!((out.mean()[0] - 2.0).abs() < 0.08);
        let v = out.covariance()[(0, 0)];
        assert!((v - 1.5).abs() < 0.2, "var {v}");
    }

    #[test]
    fn acceptance_telemetry_sane() {
        let mus = vec![vec![0.0; 2]; 5];
        let sets = gaussian_sets(6, &mus, 1.0, 500);
        let refs: Vec<&SampleMatrix> = sets.iter().collect();
        let mut img = Img::new(&refs);
        let mut rng = Pcg64::seed_from(9);
        let _ = img.run(500, &mut rng);
        assert_eq!(img.proposals, 500 * 5);
        let rate = img.accept_rate();
        assert!(rate > 0.05 && rate <= 1.0, "rate {rate}");
    }

    /// Overlapping subposteriors → higher IMG acceptance than disjoint
    /// ones (the failure mode pairwise combination addresses).
    #[test]
    fn acceptance_drops_with_separation() {
        let near = gaussian_sets(7, &[vec![0.0], vec![0.2]], 1.0, 400);
        let far = gaussian_sets(8, &[vec![0.0], vec![6.0]], 1.0, 400);
        let rate = |sets: &[SampleMatrix]| {
            let refs: Vec<&SampleMatrix> = sets.iter().collect();
            let mut img = Img::new(&refs);
            let mut rng = Pcg64::seed_from(10);
            let _ = img.run(600, &mut rng);
            img.accept_rate()
        };
        assert!(rate(&near) > rate(&far));
    }
}
