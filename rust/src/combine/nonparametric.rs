//! Nonparametric combination — Algorithm 1 of the paper.
//!
//! The KDE product of the M subposteriors is a mixture of `T^M`
//! Gaussians; component `t· = (t_1 … t_M)` has mean `θ̄_t = mean_m
//! θ^m_{t_m}`, covariance `(h²/M) I` and unnormalized weight
//!
//!   w_t = Π_m N(θ^m_{t_m} | θ̄_t, h² I).
//!
//! Components are sampled by Independent Metropolis within Gibbs: each
//! inner step redraws one machine's index uniformly and accepts with
//! probability `min(1, w_c / w_t)`; the bandwidth anneals as
//! `h_i = i^{-1/(4+d)}`.
//!
//! ## O(d) proposal evaluation
//!
//! `log w_t = -(Md/2)·log(2πh²) - D_t/(2h²)` with the scatter
//! `D_t = Σ_m |θ^m_{t_m} - θ̄_t|² = Q_t - |S_t|²/M`, where
//! `S_t = Σ_m θ^m_{t_m}` and `Q_t = Σ_m |θ^m_{t_m}|²`. Swapping one
//! index updates `S_t` in O(d) and `Q_t` in O(1) (per-draw squared norms
//! are precomputed), so an IMG sweep costs O(dM) instead of the naive
//! O(dM²) — this is the L3 hot-path optimization measured in
//! EXPERIMENTS.md §Perf. The scatter is recomputed exactly every few
//! hundred accepted swaps to stop fp drift.

use crate::error::Result;
use crate::rng::Pcg64;
use crate::stats::kde::annealed_bandwidth;
use crate::types::SampleMatrix;

/// Draw `t_out` samples from the nonparametric density-product estimate
/// (Algorithm 1). Runs in whitened coordinates (see
/// [`super::whitening_scales`]) so the annealed bandwidth is relative to
/// the subposterior scale.
pub fn nonparametric(
    sets: &[&SampleMatrix],
    t_out: usize,
    seed: u64,
) -> Result<SampleMatrix> {
    let scales = super::whitening_scales(sets);
    let whitened = super::whiten(sets, &scales);
    let refs: Vec<&SampleMatrix> = whitened.iter().collect();
    let mut img = Img::new(&refs);
    // Restarted, multi-sweep IMG (see Img::run_restarts): fresh t·
    // draws bound the freeze as h anneals, extra sweeps decorrelate.
    let mut out =
        img.run_restarts(t_out, 500, 3, &mut Pcg64::seed_from(seed));
    super::unwhiten(&mut out, &scales);
    Ok(out)
}

/// Algorithm 1 exactly as printed (absolute bandwidth, no whitening) —
/// kept for the ablation bench; use [`nonparametric`] in practice.
pub fn nonparametric_absolute_h(
    sets: &[&SampleMatrix],
    t_out: usize,
    seed: u64,
) -> Result<SampleMatrix> {
    let mut img = Img::new(sets);
    Ok(img.run(t_out, &mut Pcg64::seed_from(seed)))
}

/// IMG sampler state over M subposterior sample sets.
pub struct Img<'a> {
    sets: &'a [&'a SampleMatrix],
    dim: usize,
    /// Current component indices t_m.
    indices: Vec<usize>,
    /// S_t = Σ_m θ^m_{t_m}.
    sum: Vec<f64>,
    /// Q_t = Σ_m |θ^m_{t_m}|².
    sq_sum: f64,
    /// Precomputed |θ^m_t|² per machine per draw.
    norms: Vec<Vec<f64>>,
    /// Accepted swaps since the last exact recompute.
    since_recompute: usize,
    /// Telemetry: proposals and acceptances.
    pub proposals: usize,
    pub accepts: usize,
}

impl<'a> Img<'a> {
    pub fn new(sets: &'a [&'a SampleMatrix]) -> Self {
        assert!(!sets.is_empty());
        let dim = sets[0].dim();
        let norms: Vec<Vec<f64>> = sets
            .iter()
            .map(|s| s.rows().map(|r| r.iter().map(|v| v * v).sum()).collect())
            .collect();
        let mut img = Img {
            sets,
            dim,
            indices: vec![0; sets.len()],
            sum: vec![0.0; dim],
            sq_sum: 0.0,
            norms,
            since_recompute: 0,
            proposals: 0,
            accepts: 0,
        };
        img.recompute();
        img
    }

    /// Number of machines.
    pub fn machines(&self) -> usize {
        self.sets.len()
    }

    /// Exactly recompute S_t and Q_t from the current indices.
    fn recompute(&mut self) {
        self.sum.iter_mut().for_each(|v| *v = 0.0);
        self.sq_sum = 0.0;
        for (m, s) in self.sets.iter().enumerate() {
            let row = s.row(self.indices[m]);
            for j in 0..self.dim {
                self.sum[j] += row[j];
            }
            self.sq_sum += self.norms[m][self.indices[m]];
        }
        self.since_recompute = 0;
    }

    /// Scatter D_t = Q_t - |S_t|²/M (≥ 0 up to fp noise).
    #[inline]
    fn scatter(sq_sum: f64, sum: &[f64], m: f64) -> f64 {
        let s2: f64 = sum.iter().map(|v| v * v).sum();
        (sq_sum - s2 / m).max(0.0)
    }

    /// Algorithm 1 with restarts: independent IMG chains of `chunk`
    /// draws each (fresh `t·` per chunk, bandwidth re-annealed), with
    /// `sweeps` full index sweeps per emitted draw.
    ///
    /// Restarting and extra sweeps both leave each chain's target
    /// unchanged; they counter the freeze of the annealed index chain on
    /// well-separated subposteriors (the paper's own low-acceptance
    /// caveat, section 3.2). `chunk = t_out, sweeps = 1` recovers the
    /// algorithm exactly as printed.
    /// Chunks grow geometrically (500, 1000, 2000, …) and the first 20%
    /// of each chunk is discarded as per-restart warmup, so the pooled
    /// output's bandwidth-inflation vanishes as T → ∞ (the final chunk
    /// dominates and its h has annealed to (T/2)^{-1/(4+d)} → 0):
    /// asymptotic exactness is preserved.
    pub fn run_restarts(
        &mut self,
        t_out: usize,
        chunk0: usize,
        sweeps: usize,
        rng: &mut Pcg64,
    ) -> SampleMatrix {
        let mut chunk = chunk0.clamp(1, t_out.max(1));
        let mut out = SampleMatrix::with_capacity(self.dim, t_out);
        while out.len() < t_out {
            let n = chunk.min(t_out - out.len());
            let warmup = n / 5;
            let part = self.run_sweeps(n + warmup, sweeps, rng);
            out.extend(&part.split_off_burnin(warmup)).expect("dims agree");
            chunk = chunk.saturating_mul(2);
        }
        out.take(t_out)
    }

    /// Run Algorithm 1 for `t_out` outer iterations, drawing one
    /// combined sample per iteration.
    pub fn run(&mut self, t_out: usize, rng: &mut Pcg64) -> SampleMatrix {
        self.run_sweeps(t_out, 1, rng)
    }

    /// [`Img::run`] with `sweeps` index sweeps per emitted draw.
    pub fn run_sweeps(
        &mut self,
        t_out: usize,
        sweeps: usize,
        rng: &mut Pcg64,
    ) -> SampleMatrix {
        let m = self.sets.len() as f64;
        // Line 1: draw t· uniformly.
        for (idx, s) in self.indices.iter_mut().zip(self.sets) {
            *idx = rng.uniform_usize(s.len());
        }
        self.recompute();

        let mut out = SampleMatrix::with_capacity(self.dim, t_out);
        let mut theta = vec![0.0; self.dim];
        for i in 1..=t_out {
            // Line 3: anneal the bandwidth.
            let h = annealed_bandwidth(i, self.dim);
            let h2 = h * h;
            let mut d_cur = Self::scatter(self.sq_sum, &self.sum, m);
            // Lines 4-11: `sweeps` IMG sweeps over machines.
            for mach_sweep in 0..(self.sets.len() * sweeps.max(1)) {
                let mach = mach_sweep % self.sets.len();
                let set = self.sets[mach];
                let old_idx = self.indices[mach];
                let new_idx = rng.uniform_usize(set.len());
                self.proposals += 1;
                if new_idx == old_idx {
                    self.accepts += 1;
                    continue;
                }
                let old_row = set.row(old_idx);
                let new_row = set.row(new_idx);
                // O(d): proposed S', Q' and scatter.
                let mut s2_new = 0.0;
                for j in 0..self.dim {
                    let sj = self.sum[j] - old_row[j] + new_row[j];
                    s2_new += sj * sj;
                }
                let q_new = self.sq_sum - self.norms[mach][old_idx]
                    + self.norms[mach][new_idx];
                let d_new = (q_new - s2_new / m).max(0.0);
                // log w_c - log w_t = -(D_c - D_t)/(2h²).
                let log_ratio = -(d_new - d_cur) / (2.0 * h2);
                if log_ratio >= 0.0 || rng.uniform().ln() < log_ratio {
                    // Accept: commit the swap.
                    for j in 0..self.dim {
                        self.sum[j] += new_row[j] - old_row[j];
                    }
                    self.sq_sum = q_new;
                    self.indices[mach] = new_idx;
                    d_cur = d_new;
                    self.accepts += 1;
                    self.since_recompute += 1;
                    if self.since_recompute >= 512 {
                        self.recompute();
                        d_cur = Self::scatter(self.sq_sum, &self.sum, m);
                    }
                }
            }
            // Line 12: θ_i ~ N(θ̄_t, (h²/M) I).
            let sd = (h2 / m).sqrt();
            for j in 0..self.dim {
                theta[j] = self.sum[j] / m + sd * rng.normal();
            }
            out.push(&theta);
        }
        out
    }

    /// Acceptance rate so far.
    pub fn accept_rate(&self) -> f64 {
        if self.proposals == 0 {
            f64::NAN
        } else {
            self.accepts as f64 / self.proposals as f64
        }
    }
}

/// Naive reference implementation of Algorithm 1 with O(dM) weight
/// evaluation per proposal (recomputes θ̄ and the full product). Used by
/// tests to validate the O(d) fast path and by the perf ablation bench.
pub fn nonparametric_naive(
    sets: &[&SampleMatrix],
    t_out: usize,
    seed: u64,
) -> Result<SampleMatrix> {
    // Same whitening as the fast path so outputs are comparable 1:1.
    let scales = super::whitening_scales(sets);
    let whitened = super::whiten(sets, &scales);
    let sets: Vec<&SampleMatrix> = whitened.iter().collect();
    let sets = &sets[..];
    let mut rng = Pcg64::seed_from(seed);
    let m_count = sets.len();
    let m = m_count as f64;
    let dim = sets[0].dim();
    let mut indices: Vec<usize> =
        sets.iter().map(|s| rng.uniform_usize(s.len())).collect();

    // Full O(dM) scatter: D_t = Σ_m |θ^m - θ̄|².
    let scatter = |idx: &[usize]| -> f64 {
        let mut mean = vec![0.0; dim];
        for (mach, s) in sets.iter().enumerate() {
            for (j, v) in s.row(idx[mach]).iter().enumerate() {
                mean[j] += v / m;
            }
        }
        let mut d = 0.0;
        for (mach, s) in sets.iter().enumerate() {
            d += crate::math::linalg::sq_dist(s.row(idx[mach]), &mean);
        }
        d
    };

    let mut out = SampleMatrix::with_capacity(dim, t_out);
    let mut theta = vec![0.0; dim];
    for i in 1..=t_out {
        let h = annealed_bandwidth(i, dim);
        let h2 = h * h;
        for mach in 0..m_count {
            let mut cand = indices.clone();
            cand[mach] = rng.uniform_usize(sets[mach].len());
            let log_ratio = -(scatter(&cand) - scatter(&indices)) / (2.0 * h2);
            if log_ratio >= 0.0 || rng.uniform().ln() < log_ratio {
                indices = cand;
            }
        }
        let mut mean = vec![0.0; dim];
        for (mach, s) in sets.iter().enumerate() {
            for (j, v) in s.row(indices[mach]).iter().enumerate() {
                mean[j] += v / m;
            }
        }
        let sd = (h2 / m).sqrt();
        for j in 0..dim {
            theta[j] = mean[j] + sd * rng.normal();
        }
        out.push(&theta);
    }
    super::unwhiten(&mut out, &scales);
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::math::linalg::Mat;
    use crate::math::mvn::Mvn;

    fn gaussian_sets(
        seed: u64,
        mus: &[Vec<f64>],
        var: f64,
        t: usize,
    ) -> Vec<SampleMatrix> {
        let mut rng = Pcg64::seed_from(seed);
        mus.iter()
            .map(|mu| {
                Mvn::new(mu.clone(), Mat::scaled_identity(mu.len(), var))
                    .unwrap()
                    .sample_n(t, &mut rng)
            })
            .collect()
    }

    /// Product of Gaussian subposteriors: nonparametric combiner must
    /// recover mean ≈ average of means, var ≈ var/M.
    #[test]
    fn recovers_gaussian_product() {
        let mus = vec![vec![0.6, -0.4], vec![1.0, 0.0], vec![1.4, 0.4]];
        let sets = gaussian_sets(1, &mus, 1.0, 8000);
        let refs: Vec<&SampleMatrix> = sets.iter().collect();
        // The IMG index chain mixes slowly at first (large annealed h);
        // discard its transient like any MCMC output.
        let out = nonparametric(&refs, 8000, 2).unwrap().split_off_burnin(2000);
        let mean = out.mean();
        assert!((mean[0] - 1.0).abs() < 0.15, "mean0 {}", mean[0]);
        assert!((mean[1] - 0.0).abs() < 0.15, "mean1 {}", mean[1]);
        let c = out.covariance();
        // True product variance = 1/3 per dim (KDE widens it by ~h²).
        assert!((c[(0, 0)] - 1.0 / 3.0).abs() < 0.15, "var {}", c[(0, 0)]);
    }

    /// The O(d) fast path and the naive O(dM) implementation follow the
    /// same distribution of outputs (identical RNG stream → identical
    /// accept decisions → identical draws). Compare single plain runs
    /// (no restarts/extra sweeps) over identically whitened inputs.
    #[test]
    fn fast_path_matches_naive_exactly() {
        let mus = vec![vec![0.0, 0.0], vec![0.5, -0.5]];
        let sets = gaussian_sets(3, &mus, 0.5, 300);
        let refs: Vec<&SampleMatrix> = sets.iter().collect();
        let scales = crate::combine::whitening_scales(&refs);
        let whitened = crate::combine::whiten(&refs, &scales);
        let wrefs: Vec<&SampleMatrix> = whitened.iter().collect();
        let mut img = Img::new(&wrefs);
        let mut fast = img.run(400, &mut Pcg64::seed_from(11));
        crate::combine::unwhiten(&mut fast, &scales);
        let naive = nonparametric_naive(&refs, 400, 11).unwrap();
        assert_eq!(fast.len(), naive.len());
        for i in 0..fast.len() {
            for j in 0..2 {
                assert!(
                    (fast.row(i)[j] - naive.row(i)[j]).abs() < 1e-8,
                    "draw {i} dim {j}: {} vs {}",
                    fast.row(i)[j],
                    naive.row(i)[j]
                );
            }
        }
    }

    /// Single machine: the estimate is that machine's KDE, so the
    /// combined draws must match its moments.
    #[test]
    fn single_machine_reproduces_input() {
        let sets = gaussian_sets(4, &[vec![2.0]], 1.5, 6000);
        let refs: Vec<&SampleMatrix> = sets.iter().collect();
        let out = nonparametric(&refs, 6000, 5).unwrap();
        assert!((out.mean()[0] - 2.0).abs() < 0.08);
        let v = out.covariance()[(0, 0)];
        assert!((v - 1.5).abs() < 0.2, "var {v}");
    }

    #[test]
    fn acceptance_telemetry_sane() {
        let mus = vec![vec![0.0; 2]; 5];
        let sets = gaussian_sets(6, &mus, 1.0, 500);
        let refs: Vec<&SampleMatrix> = sets.iter().collect();
        let mut img = Img::new(&refs);
        let mut rng = Pcg64::seed_from(9);
        let _ = img.run(500, &mut rng);
        assert_eq!(img.proposals, 500 * 5);
        let rate = img.accept_rate();
        assert!(rate > 0.05 && rate <= 1.0, "rate {rate}");
    }

    /// Overlapping subposteriors → higher IMG acceptance than disjoint
    /// ones (the failure mode pairwise combination addresses).
    #[test]
    fn acceptance_drops_with_separation() {
        let near = gaussian_sets(7, &[vec![0.0], vec![0.2]], 1.0, 400);
        let far = gaussian_sets(8, &[vec![0.0], vec![6.0]], 1.0, 400);
        let rate = |sets: &[SampleMatrix]| {
            let refs: Vec<&SampleMatrix> = sets.iter().collect();
            let mut img = Img::new(&refs);
            let mut rng = Pcg64::seed_from(10);
            let _ = img.run(600, &mut rng);
            img.accept_rate()
        };
        assert!(rate(&near) > rate(&far));
    }
}
