//! Subposterior combination — the paper's core contribution (section 3).
//!
//! Given `M` sets of subposterior samples, produce draws from an
//! estimator of the density product `p_1 ⋯ p_M(θ) ∝ p(θ | x^N)`:
//!
//! * [`parametric`] — Gaussian product via the Bernstein-von Mises
//!   approximation (section 3.1; fast, asymptotically biased),
//! * [`nonparametric`] — implicit KDE-product sampling via Independent
//!   Metropolis within Gibbs (Algorithm 1; asymptotically exact),
//! * [`semiparametric`] — Hjort-Glad parametric-start × nonparametric
//!   correction (section 3.3; asymptotically exact), plus the paper's
//!   second variant [`semiparametric_nw`] with nonparametric weights,
//! * [`pairwise`] — the O(dTM) tree-of-pairs reduction (section 3.2/4),
//! * [`baselines`] — subpostAvg / subpostPool / duplicateChainsPool /
//!   consensus-weighted averaging (sections 7-8 comparison methods),
//! * [`online`] — streaming combination (section 4).

pub mod baselines;
pub mod gaussian_product;
pub mod nonparametric;
pub mod online;
pub mod pairwise;
pub mod parametric;
pub mod semiparametric;

pub use baselines::{
    consensus_weighted, duplicate_chains_pool, subpost_avg, subpost_pool,
};
pub use gaussian_product::{gaussian_product, GaussianEstimate};
pub use nonparametric::nonparametric;
pub use online::OnlineCombiner;
pub use pairwise::pairwise;
pub use parametric::parametric;
pub use semiparametric::{semiparametric, semiparametric_nw};

use crate::error::{Error, Result};
use crate::types::{SampleMatrix, SubposteriorSamples};

/// Which combination algorithm to run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CombineMethod {
    Parametric,
    Nonparametric,
    Semiparametric,
    /// Semiparametric components with nonparametric weights (paper's
    /// higher-acceptance variant).
    SemiparametricNw,
    /// Pairwise tree reduction using the nonparametric pair combiner.
    Pairwise,
    /// Baseline: average one sample from each machine.
    SubpostAvg,
    /// Baseline: union of all subposterior samples.
    SubpostPool,
    /// Baseline: consensus Monte Carlo (covariance-weighted averaging).
    ConsensusWeighted,
}

impl CombineMethod {
    pub fn name(&self) -> &'static str {
        match self {
            CombineMethod::Parametric => "parametric",
            CombineMethod::Nonparametric => "nonparametric",
            CombineMethod::Semiparametric => "semiparametric",
            CombineMethod::SemiparametricNw => "semiparametricNW",
            CombineMethod::Pairwise => "pairwise",
            CombineMethod::SubpostAvg => "subpostAvg",
            CombineMethod::SubpostPool => "subpostPool",
            CombineMethod::ConsensusWeighted => "consensusWeighted",
        }
    }

    /// All methods, for sweep-style experiments.
    pub fn all() -> &'static [CombineMethod] {
        &[
            CombineMethod::Parametric,
            CombineMethod::Nonparametric,
            CombineMethod::Semiparametric,
            CombineMethod::SemiparametricNw,
            CombineMethod::Pairwise,
            CombineMethod::SubpostAvg,
            CombineMethod::SubpostPool,
            CombineMethod::ConsensusWeighted,
        ]
    }

    pub fn parse(s: &str) -> Result<CombineMethod> {
        CombineMethod::all()
            .iter()
            .copied()
            .find(|m| m.name().eq_ignore_ascii_case(s))
            .ok_or_else(|| Error::Config(format!("unknown method '{s}'")))
    }
}

/// Dispatch a combination method. `t_out` is the number of combined
/// draws requested (pooling methods return min(t_out, pooled)).
pub fn combine(
    method: CombineMethod,
    subs: &[SubposteriorSamples],
    t_out: usize,
    seed: u64,
) -> Result<SampleMatrix> {
    let sets: Vec<&SampleMatrix> = subs.iter().map(|s| &s.samples).collect();
    combine_sets(method, &sets, t_out, seed)
}

/// Like [`combine`] but over bare sample sets.
pub fn combine_sets(
    method: CombineMethod,
    sets: &[&SampleMatrix],
    t_out: usize,
    seed: u64,
) -> Result<SampleMatrix> {
    validate_sets(sets)?;
    match method {
        CombineMethod::Parametric => parametric(sets, t_out, seed),
        CombineMethod::Nonparametric => nonparametric(sets, t_out, seed),
        CombineMethod::Semiparametric => semiparametric(sets, t_out, seed),
        CombineMethod::SemiparametricNw => {
            semiparametric_nw(sets, t_out, seed)
        }
        CombineMethod::Pairwise => pairwise(sets, t_out, seed),
        CombineMethod::SubpostAvg => subpost_avg(sets, t_out, seed),
        CombineMethod::SubpostPool => Ok(subpost_pool(sets)?.take(t_out)),
        CombineMethod::ConsensusWeighted => {
            consensus_weighted(sets, t_out, seed)
        }
    }
}

/// Per-dimension whitening scale shared by all machines: the average
/// subposterior standard deviation of each coordinate.
///
/// The paper's Algorithm 1 anneals an *absolute* bandwidth
/// `h_i = i^{-1/(4+d)}`; for posteriors concentrated at scales ≪ 1
/// (every large-N experiment in the paper) an absolute unit bandwidth
/// over-smooths catastrophically. Following standard KDE practice the
/// nonparametric/semiparametric combiners therefore operate in whitened
/// coordinates (`θ_j / s_j`) and map their draws back — a diagonal
/// linear transform under which every density-product estimator here is
/// exactly equivariant, so Theorem 5.3's rates are unchanged.
pub(crate) fn whitening_scales(sets: &[&SampleMatrix]) -> Vec<f64> {
    let d = sets[0].dim();
    let mut s = vec![0.0; d];
    let mut counted = 0usize;
    for set in sets {
        if set.len() < 2 {
            continue;
        }
        let v = crate::stats::moments::variances(set);
        for j in 0..d {
            s[j] += v[j].sqrt();
        }
        counted += 1;
    }
    let denom = counted.max(1) as f64;
    for sj in s.iter_mut() {
        *sj = (*sj / denom).max(1e-12);
    }
    s
}

/// Divide every draw's coordinate j by `scales[j]`.
pub(crate) fn whiten(sets: &[&SampleMatrix], scales: &[f64]) -> Vec<SampleMatrix> {
    sets.iter()
        .map(|set| {
            let mut out = SampleMatrix::with_capacity(set.dim(), set.len());
            let mut buf = vec![0.0; set.dim()];
            for row in set.rows() {
                for (j, (&v, &s)) in row.iter().zip(scales).enumerate() {
                    buf[j] = v / s;
                }
                out.push(&buf);
            }
            out
        })
        .collect()
}

/// Multiply every draw's coordinate j by `scales[j]` (inverse of
/// [`whiten`]).
pub(crate) fn unwhiten(samples: &mut SampleMatrix, scales: &[f64]) {
    let d = samples.dim();
    let mut out = SampleMatrix::with_capacity(d, samples.len());
    let mut buf = vec![0.0; d];
    for row in samples.rows() {
        for (j, (&v, &s)) in row.iter().zip(scales).enumerate() {
            buf[j] = v * s;
        }
        out.push(&buf);
    }
    *samples = out;
}

/// Common validation: at least one non-empty set, all dims equal.
pub(crate) fn validate_sets(sets: &[&SampleMatrix]) -> Result<()> {
    if sets.is_empty() {
        return Err(Error::Config("no subposterior sample sets".into()));
    }
    let dim = sets[0].dim();
    for (m, s) in sets.iter().enumerate() {
        if s.dim() != dim {
            return Err(Error::Shape(format!(
                "machine {m} dim {} != {dim}",
                s.dim()
            )));
        }
        if s.is_empty() {
            return Err(Error::Config(format!("machine {m} has no samples")));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn method_parse_roundtrip() {
        for &m in CombineMethod::all() {
            assert_eq!(CombineMethod::parse(m.name()).unwrap(), m);
        }
        assert!(CombineMethod::parse("bogus").is_err());
    }

    #[test]
    fn validate_rejects_mismatched_dims() {
        let a = SampleMatrix::from_rows(vec![1.0, 2.0], 2).unwrap();
        let b = SampleMatrix::from_rows(vec![1.0], 1).unwrap();
        assert!(validate_sets(&[&a, &b]).is_err());
        assert!(validate_sets(&[]).is_err());
        assert!(validate_sets(&[&a]).is_ok());
    }

    #[test]
    fn validate_rejects_empty_machine() {
        let a = SampleMatrix::from_rows(vec![1.0, 2.0], 2).unwrap();
        let b = SampleMatrix::new(2);
        assert!(validate_sets(&[&a, &b]).is_err());
    }
}
