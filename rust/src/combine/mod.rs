//! Subposterior combination — the paper's core contribution (section 3).
//!
//! Given `M` sets of subposterior samples, produce draws from an
//! estimator of the density product `p_1 ⋯ p_M(θ) ∝ p(θ | x^N)`:
//!
//! * [`parametric`] — Gaussian product via the Bernstein-von Mises
//!   approximation (section 3.1; fast, asymptotically biased),
//! * [`nonparametric`] — implicit KDE-product sampling via Independent
//!   Metropolis within Gibbs (Algorithm 1; asymptotically exact),
//! * [`semiparametric`] — Hjort-Glad parametric-start × nonparametric
//!   correction (section 3.3; asymptotically exact), plus the paper's
//!   second variant [`semiparametric_nw`] with nonparametric weights,
//! * [`pairwise`] — the O(dTM) tree-of-pairs reduction (section 3.2/4),
//! * [`baselines`] — subpostAvg / subpostPool / duplicateChainsPool /
//!   consensus-weighted averaging (sections 7-8 comparison methods),
//! * [`online`] — streaming combination (section 4).

pub mod baselines;
pub mod gaussian_product;
pub mod nonparametric;
pub mod online;
pub mod pairwise;
pub mod parametric;
pub mod semiparametric;

pub use baselines::{
    consensus_weighted, consensus_weighted_threaded, duplicate_chains_pool,
    subpost_avg, subpost_pool,
};
pub use gaussian_product::{gaussian_product, GaussianEstimate};
pub use nonparametric::nonparametric;
pub use online::OnlineCombiner;
pub use pairwise::pairwise;
pub use parametric::parametric;
pub use semiparametric::{
    semiparametric, semiparametric_nw, DEFAULT_ANNEAL_CACHE_BUDGET,
};

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use crate::error::{Error, Result};
use crate::kernel::{default_kernel, CombineKernel, CombineKernelKind};
use crate::rng::Pcg64;
use crate::types::{DrawStore, SampleMatrix, SubposteriorSamples};

/// Rows per block when building combine-stage caches (norms, whitening):
/// large enough that the inner reduction runs over a long contiguous
/// slice, small enough to stay in L1.
const CACHE_BLOCK_ROWS: usize = 64;

/// Which combination algorithm to run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CombineMethod {
    Parametric,
    Nonparametric,
    Semiparametric,
    /// Semiparametric components with nonparametric weights (paper's
    /// higher-acceptance variant).
    SemiparametricNw,
    /// Pairwise tree reduction using the nonparametric pair combiner.
    Pairwise,
    /// Baseline: average one sample from each machine.
    SubpostAvg,
    /// Baseline: union of all subposterior samples.
    SubpostPool,
    /// Baseline: consensus Monte Carlo (covariance-weighted averaging).
    ConsensusWeighted,
}

impl CombineMethod {
    pub fn name(&self) -> &'static str {
        match self {
            CombineMethod::Parametric => "parametric",
            CombineMethod::Nonparametric => "nonparametric",
            CombineMethod::Semiparametric => "semiparametric",
            CombineMethod::SemiparametricNw => "semiparametricNW",
            CombineMethod::Pairwise => "pairwise",
            CombineMethod::SubpostAvg => "subpostAvg",
            CombineMethod::SubpostPool => "subpostPool",
            CombineMethod::ConsensusWeighted => "consensusWeighted",
        }
    }

    /// All methods, for sweep-style experiments.
    pub fn all() -> &'static [CombineMethod] {
        &[
            CombineMethod::Parametric,
            CombineMethod::Nonparametric,
            CombineMethod::Semiparametric,
            CombineMethod::SemiparametricNw,
            CombineMethod::Pairwise,
            CombineMethod::SubpostAvg,
            CombineMethod::SubpostPool,
            CombineMethod::ConsensusWeighted,
        ]
    }

    pub fn parse(s: &str) -> Result<CombineMethod> {
        CombineMethod::all()
            .iter()
            .copied()
            .find(|m| m.name().eq_ignore_ascii_case(s))
            .ok_or_else(|| Error::Config(format!("unknown method '{s}'")))
    }
}

/// Dispatch a combination method. `t_out` is the number of combined
/// draws requested (pooling methods return min(t_out, pooled)).
pub fn combine(
    method: CombineMethod,
    subs: &[SubposteriorSamples],
    t_out: usize,
    seed: u64,
) -> Result<SampleMatrix> {
    combine_threaded(method, subs, t_out, seed, 1)
}

/// [`combine`] with an explicit combine-stage thread count (`0` = all
/// available cores). Output is byte-identical for a fixed seed
/// regardless of `threads` — parallelism only changes wall-clock.
pub fn combine_threaded(
    method: CombineMethod,
    subs: &[SubposteriorSamples],
    t_out: usize,
    seed: u64,
    threads: usize,
) -> Result<SampleMatrix> {
    let sets: Vec<&SampleMatrix> = subs.iter().map(|s| &s.samples).collect();
    combine_sets_threaded(method, &sets, t_out, seed, threads)
}

/// Like [`combine`] but over bare sample sets.
pub fn combine_sets(
    method: CombineMethod,
    sets: &[&SampleMatrix],
    t_out: usize,
    seed: u64,
) -> Result<SampleMatrix> {
    combine_sets_threaded(method, sets, t_out, seed, 1)
}

/// [`combine_sets`] with an explicit combine-stage thread count (`0` =
/// all available cores). Deterministic for a fixed seed at any thread
/// count.
pub fn combine_sets_threaded(
    method: CombineMethod,
    sets: &[&SampleMatrix],
    t_out: usize,
    seed: u64,
    threads: usize,
) -> Result<SampleMatrix> {
    combine_sets_tuned(
        method,
        sets,
        t_out,
        seed,
        threads,
        DEFAULT_ANNEAL_CACHE_BUDGET,
    )
}

/// [`combine_threaded`] with an explicit annealed-factorization-cache
/// budget in bytes (the `combine_cache_budget_mb` config knob). The
/// budget only applies to the semiparametric methods; every method is
/// byte-identical for a fixed seed at any budget and thread count.
pub fn combine_tuned(
    method: CombineMethod,
    subs: &[SubposteriorSamples],
    t_out: usize,
    seed: u64,
    threads: usize,
    cache_budget_bytes: usize,
) -> Result<SampleMatrix> {
    let sets: Vec<&SampleMatrix> = subs.iter().map(|s| &s.samples).collect();
    combine_sets_tuned(
        method,
        &sets,
        t_out,
        seed,
        threads,
        cache_budget_bytes,
    )
}

/// [`combine_sets_threaded`] with an explicit cache budget — see
/// [`combine_tuned`].
pub fn combine_sets_tuned(
    method: CombineMethod,
    sets: &[&SampleMatrix],
    t_out: usize,
    seed: u64,
    threads: usize,
    cache_budget_bytes: usize,
) -> Result<SampleMatrix> {
    combine_sets_with(
        method,
        sets,
        t_out,
        seed,
        &CombineTuning { threads, cache_budget_bytes, ..Default::default() },
    )
}

/// Every combine-stage performance knob in one place: thread count,
/// annealed-cache budget, and the compute-kernel backend
/// ([`CombineKernelKind`]). None of them change results — the CPU
/// backends are bit-identical by contract (`rust/tests/kernel_parity.rs`),
/// and threads/budget only trade wall-clock/memory — so the struct can
/// be threaded from config to combiner without touching the
/// determinism story.
#[derive(Debug, Clone)]
pub struct CombineTuning {
    /// Combine-stage worker threads (`0` = all cores).
    pub threads: usize,
    /// [`semiparametric::AnnealCache`] budget in bytes.
    pub cache_budget_bytes: usize,
    /// Compute-kernel backend for the dense combine ops.
    pub kernel: CombineKernelKind,
}

impl Default for CombineTuning {
    fn default() -> Self {
        CombineTuning {
            threads: 1,
            cache_budget_bytes: DEFAULT_ANNEAL_CACHE_BUDGET,
            kernel: CombineKernelKind::default(),
        }
    }
}

/// [`combine_tuned`] over a full [`CombineTuning`] — the pipeline's
/// entry point, and the only one that can select a non-default
/// compute-kernel backend.
pub fn combine_with(
    method: CombineMethod,
    subs: &[SubposteriorSamples],
    t_out: usize,
    seed: u64,
    tuning: &CombineTuning,
) -> Result<SampleMatrix> {
    let sets: Vec<&SampleMatrix> = subs.iter().map(|s| &s.samples).collect();
    combine_sets_with(method, &sets, t_out, seed, tuning)
}

/// [`combine_sets_with`] over chunked draw stores — the leader's entry
/// point when the draw plane is held in [`DrawStore`]s (dense or
/// spilled). The IMG-based methods (nonparametric, semiparametric)
/// prepare their whitened context straight from the chunked stores
/// ([`CombineContext::prepare_from_stores`]) — the un-whitened draws are
/// only ever resident one chunk per worker at a time; the remaining
/// methods need whole un-whitened sets (moment fits, tree reshuffles,
/// pooling) and densify first. Retained draws are byte-identical to
/// densifying everything up front, for every method, chunk size and
/// spill budget — per-entry accumulation order never depends on chunk
/// boundaries.
pub fn combine_stores_with(
    method: CombineMethod,
    stores: &[&DrawStore],
    t_out: usize,
    seed: u64,
    tuning: &CombineTuning,
) -> Result<SampleMatrix> {
    validate_stores(stores)?;
    let threads = resolve_threads(tuning.threads);
    match method {
        CombineMethod::Nonparametric => {
            let kernel = tuning.kernel.build()?;
            let ctx =
                CombineContext::prepare_from_stores(stores, threads, kernel)?;
            nonparametric::nonparametric_with_context(&ctx, t_out, seed, threads)
        }
        CombineMethod::Semiparametric | CombineMethod::SemiparametricNw => {
            let kernel = tuning.kernel.build()?;
            let ctx =
                CombineContext::prepare_from_stores(stores, threads, kernel)?;
            semiparametric::semiparametric_with_context(
                ctx,
                t_out,
                seed,
                method == CombineMethod::Semiparametric,
                threads,
                Some(tuning.cache_budget_bytes),
            )
        }
        _ => {
            let dense: Vec<SampleMatrix> = stores
                .iter()
                .map(|s| s.to_matrix())
                .collect::<Result<_>>()?;
            let refs: Vec<&SampleMatrix> = dense.iter().collect();
            combine_sets_with(method, &refs, t_out, seed, tuning)
        }
    }
}

/// [`combine_sets_tuned`] over a full [`CombineTuning`]. The backend is
/// instantiated once per call ([`CombineKernelKind::build`]), so an
/// unavailable backend (e.g. `device` offline) fails fast with a
/// structured error before any combine work runs.
pub fn combine_sets_with(
    method: CombineMethod,
    sets: &[&SampleMatrix],
    t_out: usize,
    seed: u64,
    tuning: &CombineTuning,
) -> Result<SampleMatrix> {
    validate_sets(sets)?;
    let threads = resolve_threads(tuning.threads);
    let kernel = tuning.kernel.build()?;
    match method {
        CombineMethod::Parametric => parametric(sets, t_out, seed),
        CombineMethod::Nonparametric => nonparametric::nonparametric_with(
            sets, t_out, seed, threads, &kernel,
        ),
        CombineMethod::Semiparametric => {
            semiparametric::semiparametric_with(
                sets,
                t_out,
                seed,
                true,
                threads,
                Some(tuning.cache_budget_bytes),
                &kernel,
            )
        }
        CombineMethod::SemiparametricNw => {
            semiparametric::semiparametric_with(
                sets,
                t_out,
                seed,
                false,
                threads,
                Some(tuning.cache_budget_bytes),
                &kernel,
            )
        }
        CombineMethod::Pairwise => {
            pairwise::pairwise_with(sets, t_out, seed, threads, &kernel)
        }
        CombineMethod::SubpostAvg => subpost_avg(sets, t_out, seed),
        CombineMethod::SubpostPool => Ok(subpost_pool(sets)?.take(t_out)),
        CombineMethod::ConsensusWeighted => {
            consensus_weighted_threaded(sets, t_out, seed, threads)
        }
    }
}

/// Resolve a requested combine-stage thread count: `0` means "all
/// available cores", anything else is taken as-is.
pub fn resolve_threads(requested: usize) -> usize {
    if requested == 0 {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    } else {
        requested
    }
}

/// Run `f(0), …, f(n-1)` on up to `threads` scoped worker threads and
/// return the results in index order.
///
/// Work is handed out through an atomic counter (no per-task spawn), so
/// coarse tasks of uneven size pack LPT-style onto the pool. `f(i)`
/// must not depend on scheduling — every caller here passes tasks that
/// are pure functions of the index plus read-only shared state, which
/// is what makes the parallel combiner's output independent of the
/// thread count.
pub(crate) fn par_map_indexed<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let threads = threads.clamp(1, n.max(1));
    if threads == 1 {
        return (0..n).map(f).collect();
    }
    let slots: Mutex<Vec<Option<T>>> =
        Mutex::new((0..n).map(|_| None).collect());
    let next = AtomicUsize::new(0);
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let v = f(i);
                slots.lock().unwrap()[i] = Some(v);
            });
        }
    });
    slots
        .into_inner()
        .unwrap()
        .into_iter()
        .map(|slot| slot.expect("every index was processed"))
        .collect()
}

/// Restart schedule shared by the IMG-based combiners: chunk sizes
/// `(kept, warmup)` summing to exactly `t_out` kept draws.
///
/// Chunks grow geometrically from `chunk0` but are capped at
/// `max(chunk0, t_out / 8)`, so the plan always splinters into enough
/// independent chains to occupy a thread pool (longest chain ≤ ~12.5%
/// of the work) while the cap itself grows linearly in `t_out` — every
/// non-tail chunk anneals its bandwidth down to `O((t_out/8)^{-1/(4+d)})`,
/// which → 0 as `t_out` → ∞, preserving asymptotic exactness. Each
/// chunk discards the first 20% as per-restart warmup.
///
/// The plan is a pure function of `(t_out, chunk0)` — never of the
/// thread count — which is one half of the determinism contract (the
/// other half being per-chunk RNG streams, [`crate::rng::Pcg64::split_n`]).
pub(crate) fn restart_plan(
    t_out: usize,
    chunk0: usize,
) -> Vec<(usize, usize)> {
    let mut plan = Vec::new();
    if t_out == 0 {
        return plan;
    }
    // cap ≥ chunk0, so it only ever binds during geometric growth.
    let cap = (t_out / 8).max(chunk0.max(1));
    let mut chunk = chunk0.max(1);
    let mut remaining = t_out;
    while remaining > 0 {
        let n = chunk.min(remaining);
        plan.push((n, n / 5));
        remaining -= n;
        chunk = chunk.saturating_mul(2).min(cap);
    }
    plan
}

/// Default first-chunk size of the restart schedule.
pub(crate) const RESTART_CHUNK0: usize = 500;
/// Default index sweeps per emitted draw in the IMG-based combiners.
pub(crate) const RESTART_SWEEPS: usize = 3;

/// Longest chain in the restart plan, in annealed iterations
/// (`keep + warmup`) — the number of per-iteration factorizations the
/// semiparametric [`semiparametric::AnnealCache`] must cover so every
/// chain hits the cache on every iteration. A pure function of
/// `(t_out, chunk0)`, like the plan itself.
pub(crate) fn max_chain_len(t_out: usize, chunk0: usize) -> usize {
    restart_plan(t_out, chunk0)
        .iter()
        .map(|&(keep, warmup)| keep + warmup)
        .max()
        .unwrap_or(0)
}

/// Orchestrate the restart plan for `t_out` draws: split one RNG
/// stream per chunk off `seed`, run `chain(keep, warmup, rng)` for
/// each chunk `threads`-wide, and concatenate the parts in plan order.
///
/// This is the single copy of the determinism-critical schedule shared
/// by the nonparametric and semiparametric combiners: both the plan
/// ([`restart_plan`]) and the per-chunk streams ([`Pcg64::split_n`])
/// are pure functions of `(t_out, seed)`, never of the thread count.
pub(crate) fn run_restart_chains<F>(
    dim: usize,
    t_out: usize,
    chunk0: usize,
    seed: u64,
    threads: usize,
    chain: F,
) -> Result<SampleMatrix>
where
    F: Fn(usize, usize, Pcg64) -> Result<SampleMatrix> + Sync,
{
    let plan = restart_plan(t_out, chunk0);
    let mut root = Pcg64::seed_from(seed);
    let rngs = root.split_n(plan.len());
    let parts = par_map_indexed(plan.len(), threads, |i| {
        let (keep, warmup) = plan[i];
        chain(keep, warmup, rngs[i].clone())
    })
    .into_iter()
    .collect::<Result<Vec<SampleMatrix>>>()?;
    let mut out = SampleMatrix::with_capacity(dim, t_out);
    for part in &parts {
        out.push_rows(part.as_slice());
    }
    Ok(out.take(t_out))
}

/// Precomputed, read-only state shared by every IMG chain of one
/// combine call: whitened per-machine draws, the whitening scales, and
/// per-draw squared norms (the O(1) `Q_t` update cache).
///
/// Built once per combine — in parallel across machines — then
/// borrowed read-only by all restart chains (scoped worker threads need
/// no `Arc`), instead of being recomputed per chain as the sequential
/// implementation did. Deliberately not `Clone`: a copy would
/// duplicate all whitened draws (O(TMd)); share by borrow instead.
#[derive(Debug)]
pub struct CombineContext {
    sets: Vec<SampleMatrix>,
    scales: Vec<f64>,
    norms: Vec<Vec<f64>>,
    /// Per-iteration factorizations of the annealed bandwidth schedule,
    /// shared read-only by every restart chain. Installed by the
    /// semiparametric setup (it needs the Gaussian product pieces);
    /// `None` for combiners that don't use dense components, or for
    /// uncached reference runs.
    anneal: Option<semiparametric::AnnealCache>,
    /// Compute-kernel backend for this combine call's dense ops —
    /// installed at context build time (it already ran the norm pass)
    /// and read by every chain for in-place factorization fallbacks.
    kernel: Arc<dyn CombineKernel>,
}

impl CombineContext {
    /// Whiten all machines and cache per-draw squared norms, fanning the
    /// per-machine work (O(Td) each) across `threads` workers, on the
    /// reference compute kernel.
    pub fn prepare(sets: &[&SampleMatrix], threads: usize) -> Self {
        Self::prepare_with(sets, threads, default_kernel())
            .expect("the reference kernel's CPU ops are infallible")
    }

    /// [`CombineContext::prepare`] on an explicit compute-kernel
    /// backend ([`crate::kernel`]): the norm cache is built through
    /// `kernel.row_norms` and the kernel is installed into the context
    /// for the chains' dense ops. CPU backends are bit-identical, so
    /// the context contents do not depend on which one ran.
    pub fn prepare_with(
        sets: &[&SampleMatrix],
        threads: usize,
        kernel: Arc<dyn CombineKernel>,
    ) -> Result<Self> {
        assert!(!sets.is_empty(), "no subposterior sample sets");
        let scales = whitening_scales(sets);
        let per_machine: Vec<(SampleMatrix, Vec<f64>)> =
            par_map_indexed(sets.len(), threads, |m| {
                let w = whiten_one(sets[m], &scales);
                let n = kernel.row_norms(&w)?;
                Ok((w, n))
            })
            .into_iter()
            .collect::<Result<_>>()?;
        let mut whitened = Vec::with_capacity(per_machine.len());
        let mut norms = Vec::with_capacity(per_machine.len());
        for (w, n) in per_machine {
            whitened.push(w);
            norms.push(n);
        }
        Ok(CombineContext { sets: whitened, scales, norms, anneal: None, kernel })
    }

    /// [`CombineContext::prepare_with`] over chunked [`DrawStore`]s —
    /// the leader's out-of-core path. Each store's row chunks are
    /// streamed twice (a variance pass for the whitening scales, then a
    /// whiten + norm pass landing directly in the whitened set), so the
    /// un-whitened draws are only ever resident one chunk per worker at
    /// a time — spilled chunks are paged in, folded, and dropped.
    ///
    /// Bit-identical to densifying first and calling `prepare_with`:
    /// the variance fold ([`store_variances`]), the whitening map and
    /// the norm fold ([`CombineKernel::row_norms_block`]) are all
    /// per-row sequential passes in draw order, so chunk boundaries —
    /// and therefore `chunk_rows` and the spill budget — never change
    /// per-entry accumulation order.
    pub fn prepare_from_stores(
        stores: &[&DrawStore],
        threads: usize,
        kernel: Arc<dyn CombineKernel>,
    ) -> Result<Self> {
        assert!(!stores.is_empty(), "no subposterior sample sets");
        let vars: Vec<Option<Vec<f64>>> =
            par_map_indexed(stores.len(), threads, |m| {
                store_variances(stores[m])
            })
            .into_iter()
            .collect::<Result<_>>()?;
        let scales = scales_from_variances(stores[0].dim(), &vars);
        let per_machine: Vec<(SampleMatrix, Vec<f64>)> =
            par_map_indexed(stores.len(), threads, |m| {
                whiten_store(stores[m], &scales, kernel.as_ref())
            })
            .into_iter()
            .collect::<Result<_>>()?;
        let mut whitened = Vec::with_capacity(per_machine.len());
        let mut norms = Vec::with_capacity(per_machine.len());
        for (w, n) in per_machine {
            whitened.push(w);
            norms.push(n);
        }
        Ok(CombineContext { sets: whitened, scales, norms, anneal: None, kernel })
    }

    /// The compute-kernel backend this context was built on.
    pub fn kernel(&self) -> &dyn CombineKernel {
        self.kernel.as_ref()
    }

    /// Bytes held by this context's whitened copies, norm caches and
    /// scales — what the pairwise tree's per-merge [`MemGauge`]
    /// accounts. Excludes the anneal cache (budgeted separately by
    /// [`CombineTuning::cache_budget_bytes`]).
    pub fn resident_bytes(&self) -> usize {
        let f = std::mem::size_of::<f64>();
        let sets: usize =
            self.sets.iter().map(|s| s.as_slice().len() * f).sum();
        let norms: usize = self.norms.iter().map(|n| n.len() * f).sum();
        sets + norms + self.scales.len() * f
    }

    /// Install the annealed-schedule factorization cache. Must happen
    /// before the restart chains fan out (the context is still
    /// exclusively owned by the combine setup at that point); chains
    /// then read it by shared borrow like the rest of the context.
    pub fn install_anneal_cache(
        &mut self,
        cache: semiparametric::AnnealCache,
    ) {
        self.anneal = Some(cache);
    }

    /// The installed factorization cache, if any.
    pub fn anneal_cache(&self) -> Option<&semiparametric::AnnealCache> {
        self.anneal.as_ref()
    }

    /// Number of machines M.
    pub fn machines(&self) -> usize {
        self.sets.len()
    }

    /// Dimensionality of θ.
    pub fn dim(&self) -> usize {
        self.sets[0].dim()
    }

    /// Whitened per-machine sample sets.
    pub fn sets(&self) -> &[SampleMatrix] {
        &self.sets
    }

    /// Per-dimension whitening scales (see [`whitening_scales`]).
    pub fn scales(&self) -> &[f64] {
        &self.scales
    }

    /// `|θ^m_t|²` per machine per draw, in whitened coordinates.
    pub fn norms(&self) -> &[Vec<f64>] {
        &self.norms
    }

    /// The degenerate-input policy of [`validate_sets`] for entry
    /// points that start from a prepared context: every machine must
    /// still have samples (dims are equal by construction here).
    pub fn validate_non_empty(&self) -> Result<()> {
        for (m, s) in self.sets.iter().enumerate() {
            ensure_machine_non_empty(m, s)?;
        }
        Ok(())
    }
}

/// Prepare one [`CombineContext`] per group, fanning the per-set work of
/// *all* groups — the variance pass behind [`whitening_scales`] and the
/// whiten/norm pass — across one `threads`-wide pool.
///
/// This is the pairwise tree's per-level path: a level's merges each
/// used to build their own context inside their slice of the worker
/// pool, serializing the O(Td)-per-set setup whenever a level had fewer
/// merges than workers (the root merge always does). Whitening
/// level-wide instead keeps every worker busy regardless of tree shape.
/// Each returned context is bit-identical to
/// `CombineContext::prepare(group, _)`: same scales (the per-set
/// variance accumulation order within a group is unchanged), same
/// per-set whitening and norms.
pub(crate) fn prepare_contexts(
    groups: &[Vec<&SampleMatrix>],
    threads: usize,
    kernel: &Arc<dyn CombineKernel>,
) -> Result<Vec<CombineContext>> {
    // Flat (group, machine) task list over every set at this level.
    let flat: Vec<(usize, usize)> = groups
        .iter()
        .enumerate()
        .flat_map(|(g, sets)| (0..sets.len()).map(move |m| (g, m)))
        .collect();

    // Per-set variance pass, fanned level-wide, then reduced per group
    // through the same scale arithmetic as `whitening_scales`
    // (`scales_from_variances` — single copy, set order preserved).
    let variances: Vec<Option<Vec<f64>>> =
        par_map_indexed(flat.len(), threads, |k| {
            let (g, m) = flat[k];
            set_variances(groups[g][m])
        });
    let mut scales: Vec<Vec<f64>> = Vec::with_capacity(groups.len());
    let mut offset = 0usize;
    for sets in groups {
        scales.push(scales_from_variances(
            sets[0].dim(),
            &variances[offset..offset + sets.len()],
        ));
        offset += sets.len();
    }

    // Whiten + norm every set, again level-wide, on the combine call's
    // kernel backend (bit-identical across CPU backends).
    let per_set: Vec<(SampleMatrix, Vec<f64>)> =
        par_map_indexed(flat.len(), threads, |k| {
            let (g, m) = flat[k];
            let w = whiten_one(groups[g][m], &scales[g]);
            let n = kernel.row_norms(&w)?;
            Ok((w, n))
        })
        .into_iter()
        .collect::<Result<_>>()?;

    let mut contexts = Vec::with_capacity(groups.len());
    let mut it = per_set.into_iter();
    for (g, sets) in groups.iter().enumerate() {
        let mut whitened = Vec::with_capacity(sets.len());
        let mut norms = Vec::with_capacity(sets.len());
        for _ in 0..sets.len() {
            let (w, n) = it.next().expect("one entry per set");
            whitened.push(w);
            norms.push(n);
        }
        contexts.push(CombineContext {
            sets: whitened,
            scales: scales[g].clone(),
            norms,
            anneal: None,
            kernel: Arc::clone(kernel),
        });
    }
    Ok(contexts)
}

/// Scatter `D_t = Q_t − |S_t|²/M` (≥ 0 up to fp noise) — the single
/// copy of the IMG weight statistic shared by the nonparametric and
/// semiparametric inner loops.
#[inline]
pub(crate) fn scatter(sq_sum: f64, sum: &[f64], m: f64) -> f64 {
    let s2: f64 = sum.iter().map(|v| v * v).sum();
    (sq_sum - s2 / m).max(0.0)
}

/// Per-draw squared norms of one sample set, reduced block-at-a-time
/// over contiguous memory ([`SampleMatrix::rows_chunked`]).
pub(crate) fn row_norms(set: &SampleMatrix) -> Vec<f64> {
    let d = set.dim();
    let mut norms = Vec::with_capacity(set.len());
    for block in set.rows_chunked(CACHE_BLOCK_ROWS) {
        for row in block.chunks_exact(d) {
            norms.push(row.iter().map(|v| v * v).sum::<f64>());
        }
    }
    norms
}

/// Per-dimension whitening scale shared by all machines: the average
/// subposterior standard deviation of each coordinate.
///
/// The paper's Algorithm 1 anneals an *absolute* bandwidth
/// `h_i = i^{-1/(4+d)}`; for posteriors concentrated at scales ≪ 1
/// (every large-N experiment in the paper) an absolute unit bandwidth
/// over-smooths catastrophically. Following standard KDE practice the
/// nonparametric/semiparametric combiners therefore operate in whitened
/// coordinates (`θ_j / s_j`) and map their draws back — a diagonal
/// linear transform under which every density-product estimator here is
/// exactly equivariant, so Theorem 5.3's rates are unchanged.
pub(crate) fn whitening_scales(sets: &[&SampleMatrix]) -> Vec<f64> {
    let vars: Vec<Option<Vec<f64>>> = sets
        .iter()
        .map(|set| set_variances(set))
        .collect();
    scales_from_variances(sets[0].dim(), &vars)
}

/// Per-set variances for the whitening pass, or `None` for sets too
/// small to have any (< 2 draws) — those are skipped by the scale
/// reduction.
fn set_variances(set: &SampleMatrix) -> Option<Vec<f64>> {
    (set.len() >= 2).then(|| crate::stats::moments::variances(set))
}

/// Chunk-streamed twin of [`set_variances`] over a [`DrawStore`]:
/// the same two per-row folds as [`crate::stats::moments`] (mean
/// accumulation in draw order then `/ n`; squared deviations in draw
/// order then `/ (n − 1)`), run chunk-at-a-time so spilled stores
/// never densify. Chunk boundaries are invisible to the accumulation,
/// so the result is bit-identical to `moments::variances` on the
/// densified store.
fn store_variances(store: &DrawStore) -> Result<Option<Vec<f64>>> {
    if store.len() < 2 {
        return Ok(None);
    }
    let d = store.dim();
    let mut m = vec![0.0; d];
    store.for_each_chunk(|block| {
        for row in block.chunks_exact(d) {
            for (mi, &xi) in m.iter_mut().zip(row) {
                *mi += xi;
            }
        }
        Ok(())
    })?;
    let n = store.len() as f64;
    for mi in m.iter_mut() {
        *mi /= n;
    }
    let mut v = vec![0.0; d];
    store.for_each_chunk(|block| {
        for row in block.chunks_exact(d) {
            for j in 0..d {
                let dev = row[j] - m[j];
                v[j] += dev * dev;
            }
        }
        Ok(())
    })?;
    let denom = (store.len() - 1) as f64;
    for vj in v.iter_mut() {
        *vj /= denom;
    }
    Ok(Some(v))
}

/// Whiten one [`DrawStore`] chunk-at-a-time straight into the whitened
/// dense set, building the norm cache through the kernel's
/// chunk-streaming op as the rows land — no un-whitened dense
/// intermediate ever exists. Same per-row arithmetic as [`whiten_one`]
/// (shared inverse-scale vector) and the same per-entry norm fold, so
/// the output is bit-identical to densify-then-whiten at any chunk
/// size or spill budget.
fn whiten_store(
    store: &DrawStore,
    scales: &[f64],
    kernel: &dyn CombineKernel,
) -> Result<(SampleMatrix, Vec<f64>)> {
    let d = store.dim();
    let inv: Vec<f64> = scales.iter().map(|s| 1.0 / s).collect();
    let mut out = SampleMatrix::with_capacity(d, store.len());
    let mut norms = Vec::with_capacity(store.len());
    let mut buf: Vec<f64> = Vec::new();
    store.for_each_chunk(|block| {
        buf.clear();
        for row in block.chunks_exact(d) {
            buf.extend(row.iter().zip(&inv).map(|(&v, &s)| v * s));
        }
        kernel.row_norms_block(&buf, d, &mut norms)?;
        out.push_rows(&buf);
        Ok(())
    })?;
    Ok((out, norms))
}

/// Reduce precomputed per-set variances to whitening scales — the
/// single copy of the scale arithmetic (mean of per-set sds per
/// coordinate, floored at 1e-12) shared by [`whitening_scales`] and the
/// level-wide [`prepare_contexts`], whose outputs must stay
/// bit-identical.
fn scales_from_variances(d: usize, vars: &[Option<Vec<f64>>]) -> Vec<f64> {
    let mut s = vec![0.0; d];
    let mut counted = 0usize;
    for v in vars.iter().flatten() {
        for j in 0..d {
            s[j] += v[j].sqrt();
        }
        counted += 1;
    }
    let denom = counted.max(1) as f64;
    for sj in s.iter_mut() {
        *sj = (*sj / denom).max(1e-12);
    }
    s
}

/// Divide every draw's coordinate j by `scales[j]`.
pub(crate) fn whiten(
    sets: &[&SampleMatrix],
    scales: &[f64],
) -> Vec<SampleMatrix> {
    sets.iter().map(|set| whiten_one(set, scales)).collect()
}

/// Whiten one machine's draws, block-at-a-time into a flat scratch
/// buffer (single bulk append per block instead of a push per row).
pub(crate) fn whiten_one(set: &SampleMatrix, scales: &[f64]) -> SampleMatrix {
    let d = set.dim();
    let inv: Vec<f64> = scales.iter().map(|s| 1.0 / s).collect();
    let mut out = SampleMatrix::with_capacity(d, set.len());
    let mut buf: Vec<f64> = Vec::with_capacity(CACHE_BLOCK_ROWS * d);
    for block in set.rows_chunked(CACHE_BLOCK_ROWS) {
        buf.clear();
        for row in block.chunks_exact(d) {
            buf.extend(row.iter().zip(&inv).map(|(&v, &s)| v * s));
        }
        out.push_rows(&buf);
    }
    out
}

/// Multiply every draw's coordinate j by `scales[j]` (inverse of
/// [`whiten`]).
pub(crate) fn unwhiten(samples: &mut SampleMatrix, scales: &[f64]) {
    let d = samples.dim();
    let mut out = SampleMatrix::with_capacity(d, samples.len());
    let mut buf = vec![0.0; d];
    for row in samples.rows() {
        for (j, (&v, &s)) in row.iter().zip(scales).enumerate() {
            buf[j] = v * s;
        }
        out.push(&buf);
    }
    *samples = out;
}

/// Common validation: at least one non-empty set, all dims equal.
pub(crate) fn validate_sets(sets: &[&SampleMatrix]) -> Result<()> {
    if sets.is_empty() {
        return Err(Error::Config("no subposterior sample sets".into()));
    }
    let dim = sets[0].dim();
    for (m, s) in sets.iter().enumerate() {
        if s.dim() != dim {
            return Err(Error::Shape(format!(
                "machine {m} dim {} != {dim}",
                s.dim()
            )));
        }
        ensure_machine_non_empty(m, s)?;
    }
    Ok(())
}

/// [`validate_sets`] over chunked draw stores — identical policy and
/// messages, so the leader's store-backed path rejects degenerate
/// inputs exactly like the dense one.
pub(crate) fn validate_stores(stores: &[&DrawStore]) -> Result<()> {
    if stores.is_empty() {
        return Err(Error::Config("no subposterior sample sets".into()));
    }
    let dim = stores[0].dim();
    for (m, s) in stores.iter().enumerate() {
        if s.dim() != dim {
            return Err(Error::Shape(format!(
                "machine {m} dim {} != {dim}",
                s.dim()
            )));
        }
        if s.is_empty() {
            return Err(Error::Config(format!("machine {m} has no samples")));
        }
    }
    Ok(())
}

/// Single copy of the empty-machine rejection shared by
/// [`validate_sets`] and [`CombineContext::validate_non_empty`].
pub(crate) fn ensure_machine_non_empty(
    m: usize,
    s: &SampleMatrix,
) -> Result<()> {
    if s.is_empty() {
        return Err(Error::Config(format!("machine {m} has no samples")));
    }
    Ok(())
}

/// Shared high-water-mark gauge for whitened combine-context bytes.
///
/// The pairwise tree threads one of these through its merge workers:
/// each merge registers its context's [`CombineContext::resident_bytes`]
/// for exactly the context's lifetime, so `peak_bytes` records the most
/// whitened-copy memory the tree ever held at once. With one worker the
/// peak equals the largest single merge group — the invariant the
/// per-outer-batch refactor exists to provide (a full level's contexts
/// are never alive together).
#[derive(Debug, Default)]
pub struct MemGauge {
    cur: AtomicUsize,
    peak: AtomicUsize,
}

impl MemGauge {
    /// Register `bytes` coming alive.
    pub(crate) fn add(&self, bytes: usize) {
        let now = self.cur.fetch_add(bytes, Ordering::Relaxed) + bytes;
        self.peak.fetch_max(now, Ordering::Relaxed);
    }

    /// Register `bytes` released.
    pub(crate) fn sub(&self, bytes: usize) {
        self.cur.fetch_sub(bytes, Ordering::Relaxed);
    }

    /// Most bytes ever registered alive at once.
    pub fn peak_bytes(&self) -> usize {
        self.peak.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn method_parse_roundtrip() {
        for &m in CombineMethod::all() {
            assert_eq!(CombineMethod::parse(m.name()).unwrap(), m);
        }
        assert!(CombineMethod::parse("bogus").is_err());
    }

    #[test]
    fn validate_rejects_mismatched_dims() {
        let a = SampleMatrix::from_rows(vec![1.0, 2.0], 2).unwrap();
        let b = SampleMatrix::from_rows(vec![1.0], 1).unwrap();
        assert!(validate_sets(&[&a, &b]).is_err());
        assert!(validate_sets(&[]).is_err());
        assert!(validate_sets(&[&a]).is_ok());
    }

    #[test]
    fn validate_rejects_empty_machine() {
        let a = SampleMatrix::from_rows(vec![1.0, 2.0], 2).unwrap();
        let b = SampleMatrix::new(2);
        assert!(validate_sets(&[&a, &b]).is_err());
    }

    #[test]
    fn restart_plan_covers_exactly_t_out() {
        for t_out in [0usize, 1, 7, 499, 500, 501, 1000, 8000, 100_000] {
            let plan = restart_plan(t_out, 500);
            let kept: usize = plan.iter().map(|&(n, _)| n).sum();
            assert_eq!(kept, t_out, "t_out {t_out}");
            for &(n, warmup) in &plan {
                assert!(n >= 1);
                assert_eq!(warmup, n / 5);
            }
        }
    }

    #[test]
    fn restart_plan_caps_longest_chain() {
        // Longest chain bounded so a thread pool can pack the plan:
        // ≤ max(chunk0, t_out/8).
        for t_out in [10_000usize, 100_000] {
            let plan = restart_plan(t_out, 500);
            let longest = plan.iter().map(|&(n, _)| n).max().unwrap();
            assert!(
                longest <= (t_out / 8).max(500),
                "t_out {t_out}: longest chunk {longest}"
            );
            assert!(plan.len() >= 8, "t_out {t_out}: {} chunks", plan.len());
        }
    }

    #[test]
    fn restart_plan_small_t_matches_legacy_schedule() {
        // Below the cap the schedule is the seed's geometric one.
        assert_eq!(restart_plan(1000, 500), vec![(500, 100), (500, 100)]);
        assert_eq!(restart_plan(300, 500), vec![(300, 60)]);
    }

    #[test]
    fn par_map_indexed_is_order_preserving_any_threads() {
        for threads in [1usize, 2, 5, 16] {
            let out = par_map_indexed(37, threads, |i| i * i);
            assert_eq!(out.len(), 37);
            for (i, v) in out.iter().enumerate() {
                assert_eq!(*v, i * i, "threads {threads}");
            }
        }
        assert!(par_map_indexed(0, 4, |i| i).is_empty());
    }

    #[test]
    fn combine_context_matches_sequential_whitening() {
        let mut rng = crate::rng::Pcg64::seed_from(5);
        let sets: Vec<SampleMatrix> = (0..3)
            .map(|_| {
                let mut s = SampleMatrix::new(2);
                for _ in 0..97 {
                    s.push(&[rng.normal() * 2.0, 1.0 + rng.normal()]);
                }
                s
            })
            .collect();
        let refs: Vec<&SampleMatrix> = sets.iter().collect();
        let seq = CombineContext::prepare(&refs, 1);
        let par = CombineContext::prepare(&refs, 4);
        assert_eq!(seq.scales(), par.scales());
        for m in 0..3 {
            assert_eq!(seq.sets()[m], par.sets()[m]);
            assert_eq!(seq.norms()[m], par.norms()[m]);
        }
        // Norms really are the whitened squared norms.
        for (row, norm) in seq.sets()[0].rows().zip(&seq.norms()[0]) {
            let want: f64 = row.iter().map(|v| v * v).sum();
            assert!((want - norm).abs() < 1e-12);
        }
    }

    /// The level-wide context builder is bit-identical to preparing
    /// each group on its own, at any thread count — including groups
    /// containing a single-draw set (variance pass skipped).
    #[test]
    fn prepare_contexts_matches_per_group_prepare() {
        let mut rng = crate::rng::Pcg64::seed_from(17);
        let sets: Vec<SampleMatrix> = (0..5)
            .map(|m| {
                let mut s = SampleMatrix::new(2);
                let n = if m == 4 { 1 } else { 80 };
                for _ in 0..n {
                    s.push(&[rng.normal() * (m + 1) as f64, rng.normal()]);
                }
                s
            })
            .collect();
        let groups: Vec<Vec<&SampleMatrix>> = vec![
            vec![&sets[0], &sets[1]],
            vec![&sets[2], &sets[3], &sets[4]],
        ];
        for threads in [1usize, 2, 4] {
            let level =
                prepare_contexts(&groups, threads, &default_kernel())
                    .unwrap();
            assert_eq!(level.len(), 2);
            for (ctx, group) in level.iter().zip(&groups) {
                let solo = CombineContext::prepare(group, 1);
                assert_eq!(ctx.scales(), solo.scales());
                for m in 0..group.len() {
                    assert_eq!(ctx.sets()[m], solo.sets()[m]);
                    assert_eq!(ctx.norms()[m], solo.norms()[m]);
                }
            }
        }
    }

    #[test]
    fn max_chain_len_matches_plan() {
        for t_out in [0usize, 1, 300, 1000, 8000, 100_000] {
            let want = restart_plan(t_out, RESTART_CHUNK0)
                .iter()
                .map(|&(k, w)| k + w)
                .max()
                .unwrap_or(0);
            assert_eq!(max_chain_len(t_out, RESTART_CHUNK0), want);
        }
    }

    #[test]
    fn validate_stores_matches_dense_policy() {
        use crate::types::DrawStoreConfig;
        let cfg = DrawStoreConfig::default();
        let a = DrawStore::from_matrix(
            &SampleMatrix::from_rows(vec![1.0, 2.0], 2).unwrap(),
            cfg,
        )
        .unwrap();
        let b = DrawStore::from_matrix(
            &SampleMatrix::from_rows(vec![1.0], 1).unwrap(),
            cfg,
        )
        .unwrap();
        let empty = DrawStore::new(2);
        assert!(validate_stores(&[]).is_err());
        assert!(validate_stores(&[&a]).is_ok());
        let err = validate_stores(&[&a, &b]).unwrap_err();
        assert!(err.to_string().contains("dim"), "{err}");
        let err = validate_stores(&[&a, &empty]).unwrap_err();
        assert!(err.to_string().contains("machine 1 has no samples"), "{err}");
    }

    /// The store-backed context builder is bit-identical to the dense
    /// one at every chunk size and spill budget — including a store
    /// small enough to skip the variance pass.
    #[test]
    fn prepare_from_stores_matches_dense_prepare() {
        use crate::types::DrawStoreConfig;
        let mut rng = crate::rng::Pcg64::seed_from(11);
        let sets: Vec<SampleMatrix> = (0..3)
            .map(|m| {
                let mut s = SampleMatrix::new(2);
                let n = if m == 2 { 1 } else { 97 };
                for _ in 0..n {
                    s.push(&[rng.normal() * 2.0, 1.0 + rng.normal()]);
                }
                s
            })
            .collect();
        let refs: Vec<&SampleMatrix> = sets.iter().collect();
        let dense = CombineContext::prepare(&refs, 1);
        for chunk_rows in [1usize, 7, 64, 200] {
            for budget in [None, Some(0), Some(1 << 20)] {
                let cfg = DrawStoreConfig {
                    chunk_rows,
                    spill_budget_bytes: budget,
                };
                let stores: Vec<DrawStore> = sets
                    .iter()
                    .map(|s| DrawStore::from_matrix(s, cfg).unwrap())
                    .collect();
                let store_refs: Vec<&DrawStore> = stores.iter().collect();
                for threads in [1usize, 3] {
                    let ctx = CombineContext::prepare_from_stores(
                        &store_refs,
                        threads,
                        default_kernel(),
                    )
                    .unwrap();
                    assert_eq!(
                        ctx.scales(),
                        dense.scales(),
                        "chunk {chunk_rows} budget {budget:?}"
                    );
                    for m in 0..sets.len() {
                        assert_eq!(ctx.sets()[m], dense.sets()[m]);
                        assert_eq!(ctx.norms()[m], dense.norms()[m]);
                    }
                    assert_eq!(ctx.resident_bytes(), dense.resident_bytes());
                }
            }
        }
    }

    /// End-to-end store dispatch: every method's retained draws are
    /// byte-identical between the dense path and a spilled, oddly
    /// chunked store path.
    #[test]
    fn combine_stores_matches_dense_combine_all_methods() {
        use crate::types::DrawStoreConfig;
        let mut rng = crate::rng::Pcg64::seed_from(21);
        let sets: Vec<SampleMatrix> = (0..3)
            .map(|_| {
                let mut s = SampleMatrix::new(2);
                for _ in 0..120 {
                    s.push(&[rng.normal(), 0.5 + rng.normal()]);
                }
                s
            })
            .collect();
        let refs: Vec<&SampleMatrix> = sets.iter().collect();
        let cfg = DrawStoreConfig {
            chunk_rows: 7,
            spill_budget_bytes: Some(0),
        };
        let stores: Vec<DrawStore> = sets
            .iter()
            .map(|s| DrawStore::from_matrix(s, cfg).unwrap())
            .collect();
        let store_refs: Vec<&DrawStore> = stores.iter().collect();
        let tuning = CombineTuning::default();
        for &method in CombineMethod::all() {
            let dense =
                combine_sets_with(method, &refs, 300, 19, &tuning).unwrap();
            let stored =
                combine_stores_with(method, &store_refs, 300, 19, &tuning)
                    .unwrap();
            assert_eq!(
                dense.as_slice(),
                stored.as_slice(),
                "{} diverged through the store path",
                method.name()
            );
        }
    }

    #[test]
    fn mem_gauge_tracks_high_water_mark() {
        let g = MemGauge::default();
        assert_eq!(g.peak_bytes(), 0);
        g.add(100);
        g.add(50);
        g.sub(100);
        g.add(20);
        assert_eq!(g.peak_bytes(), 150);
    }

    #[test]
    fn threaded_dispatch_matches_single_thread() {
        let mut rng = crate::rng::Pcg64::seed_from(9);
        let sets: Vec<SampleMatrix> = (0..4)
            .map(|_| {
                let mut s = SampleMatrix::new(2);
                for _ in 0..150 {
                    s.push(&[rng.normal(), rng.normal()]);
                }
                s
            })
            .collect();
        let refs: Vec<&SampleMatrix> = sets.iter().collect();
        for &method in &[
            CombineMethod::Nonparametric,
            CombineMethod::Semiparametric,
            CombineMethod::Pairwise,
            CombineMethod::ConsensusWeighted,
        ] {
            let a = combine_sets_threaded(method, &refs, 700, 13, 1).unwrap();
            let b = combine_sets_threaded(method, &refs, 700, 13, 4).unwrap();
            assert_eq!(
                a.as_slice(),
                b.as_slice(),
                "{} not thread-count invariant",
                method.name()
            );
        }
    }
}
