//! Experiment / pipeline configuration.
//!
//! Configs are built programmatically (builder pattern) or parsed from
//! simple `key = value` files (`.cfg`) — the CLI's `--config` flag. No
//! external dependencies are available offline, so the format is a flat,
//! documented key list rather than TOML.

use crate::combine::CombineMethod;
use crate::coordinator::transport::WireFormat;
use crate::data::io::ShardFormat;
use crate::error::{Error, Result};
use crate::kernel::CombineKernelKind;
use crate::sampler::SamplerKind;
use std::collections::BTreeMap;

/// What the pipeline scheduler does when a worker stream fails
/// (process death, bad frame, remote error, liveness expiry).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum FailurePolicy {
    /// Abort the whole run on the first failure (the historical
    /// behavior): cancel every in-flight worker, surface the first
    /// error.
    #[default]
    Failfast,
    /// Discard the failed machine's partial rows, requeue its shard,
    /// and re-dispatch — quarantining endpoints that fail repeatedly.
    /// Safe because worker RNG streams are endpoint-independent
    /// (`root.split(m)`): a retried shard reproduces bit-identical
    /// draws, so retained draws match an unfaulted run byte-for-byte.
    Retry,
}

impl FailurePolicy {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "failfast" => Ok(FailurePolicy::Failfast),
            "retry" => Ok(FailurePolicy::Retry),
            other => Err(Error::Config(format!(
                "unknown failure_policy '{other}' (expected failfast | retry)"
            ))),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            FailurePolicy::Failfast => "failfast",
            FailurePolicy::Retry => "retry",
        }
    }
}

/// Which leader-side I/O runtime drives the socket transport
/// (`io_driver` key / `--io-driver`). The choice never changes the
/// retained draws — machine RNG streams are `root.split(m)`, so the
/// driver only changes *when* bytes arrive, never *what* lands.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum IoDriver {
    /// One blocking OS thread per worker endpoint (the historical
    /// behavior, and the only driver for pipe/native runs).
    #[default]
    Threads,
    /// A `poll(2)` reactor: one thread (or a small fixed pool,
    /// `reactor_threads`) multiplexes every endpoint through
    /// nonblocking sockets — leader thread count independent of W.
    /// Socket transport only; pipe and native runs keep the thread
    /// driver regardless.
    Reactor,
}

impl IoDriver {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "threads" => Ok(IoDriver::Threads),
            "reactor" => Ok(IoDriver::Reactor),
            other => Err(Error::Config(format!(
                "unknown io_driver '{other}' (expected threads | reactor)"
            ))),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            IoDriver::Threads => "threads",
            IoDriver::Reactor => "reactor",
        }
    }
}

/// Full configuration of an embarrassingly-parallel MCMC run.
#[derive(Debug, Clone)]
pub struct PipelineConfig {
    /// Model name: gaussian | logistic | gmm | poisson_gamma | linreg.
    pub model: String,
    /// Number of machines M.
    pub machines: usize,
    /// Post-burn-in draws per machine T.
    pub samples_per_machine: usize,
    /// Burn-in per machine (default: T/5, the paper's 1/6-of-total rule).
    pub burn_in: usize,
    /// Thinning.
    pub thin: usize,
    /// Root RNG seed (workers derive independent streams).
    pub seed: u64,
    /// Worker sampler.
    pub sampler: SamplerKind,
    /// Combination method for the leader.
    pub method: CombineMethod,
    /// Combined draws to emit (defaults to samples_per_machine).
    pub t_out: usize,
    /// OS threads to use for workers (defaults to machines). Applies
    /// to the in-thread path only: in `process_mode` every machine is
    /// its own OS process and all M run concurrently, exactly like the
    /// paper's cluster.
    pub threads: usize,
    /// OS threads for the leader's combination stage (restart chains,
    /// pairwise tree merges, setup caches). `0` = all available cores.
    /// Output is byte-identical for a fixed seed at any value — this
    /// knob only trades wall-clock.
    pub combine_threads: usize,
    /// Evaluate the subposterior through the PJRT runtime instead of the
    /// native backend (requires artifacts/).
    pub use_runtime: bool,
    /// Artifact directory for `use_runtime`.
    pub artifact_dir: String,
    /// Run each worker in its own OS process instead of an in-process
    /// thread (`coordinator::pipeline::run_process`). Byte-identical to
    /// thread mode for a fixed seed.
    pub process_mode: bool,
    /// Worker executable for `process_mode`. Empty means "this
    /// executable" (`std::env::current_exe`), which is right for the
    /// CLI; library embedders and tests point it at the `repro` binary.
    pub worker_bin: String,
    /// Socket-transport worker endpoints: comma-separated `host:port`
    /// list of `repro serve` daemons. Non-empty switches the pipeline
    /// to socket mode (overrides `process_mode`); the W endpoints are
    /// oversubscribed when W < machines. Byte-identical to thread mode
    /// for a fixed seed at any W.
    pub workers: String,
    /// Concurrent worker processes in process mode (`0` = one per
    /// machine, PR 2's behaviour). Fewer slots than machines
    /// oversubscribes: the M shard-manifests queue and are assigned to
    /// processes as they free up — output is unchanged, only the
    /// peak process count drops.
    pub worker_slots: usize,
    /// Spill format for process/socket-mode shards (`json` | `binary`).
    /// Binary skips float↔decimal conversion for very large N; workers
    /// autodetect, so the two ends never need to agree in advance.
    pub shard_format: ShardFormat,
    /// Memory budget (MiB) for the semiparametric combiner's annealed
    /// factorization cache. Output is byte-identical at any value —
    /// iterations past the cap fall back to in-place recomputation —
    /// so this only trades memory for combine-stage speed. Default 256.
    pub combine_cache_budget_mb: usize,
    /// Compute-kernel backend for the combine stage's dense ops
    /// (`naive` | `blocked` | `device`). The CPU backends are
    /// bit-identical — retained draws do not depend on this knob —
    /// and `device` requires vendored PJRT bindings (a structured
    /// error otherwise). Default: `naive` (the reference).
    pub combine_backend: CombineKernelKind,
    /// Ship each machine's shard to socket-transport workers *inline*
    /// (a binary frame after the manifest frame) instead of requiring
    /// the daemon to read `shard_path` from a shared filesystem.
    /// Byte-identical to path mode — the daemon decodes the same
    /// spilled bytes. Ignored by the thread and pipe runtimes, which
    /// share a filesystem by construction.
    pub shard_inline: bool,
    /// Leader-side frame cap in bytes for pipe/socket transports
    /// (`0` = the 64 MiB default). Raise it — together with the
    /// daemon-side `repro serve --max-frame-bytes` — when inline
    /// shards exceed the default; the oversized-shard pre-check names
    /// both knobs.
    pub max_frame_bytes: usize,
    /// Draw-plane wire encoding for pipe/socket transports (`json` |
    /// `binary`). JSON is the original one-frame-per-draw wire; binary
    /// ships batched raw-LE-f64 chunk frames (see
    /// `coordinator::transport::DrawChunk`). Retained draws are
    /// byte-identical either way; binary is additionally bit-exact for
    /// NaN payloads and skips float↔decimal entirely. Ignored by the
    /// thread runtime, which never serializes.
    pub wire_format: WireFormat,
    /// Draws coalesced per binary chunk frame (`--draw-batch`; zero is
    /// rejected at parse). A binary-plane knob with no effect on the
    /// JSON wire or on outputs — any batch size yields byte-identical
    /// retained draws. Default 64.
    pub draw_batch: usize,
    /// Rows per sealed chunk in the leader's draw stores
    /// (`chunk_rows` key / `--chunk-rows`; zero is rejected at parse).
    /// A memory-layout knob: retained draws are byte-identical at any
    /// value. Default 512.
    pub chunk_rows: usize,
    /// Draw-plane spill budget in MiB (`draw_spill_budget_mb` key /
    /// `--draw-spill-budget-mb`). Absent ⇒ dense, today's behavior;
    /// `0` ⇒ every sealed chunk spills to disk immediately; otherwise
    /// each machine's store spills coldest chunks first once its sealed
    /// resident bytes exceed the budget. Retained draws are
    /// byte-identical at any value — the budget trades memory for
    /// segment-file I/O, never results.
    pub draw_spill_budget_mb: Option<usize>,
    /// Scheduler response to worker failures (`failure_policy` key /
    /// `--failure-policy`): `failfast` (default) aborts the run;
    /// `retry` re-dispatches failed shards with backoff and endpoint
    /// quarantine. Retained draws are byte-identical either way a run
    /// completes — retried RNG streams are endpoint-independent.
    pub failure_policy: FailurePolicy,
    /// Re-dispatch attempts per machine beyond the first under
    /// `failure_policy = retry` (`--max-retries`). Default 2.
    pub max_retries: usize,
    /// Worker heartbeat interval in seconds (`heartbeat_secs` key /
    /// `--heartbeat-secs`; `0` = disabled). Carried to workers in the
    /// manifest, so old daemons that ignore it simply never beacon —
    /// the leader only requires *some* frame per liveness window.
    pub heartbeat_secs: usize,
    /// Leader-side liveness deadline in seconds
    /// (`liveness_timeout_secs` key / `--liveness-timeout-secs`; `0` =
    /// disabled): a socket worker that produces no frame (draw or
    /// heartbeat) for this long is declared dead instead of hanging
    /// the endpoint loop. Must exceed `heartbeat_secs` when both are
    /// set.
    pub liveness_timeout_secs: usize,
    /// Socket dial timeout in seconds (`connect_timeout_secs` key /
    /// `--connect-timeout-secs`; zero is rejected at parse).
    /// Default 30.
    pub connect_timeout_secs: usize,
    /// Leader-side socket I/O runtime (`io_driver` key /
    /// `--io-driver {threads,reactor}`). Default `threads` until the
    /// reactor smoke is green in CI; consulted only for socket runs —
    /// pipe and native runs keep the thread driver either way.
    pub io_driver: IoDriver,
    /// Reactor thread-pool size under `io_driver = reactor`
    /// (`reactor_threads` key / `--reactor-threads`; zero is rejected
    /// at parse). Endpoints are partitioned across the pool; machines
    /// are pulled from one shared queue. Default 1 — the whole point
    /// is that leader thread count no longer scales with W.
    pub reactor_threads: usize,
}

impl PipelineConfig {
    pub fn builder(model: &str) -> PipelineConfigBuilder {
        PipelineConfigBuilder::new(model)
    }

    /// Parse a flat `key = value` config file (lines starting with `#`
    /// are comments).
    pub fn from_str_cfg(text: &str) -> Result<Self> {
        let mut kv = BTreeMap::new();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let (k, v) = line.split_once('=').ok_or_else(|| {
                Error::Parse(format!("line {}: expected key = value", lineno + 1))
            })?;
            kv.insert(k.trim().to_string(), v.trim().to_string());
        }
        let get = |k: &str| kv.get(k).cloned();
        let parse_usize = |k: &str, default: usize| -> Result<usize> {
            match get(k) {
                None => Ok(default),
                Some(v) => v
                    .parse()
                    .map_err(|_| Error::Parse(format!("bad usize for {k}: {v}"))),
            }
        };
        let model = get("model")
            .ok_or_else(|| Error::Config("missing 'model'".into()))?;
        let mut b = PipelineConfigBuilder::new(&model);
        b.machines = parse_usize("machines", b.machines)?;
        b.samples_per_machine =
            parse_usize("samples_per_machine", b.samples_per_machine)?;
        b.burn_in = match get("burn_in") {
            None => None,
            Some(v) => Some(v.parse().map_err(|_| {
                Error::Parse(format!("bad usize for burn_in: {v}"))
            })?),
        };
        b.thin = parse_usize("thin", b.thin)?;
        b.threads = match get("threads") {
            None => b.threads,
            Some(v) => Some(v.parse().map_err(|_| {
                Error::Parse(format!("bad usize for threads: {v}"))
            })?),
        };
        b.seed = match get("seed") {
            None => b.seed,
            Some(v) => v
                .parse()
                .map_err(|_| Error::Parse(format!("bad u64 for seed: {v}")))?,
        };
        if let Some(v) = get("method") {
            b.method = CombineMethod::parse(&v)?;
        }
        if let Some(v) = get("sampler") {
            b.sampler = Some(parse_sampler(&v)?);
        }
        b.t_out = match get("t_out") {
            None => None,
            Some(v) => Some(v.parse().map_err(|_| {
                Error::Parse(format!("bad usize for t_out: {v}"))
            })?),
        };
        b.combine_threads =
            parse_usize("combine_threads", b.combine_threads)?;
        if let Some(v) = get("use_runtime") {
            b.use_runtime = v == "true" || v == "1";
        }
        if let Some(v) = get("artifact_dir") {
            b.artifact_dir = v;
        }
        if let Some(v) = get("process_mode") {
            b.process_mode = v == "true" || v == "1";
        }
        if let Some(v) = get("worker_bin") {
            b.worker_bin = v;
        }
        if let Some(v) = get("workers") {
            b.workers = v;
        }
        b.worker_slots = parse_usize("worker_slots", b.worker_slots)?;
        if let Some(v) = get("shard_format") {
            b.shard_format = ShardFormat::parse(&v)?;
        }
        b.combine_cache_budget_mb = parse_usize(
            "combine_cache_budget_mb",
            b.combine_cache_budget_mb,
        )?;
        if let Some(v) = get("combine_backend") {
            b.combine_backend = CombineKernelKind::parse(&v)?;
        }
        if let Some(v) = get("shard_inline") {
            b.shard_inline = v == "true" || v == "1";
        }
        b.max_frame_bytes =
            parse_usize("max_frame_bytes", b.max_frame_bytes)?;
        if let Some(v) = get("wire_format") {
            b.wire_format = WireFormat::parse(&v)?;
        }
        b.draw_batch = parse_usize("draw_batch", b.draw_batch)?;
        b.chunk_rows = parse_usize("chunk_rows", b.chunk_rows)?;
        b.draw_spill_budget_mb = match get("draw_spill_budget_mb") {
            None => None,
            Some(v) => Some(v.parse().map_err(|_| {
                Error::Parse(format!("bad usize for draw_spill_budget_mb: {v}"))
            })?),
        };
        if let Some(v) = get("failure_policy") {
            b.failure_policy = FailurePolicy::parse(&v)?;
        }
        b.max_retries = parse_usize("max_retries", b.max_retries)?;
        b.heartbeat_secs =
            parse_usize("heartbeat_secs", b.heartbeat_secs)?;
        b.liveness_timeout_secs = parse_usize(
            "liveness_timeout_secs",
            b.liveness_timeout_secs,
        )?;
        b.connect_timeout_secs = parse_usize(
            "connect_timeout_secs",
            b.connect_timeout_secs,
        )?;
        if let Some(v) = get("io_driver") {
            b.io_driver = IoDriver::parse(&v)?;
        }
        b.reactor_threads =
            parse_usize("reactor_threads", b.reactor_threads)?;
        // Degenerate knobs are rejected here, with the key named, rather
        // than silently clamped or left to panic deep in the draw plane.
        if b.connect_timeout_secs == 0 {
            return Err(Error::Config(
                "connect_timeout_secs must be >= 1 (got 0); \
                 a zero dial timeout can never connect"
                    .into(),
            ));
        }
        if b.draw_batch == 0 {
            return Err(Error::Config(
                "draw_batch must be >= 1 (got 0)".into(),
            ));
        }
        if b.chunk_rows == 0 {
            return Err(Error::Config(
                "chunk_rows must be >= 1 (got 0)".into(),
            ));
        }
        if b.reactor_threads == 0 {
            return Err(Error::Config(
                "reactor_threads must be >= 1 (got 0); \
                 a reactor with no threads polls nothing"
                    .into(),
            ));
        }
        Ok(b.build())
    }

    pub fn from_file(path: &str) -> Result<Self> {
        Self::from_str_cfg(&std::fs::read_to_string(path)?)
    }

    /// Render this config as the flat `key = value` text
    /// [`PipelineConfig::from_str_cfg`] parses, covering every key the
    /// parser accepts. The rendering round-trips exactly —
    /// `from_str_cfg(&cfg.to_cfg_string())` rebuilds the config field
    /// for field (sampler floats travel through [`sampler_spec`]'s
    /// shortest-round-trip `{:e}` form; the seed is a plain decimal
    /// `u64`). This is the job-spec wire format `repro submit` ships to
    /// `repro leaderd`: the daemon re-parses the spec with exactly the
    /// validation a `--config` file gets, so a submitted job and a solo
    /// CLI run see identical configs — the root of the byte-identity
    /// contract across the two entry points.
    pub fn to_cfg_string(&self) -> String {
        let mut s = String::with_capacity(768);
        {
            let mut kv = |k: &str, v: String| {
                s.push_str(k);
                s.push_str(" = ");
                s.push_str(&v);
                s.push('\n');
            };
            kv("model", self.model.clone());
            kv("machines", self.machines.to_string());
            kv("samples_per_machine", self.samples_per_machine.to_string());
            kv("burn_in", self.burn_in.to_string());
            kv("thin", self.thin.to_string());
            kv("threads", self.threads.to_string());
            kv("seed", self.seed.to_string());
            kv("sampler", sampler_spec(&self.sampler));
            kv("method", self.method.name().to_string());
            kv("t_out", self.t_out.to_string());
            kv("combine_threads", self.combine_threads.to_string());
            kv("use_runtime", self.use_runtime.to_string());
            if !self.artifact_dir.is_empty() {
                kv("artifact_dir", self.artifact_dir.clone());
            }
            kv("process_mode", self.process_mode.to_string());
            if !self.worker_bin.is_empty() {
                kv("worker_bin", self.worker_bin.clone());
            }
            if !self.workers.is_empty() {
                kv("workers", self.workers.clone());
            }
            kv("worker_slots", self.worker_slots.to_string());
            kv("shard_format", self.shard_format.name().to_string());
            kv(
                "combine_cache_budget_mb",
                self.combine_cache_budget_mb.to_string(),
            );
            kv("combine_backend", self.combine_backend.name().to_string());
            kv("shard_inline", self.shard_inline.to_string());
            kv("max_frame_bytes", self.max_frame_bytes.to_string());
            kv("wire_format", self.wire_format.name().to_string());
            kv("draw_batch", self.draw_batch.to_string());
            kv("chunk_rows", self.chunk_rows.to_string());
            if let Some(mb) = self.draw_spill_budget_mb {
                kv("draw_spill_budget_mb", mb.to_string());
            }
            kv("failure_policy", self.failure_policy.name().to_string());
            kv("max_retries", self.max_retries.to_string());
            kv("heartbeat_secs", self.heartbeat_secs.to_string());
            kv(
                "liveness_timeout_secs",
                self.liveness_timeout_secs.to_string(),
            );
            kv(
                "connect_timeout_secs",
                self.connect_timeout_secs.to_string(),
            );
            kv("io_driver", self.io_driver.name().to_string());
            kv("reactor_threads", self.reactor_threads.to_string());
        }
        s
    }
}

/// Parse a sampler spec string — also the wire format process-mode
/// worker manifests carry, so it is public alongside [`sampler_spec`].
pub fn parse_sampler(s: &str) -> Result<SamplerKind> {
    // Formats: "hmc:eps,L" | "nuts:eps,maxdepth" | "rwm:scale" | "mala:eps"
    let (name, args) = match s.split_once(':') {
        Some((n, a)) => (n, a),
        None => (s, ""),
    };
    let nums: Vec<f64> = if args.is_empty() {
        vec![]
    } else {
        args.split(',')
            .map(|v| {
                v.trim()
                    .parse()
                    .map_err(|_| Error::Parse(format!("bad sampler arg {v}")))
            })
            .collect::<Result<_>>()?
    };
    let f = |i: usize, d: f64| nums.get(i).copied().unwrap_or(d);
    match name {
        "hmc" => Ok(SamplerKind::Hmc {
            step: f(0, 0.1),
            n_leapfrog: f(1, 10.0) as usize,
        }),
        "nuts" => Ok(SamplerKind::Nuts {
            step: f(0, 0.1),
            max_depth: f(1, 10.0) as usize,
        }),
        "rwm" => Ok(SamplerKind::Rwm { scale: f(0, 1.0) }),
        "mala" => Ok(SamplerKind::Mala { step: f(0, 0.1) }),
        other => Err(Error::Config(format!("unknown sampler '{other}'"))),
    }
}

/// Render a [`SamplerKind`] as the spec string [`parse_sampler`]
/// accepts. Floats use `{:e}` (shortest round-trip), so
/// `parse_sampler(&sampler_spec(k))` reproduces `k` bit-exactly — the
/// property the process-mode worker manifest relies on.
pub fn sampler_spec(kind: &SamplerKind) -> String {
    match *kind {
        SamplerKind::Hmc { step, n_leapfrog } => {
            format!("hmc:{step:e},{n_leapfrog}")
        }
        SamplerKind::Nuts { step, max_depth } => {
            format!("nuts:{step:e},{max_depth}")
        }
        SamplerKind::Rwm { scale } => format!("rwm:{scale:e}"),
        SamplerKind::Mala { step } => format!("mala:{step:e}"),
    }
}

/// Builder for [`PipelineConfig`].
#[derive(Debug, Clone)]
pub struct PipelineConfigBuilder {
    model: String,
    machines: usize,
    samples_per_machine: usize,
    burn_in: Option<usize>,
    thin: usize,
    seed: u64,
    sampler: Option<SamplerKind>,
    method: CombineMethod,
    t_out: Option<usize>,
    threads: Option<usize>,
    combine_threads: usize,
    use_runtime: bool,
    artifact_dir: String,
    process_mode: bool,
    worker_bin: String,
    workers: String,
    worker_slots: usize,
    shard_format: ShardFormat,
    combine_cache_budget_mb: usize,
    combine_backend: CombineKernelKind,
    shard_inline: bool,
    max_frame_bytes: usize,
    wire_format: WireFormat,
    draw_batch: usize,
    chunk_rows: usize,
    draw_spill_budget_mb: Option<usize>,
    failure_policy: FailurePolicy,
    max_retries: usize,
    heartbeat_secs: usize,
    liveness_timeout_secs: usize,
    connect_timeout_secs: usize,
    io_driver: IoDriver,
    reactor_threads: usize,
}

impl PipelineConfigBuilder {
    pub fn new(model: &str) -> Self {
        PipelineConfigBuilder {
            model: model.to_string(),
            machines: 10,
            samples_per_machine: 1000,
            burn_in: None,
            thin: 1,
            seed: 42,
            sampler: None,
            method: CombineMethod::Semiparametric,
            t_out: None,
            threads: None,
            combine_threads: 0,
            use_runtime: false,
            artifact_dir: "artifacts".to_string(),
            process_mode: false,
            worker_bin: String::new(),
            workers: String::new(),
            worker_slots: 0,
            shard_format: ShardFormat::Json,
            combine_cache_budget_mb: 256,
            combine_backend: CombineKernelKind::default(),
            shard_inline: false,
            max_frame_bytes: 0,
            wire_format: WireFormat::Json,
            draw_batch: 64,
            chunk_rows: crate::data::store::DEFAULT_CHUNK_ROWS,
            draw_spill_budget_mb: None,
            failure_policy: FailurePolicy::Failfast,
            max_retries: 2,
            heartbeat_secs: 0,
            liveness_timeout_secs: 0,
            connect_timeout_secs: 30,
            io_driver: IoDriver::Threads,
            reactor_threads: 1,
        }
    }

    pub fn machines(mut self, m: usize) -> Self {
        self.machines = m;
        self
    }

    pub fn samples_per_machine(mut self, t: usize) -> Self {
        self.samples_per_machine = t;
        self
    }

    pub fn burn_in(mut self, b: usize) -> Self {
        self.burn_in = Some(b);
        self
    }

    pub fn thin(mut self, t: usize) -> Self {
        self.thin = t.max(1);
        self
    }

    pub fn seed(mut self, s: u64) -> Self {
        self.seed = s;
        self
    }

    pub fn sampler(mut self, s: SamplerKind) -> Self {
        self.sampler = Some(s);
        self
    }

    pub fn method(mut self, m: CombineMethod) -> Self {
        self.method = m;
        self
    }

    pub fn t_out(mut self, t: usize) -> Self {
        self.t_out = Some(t);
        self
    }

    pub fn threads(mut self, t: usize) -> Self {
        self.threads = Some(t);
        self
    }

    /// Combine-stage thread count; `0` (the default) uses all cores.
    pub fn combine_threads(mut self, t: usize) -> Self {
        self.combine_threads = t;
        self
    }

    pub fn use_runtime(mut self, b: bool) -> Self {
        self.use_runtime = b;
        self
    }

    /// Run workers as OS processes (see `PipelineConfig::process_mode`).
    pub fn process_mode(mut self, b: bool) -> Self {
        self.process_mode = b;
        self
    }

    /// Worker executable for process mode (empty = this executable).
    pub fn worker_bin(mut self, path: &str) -> Self {
        self.worker_bin = path.to_string();
        self
    }

    /// Socket worker endpoints, comma-separated `host:port` list
    /// (empty = no socket transport).
    pub fn workers(mut self, spec: &str) -> Self {
        self.workers = spec.to_string();
        self
    }

    /// Concurrent worker processes in process mode (0 = one per
    /// machine). W < machines oversubscribes without changing output.
    pub fn worker_slots(mut self, w: usize) -> Self {
        self.worker_slots = w;
        self
    }

    /// Spill format for process/socket-mode shards.
    pub fn shard_format(mut self, f: ShardFormat) -> Self {
        self.shard_format = f;
        self
    }

    /// Annealed factorization cache budget in MiB (identical output at
    /// any value).
    pub fn combine_cache_budget_mb(mut self, mb: usize) -> Self {
        self.combine_cache_budget_mb = mb;
        self
    }

    /// Combine-stage compute-kernel backend (CPU backends are
    /// bit-identical; see `PipelineConfig::combine_backend`).
    pub fn combine_backend(mut self, k: CombineKernelKind) -> Self {
        self.combine_backend = k;
        self
    }

    /// Ship shards to socket workers inline over the connection
    /// instead of via a shared filesystem path.
    pub fn shard_inline(mut self, b: bool) -> Self {
        self.shard_inline = b;
        self
    }

    /// Leader-side transport frame cap in bytes (`0` = 64 MiB
    /// default) — see `PipelineConfig::max_frame_bytes`.
    pub fn max_frame_bytes(mut self, bytes: usize) -> Self {
        self.max_frame_bytes = bytes;
        self
    }

    /// Draw-plane wire encoding for pipe/socket transports — see
    /// `PipelineConfig::wire_format`.
    pub fn wire_format(mut self, f: WireFormat) -> Self {
        self.wire_format = f;
        self
    }

    /// Draws per binary chunk frame (clamped to ≥ 1) — see
    /// `PipelineConfig::draw_batch`.
    pub fn draw_batch(mut self, n: usize) -> Self {
        self.draw_batch = n;
        self
    }

    /// Rows per sealed draw-store chunk (clamped to ≥ 1) — see
    /// `PipelineConfig::chunk_rows`.
    pub fn chunk_rows(mut self, n: usize) -> Self {
        self.chunk_rows = n;
        self
    }

    /// Draw-plane spill budget in MiB (`None` = dense) — see
    /// `PipelineConfig::draw_spill_budget_mb`.
    pub fn draw_spill_budget_mb(mut self, mb: Option<usize>) -> Self {
        self.draw_spill_budget_mb = mb;
        self
    }

    /// Scheduler failure policy — see
    /// `PipelineConfig::failure_policy`.
    pub fn failure_policy(mut self, p: FailurePolicy) -> Self {
        self.failure_policy = p;
        self
    }

    /// Retry budget per machine under the retry policy — see
    /// `PipelineConfig::max_retries`.
    pub fn max_retries(mut self, n: usize) -> Self {
        self.max_retries = n;
        self
    }

    /// Worker heartbeat interval in seconds (`0` = disabled) — see
    /// `PipelineConfig::heartbeat_secs`.
    pub fn heartbeat_secs(mut self, s: usize) -> Self {
        self.heartbeat_secs = s;
        self
    }

    /// Leader liveness deadline in seconds (`0` = disabled) — see
    /// `PipelineConfig::io_driver`.
    pub fn io_driver(mut self, d: IoDriver) -> Self {
        self.io_driver = d;
        self
    }

    /// `PipelineConfig::reactor_threads` (clamped to ≥ 1).
    pub fn reactor_threads(mut self, n: usize) -> Self {
        self.reactor_threads = n.max(1);
        self
    }

    /// `PipelineConfig::liveness_timeout_secs`.
    pub fn liveness_timeout_secs(mut self, s: usize) -> Self {
        self.liveness_timeout_secs = s;
        self
    }

    /// Socket dial timeout in seconds (clamped to ≥ 1) — see
    /// `PipelineConfig::connect_timeout_secs`.
    pub fn connect_timeout_secs(mut self, s: usize) -> Self {
        self.connect_timeout_secs = s;
        self
    }

    pub fn artifact_dir(mut self, d: &str) -> Self {
        self.artifact_dir = d.to_string();
        self
    }

    pub fn build(self) -> PipelineConfig {
        let t = self.samples_per_machine;
        PipelineConfig {
            model: self.model,
            machines: self.machines,
            samples_per_machine: t,
            burn_in: self.burn_in.unwrap_or(t / 5),
            // Clamp here, not only in the setter: `from_str_cfg` writes
            // the field directly, and `thin = 0` would divide by zero
            // in the worker loop.
            thin: self.thin.max(1),
            seed: self.seed,
            sampler: self
                .sampler
                .unwrap_or(SamplerKind::Hmc { step: 0.1, n_leapfrog: 10 }),
            method: self.method,
            t_out: self.t_out.unwrap_or(t),
            threads: self.threads.unwrap_or(self.machines),
            combine_threads: self.combine_threads,
            use_runtime: self.use_runtime,
            artifact_dir: self.artifact_dir,
            process_mode: self.process_mode,
            worker_bin: self.worker_bin,
            workers: self.workers,
            worker_slots: self.worker_slots,
            shard_format: self.shard_format,
            combine_cache_budget_mb: self.combine_cache_budget_mb,
            combine_backend: self.combine_backend,
            shard_inline: self.shard_inline,
            max_frame_bytes: self.max_frame_bytes,
            wire_format: self.wire_format,
            // Backstop clamps for programmatic builders; `from_str_cfg`
            // rejects the zero values outright before reaching here.
            draw_batch: self.draw_batch.max(1),
            chunk_rows: self.chunk_rows.max(1),
            draw_spill_budget_mb: self.draw_spill_budget_mb,
            failure_policy: self.failure_policy,
            max_retries: self.max_retries,
            heartbeat_secs: self.heartbeat_secs,
            liveness_timeout_secs: self.liveness_timeout_secs,
            io_driver: self.io_driver,
            reactor_threads: self.reactor_threads.max(1),
            connect_timeout_secs: self.connect_timeout_secs.max(1),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_defaults() {
        let c = PipelineConfig::builder("gaussian").build();
        assert_eq!(c.machines, 10);
        assert_eq!(c.burn_in, 200);
        assert_eq!(c.t_out, 1000);
        assert_eq!(c.threads, 10);
        assert_eq!(c.combine_threads, 0); // auto: all cores
        assert!(!c.process_mode);
        assert!(c.worker_bin.is_empty()); // empty = current executable
    }

    #[test]
    fn sampler_spec_roundtrips_bit_exactly() {
        let kinds = [
            SamplerKind::Hmc { step: 0.1, n_leapfrog: 10 },
            SamplerKind::Nuts { step: 1.0 / 3.0, max_depth: 7 },
            SamplerKind::Rwm { scale: 2.5e-8 },
            SamplerKind::Mala { step: 0.025 },
        ];
        for k in &kinds {
            let spec = sampler_spec(k);
            let back = parse_sampler(&spec).unwrap();
            assert_eq!(
                format!("{k:?}"),
                format!("{back:?}"),
                "spec '{spec}' did not round-trip"
            );
        }
    }

    #[test]
    fn cfg_string_roundtrips_every_key() {
        // Every knob off its default, including a seed near u64::MAX
        // and a sampler whose floats need shortest-round-trip `{:e}`
        // rendering — the job-spec wire format must survive a
        // parse → render → parse cycle without drift.
        let cfg = PipelineConfig::builder("logistic")
            .machines(7)
            .samples_per_machine(300)
            .burn_in(11)
            .thin(3)
            .threads(4)
            .seed(u64::MAX - 5)
            .sampler(SamplerKind::Nuts { step: 1.0 / 3.0, max_depth: 7 })
            .method(CombineMethod::Pairwise)
            .t_out(123)
            .combine_threads(2)
            .use_runtime(true)
            .artifact_dir("artifacts/run1")
            .process_mode(true)
            .worker_bin("/usr/bin/repro")
            .workers("127.0.0.1:9001,127.0.0.1:9002")
            .worker_slots(3)
            .shard_format(ShardFormat::Binary)
            .combine_cache_budget_mb(64)
            .combine_backend(CombineKernelKind::Blocked)
            .shard_inline(true)
            .max_frame_bytes(1 << 20)
            .wire_format(WireFormat::Binary)
            .draw_batch(17)
            .chunk_rows(33)
            .draw_spill_budget_mb(Some(8))
            .failure_policy(FailurePolicy::Retry)
            .max_retries(5)
            .heartbeat_secs(2)
            .liveness_timeout_secs(9)
            .connect_timeout_secs(6)
            .io_driver(IoDriver::Reactor)
            .reactor_threads(3)
            .build();
        let text = cfg.to_cfg_string();
        let back = PipelineConfig::from_str_cfg(&text).unwrap();
        assert_eq!(back.to_cfg_string(), text, "render must be a fixpoint");
        assert_eq!(back.seed, u64::MAX - 5);
        assert_eq!(back.threads, 4);
        assert_eq!(back.burn_in, 11);
        assert_eq!(back.workers, "127.0.0.1:9001,127.0.0.1:9002");
        assert_eq!(back.io_driver, IoDriver::Reactor);
        assert_eq!(back.failure_policy, FailurePolicy::Retry);
        assert_eq!(back.draw_spill_budget_mb, Some(8));
        assert_eq!(
            format!("{:?}", back.sampler),
            format!("{:?}", cfg.sampler)
        );
        // Optional keys are omitted, not rendered as empty values.
        let lean = PipelineConfig::builder("gaussian").build();
        let lean_text = lean.to_cfg_string();
        assert!(!lean_text.contains("artifact_dir"));
        assert!(!lean_text.contains("worker_bin"));
        assert!(!lean_text.contains("workers "));
        assert!(!lean_text.contains("draw_spill_budget_mb"));
        let lean_back = PipelineConfig::from_str_cfg(&lean_text).unwrap();
        assert_eq!(lean_back.to_cfg_string(), lean_text);
    }

    #[test]
    fn cfg_file_thin_zero_clamped() {
        let c = PipelineConfig::from_str_cfg("model = gaussian\nthin = 0\n")
            .unwrap();
        assert_eq!(c.thin, 1);
    }

    #[test]
    fn cfg_file_process_mode_keys() {
        let c = PipelineConfig::from_str_cfg(
            "model = gaussian\nprocess_mode = true\nworker_bin = /usr/bin/repro\n",
        )
        .unwrap();
        assert!(c.process_mode);
        assert_eq!(c.worker_bin, "/usr/bin/repro");
        // Distributed-runtime defaults: no socket workers, one process
        // per machine, JSON spills, 256 MiB anneal cache.
        assert!(c.workers.is_empty());
        assert_eq!(c.worker_slots, 0);
        assert_eq!(c.shard_format, ShardFormat::Json);
        assert_eq!(c.combine_cache_budget_mb, 256);
        assert_eq!(c.combine_backend, CombineKernelKind::Naive);
        assert!(!c.shard_inline);
        // Draw-plane defaults: the original JSON wire, 64-draw batches
        // (a binary-only knob until wire_format flips).
        assert_eq!(c.wire_format, WireFormat::Json);
        assert_eq!(c.draw_batch, 64);
    }

    #[test]
    fn cfg_file_wire_format_keys() {
        let c = PipelineConfig::from_str_cfg(
            "model = gaussian\nwire_format = binary\ndraw_batch = 7\n",
        )
        .unwrap();
        assert_eq!(c.wire_format, WireFormat::Binary);
        assert_eq!(c.draw_batch, 7);
        // A zero batch is a config error named at parse time, not a
        // silent clamp (the builder's `.max(1)` stays only as a backstop
        // for programmatic callers).
        let err = PipelineConfig::from_str_cfg(
            "model = gaussian\ndraw_batch = 0\n",
        )
        .unwrap_err();
        assert!(
            err.to_string().contains("draw_batch"),
            "error should name the key: {err}"
        );
        assert!(PipelineConfig::from_str_cfg(
            "model = gaussian\nwire_format = msgpack\n"
        )
        .is_err());
        assert!(PipelineConfig::from_str_cfg(
            "model = gaussian\ndraw_batch = many\n"
        )
        .is_err());
    }

    #[test]
    fn cfg_file_draw_store_keys() {
        let c = PipelineConfig::from_str_cfg(
            "model = gaussian\nchunk_rows = 128\ndraw_spill_budget_mb = 4\n",
        )
        .unwrap();
        assert_eq!(c.chunk_rows, 128);
        assert_eq!(c.draw_spill_budget_mb, Some(4));
        // Defaults: 512-row chunks, no spill budget (dense draw plane).
        let c = PipelineConfig::from_str_cfg("model = gaussian\n").unwrap();
        assert_eq!(c.chunk_rows, crate::data::store::DEFAULT_CHUNK_ROWS);
        assert_eq!(c.draw_spill_budget_mb, None);
        // Budget 0 is meaningful (spill everything), so it parses fine;
        // chunk_rows = 0 is degenerate and rejected with the key named.
        let c = PipelineConfig::from_str_cfg(
            "model = gaussian\ndraw_spill_budget_mb = 0\n",
        )
        .unwrap();
        assert_eq!(c.draw_spill_budget_mb, Some(0));
        let err = PipelineConfig::from_str_cfg(
            "model = gaussian\nchunk_rows = 0\n",
        )
        .unwrap_err();
        assert!(
            err.to_string().contains("chunk_rows"),
            "error should name the key: {err}"
        );
        // Negative and non-numeric budgets fail the usize parse with a
        // structured error, never a panic or a wrapped value.
        for bad in ["-1", "lots", "18446744073709551616"] {
            let cfg = format!("model = gaussian\ndraw_spill_budget_mb = {bad}\n");
            let err = PipelineConfig::from_str_cfg(&cfg).unwrap_err();
            assert!(
                err.to_string().contains("draw_spill_budget_mb"),
                "error should name the key for '{bad}': {err}"
            );
        }
        assert!(PipelineConfig::from_str_cfg(
            "model = gaussian\nchunk_rows = -5\n"
        )
        .is_err());
    }

    #[test]
    fn cfg_file_kernel_and_inline_keys() {
        let c = PipelineConfig::from_str_cfg(
            "model = gaussian\n\
             combine_backend = blocked\n\
             shard_inline = true\n\
             max_frame_bytes = 134217728\n",
        )
        .unwrap();
        assert_eq!(c.combine_backend, CombineKernelKind::Blocked);
        assert!(c.shard_inline);
        assert_eq!(c.max_frame_bytes, 134_217_728);
        let c = PipelineConfig::from_str_cfg(
            "model = gaussian\ncombine_backend = device\n",
        )
        .unwrap();
        assert_eq!(c.combine_backend, CombineKernelKind::Device);
        assert!(PipelineConfig::from_str_cfg(
            "model = gaussian\ncombine_backend = gpu\n"
        )
        .is_err());
    }

    #[test]
    fn cfg_file_distributed_keys() {
        let c = PipelineConfig::from_str_cfg(
            "model = gaussian\n\
             workers = 10.0.0.1:7001, 10.0.0.2:7001\n\
             worker_slots = 3\n\
             shard_format = binary\n\
             combine_cache_budget_mb = 64\n",
        )
        .unwrap();
        assert_eq!(c.workers, "10.0.0.1:7001, 10.0.0.2:7001");
        assert_eq!(c.worker_slots, 3);
        assert_eq!(c.shard_format, ShardFormat::Binary);
        assert_eq!(c.combine_cache_budget_mb, 64);
        assert!(PipelineConfig::from_str_cfg(
            "model = gaussian\nshard_format = yaml\n"
        )
        .is_err());
        assert!(PipelineConfig::from_str_cfg(
            "model = gaussian\ncombine_cache_budget_mb = lots\n"
        )
        .is_err());
    }

    #[test]
    fn cfg_file_resilience_keys() {
        let c = PipelineConfig::from_str_cfg(
            "model = gaussian\n\
             failure_policy = retry\n\
             max_retries = 5\n\
             heartbeat_secs = 2\n\
             liveness_timeout_secs = 10\n\
             connect_timeout_secs = 3\n",
        )
        .unwrap();
        assert_eq!(c.failure_policy, FailurePolicy::Retry);
        assert_eq!(c.max_retries, 5);
        assert_eq!(c.heartbeat_secs, 2);
        assert_eq!(c.liveness_timeout_secs, 10);
        assert_eq!(c.connect_timeout_secs, 3);
        // Defaults: fail-fast, 2 retries held in reserve, heartbeats
        // and liveness off, the historical 30 s dial timeout.
        let c = PipelineConfig::from_str_cfg("model = gaussian\n").unwrap();
        assert_eq!(c.failure_policy, FailurePolicy::Failfast);
        assert_eq!(c.max_retries, 2);
        assert_eq!(c.heartbeat_secs, 0);
        assert_eq!(c.liveness_timeout_secs, 0);
        assert_eq!(c.connect_timeout_secs, 30);
        // Bad values are structured errors naming the key.
        let err = PipelineConfig::from_str_cfg(
            "model = gaussian\nfailure_policy = shrug\n",
        )
        .unwrap_err();
        assert!(err.to_string().contains("failure_policy"), "{err}");
        let err = PipelineConfig::from_str_cfg(
            "model = gaussian\nconnect_timeout_secs = 0\n",
        )
        .unwrap_err();
        assert!(err.to_string().contains("connect_timeout_secs"), "{err}");
        assert!(PipelineConfig::from_str_cfg(
            "model = gaussian\nmax_retries = some\n"
        )
        .is_err());
    }

    #[test]
    fn cfg_file_io_driver_keys() {
        let c = PipelineConfig::from_str_cfg(
            "model = gaussian\n\
             io_driver = reactor\n\
             reactor_threads = 2\n",
        )
        .unwrap();
        assert_eq!(c.io_driver, IoDriver::Reactor);
        assert_eq!(c.reactor_threads, 2);
        // Defaults: thread-per-endpoint, one reactor thread.
        let c = PipelineConfig::from_str_cfg("model = gaussian\n").unwrap();
        assert_eq!(c.io_driver, IoDriver::Threads);
        assert_eq!(c.reactor_threads, 1);
        // Bad values are structured errors naming the key.
        let err = PipelineConfig::from_str_cfg(
            "model = gaussian\nio_driver = epoll\n",
        )
        .unwrap_err();
        assert!(err.to_string().contains("io_driver"), "{err}");
        let err = PipelineConfig::from_str_cfg(
            "model = gaussian\nreactor_threads = 0\n",
        )
        .unwrap_err();
        assert!(err.to_string().contains("reactor_threads"), "{err}");
        assert_eq!(IoDriver::parse("threads").unwrap().name(), "threads");
        assert_eq!(IoDriver::parse("reactor").unwrap().name(), "reactor");
    }

    #[test]
    fn cfg_file_roundtrip() {
        let text = "\
# demo config
model = logistic
machines = 20
samples_per_machine = 500
method = nonparametric
sampler = hmc:0.05,20
seed = 7
combine_threads = 4
use_runtime = true
artifact_dir = my_artifacts
";
        let c = PipelineConfig::from_str_cfg(text).unwrap();
        assert_eq!(c.model, "logistic");
        assert_eq!(c.machines, 20);
        assert_eq!(c.method.name(), "nonparametric");
        assert_eq!(c.seed, 7);
        assert_eq!(c.combine_threads, 4);
        assert!(c.use_runtime);
        assert_eq!(c.artifact_dir, "my_artifacts");
        match c.sampler {
            SamplerKind::Hmc { step, n_leapfrog } => {
                assert!((step - 0.05).abs() < 1e-12);
                assert_eq!(n_leapfrog, 20);
            }
            _ => panic!("wrong sampler"),
        }
    }

    #[test]
    fn cfg_rejects_garbage() {
        assert!(PipelineConfig::from_str_cfg("model logistic").is_err());
        assert!(PipelineConfig::from_str_cfg("machines = 5").is_err()); // no model
        assert!(
            PipelineConfig::from_str_cfg("model = x\nmachines = nope").is_err()
        );
        assert!(PipelineConfig::from_str_cfg(
            "model = x\nsampler = warp:1"
        )
        .is_err());
    }

    #[test]
    fn sampler_spec_parsing() {
        assert!(matches!(
            parse_sampler("rwm:2.0").unwrap(),
            SamplerKind::Rwm { .. }
        ));
        assert!(matches!(
            parse_sampler("nuts").unwrap(),
            SamplerKind::Nuts { .. }
        ));
        assert!(matches!(
            parse_sampler("mala:0.2").unwrap(),
            SamplerKind::Mala { .. }
        ));
    }
}
