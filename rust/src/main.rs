//! `repro` — embarrassingly parallel MCMC CLI (leader entrypoint).
//!
//! Subcommands:
//!   pipeline      run partition → parallel sample → combine end-to-end
//!   single-chain  run the regularChain baseline
//!   combine       combine subposterior sample CSVs into posterior draws
//!   eval          L2 distance between two sample CSVs
//!   info          inspect an artifact directory
//!   worker        (hidden) process-mode worker: load a shard manifest,
//!                 sample, stream frames on stdout — spawned by
//!                 `pipeline --process-mode true`, not by hand
//!   serve         (hidden) socket-mode worker daemon: listen on TCP,
//!                 accept a manifest frame per connection, stream the
//!                 run back — dialed by `pipeline --workers a,b,…`
//!   leaderd       persistent leader daemon: accept many concurrent
//!                 pipeline jobs over the RPJOB1 protocol, each
//!                 byte-identical to the solo run of the same spec
//!   submit        ship a pipeline job spec to a leaderd, stream back
//!                 progress and combined draws
//!
//! Examples:
//!   repro pipeline --model logistic --n 50000 --d 50 --machines 10 \
//!       --samples 2000 --method semiparametric --out combined.csv
//!   repro combine --method nonparametric --out post.csv m0.csv m1.csv
//!   repro eval a.csv b.csv
//!   repro info --artifacts artifacts

use std::collections::BTreeMap;
use std::path::Path;
use std::process::ExitCode;

use repro::combine::CombineMethod;
use repro::config::PipelineConfig;
use repro::coordinator::pipeline;
use repro::data::{io, synth, Dataset};
use repro::error::{Error, Result};
use repro::evaluation::l2_distance_subsampled;
use repro::types::SampleMatrix;

/// Tiny flag parser: `--key value` pairs plus positional arguments.
struct Args {
    flags: BTreeMap<String, String>,
    positional: Vec<String>,
}

impl Args {
    fn parse(argv: &[String]) -> Result<Args> {
        let mut flags = BTreeMap::new();
        let mut positional = Vec::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(key) = a.strip_prefix("--") {
                let val = argv.get(i + 1).ok_or_else(|| {
                    Error::Config(format!("flag --{key} needs a value"))
                })?;
                flags.insert(key.to_string(), val.clone());
                i += 2;
            } else {
                positional.push(a.clone());
                i += 1;
            }
        }
        Ok(Args { flags, positional })
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::Config(format!("bad --{key}: {v}"))),
        }
    }

    fn get_u64(&self, key: &str, default: u64) -> Result<u64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::Config(format!("bad --{key}: {v}"))),
        }
    }
}

fn build_dataset(model: &str, n: usize, d: usize, seed: u64) -> Result<Dataset> {
    synth::by_name(model, n, d, seed)
}

/// Build a [`PipelineConfig`] from CLI flags. Shared by `pipeline`
/// (solo run) and `submit` (job shipped to a leader daemon) so a
/// submitted spec accepts the identical flag surface — and therefore
/// describes the identical run — as the solo CLI. `--config FILE`
/// takes precedence over individual flags.
fn pipeline_cfg_from_args(args: &Args) -> Result<PipelineConfig> {
    match args.get("config") {
        Some(path) => PipelineConfig::from_file(path),
        None => {
            let model = args.get("model").unwrap_or("gaussian").to_string();
            let mut b = PipelineConfig::builder(&model)
                .machines(args.get_usize("machines", 10)?)
                .samples_per_machine(args.get_usize("samples", 1000)?)
                .seed(args.get_u64("seed", 42)?);
            if let Some(m) = args.get("method") {
                b = b.method(CombineMethod::parse(m)?);
            }
            if let Some(t) = args.get("threads") {
                b = b.threads(t.parse().map_err(|_| {
                    Error::Config(format!("bad --threads: {t}"))
                })?);
            }
            if let Some(t) = args.get("combine-threads") {
                b = b.combine_threads(t.parse().map_err(|_| {
                    Error::Config(format!("bad --combine-threads: {t}"))
                })?);
            }
            if args.get("use-runtime") == Some("true") {
                b = b.use_runtime(true);
            }
            if args.get("process-mode") == Some("true") {
                b = b.process_mode(true);
            }
            if let Some(w) = args.get("worker-bin") {
                b = b.worker_bin(w);
            }
            if let Some(w) = args.get("workers") {
                b = b.workers(w);
            }
            if let Some(w) = args.get("worker-slots") {
                b = b.worker_slots(w.parse().map_err(|_| {
                    Error::Config(format!("bad --worker-slots: {w}"))
                })?);
            }
            if let Some(f) = args.get("shard-format") {
                b = b.shard_format(io::ShardFormat::parse(f)?);
            }
            if let Some(m) = args.get("combine-cache-budget-mb") {
                b = b.combine_cache_budget_mb(m.parse().map_err(|_| {
                    Error::Config(format!(
                        "bad --combine-cache-budget-mb: {m}"
                    ))
                })?);
            }
            if let Some(k) = args.get("combine-backend") {
                b = b.combine_backend(
                    repro::kernel::CombineKernelKind::parse(k)?,
                );
            }
            if args.get("shard-inline") == Some("true") {
                b = b.shard_inline(true);
            }
            if let Some(v) = args.get("max-frame-bytes") {
                b = b.max_frame_bytes(v.parse().map_err(|_| {
                    Error::Config(format!("bad --max-frame-bytes: {v}"))
                })?);
            }
            if let Some(v) = args.get("wire-format") {
                b = b.wire_format(
                    repro::coordinator::transport::WireFormat::parse(v)?,
                );
            }
            if let Some(v) = args.get("draw-batch") {
                let n: usize = v.parse().map_err(|_| {
                    Error::Config(format!("bad --draw-batch: {v}"))
                })?;
                if n == 0 {
                    return Err(Error::Config(
                        "--draw-batch must be >= 1 (got 0)".into(),
                    ));
                }
                b = b.draw_batch(n);
            }
            if let Some(v) = args.get("chunk-rows") {
                let n: usize = v.parse().map_err(|_| {
                    Error::Config(format!("bad --chunk-rows: {v}"))
                })?;
                if n == 0 {
                    return Err(Error::Config(
                        "--chunk-rows must be >= 1 (got 0)".into(),
                    ));
                }
                b = b.chunk_rows(n);
            }
            if let Some(v) = args.get("draw-spill-budget-mb") {
                b = b.draw_spill_budget_mb(Some(v.parse().map_err(
                    |_| {
                        Error::Config(format!(
                            "bad --draw-spill-budget-mb: {v}"
                        ))
                    },
                )?));
            }
            if let Some(v) = args.get("failure-policy") {
                b = b.failure_policy(
                    repro::config::FailurePolicy::parse(v)?,
                );
            }
            if let Some(v) = args.get("max-retries") {
                b = b.max_retries(v.parse().map_err(|_| {
                    Error::Config(format!("bad --max-retries: {v}"))
                })?);
            }
            if let Some(v) = args.get("heartbeat-secs") {
                b = b.heartbeat_secs(v.parse().map_err(|_| {
                    Error::Config(format!("bad --heartbeat-secs: {v}"))
                })?);
            }
            if let Some(v) = args.get("liveness-timeout-secs") {
                b = b.liveness_timeout_secs(v.parse().map_err(|_| {
                    Error::Config(format!(
                        "bad --liveness-timeout-secs: {v}"
                    ))
                })?);
            }
            if let Some(v) = args.get("io-driver") {
                b = b.io_driver(repro::config::IoDriver::parse(v)?);
            }
            if let Some(v) = args.get("reactor-threads") {
                let n: usize = v.parse().map_err(|_| {
                    Error::Config(format!("bad --reactor-threads: {v}"))
                })?;
                if n == 0 {
                    return Err(Error::Config(
                        "--reactor-threads must be >= 1 (got 0); \
                         a reactor with no threads polls nothing"
                            .into(),
                    ));
                }
                b = b.reactor_threads(n);
            }
            if let Some(v) = args.get("connect-timeout-secs") {
                let secs: usize = v.parse().map_err(|_| {
                    Error::Config(format!(
                        "bad --connect-timeout-secs: {v}"
                    ))
                })?;
                if secs == 0 {
                    return Err(Error::Config(
                        "--connect-timeout-secs must be >= 1 (got 0); \
                         a zero dial timeout can never connect"
                            .into(),
                    ));
                }
                b = b.connect_timeout_secs(secs);
            }
            if let Some(d) = args.get("artifacts") {
                b = b.artifact_dir(d);
            }
            Ok(b.build())
        }
    }
}

fn cmd_pipeline(args: &Args) -> Result<()> {
    let cfg = pipeline_cfg_from_args(args)?;
    let n = args.get_usize("n", 10_000)?;
    let d = args.get_usize("d", 10)?;
    let data = build_dataset(&cfg.model, n, d, cfg.seed)?;
    eprintln!(
        "pipeline: model={} n={} d={} M={} T={} method={}",
        cfg.model,
        n,
        data.param_dim(),
        cfg.machines,
        cfg.samples_per_machine,
        cfg.method.name()
    );
    let out = if cfg.use_runtime {
        run_runtime_pipeline(&cfg, &data)?
    } else if cfg.process_mode || !cfg.workers.is_empty() {
        pipeline::run_process(&cfg, &data)?
    } else {
        pipeline::run_native(&cfg, &data)?
    };
    eprintln!("{}", out.metrics);
    eprintln!(
        "cluster-time model: sampling={:.3}s transfer={:.6}s combine={:.3}s",
        out.timing.sampling_secs, out.timing.transfer_secs, out.timing.combine_secs
    );
    let mean = out.combined.mean();
    let show = mean.len().min(8);
    eprintln!("posterior mean (first {show} dims): {:?}", &mean[..show]);
    if let Some(path) = args.get("out") {
        io::write_samples_csv(Path::new(path), &out.combined)?;
        eprintln!("wrote {} draws to {path}", out.combined.len());
    }
    Ok(())
}

/// PJRT-runtime pipeline: subposteriors evaluated through compiled
/// artifacts (sequential workers; see pipeline::run_sequential docs).
fn run_runtime_pipeline(
    cfg: &PipelineConfig,
    data: &Dataset,
) -> Result<pipeline::PipelineOutput> {
    use repro::coordinator::partition::Partitioner;
    use repro::model::LogDensity;
    use repro::runtime::{RuntimeClient, XlaDensity};
    let client = RuntimeClient::cpu(Path::new(&cfg.artifact_dir))?;
    eprintln!("runtime: platform={}", client.platform());
    let shards =
        Partitioner::Contiguous.split(data.len(), cfg.machines, cfg.seed)?;
    let prior_w = 1.0 / cfg.machines as f64;
    let models: Vec<Box<dyn LogDensity>> = shards
        .iter()
        .map(|idx| {
            let xd = XlaDensity::from_shard(&client, data, idx, prior_w)?;
            eprintln!("  machine: {xd:?}");
            Ok(Box::new(xd) as Box<dyn LogDensity>)
        })
        .collect::<Result<_>>()?;
    pipeline::run_sequential(cfg, models)
}

fn cmd_single_chain(args: &Args) -> Result<()> {
    let model = args.get("model").unwrap_or("gaussian");
    let n = args.get_usize("n", 10_000)?;
    let d = args.get_usize("d", 10)?;
    let seed = args.get_u64("seed", 42)?;
    let cfg = PipelineConfig::builder(model)
        .machines(1)
        .samples_per_machine(args.get_usize("samples", 1000)?)
        .seed(seed)
        .build();
    let data = build_dataset(model, n, d, seed)?;
    let out = pipeline::run_single_chain(&cfg, &data)?;
    eprintln!(
        "single chain: {} draws, accept={:.3}, {:.3}s",
        out.samples.len(),
        out.accept_rate,
        out.wall_secs
    );
    if let Some(path) = args.get("out") {
        io::write_samples_csv(Path::new(path), &out.samples)?;
    }
    Ok(())
}

fn cmd_combine(args: &Args) -> Result<()> {
    if args.positional.is_empty() {
        return Err(Error::Config(
            "combine needs subposterior CSV paths".into(),
        ));
    }
    let sets: Vec<SampleMatrix> = args
        .positional
        .iter()
        .map(|p| io::read_samples_csv(Path::new(p)))
        .collect::<Result<_>>()?;
    let refs: Vec<&SampleMatrix> = sets.iter().collect();
    let method =
        CombineMethod::parse(args.get("method").unwrap_or("semiparametric"))?;
    let t_out = args.get_usize("t", refs[0].len())?;
    let seed = args.get_u64("seed", 42)?;
    let threads = args.get_usize("combine-threads", 0)?;
    let combined = repro::combine::combine_sets_threaded(
        method, &refs, t_out, seed, threads,
    )?;
    eprintln!(
        "combined {} machines → {} draws via {}",
        refs.len(),
        combined.len(),
        method.name()
    );
    let out = args.get("out").unwrap_or("combined.csv");
    io::write_samples_csv(Path::new(out), &combined)?;
    eprintln!("wrote {out}");
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    if args.positional.len() != 2 {
        return Err(Error::Config("eval needs exactly two CSV paths".into()));
    }
    let a = io::read_samples_csv(Path::new(&args.positional[0]))?;
    let b = io::read_samples_csv(Path::new(&args.positional[1]))?;
    let cap = args.get_usize("subsample", 500)?;
    println!("{:.6e}", l2_distance_subsampled(&a, &b, cap));
    Ok(())
}

/// Hidden process-mode worker (spawned by `pipeline --process-mode
/// true`): load the manifest, then run the shared manifest-execution
/// path (`coordinator::serve::run_manifest` — the same code socket
/// daemons run), streaming each frame onto stdout. Errors go to stderr
/// + a non-zero exit; the leader attaches them to the failing machine.
fn cmd_worker(args: &Args) -> Result<()> {
    use repro::coordinator::serve::run_manifest;
    use repro::coordinator::transport::{write_frame_bytes, WorkerManifest};

    let manifest_path = args
        .get("manifest")
        .ok_or_else(|| Error::Config("worker needs --manifest".into()))?;
    let wm = WorkerManifest::load(Path::new(manifest_path))?;
    let stdout = std::io::stdout();
    let mut out = std::io::BufWriter::new(stdout.lock());
    let machine = wm.machine;
    run_manifest(&wm, &mut |frame: &[u8]| -> std::io::Result<()> {
        if let Err(e) = write_frame_bytes(&mut out, frame) {
            // The frame stream is this process's only output: with the
            // pipe gone (leader died or canceled the run) the rest of
            // the chain is wasted work — bail out now rather than
            // sampling draws nobody will read.
            eprintln!("worker {machine}: stdout stream closed: {e}");
            std::process::exit(1);
        }
        Ok(())
    })
}

/// Hidden socket-mode worker daemon (dialed by `pipeline --workers`):
/// bind `--listen`, print `LISTENING <addr>` (so `--listen host:0`
/// ephemeral ports are discoverable), serve one manifest per
/// connection. `--jobs N` exits after N jobs (0 = serve until killed);
/// `--max-frame-bytes B` raises the inbound frame cap for leaders
/// shipping large shards inline (`--shard-inline true`);
/// `--manifest-timeout-secs S` bounds how long an accepted connection
/// may take to deliver its manifest frame; `--fault SPEC` arms the
/// deterministic chaos layer (refuse-dial | drop-after:N | delay-ms:MS
/// | corrupt:N) so CI can stand up a misbehaving endpoint.
fn cmd_serve(args: &Args) -> Result<()> {
    use repro::coordinator::serve::{serve, ServeOptions};
    use repro::coordinator::FaultSpec;
    let listen = args.get("listen").unwrap_or("127.0.0.1:0");
    let jobs = args.get_usize("jobs", 0)?;
    let mut opts = ServeOptions {
        max_jobs: if jobs == 0 { None } else { Some(jobs) },
        ..Default::default()
    };
    // Inbound frame cap (manifest + optional inline shard frame).
    // Leaders shipping shards inline past the 64 MiB default need
    // this raised in step with their transport-side cap.
    if let Some(b) = args.get("max-frame-bytes") {
        opts.max_frame_bytes = b.parse().map_err(|_| {
            Error::Config(format!("bad --max-frame-bytes: {b}"))
        })?;
    }
    if let Some(s) = args.get("manifest-timeout-secs") {
        let secs: u64 = s.parse().map_err(|_| {
            Error::Config(format!("bad --manifest-timeout-secs: {s}"))
        })?;
        if secs == 0 {
            return Err(Error::Config(
                "--manifest-timeout-secs must be >= 1 (got 0); \
                 an unbounded manifest read would let one idle \
                 connection wedge the daemon"
                    .into(),
            ));
        }
        opts.manifest_timeout = std::time::Duration::from_secs(secs);
    }
    if let Some(spec) = args.get("fault") {
        opts.fault = Some(FaultSpec::parse(spec)?);
    }
    serve(listen, &opts, &mut std::io::stdout())
}

/// Bridge SIGTERM/ctrl-c into the leader daemon's graceful-shutdown
/// handle. The handler itself only flips one static atomic
/// (async-signal-safe); a watcher thread forwards the flip to the
/// [`repro::coordinator::Shutdown`] handle, which makes the daemon
/// refuse new submissions, drain in-flight jobs, and exit 0.
#[cfg(unix)]
fn install_shutdown_signals(shutdown: &repro::coordinator::Shutdown) {
    use std::sync::atomic::{AtomicBool, Ordering};
    static SIGNALED: AtomicBool = AtomicBool::new(false);
    extern "C" fn on_signal(_sig: i32) {
        SIGNALED.store(true, Ordering::SeqCst);
    }
    // Bare libc declaration, same idiom as coordinator::reactor — the
    // repo links no signal crate.
    extern "C" {
        fn signal(sig: i32, handler: usize) -> usize;
    }
    const SIGINT: i32 = 2;
    const SIGTERM: i32 = 15;
    unsafe {
        signal(SIGINT, on_signal as usize);
        signal(SIGTERM, on_signal as usize);
    }
    let shutdown = shutdown.clone();
    std::thread::spawn(move || loop {
        if SIGNALED.load(Ordering::SeqCst) {
            shutdown.trigger();
            return;
        }
        std::thread::sleep(std::time::Duration::from_millis(50));
    });
}

#[cfg(not(unix))]
fn install_shutdown_signals(_shutdown: &repro::coordinator::Shutdown) {}

/// Leader daemon: bind `--listen`, print `LISTENING <addr>`, accept
/// concurrent pipeline jobs over the RPJOB1 protocol with up to
/// `--max-concurrent-jobs` running at once (further jobs queue FIFO).
/// `--jobs N` exits after N connections drain (0 = serve until
/// SIGTERM/ctrl-c, which drains gracefully); the per-job summary and
/// aggregate job metrics print on exit.
fn cmd_leaderd(args: &Args) -> Result<()> {
    use repro::coordinator::server::{leaderd, LeaderdOptions, Shutdown};
    let listen = args.get("listen").unwrap_or("127.0.0.1:0");
    let defaults = LeaderdOptions::default();
    let max_concurrent_jobs = args
        .get_usize("max-concurrent-jobs", defaults.max_concurrent_jobs)?;
    if max_concurrent_jobs == 0 {
        return Err(Error::Config(
            "--max-concurrent-jobs must be >= 1 (got 0); a daemon \
             that can run nothing admits nothing"
                .into(),
        ));
    }
    let jobs = args.get_usize("jobs", 0)?;
    let mut opts = LeaderdOptions {
        max_concurrent_jobs,
        max_jobs: if jobs == 0 { None } else { Some(jobs) },
        ..defaults
    };
    if let Some(b) = args.get("max-frame-bytes") {
        opts.max_frame_bytes = b.parse().map_err(|_| {
            Error::Config(format!("bad --max-frame-bytes: {b}"))
        })?;
    }
    if let Some(s) = args.get("submit-timeout-secs") {
        let secs: u64 = s.parse().map_err(|_| {
            Error::Config(format!("bad --submit-timeout-secs: {s}"))
        })?;
        if secs == 0 {
            return Err(Error::Config(
                "--submit-timeout-secs must be >= 1 (got 0); an \
                 unbounded submit read would let one idle connection \
                 pin a client thread forever"
                    .into(),
            ));
        }
        opts.submit_timeout = std::time::Duration::from_secs(secs);
    }
    let shutdown = Shutdown::new();
    install_shutdown_signals(&shutdown);
    let summary =
        leaderd(listen, &opts, &shutdown, &mut std::io::stdout())?;
    eprint!("{summary}");
    Ok(())
}

/// Submit one pipeline job to a running leader daemon. Takes the same
/// flag surface as `pipeline` (or `--config FILE`), ships the spec to
/// `--to HOST:PORT`, narrates lifecycle frames on stderr, and writes
/// the combined draws — byte-identical to the solo run of the same
/// spec — to `--out`.
fn cmd_submit(args: &Args) -> Result<()> {
    use repro::coordinator::server::client::submit;
    use repro::coordinator::server::{JobSpec, JobState, JobUpdate};
    let to = args.get("to").ok_or_else(|| {
        Error::Config(
            "submit needs --to HOST:PORT (a running repro leaderd)"
                .into(),
        )
    })?;
    let cfg = pipeline_cfg_from_args(args)?;
    let n = args.get_usize("n", 10_000)?;
    let d = args.get_usize("d", 10)?;
    let spec = JobSpec::from_config(&cfg, n, d);
    eprintln!(
        "submit → {to}: model={} n={n} M={} T={} method={} seed={}",
        cfg.model,
        cfg.machines,
        cfg.samples_per_machine,
        cfg.method.name(),
        cfg.seed
    );
    let outcome = submit(to, &spec, &mut |u: &JobUpdate| match u.state {
        JobState::Running => eprintln!(
            "job {}: running (queued {:.1} ms)",
            u.job,
            u.queue_wait_ms.unwrap_or(0.0)
        ),
        JobState::Done => {}
        _ => eprintln!("job {}: {}", u.job, u.state.name()),
    })?;
    eprintln!(
        "job {}: done — {} draws (dim {}) queue_wait_ms={:.1} \
         time_to_first_draw_ms={:.1}",
        outcome.job,
        outcome.combined.len(),
        outcome.combined.dim(),
        outcome.queue_wait_ms,
        outcome.time_to_first_draw_ms
    );
    if let Some(path) = args.get("out") {
        io::write_samples_csv(Path::new(path), &outcome.combined)?;
        eprintln!("wrote {} draws to {path}", outcome.combined.len());
    }
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    let dir = args.get("artifacts").unwrap_or("artifacts");
    let manifest = repro::runtime::Manifest::load(Path::new(dir))?;
    println!("{} artifacts in {dir}:", manifest.artifacts.len());
    for a in &manifest.artifacts {
        let n = a.param("n").unwrap_or(0);
        println!(
            "  {:40} kind={:9} model={:13} n={:6} inputs={} outputs={}",
            a.name,
            a.kind,
            a.model,
            n,
            a.inputs.len(),
            a.outputs.len()
        );
    }
    Ok(())
}

fn usage() -> &'static str {
    "usage: repro <pipeline|single-chain|combine|eval|info|leaderd|submit> [flags]\n\
     \n\
     pipeline      --model M --n N --d D --machines M --samples T \\\n\
                   --method NAME --seed S [--threads K] \\\n\
                   [--combine-threads K] [--combine-cache-budget-mb MB] \\\n\
                   [--combine-backend naive|blocked|device] \\\n\
                   [--out FILE] [--shard-format json|binary] \\\n\
                   [--wire-format json|binary [--draw-batch N]] \\\n\
                   [--chunk-rows R] [--draw-spill-budget-mb MB] \\\n\
                   [--process-mode true [--worker-bin PATH] \\\n\
                    [--worker-slots W]] \\\n\
                   [--workers HOST:PORT,… (repro serve daemons) \\\n\
                    [--shard-inline true] [--max-frame-bytes B] \\\n\
                    [--heartbeat-secs S] [--liveness-timeout-secs S] \\\n\
                    [--connect-timeout-secs S] \\\n\
                    [--io-driver threads|reactor [--reactor-threads K]]] \\\n\
                   [--failure-policy failfast|retry [--max-retries N]] \\\n\
                   [--use-runtime true --artifacts DIR] [--config FILE]\n\
     single-chain  --model M --n N --d D --samples T [--out FILE]\n\
     combine       --method NAME [--t T] [--combine-threads K] \\\n\
                   [--out FILE] m0.csv m1.csv …\n\
     eval          [--subsample K] a.csv b.csv\n\
     info          [--artifacts DIR]\n\
     leaderd       [--listen HOST:PORT] [--max-concurrent-jobs K] \\\n\
                   [--jobs N] [--max-frame-bytes B] \\\n\
                   [--submit-timeout-secs S]\n\
     submit        --to HOST:PORT [pipeline flags | --config FILE] \\\n\
                   [--n N] [--d D] [--out FILE]"
}

fn main() -> ExitCode {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = argv.first().cloned() else {
        eprintln!("{}", usage());
        return ExitCode::FAILURE;
    };
    let args = match Args::parse(&argv[1..]) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n\n{}", usage());
            return ExitCode::FAILURE;
        }
    };
    let result = match cmd.as_str() {
        "pipeline" => cmd_pipeline(&args),
        "single-chain" => cmd_single_chain(&args),
        "combine" => cmd_combine(&args),
        "eval" => cmd_eval(&args),
        "info" => cmd_info(&args),
        // Hidden: spawned by `pipeline --process-mode true`.
        "worker" => cmd_worker(&args),
        // Hidden: the socket-transport worker daemon.
        "serve" => cmd_serve(&args),
        "leaderd" => cmd_leaderd(&args),
        "submit" => cmd_submit(&args),
        "help" | "--help" | "-h" => {
            println!("{}", usage());
            Ok(())
        }
        other => Err(Error::Config(format!("unknown command '{other}'"))),
    };
    match result {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            ExitCode::FAILURE
        }
    }
}
