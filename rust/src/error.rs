//! Crate-wide error type.

use std::fmt;

// Offline stub standing in for the real PJRT bindings (see
// `runtime/xla_shim.rs` for how to swap in the vendored crate).
use crate::runtime::xla_shim as xla;

/// Errors produced anywhere in the library.
#[derive(Debug)]
pub enum Error {
    /// Shape/dimension mismatch in a numeric routine.
    Shape(String),
    /// Matrix is not positive definite (Cholesky failure etc.).
    NotPosDef(String),
    /// Invalid configuration or argument.
    Config(String),
    /// Artifact manifest / runtime problems.
    Runtime(String),
    /// Underlying XLA/PJRT error.
    Xla(String),
    /// I/O error.
    Io(std::io::Error),
    /// JSON / config parse error (manifest, CLI, config files).
    Parse(String),
    /// Length-prefixed frame protocol violation (worker streams). Kept
    /// structured so leaders and socket peers can tell a corrupt prefix
    /// from an oversized frame from a mid-payload truncation.
    Frame(FrameError),
    /// A combine-kernel backend that cannot run in this build/
    /// environment (e.g. `--combine-backend device` with no vendored
    /// PJRT bindings). Structured so callers can distinguish "backend
    /// unavailable" from a genuine runtime fault and tell the user
    /// which backend to fall back to.
    KernelUnavailable {
        backend: &'static str,
        reason: String,
    },
}

/// Structured frame-protocol failures (see `coordinator::transport`).
#[derive(Debug)]
pub enum FrameError {
    /// Stream ended inside a length-prefix line.
    TruncatedPrefix,
    /// Length-prefix line exceeds the longest valid `usize` rendering —
    /// the stream is not frame-framed at all.
    PrefixTooLong { limit: usize },
    /// Length-prefix line is not a decimal `usize`.
    BadPrefix(String),
    /// Declared payload length exceeds the transport's frame cap.
    Oversized { len: usize, max: usize },
    /// Stream ended before the declared payload length was read.
    TruncatedPayload { expected: usize },
    /// Payload not followed by the terminating newline.
    MissingNewline,
    /// Payload bytes are not UTF-8.
    NotUtf8,
}

impl fmt::Display for FrameError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            FrameError::TruncatedPrefix => {
                write!(f, "truncated frame length prefix")
            }
            FrameError::PrefixTooLong { limit } => write!(
                f,
                "frame length prefix too long (> {limit} bytes; not a \
                 frame stream?)"
            ),
            FrameError::BadPrefix(p) => {
                write!(f, "bad frame length prefix {p:?}")
            }
            FrameError::Oversized { len, max } => write!(
                f,
                "frame of {len} bytes exceeds the transport cap of {max} \
                 bytes"
            ),
            FrameError::TruncatedPayload { expected } => write!(
                f,
                "frame truncated mid-payload (expected {expected} bytes)"
            ),
            FrameError::MissingNewline => {
                write!(f, "frame missing trailing newline")
            }
            FrameError::NotUtf8 => write!(f, "frame payload is not utf-8"),
        }
    }
}

impl From<FrameError> for Error {
    fn from(e: FrameError) -> Self {
        Error::Frame(e)
    }
}

pub type Result<T> = std::result::Result<T, Error>;

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Shape(m) => write!(f, "shape error: {m}"),
            Error::NotPosDef(m) => write!(f, "matrix not positive definite: {m}"),
            Error::Config(m) => write!(f, "config error: {m}"),
            Error::Runtime(m) => write!(f, "runtime error: {m}"),
            Error::Xla(m) => write!(f, "xla error: {m}"),
            Error::Io(e) => write!(f, "io error: {e}"),
            Error::Parse(m) => write!(f, "parse error: {m}"),
            Error::Frame(e) => write!(f, "frame protocol error: {e}"),
            Error::KernelUnavailable { backend, reason } => write!(
                f,
                "combine kernel backend '{backend}' unavailable: {reason}"
            ),
        }
    }
}

impl std::error::Error for Error {}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

/// Shorthand for `Error::Shape` with formatting.
#[macro_export]
macro_rules! shape_err {
    ($($arg:tt)*) => {
        $crate::error::Error::Shape(format!($($arg)*))
    };
}
