//! Crate-wide error type.

use std::fmt;

// Offline stub standing in for the real PJRT bindings (see
// `runtime/xla_shim.rs` for how to swap in the vendored crate).
use crate::runtime::xla_shim as xla;

/// Errors produced anywhere in the library.
#[derive(Debug)]
pub enum Error {
    /// Shape/dimension mismatch in a numeric routine.
    Shape(String),
    /// Matrix is not positive definite (Cholesky failure etc.).
    NotPosDef(String),
    /// Invalid configuration or argument.
    Config(String),
    /// Artifact manifest / runtime problems.
    Runtime(String),
    /// Underlying XLA/PJRT error.
    Xla(String),
    /// I/O error.
    Io(std::io::Error),
    /// JSON / config parse error (manifest, CLI, config files).
    Parse(String),
}

pub type Result<T> = std::result::Result<T, Error>;

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Shape(m) => write!(f, "shape error: {m}"),
            Error::NotPosDef(m) => write!(f, "matrix not positive definite: {m}"),
            Error::Config(m) => write!(f, "config error: {m}"),
            Error::Runtime(m) => write!(f, "runtime error: {m}"),
            Error::Xla(m) => write!(f, "xla error: {m}"),
            Error::Io(e) => write!(f, "io error: {e}"),
            Error::Parse(m) => write!(f, "parse error: {m}"),
        }
    }
}

impl std::error::Error for Error {}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

/// Shorthand for `Error::Shape` with formatting.
#[macro_export]
macro_rules! shape_err {
    ($($arg:tt)*) => {
        $crate::error::Error::Shape(format!($($arg)*))
    };
}
