//! Run-level metrics collected by the pipeline.

use std::fmt;

/// Counters and summaries for one pipeline run.
#[derive(Debug, Clone, Default)]
pub struct RunMetrics {
    pub machines: usize,
    pub samples_per_machine: usize,
    pub param_dim: usize,
    /// Per-machine acceptance rates.
    pub accept_rates: Vec<f64>,
    /// Per-machine wall-clock seconds.
    pub worker_secs: Vec<f64>,
    /// Scalars transferred worker→leader.
    pub scalars_transferred: usize,
    /// Seconds spent in the combination stage.
    pub combine_secs: f64,
    /// Total end-to-end wall-clock (real, not modeled).
    pub total_secs: f64,
    /// Peak resident bytes of the leader's draw plane (sum of
    /// per-machine store peaks; `0` for the sequential path, which
    /// holds no leader stores).
    pub draw_peak_bytes: usize,
    /// Draw-plane bytes spilled to disk at combine time (`0` when no
    /// spill budget is configured).
    pub draw_spilled_bytes: usize,
    /// Shards re-dispatched after a worker failure (`--failure-policy
    /// retry`); `0` under fail-fast or a clean run.
    pub shard_retries: usize,
    /// Endpoints benched after repeated failures; the job finished on
    /// the surviving pool.
    pub endpoints_quarantined: usize,
    /// Liveness deadlines that expired (no draw or heartbeat frame
    /// within `--liveness-timeout-secs`) — each counts a wedged or
    /// partitioned peer the deadline converted into a schedulable
    /// failure.
    pub heartbeats_missed: usize,
    /// `poll(2)` returns across all reactor threads (`--io-driver
    /// reactor`); `0` under the threads driver.
    pub reactor_wakeups: usize,
    /// Milliseconds from scheduler start to the first draw/chunk frame
    /// landing on the leader; `0.0` when no frame arrived (or under
    /// drivers that don't measure it).
    pub time_to_first_draw_ms: f64,
    /// Per-endpoint busy fraction (seconds a worker connection was
    /// open on that slot / scheduler wall time); empty under the
    /// threads driver.
    pub endpoint_busy: Vec<f64>,
    /// Jobs the daemon accepted over its lifetime (`repro leaderd`);
    /// `0` for a solo CLI run, which also suppresses the jobs line in
    /// the Display rendering.
    pub jobs_accepted: usize,
    /// Accepted jobs that ended in the `failed` state.
    pub jobs_failed: usize,
    /// Per-job milliseconds between submission and the job's pipeline
    /// starting — time spent queued behind `--max-concurrent-jobs`.
    pub job_queue_wait_ms: Vec<f64>,
}

impl RunMetrics {
    pub fn mean_accept_rate(&self) -> f64 {
        if self.accept_rates.is_empty() {
            return f64::NAN;
        }
        self.accept_rates.iter().sum::<f64>() / self.accept_rates.len() as f64
    }

    pub fn max_worker_secs(&self) -> f64 {
        self.worker_secs.iter().copied().fold(0.0, f64::max)
    }

    /// Load imbalance: max/mean worker time (1.0 = perfectly balanced).
    pub fn imbalance(&self) -> f64 {
        if self.worker_secs.is_empty() {
            return f64::NAN;
        }
        let mean = self.worker_secs.iter().sum::<f64>()
            / self.worker_secs.len() as f64;
        if mean == 0.0 {
            return 1.0;
        }
        self.max_worker_secs() / mean
    }

    /// Mean per-endpoint busy fraction; `0.0` when not measured.
    pub fn mean_endpoint_busy(&self) -> f64 {
        if self.endpoint_busy.is_empty() {
            return 0.0;
        }
        self.endpoint_busy.iter().sum::<f64>()
            / self.endpoint_busy.len() as f64
    }

    /// Mean per-job queue wait in milliseconds; `0.0` when no job
    /// recorded one (daemon never saturated, or not a daemon run).
    pub fn mean_job_queue_wait_ms(&self) -> f64 {
        if self.job_queue_wait_ms.is_empty() {
            return 0.0;
        }
        self.job_queue_wait_ms.iter().sum::<f64>()
            / self.job_queue_wait_ms.len() as f64
    }
}

impl fmt::Display for RunMetrics {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(
            f,
            "machines={} T={} d={}",
            self.machines, self.samples_per_machine, self.param_dim
        )?;
        writeln!(
            f,
            "accept_rate(mean)={:.3} worker_secs(max)={:.3} imbalance={:.2}",
            self.mean_accept_rate(),
            self.max_worker_secs(),
            self.imbalance()
        )?;
        writeln!(
            f,
            "scalars={} combine_secs={:.3} total_secs={:.3}",
            self.scalars_transferred, self.combine_secs, self.total_secs
        )?;
        writeln!(
            f,
            "draw_peak_bytes={} draw_spilled_bytes={}",
            self.draw_peak_bytes, self.draw_spilled_bytes
        )?;
        writeln!(
            f,
            "shard_retries={} endpoints_quarantined={} heartbeats_missed={}",
            self.shard_retries,
            self.endpoints_quarantined,
            self.heartbeats_missed
        )?;
        write!(
            f,
            "reactor_wakeups={} time_to_first_draw_ms={:.1} endpoint_busy(mean)={:.3}",
            self.reactor_wakeups,
            self.time_to_first_draw_ms,
            self.mean_endpoint_busy()
        )?;
        // Job accounting exists only for daemon (`repro leaderd`)
        // lifetimes; solo runs never accept a job, so their summaries
        // stay exactly as before the daemon existed.
        if self.jobs_accepted > 0 {
            write!(
                f,
                "\njobs_accepted={} jobs_failed={} \
                 job_queue_wait_ms(mean)={:.1}",
                self.jobs_accepted,
                self.jobs_failed,
                self.mean_job_queue_wait_ms()
            )?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summaries() {
        let m = RunMetrics {
            machines: 2,
            samples_per_machine: 10,
            param_dim: 3,
            accept_rates: vec![0.6, 0.8],
            worker_secs: vec![1.0, 3.0],
            scalars_transferred: 60,
            combine_secs: 0.5,
            total_secs: 4.0,
            draw_peak_bytes: 480,
            draw_spilled_bytes: 320,
            shard_retries: 2,
            endpoints_quarantined: 1,
            heartbeats_missed: 3,
            reactor_wakeups: 42,
            time_to_first_draw_ms: 12.5,
            endpoint_busy: vec![0.5, 0.9],
            jobs_accepted: 0,
            jobs_failed: 0,
            job_queue_wait_ms: Vec::new(),
        };
        assert!((m.mean_accept_rate() - 0.7).abs() < 1e-12);
        assert!((m.max_worker_secs() - 3.0).abs() < 1e-12);
        assert!((m.imbalance() - 1.5).abs() < 1e-12);
        let s = format!("{m}");
        assert!(s.contains("machines=2"));
        assert!(s.contains("draw_peak_bytes=480"));
        assert!(s.contains("draw_spilled_bytes=320"));
        assert!(s.contains("shard_retries=2"));
        assert!(s.contains("endpoints_quarantined=1"));
        assert!(s.contains("heartbeats_missed=3"));
        assert!((m.mean_endpoint_busy() - 0.7).abs() < 1e-12);
        assert!(s.contains("reactor_wakeups=42"));
        assert!(s.contains("time_to_first_draw_ms=12.5"));
        assert!(s.contains("endpoint_busy(mean)=0.700"));
        // Solo runs (jobs_accepted == 0) never print the jobs line.
        assert!(!s.contains("jobs_accepted"));
    }

    #[test]
    fn daemon_metrics_print_job_line() {
        let m = RunMetrics {
            jobs_accepted: 3,
            jobs_failed: 1,
            job_queue_wait_ms: vec![10.0, 30.0],
            ..RunMetrics::default()
        };
        assert!((m.mean_job_queue_wait_ms() - 20.0).abs() < 1e-12);
        let s = format!("{m}");
        assert!(s.contains("jobs_accepted=3"));
        assert!(s.contains("jobs_failed=1"));
        assert!(s.contains("job_queue_wait_ms(mean)=20.0"));
    }

    #[test]
    fn empty_metrics_are_nan_not_panic() {
        let m = RunMetrics::default();
        assert!(m.mean_accept_rate().is_nan());
        assert!(m.imbalance().is_nan());
    }
}
