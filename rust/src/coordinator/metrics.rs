//! Run-level metrics collected by the pipeline.

use std::fmt;

/// Counters and summaries for one pipeline run.
#[derive(Debug, Clone, Default)]
pub struct RunMetrics {
    pub machines: usize,
    pub samples_per_machine: usize,
    pub param_dim: usize,
    /// Per-machine acceptance rates.
    pub accept_rates: Vec<f64>,
    /// Per-machine wall-clock seconds.
    pub worker_secs: Vec<f64>,
    /// Scalars transferred worker→leader.
    pub scalars_transferred: usize,
    /// Seconds spent in the combination stage.
    pub combine_secs: f64,
    /// Total end-to-end wall-clock (real, not modeled).
    pub total_secs: f64,
    /// Peak resident bytes of the leader's draw plane (sum of
    /// per-machine store peaks; `0` for the sequential path, which
    /// holds no leader stores).
    pub draw_peak_bytes: usize,
    /// Draw-plane bytes spilled to disk at combine time (`0` when no
    /// spill budget is configured).
    pub draw_spilled_bytes: usize,
    /// Shards re-dispatched after a worker failure (`--failure-policy
    /// retry`); `0` under fail-fast or a clean run.
    pub shard_retries: usize,
    /// Endpoints benched after repeated failures; the job finished on
    /// the surviving pool.
    pub endpoints_quarantined: usize,
    /// Liveness deadlines that expired (no draw or heartbeat frame
    /// within `--liveness-timeout-secs`) — each counts a wedged or
    /// partitioned peer the deadline converted into a schedulable
    /// failure.
    pub heartbeats_missed: usize,
}

impl RunMetrics {
    pub fn mean_accept_rate(&self) -> f64 {
        if self.accept_rates.is_empty() {
            return f64::NAN;
        }
        self.accept_rates.iter().sum::<f64>() / self.accept_rates.len() as f64
    }

    pub fn max_worker_secs(&self) -> f64 {
        self.worker_secs.iter().copied().fold(0.0, f64::max)
    }

    /// Load imbalance: max/mean worker time (1.0 = perfectly balanced).
    pub fn imbalance(&self) -> f64 {
        if self.worker_secs.is_empty() {
            return f64::NAN;
        }
        let mean = self.worker_secs.iter().sum::<f64>()
            / self.worker_secs.len() as f64;
        if mean == 0.0 {
            return 1.0;
        }
        self.max_worker_secs() / mean
    }
}

impl fmt::Display for RunMetrics {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(
            f,
            "machines={} T={} d={}",
            self.machines, self.samples_per_machine, self.param_dim
        )?;
        writeln!(
            f,
            "accept_rate(mean)={:.3} worker_secs(max)={:.3} imbalance={:.2}",
            self.mean_accept_rate(),
            self.max_worker_secs(),
            self.imbalance()
        )?;
        writeln!(
            f,
            "scalars={} combine_secs={:.3} total_secs={:.3}",
            self.scalars_transferred, self.combine_secs, self.total_secs
        )?;
        writeln!(
            f,
            "draw_peak_bytes={} draw_spilled_bytes={}",
            self.draw_peak_bytes, self.draw_spilled_bytes
        )?;
        write!(
            f,
            "shard_retries={} endpoints_quarantined={} heartbeats_missed={}",
            self.shard_retries,
            self.endpoints_quarantined,
            self.heartbeats_missed
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summaries() {
        let m = RunMetrics {
            machines: 2,
            samples_per_machine: 10,
            param_dim: 3,
            accept_rates: vec![0.6, 0.8],
            worker_secs: vec![1.0, 3.0],
            scalars_transferred: 60,
            combine_secs: 0.5,
            total_secs: 4.0,
            draw_peak_bytes: 480,
            draw_spilled_bytes: 320,
            shard_retries: 2,
            endpoints_quarantined: 1,
            heartbeats_missed: 3,
        };
        assert!((m.mean_accept_rate() - 0.7).abs() < 1e-12);
        assert!((m.max_worker_secs() - 3.0).abs() < 1e-12);
        assert!((m.imbalance() - 1.5).abs() < 1e-12);
        let s = format!("{m}");
        assert!(s.contains("machines=2"));
        assert!(s.contains("draw_peak_bytes=480"));
        assert!(s.contains("draw_spilled_bytes=320"));
        assert!(s.contains("shard_retries=2"));
        assert!(s.contains("endpoints_quarantined=1"));
        assert!(s.contains("heartbeats_missed=3"));
    }

    #[test]
    fn empty_metrics_are_nan_not_panic() {
        let m = RunMetrics::default();
        assert!(m.mean_accept_rate().is_nan());
        assert!(m.imbalance().is_nan());
    }
}
