//! Cluster-time accounting (how the paper measures "time").
//!
//! Workers here are OS threads, not separate cluster nodes, but the
//! algorithmic timing model is the paper's: parallel sampling time is
//! the *max* over machines (they run concurrently and never wait),
//! transfer adds `d·T·M` scalars at an assumed link rate, and the
//! combination runs on one machine afterwards (or online, overlapped).

use crate::types::SubposteriorSamples;

/// Timing breakdown of one embarrassingly-parallel run.
#[derive(Debug, Clone)]
pub struct ClusterTiming {
    /// max_m (worker wall-clock), seconds.
    pub sampling_secs: f64,
    /// Modeled transfer time for d·T·M scalars, seconds.
    pub transfer_secs: f64,
    /// Measured combination time, seconds.
    pub combine_secs: f64,
}

impl ClusterTiming {
    /// Assumed link throughput: 1e8 scalars/sec (≈ 800 MB/s of f64 —
    /// commodity 10GbE, matching the paper's "standard cluster").
    pub const SCALARS_PER_SEC: f64 = 1e8;

    pub fn from_run(
        subs: &[SubposteriorSamples],
        combine_secs: f64,
    ) -> ClusterTiming {
        let sampling_secs = subs
            .iter()
            .map(|s| s.wall_secs)
            .fold(0.0, f64::max);
        let scalars: usize = subs
            .iter()
            .map(|s| s.samples.len() * s.samples.dim())
            .sum();
        ClusterTiming {
            sampling_secs,
            transfer_secs: scalars as f64 / Self::SCALARS_PER_SEC,
            combine_secs,
        }
    }

    /// Total modeled wall-clock.
    pub fn total_secs(&self) -> f64 {
        self.sampling_secs + self.transfer_secs + self.combine_secs
    }
}

/// Error-vs-time protocol support: the set of draws from one machine
/// that were available within `budget` seconds of sampling.
pub fn draws_within(
    sub: &SubposteriorSamples,
    budget: f64,
) -> crate::types::SampleMatrix {
    let mut out = crate::types::SampleMatrix::new(sub.samples.dim());
    for (i, &t) in sub.draw_times.iter().enumerate() {
        if t <= budget {
            out.push(sub.samples.row(i));
        } else {
            break;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::SampleMatrix;

    fn fake_sub(machine: usize, wall: f64, n: usize) -> SubposteriorSamples {
        let mut samples = SampleMatrix::new(2);
        let mut draw_times = Vec::new();
        for i in 0..n {
            samples.push(&[i as f64, 0.0]);
            draw_times.push(wall * (i + 1) as f64 / n as f64);
        }
        SubposteriorSamples {
            machine,
            samples,
            accept_rate: 1.0,
            wall_secs: wall,
            draw_times,
        }
    }

    #[test]
    fn sampling_time_is_max_over_workers() {
        let subs = vec![fake_sub(0, 2.0, 10), fake_sub(1, 5.0, 10)];
        let t = ClusterTiming::from_run(&subs, 0.5);
        assert!((t.sampling_secs - 5.0).abs() < 1e-12);
        assert!((t.total_secs() - (5.0 + t.transfer_secs + 0.5)).abs() < 1e-12);
        // 20 draws × 2 dims = 40 scalars.
        assert!((t.transfer_secs - 40.0 / ClusterTiming::SCALARS_PER_SEC).abs() < 1e-18);
    }

    #[test]
    fn draws_within_budget_prefix() {
        let sub = fake_sub(0, 10.0, 10); // draws at 1,2,…,10s
        assert_eq!(draws_within(&sub, 3.5).len(), 3);
        assert_eq!(draws_within(&sub, 0.5).len(), 0);
        assert_eq!(draws_within(&sub, 100.0).len(), 10);
    }
}
