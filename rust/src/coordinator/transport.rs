//! Process-mode wire protocol: length-prefixed ndjson frames.
//!
//! A worker process streams its draws to the leader over stdout as a
//! sequence of frames, each `"<decimal byte length>\n<json payload>\n"`.
//! The length prefix lets the leader slice payloads without scanning
//! for delimiters inside them; the trailing newline keeps the stream
//! greppable when captured to a file. Payloads are [`WireMsg`]s — every
//! draw ([`crate::coordinator::worker::DrawMsg`]) followed by one final
//! [`WorkerSummary`] carrying the telemetry that is not per-draw.
//!
//! Floats cross the boundary through [`Json`]'s shortest-round-trip
//! rendering, so a draw decoded by the leader is bit-identical to the
//! one the worker produced — process mode inherits the thread-mode
//! determinism guarantee byte-for-byte.

use std::io::{BufRead, Read, Write};
use std::path::Path;

use crate::coordinator::worker::DrawMsg;
use crate::error::{Error, Result};
use crate::runtime::json::{self, Json};

/// Largest frame the leader will accept (a draw is O(d) floats; this
/// bounds memory against a corrupt or hostile length prefix).
const MAX_FRAME_BYTES: usize = 64 * 1024 * 1024;

/// Write one frame: decimal payload length, newline, payload, newline.
/// Flushes so the leader sees draws as they are produced, not when the
/// worker's buffer happens to fill.
pub fn write_frame<W: Write>(w: &mut W, payload: &str) -> std::io::Result<()> {
    writeln!(w, "{}", payload.len())?;
    w.write_all(payload.as_bytes())?;
    w.write_all(b"\n")?;
    w.flush()
}

/// Longest accepted length-prefix line: a valid `usize` is ≤ 20
/// digits, so anything longer means the stream is not frame-framed
/// (e.g. `--worker-bin` points at a binary that prints prose). Bounding
/// the prefix read keeps leader memory bounded even on a newline-free
/// garbage stream.
const MAX_PREFIX_BYTES: usize = 24;

/// Incremental frame reader over any buffered byte stream.
pub struct FrameReader<R: BufRead> {
    inner: R,
}

impl<R: BufRead> FrameReader<R> {
    pub fn new(inner: R) -> Self {
        FrameReader { inner }
    }

    /// Read the bounded length-prefix line, or `None` at clean EOF.
    fn read_prefix(&mut self) -> Result<Option<String>> {
        let mut line = Vec::with_capacity(MAX_PREFIX_BYTES);
        let mut byte = [0u8; 1];
        loop {
            let n = self.inner.read(&mut byte).map_err(Error::Io)?;
            if n == 0 {
                return if line.is_empty() {
                    Ok(None)
                } else {
                    Err(Error::Parse(
                        "truncated frame length prefix".into(),
                    ))
                };
            }
            if byte[0] == b'\n' {
                break;
            }
            if line.len() >= MAX_PREFIX_BYTES {
                return Err(Error::Parse(
                    "frame length prefix too long (not a frame stream?)"
                        .into(),
                ));
            }
            line.push(byte[0]);
        }
        Ok(Some(String::from_utf8_lossy(&line).into_owned()))
    }

    /// Read the next frame's payload, or `None` at clean end-of-stream.
    pub fn read_frame(&mut self) -> Result<Option<String>> {
        let Some(prefix) = self.read_prefix()? else {
            return Ok(None);
        };
        let len: usize = prefix.trim().parse().map_err(|_| {
            Error::Parse(format!(
                "bad frame length prefix {:?}",
                prefix.trim()
            ))
        })?;
        if len > MAX_FRAME_BYTES {
            return Err(Error::Parse(format!("frame of {len} bytes too large")));
        }
        let mut buf = vec![0u8; len + 1]; // payload + trailing newline
        self.inner.read_exact(&mut buf).map_err(Error::Io)?;
        if buf.pop() != Some(b'\n') {
            return Err(Error::Parse("frame missing trailing newline".into()));
        }
        String::from_utf8(buf)
            .map(Some)
            .map_err(|_| Error::Parse("frame payload is not utf-8".into()))
    }
}

/// End-of-run telemetry a worker cannot attach to any single draw.
#[derive(Debug, Clone, PartialEq)]
pub struct WorkerSummary {
    pub machine: usize,
    /// Mean acceptance rate (NaN when no post-burn-in steps ran; crosses
    /// the wire as JSON `null`).
    pub accept_rate: f64,
    pub wall_secs: f64,
}

/// One decoded frame payload.
#[derive(Debug, Clone)]
pub enum WireMsg {
    Draw(DrawMsg),
    Summary(WorkerSummary),
}

/// Encode one float for the wire. Finite values go through [`Json`]'s
/// bit-exact number rendering; non-finite values (which JSON numbers
/// cannot carry) become the string tokens `"inf"` / `"-inf"` / `"nan"`
/// so a diverged chain's ±∞ survives the pipe as ±∞, not as a silent
/// NaN — keeping process mode value-identical to thread mode even off
/// the happy path.
fn wire_f64(v: f64) -> Json {
    if v.is_finite() {
        Json::Num(v)
    } else if v.is_nan() {
        Json::Str("nan".into())
    } else if v > 0.0 {
        Json::Str("inf".into())
    } else {
        Json::Str("-inf".into())
    }
}

/// Inverse of [`wire_f64`]. Also accepts `null` (what a non-finite
/// float rendered as before it had a token) as NaN for leniency.
fn f64_from_wire(j: &Json) -> Result<f64> {
    match j {
        Json::Null => Ok(f64::NAN),
        Json::Str(s) => match s.as_str() {
            "nan" => Ok(f64::NAN),
            "inf" => Ok(f64::INFINITY),
            "-inf" => Ok(f64::NEG_INFINITY),
            other => {
                Err(Error::Parse(format!("bad float token '{other}'")))
            }
        },
        other => other.as_f64(),
    }
}

/// Encode a draw as a frame payload.
pub fn encode_draw(msg: &DrawMsg) -> String {
    json::obj(vec![
        ("type", Json::Str("draw".into())),
        ("machine", Json::Num(msg.machine as f64)),
        ("theta", Json::Arr(msg.theta.iter().map(|&v| wire_f64(v)).collect())),
        ("elapsed", wire_f64(msg.elapsed)),
        ("last", Json::Bool(msg.last)),
    ])
    .render()
}

/// Encode a worker summary as a frame payload.
pub fn encode_summary(s: &WorkerSummary) -> String {
    json::obj(vec![
        ("type", Json::Str("summary".into())),
        ("machine", Json::Num(s.machine as f64)),
        ("accept_rate", wire_f64(s.accept_rate)),
        ("wall_secs", wire_f64(s.wall_secs)),
    ])
    .render()
}

impl WireMsg {
    pub fn decode(text: &str) -> Result<WireMsg> {
        let j = Json::parse(text)?;
        match j.get("type")?.as_str()? {
            "draw" => Ok(WireMsg::Draw(DrawMsg {
                machine: j.get("machine")?.as_usize()?,
                theta: j
                    .get("theta")?
                    .as_arr()?
                    .iter()
                    .map(f64_from_wire)
                    .collect::<Result<_>>()?,
                elapsed: f64_from_wire(j.get("elapsed")?)?,
                last: j.get("last")?.as_bool()?,
            })),
            "summary" => Ok(WireMsg::Summary(WorkerSummary {
                machine: j.get("machine")?.as_usize()?,
                accept_rate: f64_from_wire(j.get("accept_rate")?)?,
                wall_secs: f64_from_wire(j.get("wall_secs")?)?,
            })),
            other => {
                Err(Error::Parse(format!("unknown wire message type '{other}'")))
            }
        }
    }
}

/// Everything a worker process needs to reproduce its in-thread twin:
/// which machine it is, the shared run geometry, the root seed its RNG
/// stream is split from, the sampler spec, and where its spilled shard
/// lives. Written by the leader next to the shard file; the `worker`
/// CLI subcommand loads it as its sole input.
#[derive(Debug, Clone, PartialEq)]
pub struct WorkerManifest {
    pub machine: usize,
    pub machines: usize,
    /// Root seed — the worker derives `Pcg64::seed_from(seed).split(m)`
    /// exactly as the in-thread path does. Serialized as a string so
    /// u64 seeds above 2^53 survive the f64-based JSON number grammar.
    pub seed: u64,
    pub samples: usize,
    pub burn_in: usize,
    pub thin: usize,
    pub prior_weight: f64,
    /// Sampler spec in [`crate::config::parse_sampler`] syntax.
    pub sampler: String,
    pub shard_path: String,
    /// Expected parameter dimension (validated against the shard).
    pub dim: usize,
}

impl WorkerManifest {
    pub fn to_json(&self) -> Json {
        json::obj(vec![
            ("machine", Json::Num(self.machine as f64)),
            ("machines", Json::Num(self.machines as f64)),
            ("seed", Json::Str(self.seed.to_string())),
            ("samples", Json::Num(self.samples as f64)),
            ("burn_in", Json::Num(self.burn_in as f64)),
            ("thin", Json::Num(self.thin as f64)),
            ("prior_weight", Json::Num(self.prior_weight)),
            ("sampler", Json::Str(self.sampler.clone())),
            ("shard_path", Json::Str(self.shard_path.clone())),
            ("dim", Json::Num(self.dim as f64)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        let seed = j.get("seed")?.as_str()?;
        Ok(WorkerManifest {
            machine: j.get("machine")?.as_usize()?,
            machines: j.get("machines")?.as_usize()?,
            seed: seed.parse().map_err(|_| {
                Error::Parse(format!("bad u64 seed '{seed}'"))
            })?,
            samples: j.get("samples")?.as_usize()?,
            burn_in: j.get("burn_in")?.as_usize()?,
            thin: j.get("thin")?.as_usize()?,
            prior_weight: j.get("prior_weight")?.as_f64()?,
            sampler: j.get("sampler")?.as_str()?.to_string(),
            shard_path: j.get("shard_path")?.as_str()?.to_string(),
            dim: j.get("dim")?.as_usize()?,
        })
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_json().render())?;
        Ok(())
    }

    pub fn load(path: &Path) -> Result<Self> {
        Self::from_json(&Json::parse(&std::fs::read_to_string(path)?)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::BufReader;

    fn draw(machine: usize, theta: Vec<f64>, last: bool) -> DrawMsg {
        DrawMsg { machine, theta, elapsed: 0.125, last }
    }

    #[test]
    fn frame_roundtrip_over_byte_stream() {
        let mut buf: Vec<u8> = Vec::new();
        write_frame(&mut buf, "hello").unwrap();
        write_frame(&mut buf, "").unwrap();
        write_frame(&mut buf, "{\"k\":[1,2]}").unwrap();
        let mut r = FrameReader::new(BufReader::new(buf.as_slice()));
        assert_eq!(r.read_frame().unwrap().unwrap(), "hello");
        assert_eq!(r.read_frame().unwrap().unwrap(), "");
        assert_eq!(r.read_frame().unwrap().unwrap(), "{\"k\":[1,2]}");
        assert!(r.read_frame().unwrap().is_none());
        assert!(r.read_frame().unwrap().is_none()); // EOF is sticky
    }

    #[test]
    fn frame_reader_rejects_garbage() {
        let mut r = FrameReader::new(BufReader::new(&b"notalen\nxx\n"[..]));
        assert!(r.read_frame().is_err());
        // Length longer than the remaining stream → io error.
        let mut r = FrameReader::new(BufReader::new(&b"100\nshort\n"[..]));
        assert!(r.read_frame().is_err());
        // Payload not followed by newline.
        let mut r = FrameReader::new(BufReader::new(&b"2\nabX"[..]));
        assert!(r.read_frame().is_err());
    }

    /// A non-frame stream (e.g. `--worker-bin` pointing at a chatty
    /// binary) must fail fast with bounded memory, even with no
    /// newline in sight.
    #[test]
    fn frame_reader_bounds_prefix_on_newline_free_garbage() {
        let garbage = vec![b'x'; 4096];
        let mut r = FrameReader::new(BufReader::new(garbage.as_slice()));
        let err = r.read_frame().unwrap_err();
        assert!(err.to_string().contains("prefix too long"), "{err}");
        // Truncated prefix (EOF before newline) is also an error, not
        // a clean end-of-stream.
        let mut r = FrameReader::new(BufReader::new(&b"123"[..]));
        assert!(r.read_frame().is_err());
    }

    #[test]
    fn draw_roundtrip_is_bit_exact() {
        let msg = draw(3, vec![0.1, -1.0 / 3.0, 1e-300, -0.0], true);
        let decoded = match WireMsg::decode(&encode_draw(&msg)).unwrap() {
            WireMsg::Draw(d) => d,
            other => panic!("wrong variant {other:?}"),
        };
        assert_eq!(decoded.machine, 3);
        assert!(decoded.last);
        assert_eq!(decoded.theta.len(), msg.theta.len());
        for (a, b) in msg.theta.iter().zip(&decoded.theta) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert_eq!(msg.elapsed.to_bits(), decoded.elapsed.to_bits());
    }

    /// Non-finite floats have no JSON number form; the wire carries
    /// them as tokens so ±∞ survives as ±∞ (not a silent NaN).
    #[test]
    fn draw_roundtrip_preserves_nonfinite_values() {
        let msg = draw(
            0,
            vec![f64::INFINITY, f64::NEG_INFINITY, f64::NAN, 1.5],
            false,
        );
        let decoded = match WireMsg::decode(&encode_draw(&msg)).unwrap() {
            WireMsg::Draw(d) => d,
            other => panic!("wrong variant {other:?}"),
        };
        assert_eq!(decoded.theta[0], f64::INFINITY);
        assert_eq!(decoded.theta[1], f64::NEG_INFINITY);
        assert!(decoded.theta[2].is_nan());
        assert_eq!(decoded.theta[3], 1.5);
    }

    #[test]
    fn summary_roundtrip_preserves_nan_accept_rate() {
        let s = WorkerSummary {
            machine: 1,
            accept_rate: f64::NAN,
            wall_secs: 2.5,
        };
        match WireMsg::decode(&encode_summary(&s)).unwrap() {
            WireMsg::Summary(back) => {
                assert_eq!(back.machine, 1);
                assert!(back.accept_rate.is_nan());
                assert_eq!(back.wall_secs, 2.5);
            }
            other => panic!("wrong variant {other:?}"),
        }
    }

    #[test]
    fn decode_rejects_unknown_type() {
        assert!(WireMsg::decode("{\"type\":\"nope\"}").is_err());
        assert!(WireMsg::decode("not json").is_err());
    }

    #[test]
    fn manifest_file_roundtrip_with_large_seed() {
        let m = WorkerManifest {
            machine: 2,
            machines: 8,
            seed: u64::MAX - 1, // not representable as f64
            samples: 1000,
            burn_in: 0,
            thin: 3,
            prior_weight: 1.0 / 8.0,
            sampler: "hmc:1e-1,10".into(),
            shard_path: "/tmp/shard_2.json".into(),
            dim: 4,
        };
        let dir = std::env::temp_dir().join("repro_transport_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("worker_2.json");
        m.save(&path).unwrap();
        let back = WorkerManifest::load(&path).unwrap();
        assert_eq!(m, back);
        std::fs::remove_dir_all(&dir).ok();
    }
}
