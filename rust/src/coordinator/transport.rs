//! Leader↔worker wire protocol and pluggable transports.
//!
//! A worker streams its draws to the leader as a sequence of
//! length-prefixed ndjson frames, each
//! `"<decimal byte length>\n<json payload>\n"`. The length prefix lets
//! the leader slice payloads without scanning for delimiters inside
//! them; the trailing newline keeps the stream greppable when captured
//! to a file. Payloads are [`WireMsg`]s — every draw
//! ([`crate::coordinator::worker::DrawMsg`]) followed by one final
//! [`WorkerSummary`] carrying the telemetry that is not per-draw.
//!
//! The byte channel underneath is pluggable via the [`Transport`]
//! trait: [`PipeTransport`] spawns one child process per assignment and
//! reads its stdout (PR 2's process mode), [`SocketTransport`] dials a
//! `repro serve` worker daemon over TCP, sends the [`WorkerManifest`]
//! as the first frame, and reads draw frames back. Both speak the exact
//! same frame grammar, so the leader-side scheduler
//! ([`crate::coordinator::pipeline::run_with_transport`]) is
//! transport-agnostic.
//!
//! Floats cross the boundary through [`Json`]'s shortest-round-trip
//! rendering, so a draw decoded by the leader is bit-identical to the
//! one the worker produced — every transport inherits the thread-mode
//! determinism guarantee byte-for-byte.
//!
//! # The binary draw plane
//!
//! JSON frames pay float→decimal→float per coordinate and one frame
//! per draw. [`WireFormat::Binary`] replaces the *draw* plane with
//! batched [`DrawChunk`] frames — the same length-prefixed grammar,
//! but the payload is `RPDRAW1\n` magic + a fixed header + raw LE f64
//! rows, coalescing `draw_batch` draws per frame. Control frames
//! (summary, error, manifest) stay JSON in both modes. The leader
//! sniffs each frame for the magic ([`WireMsg::decode_frame`]), so a
//! daemon that ignores the negotiated `wire_format` manifest field and
//! answers in JSON still interoperates — mixed-version fleets degrade
//! to the JSON plane instead of failing.
//!
//! The same grammar also carries the client↔leader-daemon `RPJOB1`
//! protocol ([`crate::coordinator::server`]): JSON job-lifecycle
//! frames interleaved with binary `RPDRAW1` result chunks, one frame
//! vocabulary end to end.
//!
//! ## Float fidelity contract
//!
//! Both planes preserve every float *value*, including ±∞ and NaN
//! (JSON carries non-finite values as the tokens `"inf"`/`"-inf"`/
//! `"nan"`). The JSON plane is lossy in exactly one documented way:
//! all NaNs decode as the one canonical quiet NaN, so a NaN's *bit
//! payload* does not survive. The binary plane ships `f64::to_bits`
//! verbatim and is the only bit-exact encoding — retained draws are
//! nevertheless byte-identical across both formats because samplers
//! only ever emit canonical NaNs (if they emit NaN at all).

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{Shutdown, TcpStream, ToSocketAddrs};
use std::path::{Path, PathBuf};
use std::process::{Child, ChildStdout, Command, Stdio};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::coordinator::worker::DrawMsg;
use crate::error::{Error, FrameError, Result};
use crate::runtime::json::{self, Json};

/// Default largest frame a reader will accept (a draw is O(d) floats;
/// this bounds memory against a corrupt or hostile length prefix).
/// Transports carry their own cap — see [`Transport::max_frame_bytes`].
pub const DEFAULT_MAX_FRAME_BYTES: usize = 64 * 1024 * 1024;

/// Default dial timeout for socket endpoints (see [`SocketTransport`]);
/// override with the `connect_timeout_secs` config key /
/// `--connect-timeout-secs` flag.
pub const DEFAULT_CONNECT_TIMEOUT: Duration = Duration::from_secs(30);

/// Marker every liveness-deadline expiry message carries, so the
/// scheduler can tell "the peer went silent past the deadline" from
/// other stream failures without a dedicated error variant.
pub const LIVENESS_EXPIRED_MARKER: &str = "liveness deadline expired";

/// Draw-plane encoding, selected by the `wire_format` config key /
/// `--wire-format` flag and negotiated per worker via the
/// [`WorkerManifest`] so old daemons keep working (absent field ⇒
/// JSON).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum WireFormat {
    /// One JSON frame per draw — the original wire. A JSON-mode run
    /// puts byte-identical frames on the wire regardless of
    /// `draw_batch` (batching is a binary-plane knob).
    #[default]
    Json,
    /// Batched [`DrawChunk`] frames: `RPDRAW1\n` magic + raw LE f64
    /// payload, `draw_batch` draws per frame. Bit-exact for every
    /// f64, including NaN payloads — the only lossless encoding.
    Binary,
}

impl WireFormat {
    /// Parse the config/CLI token.
    pub fn parse(s: &str) -> Result<WireFormat> {
        match s.trim().to_ascii_lowercase().as_str() {
            "json" => Ok(WireFormat::Json),
            "binary" | "bin" => Ok(WireFormat::Binary),
            other => Err(Error::Config(format!(
                "unknown wire format '{other}' (expected json or binary)"
            ))),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            WireFormat::Json => "json",
            WireFormat::Binary => "binary",
        }
    }
}

/// Magic prefix announcing a binary draw-chunk frame payload (same
/// shape as the `RPSHRD1\n` shard magic). The leader sniffs every
/// frame for it, so binary draw frames and JSON control frames share
/// one stream.
pub const DRAW_MAGIC: &[u8; 8] = b"RPDRAW1\n";

/// Frame-kind byte following the magic (room for future binary frame
/// kinds on the same magic).
const DRAW_KIND_CHUNK: u8 = 0;

/// Fixed chunk header: magic (8) + kind (1) + machine u64 LE (8) +
/// chunk_len u64 LE (8) + d u64 LE (8) + last flag (1).
const CHUNK_HEADER_BYTES: usize = 8 + 1 + 8 + 8 + 8 + 1;

/// Write one frame: decimal payload length, newline, payload, newline.
/// Flushes so the leader sees draws as they are produced, not when the
/// worker's buffer happens to fill.
pub fn write_frame<W: Write>(w: &mut W, payload: &str) -> std::io::Result<()> {
    write_frame_bytes(w, payload.as_bytes())
}

/// [`write_frame`] for a raw byte payload — the same grammar (decimal
/// length, newline, payload, newline), without requiring the payload to
/// be text. Used to ship binary shard spills inline over the socket
/// transport; readers opt in via [`FrameReader::read_frame_bytes`].
pub fn write_frame_bytes<W: Write>(
    w: &mut W,
    payload: &[u8],
) -> std::io::Result<()> {
    writeln!(w, "{}", payload.len())?;
    w.write_all(payload)?;
    w.write_all(b"\n")?;
    w.flush()
}

/// Longest accepted length-prefix line: a valid `usize` is ≤ 20
/// digits, so anything longer means the stream is not frame-framed
/// (e.g. `--worker-bin` points at a binary that prints prose). Bounding
/// the prefix read keeps leader memory bounded even on a newline-free
/// garbage stream.
const MAX_PREFIX_BYTES: usize = 24;

/// Incremental frame reader over any buffered byte stream. Protocol
/// violations surface as structured [`FrameError`]s (wrapped in
/// [`Error::Frame`]) so peers can tell a corrupt prefix from an
/// oversized frame from a mid-payload truncation.
pub struct FrameReader<R: BufRead> {
    inner: R,
    max_frame_bytes: usize,
}

impl<R: BufRead> FrameReader<R> {
    /// Reader with the default frame cap.
    pub fn new(inner: R) -> Self {
        Self::with_max_frame(inner, DEFAULT_MAX_FRAME_BYTES)
    }

    /// Reader with a transport-specific frame cap (see
    /// [`Transport::max_frame_bytes`]).
    pub fn with_max_frame(inner: R, max_frame_bytes: usize) -> Self {
        FrameReader { inner, max_frame_bytes: max_frame_bytes.max(1) }
    }

    /// Read the bounded length-prefix line and parse it, or `None` at
    /// clean EOF. Parses in place off a stack buffer, so the hot frame
    /// loop's prefix handling allocates nothing.
    fn read_prefix_len(&mut self) -> Result<Option<usize>> {
        let mut line = [0u8; MAX_PREFIX_BYTES];
        let mut used = 0usize;
        let mut byte = [0u8; 1];
        loop {
            let n = self.inner.read(&mut byte).map_err(Error::Io)?;
            if n == 0 {
                return if used == 0 {
                    Ok(None)
                } else {
                    Err(FrameError::TruncatedPrefix.into())
                };
            }
            if byte[0] == b'\n' {
                break;
            }
            if used >= MAX_PREFIX_BYTES {
                return Err(FrameError::PrefixTooLong {
                    limit: MAX_PREFIX_BYTES,
                }
                .into());
            }
            line[used] = byte[0];
            used += 1;
        }
        let text = String::from_utf8_lossy(&line[..used]);
        let trimmed = text.trim();
        trimmed.parse::<usize>().map(Some).map_err(|_| {
            Error::Frame(FrameError::BadPrefix(trimmed.to_string()))
        })
    }

    /// Read the next frame's payload, or `None` at clean end-of-stream.
    pub fn read_frame(&mut self) -> Result<Option<String>> {
        match self.read_frame_bytes()? {
            None => Ok(None),
            Some(buf) => String::from_utf8(buf)
                .map(Some)
                .map_err(|_| FrameError::NotUtf8.into()),
        }
    }

    /// [`FrameReader::read_frame`] without the UTF-8 requirement — for
    /// frames whose payload is raw bytes (inline binary shard spills).
    /// Same grammar, same structured violations. Allocates a fresh
    /// `Vec` per frame; the hot draw loop uses
    /// [`FrameReader::read_frame_into`] instead.
    pub fn read_frame_bytes(&mut self) -> Result<Option<Vec<u8>>> {
        let mut buf = Vec::new();
        match self.read_frame_into(&mut buf)? {
            None => Ok(None),
            Some(_) => Ok(Some(buf)),
        }
    }

    /// Read the next frame's payload into `buf` (cleared first),
    /// returning its length, or `None` at clean end-of-stream. Callers
    /// hand in one reused buffer, so the steady-state frame loop
    /// performs no heap allocation — the leader-side half of the
    /// draw-plane no-per-draw-allocation contract.
    pub fn read_frame_into(
        &mut self,
        buf: &mut Vec<u8>,
    ) -> Result<Option<usize>> {
        let Some(len) = self.read_prefix_len()? else {
            return Ok(None);
        };
        if len > self.max_frame_bytes {
            return Err(FrameError::Oversized {
                len,
                max: self.max_frame_bytes,
            }
            .into());
        }
        buf.clear();
        buf.resize(len + 1, 0); // payload + trailing newline
        self.inner.read_exact(buf).map_err(|e| {
            // Distinguish "the stream ended mid-payload" (a protocol
            // violation the peer can diagnose) from a genuine I/O fault.
            if e.kind() == std::io::ErrorKind::UnexpectedEof {
                Error::Frame(FrameError::TruncatedPayload { expected: len })
            } else {
                Error::Io(e)
            }
        })?;
        if buf.pop() != Some(b'\n') {
            return Err(FrameError::MissingNewline.into());
        }
        Ok(Some(len))
    }

    /// Consume the reader, returning the underlying stream. The
    /// reactor's incremental decoder (`coordinator::reactor`) parses
    /// frames off an in-memory slice and needs the unconsumed
    /// remainder back to know how many buffered bytes a completed
    /// frame consumed.
    pub fn into_inner(self) -> R {
        self.inner
    }
}

/// End-of-run telemetry a worker cannot attach to any single draw.
#[derive(Debug, Clone, PartialEq)]
pub struct WorkerSummary {
    pub machine: usize,
    /// Mean acceptance rate (NaN when no post-burn-in steps ran; crosses
    /// the wire as JSON `null`).
    pub accept_rate: f64,
    pub wall_secs: f64,
}

/// A batch of consecutive retained draws from one machine, shipped as
/// one binary frame: the [`DRAW_MAGIC`] header followed by
/// `chunk_len × dim` theta f64s (row-major LE) and `chunk_len`
/// cumulative elapsed-seconds f64s (LE). Bit-exact: every value goes
/// through `f64::to_bits`/`from_bits`, so NaN payloads and -0.0
/// survive — the wire's only lossless draw encoding.
#[derive(Debug, Clone, PartialEq)]
pub struct DrawChunk {
    pub machine: usize,
    /// Parameter dimension d (validated by the leader against the run).
    pub dim: usize,
    /// `count() × dim` row-major draw coordinates.
    pub thetas: Vec<f64>,
    /// One cumulative elapsed time per draw (`count()` entries).
    pub elapsed: Vec<f64>,
    /// Whether the final draw of this chunk is the machine's last
    /// retained draw.
    pub last: bool,
}

impl DrawChunk {
    /// Number of draws in the chunk.
    pub fn count(&self) -> usize {
        self.elapsed.len()
    }

    /// Serialize into `out` (cleared first) — callers reuse one scratch
    /// buffer across chunks, so the steady-state encode allocates
    /// nothing.
    pub fn encode_into(&self, out: &mut Vec<u8>) {
        encode_chunk_into(
            self.machine,
            self.dim,
            &self.thetas,
            &self.elapsed,
            self.last,
            out,
        );
    }

    /// Decode a frame payload that starts with [`DRAW_MAGIC`]. The
    /// header's promised length must match the payload exactly — a
    /// truncated or padded chunk is a structured parse error, never a
    /// short read.
    pub fn decode(payload: &[u8]) -> Result<DrawChunk> {
        if payload.len() < CHUNK_HEADER_BYTES || &payload[..8] != DRAW_MAGIC
        {
            return Err(Error::Parse(
                "binary draw frame: missing RPDRAW1 header".into(),
            ));
        }
        if payload[8] != DRAW_KIND_CHUNK {
            return Err(Error::Parse(format!(
                "binary draw frame: unknown kind byte {}",
                payload[8]
            )));
        }
        let u64_at = |off: usize| {
            let mut b = [0u8; 8];
            b.copy_from_slice(&payload[off..off + 8]);
            u64::from_le_bytes(b) as usize
        };
        let machine = u64_at(9);
        let chunk_len = u64_at(17);
        let dim = u64_at(25);
        let last = match payload[33] {
            0 => false,
            1 => true,
            other => {
                return Err(Error::Parse(format!(
                    "binary draw frame: bad last flag {other}"
                )))
            }
        };
        if dim == 0 {
            return Err(Error::Parse(
                "binary draw frame: zero dimension".into(),
            ));
        }
        let scalars = chunk_len
            .checked_mul(dim)
            .and_then(|td| td.checked_add(chunk_len))
            .and_then(|n| n.checked_mul(8))
            .ok_or_else(|| {
                Error::Parse("binary draw frame: length overflow".into())
            })?;
        let expected = CHUNK_HEADER_BYTES + scalars;
        if payload.len() != expected {
            return Err(Error::Parse(format!(
                "binary draw frame: {} payload bytes but the header \
                 promises {expected} ({chunk_len} draws × dim {dim})",
                payload.len()
            )));
        }
        let body = &payload[CHUNK_HEADER_BYTES..];
        let f64s = |bytes: &[u8]| -> Vec<f64> {
            bytes
                .chunks_exact(8)
                .map(|c| {
                    let mut b = [0u8; 8];
                    b.copy_from_slice(c);
                    f64::from_le_bytes(b)
                })
                .collect()
        };
        let theta_bytes = 8 * chunk_len * dim;
        Ok(DrawChunk {
            machine,
            dim,
            thetas: f64s(&body[..theta_bytes]),
            elapsed: f64s(&body[theta_bytes..]),
            last,
        })
    }
}

/// [`DrawChunk::encode_into`] over borrowed parts, so the worker-side
/// [`DrawEncoder`] can serialize its accumulation buffers without
/// moving them into a `DrawChunk`.
fn encode_chunk_into(
    machine: usize,
    dim: usize,
    thetas: &[f64],
    elapsed: &[f64],
    last: bool,
    out: &mut Vec<u8>,
) {
    debug_assert_eq!(thetas.len(), elapsed.len() * dim);
    out.clear();
    out.reserve(CHUNK_HEADER_BYTES + 8 * (thetas.len() + elapsed.len()));
    out.extend_from_slice(DRAW_MAGIC);
    out.push(DRAW_KIND_CHUNK);
    out.extend_from_slice(&(machine as u64).to_le_bytes());
    out.extend_from_slice(&(elapsed.len() as u64).to_le_bytes());
    out.extend_from_slice(&(dim as u64).to_le_bytes());
    out.push(last as u8);
    for &v in thetas {
        out.extend_from_slice(&v.to_le_bytes());
    }
    for &v in elapsed {
        out.extend_from_slice(&v.to_le_bytes());
    }
}

/// Worker-side draw-plane encoder with reused buffers.
///
/// JSON mode emits exactly the legacy per-draw [`encode_draw`] frames
/// — `draw_batch` is a binary-plane knob, so a JSON-mode run's wire is
/// byte-identical to the pre-batching protocol. Binary mode coalesces
/// up to `batch` draws per [`DrawChunk`] frame, accumulating into
/// buffers that are cleared (capacity kept) on every flush: once the
/// buffers reach steady state the hot loop performs no per-draw heap
/// allocation. `flush` must be called after the final draw to emit a
/// partial tail chunk.
pub struct DrawEncoder {
    format: WireFormat,
    batch: usize,
    machine: usize,
    dim: usize,
    thetas: Vec<f64>,
    elapsed: Vec<f64>,
    last: bool,
    scratch: Vec<u8>,
}

impl DrawEncoder {
    /// Encoder for one worker's draw stream. `batch` is clamped to ≥ 1.
    pub fn new(
        format: WireFormat,
        batch: usize,
        machine: usize,
        dim: usize,
    ) -> DrawEncoder {
        let batch = batch.max(1);
        let binary = format == WireFormat::Binary;
        DrawEncoder {
            format,
            batch,
            machine,
            dim,
            thetas: Vec::with_capacity(if binary { batch * dim } else { 0 }),
            elapsed: Vec::with_capacity(if binary { batch } else { 0 }),
            last: false,
            scratch: Vec::new(),
        }
    }

    /// Buffer one draw; emits a frame payload through `sink` when the
    /// batch fills (binary) or immediately (JSON).
    pub fn push<S>(
        &mut self,
        msg: &DrawMsg,
        sink: &mut S,
    ) -> std::io::Result<()>
    where
        S: FnMut(&[u8]) -> std::io::Result<()>,
    {
        match self.format {
            WireFormat::Json => sink(encode_draw(msg).as_bytes()),
            WireFormat::Binary => {
                debug_assert_eq!(msg.machine, self.machine);
                debug_assert_eq!(msg.theta.len(), self.dim);
                self.thetas.extend_from_slice(&msg.theta);
                self.elapsed.push(msg.elapsed);
                self.last |= msg.last;
                if self.elapsed.len() >= self.batch {
                    self.flush(sink)?;
                }
                Ok(())
            }
        }
    }

    /// Emit buffered draws as one chunk frame (no-op when empty or in
    /// JSON mode, which never buffers).
    pub fn flush<S>(&mut self, sink: &mut S) -> std::io::Result<()>
    where
        S: FnMut(&[u8]) -> std::io::Result<()>,
    {
        if self.elapsed.is_empty() {
            return Ok(());
        }
        encode_chunk_into(
            self.machine,
            self.dim,
            &self.thetas,
            &self.elapsed,
            self.last,
            &mut self.scratch,
        );
        self.thetas.clear();
        self.elapsed.clear();
        self.last = false;
        sink(&self.scratch)
    }

    /// Draws currently buffered (0 in JSON mode).
    pub fn buffered(&self) -> usize {
        self.elapsed.len()
    }

    /// Current scratch-buffer capacity — the allocation-reuse test
    /// hook: after the first full flush this must stay constant.
    pub fn scratch_capacity(&self) -> usize {
        self.scratch.capacity()
    }
}

/// One decoded frame payload.
#[derive(Debug, Clone)]
pub enum WireMsg {
    Draw(DrawMsg),
    /// A batched binary draw chunk (see [`DrawChunk`]).
    Chunk(DrawChunk),
    Summary(WorkerSummary),
    /// Worker-side failure report. Socket daemons have no stderr the
    /// leader can collect, so a job that dies after the connection is
    /// up reports its root cause in-band instead of just closing the
    /// stream.
    Error { machine: usize, message: String },
    /// In-band `RPHB` liveness beacon: the worker emits one between
    /// draw frames whenever `heartbeat_secs` elapse without other
    /// traffic (notably across the frame-free burn-in stretch), so a
    /// leader holding a read deadline can tell "alive but not
    /// retaining draws yet" from "wedged or partitioned". Carries no
    /// draw data; the scheduler validates the machine id and drops it.
    /// Manifest-negotiated (`heartbeat_secs` field) so old daemons —
    /// which never emit it — keep working.
    Heartbeat { machine: usize },
}

/// Encode one float for the wire. Finite values go through [`Json`]'s
/// bit-exact number rendering; non-finite values (which JSON numbers
/// cannot carry) become the string tokens `"inf"` / `"-inf"` / `"nan"`
/// so a diverged chain's ±∞ survives the pipe as ±∞, not as a silent
/// NaN — keeping process mode value-identical to thread mode even off
/// the happy path.
fn wire_f64(v: f64) -> Json {
    if v.is_finite() {
        Json::Num(v)
    } else if v.is_nan() {
        Json::Str("nan".into())
    } else if v > 0.0 {
        Json::Str("inf".into())
    } else {
        Json::Str("-inf".into())
    }
}

/// Inverse of [`wire_f64`]. Also accepts `null` (what a non-finite
/// float rendered as before it had a token) as NaN for leniency.
fn f64_from_wire(j: &Json) -> Result<f64> {
    match j {
        Json::Null => Ok(f64::NAN),
        Json::Str(s) => match s.as_str() {
            "nan" => Ok(f64::NAN),
            "inf" => Ok(f64::INFINITY),
            "-inf" => Ok(f64::NEG_INFINITY),
            other => {
                Err(Error::Parse(format!("bad float token '{other}'")))
            }
        },
        other => other.as_f64(),
    }
}

/// Encode a draw as a frame payload.
pub fn encode_draw(msg: &DrawMsg) -> String {
    json::obj(vec![
        ("type", Json::Str("draw".into())),
        ("machine", Json::Num(msg.machine as f64)),
        ("theta", Json::Arr(msg.theta.iter().map(|&v| wire_f64(v)).collect())),
        ("elapsed", wire_f64(msg.elapsed)),
        ("last", Json::Bool(msg.last)),
    ])
    .render()
}

/// Encode a worker summary as a frame payload.
pub fn encode_summary(s: &WorkerSummary) -> String {
    json::obj(vec![
        ("type", Json::Str("summary".into())),
        ("machine", Json::Num(s.machine as f64)),
        ("accept_rate", wire_f64(s.accept_rate)),
        ("wall_secs", wire_f64(s.wall_secs)),
    ])
    .render()
}

/// Encode a worker-side failure report as a frame payload.
pub fn encode_error(machine: usize, message: &str) -> String {
    json::obj(vec![
        ("type", Json::Str("error".into())),
        ("machine", Json::Num(machine as f64)),
        ("message", Json::Str(message.into())),
    ])
    .render()
}

/// Encode an `RPHB` heartbeat beacon as a frame payload (a JSON
/// control frame — the draw plane's wire format does not apply).
pub fn encode_heartbeat(machine: usize) -> String {
    json::obj(vec![
        ("type", Json::Str("hb".into())),
        ("machine", Json::Num(machine as f64)),
    ])
    .render()
}

impl WireMsg {
    /// Decode a raw frame payload from either plane: binary chunk
    /// frames announce themselves with [`DRAW_MAGIC`]; anything else
    /// must be UTF-8 JSON (summary and error frames stay JSON even in
    /// binary mode). The sniff is per frame, so a peer that never
    /// upgraded to the binary plane keeps decoding on the same stream.
    pub fn decode_frame(payload: &[u8]) -> Result<WireMsg> {
        if payload.starts_with(DRAW_MAGIC) {
            return DrawChunk::decode(payload).map(WireMsg::Chunk);
        }
        let text = std::str::from_utf8(payload)
            .map_err(|_| Error::Frame(FrameError::NotUtf8))?;
        WireMsg::decode(text)
    }

    pub fn decode(text: &str) -> Result<WireMsg> {
        let j = Json::parse(text)?;
        match j.get("type")?.as_str()? {
            "draw" => Ok(WireMsg::Draw(DrawMsg {
                machine: j.get("machine")?.as_usize()?,
                theta: j
                    .get("theta")?
                    .as_arr()?
                    .iter()
                    .map(f64_from_wire)
                    .collect::<Result<_>>()?,
                elapsed: f64_from_wire(j.get("elapsed")?)?,
                last: j.get("last")?.as_bool()?,
            })),
            "summary" => Ok(WireMsg::Summary(WorkerSummary {
                machine: j.get("machine")?.as_usize()?,
                accept_rate: f64_from_wire(j.get("accept_rate")?)?,
                wall_secs: f64_from_wire(j.get("wall_secs")?)?,
            })),
            "error" => Ok(WireMsg::Error {
                machine: j.get("machine")?.as_usize()?,
                message: j.get("message")?.as_str()?.to_string(),
            }),
            "hb" => Ok(WireMsg::Heartbeat {
                machine: j.get("machine")?.as_usize()?,
            }),
            other => {
                Err(Error::Parse(format!("unknown wire message type '{other}'")))
            }
        }
    }
}

/// Everything a worker process needs to reproduce its in-thread twin:
/// which machine it is, the shared run geometry, the root seed its RNG
/// stream is split from, the sampler spec, and where its spilled shard
/// lives. Written by the leader next to the shard file; the `worker`
/// CLI subcommand loads it as its sole input.
#[derive(Debug, Clone, PartialEq)]
pub struct WorkerManifest {
    pub machine: usize,
    pub machines: usize,
    /// Root seed — the worker derives `Pcg64::seed_from(seed).split(m)`
    /// exactly as the in-thread path does. Serialized as a string so
    /// u64 seeds above 2^53 survive the f64-based JSON number grammar.
    pub seed: u64,
    pub samples: usize,
    pub burn_in: usize,
    pub thin: usize,
    pub prior_weight: f64,
    /// Sampler spec in [`crate::config::parse_sampler`] syntax.
    pub sampler: String,
    pub shard_path: String,
    /// Expected parameter dimension (validated against the shard).
    pub dim: usize,
    /// When set, the shard arrives *inline* as a binary frame right
    /// after this manifest frame, and `shard_path` is only the
    /// leader-side spill (never resolved by the worker) — socket
    /// daemons stop needing a shared filesystem. Absent in old
    /// manifests ⇒ `false` (path mode), so mixed-version fleets keep
    /// working.
    pub shard_inline: bool,
    /// Draw-plane encoding the worker must answer in (control frames
    /// stay JSON either way). Absent in old manifests ⇒
    /// [`WireFormat::Json`]; and because the leader sniffs every frame
    /// for the [`DRAW_MAGIC`], an old daemon that ignores this field
    /// and answers in JSON still interoperates.
    pub wire_format: WireFormat,
    /// Draws coalesced per binary chunk frame (a binary-plane knob;
    /// ignored in JSON mode). Consumers clamp to ≥ 1. Absent in old
    /// manifests ⇒ 1.
    pub draw_batch: usize,
    /// Heartbeat interval: the worker emits an `RPHB` beacon frame
    /// ([`WireMsg::Heartbeat`]) whenever this many seconds pass
    /// without any other frame on the wire. `0` disables heartbeats
    /// entirely, and absent in old manifests ⇒ `0`, so daemons and
    /// leaders that predate the beacon interoperate unchanged.
    pub heartbeat_secs: usize,
}

impl WorkerManifest {
    pub fn to_json(&self) -> Json {
        json::obj(vec![
            ("machine", Json::Num(self.machine as f64)),
            ("machines", Json::Num(self.machines as f64)),
            ("seed", Json::Str(self.seed.to_string())),
            ("samples", Json::Num(self.samples as f64)),
            ("burn_in", Json::Num(self.burn_in as f64)),
            ("thin", Json::Num(self.thin as f64)),
            ("prior_weight", Json::Num(self.prior_weight)),
            ("sampler", Json::Str(self.sampler.clone())),
            ("shard_path", Json::Str(self.shard_path.clone())),
            ("dim", Json::Num(self.dim as f64)),
            ("shard_inline", Json::Bool(self.shard_inline)),
            ("wire_format", Json::Str(self.wire_format.name().into())),
            ("draw_batch", Json::Num(self.draw_batch as f64)),
            ("heartbeat_secs", Json::Num(self.heartbeat_secs as f64)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        let seed = j.get("seed")?.as_str()?;
        // Optional for backward compatibility with pre-inline manifests.
        let shard_inline = match j.get("shard_inline") {
            Ok(v) => v.as_bool()?,
            Err(_) => false,
        };
        // Optional for backward compatibility with pre-binary-plane
        // manifests: absent ⇒ the original JSON wire, one draw/frame.
        let wire_format = match j.get("wire_format") {
            Ok(v) => WireFormat::parse(v.as_str()?)?,
            Err(_) => WireFormat::Json,
        };
        let draw_batch = match j.get("draw_batch") {
            Ok(v) => v.as_usize()?,
            Err(_) => 1,
        };
        // Optional for backward compatibility with pre-heartbeat
        // manifests: absent ⇒ no beacons.
        let heartbeat_secs = match j.get("heartbeat_secs") {
            Ok(v) => v.as_usize()?,
            Err(_) => 0,
        };
        Ok(WorkerManifest {
            machine: j.get("machine")?.as_usize()?,
            machines: j.get("machines")?.as_usize()?,
            seed: seed.parse().map_err(|_| {
                Error::Parse(format!("bad u64 seed '{seed}'"))
            })?,
            samples: j.get("samples")?.as_usize()?,
            burn_in: j.get("burn_in")?.as_usize()?,
            thin: j.get("thin")?.as_usize()?,
            prior_weight: j.get("prior_weight")?.as_f64()?,
            sampler: j.get("sampler")?.as_str()?.to_string(),
            shard_path: j.get("shard_path")?.as_str()?.to_string(),
            dim: j.get("dim")?.as_usize()?,
            shard_inline,
            wire_format,
            draw_batch,
            heartbeat_secs,
        })
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_json().render())?;
        Ok(())
    }

    pub fn load(path: &Path) -> Result<Self> {
        Self::from_json(&Json::parse(&std::fs::read_to_string(path)?)?)
    }
}

/// A live channel to one worker executing one [`WorkerManifest`].
/// Returned by [`Transport::connect`]; consumed by the leader-side
/// scheduler, which reads messages until end-of-stream and then calls
/// [`WorkerConnection::finish`].
pub trait WorkerConnection: Send {
    /// Next decoded message, or `None` at clean end-of-stream.
    fn recv(&mut self) -> Result<Option<WireMsg>>;

    /// Called once after a *clean* end-of-stream: verify the worker
    /// finished successfully and surface its exit diagnostics (exit
    /// status + stderr for child processes; nothing extra for sockets,
    /// whose failures arrive in-band as [`WireMsg::Error`] frames).
    /// Must not be called after a `recv` error — drop the connection
    /// instead, which cancels the worker without blocking.
    fn finish(&mut self) -> Result<()>;
}

/// A way to run [`WorkerManifest`]s on a pool of worker endpoints.
///
/// A transport exposes `slots()` concurrently usable endpoints; the
/// leader's scheduler oversubscribes when the machine count M exceeds
/// the slot count W by queueing the M manifests and assigning them to
/// endpoints as they free up. Per-machine RNG streams come from the
/// root seed (`root.split(m)`), never from the endpoint, so the
/// retained draws are byte-identical to thread mode regardless of W,
/// arrival order, or transport.
pub trait Transport: Sync {
    /// Short name for diagnostics ("pipe", "socket").
    fn name(&self) -> &'static str;

    /// Number of concurrently usable worker endpoints W.
    fn slots(&self) -> usize;

    /// Start executing `manifest` on endpoint `slot` (`0..slots()`).
    /// `manifest_path` is the leader-side spill of the same manifest;
    /// pipe workers receive it as `--manifest`, socket workers receive
    /// the manifest itself as the connection's first frame.
    fn connect(
        &self,
        slot: usize,
        manifest: &WorkerManifest,
        manifest_path: &Path,
    ) -> Result<Box<dyn WorkerConnection>>;

    /// Largest frame this transport accepts from a worker.
    fn max_frame_bytes(&self) -> usize {
        DEFAULT_MAX_FRAME_BYTES
    }

    /// Whether the leader should mark manifests `shard_inline` and
    /// ship each shard's spilled bytes over the connection instead of
    /// relying on the worker resolving `shard_path` on a shared
    /// filesystem. Default `false`: pipe workers and in-thread runs
    /// share a filesystem by construction.
    fn wants_inline_shard(&self) -> bool {
        false
    }

    /// Cancel every in-flight worker this transport has started — the
    /// scheduler's fail-fast path, called once on the run's first
    /// failure. Pipe children are killed outright; socket connections
    /// are shut down, which makes the daemon's next draw write fail
    /// and abort its chain. Default: nothing to cancel.
    fn cancel_all(&self) {}
}

/// PR 2's process mode behind the [`Transport`] trait: every
/// assignment spawns `<worker-bin> worker --manifest <path>` and reads
/// its stdout frame stream. `slots` bounds how many children run at
/// once — fewer slots than machines oversubscribes.
pub struct PipeTransport {
    worker_bin: PathBuf,
    slots: usize,
    max_frame_bytes: usize,
    /// Every child this transport has spawned, shared with the
    /// connections draining them, so [`Transport::cancel_all`] can kill
    /// in-flight workers from the failing thread (killing closes the
    /// child's stdout, which unblocks the sibling's frame read).
    children: Mutex<Vec<Arc<Mutex<Child>>>>,
}

impl PipeTransport {
    pub fn new(worker_bin: PathBuf, slots: usize) -> PipeTransport {
        PipeTransport {
            worker_bin,
            slots: slots.max(1),
            max_frame_bytes: DEFAULT_MAX_FRAME_BYTES,
            children: Mutex::new(Vec::new()),
        }
    }

    /// Override the per-frame byte cap (satellite knob; the default
    /// suits draws of any realistic dimension).
    pub fn with_max_frame_bytes(mut self, bytes: usize) -> PipeTransport {
        self.max_frame_bytes = bytes.max(1);
        self
    }
}

impl Transport for PipeTransport {
    fn name(&self) -> &'static str {
        "pipe"
    }

    fn slots(&self) -> usize {
        self.slots
    }

    fn connect(
        &self,
        _slot: usize,
        manifest: &WorkerManifest,
        manifest_path: &Path,
    ) -> Result<Box<dyn WorkerConnection>> {
        let mut child = Command::new(&self.worker_bin)
            .arg("worker")
            .arg("--manifest")
            .arg(manifest_path)
            .stdin(Stdio::null())
            .stdout(Stdio::piped())
            .stderr(Stdio::piped())
            .spawn()
            .map_err(|e| {
                Error::Runtime(format!(
                    "spawning worker {} ({}): {e}",
                    manifest.machine,
                    self.worker_bin.display()
                ))
            })?;
        let stdout = child.stdout.take().ok_or_else(|| {
            Error::Runtime(format!(
                "worker {}: no stdout pipe",
                manifest.machine
            ))
        })?;
        // Drain stderr concurrently from the start: a child that fills
        // the OS pipe buffer with (say) a long panic backtrace would
        // otherwise block in that write, never close stdout, and
        // deadlock the leader inside read_frame.
        let stderr_drain = child.stderr.take().map(|mut se| {
            std::thread::spawn(move || {
                let mut text = String::new();
                if let Err(e) = se.read_to_string(&mut text) {
                    // Surface the read failure instead of silently
                    // reporting an empty (or truncated) stderr — the
                    // exit diagnostic says why the capture is partial.
                    text.push_str(&format!("\n<stderr read failed: {e}>"));
                }
                text
            })
        });
        let child = Arc::new(Mutex::new(child));
        self.children.lock().unwrap().push(Arc::clone(&child));
        Ok(Box::new(PipeConnection {
            machine: manifest.machine,
            frames: FrameReader::with_max_frame(
                BufReader::new(stdout),
                self.max_frame_bytes,
            ),
            buf: Vec::new(),
            stderr_drain,
            child,
            reaped: false,
        }))
    }

    fn max_frame_bytes(&self) -> usize {
        self.max_frame_bytes
    }

    /// Kill every child spawned so far. Already-reaped children ignore
    /// the kill; live ones exit, closing their stdout, so the threads
    /// draining them fall out of `recv` and reap them.
    fn cancel_all(&self) {
        for child in self.children.lock().unwrap().iter() {
            child.lock().unwrap().kill().ok();
        }
    }
}

struct PipeConnection {
    machine: usize,
    frames: FrameReader<BufReader<ChildStdout>>,
    /// Reused frame-payload buffer: every frame of the child's stream
    /// lands in this one allocation (see
    /// [`FrameReader::read_frame_into`]).
    buf: Vec<u8>,
    stderr_drain: Option<std::thread::JoinHandle<String>>,
    /// Shared with the owning [`PipeTransport`]'s cancel registry.
    child: Arc<Mutex<Child>>,
    reaped: bool,
}

impl WorkerConnection for PipeConnection {
    fn recv(&mut self) -> Result<Option<WireMsg>> {
        match self.frames.read_frame_into(&mut self.buf)? {
            Some(_) => WireMsg::decode_frame(&self.buf).map(Some),
            None => Ok(None),
        }
    }

    fn finish(&mut self) -> Result<()> {
        // Stdout hit EOF, so the child is exiting: collect what it said
        // on stderr, then reap.
        let stderr_text = self
            .stderr_drain
            .take()
            .and_then(|h| h.join().ok())
            .unwrap_or_default();
        let status = self.child.lock().unwrap().wait().map_err(|e| {
            Error::Runtime(format!("worker {}: wait: {e}", self.machine))
        })?;
        self.reaped = true;
        if !status.success() {
            return Err(Error::Runtime(format!(
                "worker {} exited with {status}: {}",
                self.machine,
                stderr_text.trim()
            )));
        }
        Ok(())
    }
}

impl Drop for PipeConnection {
    /// Dropped before a successful [`finish`](WorkerConnection::finish)
    /// — i.e. on any leader-side error path — the child is cancelled
    /// and reaped so a failing run never leaks worker processes.
    fn drop(&mut self) {
        if !self.reaped {
            let mut child = self.child.lock().unwrap();
            child.kill().ok();
            child.wait().ok();
        }
    }
}

/// Multi-host transport: every endpoint is a `repro serve --listen`
/// worker daemon. Each assignment opens a fresh TCP connection to the
/// endpoint, sends the [`WorkerManifest`] as the first frame, and
/// reads [`WireMsg`] frames back until the daemon closes the
/// connection after its summary frame.
///
/// The manifest's `shard_path` is resolved on the *daemon's*
/// filesystem, so leader and daemons must share one (same host, NFS,
/// or a pre-distributed spill directory).
pub struct SocketTransport {
    addrs: Vec<String>,
    max_frame_bytes: usize,
    /// Dial timeout (`connect_timeout_secs` config key).
    connect_timeout: Duration,
    /// Liveness deadline: longest silence tolerated between frames
    /// from a connected worker before its stream fails with a
    /// structured expiry error. `None` (the default) keeps reads
    /// unbounded — the pre-heartbeat behavior, where a worker
    /// legitimately emits nothing for the whole burn-in stretch.
    /// Pair with manifest-negotiated heartbeats so an *alive* worker
    /// always has traffic inside the deadline.
    read_deadline: Option<Duration>,
    /// Ship each shard inline as a binary frame after the manifest
    /// frame (`shard_inline` config key / `--shard-inline`): daemons
    /// stop needing a shared filesystem. The shard bytes sent are the
    /// leader's own spill file, so inline and path delivery decode
    /// bit-identically.
    inline_shards: bool,
    /// Clones of every in-flight connection's stream, shared so
    /// [`Transport::cancel_all`] can shut them down from the failing
    /// thread: the blocked reader sees EOF, and the daemon's next draw
    /// write fails, aborting its chain.
    live: Mutex<Vec<TcpStream>>,
}

impl SocketTransport {
    /// One endpoint per address (`host:port`). Rejects an empty list.
    pub fn new(addrs: Vec<String>) -> Result<SocketTransport> {
        if addrs.is_empty() {
            return Err(Error::Config(
                "socket transport needs at least one worker address".into(),
            ));
        }
        Ok(SocketTransport {
            addrs,
            max_frame_bytes: DEFAULT_MAX_FRAME_BYTES,
            connect_timeout: DEFAULT_CONNECT_TIMEOUT,
            read_deadline: None,
            inline_shards: false,
            live: Mutex::new(Vec::new()),
        })
    }

    /// Override the dial timeout (the `connect_timeout_secs` config
    /// key / `--connect-timeout-secs` flag).
    pub fn with_connect_timeout(mut self, t: Duration) -> SocketTransport {
        self.connect_timeout = t;
        self
    }

    /// Arm a liveness deadline on every connection's reads (the
    /// `liveness_timeout_secs` config key / `--liveness-timeout-secs`
    /// flag): a worker silent for longer fails its stream with a
    /// structured [`LIVENESS_EXPIRED_MARKER`] error instead of hanging
    /// the endpoint loop forever.
    pub fn with_read_deadline(
        mut self,
        deadline: Option<Duration>,
    ) -> SocketTransport {
        self.read_deadline = deadline;
        self
    }

    /// Enable (or disable) inline shard delivery — see the
    /// `inline_shards` field docs.
    pub fn with_inline_shards(mut self, inline: bool) -> SocketTransport {
        self.inline_shards = inline;
        self
    }

    /// Parse a comma-separated `host:port,host:port,…` list (the
    /// `--workers` CLI flag / `workers` config key).
    pub fn from_spec(spec: &str) -> Result<SocketTransport> {
        SocketTransport::new(
            spec.split(',')
                .map(|a| a.trim().to_string())
                .filter(|a| !a.is_empty())
                .collect(),
        )
    }

    /// Override the per-frame byte cap.
    pub fn with_max_frame_bytes(mut self, bytes: usize) -> SocketTransport {
        self.max_frame_bytes = bytes.max(1);
        self
    }
}

impl Transport for SocketTransport {
    fn name(&self) -> &'static str {
        "socket"
    }

    fn slots(&self) -> usize {
        self.addrs.len()
    }

    fn connect(
        &self,
        slot: usize,
        manifest: &WorkerManifest,
        _manifest_path: &Path,
    ) -> Result<Box<dyn WorkerConnection>> {
        let addr = &self.addrs[slot];
        // Bound the dial: an unroutable endpoint should fail the run,
        // not hang it. (A merely *busy* daemon still accepts promptly —
        // the OS completes the handshake into the listen backlog.)
        // Reads stay unbounded unless a liveness deadline is armed: a
        // deadline-free worker legitimately emits no frames for the
        // whole burn-in stretch.
        let sock_addr = addr
            .to_socket_addrs()
            .map_err(|e| {
                Error::Runtime(format!(
                    "resolving worker address {addr}: {e}"
                ))
            })?
            .next()
            .ok_or_else(|| {
                Error::Runtime(format!(
                    "worker address {addr} resolved to nothing"
                ))
            })?;
        let stream =
            TcpStream::connect_timeout(&sock_addr, self.connect_timeout)
                .map_err(|e| {
                    Error::Runtime(format!(
                        "connecting to worker {addr} for machine {}: {e}",
                        manifest.machine
                    ))
                })?;
        stream.set_nodelay(true).ok();
        if let Some(deadline) = self.read_deadline {
            // A failed set_read_timeout would silently disarm the
            // liveness contract the caller asked for — propagate it.
            stream.set_read_timeout(Some(deadline)).map_err(|e| {
                Error::Runtime(format!(
                    "arming the {deadline:?} liveness read deadline on \
                     worker {addr}: {e}"
                ))
            })?;
        }
        // Register with the cancel list *before* any write: the inline
        // shard frame below can be tens of MB, and a daemon that stops
        // draining its socket would block that write forever — the
        // fail-fast path (`cancel_all` from a failing sibling) must be
        // able to shut this stream down mid-send.
        self.live
            .lock()
            .unwrap()
            .push(stream.try_clone().map_err(Error::Io)?);
        let mut writer = stream.try_clone().map_err(Error::Io)?;
        write_frame(&mut writer, &manifest.to_json().render()).map_err(
            |e| {
                Error::Runtime(format!(
                    "sending manifest for machine {} to {addr}: {e}",
                    manifest.machine
                ))
            },
        )?;
        // Inline delivery: the manifest promised (`shard_inline`) that
        // the next frame carries the shard's spilled bytes — read the
        // leader-side spill and ship it, so the daemon never resolves
        // `shard_path` on its own filesystem. Gated on the manifest
        // flag (not the transport field) so leader and daemon can never
        // disagree about the frame sequence.
        if manifest.shard_inline {
            let bytes =
                std::fs::read(&manifest.shard_path).map_err(|e| {
                    Error::Runtime(format!(
                        "reading spilled shard {} for inline delivery: {e}",
                        manifest.shard_path
                    ))
                })?;
            // Pre-check against the frame cap: the daemon's reader
            // enforces its own `max_frame_bytes` (same default as
            // ours), so an oversized shard would otherwise burn a
            // dispatch and fail deep in the run with a bare Oversized
            // frame error. Fail here instead, naming the fixes.
            if bytes.len() > self.max_frame_bytes {
                return Err(Error::Runtime(format!(
                    "machine {}'s shard is {} bytes, over the {}-byte \
                     inline-frame cap — raise it on both ends \
                     (`pipeline --max-frame-bytes` / the \
                     `max_frame_bytes` config key on the leader, \
                     `repro serve --max-frame-bytes` on the daemons) \
                     or use path mode (drop --shard-inline) over a \
                     shared filesystem",
                    manifest.machine,
                    bytes.len(),
                    self.max_frame_bytes
                )));
            }
            // The bytes come off the just-written spill file (page-
            // cache-warm), not a second in-memory encode: the spill
            // must exist anyway — it is the run's inspectable copy and
            // the path-mode fallback — and `io::shard_to_bytes` pins
            // the file ≡ memory equivalence for transports that do
            // want to skip the disk.
            write_frame_bytes(&mut writer, &bytes).map_err(|e| {
                Error::Runtime(format!(
                    "sending inline shard for machine {} to {addr}: {e}",
                    manifest.machine
                ))
            })?;
        }
        Ok(Box::new(SocketConnection {
            frames: FrameReader::with_max_frame(
                BufReader::new(stream),
                self.max_frame_bytes,
            ),
            buf: Vec::new(),
            read_deadline: self.read_deadline,
        }))
    }

    fn max_frame_bytes(&self) -> usize {
        self.max_frame_bytes
    }

    fn wants_inline_shard(&self) -> bool {
        self.inline_shards
    }

    /// Shut down every connection opened so far; already-closed ones
    /// ignore it. In-flight daemons abort their chains at the next
    /// failed draw write.
    fn cancel_all(&self) {
        for stream in self.live.lock().unwrap().iter() {
            stream.shutdown(Shutdown::Both).ok();
        }
    }
}

struct SocketConnection {
    frames: FrameReader<BufReader<TcpStream>>,
    /// Reused frame-payload buffer (see [`FrameReader::read_frame_into`]).
    buf: Vec<u8>,
    /// The armed liveness deadline, kept for the expiry diagnostic.
    read_deadline: Option<Duration>,
}

impl WorkerConnection for SocketConnection {
    fn recv(&mut self) -> Result<Option<WireMsg>> {
        match self.frames.read_frame_into(&mut self.buf) {
            Ok(Some(_)) => WireMsg::decode_frame(&self.buf).map(Some),
            Ok(None) => Ok(None),
            // A timed-out read is the armed deadline firing, not a
            // stream fault: report it as a liveness expiry the
            // scheduler can recognize (and count) by its marker.
            Err(Error::Io(e))
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock
                        | std::io::ErrorKind::TimedOut
                ) && self.read_deadline.is_some() =>
            {
                Err(Error::Runtime(format!(
                    "{LIVENESS_EXPIRED_MARKER}: no frame (draw or \
                     heartbeat) within {:?} — peer wedged or partitioned",
                    self.read_deadline.unwrap_or_default()
                )))
            }
            Err(e) => Err(e),
        }
    }

    fn finish(&mut self) -> Result<()> {
        // A clean close after the summary frame is the daemon's whole
        // success signal; failures arrive in-band as error frames.
        Ok(())
    }
}

/// One deterministic misbehavior, parsed from a `--fault` spec token.
///
/// The same grammar drives both chaos surfaces: leader-side, a
/// [`FaultInjector`] wrapper transport applies the fault to a slot's
/// connections; daemon-side, `repro serve --fault <spec>` applies it
/// to every job's outbound frame stream — so the retry/heartbeat/
/// quarantine matrix is exercisable over real pipes and sockets
/// without OS-level packet tricks.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultSpec {
    /// Refuse the dial (leader-side: `connect` errors; daemon-side:
    /// accept then immediately close, before reading the manifest).
    RefuseDial,
    /// Drop the connection after N frames have crossed it.
    DropAfterFrames(usize),
    /// Sleep this many milliseconds before every frame — a slow link.
    DelayMillis(u64),
    /// Corrupt frame N (0-based): daemon-side the payload's bytes are
    /// actually flipped on the wire; leader-side the received frame is
    /// replaced by the structured parse error real corruption decodes
    /// to.
    CorruptFrame(usize),
}

impl FaultSpec {
    /// Parse a spec token: `refuse-dial`, `drop-after:N`,
    /// `delay-ms:MS`, or `corrupt:N`.
    pub fn parse(s: &str) -> Result<FaultSpec> {
        let s = s.trim();
        if s == "refuse-dial" {
            return Ok(FaultSpec::RefuseDial);
        }
        let (kind, arg) = s.split_once(':').ok_or_else(|| {
            Error::Config(format!(
                "bad fault spec '{s}' (expected refuse-dial, \
                 drop-after:N, delay-ms:MS, or corrupt:N)"
            ))
        })?;
        let n: u64 = arg.trim().parse().map_err(|_| {
            Error::Config(format!(
                "bad fault spec '{s}': '{}' is not a number",
                arg.trim()
            ))
        })?;
        match kind.trim() {
            "drop-after" => Ok(FaultSpec::DropAfterFrames(n as usize)),
            "delay-ms" => Ok(FaultSpec::DelayMillis(n)),
            "corrupt" => Ok(FaultSpec::CorruptFrame(n as usize)),
            other => Err(Error::Config(format!(
                "unknown fault kind '{other}' (expected refuse-dial, \
                 drop-after, delay-ms, or corrupt)"
            ))),
        }
    }
}

/// Deterministic chaos wrapper: forwards everything to an inner
/// transport, applying a per-slot [`FaultSpec`] to that slot's
/// connections. Slots without a fault behave exactly like the inner
/// transport, so a mixed pool (one faulty endpoint, W−1 healthy ones)
/// is one `with_fault` call — the shape every retry/quarantine test
/// wants.
pub struct FaultInjector<T: Transport> {
    inner: T,
    faults: Mutex<Vec<Option<FaultSpec>>>,
}

impl<T: Transport> FaultInjector<T> {
    pub fn new(inner: T) -> FaultInjector<T> {
        let slots = inner.slots();
        FaultInjector { inner, faults: Mutex::new(vec![None; slots]) }
    }

    /// Arm `fault` on endpoint `slot`'s future connections.
    pub fn with_fault(self, slot: usize, fault: FaultSpec) -> Self {
        self.faults.lock().unwrap()[slot] = Some(fault);
        self
    }
}

impl<T: Transport> Transport for FaultInjector<T> {
    fn name(&self) -> &'static str {
        self.inner.name()
    }

    fn slots(&self) -> usize {
        self.inner.slots()
    }

    fn connect(
        &self,
        slot: usize,
        manifest: &WorkerManifest,
        manifest_path: &Path,
    ) -> Result<Box<dyn WorkerConnection>> {
        let fault = self.faults.lock().unwrap()[slot];
        if let Some(FaultSpec::RefuseDial) = fault {
            return Err(Error::Runtime(format!(
                "fault injector: endpoint {slot} refused the dial for \
                 machine {}",
                manifest.machine
            )));
        }
        let inner = self.inner.connect(slot, manifest, manifest_path)?;
        Ok(Box::new(FaultConnection { inner, fault, frames_seen: 0 }))
    }

    fn max_frame_bytes(&self) -> usize {
        self.inner.max_frame_bytes()
    }

    fn wants_inline_shard(&self) -> bool {
        self.inner.wants_inline_shard()
    }

    fn cancel_all(&self) {
        self.inner.cancel_all()
    }
}

struct FaultConnection {
    inner: Box<dyn WorkerConnection>,
    fault: Option<FaultSpec>,
    frames_seen: usize,
}

impl WorkerConnection for FaultConnection {
    fn recv(&mut self) -> Result<Option<WireMsg>> {
        match self.fault {
            Some(FaultSpec::DropAfterFrames(n))
                if self.frames_seen >= n =>
            {
                return Err(Error::Runtime(format!(
                    "fault injector: connection dropped after {n} frames"
                )));
            }
            Some(FaultSpec::DelayMillis(ms)) => {
                std::thread::sleep(Duration::from_millis(ms));
            }
            _ => {}
        }
        let msg = self.inner.recv()?;
        if msg.is_some() {
            if let Some(FaultSpec::CorruptFrame(n)) = self.fault {
                if self.frames_seen == n {
                    self.frames_seen += 1;
                    // What a bit-flipped RPDRAW1 payload decodes to.
                    return Err(Error::Parse(format!(
                        "fault injector: frame {n} corrupted in flight"
                    )));
                }
            }
            self.frames_seen += 1;
        }
        Ok(msg)
    }

    fn finish(&mut self) -> Result<()> {
        self.inner.finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::BufReader;

    fn draw(machine: usize, theta: Vec<f64>, last: bool) -> DrawMsg {
        DrawMsg { machine, theta, elapsed: 0.125, last }
    }

    #[test]
    fn frame_roundtrip_over_byte_stream() {
        let mut buf: Vec<u8> = Vec::new();
        write_frame(&mut buf, "hello").unwrap();
        write_frame(&mut buf, "").unwrap();
        write_frame(&mut buf, "{\"k\":[1,2]}").unwrap();
        let mut r = FrameReader::new(BufReader::new(buf.as_slice()));
        assert_eq!(r.read_frame().unwrap().unwrap(), "hello");
        assert_eq!(r.read_frame().unwrap().unwrap(), "");
        assert_eq!(r.read_frame().unwrap().unwrap(), "{\"k\":[1,2]}");
        assert!(r.read_frame().unwrap().is_none());
        assert!(r.read_frame().unwrap().is_none()); // EOF is sticky
    }

    /// Binary frames share the grammar with text frames: arbitrary
    /// (non-UTF-8) payloads round-trip through
    /// `write_frame_bytes`/`read_frame_bytes`, text readers reject them
    /// structurally, and the two reader flavours interleave on one
    /// stream — the manifest-then-inline-shard sequence.
    #[test]
    fn byte_frames_roundtrip_and_interleave_with_text() {
        let shard_bytes = vec![0xFFu8, 0x00, b'R', 0xFE, b'\n', 0x80];
        let mut buf: Vec<u8> = Vec::new();
        write_frame(&mut buf, "{\"type\":\"manifest\"}").unwrap();
        write_frame_bytes(&mut buf, &shard_bytes).unwrap();
        write_frame(&mut buf, "after").unwrap();
        let mut r = FrameReader::new(BufReader::new(buf.as_slice()));
        assert_eq!(r.read_frame().unwrap().unwrap(), "{\"type\":\"manifest\"}");
        assert_eq!(r.read_frame_bytes().unwrap().unwrap(), shard_bytes);
        assert_eq!(r.read_frame().unwrap().unwrap(), "after");
        assert!(r.read_frame_bytes().unwrap().is_none());
        // A text read of a non-UTF-8 payload is the structured NotUtf8
        // violation, not a panic or a lossy string.
        let mut buf2: Vec<u8> = Vec::new();
        write_frame_bytes(&mut buf2, &shard_bytes).unwrap();
        let mut r2 = FrameReader::new(BufReader::new(buf2.as_slice()));
        assert!(matches!(
            r2.read_frame().unwrap_err(),
            Error::Frame(crate::error::FrameError::NotUtf8)
        ));
    }

    /// `shard_inline` survives the manifest JSON round-trip, and
    /// manifests written before the field existed decode as path mode.
    #[test]
    fn manifest_shard_inline_roundtrip_and_backcompat() {
        let mut m = WorkerManifest {
            machine: 0,
            machines: 2,
            seed: 1,
            samples: 5,
            burn_in: 0,
            thin: 1,
            prior_weight: 0.5,
            sampler: "rwm:1".into(),
            shard_path: "/tmp/s.bin".into(),
            dim: 2,
            shard_inline: true,
            wire_format: WireFormat::Json,
            draw_batch: 1,
            heartbeat_secs: 0,
        };
        let back =
            WorkerManifest::from_json(&Json::parse(&m.to_json().render()).unwrap())
                .unwrap();
        assert_eq!(m, back);
        m.shard_inline = false;
        // Strip the field to simulate an old leader's manifest.
        let mut obj = match m.to_json() {
            Json::Obj(o) => o,
            _ => unreachable!(),
        };
        obj.remove("shard_inline");
        let old = WorkerManifest::from_json(&Json::Obj(obj)).unwrap();
        assert_eq!(m, old, "missing field must decode as path mode");
    }

    #[test]
    fn frame_reader_rejects_garbage_with_structured_errors() {
        use crate::error::FrameError;
        // Corrupt (non-decimal) length prefix.
        let mut r = FrameReader::new(BufReader::new(&b"notalen\nxx\n"[..]));
        assert!(matches!(
            r.read_frame().unwrap_err(),
            Error::Frame(FrameError::BadPrefix(_))
        ));
        // Length longer than the remaining stream → truncated payload,
        // not a generic io error.
        let mut r = FrameReader::new(BufReader::new(&b"100\nshort\n"[..]));
        assert!(matches!(
            r.read_frame().unwrap_err(),
            Error::Frame(FrameError::TruncatedPayload { expected: 100 })
        ));
        // Payload not followed by newline.
        let mut r = FrameReader::new(BufReader::new(&b"2\nabX"[..]));
        assert!(matches!(
            r.read_frame().unwrap_err(),
            Error::Frame(FrameError::MissingNewline)
        ));
    }

    /// The frame cap is a per-reader (transport-level) parameter, and an
    /// oversized prefix reports both the declared length and the cap.
    #[test]
    fn frame_cap_is_a_reader_parameter() {
        use crate::error::FrameError;
        let mut buf: Vec<u8> = Vec::new();
        write_frame(&mut buf, "twelve bytes").unwrap();
        // Under the default cap the frame reads fine…
        let mut r = FrameReader::new(BufReader::new(buf.as_slice()));
        assert_eq!(r.read_frame().unwrap().unwrap(), "twelve bytes");
        // …but a transport configured with a smaller cap rejects it
        // with a structured, diagnosable error.
        let mut r =
            FrameReader::with_max_frame(BufReader::new(buf.as_slice()), 8);
        match r.read_frame().unwrap_err() {
            Error::Frame(FrameError::Oversized { len, max }) => {
                assert_eq!(len, 12);
                assert_eq!(max, 8);
            }
            other => panic!("wrong error {other:?}"),
        }
    }

    /// A stream that dies after N well-formed draw frames yields those
    /// N draws and then a structured truncation error — the leader can
    /// report exactly where the worker went silent.
    #[test]
    fn early_eof_after_n_draws_is_structured() {
        use crate::error::FrameError;
        let mut buf: Vec<u8> = Vec::new();
        for i in 0..3 {
            write_frame(&mut buf, &encode_draw(&draw(0, vec![i as f64], false)))
                .unwrap();
        }
        buf.extend_from_slice(b"17"); // prefix cut off mid-line
        let mut r = FrameReader::new(BufReader::new(buf.as_slice()));
        for _ in 0..3 {
            let payload = r.read_frame().unwrap().unwrap();
            assert!(matches!(
                WireMsg::decode(&payload).unwrap(),
                WireMsg::Draw(_)
            ));
        }
        assert!(matches!(
            r.read_frame().unwrap_err(),
            Error::Frame(FrameError::TruncatedPrefix)
        ));
    }

    /// A non-frame stream (e.g. `--worker-bin` pointing at a chatty
    /// binary) must fail fast with bounded memory, even with no
    /// newline in sight.
    #[test]
    fn frame_reader_bounds_prefix_on_newline_free_garbage() {
        let garbage = vec![b'x'; 4096];
        let mut r = FrameReader::new(BufReader::new(garbage.as_slice()));
        let err = r.read_frame().unwrap_err();
        assert!(err.to_string().contains("prefix too long"), "{err}");
        // Truncated prefix (EOF before newline) is also an error, not
        // a clean end-of-stream.
        let mut r = FrameReader::new(BufReader::new(&b"123"[..]));
        assert!(r.read_frame().is_err());
    }

    #[test]
    fn draw_roundtrip_is_bit_exact() {
        let msg = draw(3, vec![0.1, -1.0 / 3.0, 1e-300, -0.0], true);
        let decoded = match WireMsg::decode(&encode_draw(&msg)).unwrap() {
            WireMsg::Draw(d) => d,
            other => panic!("wrong variant {other:?}"),
        };
        assert_eq!(decoded.machine, 3);
        assert!(decoded.last);
        assert_eq!(decoded.theta.len(), msg.theta.len());
        for (a, b) in msg.theta.iter().zip(&decoded.theta) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert_eq!(msg.elapsed.to_bits(), decoded.elapsed.to_bits());
    }

    /// Non-finite floats have no JSON number form; the wire carries
    /// them as tokens so ±∞ survives as ±∞ (not a silent NaN).
    #[test]
    fn draw_roundtrip_preserves_nonfinite_values() {
        let msg = draw(
            0,
            vec![f64::INFINITY, f64::NEG_INFINITY, f64::NAN, 1.5],
            false,
        );
        let decoded = match WireMsg::decode(&encode_draw(&msg)).unwrap() {
            WireMsg::Draw(d) => d,
            other => panic!("wrong variant {other:?}"),
        };
        assert_eq!(decoded.theta[0], f64::INFINITY);
        assert_eq!(decoded.theta[1], f64::NEG_INFINITY);
        assert!(decoded.theta[2].is_nan());
        assert_eq!(decoded.theta[3], 1.5);
    }

    #[test]
    fn summary_roundtrip_preserves_nan_accept_rate() {
        let s = WorkerSummary {
            machine: 1,
            accept_rate: f64::NAN,
            wall_secs: 2.5,
        };
        match WireMsg::decode(&encode_summary(&s)).unwrap() {
            WireMsg::Summary(back) => {
                assert_eq!(back.machine, 1);
                assert!(back.accept_rate.is_nan());
                assert_eq!(back.wall_secs, 2.5);
            }
            other => panic!("wrong variant {other:?}"),
        }
    }

    #[test]
    fn decode_rejects_unknown_type() {
        assert!(WireMsg::decode("{\"type\":\"nope\"}").is_err());
        assert!(WireMsg::decode("not json").is_err());
    }

    #[test]
    fn error_frame_roundtrips() {
        let payload = encode_error(4, "shard missing: /tmp/shard_4.bin");
        match WireMsg::decode(&payload).unwrap() {
            WireMsg::Error { machine, message } => {
                assert_eq!(machine, 4);
                assert!(message.contains("shard missing"));
            }
            other => panic!("wrong variant {other:?}"),
        }
    }

    #[test]
    fn socket_transport_spec_parsing() {
        let t = SocketTransport::from_spec(
            "127.0.0.1:7001, 127.0.0.1:7002 ,,",
        )
        .unwrap();
        assert_eq!(t.slots(), 2);
        assert_eq!(t.name(), "socket");
        assert!(SocketTransport::from_spec("  ,, ").is_err());
    }

    /// Dialing a dead endpoint surfaces a connect error naming both the
    /// address and the machine, not a bare io error.
    #[test]
    fn socket_transport_connect_failure_is_diagnosable() {
        // Bind-then-drop to get a port with (very likely) no listener.
        let dead = {
            let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap().to_string()
        };
        let t = SocketTransport::from_spec(&dead).unwrap();
        let m = WorkerManifest {
            machine: 1,
            machines: 2,
            seed: 3,
            samples: 4,
            burn_in: 0,
            thin: 1,
            prior_weight: 0.5,
            sampler: "rwm:1".into(),
            shard_path: "/tmp/none".into(),
            dim: 1,
            shard_inline: false,
            wire_format: WireFormat::Json,
            draw_batch: 1,
            heartbeat_secs: 0,
        };
        let err =
            t.connect(0, &m, Path::new("/tmp/none.json")).unwrap_err();
        let text = err.to_string();
        assert!(
            text.contains("connecting to worker") && text.contains(&dead),
            "{text}"
        );
    }

    /// An inline shard bigger than the transport's frame cap fails at
    /// dispatch with an error naming the cap and the ways out — not
    /// deep in the run with a bare Oversized frame error from the
    /// daemon's reader.
    #[test]
    fn oversized_inline_shard_fails_fast_at_the_leader() {
        let dir = std::env::temp_dir().join("repro_inline_cap_test");
        std::fs::create_dir_all(&dir).unwrap();
        let shard_path = dir.join("big.bin");
        std::fs::write(&shard_path, vec![0u8; 256]).unwrap();
        // A listener that never accepts is enough: the handshake
        // completes into the backlog, and connect() fails on the size
        // pre-check before any daemon interaction.
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let t = SocketTransport::from_spec(&addr)
            .unwrap()
            .with_inline_shards(true)
            .with_max_frame_bytes(64);
        let m = WorkerManifest {
            machine: 0,
            machines: 1,
            seed: 1,
            samples: 2,
            burn_in: 0,
            thin: 1,
            prior_weight: 1.0,
            sampler: "rwm:1".into(),
            shard_path: shard_path.to_string_lossy().into_owned(),
            dim: 1,
            shard_inline: true,
            wire_format: WireFormat::Json,
            draw_batch: 1,
            heartbeat_secs: 0,
        };
        let err = t.connect(0, &m, Path::new("/tmp/none.json")).unwrap_err();
        let text = err.to_string();
        assert!(
            text.contains("inline-frame cap") && text.contains("256"),
            "{text}"
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn manifest_file_roundtrip_with_large_seed() {
        let m = WorkerManifest {
            machine: 2,
            machines: 8,
            seed: u64::MAX - 1, // not representable as f64
            samples: 1000,
            burn_in: 0,
            thin: 3,
            prior_weight: 1.0 / 8.0,
            sampler: "hmc:1e-1,10".into(),
            shard_path: "/tmp/shard_2.json".into(),
            dim: 4,
            shard_inline: true,
            wire_format: WireFormat::Binary,
            draw_batch: 7,
            heartbeat_secs: 5,
        };
        let dir = std::env::temp_dir().join("repro_transport_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("worker_2.json");
        m.save(&path).unwrap();
        let back = WorkerManifest::load(&path).unwrap();
        assert_eq!(m, back);
        std::fs::remove_dir_all(&dir).ok();
    }

    /// Manifests written before the binary draw plane existed decode
    /// as the original wire: JSON, one draw per frame.
    #[test]
    fn manifest_wire_fields_backcompat() {
        let mut m = WorkerManifest {
            machine: 0,
            machines: 2,
            seed: 1,
            samples: 5,
            burn_in: 0,
            thin: 1,
            prior_weight: 0.5,
            sampler: "rwm:1".into(),
            shard_path: "/tmp/s.bin".into(),
            dim: 2,
            shard_inline: false,
            wire_format: WireFormat::Binary,
            draw_batch: 64,
            heartbeat_secs: 0,
        };
        let back = WorkerManifest::from_json(
            &Json::parse(&m.to_json().render()).unwrap(),
        )
        .unwrap();
        assert_eq!(m, back, "wire fields must survive the round-trip");
        // Strip the fields to simulate an old leader's manifest.
        let mut obj = match m.to_json() {
            Json::Obj(o) => o,
            _ => unreachable!(),
        };
        obj.remove("wire_format");
        obj.remove("draw_batch");
        let old = WorkerManifest::from_json(&Json::Obj(obj)).unwrap();
        m.wire_format = WireFormat::Json;
        m.draw_batch = 1;
        assert_eq!(m, old, "missing fields must decode as json wire");
    }

    #[test]
    fn wire_format_parses_tokens() {
        assert_eq!(WireFormat::parse("json").unwrap(), WireFormat::Json);
        assert_eq!(WireFormat::parse(" Binary ").unwrap(), WireFormat::Binary);
        assert_eq!(WireFormat::parse("bin").unwrap(), WireFormat::Binary);
        assert!(WireFormat::parse("msgpack").is_err());
        assert_eq!(WireFormat::default().name(), "json");
    }

    /// The binary chunk frame is bit-exact for every f64: NaN bit
    /// payloads, ±∞ and -0.0 all survive `encode_into` → `decode`
    /// untouched — the lossless-encoding half of the wire contract.
    #[test]
    fn chunk_roundtrip_is_bit_exact_including_nan_payloads() {
        let payload_nan = f64::from_bits(0x7ff8_dead_beef_cafe);
        let chunk = DrawChunk {
            machine: 3,
            dim: 2,
            thetas: vec![
                payload_nan,
                -0.0,
                f64::INFINITY,
                f64::NEG_INFINITY,
                1.0 / 3.0,
                1e-300,
            ],
            elapsed: vec![0.5, 1.5, 2.5],
            last: true,
        };
        let mut buf = Vec::new();
        chunk.encode_into(&mut buf);
        assert!(buf.starts_with(DRAW_MAGIC));
        let back = DrawChunk::decode(&buf).unwrap();
        assert_eq!(back.machine, 3);
        assert_eq!(back.dim, 2);
        assert_eq!(back.count(), 3);
        assert!(back.last);
        for (a, b) in chunk.thetas.iter().zip(&back.thetas) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        for (a, b) in chunk.elapsed.iter().zip(&back.elapsed) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    /// The JSON plane's one documented loss: every NaN decodes as the
    /// canonical quiet NaN, so NaN *bit payloads* are canonicalized
    /// (values — including ±∞ and NaN-ness itself — are preserved;
    /// see `draw_roundtrip_preserves_nonfinite_values`). This is the
    /// regression pin for the "binary is the only lossless encoding"
    /// contract.
    #[test]
    fn json_wire_canonicalizes_nan_payload_bits() {
        let payload_nan = f64::from_bits(0x7ff8_dead_beef_cafe);
        let msg = draw(0, vec![payload_nan], false);
        let decoded = match WireMsg::decode(&encode_draw(&msg)).unwrap() {
            WireMsg::Draw(d) => d,
            other => panic!("wrong variant {other:?}"),
        };
        assert!(decoded.theta[0].is_nan(), "NaN-ness survives");
        assert_ne!(
            decoded.theta[0].to_bits(),
            payload_nan.to_bits(),
            "JSON canonicalizes the NaN payload — documented-lossy"
        );
    }

    /// Chunk decode rejects structural corruption with parse errors,
    /// never panics or short reads.
    #[test]
    fn chunk_decode_rejects_corrupt_frames() {
        let chunk = DrawChunk {
            machine: 0,
            dim: 2,
            thetas: vec![1.0, 2.0],
            elapsed: vec![0.1],
            last: false,
        };
        let mut buf = Vec::new();
        chunk.encode_into(&mut buf);
        // Truncated body.
        assert!(DrawChunk::decode(&buf[..buf.len() - 1]).is_err());
        // Padded body.
        let mut padded = buf.clone();
        padded.push(0);
        assert!(DrawChunk::decode(&padded).is_err());
        // Unknown kind byte.
        let mut bad_kind = buf.clone();
        bad_kind[8] = 9;
        assert!(DrawChunk::decode(&bad_kind).is_err());
        // Bad last flag.
        let mut bad_last = buf.clone();
        bad_last[33] = 7;
        assert!(DrawChunk::decode(&bad_last).is_err());
        // Not a chunk at all.
        assert!(DrawChunk::decode(b"RPDRAW1\n").is_err());
    }

    /// `decode_frame` sniffs the magic per frame, so binary chunks and
    /// JSON control frames interleave on one stream.
    #[test]
    fn decode_frame_sniffs_magic_per_frame() {
        let mut stream: Vec<u8> = Vec::new();
        write_frame(&mut stream, &encode_draw(&draw(1, vec![0.5], false)))
            .unwrap();
        let chunk = DrawChunk {
            machine: 1,
            dim: 1,
            thetas: vec![1.5, 2.5],
            elapsed: vec![0.1, 0.2],
            last: false,
        };
        let mut payload = Vec::new();
        chunk.encode_into(&mut payload);
        write_frame_bytes(&mut stream, &payload).unwrap();
        write_frame(
            &mut stream,
            &encode_summary(&WorkerSummary {
                machine: 1,
                accept_rate: 0.25,
                wall_secs: 1.0,
            }),
        )
        .unwrap();
        let mut r = FrameReader::new(BufReader::new(stream.as_slice()));
        let mut buf = Vec::new();
        r.read_frame_into(&mut buf).unwrap().unwrap();
        assert!(matches!(
            WireMsg::decode_frame(&buf).unwrap(),
            WireMsg::Draw(_)
        ));
        r.read_frame_into(&mut buf).unwrap().unwrap();
        match WireMsg::decode_frame(&buf).unwrap() {
            WireMsg::Chunk(c) => assert_eq!(c, chunk),
            other => panic!("wrong variant {other:?}"),
        }
        r.read_frame_into(&mut buf).unwrap().unwrap();
        assert!(matches!(
            WireMsg::decode_frame(&buf).unwrap(),
            WireMsg::Summary(_)
        ));
        assert!(r.read_frame_into(&mut buf).unwrap().is_none());
    }

    /// Binary batching: 10 draws at batch 4 emit 4+4 draw chunks plus
    /// a 2-draw tail on flush, the concatenated payload reproduces
    /// the input order, and only the final chunk carries `last`.
    #[test]
    fn draw_encoder_batches_with_tail_flush() {
        let mut enc = DrawEncoder::new(WireFormat::Binary, 4, 2, 3);
        let mut frames: Vec<Vec<u8>> = Vec::new();
        let mut sink = |payload: &[u8]| {
            frames.push(payload.to_vec());
            Ok(())
        };
        for i in 0..10 {
            let msg = DrawMsg {
                machine: 2,
                theta: vec![i as f64, -(i as f64), 0.5 * i as f64],
                elapsed: i as f64,
                last: i == 9,
            };
            enc.push(&msg, &mut sink).unwrap();
        }
        assert_eq!(frames.len(), 2, "two full batches emitted eagerly");
        assert_eq!(enc.buffered(), 2);
        enc.flush(&mut sink).unwrap();
        assert_eq!(enc.buffered(), 0);
        enc.flush(&mut sink).unwrap(); // empty flush is a no-op
        assert_eq!(frames.len(), 3);
        let chunks: Vec<DrawChunk> = frames
            .iter()
            .map(|f| DrawChunk::decode(f).unwrap())
            .collect();
        assert_eq!(
            chunks.iter().map(DrawChunk::count).collect::<Vec<_>>(),
            vec![4, 4, 2]
        );
        assert_eq!(
            chunks.iter().map(|c| c.last).collect::<Vec<_>>(),
            vec![false, false, true]
        );
        let all: Vec<f64> =
            chunks.iter().flat_map(|c| c.thetas.clone()).collect();
        for (i, row) in all.chunks_exact(3).enumerate() {
            assert_eq!(row, &[i as f64, -(i as f64), 0.5 * i as f64]);
        }
        let times: Vec<f64> =
            chunks.iter().flat_map(|c| c.elapsed.clone()).collect();
        assert_eq!(times, (0..10).map(|i| i as f64).collect::<Vec<_>>());
    }

    /// JSON mode ignores the batch knob and emits the legacy per-draw
    /// frames byte-for-byte — a JSON-mode run's wire is identical to
    /// the pre-batching protocol.
    #[test]
    fn draw_encoder_json_mode_is_wire_identical_to_legacy() {
        let mut enc = DrawEncoder::new(WireFormat::Json, 64, 0, 2);
        let mut frames: Vec<Vec<u8>> = Vec::new();
        let mut sink = |payload: &[u8]| {
            frames.push(payload.to_vec());
            Ok(())
        };
        let msgs: Vec<DrawMsg> = (0..3)
            .map(|i| DrawMsg {
                machine: 0,
                theta: vec![i as f64, 0.25],
                elapsed: 0.125,
                last: i == 2,
            })
            .collect();
        for m in &msgs {
            enc.push(m, &mut sink).unwrap();
        }
        enc.flush(&mut sink).unwrap();
        assert_eq!(frames.len(), 3, "one frame per draw, flush adds none");
        for (m, f) in msgs.iter().zip(&frames) {
            assert_eq!(f.as_slice(), encode_draw(m).as_bytes());
        }
    }

    /// The hot-loop allocation contract: after the first full flush
    /// the encoder's scratch and accumulation buffers stop growing —
    /// pushing more draws reuses the same allocations.
    #[test]
    fn draw_encoder_reuses_scratch_across_flushes() {
        let mut enc = DrawEncoder::new(WireFormat::Binary, 8, 0, 4);
        let mut sink = |_: &[u8]| Ok(());
        let mut scratch_cap = 0usize;
        for round in 0..6 {
            for i in 0..8 {
                let msg = DrawMsg {
                    machine: 0,
                    theta: vec![i as f64; 4],
                    elapsed: i as f64,
                    last: false,
                };
                enc.push(&msg, &mut sink).unwrap();
            }
            assert_eq!(enc.buffered(), 0, "full batch flushes eagerly");
            if round == 0 {
                scratch_cap = enc.scratch_capacity();
                assert!(scratch_cap > 0, "first flush sized the scratch");
            } else {
                assert_eq!(
                    enc.scratch_capacity(),
                    scratch_cap,
                    "steady-state flushes must not reallocate the \
                     scratch buffer"
                );
            }
        }
    }

    /// `read_frame_into` reuses the caller's buffer: after the largest
    /// frame has been seen, smaller and equal frames do not grow it.
    #[test]
    fn read_frame_into_reuses_buffer() {
        let mut stream: Vec<u8> = Vec::new();
        write_frame_bytes(&mut stream, &vec![7u8; 512]).unwrap();
        write_frame_bytes(&mut stream, &vec![8u8; 32]).unwrap();
        write_frame_bytes(&mut stream, &vec![9u8; 512]).unwrap();
        let mut r = FrameReader::new(BufReader::new(stream.as_slice()));
        let mut buf = Vec::new();
        assert_eq!(r.read_frame_into(&mut buf).unwrap(), Some(512));
        assert_eq!(buf, vec![7u8; 512]);
        let cap = buf.capacity();
        assert_eq!(r.read_frame_into(&mut buf).unwrap(), Some(32));
        assert_eq!(buf, vec![8u8; 32]);
        assert_eq!(r.read_frame_into(&mut buf).unwrap(), Some(512));
        assert_eq!(buf, vec![9u8; 512]);
        assert_eq!(
            buf.capacity(),
            cap,
            "equal-sized frames must reuse the allocation"
        );
        assert!(r.read_frame_into(&mut buf).unwrap().is_none());
    }

    /// The RPHB beacon is a JSON control frame: it round-trips through
    /// both decode paths and never collides with the draw plane.
    #[test]
    fn heartbeat_frame_roundtrips() {
        let payload = encode_heartbeat(6);
        match WireMsg::decode(&payload).unwrap() {
            WireMsg::Heartbeat { machine } => assert_eq!(machine, 6),
            other => panic!("wrong variant {other:?}"),
        }
        match WireMsg::decode_frame(payload.as_bytes()).unwrap() {
            WireMsg::Heartbeat { machine } => assert_eq!(machine, 6),
            other => panic!("wrong variant {other:?}"),
        }
    }

    /// Manifests written before heartbeats existed decode with the
    /// beacon disabled — old leaders and daemons interoperate.
    #[test]
    fn manifest_heartbeat_field_backcompat() {
        let mut m = WorkerManifest {
            machine: 0,
            machines: 2,
            seed: 1,
            samples: 5,
            burn_in: 0,
            thin: 1,
            prior_weight: 0.5,
            sampler: "rwm:1".into(),
            shard_path: "/tmp/s.bin".into(),
            dim: 2,
            shard_inline: false,
            wire_format: WireFormat::Json,
            draw_batch: 1,
            heartbeat_secs: 3,
        };
        let back = WorkerManifest::from_json(
            &Json::parse(&m.to_json().render()).unwrap(),
        )
        .unwrap();
        assert_eq!(m, back, "heartbeat_secs must survive the round-trip");
        let mut obj = match m.to_json() {
            Json::Obj(o) => o,
            _ => unreachable!(),
        };
        obj.remove("heartbeat_secs");
        let old = WorkerManifest::from_json(&Json::Obj(obj)).unwrap();
        m.heartbeat_secs = 0;
        assert_eq!(m, old, "missing field must decode as beacon-off");
    }

    #[test]
    fn fault_spec_parses_tokens() {
        assert_eq!(
            FaultSpec::parse("refuse-dial").unwrap(),
            FaultSpec::RefuseDial
        );
        assert_eq!(
            FaultSpec::parse(" drop-after:3 ").unwrap(),
            FaultSpec::DropAfterFrames(3)
        );
        assert_eq!(
            FaultSpec::parse("delay-ms:250").unwrap(),
            FaultSpec::DelayMillis(250)
        );
        assert_eq!(
            FaultSpec::parse("corrupt:0").unwrap(),
            FaultSpec::CorruptFrame(0)
        );
        assert!(FaultSpec::parse("drop-after:x").is_err());
        assert!(FaultSpec::parse("flood").is_err());
        assert!(FaultSpec::parse("jitter:5").is_err());
    }

    /// Scripted transport for fault-injector unit tests: every connect
    /// on a slot replays the same message sequence.
    struct ReplayTransport {
        script: Vec<WireMsg>,
    }

    struct ReplayConnection {
        msgs: std::collections::VecDeque<WireMsg>,
    }

    impl WorkerConnection for ReplayConnection {
        fn recv(&mut self) -> Result<Option<WireMsg>> {
            Ok(self.msgs.pop_front())
        }
        fn finish(&mut self) -> Result<()> {
            Ok(())
        }
    }

    impl Transport for ReplayTransport {
        fn name(&self) -> &'static str {
            "replay"
        }
        fn slots(&self) -> usize {
            2
        }
        fn connect(
            &self,
            _slot: usize,
            _manifest: &WorkerManifest,
            _manifest_path: &Path,
        ) -> Result<Box<dyn WorkerConnection>> {
            Ok(Box::new(ReplayConnection {
                msgs: self.script.iter().cloned().collect(),
            }))
        }
    }

    fn replay_script() -> Vec<WireMsg> {
        vec![
            WireMsg::Draw(draw(0, vec![1.0], false)),
            WireMsg::Draw(draw(0, vec![2.0], false)),
            WireMsg::Summary(WorkerSummary {
                machine: 0,
                accept_rate: 0.5,
                wall_secs: 0.25,
            }),
        ]
    }

    /// The injector is deterministic and slot-scoped: the faulted slot
    /// misbehaves exactly as specified while the clean slot passes the
    /// whole script through untouched.
    #[test]
    fn fault_injector_is_deterministic_and_slot_scoped() {
        let wm = WorkerManifest {
            machine: 0,
            machines: 1,
            seed: 1,
            samples: 2,
            burn_in: 0,
            thin: 1,
            prior_weight: 1.0,
            sampler: "rwm:1".into(),
            shard_path: "/tmp/none".into(),
            dim: 1,
            shard_inline: false,
            wire_format: WireFormat::Json,
            draw_batch: 1,
            heartbeat_secs: 0,
        };
        let p = Path::new("/tmp/none.json");

        // drop-after:1 — one frame crosses, then the connection dies.
        let t = FaultInjector::new(ReplayTransport {
            script: replay_script(),
        })
        .with_fault(0, FaultSpec::DropAfterFrames(1));
        let mut conn = t.connect(0, &wm, p).unwrap();
        assert!(conn.recv().unwrap().is_some());
        let err = conn.recv().unwrap_err();
        assert!(
            err.to_string().contains("dropped after 1 frames"),
            "{err}"
        );
        // The clean slot replays everything.
        let mut clean = t.connect(1, &wm, p).unwrap();
        let mut n = 0;
        while clean.recv().unwrap().is_some() {
            n += 1;
        }
        assert_eq!(n, 3, "unfaulted slot must pass the script through");

        // corrupt:1 — frame 0 decodes, frame 1 is a parse error, and
        // the stream recovers afterwards (the frame was consumed).
        let t = FaultInjector::new(ReplayTransport {
            script: replay_script(),
        })
        .with_fault(0, FaultSpec::CorruptFrame(1));
        let mut conn = t.connect(0, &wm, p).unwrap();
        assert!(conn.recv().unwrap().is_some());
        let err = conn.recv().unwrap_err();
        assert!(matches!(err, Error::Parse(_)), "{err:?}");

        // refuse-dial — connect itself fails, naming slot and machine.
        let t = FaultInjector::new(ReplayTransport {
            script: replay_script(),
        })
        .with_fault(0, FaultSpec::RefuseDial);
        let err = t.connect(0, &wm, p).unwrap_err();
        assert!(err.to_string().contains("refused the dial"), "{err}");

        // delay-ms — frames still arrive, just later.
        let t = FaultInjector::new(ReplayTransport {
            script: replay_script(),
        })
        .with_fault(0, FaultSpec::DelayMillis(1));
        let mut conn = t.connect(0, &wm, p).unwrap();
        let mut n = 0;
        while conn.recv().unwrap().is_some() {
            n += 1;
        }
        assert_eq!(n, 3, "a slow link loses nothing");
    }
}
