//! Worker-daemon side of the socket transport, plus the single copy of
//! manifest execution shared by every worker entrypoint.
//!
//! `repro serve --listen <addr>` runs [`serve`]: bind a TCP listener,
//! announce the bound address (`LISTENING <addr>` on stdout — the
//! leader-side tooling and tests parse this to support `:0` ephemeral
//! ports), then accept one connection at a time. Each connection is one
//! job: the first inbound frame is a [`WorkerManifest`] — followed, when
//! the manifest says `shard_inline`, by one binary frame carrying the
//! shard's spilled bytes (format autodetected, so daemons need no
//! shared filesystem) — and the outbound stream is the exact frame
//! sequence a pipe-mode worker writes on stdout (every draw, then one
//! summary), after which the daemon closes the connection — the
//! clean-EOF success signal the leader's
//! [`SocketTransport`](crate::coordinator::transport::SocketTransport)
//! expects. Job failures are reported in-band as `error` frames since a
//! remote daemon has no stderr the leader could collect. The daemon is
//! leader-driver-agnostic: whether the leader runs thread-per-endpoint
//! or the `poll(2)` reactor (`--io-driver reactor`,
//! [`crate::coordinator::reactor`]), the wire contract here — manifest
//! in, frames out, clean EOF — is unchanged; the reactor only reads
//! the same stream nonblockingly.
//!
//! [`run_manifest`] is the shared execution path: the pipe-mode
//! `worker` CLI subcommand drives it with a stdout sink, [`serve`] with
//! a socket sink. Both therefore derive the worker RNG stream the same
//! way (`root.split(m)`), load shards through the same format
//! autodetection, and emit bit-identical frames — which is what keeps
//! socket ≡ process ≡ thread draws byte-for-byte.

use std::cell::{Cell, RefCell};
use std::io::{BufReader, BufWriter, Write};
use std::net::{Shutdown, TcpListener, TcpStream};
use std::path::Path;
use std::time::{Duration, Instant};

use crate::config;
use crate::coordinator::transport::{
    encode_error, encode_heartbeat, encode_summary, write_frame,
    write_frame_bytes, DrawEncoder, FaultSpec, FrameReader,
    WorkerManifest, WorkerSummary, DEFAULT_MAX_FRAME_BYTES,
};
use crate::coordinator::worker::{run_worker_with_ticks, DrawMsg};
use crate::data::{io, Dataset};
use crate::error::{Error, Result};
use crate::rng::Pcg64;
use crate::runtime::json::Json;

/// Execute one worker manifest end-to-end: load the shard (JSON or
/// binary, autodetected), build the subposterior target, derive the
/// `root.split(m)` RNG stream, sample, and push every frame payload
/// (draws — encoded per the manifest's `wire_format`/`draw_batch`
/// through a [`DrawEncoder`] — then the final JSON summary) through
/// `sink` as raw bytes.
///
/// A sink failure mid-run aborts the chain immediately — with the peer
/// gone, the remaining iterations are dead compute, and a daemon stuck
/// finishing an orphaned job could not serve its next connection — and
/// the job returns an error instead of a summary. Sinks that prefer to
/// exit the whole process (the pipe-mode worker, whose only purpose is
/// its stdout stream) can do so from inside the sink instead.
pub fn run_manifest<F>(wm: &WorkerManifest, sink: &mut F) -> Result<()>
where
    F: FnMut(&[u8]) -> std::io::Result<()>,
{
    let data = io::read_shard(Path::new(&wm.shard_path))?;
    run_manifest_with_data(wm, &data, sink)
}

/// [`run_manifest`] over an already-decoded shard — the inline-shard
/// path: socket daemons receive the shard bytes as the frame after the
/// manifest ([`io::shard_from_bytes`]) and never touch `shard_path`.
/// Everything downstream of the shard load is this single copy, so
/// inline and path delivery produce bit-identical frame streams.
pub fn run_manifest_with_data<F>(
    wm: &WorkerManifest,
    data: &Dataset,
    sink: &mut F,
) -> Result<()>
where
    F: FnMut(&[u8]) -> std::io::Result<()>,
{
    let hb = if wm.heartbeat_secs > 0 {
        Some(Duration::from_secs(wm.heartbeat_secs as u64))
    } else {
        None
    };
    run_manifest_with_data_at(wm, data, sink, hb)
}

/// [`run_manifest_with_data`] with an explicit heartbeat interval —
/// the manifest's `heartbeat_secs` resolved to a `Duration` (tests use
/// `Duration::ZERO` to force a beacon on every tick without waiting
/// wall-clock seconds).
fn run_manifest_with_data_at<F>(
    wm: &WorkerManifest,
    data: &Dataset,
    sink: &mut F,
    heartbeat: Option<Duration>,
) -> Result<()>
where
    F: FnMut(&[u8]) -> std::io::Result<()>,
{
    if wm.machine >= wm.machines {
        return Err(Error::Config(format!(
            "machine {} out of range ({} machines)",
            wm.machine, wm.machines
        )));
    }
    let idx: Vec<usize> = (0..data.len()).collect();
    let target = data.subposterior(&idx, wm.prior_weight)?;
    if target.dim() != wm.dim {
        return Err(Error::Config(format!(
            "shard dim {} != manifest dim {}",
            target.dim(),
            wm.dim
        )));
    }

    // Same stream derivation as the in-thread path: split 0..machines
    // off the root generator sequentially, keep stream m.
    let mut root = Pcg64::seed_from(wm.seed);
    let rng = root.split_n(wm.machines).swap_remove(wm.machine);
    let sampler =
        config::parse_sampler(&wm.sampler)?.build(target.dim());

    // The draw plane goes through one encoder with reused buffers:
    // JSON mode emits the legacy per-draw frames, binary mode batches
    // `draw_batch` draws per chunk frame — either way this is the only
    // place draws are serialized, so pipe and socket workers stay
    // frame-identical.
    let enc = DrawEncoder::new(
        wm.wire_format,
        wm.draw_batch,
        wm.machine,
        target.dim(),
    );
    // The emit and tick callbacks both need the encoder and sink (the
    // tick writes RPHB beacon frames on the same stream), so they
    // share them through a RefCell; emit and tick never nest, so the
    // borrows never overlap.
    let state = RefCell::new((enc, sink));
    let broken = Cell::new(false);
    // Beacon clock: any frame (draw chunk or beacon) counts as
    // traffic, so heartbeats only fill genuine silence — notably the
    // frame-free burn-in stretch.
    let last_frame = Cell::new(Instant::now());
    let result = run_worker_with_ticks(
        wm.machine,
        target.as_ref(),
        sampler,
        wm.samples,
        wm.burn_in,
        wm.thin,
        rng,
        &mut |msg: &DrawMsg| {
            let mut guard = state.borrow_mut();
            let (enc, sink) = &mut *guard;
            let pushed = enc.push(msg, &mut |frame: &[u8]| {
                last_frame.set(Instant::now());
                sink(frame)
            });
            if pushed.is_err() {
                broken.set(true);
            }
            !broken.get()
        },
        &mut || {
            let Some(interval) = heartbeat else { return true };
            if broken.get() {
                return false;
            }
            if last_frame.get().elapsed() >= interval {
                let mut guard = state.borrow_mut();
                let (_, sink) = &mut *guard;
                last_frame.set(Instant::now());
                if sink(encode_heartbeat(wm.machine).as_bytes()).is_err()
                {
                    // The peer is gone: the rest of the chain is dead
                    // compute, exactly like a failed draw write.
                    broken.set(true);
                }
            }
            !broken.get()
        },
    );
    let (mut enc, sink) = state.into_inner();
    if broken.get() || enc.flush(sink).is_err() {
        return Err(Error::Runtime(format!(
            "worker {}: draw stream closed mid-run",
            wm.machine
        )));
    }
    sink(
        encode_summary(&WorkerSummary {
            machine: wm.machine,
            accept_rate: result.accept_rate,
            wall_secs: result.wall_secs,
        })
        .as_bytes(),
    )?;
    Ok(())
}

/// Options for [`serve`].
#[derive(Debug, Clone)]
pub struct ServeOptions {
    /// Exit after this many jobs (`None` = serve until killed). Lets
    /// tests and CI smoke runs shut daemons down deterministically.
    pub max_jobs: Option<usize>,
    /// Frame cap for inbound manifest frames.
    pub max_frame_bytes: usize,
    /// How long a freshly accepted connection may take to deliver its
    /// manifest frame (`--manifest-timeout-secs`). The daemon serves
    /// one connection at a time, so without this bound a single idle
    /// connection (port scanner, health check, half-open leader) would
    /// wedge the accept loop forever; a timed-out connection is
    /// dropped and the daemon moves on. A real leader sends the
    /// manifest immediately after connecting — even when its
    /// connection waited in the accept backlog, the frame is already
    /// buffered by the time the daemon reads — so the 30 s default is
    /// generous.
    pub manifest_timeout: Duration,
    /// Deterministic chaos hook (`--fault <spec>`): apply this
    /// [`FaultSpec`] to every job — CI's way of standing up a
    /// misbehaving endpoint without OS-level packet tricks.
    pub fault: Option<FaultSpec>,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions {
            max_jobs: None,
            max_frame_bytes: DEFAULT_MAX_FRAME_BYTES,
            manifest_timeout: DEFAULT_MANIFEST_TIMEOUT,
            fault: None,
        }
    }
}

/// Run the worker daemon: bind `addr`, announce `LISTENING <addr>` on
/// `announce`, then serve jobs one connection at a time. A failed job
/// is reported to that job's leader in-band (and to the daemon's
/// stderr); the daemon itself stays up for the next connection.
pub fn serve(
    addr: &str,
    opts: &ServeOptions,
    announce: &mut dyn Write,
) -> Result<()> {
    let listener = TcpListener::bind(addr).map_err(|e| {
        Error::Runtime(format!("binding worker daemon to {addr}: {e}"))
    })?;
    let local = listener.local_addr().map_err(Error::Io)?;
    writeln!(announce, "LISTENING {local}")?;
    announce.flush()?;
    let mut served = 0usize;
    for conn in listener.incoming() {
        let stream = match conn {
            Ok(s) => s,
            Err(e) => {
                eprintln!("serve: accept: {e}");
                continue;
            }
        };
        let peer = stream
            .peer_addr()
            .map(|a| a.to_string())
            .unwrap_or_else(|_| "<unknown>".into());
        if opts.fault == Some(FaultSpec::RefuseDial) {
            // Chaos hook: hang up before reading the manifest — what a
            // crashed-but-still-bound or firewalled endpoint looks
            // like to the leader.
            eprintln!("serve: fault: refusing connection from {peer}");
            stream.shutdown(Shutdown::Both).ok();
            served += 1;
            if opts.max_jobs.is_some_and(|cap| served >= cap) {
                break;
            }
            continue;
        }
        if let Err(e) = handle_conn(stream, opts) {
            eprintln!("serve: job from {peer} failed: {e}");
        }
        served += 1;
        if opts.max_jobs.is_some_and(|cap| served >= cap) {
            break;
        }
    }
    Ok(())
}

/// Default bound on the manifest read — see
/// [`ServeOptions::manifest_timeout`]. Public because the leader
/// daemon (`repro leaderd`) reuses it as the default bound on a
/// client's submit frame: both daemons face the same
/// idle-connection-wedges-the-loop hazard on their first inbound
/// frame.
pub const DEFAULT_MANIFEST_TIMEOUT: Duration = Duration::from_secs(30);

/// One job: read the manifest frame, stream the run back, close.
fn handle_conn(stream: TcpStream, opts: &ServeOptions) -> Result<()> {
    stream.set_nodelay(true).ok();
    // Only the inbound frames (manifest, plus the optional inline
    // shard frame, both sent immediately by a real leader) are
    // bounded: after them, the daemon only writes, so no further read
    // can block the loop. A failure to arm the bound would silently
    // reopen the wedged-accept-loop hole, so it fails the job (logged
    // by the accept loop; the daemon stays up) instead of being
    // swallowed.
    stream
        .set_read_timeout(Some(opts.manifest_timeout))
        .map_err(|e| {
            Error::Runtime(format!(
                "arming the {:?} manifest read timeout: {e}",
                opts.manifest_timeout
            ))
        })?;
    let reader = stream.try_clone().map_err(Error::Io)?;
    let mut frames = FrameReader::with_max_frame(
        BufReader::new(reader),
        opts.max_frame_bytes,
    );
    let payload = frames.read_frame()?.ok_or_else(|| {
        Error::Runtime("connection closed before a manifest frame".into())
    })?;
    let wm = WorkerManifest::from_json(&Json::parse(&payload)?)?;
    let mut out = BufWriter::new(stream.try_clone().map_err(Error::Io)?);
    // Chaos hooks on the outbound stream: count frames, and misbehave
    // exactly as the armed `--fault` spec says. `fault_stream` is a
    // raw clone so DropAfterFrames can hard-kill the socket (FIN
    // mid-stream) rather than politely erroring in-band.
    let fault = opts.fault;
    let fault_stream = stream.try_clone().map_err(Error::Io)?;
    let mut frames_out = 0usize;
    let mut sink = |frame: &[u8]| -> std::io::Result<()> {
        match fault {
            Some(FaultSpec::DropAfterFrames(n)) if frames_out >= n => {
                fault_stream.shutdown(Shutdown::Both).ok();
                return Err(std::io::Error::new(
                    std::io::ErrorKind::BrokenPipe,
                    format!("fault: connection dropped after {n} frames"),
                ));
            }
            Some(FaultSpec::DelayMillis(ms)) => {
                std::thread::sleep(Duration::from_millis(ms));
            }
            _ => {}
        }
        if let Some(FaultSpec::CorruptFrame(n)) = fault {
            if frames_out == n {
                frames_out += 1;
                let mut bad = frame.to_vec();
                // Flip the first byte: a chunk loses its magic, JSON
                // loses its brace — either way the leader's decode
                // fails structurally instead of yielding wrong draws.
                if let Some(b) = bad.first_mut() {
                    *b ^= 0xFF;
                }
                return write_frame_bytes(&mut out, &bad);
            }
        }
        frames_out += 1;
        write_frame_bytes(&mut out, frame)
    };
    let run = if wm.shard_inline {
        // Inline delivery: the next frame is the shard's spilled bytes
        // (format autodetected, exactly as a file read would) — the
        // daemon's filesystem is never involved.
        match frames.read_frame_bytes() {
            Ok(Some(bytes)) => match io::shard_from_bytes(&bytes) {
                Ok(data) => run_manifest_with_data(&wm, &data, &mut sink),
                Err(e) => Err(e),
            },
            Ok(None) => Err(Error::Runtime(
                "connection closed before the inline shard frame".into(),
            )),
            Err(e) => Err(e),
        }
    } else {
        run_manifest(&wm, &mut sink)
    };
    if let Err(e) = &run {
        // Best-effort in-band failure report; if the leader is already
        // gone this write fails too, which is fine.
        let _ = write_frame(&mut out, &encode_error(wm.machine, &e.to_string()));
    }
    out.flush().ok();
    // Half-close is enough for the leader to see EOF, but shutting both
    // directions also unblocks a leader mid-write.
    stream.shutdown(Shutdown::Both).ok();
    run
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::transport::{WireFormat, WireMsg};
    use crate::data::synth;

    /// One bound for every blocking wait in this module — a daemon
    /// that takes longer than this to announce is already wedged.
    const RECV_TIMEOUT: Duration = Duration::from_secs(30);

    fn spill_manifest(
        dir: &Path,
        machine: usize,
        machines: usize,
        format: io::ShardFormat,
    ) -> WorkerManifest {
        let data = synth::gaussian(300, 2, 11);
        let idx: Vec<usize> = (machine * 100..(machine + 1) * 100).collect();
        let shard = data.select(&idx).unwrap();
        let shard_path = dir.join(format!("shard_{machine}.dat"));
        io::write_shard(&shard_path, &shard, format).unwrap();
        WorkerManifest {
            machine,
            machines,
            seed: 9,
            samples: 25,
            burn_in: 5,
            thin: 1,
            prior_weight: 1.0 / machines as f64,
            sampler: "rwm:1e0".into(),
            shard_path: shard_path.to_string_lossy().into_owned(),
            dim: 2,
            shard_inline: false,
            wire_format: WireFormat::Json,
            draw_batch: 1,
            heartbeat_secs: 0,
        }
    }

    /// The frame sequence out of `run_manifest` is the wire contract:
    /// exactly `samples` draw frames, then one summary frame, all
    /// decodable, all for the right machine — and identical whether the
    /// shard was spilled as JSON or binary.
    #[test]
    fn run_manifest_emits_draws_then_summary_for_both_formats() {
        let dir = std::env::temp_dir().join("repro_serve_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        let mut streams: Vec<Vec<String>> = Vec::new();
        for format in [io::ShardFormat::Json, io::ShardFormat::Binary] {
            let wm = spill_manifest(&dir, 1, 3, format);
            let mut frames: Vec<Vec<u8>> = Vec::new();
            run_manifest(&wm, &mut |frame: &[u8]| {
                frames.push(frame.to_vec());
                Ok(())
            })
            .unwrap();
            assert_eq!(frames.len(), 26);
            for f in &frames[..25] {
                match WireMsg::decode_frame(f).unwrap() {
                    WireMsg::Draw(d) => {
                        assert_eq!(d.machine, 1);
                        assert_eq!(d.theta.len(), 2);
                    }
                    other => panic!("wrong variant {other:?}"),
                }
            }
            match WireMsg::decode_frame(&frames[25]).unwrap() {
                WireMsg::Summary(s) => assert_eq!(s.machine, 1),
                other => panic!("wrong variant {other:?}"),
            }
            // Draw timings differ run to run; the draw payloads must
            // not depend on the spill format.
            let thetas: Vec<String> = frames[..25]
                .iter()
                .map(|f| match WireMsg::decode_frame(f).unwrap() {
                    WireMsg::Draw(d) => format!("{:?}", d.theta),
                    _ => unreachable!(),
                })
                .collect();
            streams.push(thetas);
        }
        assert_eq!(
            streams[0], streams[1],
            "draws diverged between JSON and binary shard spills"
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    /// The binary wire carries the same draws as the JSON wire,
    /// bit-exactly, batched `draw_batch` per chunk frame with `last`
    /// only on the final chunk — 25 draws at batch 7 is 4 chunk frames
    /// (7+7+7+4) plus the JSON summary.
    #[test]
    fn run_manifest_binary_wire_matches_json_wire_bit_exactly() {
        use crate::coordinator::transport::DrawChunk;
        let dir = std::env::temp_dir().join("repro_serve_binwire_test");
        std::fs::create_dir_all(&dir).unwrap();
        let wm_json = spill_manifest(&dir, 1, 3, io::ShardFormat::Binary);
        let mut json_thetas: Vec<u64> = Vec::new();
        run_manifest(&wm_json, &mut |frame: &[u8]| {
            if let WireMsg::Draw(d) = WireMsg::decode_frame(frame).unwrap()
            {
                json_thetas.extend(d.theta.iter().map(|v| v.to_bits()));
            }
            Ok(())
        })
        .unwrap();
        assert_eq!(json_thetas.len(), 25 * 2);

        let mut wm_bin = wm_json.clone();
        wm_bin.wire_format = WireFormat::Binary;
        wm_bin.draw_batch = 7;
        let mut frames: Vec<Vec<u8>> = Vec::new();
        run_manifest(&wm_bin, &mut |frame: &[u8]| {
            frames.push(frame.to_vec());
            Ok(())
        })
        .unwrap();
        assert_eq!(frames.len(), 5, "4 chunk frames + 1 summary");
        let chunks: Vec<DrawChunk> = frames[..4]
            .iter()
            .map(|f| match WireMsg::decode_frame(f).unwrap() {
                WireMsg::Chunk(c) => c,
                other => panic!("wrong variant {other:?}"),
            })
            .collect();
        assert_eq!(
            chunks.iter().map(DrawChunk::count).collect::<Vec<_>>(),
            vec![7, 7, 7, 4]
        );
        assert_eq!(
            chunks.iter().map(|c| c.last).collect::<Vec<_>>(),
            vec![false, false, false, true]
        );
        for c in &chunks {
            assert_eq!(c.machine, 1);
            assert_eq!(c.dim, 2);
            assert_eq!(c.elapsed.len(), c.count());
        }
        let bin_thetas: Vec<u64> = chunks
            .iter()
            .flat_map(|c| c.thetas.iter().map(|v| v.to_bits()))
            .collect();
        assert_eq!(
            bin_thetas, json_thetas,
            "binary wire must carry bit-identical draws"
        );
        assert!(matches!(
            WireMsg::decode_frame(&frames[4]).unwrap(),
            WireMsg::Summary(_)
        ));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn run_manifest_rejects_bad_machine_and_missing_shard() {
        let dir = std::env::temp_dir().join("repro_serve_badjob_test");
        std::fs::create_dir_all(&dir).unwrap();
        let mut wm = spill_manifest(&dir, 0, 2, io::ShardFormat::Json);
        wm.machine = 5; // out of range
        let err = run_manifest(&wm, &mut |_f: &[u8]| Ok(())).unwrap_err();
        assert!(err.to_string().contains("out of range"), "{err}");
        let mut wm = spill_manifest(&dir, 0, 2, io::ShardFormat::Json);
        wm.shard_path = dir.join("nope.json").to_string_lossy().into_owned();
        assert!(run_manifest(&wm, &mut |_f: &[u8]| Ok(())).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    /// A sink that dies mid-stream turns into a job error, not a
    /// summary — the leader must never see a summary for a stream it
    /// did not fully receive — and the chain aborts right there rather
    /// than burning the remaining iterations as dead compute.
    #[test]
    fn run_manifest_aborts_on_broken_sink() {
        let dir = std::env::temp_dir().join("repro_serve_broken_sink_test");
        std::fs::create_dir_all(&dir).unwrap();
        let wm = spill_manifest(&dir, 0, 2, io::ShardFormat::Binary);
        let mut wrote = 0usize;
        let err = run_manifest(&wm, &mut |_f: &[u8]| {
            wrote += 1;
            if wrote > 3 {
                Err(std::io::Error::new(
                    std::io::ErrorKind::BrokenPipe,
                    "peer gone",
                ))
            } else {
                Ok(())
            }
        })
        .unwrap_err();
        assert!(err.to_string().contains("stream closed"), "{err}");
        assert_eq!(
            wrote, 4,
            "chain must abort at the first failed write (3 ok + 1 failed), \
             not keep sampling the remaining draws"
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    /// Captures the daemon's `LISTENING <addr>` announce line (which
    /// `writeln!` may deliver across several `write` calls) and hands
    /// the bound address to the test thread once it is complete.
    struct Announcer {
        buf: Vec<u8>,
        tx: std::sync::mpsc::Sender<String>,
        sent: bool,
    }

    impl Announcer {
        fn channel() -> (Announcer, std::sync::mpsc::Receiver<String>) {
            let (tx, rx) = std::sync::mpsc::channel();
            (Announcer { buf: Vec::new(), tx, sent: false }, rx)
        }
    }

    impl Write for Announcer {
        fn write(&mut self, b: &[u8]) -> std::io::Result<usize> {
            self.buf.extend_from_slice(b);
            if !self.sent {
                if let Some(pos) = self.buf.iter().position(|&c| c == b'\n')
                {
                    let line = String::from_utf8_lossy(&self.buf[..pos]);
                    if let Some(rest) = line.trim().strip_prefix("LISTENING")
                    {
                        let _ = self.tx.send(rest.trim().to_string());
                        self.sent = true;
                    }
                }
            }
            Ok(b.len())
        }

        fn flush(&mut self) -> std::io::Result<()> {
            Ok(())
        }
    }

    /// End-to-end over a real localhost socket: a daemon thread serving
    /// one job, a client sending a manifest frame and draining frames.
    #[test]
    fn serve_runs_one_job_over_tcp() {
        let dir = std::env::temp_dir().join("repro_serve_tcp_test");
        std::fs::create_dir_all(&dir).unwrap();
        let wm = spill_manifest(&dir, 0, 2, io::ShardFormat::Binary);

        let opts = ServeOptions { max_jobs: Some(1), ..Default::default() };
        let (mut announcer, addr_rx) = Announcer::channel();
        let daemon = std::thread::spawn(move || {
            serve("127.0.0.1:0", &opts, &mut announcer).unwrap();
        });
        let addr = addr_rx
            .recv_timeout(RECV_TIMEOUT)
            .expect("daemon never announced its address");

        let stream = TcpStream::connect(&addr).unwrap();
        let mut writer = stream.try_clone().unwrap();
        write_frame(&mut writer, &wm.to_json().render()).unwrap();
        let mut frames = FrameReader::new(BufReader::new(stream));
        let mut draws = 0usize;
        let mut summaries = 0usize;
        while let Some(payload) = frames.read_frame().unwrap() {
            match WireMsg::decode(&payload).unwrap() {
                WireMsg::Draw(d) => {
                    assert_eq!(d.machine, 0);
                    draws += 1;
                }
                WireMsg::Chunk(_) => {
                    panic!("unexpected chunk on the JSON wire")
                }
                WireMsg::Summary(s) => {
                    assert_eq!(s.machine, 0);
                    summaries += 1;
                }
                WireMsg::Error { message, .. } => {
                    panic!("unexpected remote failure: {message}")
                }
                WireMsg::Heartbeat { .. } => {
                    panic!("heartbeats must be off when heartbeat_secs=0")
                }
            }
        }
        assert_eq!(draws, 25);
        assert_eq!(summaries, 1);
        daemon.join().unwrap();
        std::fs::remove_dir_all(&dir).ok();
    }

    /// Inline shard delivery over a real TCP connection: the manifest
    /// points `shard_path` at a file that does **not exist on the
    /// daemon's filesystem**, the shard bytes ride the connection as
    /// the frame after the manifest, and the job still streams the
    /// full draw+summary sequence — proof the shared-filesystem
    /// requirement is gone. The draws must be identical to a path-mode
    /// job over the same shard.
    #[test]
    fn serve_runs_inline_shard_job_without_touching_the_filesystem() {
        use crate::coordinator::transport::write_frame_bytes;
        let dir = std::env::temp_dir().join("repro_serve_inline_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path_wm = spill_manifest(&dir, 0, 2, io::ShardFormat::Binary);
        let shard_bytes = std::fs::read(&path_wm.shard_path).unwrap();
        // Path-mode reference stream (thetas only; timings vary).
        let mut reference: Vec<Vec<u8>> = Vec::new();
        run_manifest(&path_wm, &mut |frame: &[u8]| {
            reference.push(frame.to_vec());
            Ok(())
        })
        .unwrap();
        let ref_thetas: Vec<String> = reference
            .iter()
            .filter_map(|f| match WireMsg::decode_frame(f).unwrap() {
                WireMsg::Draw(d) => Some(format!("{:?}", d.theta)),
                _ => None,
            })
            .collect();

        let mut wm = path_wm.clone();
        wm.shard_inline = true;
        wm.shard_path =
            dir.join("not-on-this-host.bin").to_string_lossy().into_owned();

        let opts = ServeOptions { max_jobs: Some(1), ..Default::default() };
        let (mut announcer, addr_rx) = Announcer::channel();
        let daemon = std::thread::spawn(move || {
            serve("127.0.0.1:0", &opts, &mut announcer).unwrap();
        });
        let addr = addr_rx
            .recv_timeout(RECV_TIMEOUT)
            .expect("daemon never announced its address");

        let stream = TcpStream::connect(&addr).unwrap();
        let mut writer = stream.try_clone().unwrap();
        write_frame(&mut writer, &wm.to_json().render()).unwrap();
        write_frame_bytes(&mut writer, &shard_bytes).unwrap();
        let mut frames = FrameReader::new(BufReader::new(stream));
        let mut thetas: Vec<String> = Vec::new();
        let mut summaries = 0usize;
        while let Some(payload) = frames.read_frame().unwrap() {
            match WireMsg::decode(&payload).unwrap() {
                WireMsg::Draw(d) => thetas.push(format!("{:?}", d.theta)),
                WireMsg::Chunk(_) => {
                    panic!("unexpected chunk on the JSON wire")
                }
                WireMsg::Summary(s) => {
                    assert_eq!(s.machine, 0);
                    summaries += 1;
                }
                WireMsg::Error { message, .. } => {
                    panic!("inline job failed remotely: {message}")
                }
                WireMsg::Heartbeat { .. } => {
                    panic!("heartbeats must be off when heartbeat_secs=0")
                }
            }
        }
        assert_eq!(summaries, 1);
        assert_eq!(
            thetas, ref_thetas,
            "inline shard delivery must reproduce the path-mode draws"
        );
        daemon.join().unwrap();
        std::fs::remove_dir_all(&dir).ok();
    }

    /// An inline-marked connection that closes before the shard frame
    /// is a clean in-band error, and the daemon stays up.
    #[test]
    fn serve_reports_missing_inline_shard_frame_in_band() {
        let dir = std::env::temp_dir().join("repro_serve_inline_missing");
        std::fs::create_dir_all(&dir).unwrap();
        let mut wm = spill_manifest(&dir, 0, 2, io::ShardFormat::Json);
        wm.shard_inline = true;
        let opts = ServeOptions { max_jobs: Some(1), ..Default::default() };
        let (mut announcer, addr_rx) = Announcer::channel();
        let daemon = std::thread::spawn(move || {
            serve("127.0.0.1:0", &opts, &mut announcer).ok();
        });
        let addr = addr_rx
            .recv_timeout(RECV_TIMEOUT)
            .expect("daemon never announced its address");
        let stream = TcpStream::connect(&addr).unwrap();
        let mut writer = stream.try_clone().unwrap();
        write_frame(&mut writer, &wm.to_json().render()).unwrap();
        // Half-close our sending side: the daemon sees EOF where the
        // shard frame should be.
        stream.shutdown(Shutdown::Write).ok();
        let mut frames = FrameReader::new(BufReader::new(stream));
        let mut saw_error = false;
        while let Some(payload) = frames.read_frame().unwrap() {
            if let WireMsg::Error { message, .. } =
                WireMsg::decode(&payload).unwrap()
            {
                assert!(
                    message.contains("inline shard"),
                    "error should name the missing frame: {message}"
                );
                saw_error = true;
            }
        }
        assert!(saw_error, "missing shard frame must surface in-band");
        daemon.join().unwrap();
        std::fs::remove_dir_all(&dir).ok();
    }

    /// A job that fails after the connection is up (missing shard)
    /// reaches the client as an in-band error frame, and the daemon
    /// survives to serve the next connection.
    #[test]
    fn serve_reports_job_failure_in_band_and_stays_up() {
        let dir = std::env::temp_dir().join("repro_serve_fail_test");
        std::fs::create_dir_all(&dir).unwrap();
        let good = spill_manifest(&dir, 0, 2, io::ShardFormat::Json);
        let mut bad = good.clone();
        bad.shard_path =
            dir.join("missing.json").to_string_lossy().into_owned();

        let opts = ServeOptions { max_jobs: Some(2), ..Default::default() };
        let (mut announcer, addr_rx) = Announcer::channel();
        let daemon = std::thread::spawn(move || {
            serve("127.0.0.1:0", &opts, &mut announcer).ok();
        });
        let addr = addr_rx
            .recv_timeout(RECV_TIMEOUT)
            .expect("daemon never announced its address");

        // Job 1: broken manifest → error frame.
        let stream = TcpStream::connect(&addr).unwrap();
        let mut writer = stream.try_clone().unwrap();
        write_frame(&mut writer, &bad.to_json().render()).unwrap();
        let mut frames = FrameReader::new(BufReader::new(stream));
        let mut saw_error = false;
        while let Some(payload) = frames.read_frame().unwrap() {
            if let WireMsg::Error { machine, message } =
                WireMsg::decode(&payload).unwrap()
            {
                assert_eq!(machine, 0);
                assert!(!message.is_empty());
                saw_error = true;
            }
        }
        assert!(saw_error, "job failure must arrive as an error frame");

        // Job 2: the daemon is still alive and serves a good job.
        let stream = TcpStream::connect(&addr).unwrap();
        let mut writer = stream.try_clone().unwrap();
        write_frame(&mut writer, &good.to_json().render()).unwrap();
        let mut frames = FrameReader::new(BufReader::new(stream));
        let mut summaries = 0usize;
        while let Some(payload) = frames.read_frame().unwrap() {
            if matches!(
                WireMsg::decode(&payload).unwrap(),
                WireMsg::Summary(_)
            ) {
                summaries += 1;
            }
        }
        assert_eq!(summaries, 1);
        daemon.join().unwrap();
        std::fs::remove_dir_all(&dir).ok();
    }

    /// RPHB beacons interleave with the draw stream when the manifest
    /// arms them — and never perturb the draws. A zero interval forces
    /// a beacon on every tick, so the beacon count is deterministic
    /// (one per chain iteration, burn-in included) without the test
    /// waiting wall-clock seconds; the draw frames must be
    /// byte-identical to a beacon-free run of the same manifest.
    #[test]
    fn heartbeats_interleave_without_perturbing_draws() {
        let dir = std::env::temp_dir().join("repro_serve_hb_test");
        std::fs::create_dir_all(&dir).unwrap();
        let wm = spill_manifest(&dir, 1, 3, io::ShardFormat::Binary);
        let data = io::read_shard(Path::new(&wm.shard_path)).unwrap();

        let mut quiet: Vec<Vec<u8>> = Vec::new();
        run_manifest_with_data_at(&wm, &data, &mut |f: &[u8]| {
            quiet.push(f.to_vec());
            Ok(())
        }, None)
        .unwrap();

        let mut noisy: Vec<Vec<u8>> = Vec::new();
        run_manifest_with_data_at(&wm, &data, &mut |f: &[u8]| {
            noisy.push(f.to_vec());
            Ok(())
        }, Some(Duration::ZERO))
        .unwrap();

        let beacons: Vec<&Vec<u8>> = noisy
            .iter()
            .filter(|f| {
                matches!(
                    WireMsg::decode_frame(f).unwrap(),
                    WireMsg::Heartbeat { .. }
                )
            })
            .collect();
        // total iterations = burn_in + (samples-1)*thin + 1 = 5+24+1.
        assert_eq!(
            beacons.len(),
            30,
            "zero interval must beacon once per chain iteration"
        );
        for f in &beacons {
            match WireMsg::decode_frame(f).unwrap() {
                WireMsg::Heartbeat { machine } => assert_eq!(machine, 1),
                _ => unreachable!(),
            }
        }
        let payload: Vec<&Vec<u8>> = noisy
            .iter()
            .filter(|f| {
                !matches!(
                    WireMsg::decode_frame(f).unwrap(),
                    WireMsg::Heartbeat { .. }
                )
            })
            .collect();
        assert_eq!(
            payload,
            quiet.iter().collect::<Vec<_>>(),
            "beacons must leave the draw/summary frames byte-identical"
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    /// `--fault drop-after:N` hard-kills the connection mid-stream: the
    /// client sees exactly N frames then EOF with no summary — the
    /// wire shape of a worker crash, which is what the retry scheduler
    /// is tested against.
    #[test]
    fn serve_drop_after_fault_kills_the_stream_mid_run() {
        let dir = std::env::temp_dir().join("repro_serve_dropfault_test");
        std::fs::create_dir_all(&dir).unwrap();
        let wm = spill_manifest(&dir, 0, 2, io::ShardFormat::Json);
        let opts = ServeOptions {
            max_jobs: Some(1),
            fault: Some(FaultSpec::DropAfterFrames(3)),
            ..Default::default()
        };
        let (mut announcer, addr_rx) = Announcer::channel();
        let daemon = std::thread::spawn(move || {
            serve("127.0.0.1:0", &opts, &mut announcer).ok();
        });
        let addr = addr_rx
            .recv_timeout(RECV_TIMEOUT)
            .expect("daemon never announced its address");
        let stream = TcpStream::connect(&addr).unwrap();
        let mut writer = stream.try_clone().unwrap();
        write_frame(&mut writer, &wm.to_json().render()).unwrap();
        let mut frames = FrameReader::new(BufReader::new(stream));
        let mut draws = 0usize;
        let mut summaries = 0usize;
        loop {
            match frames.read_frame() {
                Ok(Some(payload)) => {
                    match WireMsg::decode(&payload).unwrap() {
                        WireMsg::Draw(_) => draws += 1,
                        WireMsg::Summary(_) => summaries += 1,
                        other => panic!("unexpected frame {other:?}"),
                    }
                }
                // Clean EOF or a torn frame — both are valid shapes
                // for a hard mid-stream kill; what matters is that the
                // stream ended early without a summary.
                Ok(None) | Err(_) => break,
            }
        }
        assert_eq!(draws, 3, "exactly N frames escape before the drop");
        assert_eq!(summaries, 0, "a dropped job must never summarize");
        daemon.join().unwrap();
        std::fs::remove_dir_all(&dir).ok();
    }
}
