//! End-to-end pipeline: partition → parallel subposterior sampling →
//! streaming → combination.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::channel;
use std::sync::Mutex;
use std::time::Instant;

use crate::combine;
use crate::config::PipelineConfig;
use crate::coordinator::metrics::RunMetrics;
use crate::coordinator::partition::Partitioner;
use crate::coordinator::timing::ClusterTiming;
use crate::coordinator::worker::{run_worker, DrawMsg};
use crate::coordinator::Leader;
use crate::data::Dataset;
use crate::error::{Error, Result};
use crate::model::LogDensity;
use crate::rng::Pcg64;
use crate::types::{SampleMatrix, SubposteriorSamples};

/// Everything a pipeline run produces.
#[derive(Debug)]
pub struct PipelineOutput {
    /// Per-machine subposterior draws (criterion 2's independent chains).
    pub subposteriors: Vec<SubposteriorSamples>,
    /// Full-posterior draws from the configured combination method.
    pub combined: SampleMatrix,
    /// Counters and timings.
    pub metrics: RunMetrics,
    /// Paper-style cluster-time model.
    pub timing: ClusterTiming,
}

/// Run the full embarrassingly-parallel pipeline with native (pure-rust)
/// subposterior evaluation and OS-thread workers.
pub fn run_native(cfg: &PipelineConfig, data: &Dataset) -> Result<PipelineOutput> {
    let shards =
        Partitioner::Contiguous.split(data.len(), cfg.machines, cfg.seed)?;
    let prior_w = 1.0 / cfg.machines as f64;
    let dim = data.param_dim();
    let t0 = Instant::now();

    // Independent RNG stream per worker, derived from the root seed.
    let mut root = Pcg64::seed_from(cfg.seed);
    let worker_rngs: Vec<Pcg64> =
        (0..cfg.machines).map(|m| root.split(m as u64)).collect();

    let (tx, rx) = channel::<DrawMsg>();
    let results: Mutex<Vec<Option<SubposteriorSamples>>> =
        Mutex::new((0..cfg.machines).map(|_| None).collect());
    let next_machine = AtomicUsize::new(0);
    let n_threads = cfg.threads.clamp(1, cfg.machines);
    let rng_slots: Vec<Mutex<Option<Pcg64>>> =
        worker_rngs.into_iter().map(|r| Mutex::new(Some(r))).collect();

    let mut leader = Leader::new(cfg.machines, dim);
    std::thread::scope(|scope| -> Result<()> {
        for _ in 0..n_threads {
            let tx = tx.clone();
            let shards = &shards;
            let results = &results;
            let next_machine = &next_machine;
            let rng_slots = &rng_slots;
            scope.spawn(move || {
                loop {
                    let m = next_machine.fetch_add(1, Ordering::SeqCst);
                    if m >= cfg.machines {
                        break;
                    }
                    let target = match data.subposterior(&shards[m], prior_w)
                    {
                        Ok(t) => t,
                        Err(_) => break, // validated above; unreachable
                    };
                    let rng = rng_slots[m].lock().unwrap().take().unwrap();
                    let sampler = cfg.sampler.build(target.dim());
                    let out = run_worker(
                        m,
                        target.as_ref(),
                        sampler,
                        cfg.samples_per_machine,
                        cfg.burn_in,
                        cfg.thin,
                        rng,
                        Some(&tx),
                    );
                    results.lock().unwrap()[m] = Some(out);
                }
            });
        }
        drop(tx); // close our copy so rx terminates when workers finish
        leader.drain(&rx)?;
        Ok(())
    })?;

    let subposteriors: Vec<SubposteriorSamples> = results
        .into_inner()
        .unwrap()
        .into_iter()
        .map(|o| o.ok_or_else(|| Error::Runtime("worker died".into())))
        .collect::<Result<_>>()?;

    finish_run(cfg, subposteriors, leader.scalars_received, t0)
}

/// Run the pipeline over pre-built subposterior models, sequentially on
/// the calling thread. This is the path for PJRT-runtime-backed models
/// (the XLA client is not `Send`); per-worker wall-clocks are still
/// measured individually so [`ClusterTiming`] models the parallel
/// cluster the paper ran on.
pub fn run_sequential(
    cfg: &PipelineConfig,
    models: Vec<Box<dyn LogDensity + '_>>,
) -> Result<PipelineOutput> {
    if models.len() != cfg.machines {
        return Err(Error::Config(format!(
            "{} models for {} machines",
            models.len(),
            cfg.machines
        )));
    }
    let t0 = Instant::now();
    let mut root = Pcg64::seed_from(cfg.seed);
    let mut subposteriors = Vec::with_capacity(cfg.machines);
    let mut scalars = 0usize;
    for (m, target) in models.iter().enumerate() {
        let rng = root.split(m as u64);
        let sampler = cfg.sampler.build(target.dim());
        let out = run_worker(
            m,
            target.as_ref(),
            sampler,
            cfg.samples_per_machine,
            cfg.burn_in,
            cfg.thin,
            rng,
            None,
        );
        scalars += out.samples.len() * out.samples.dim();
        subposteriors.push(out);
    }
    finish_run(cfg, subposteriors, scalars, t0)
}

fn finish_run(
    cfg: &PipelineConfig,
    subposteriors: Vec<SubposteriorSamples>,
    scalars: usize,
    t0: Instant,
) -> Result<PipelineOutput> {
    let tc = Instant::now();
    // Combine-stage parallelism (cfg.combine_threads, 0 = all cores):
    // deterministic for a fixed seed at any thread count, so the knob
    // only affects wall-clock.
    let combined = combine::combine_threaded(
        cfg.method,
        &subposteriors,
        cfg.t_out,
        cfg.seed ^ 0x5EED,
        cfg.combine_threads,
    )?;
    let combine_secs = tc.elapsed().as_secs_f64();

    let timing = ClusterTiming::from_run(&subposteriors, combine_secs);
    let metrics = RunMetrics {
        machines: cfg.machines,
        samples_per_machine: cfg.samples_per_machine,
        param_dim: combined.dim(),
        accept_rates: subposteriors.iter().map(|s| s.accept_rate).collect(),
        worker_secs: subposteriors.iter().map(|s| s.wall_secs).collect(),
        scalars_transferred: scalars,
        combine_secs,
        total_secs: t0.elapsed().as_secs_f64(),
    };
    Ok(PipelineOutput { subposteriors, combined, metrics, timing })
}

/// Run a single full-data chain (the `regularChain` baseline).
pub fn run_single_chain(
    cfg: &PipelineConfig,
    data: &Dataset,
) -> Result<SubposteriorSamples> {
    let target = data.full_posterior()?;
    let mut rng = Pcg64::seed_from(cfg.seed ^ 0xF0F0);
    let sampler = cfg.sampler.build(target.dim());
    Ok(run_worker(
        0,
        target.as_ref(),
        sampler,
        cfg.samples_per_machine,
        cfg.burn_in,
        cfg.thin,
        rng.split(0),
        None,
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::combine::CombineMethod;
    use crate::data::synth;

    fn cfg(machines: usize, t: usize) -> PipelineConfig {
        PipelineConfig::builder("gaussian")
            .machines(machines)
            .samples_per_machine(t)
            .method(CombineMethod::Parametric)
            .seed(11)
            .build()
    }

    #[test]
    fn native_pipeline_recovers_posterior_mean() {
        let data = synth::gaussian(4000, 2, 5);
        let out = run_native(&cfg(4, 800), &data).unwrap();
        assert_eq!(out.subposteriors.len(), 4);
        assert_eq!(out.combined.len(), 800);
        // Posterior mean ≈ sample mean of the data (n large, weak prior).
        let mean = out.combined.mean();
        assert!((mean[0] - 1.0).abs() < 0.1, "mean0 {}", mean[0]);
        assert!((mean[1] - 1.1).abs() < 0.1, "mean1 {}", mean[1]);
        assert_eq!(
            out.metrics.scalars_transferred,
            4 * 800 * 2,
            "O(dTM) communication"
        );
        assert!(out.timing.total_secs() > 0.0);
    }

    #[test]
    fn thread_cap_does_not_change_results_count() {
        let data = synth::gaussian(1000, 2, 6);
        let mut c = cfg(6, 200);
        c.threads = 2; // fewer threads than machines
        let out = run_native(&c, &data).unwrap();
        assert_eq!(out.subposteriors.len(), 6);
        for s in &out.subposteriors {
            assert_eq!(s.samples.len(), 200);
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let data = synth::gaussian(500, 1, 7);
        let a = run_native(&cfg(2, 100), &data).unwrap();
        let b = run_native(&cfg(2, 100), &data).unwrap();
        for (sa, sb) in a.subposteriors.iter().zip(&b.subposteriors) {
            assert_eq!(sa.samples.as_slice(), sb.samples.as_slice());
        }
        assert_eq!(a.combined.as_slice(), b.combined.as_slice());
    }

    /// The combine stage must be byte-identical whatever thread count
    /// the leader is given (1, 4, or auto) — including through the full
    /// pipeline with an IMG-based method.
    #[test]
    fn combine_threads_do_not_change_output() {
        let data = synth::gaussian(1200, 2, 12);
        let make = |combine_threads: usize| {
            let mut c = cfg(3, 300);
            c.method = CombineMethod::Nonparametric;
            c.combine_threads = combine_threads;
            run_native(&c, &data).unwrap()
        };
        let base = make(1);
        for t in [4usize, 0] {
            let out = make(t);
            assert_eq!(
                base.combined.as_slice(),
                out.combined.as_slice(),
                "combine_threads {t} diverged"
            );
        }
    }

    #[test]
    fn sequential_matches_machine_count() {
        let data = synth::gaussian(600, 1, 8);
        let shards = Partitioner::Contiguous.split(600, 3, 0).unwrap();
        let models: Vec<Box<dyn LogDensity>> = shards
            .iter()
            .map(|idx| data.subposterior(idx, 1.0 / 3.0).unwrap())
            .collect();
        let out = run_sequential(&cfg(3, 150), models).unwrap();
        assert_eq!(out.subposteriors.len(), 3);
        assert_eq!(out.combined.len(), 150);
    }

    #[test]
    fn single_chain_baseline_runs() {
        let data = synth::gaussian(500, 2, 9);
        let out = run_single_chain(&cfg(1, 300), &data).unwrap();
        assert_eq!(out.samples.len(), 300);
        let mean = out.samples.mean();
        assert!((mean[0] - 1.0).abs() < 0.15, "mean {:?}", mean);
    }
}
