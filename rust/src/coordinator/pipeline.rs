//! End-to-end pipeline: partition → parallel subposterior sampling →
//! streaming → combination.
//!
//! Two worker runtimes share the leader/combiner stack: [`run_native`]
//! (OS threads in this process) and [`run_process`] (one OS process per
//! machine, draws streamed back over length-prefixed ndjson pipes —
//! see [`crate::coordinator::transport`]). Both derive worker RNGs as
//! `Pcg64::seed_from(seed).split(m)`, so their outputs are
//! byte-identical for the same config.

use std::io::{BufReader, Read};
use std::path::PathBuf;
use std::process::{Child, Command, Stdio};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::Mutex;
use std::time::Instant;

use crate::combine;
use crate::config::{self, PipelineConfig};
use crate::coordinator::metrics::RunMetrics;
use crate::coordinator::partition::Partitioner;
use crate::coordinator::timing::ClusterTiming;
use crate::coordinator::transport::{
    FrameReader, WireMsg, WorkerManifest, WorkerSummary,
};
use crate::coordinator::worker::{run_worker, DrawMsg};
use crate::coordinator::Leader;
use crate::data::{io, Dataset};
use crate::error::{Error, Result};
use crate::model::LogDensity;
use crate::rng::Pcg64;
use crate::types::{SampleMatrix, SubposteriorSamples};

/// Everything a pipeline run produces.
#[derive(Debug)]
pub struct PipelineOutput {
    /// Per-machine subposterior draws (criterion 2's independent chains).
    pub subposteriors: Vec<SubposteriorSamples>,
    /// Full-posterior draws from the configured combination method.
    pub combined: SampleMatrix,
    /// Counters and timings.
    pub metrics: RunMetrics,
    /// Paper-style cluster-time model.
    pub timing: ClusterTiming,
}

/// Run the full embarrassingly-parallel pipeline with native (pure-rust)
/// subposterior evaluation and OS-thread workers.
pub fn run_native(cfg: &PipelineConfig, data: &Dataset) -> Result<PipelineOutput> {
    let shards =
        Partitioner::Contiguous.split(data.len(), cfg.machines, cfg.seed)?;
    let prior_w = 1.0 / cfg.machines as f64;
    let dim = data.param_dim();
    let t0 = Instant::now();

    // Independent RNG stream per worker, derived from the root seed.
    let mut root = Pcg64::seed_from(cfg.seed);
    let worker_rngs: Vec<Pcg64> =
        (0..cfg.machines).map(|m| root.split(m as u64)).collect();

    let (tx, rx) = channel::<DrawMsg>();
    let results: Mutex<Vec<Option<SubposteriorSamples>>> =
        Mutex::new((0..cfg.machines).map(|_| None).collect());
    // First real error hit inside a worker thread; surfaced after the
    // scope instead of the misleading "worker died" the abandoned
    // machines would otherwise produce.
    let worker_err: Mutex<Option<Error>> = Mutex::new(None);
    let next_machine = AtomicUsize::new(0);
    let n_threads = cfg.threads.clamp(1, cfg.machines);
    let rng_slots: Vec<Mutex<Option<Pcg64>>> =
        worker_rngs.into_iter().map(|r| Mutex::new(Some(r))).collect();

    let mut leader = Leader::new(cfg.machines, dim);
    leader.set_combine_threads(cfg.combine_threads);
    std::thread::scope(|scope| -> Result<()> {
        for _ in 0..n_threads {
            let tx = tx.clone();
            let shards = &shards;
            let results = &results;
            let worker_err = &worker_err;
            let next_machine = &next_machine;
            let rng_slots = &rng_slots;
            scope.spawn(move || {
                loop {
                    let m = next_machine.fetch_add(1, Ordering::SeqCst);
                    if m >= cfg.machines {
                        break;
                    }
                    let target = match data.subposterior(&shards[m], prior_w)
                    {
                        Ok(t) => t,
                        Err(e) => {
                            let mut slot = worker_err.lock().unwrap();
                            if slot.is_none() {
                                *slot = Some(e);
                            }
                            break;
                        }
                    };
                    let rng = rng_slots[m].lock().unwrap().take().unwrap();
                    let sampler = cfg.sampler.build(target.dim());
                    let out = run_worker(
                        m,
                        target.as_ref(),
                        sampler,
                        cfg.samples_per_machine,
                        cfg.burn_in,
                        cfg.thin,
                        rng,
                        Some(&tx),
                    );
                    results.lock().unwrap()[m] = Some(out);
                }
            });
        }
        drop(tx); // close our copy so rx terminates when workers finish
        leader.drain(&rx)?;
        Ok(())
    })?;
    if let Some(e) = worker_err.into_inner().unwrap() {
        return Err(e);
    }

    let subposteriors: Vec<SubposteriorSamples> = results
        .into_inner()
        .unwrap()
        .into_iter()
        .map(|o| o.ok_or_else(|| Error::Runtime("worker died".into())))
        .collect::<Result<_>>()?;

    finish_run(cfg, subposteriors, leader.scalars_received, t0)
}

/// Scratch-directory sequence number: keeps concurrent `run_process`
/// calls in one process (e.g. the test harness) from colliding.
static SCRATCH_SEQ: AtomicUsize = AtomicUsize::new(0);

fn scratch_dir(seed: u64) -> Result<PathBuf> {
    let seq = SCRATCH_SEQ.fetch_add(1, Ordering::Relaxed);
    let dir = std::env::temp_dir().join(format!(
        "repro_workers_{}_{}_{}",
        std::process::id(),
        seed,
        seq
    ));
    std::fs::create_dir_all(&dir)?;
    Ok(dir)
}

/// Run the pipeline with one OS **process** per machine — the paper's
/// actual deployment shape ("machines communicate only at the final
/// combination stage"), and the prerequisite for multi-host runners.
///
/// The leader spills each shard plus a [`WorkerManifest`] to a scratch
/// directory, spawns `<worker-bin> worker --manifest …` per machine,
/// and drains every child's stdout frame stream through the same
/// [`Leader`]/`OnlineCombiner` the in-thread path uses. Workers derive
/// their RNG streams from the same root-seed `split(m)` schedule, and
/// draws cross the pipe through bit-exact float serialization, so the
/// output is **byte-identical to [`run_native`]** for the same config.
///
/// All M processes run concurrently — a "machine" in process mode *is*
/// a processor, so `cfg.threads` (the in-process worker-pool cap)
/// deliberately does not apply here. The first failure anywhere
/// cancels the remaining children instead of letting them sample into
/// a doomed run, and the root-cause error is the one surfaced.
///
/// Degrades cleanly: with `cfg.process_mode` off this is exactly
/// [`run_native`]. An empty `cfg.worker_bin` means "this executable"
/// (the CLI case); tests point it at the `repro` binary explicitly.
pub fn run_process(cfg: &PipelineConfig, data: &Dataset) -> Result<PipelineOutput> {
    if !cfg.process_mode {
        return run_native(cfg, data);
    }
    let shards =
        Partitioner::Contiguous.split(data.len(), cfg.machines, cfg.seed)?;
    let prior_w = 1.0 / cfg.machines as f64;
    let dim = data.param_dim();
    let t0 = Instant::now();

    let worker_bin: PathBuf = if cfg.worker_bin.is_empty() {
        std::env::current_exe()?
    } else {
        PathBuf::from(&cfg.worker_bin)
    };
    let scratch = scratch_dir(cfg.seed)?;

    let spawn_one = |m: usize, shard: &[usize]| -> Result<Child> {
        let shard_path = scratch.join(format!("shard_{m}.json"));
        io::write_shard_json(&shard_path, &data.select(shard)?)?;
        let manifest = WorkerManifest {
            machine: m,
            machines: cfg.machines,
            seed: cfg.seed,
            samples: cfg.samples_per_machine,
            burn_in: cfg.burn_in,
            thin: cfg.thin,
            prior_weight: prior_w,
            sampler: config::sampler_spec(&cfg.sampler),
            shard_path: shard_path.to_string_lossy().into_owned(),
            dim,
        };
        let manifest_path = scratch.join(format!("worker_{m}.json"));
        manifest.save(&manifest_path)?;
        Command::new(&worker_bin)
            .arg("worker")
            .arg("--manifest")
            .arg(&manifest_path)
            .stdin(Stdio::null())
            .stdout(Stdio::piped())
            .stderr(Stdio::piped())
            .spawn()
            .map_err(|e| {
                Error::Runtime(format!(
                    "spawning worker {m} ({}): {e}",
                    worker_bin.display()
                ))
            })
    };
    let mut children: Vec<Mutex<Child>> = Vec::with_capacity(cfg.machines);
    for (m, shard) in shards.iter().enumerate() {
        match spawn_one(m, shard) {
            Ok(c) => children.push(Mutex::new(c)),
            Err(e) => {
                // Don't leak the children already running.
                for c in &children {
                    let mut c = c.lock().unwrap();
                    c.kill().ok();
                    c.wait().ok();
                }
                std::fs::remove_dir_all(&scratch).ok();
                return Err(e);
            }
        }
    }

    let (tx, rx) = channel::<DrawMsg>();
    let results: Mutex<Vec<Option<SubposteriorSamples>>> =
        Mutex::new((0..cfg.machines).map(|_| None).collect());
    // First root-cause failure; set by whichever reader thread trips
    // it, which also cancels every other child (fail fast). Every
    // drain_child error path records here, so a `None` result slot
    // below always comes with a root_err to surface.
    let root_err: Mutex<Option<Error>> = Mutex::new(None);
    let mut leader = Leader::new(cfg.machines, dim);
    leader.set_combine_threads(cfg.combine_threads);
    let drained = std::thread::scope(|scope| -> Result<()> {
        for m in 0..children.len() {
            let tx = tx.clone();
            let children = &children;
            let results = &results;
            let root_err = &root_err;
            scope.spawn(move || {
                if let Ok(out) = drain_child(m, children, dim, &tx, root_err)
                {
                    results.lock().unwrap()[m] = Some(out);
                }
            });
        }
        drop(tx);
        leader.drain(&rx)?;
        Ok(())
    });
    std::fs::remove_dir_all(&scratch).ok();
    drained?;
    if let Some(e) = root_err.into_inner().unwrap() {
        return Err(e);
    }

    let subposteriors: Vec<SubposteriorSamples> = results
        .into_inner()
        .unwrap()
        .into_iter()
        .map(|o| o.ok_or_else(|| Error::Runtime("worker died".into())))
        .collect::<Result<_>>()?;

    finish_run(cfg, subposteriors, leader.scalars_received, t0)
}

/// Consume one child's frame stream: forward every draw into the
/// leader's channel, rebuild the machine's [`SubposteriorSamples`] from
/// the stream plus the final summary frame, and turn a non-zero exit
/// into the child's own stderr rather than a generic failure. On any
/// failure the root cause is recorded in `root_err` (first writer wins)
/// and every sibling child is killed, so the run fails fast instead of
/// letting healthy workers finish a doomed run.
fn drain_child(
    machine: usize,
    children: &[Mutex<Child>],
    dim: usize,
    tx: &Sender<DrawMsg>,
    root_err: &Mutex<Option<Error>>,
) -> Result<SubposteriorSamples> {
    // Record the root cause (unless a sibling already failed first),
    // cancel everyone, reap our own child, and build this thread's
    // error. Children killed here hit EOF on their readers, which land
    // in the non-success exit path below — also routed through this
    // helper, where `root_err` is already taken so the original cause
    // survives.
    let fail_all = |msg: String| -> Error {
        {
            let mut slot = root_err.lock().unwrap();
            if slot.is_none() {
                *slot = Some(Error::Runtime(msg.clone()));
            }
        }
        for c in children {
            c.lock().unwrap().kill().ok();
        }
        children[machine].lock().unwrap().wait().ok();
        Error::Runtime(msg)
    };

    let stdout = children[machine].lock().unwrap().stdout.take();
    let Some(stdout) = stdout else {
        return Err(fail_all(format!("worker {machine}: no stdout pipe")));
    };
    // Drain stderr concurrently from the start: a child that fills the
    // OS pipe buffer with (say) a long panic backtrace would otherwise
    // block in that write, never close stdout, and deadlock this
    // thread inside read_frame. Detached on purpose — on the fail_all
    // paths the kill closes the pipe and the drainer exits on its own.
    let stderr = children[machine].lock().unwrap().stderr.take();
    let stderr_drain = stderr.map(|mut se| {
        std::thread::spawn(move || {
            let mut text = String::new();
            se.read_to_string(&mut text).ok();
            text
        })
    });
    let mut frames = FrameReader::new(BufReader::new(stdout));
    let mut samples = SampleMatrix::new(dim);
    let mut draw_times = Vec::new();
    let mut summary: Option<WorkerSummary> = None;
    loop {
        let payload = match frames.read_frame() {
            Ok(Some(p)) => p,
            Ok(None) => break,
            Err(e) => {
                return Err(fail_all(format!(
                    "worker {machine}: bad frame: {e}"
                )))
            }
        };
        let msg = match WireMsg::decode(&payload) {
            Ok(m) => m,
            Err(e) => {
                return Err(fail_all(format!(
                    "worker {machine}: bad message: {e}"
                )))
            }
        };
        match msg {
            WireMsg::Draw(d) => {
                if d.machine != machine || d.theta.len() != dim {
                    return Err(fail_all(format!(
                        "worker {machine}: draw for machine {} with dim {}",
                        d.machine,
                        d.theta.len()
                    )));
                }
                samples.push(&d.theta);
                draw_times.push(d.elapsed);
                // Leader hung up → keep draining (mirrors thread mode).
                let _ = tx.send(d);
            }
            WireMsg::Summary(s) => summary = Some(s),
        }
    }
    // stdout hit EOF, so the child is exiting: collect what it said on
    // stderr, then reap. The frame loop above holds no child lock, so
    // a failing sibling's kill sweep is never blocked on this thread.
    let stderr_text = stderr_drain
        .and_then(|h| h.join().ok())
        .unwrap_or_default();
    let status = match children[machine].lock().unwrap().wait() {
        Ok(s) => s,
        Err(e) => {
            return Err(fail_all(format!("worker {machine}: wait: {e}")))
        }
    };
    if !status.success() {
        return Err(fail_all(format!(
            "worker {machine} exited with {status}: {}",
            stderr_text.trim()
        )));
    }
    let summary = match summary {
        Some(s) if s.machine == machine => s,
        Some(s) => {
            return Err(fail_all(format!(
                "worker {machine}: summary for machine {}",
                s.machine
            )))
        }
        None => {
            return Err(fail_all(format!(
                "worker {machine}: stream ended without a summary frame"
            )))
        }
    };
    Ok(SubposteriorSamples {
        machine,
        samples,
        accept_rate: summary.accept_rate,
        wall_secs: summary.wall_secs,
        draw_times,
    })
}

/// Run the pipeline over pre-built subposterior models, sequentially on
/// the calling thread. This is the path for PJRT-runtime-backed models
/// (the XLA client is not `Send`); per-worker wall-clocks are still
/// measured individually so [`ClusterTiming`] models the parallel
/// cluster the paper ran on.
pub fn run_sequential(
    cfg: &PipelineConfig,
    models: Vec<Box<dyn LogDensity + '_>>,
) -> Result<PipelineOutput> {
    if models.len() != cfg.machines {
        return Err(Error::Config(format!(
            "{} models for {} machines",
            models.len(),
            cfg.machines
        )));
    }
    let t0 = Instant::now();
    let mut root = Pcg64::seed_from(cfg.seed);
    let mut subposteriors = Vec::with_capacity(cfg.machines);
    let mut scalars = 0usize;
    for (m, target) in models.iter().enumerate() {
        let rng = root.split(m as u64);
        let sampler = cfg.sampler.build(target.dim());
        let out = run_worker(
            m,
            target.as_ref(),
            sampler,
            cfg.samples_per_machine,
            cfg.burn_in,
            cfg.thin,
            rng,
            None,
        );
        scalars += out.samples.len() * out.samples.dim();
        subposteriors.push(out);
    }
    finish_run(cfg, subposteriors, scalars, t0)
}

fn finish_run(
    cfg: &PipelineConfig,
    subposteriors: Vec<SubposteriorSamples>,
    scalars: usize,
    t0: Instant,
) -> Result<PipelineOutput> {
    let tc = Instant::now();
    // Combine-stage parallelism (cfg.combine_threads, 0 = all cores):
    // deterministic for a fixed seed at any thread count, so the knob
    // only affects wall-clock.
    let combined = combine::combine_threaded(
        cfg.method,
        &subposteriors,
        cfg.t_out,
        cfg.seed ^ 0x5EED,
        cfg.combine_threads,
    )?;
    let combine_secs = tc.elapsed().as_secs_f64();

    let timing = ClusterTiming::from_run(&subposteriors, combine_secs);
    let metrics = RunMetrics {
        machines: cfg.machines,
        samples_per_machine: cfg.samples_per_machine,
        param_dim: combined.dim(),
        accept_rates: subposteriors.iter().map(|s| s.accept_rate).collect(),
        worker_secs: subposteriors.iter().map(|s| s.wall_secs).collect(),
        scalars_transferred: scalars,
        combine_secs,
        total_secs: t0.elapsed().as_secs_f64(),
    };
    Ok(PipelineOutput { subposteriors, combined, metrics, timing })
}

/// Run a single full-data chain (the `regularChain` baseline).
pub fn run_single_chain(
    cfg: &PipelineConfig,
    data: &Dataset,
) -> Result<SubposteriorSamples> {
    let target = data.full_posterior()?;
    let mut rng = Pcg64::seed_from(cfg.seed ^ 0xF0F0);
    let sampler = cfg.sampler.build(target.dim());
    Ok(run_worker(
        0,
        target.as_ref(),
        sampler,
        cfg.samples_per_machine,
        cfg.burn_in,
        cfg.thin,
        rng.split(0),
        None,
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::combine::CombineMethod;
    use crate::data::synth;

    fn cfg(machines: usize, t: usize) -> PipelineConfig {
        PipelineConfig::builder("gaussian")
            .machines(machines)
            .samples_per_machine(t)
            .method(CombineMethod::Parametric)
            .seed(11)
            .build()
    }

    #[test]
    fn native_pipeline_recovers_posterior_mean() {
        let data = synth::gaussian(4000, 2, 5);
        let out = run_native(&cfg(4, 800), &data).unwrap();
        assert_eq!(out.subposteriors.len(), 4);
        assert_eq!(out.combined.len(), 800);
        // Posterior mean ≈ sample mean of the data (n large, weak prior).
        let mean = out.combined.mean();
        assert!((mean[0] - 1.0).abs() < 0.1, "mean0 {}", mean[0]);
        assert!((mean[1] - 1.1).abs() < 0.1, "mean1 {}", mean[1]);
        assert_eq!(
            out.metrics.scalars_transferred,
            4 * 800 * 2,
            "O(dTM) communication"
        );
        assert!(out.timing.total_secs() > 0.0);
    }

    #[test]
    fn thread_cap_does_not_change_results_count() {
        let data = synth::gaussian(1000, 2, 6);
        let mut c = cfg(6, 200);
        c.threads = 2; // fewer threads than machines
        let out = run_native(&c, &data).unwrap();
        assert_eq!(out.subposteriors.len(), 6);
        for s in &out.subposteriors {
            assert_eq!(s.samples.len(), 200);
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let data = synth::gaussian(500, 1, 7);
        let a = run_native(&cfg(2, 100), &data).unwrap();
        let b = run_native(&cfg(2, 100), &data).unwrap();
        for (sa, sb) in a.subposteriors.iter().zip(&b.subposteriors) {
            assert_eq!(sa.samples.as_slice(), sb.samples.as_slice());
        }
        assert_eq!(a.combined.as_slice(), b.combined.as_slice());
    }

    /// The combine stage must be byte-identical whatever thread count
    /// the leader is given (1, 4, or auto) — including through the full
    /// pipeline with an IMG-based method.
    #[test]
    fn combine_threads_do_not_change_output() {
        let data = synth::gaussian(1200, 2, 12);
        let make = |combine_threads: usize| {
            let mut c = cfg(3, 300);
            c.method = CombineMethod::Nonparametric;
            c.combine_threads = combine_threads;
            run_native(&c, &data).unwrap()
        };
        let base = make(1);
        for t in [4usize, 0] {
            let out = make(t);
            assert_eq!(
                base.combined.as_slice(),
                out.combined.as_slice(),
                "combine_threads {t} diverged"
            );
        }
    }

    /// RNG-stream contract: `run_native` (threads) and `run_sequential`
    /// both derive worker m's generator as `root.split(m)` from the
    /// same root seed, so the two paths must produce byte-identical
    /// subposterior draws (the process path is locked to the same
    /// contract in `rust/tests/process_pipeline.rs`, which spawns real
    /// child processes).
    #[test]
    fn native_and_sequential_share_worker_rng_streams() {
        let data = synth::gaussian(900, 2, 13);
        let c = cfg(3, 120);
        let native = run_native(&c, &data).unwrap();
        let shards =
            Partitioner::Contiguous.split(900, 3, c.seed).unwrap();
        let models: Vec<Box<dyn LogDensity>> = shards
            .iter()
            .map(|idx| data.subposterior(idx, 1.0 / 3.0).unwrap())
            .collect();
        let seq = run_sequential(&c, models).unwrap();
        for (a, b) in native.subposteriors.iter().zip(&seq.subposteriors) {
            assert_eq!(
                a.samples.as_slice(),
                b.samples.as_slice(),
                "machine {} diverged between thread and sequential paths",
                a.machine
            );
        }
        assert_eq!(native.combined.as_slice(), seq.combined.as_slice());
    }

    #[test]
    fn sequential_matches_machine_count() {
        let data = synth::gaussian(600, 1, 8);
        let shards = Partitioner::Contiguous.split(600, 3, 0).unwrap();
        let models: Vec<Box<dyn LogDensity>> = shards
            .iter()
            .map(|idx| data.subposterior(idx, 1.0 / 3.0).unwrap())
            .collect();
        let out = run_sequential(&cfg(3, 150), models).unwrap();
        assert_eq!(out.subposteriors.len(), 3);
        assert_eq!(out.combined.len(), 150);
    }

    #[test]
    fn single_chain_baseline_runs() {
        let data = synth::gaussian(500, 2, 9);
        let out = run_single_chain(&cfg(1, 300), &data).unwrap();
        assert_eq!(out.samples.len(), 300);
        let mean = out.samples.mean();
        assert!((mean[0] - 1.0).abs() < 0.15, "mean {:?}", mean);
    }
}
