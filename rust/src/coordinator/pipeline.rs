//! End-to-end pipeline: partition → parallel subposterior sampling →
//! streaming → combination.
//!
//! Three worker runtimes share the leader/combiner stack:
//! [`run_native`] (OS threads in this process) and
//! [`run_with_transport`] over any
//! [`Transport`](crate::coordinator::transport::Transport) —
//! [`PipeTransport`] (one child process per assignment, PR 2's process
//! mode) or [`SocketTransport`] (`repro serve` daemons dialed over
//! TCP). [`run_process`] picks the transport from the config. Every
//! runtime derives worker m's RNG as `Pcg64::seed_from(seed).split(m)`
//! — from the *machine index*, never the executing endpoint — so the
//! retained draws are byte-identical for the same config regardless of
//! worker count W, assignment order, or transport.

use std::collections::VecDeque;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::combine;
use crate::config::{self, FailurePolicy, IoDriver, PipelineConfig};
use crate::coordinator::metrics::RunMetrics;
use crate::coordinator::partition::Partitioner;
use crate::coordinator::timing::ClusterTiming;
use crate::coordinator::transport::{
    PipeTransport, SocketTransport, Transport, WireMsg, WorkerManifest,
    WorkerSummary, LIVENESS_EXPIRED_MARKER,
};
use crate::coordinator::worker::{run_worker, DrawMsg};
use crate::coordinator::{Leader, LeaderMsg};
use crate::data::{io, Dataset};
use crate::error::{Error, Result};
use crate::model::LogDensity;
use crate::rng::Pcg64;
use crate::types::{DrawStoreConfig, SampleMatrix, SubposteriorSamples};

/// Everything a pipeline run produces.
#[derive(Debug)]
pub struct PipelineOutput {
    /// Per-machine subposterior draws (criterion 2's independent chains).
    pub subposteriors: Vec<SubposteriorSamples>,
    /// Full-posterior draws from the configured combination method.
    pub combined: SampleMatrix,
    /// Counters and timings.
    pub metrics: RunMetrics,
    /// Paper-style cluster-time model.
    pub timing: ClusterTiming,
    /// Scratch run directory of a process/socket-mode run (shard spills
    /// + worker manifests), `None` for in-thread runs. Owning it here
    /// keeps the spill files inspectable for the lifetime of the
    /// output; the directory is removed when the output drops — and on
    /// every early-error path, where the pipeline's local binding
    /// drops.
    pub run_dir: Option<RunDir>,
}

/// Pipeline lifecycle stages, surfaced to `_events` callers as they
/// begin. The daemon's job state machine maps these onto `RPJOB1`
/// lifecycle frames (`Sampling` → `running`, `Combining` →
/// `combining`); a solo CLI run uses the plain entry points, whose
/// no-op hook makes the phases invisible. Phases carry no data and
/// never feed RNG state — they are observability only, so wiring them
/// in cannot perturb the byte-identity contract.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RunPhase {
    /// Subposterior sampling has started (workers dialed/spawned).
    Sampling,
    /// All draws landed; the combine stage is starting.
    Combining,
}

/// Run the full embarrassingly-parallel pipeline with native (pure-rust)
/// subposterior evaluation and OS-thread workers.
pub fn run_native(cfg: &PipelineConfig, data: &Dataset) -> Result<PipelineOutput> {
    run_native_events(cfg, data, &|_| {})
}

/// [`run_native`] with lifecycle events: `on_phase` fires as each
/// [`RunPhase`] begins. `Sync` because worker threads are alive when
/// phases fire (the hook itself is only ever called from this thread).
pub fn run_native_events(
    cfg: &PipelineConfig,
    data: &Dataset,
    on_phase: &(dyn Fn(RunPhase) + Sync),
) -> Result<PipelineOutput> {
    validate_combine_backend(cfg)?;
    let shards =
        Partitioner::Contiguous.split(data.len(), cfg.machines, cfg.seed)?;
    let prior_w = 1.0 / cfg.machines as f64;
    let dim = data.param_dim();
    let t0 = Instant::now();

    // Independent RNG stream per worker, derived from the root seed.
    let mut root = Pcg64::seed_from(cfg.seed);
    let worker_rngs: Vec<Pcg64> =
        (0..cfg.machines).map(|m| root.split(m as u64)).collect();

    let (tx, rx) = channel::<DrawMsg>();
    let results: Mutex<Vec<Option<SubposteriorSamples>>> =
        Mutex::new((0..cfg.machines).map(|_| None).collect());
    // First real error hit inside a worker thread; surfaced after the
    // scope instead of the misleading "worker died" the abandoned
    // machines would otherwise produce.
    let worker_err: Mutex<Option<Error>> = Mutex::new(None);
    let next_machine = AtomicUsize::new(0);
    let n_threads = cfg.threads.clamp(1, cfg.machines);
    let rng_slots: Vec<Mutex<Option<Pcg64>>> =
        worker_rngs.into_iter().map(|r| Mutex::new(Some(r))).collect();

    let mut leader =
        Leader::with_store_config(cfg.machines, dim, store_config(cfg));
    leader.set_combine_threads(cfg.combine_threads);
    leader.set_combine_cache_budget(cache_budget_bytes(cfg));
    leader.set_combine_kernel(cfg.combine_backend);
    on_phase(RunPhase::Sampling);
    std::thread::scope(|scope| -> Result<()> {
        for _ in 0..n_threads {
            let tx = tx.clone();
            let shards = &shards;
            let results = &results;
            let worker_err = &worker_err;
            let next_machine = &next_machine;
            let rng_slots = &rng_slots;
            scope.spawn(move || {
                loop {
                    let m = next_machine.fetch_add(1, Ordering::SeqCst);
                    if m >= cfg.machines {
                        break;
                    }
                    let target = match data.subposterior(&shards[m], prior_w)
                    {
                        Ok(t) => t,
                        Err(e) => {
                            let mut slot = worker_err.lock().unwrap();
                            if slot.is_none() {
                                *slot = Some(e);
                            }
                            break;
                        }
                    };
                    let rng = rng_slots[m].lock().unwrap().take().unwrap();
                    let sampler = cfg.sampler.build(target.dim());
                    let out = run_worker(
                        m,
                        target.as_ref(),
                        sampler,
                        cfg.samples_per_machine,
                        cfg.burn_in,
                        cfg.thin,
                        rng,
                        Some(&tx),
                    );
                    results.lock().unwrap()[m] = Some(out);
                }
            });
        }
        drop(tx); // close our copy so rx terminates when workers finish
        leader.drain(&rx)?;
        Ok(())
    })?;
    if let Some(e) = worker_err.into_inner().unwrap() {
        return Err(e);
    }

    let subposteriors: Vec<SubposteriorSamples> = results
        .into_inner()
        .unwrap()
        .into_iter()
        .map(|o| o.ok_or_else(|| Error::Runtime("worker died".into())))
        .collect::<Result<_>>()?;

    on_phase(RunPhase::Combining);
    finish_run(cfg, subposteriors, leader.scalars_received, t0, Some(&leader))
}

/// Scratch-directory sequence number: keeps concurrent transport runs
/// in one process (e.g. the test harness) from colliding.
static SCRATCH_SEQ: AtomicUsize = AtomicUsize::new(0);

/// Tempdir-style scratch directory for one process/socket-mode run:
/// shard spills and worker manifests live here, at a pid + seed +
/// sequence-unique path under the OS temp root (never derived from the
/// worker binary's location, which may have no usable parent at all).
/// Removed recursively on drop; on success the [`PipelineOutput`] owns
/// it, so cleanup happens when the caller is done with the output.
#[derive(Debug)]
pub struct RunDir {
    path: PathBuf,
}

impl RunDir {
    fn create(seed: u64) -> Result<RunDir> {
        let seq = SCRATCH_SEQ.fetch_add(1, Ordering::Relaxed);
        let path = std::env::temp_dir().join(format!(
            "repro_run_{}_{}_{}",
            std::process::id(),
            seed,
            seq
        ));
        std::fs::create_dir_all(&path)?;
        Ok(RunDir { path })
    }

    pub fn path(&self) -> &Path {
        &self.path
    }
}

impl Drop for RunDir {
    fn drop(&mut self) {
        std::fs::remove_dir_all(&self.path).ok();
    }
}

/// The configured anneal-cache budget in bytes.
fn cache_budget_bytes(cfg: &PipelineConfig) -> usize {
    cfg.combine_cache_budget_mb.saturating_mul(1 << 20)
}

/// The leader-side draw-store configuration the config describes:
/// row-chunk granularity (`chunk_rows` key / `--chunk-rows`) and the
/// optional spill budget (`draw_spill_budget_mb` MiB → bytes; absent =
/// fully dense). Neither knob changes the retained draws — the store
/// backends are byte-identical by contract — so this only bounds the
/// leader's resident memory.
fn store_config(cfg: &PipelineConfig) -> DrawStoreConfig {
    DrawStoreConfig {
        chunk_rows: cfg.chunk_rows,
        spill_budget_bytes: cfg
            .draw_spill_budget_mb
            .map(|mb| mb.saturating_mul(1 << 20)),
    }
}

/// The combine-stage tuning block the config describes: threads,
/// anneal-cache budget, and the compute-kernel backend
/// (`combine_backend` key / `--combine-backend` flag). None of these
/// change the retained draws — CPU kernel backends are bit-identical
/// by contract.
fn combine_tuning(cfg: &PipelineConfig) -> combine::CombineTuning {
    combine::CombineTuning {
        threads: cfg.combine_threads,
        cache_budget_bytes: cache_budget_bytes(cfg),
        kernel: cfg.combine_backend,
    }
}

/// Instantiate (and discard) the configured combine-kernel backend —
/// run by every pipeline entry point *before* the sampling stage, so
/// an unavailable backend (`--combine-backend device` offline) kills
/// the run immediately instead of after hours of sampling whose
/// combine step was doomed from the start.
fn validate_combine_backend(cfg: &PipelineConfig) -> Result<()> {
    cfg.combine_backend.build().map(|_| ())
}

/// Run the pipeline with out-of-process workers, choosing the transport
/// from the config: socket mode when `cfg.workers` names `repro serve`
/// endpoints, else pipe mode when `cfg.process_mode` is set (one child
/// process per assignment, at most `cfg.worker_slots` concurrently —
/// `0` = one per machine), else the in-thread [`run_native`] path.
///
/// All three are **byte-identical** for a fixed seed — asserted by
/// `rust/tests/process_pipeline.rs` and `rust/tests/socket_pipeline.rs`
/// against real child processes and real localhost daemons.
pub fn run_process(cfg: &PipelineConfig, data: &Dataset) -> Result<PipelineOutput> {
    run_process_events(cfg, data, &|_| {})
}

/// [`run_process`] with lifecycle events — the daemon's job runner
/// entry point: same transport dispatch, same byte-identity contract,
/// plus [`RunPhase`] notifications for the RPJOB1 state machine.
pub fn run_process_events(
    cfg: &PipelineConfig,
    data: &Dataset,
    on_phase: &(dyn Fn(RunPhase) + Sync),
) -> Result<PipelineOutput> {
    if !cfg.workers.is_empty() {
        validate_liveness(cfg)?;
        if cfg.io_driver == IoDriver::Reactor {
            #[cfg(unix)]
            {
                return run_reactor_socket(cfg, data, on_phase);
            }
            #[cfg(not(unix))]
            {
                return Err(Error::Config(
                    "--io-driver reactor needs a unix poll(2) host; \
                     use --io-driver threads"
                        .into(),
                ));
            }
        }
        let transport = build_socket_transport(cfg)?;
        return run_with_transport_events(cfg, data, &transport, on_phase);
    }
    if !cfg.process_mode {
        return run_native_events(cfg, data, on_phase);
    }
    let worker_bin: PathBuf = if cfg.worker_bin.is_empty() {
        std::env::current_exe()?
    } else {
        PathBuf::from(&cfg.worker_bin)
    };
    let slots = if cfg.worker_slots == 0 {
        cfg.machines
    } else {
        cfg.worker_slots
    };
    let mut transport = PipeTransport::new(worker_bin, slots);
    if cfg.max_frame_bytes != 0 {
        transport = transport.with_max_frame_bytes(cfg.max_frame_bytes);
    }
    run_with_transport_events(cfg, data, &transport, on_phase)
}

/// Reject a liveness deadline no longer than the heartbeat interval —
/// such a deadline declares healthy workers dead between beacons.
fn validate_liveness(cfg: &PipelineConfig) -> Result<()> {
    if cfg.liveness_timeout_secs > 0
        && cfg.heartbeat_secs > 0
        && cfg.liveness_timeout_secs <= cfg.heartbeat_secs
    {
        return Err(Error::Config(format!(
            "liveness_timeout_secs ({}) must exceed heartbeat_secs \
             ({}) — a deadline no longer than the beacon interval \
             declares healthy workers dead",
            cfg.liveness_timeout_secs, cfg.heartbeat_secs
        )));
    }
    Ok(())
}

/// Build the [`SocketTransport`] that `cfg.workers` describes — inline
/// shards, connect timeout, liveness read deadline, frame cap — after
/// validating the heartbeat/liveness pairing. Shared by
/// [`run_process`] and the daemon's job runner
/// (`coordinator::server::jobs`), so a submitted job dials its
/// endpoints with exactly the tuning a solo CLI run would.
pub(crate) fn build_socket_transport(
    cfg: &PipelineConfig,
) -> Result<SocketTransport> {
    validate_liveness(cfg)?;
    let mut transport = SocketTransport::from_spec(&cfg.workers)?
        .with_inline_shards(cfg.shard_inline)
        .with_connect_timeout(Duration::from_secs(
            cfg.connect_timeout_secs as u64,
        ))
        .with_read_deadline(
            (cfg.liveness_timeout_secs > 0).then(|| {
                Duration::from_secs(cfg.liveness_timeout_secs as u64)
            }),
        );
    if cfg.max_frame_bytes != 0 {
        transport = transport.with_max_frame_bytes(cfg.max_frame_bytes);
    }
    Ok(transport)
}

/// Run the pipeline over any [`Transport`] — the paper's actual
/// deployment shape ("machines communicate only at the final
/// combination stage"), generalized from PR 2's one-child-per-machine
/// process mode.
///
/// The leader spills each machine's shard (in `cfg.shard_format`) plus
/// a [`WorkerManifest`] into a fresh [`RunDir`], then schedules the M
/// manifests onto the transport's W endpoints. When W < M the
/// endpoints are **oversubscribed**: manifests queue and are assigned
/// to whichever endpoint frees up first. Because machine m's RNG
/// stream is `root.split(m)` — a function of the manifest, not the
/// endpoint — the retained draws are byte-identical to [`run_native`]
/// regardless of W, assignment order, or transport.
///
/// The first failure anywhere fails fast: it stops further
/// assignments, cancels every in-flight worker through
/// [`Transport::cancel_all`] (pipe children are killed; socket daemons
/// abort their chains at the next failed draw write), and surfaces as
/// the run's root-cause error.
pub fn run_with_transport(
    cfg: &PipelineConfig,
    data: &Dataset,
    transport: &dyn Transport,
) -> Result<PipelineOutput> {
    run_with_transport_events(cfg, data, transport, &|_| {})
}

/// [`run_with_transport`] with lifecycle events for the daemon's job
/// state machine; see [`RunPhase`].
pub fn run_with_transport_events(
    cfg: &PipelineConfig,
    data: &Dataset,
    transport: &dyn Transport,
    on_phase: &(dyn Fn(RunPhase) + Sync),
) -> Result<PipelineOutput> {
    validate_combine_backend(cfg)?;
    let dim = data.param_dim();
    let t0 = Instant::now();
    let run_dir = RunDir::create(cfg.seed)?;
    let (manifests, manifest_paths) = spill_assignments(
        cfg,
        data,
        &run_dir,
        transport.wants_inline_shard(),
    )?;

    let slots = transport.slots().clamp(1, cfg.machines);
    let (tx, rx) = channel::<LeaderMsg>();
    let results: Mutex<Vec<Option<SubposteriorSamples>>> =
        Mutex::new((0..cfg.machines).map(|_| None).collect());
    // First root-cause failure (first writer wins); setting `abort`
    // stops every endpoint loop from pulling further assignments, so a
    // doomed run fails after at most one in-flight job per endpoint. A
    // `None` result slot below therefore always comes with a root_err
    // to surface.
    let root_err: Mutex<Option<Error>> = Mutex::new(None);
    let abort = AtomicBool::new(false);
    let mut leader =
        Leader::with_store_config(cfg.machines, dim, store_config(cfg));
    leader.set_combine_threads(cfg.combine_threads);
    leader.set_combine_cache_budget(cache_budget_bytes(cfg));
    leader.set_combine_kernel(cfg.combine_backend);
    // Resilience accounting, stamped onto the metrics after the run.
    let retries = AtomicUsize::new(0);
    let quarantines = AtomicUsize::new(0);
    let missed = AtomicUsize::new(0);
    // Elapsed nanos of the first draw to land anywhere (first writer
    // wins across endpoint threads); `u64::MAX` = none yet. Mirrors
    // the reactor driver's `time_to_first_draw_ms` so the daemon can
    // report the per-job row under either io-driver.
    let first_draw_nanos = AtomicU64::new(u64::MAX);
    on_phase(RunPhase::Sampling);
    let drained = match cfg.failure_policy {
        FailurePolicy::Failfast => {
            let next_machine = AtomicUsize::new(0);
            std::thread::scope(|scope| -> Result<()> {
                for slot in 0..slots {
                    let tx = tx.clone();
                    let manifests = &manifests;
                    let manifest_paths = &manifest_paths;
                    let results = &results;
                    let root_err = &root_err;
                    let abort = &abort;
                    let next_machine = &next_machine;
                    let first_draw_nanos = &first_draw_nanos;
                    scope.spawn(move || {
                        // One endpoint's assignment loop: pull queued
                        // machines until the queue is empty or the run
                        // is aborted.
                        while !abort.load(Ordering::SeqCst) {
                            let m =
                                next_machine.fetch_add(1, Ordering::SeqCst);
                            if m >= manifests.len() {
                                break;
                            }
                            match run_assignment(
                                transport,
                                slot,
                                &manifests[m],
                                &manifest_paths[m],
                                dim,
                                &tx,
                                t0,
                                first_draw_nanos,
                            ) {
                                Ok(out) => {
                                    results.lock().unwrap()[m] = Some(out);
                                }
                                Err(e) => {
                                    // Fail fast: kill every in-flight
                                    // sibling (pipe children die
                                    // outright; socket daemons abort at
                                    // their next failed draw write)
                                    // instead of letting healthy
                                    // workers finish a doomed run.
                                    // Their threads surface secondary
                                    // errors, but first-write-wins
                                    // keeps this one as the root cause.
                                    fail_run(root_err, abort, transport, e);
                                    break;
                                }
                            }
                        }
                    });
                }
                drop(tx);
                leader.drain_stream(&rx)?;
                Ok(())
            })
        }
        FailurePolicy::Retry => {
            let max_attempts = cfg.max_retries.saturating_add(1);
            // Requeueable work: failed machines go to the back after
            // their partial rows are reset, so surviving endpoints pick
            // them up.
            let pending: Mutex<VecDeque<usize>> =
                Mutex::new((0..cfg.machines).collect());
            // Wakes idle endpoints the moment work requeues or the
            // run resolves — replacing the old 10 ms sleep-poll, which
            // cost up to a sleep of tail latency per requeue and kept
            // idle endpoint threads busy-waiting.
            let sched_cv = Condvar::new();
            let attempts: Mutex<Vec<usize>> =
                Mutex::new(vec![0; cfg.machines]);
            let slot_failures: Mutex<Vec<usize>> =
                Mutex::new(vec![0; slots]);
            // Every failed attempt, endpoint and cause included — the
            // structured diagnostic when the run ultimately fails.
            let attempt_log: Mutex<Vec<String>> = Mutex::new(Vec::new());
            let live_endpoints = AtomicUsize::new(slots);
            let completed = AtomicUsize::new(0);
            std::thread::scope(|scope| -> Result<()> {
                for slot in 0..slots {
                    let tx = tx.clone();
                    let manifests = &manifests;
                    let manifest_paths = &manifest_paths;
                    let results = &results;
                    let root_err = &root_err;
                    let abort = &abort;
                    let pending = &pending;
                    let sched_cv = &sched_cv;
                    let attempts = &attempts;
                    let slot_failures = &slot_failures;
                    let attempt_log = &attempt_log;
                    let live_endpoints = &live_endpoints;
                    let completed = &completed;
                    let retries = &retries;
                    let quarantines = &quarantines;
                    let missed = &missed;
                    let first_draw_nanos = &first_draw_nanos;
                    scope.spawn(move || loop {
                        if abort.load(Ordering::SeqCst) {
                            break;
                        }
                        // Queue empty but machines may still be in
                        // flight on other endpoints — and a flight can
                        // fail and requeue, so idle endpoints park on
                        // the Condvar until a completion or requeue
                        // signals (the timeout only backstops a
                        // notification racing in before the park).
                        let m = {
                            let mut q = pending.lock().unwrap();
                            loop {
                                if abort.load(Ordering::SeqCst)
                                    || completed.load(Ordering::SeqCst)
                                        >= cfg.machines
                                {
                                    break None;
                                }
                                if let Some(m) = q.pop_front() {
                                    break Some(m);
                                }
                                q = sched_cv
                                    .wait_timeout(
                                        q,
                                        Duration::from_millis(500),
                                    )
                                    .unwrap()
                                    .0;
                            }
                        };
                        let Some(m) = m else {
                            break;
                        };
                        let attempt = {
                            let mut a = attempts.lock().unwrap();
                            a[m] += 1;
                            a[m]
                        };
                        match run_assignment(
                            transport,
                            slot,
                            &manifests[m],
                            &manifest_paths[m],
                            dim,
                            &tx,
                            t0,
                            first_draw_nanos,
                        ) {
                            Ok(out) => {
                                results.lock().unwrap()[m] = Some(out);
                                completed.fetch_add(1, Ordering::SeqCst);
                                // The last completion releases every
                                // parked endpoint to exit.
                                sched_cv.notify_all();
                            }
                            Err(e) => {
                                if e.to_string()
                                    .contains(LIVENESS_EXPIRED_MARKER)
                                {
                                    missed.fetch_add(1, Ordering::SeqCst);
                                }
                                attempt_log.lock().unwrap().push(format!(
                                    "machine {m} attempt \
                                     {attempt}/{max_attempts} on endpoint \
                                     {slot}: {e}"
                                ));
                                // Discard the failed attempt's partial
                                // rows before any retry traffic can
                                // land behind them. This machine has
                                // exactly one live sender (this
                                // thread), so on the leader's FIFO
                                // channel the Reset is ordered after
                                // the partial stream and before the
                                // retry's.
                                let _ = tx
                                    .send(LeaderMsg::Reset { machine: m });
                                if attempt >= max_attempts {
                                    fail_run(
                                        root_err,
                                        abort,
                                        transport,
                                        Error::Runtime(format!(
                                            "machine {m}: retries \
                                             exhausted after \
                                             {max_attempts} attempts:\n  {}",
                                            attempt_log
                                                .lock()
                                                .unwrap()
                                                .join("\n  ")
                                        )),
                                    );
                                    // Parked siblings must observe the
                                    // abort, not wait out the backstop.
                                    sched_cv.notify_all();
                                    break;
                                }
                                retries.fetch_add(1, Ordering::SeqCst);
                                let quarantine_now = {
                                    let mut sf =
                                        slot_failures.lock().unwrap();
                                    sf[slot] += 1;
                                    sf[slot] >= QUARANTINE_AFTER
                                };
                                // Capped exponential backoff on the
                                // failing endpoint; the shard requeues
                                // after the sleep so a healthy sibling
                                // is not held up waiting on it.
                                let backoff_ms = (RETRY_BACKOFF_BASE_MS
                                    << (attempt - 1).min(4))
                                .min(RETRY_BACKOFF_CAP_MS);
                                std::thread::sleep(Duration::from_millis(
                                    backoff_ms,
                                ));
                                pending.lock().unwrap().push_back(m);
                                // Hand the requeued machine to an idle
                                // endpoint immediately.
                                sched_cv.notify_all();
                                if quarantine_now {
                                    quarantines
                                        .fetch_add(1, Ordering::SeqCst);
                                    if live_endpoints
                                        .fetch_sub(1, Ordering::SeqCst)
                                        == 1
                                    {
                                        // This was the last live
                                        // endpoint and it just failed a
                                        // machine, so work is
                                        // outstanding with nowhere to
                                        // run it.
                                        fail_run(
                                            root_err,
                                            abort,
                                            transport,
                                            Error::Runtime(format!(
                                                "all {slots} worker \
                                                 endpoints quarantined \
                                                 after repeated \
                                                 failures:\n  {}",
                                                attempt_log
                                                    .lock()
                                                    .unwrap()
                                                    .join("\n  ")
                                            )),
                                        );
                                        sched_cv.notify_all();
                                    }
                                    break;
                                }
                            }
                        }
                    });
                }
                drop(tx);
                // No `all_finished` early exit here: under retry a
                // machine can finish and *then* a sibling's failure
                // arrives, so completion is not stable until every
                // sender is gone — exiting early would strand Reset
                // messages and land a retried stream on top of the
                // failed prefix.
                leader.drain_stream_all(&rx)?;
                Ok(())
            })
        }
    };
    drained?;
    if let Some(e) = root_err.into_inner().unwrap() {
        return Err(e);
    }

    let subposteriors: Vec<SubposteriorSamples> = results
        .into_inner()
        .unwrap()
        .into_iter()
        .map(|o| o.ok_or_else(|| Error::Runtime("worker died".into())))
        .collect::<Result<_>>()?;

    on_phase(RunPhase::Combining);
    let mut out = finish_run(
        cfg,
        subposteriors,
        leader.scalars_received,
        t0,
        Some(&leader),
    )?;
    out.metrics.shard_retries = retries.load(Ordering::SeqCst);
    out.metrics.endpoints_quarantined = quarantines.load(Ordering::SeqCst);
    out.metrics.heartbeats_missed = missed.load(Ordering::SeqCst);
    let first = first_draw_nanos.load(Ordering::SeqCst);
    if first != u64::MAX {
        out.metrics.time_to_first_draw_ms = first as f64 / 1e6;
    }
    out.run_dir = Some(run_dir);
    Ok(out)
}

/// Spill every shard + manifest up front: assignments are pulled off
/// a queue by whichever endpoint frees up first, so all files must
/// exist before the first connection. Shared by the threads driver
/// ([`run_with_transport`]) and the reactor driver — both see the
/// same manifests, which is what carries the byte-identity contract
/// across `--io-driver` values.
fn spill_assignments(
    cfg: &PipelineConfig,
    data: &Dataset,
    run_dir: &RunDir,
    inline_shards: bool,
) -> Result<(Vec<WorkerManifest>, Vec<PathBuf>)> {
    let shards =
        Partitioner::Contiguous.split(data.len(), cfg.machines, cfg.seed)?;
    let prior_w = 1.0 / cfg.machines as f64;
    let dim = data.param_dim();
    let mut manifests = Vec::with_capacity(cfg.machines);
    let mut manifest_paths = Vec::with_capacity(cfg.machines);
    for (m, shard) in shards.iter().enumerate() {
        let shard_path = run_dir.path().join(format!(
            "shard_{m}.{}",
            cfg.shard_format.extension()
        ));
        io::write_shard(&shard_path, &data.select(shard)?, cfg.shard_format)?;
        let manifest = WorkerManifest {
            machine: m,
            machines: cfg.machines,
            seed: cfg.seed,
            samples: cfg.samples_per_machine,
            burn_in: cfg.burn_in,
            thin: cfg.thin,
            prior_weight: prior_w,
            sampler: config::sampler_spec(&cfg.sampler),
            shard_path: shard_path.to_string_lossy().into_owned(),
            dim,
            // The transport decides shard delivery: inline frames for
            // socket fleets without a shared filesystem, path mode
            // otherwise. Setting it on the manifest keeps leader and
            // worker in lockstep about the frame sequence.
            shard_inline: inline_shards,
            // The draw plane: JSON per-draw frames or batched binary
            // chunks. Negotiated through the manifest so a worker that
            // predates the binary plane simply ignores the fields and
            // streams JSON, which the leader accepts frame-by-frame.
            wire_format: cfg.wire_format,
            draw_batch: cfg.draw_batch,
            // Manifest-negotiated heartbeats: a worker that predates
            // RPHB beacons ignores the field and never beacons, which
            // is only fatal if the leader also armed a liveness
            // deadline — exactly the contract the knobs document.
            heartbeat_secs: cfg.heartbeat_secs,
        };
        let manifest_path = run_dir.path().join(format!("worker_{m}.json"));
        manifest.save(&manifest_path)?;
        manifests.push(manifest);
        manifest_paths.push(manifest_path);
    }
    Ok((manifests, manifest_paths))
}

/// Socket mode under `--io-driver reactor`: same spill prelude, same
/// leader drain, same failure-policy semantics — but the W endpoints
/// are multiplexed by the `poll(2)` reactor pool
/// ([`crate::coordinator::reactor`]) instead of W blocking threads, so
/// the leader's thread count is independent of W. Retained draws are
/// byte-identical to the threads driver by construction: the reactor
/// consumes the same manifests and only changes *when* bytes arrive.
#[cfg(unix)]
fn run_reactor_socket(
    cfg: &PipelineConfig,
    data: &Dataset,
    on_phase: &(dyn Fn(RunPhase) + Sync),
) -> Result<PipelineOutput> {
    use crate::coordinator::reactor;
    use crate::coordinator::transport::DEFAULT_MAX_FRAME_BYTES;

    validate_combine_backend(cfg)?;
    let addrs: Vec<String> = cfg
        .workers
        .split(',')
        .map(|a| a.trim().to_string())
        .filter(|a| !a.is_empty())
        .collect();
    if addrs.is_empty() {
        return Err(Error::Config(
            "socket transport needs at least one worker address".into(),
        ));
    }
    let dim = data.param_dim();
    let t0 = Instant::now();
    let run_dir = RunDir::create(cfg.seed)?;
    let (manifests, _manifest_paths) =
        spill_assignments(cfg, data, &run_dir, cfg.shard_inline)?;
    let rcfg = reactor::ReactorConfig {
        addrs,
        connect_timeout: Duration::from_secs(
            cfg.connect_timeout_secs as u64,
        ),
        liveness: (cfg.liveness_timeout_secs > 0)
            .then(|| Duration::from_secs(cfg.liveness_timeout_secs as u64)),
        max_frame_bytes: if cfg.max_frame_bytes != 0 {
            cfg.max_frame_bytes
        } else {
            DEFAULT_MAX_FRAME_BYTES
        },
        failure_policy: cfg.failure_policy,
        max_retries: cfg.max_retries,
        reactor_threads: cfg.reactor_threads,
        dim,
    };
    let (tx, rx) = channel::<LeaderMsg>();
    let mut leader =
        Leader::with_store_config(cfg.machines, dim, store_config(cfg));
    leader.set_combine_threads(cfg.combine_threads);
    leader.set_combine_cache_budget(cache_budget_bytes(cfg));
    leader.set_combine_kernel(cfg.combine_backend);
    on_phase(RunPhase::Sampling);
    let outcome = std::thread::scope(
        |scope| -> Result<reactor::ReactorOutcome> {
            let manifests = &manifests;
            let rcfg = &rcfg;
            let handle = scope
                .spawn(move || reactor::run_reactor(rcfg, manifests, tx));
            match cfg.failure_policy {
                FailurePolicy::Failfast => leader.drain_stream(&rx)?,
                FailurePolicy::Retry => leader.drain_stream_all(&rx)?,
            }
            handle
                .join()
                .map_err(|_| Error::Runtime("reactor pool panicked".into()))
        },
    )?;
    if let Some(e) = outcome.root_err {
        return Err(e);
    }
    let subposteriors: Vec<SubposteriorSamples> = outcome
        .results
        .into_iter()
        .map(|o| o.ok_or_else(|| Error::Runtime("worker died".into())))
        .collect::<Result<_>>()?;
    on_phase(RunPhase::Combining);
    let mut out = finish_run(
        cfg,
        subposteriors,
        leader.scalars_received,
        t0,
        Some(&leader),
    )?;
    out.metrics.shard_retries = outcome.retries;
    out.metrics.endpoints_quarantined = outcome.quarantines;
    out.metrics.heartbeats_missed = outcome.missed;
    out.metrics.reactor_wakeups = outcome.wakeups;
    out.metrics.time_to_first_draw_ms =
        outcome.time_to_first_draw_ms.unwrap_or(0.0);
    out.metrics.endpoint_busy = outcome.endpoint_busy;
    out.run_dir = Some(run_dir);
    Ok(out)
}

/// Total failures after which an endpoint is benched under the retry
/// policy: the job proceeds on the surviving pool and the endpoint is
/// never dialed again this run. Shared with the reactor driver so both
/// schedulers bench endpoints on the same evidence.
pub(crate) const QUARANTINE_AFTER: usize = 2;

/// Capped exponential backoff before a failed shard requeues:
/// `base · 2^(attempt-1)`, capped. Shared with the reactor driver,
/// which serves the same schedule from its poll timeout instead of a
/// thread sleep.
pub(crate) const RETRY_BACKOFF_BASE_MS: u64 = 100;
pub(crate) const RETRY_BACKOFF_CAP_MS: u64 = 2_000;

/// Stamp the elapsed nanos of the run's first landed draw (first
/// writer wins across endpoint threads). The cheap relaxed load makes
/// the steady-state cost of this per-draw call one uncontended read.
fn record_first_draw(t0: Instant, first: &AtomicU64) {
    if first.load(Ordering::Relaxed) != u64::MAX {
        return;
    }
    let nanos = t0.elapsed().as_nanos() as u64;
    let _ = first.compare_exchange(
        u64::MAX,
        nanos.max(1),
        Ordering::SeqCst,
        Ordering::SeqCst,
    );
}

/// Record `e` as the run's root cause (first writer wins), flag the
/// abort, and cancel every in-flight worker through the transport.
fn fail_run(
    root_err: &Mutex<Option<Error>>,
    abort: &AtomicBool,
    transport: &dyn Transport,
    e: Error,
) {
    {
        let mut first = root_err.lock().unwrap();
        if first.is_none() {
            *first = Some(e);
        }
    }
    abort.store(true, Ordering::SeqCst);
    transport.cancel_all();
}

/// Execute one manifest on one transport endpoint: open the
/// connection, forward every draw into the leader's channel, rebuild
/// the machine's [`SubposteriorSamples`] from the stream plus the
/// final summary frame, and surface worker-side diagnostics (exit
/// status + stderr for pipe children, in-band error frames for socket
/// daemons). On an error return the connection has been dropped, which
/// cancels a still-running pipe child.
#[allow(clippy::too_many_arguments)]
fn run_assignment(
    transport: &dyn Transport,
    slot: usize,
    manifest: &WorkerManifest,
    manifest_path: &Path,
    dim: usize,
    tx: &Sender<LeaderMsg>,
    t0: Instant,
    first_draw_nanos: &AtomicU64,
) -> Result<SubposteriorSamples> {
    let machine = manifest.machine;
    let mut conn = transport.connect(slot, manifest, manifest_path)?;
    let mut samples = SampleMatrix::new(dim);
    let mut draw_times = Vec::new();
    let mut summary: Option<WorkerSummary> = None;
    loop {
        let msg = match conn.recv() {
            Ok(Some(msg)) => msg,
            Ok(None) => break,
            Err(e) => {
                return Err(Error::Runtime(format!(
                    "worker {machine} ({} transport): bad frame: {e}",
                    transport.name()
                )));
            }
        };
        match msg {
            WireMsg::Draw(d) => {
                record_first_draw(t0, first_draw_nanos);
                if d.machine != machine || d.theta.len() != dim {
                    return Err(Error::Runtime(format!(
                        "worker {machine}: draw for machine {} with dim {}",
                        d.machine,
                        d.theta.len()
                    )));
                }
                samples.push(&d.theta);
                draw_times.push(d.elapsed);
                // Leader hung up → keep draining (mirrors thread mode).
                let _ = tx.send(LeaderMsg::Draw(d));
            }
            WireMsg::Chunk(chunk) => {
                record_first_draw(t0, first_draw_nanos);
                if chunk.machine != machine
                    || chunk.dim != dim
                    || chunk.thetas.len() != chunk.elapsed.len() * dim
                {
                    return Err(Error::Runtime(format!(
                        "worker {machine}: chunk for machine {} with dim {} \
                         ({} scalars, {} rows)",
                        chunk.machine,
                        chunk.dim,
                        chunk.thetas.len(),
                        chunk.elapsed.len()
                    )));
                }
                // Batched landing: the whole chunk memcpys into the
                // per-machine matrix — no per-draw Vec, no Json tree.
                samples.push_rows(&chunk.thetas);
                draw_times.extend_from_slice(&chunk.elapsed);
                // Move the decoded buffers to the leader (no copy).
                let _ = tx.send(LeaderMsg::Chunk(chunk));
            }
            WireMsg::Summary(s) => {
                if s.machine != machine {
                    return Err(Error::Runtime(format!(
                        "worker {machine}: summary for machine {}",
                        s.machine
                    )));
                }
                summary = Some(s);
            }
            WireMsg::Error { machine: from, message } => {
                return Err(Error::Runtime(format!(
                    "worker {from}: remote failure: {message}"
                )));
            }
            WireMsg::Heartbeat { machine: from } => {
                if from != machine {
                    return Err(Error::Runtime(format!(
                        "worker {machine}: heartbeat for machine {from}"
                    )));
                }
                // Liveness beacon only: its arrival already reset the
                // socket read deadline; nothing lands.
            }
        }
    }
    // Clean end-of-stream: let the endpoint report exit diagnostics
    // (a crashed pipe child surfaces its stderr here) before the
    // missing-summary check, so the root cause wins.
    conn.finish()?;
    let summary = summary.ok_or_else(|| {
        Error::Runtime(format!(
            "worker {machine}: stream ended without a summary frame"
        ))
    })?;
    Ok(SubposteriorSamples {
        machine,
        samples,
        accept_rate: summary.accept_rate,
        wall_secs: summary.wall_secs,
        draw_times,
    })
}

/// Run the pipeline over pre-built subposterior models, sequentially on
/// the calling thread. This is the path for PJRT-runtime-backed models
/// (the XLA client is not `Send`); per-worker wall-clocks are still
/// measured individually so [`ClusterTiming`] models the parallel
/// cluster the paper ran on.
pub fn run_sequential(
    cfg: &PipelineConfig,
    models: Vec<Box<dyn LogDensity + '_>>,
) -> Result<PipelineOutput> {
    if models.len() != cfg.machines {
        return Err(Error::Config(format!(
            "{} models for {} machines",
            models.len(),
            cfg.machines
        )));
    }
    validate_combine_backend(cfg)?;
    let t0 = Instant::now();
    let mut root = Pcg64::seed_from(cfg.seed);
    let mut subposteriors = Vec::with_capacity(cfg.machines);
    let mut scalars = 0usize;
    for (m, target) in models.iter().enumerate() {
        let rng = root.split(m as u64);
        let sampler = cfg.sampler.build(target.dim());
        let out = run_worker(
            m,
            target.as_ref(),
            sampler,
            cfg.samples_per_machine,
            cfg.burn_in,
            cfg.thin,
            rng,
            None,
        );
        scalars += out.samples.len() * out.samples.dim();
        subposteriors.push(out);
    }
    finish_run(cfg, subposteriors, scalars, t0, None)
}

fn finish_run(
    cfg: &PipelineConfig,
    subposteriors: Vec<SubposteriorSamples>,
    scalars: usize,
    t0: Instant,
    leader: Option<&Leader>,
) -> Result<PipelineOutput> {
    let tc = Instant::now();
    // Combine-stage tuning (threads, cache budget, kernel backend):
    // deterministic for a fixed seed at any value of any knob — CPU
    // kernel backends are bit-identical — so this only affects
    // wall-clock/memory. With a leader present the final combine runs
    // over its draw stores (dense or spill-backed, byte-identical
    // either way); the sequential path holds no leader and combines
    // the dense per-machine matrices directly.
    let combined = match leader {
        Some(leader) => {
            leader.draws(cfg.method, cfg.t_out, cfg.seed ^ 0x5EED)?
        }
        None => combine::combine_with(
            cfg.method,
            &subposteriors,
            cfg.t_out,
            cfg.seed ^ 0x5EED,
            &combine_tuning(cfg),
        )?,
    };
    let combine_secs = tc.elapsed().as_secs_f64();

    let draw_stats = leader.map(Leader::draw_stats).unwrap_or_default();
    let timing = ClusterTiming::from_run(&subposteriors, combine_secs);
    let metrics = RunMetrics {
        machines: cfg.machines,
        samples_per_machine: cfg.samples_per_machine,
        param_dim: combined.dim(),
        accept_rates: subposteriors.iter().map(|s| s.accept_rate).collect(),
        worker_secs: subposteriors.iter().map(|s| s.wall_secs).collect(),
        scalars_transferred: scalars,
        combine_secs,
        total_secs: t0.elapsed().as_secs_f64(),
        draw_peak_bytes: draw_stats.peak_resident_bytes,
        draw_spilled_bytes: draw_stats.spilled_bytes,
        // Resilience counters and reactor telemetry are owned by the
        // transport scheduler, which stamps them after this returns;
        // thread/sequential runs have no endpoints to retry, quarantine
        // or poll.
        shard_retries: 0,
        endpoints_quarantined: 0,
        heartbeats_missed: 0,
        reactor_wakeups: 0,
        time_to_first_draw_ms: 0.0,
        endpoint_busy: Vec::new(),
        // Job accounting belongs to the daemon (`repro leaderd`),
        // which aggregates it across runs; a single pipeline run is
        // not itself a job.
        jobs_accepted: 0,
        jobs_failed: 0,
        job_queue_wait_ms: Vec::new(),
    };
    Ok(PipelineOutput {
        subposteriors,
        combined,
        metrics,
        timing,
        run_dir: None,
    })
}

/// Run a single full-data chain (the `regularChain` baseline).
pub fn run_single_chain(
    cfg: &PipelineConfig,
    data: &Dataset,
) -> Result<SubposteriorSamples> {
    let target = data.full_posterior()?;
    let mut rng = Pcg64::seed_from(cfg.seed ^ 0xF0F0);
    let sampler = cfg.sampler.build(target.dim());
    Ok(run_worker(
        0,
        target.as_ref(),
        sampler,
        cfg.samples_per_machine,
        cfg.burn_in,
        cfg.thin,
        rng.split(0),
        None,
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::combine::CombineMethod;
    use crate::data::synth;

    fn cfg(machines: usize, t: usize) -> PipelineConfig {
        PipelineConfig::builder("gaussian")
            .machines(machines)
            .samples_per_machine(t)
            .method(CombineMethod::Parametric)
            .seed(11)
            .build()
    }

    #[test]
    fn native_pipeline_recovers_posterior_mean() {
        let data = synth::gaussian(4000, 2, 5);
        let out = run_native(&cfg(4, 800), &data).unwrap();
        assert_eq!(out.subposteriors.len(), 4);
        assert_eq!(out.combined.len(), 800);
        // Posterior mean ≈ sample mean of the data (n large, weak prior).
        let mean = out.combined.mean();
        assert!((mean[0] - 1.0).abs() < 0.1, "mean0 {}", mean[0]);
        assert!((mean[1] - 1.1).abs() < 0.1, "mean1 {}", mean[1]);
        assert_eq!(
            out.metrics.scalars_transferred,
            4 * 800 * 2,
            "O(dTM) communication"
        );
        assert!(out.timing.total_secs() > 0.0);
    }

    #[test]
    fn thread_cap_does_not_change_results_count() {
        let data = synth::gaussian(1000, 2, 6);
        let mut c = cfg(6, 200);
        c.threads = 2; // fewer threads than machines
        let out = run_native(&c, &data).unwrap();
        assert_eq!(out.subposteriors.len(), 6);
        for s in &out.subposteriors {
            assert_eq!(s.samples.len(), 200);
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let data = synth::gaussian(500, 1, 7);
        let a = run_native(&cfg(2, 100), &data).unwrap();
        let b = run_native(&cfg(2, 100), &data).unwrap();
        for (sa, sb) in a.subposteriors.iter().zip(&b.subposteriors) {
            assert_eq!(sa.samples.as_slice(), sb.samples.as_slice());
        }
        assert_eq!(a.combined.as_slice(), b.combined.as_slice());
    }

    /// The combine stage must be byte-identical whatever thread count
    /// the leader is given (1, 4, or auto) — including through the full
    /// pipeline with an IMG-based method.
    #[test]
    fn combine_threads_do_not_change_output() {
        let data = synth::gaussian(1200, 2, 12);
        let make = |combine_threads: usize| {
            let mut c = cfg(3, 300);
            c.method = CombineMethod::Nonparametric;
            c.combine_threads = combine_threads;
            run_native(&c, &data).unwrap()
        };
        let base = make(1);
        for t in [4usize, 0] {
            let out = make(t);
            assert_eq!(
                base.combined.as_slice(),
                out.combined.as_slice(),
                "combine_threads {t} diverged"
            );
        }
    }

    /// RNG-stream contract: `run_native` (threads) and `run_sequential`
    /// both derive worker m's generator as `root.split(m)` from the
    /// same root seed, so the two paths must produce byte-identical
    /// subposterior draws (the process path is locked to the same
    /// contract in `rust/tests/process_pipeline.rs`, which spawns real
    /// child processes).
    #[test]
    fn native_and_sequential_share_worker_rng_streams() {
        let data = synth::gaussian(900, 2, 13);
        let c = cfg(3, 120);
        let native = run_native(&c, &data).unwrap();
        let shards =
            Partitioner::Contiguous.split(900, 3, c.seed).unwrap();
        let models: Vec<Box<dyn LogDensity>> = shards
            .iter()
            .map(|idx| data.subposterior(idx, 1.0 / 3.0).unwrap())
            .collect();
        let seq = run_sequential(&c, models).unwrap();
        for (a, b) in native.subposteriors.iter().zip(&seq.subposteriors) {
            assert_eq!(
                a.samples.as_slice(),
                b.samples.as_slice(),
                "machine {} diverged between thread and sequential paths",
                a.machine
            );
        }
        assert_eq!(native.combined.as_slice(), seq.combined.as_slice());
    }

    #[test]
    fn sequential_matches_machine_count() {
        let data = synth::gaussian(600, 1, 8);
        let shards = Partitioner::Contiguous.split(600, 3, 0).unwrap();
        let models: Vec<Box<dyn LogDensity>> = shards
            .iter()
            .map(|idx| data.subposterior(idx, 1.0 / 3.0).unwrap())
            .collect();
        let out = run_sequential(&cfg(3, 150), models).unwrap();
        assert_eq!(out.subposteriors.len(), 3);
        assert_eq!(out.combined.len(), 150);
    }

    #[test]
    fn single_chain_baseline_runs() {
        let data = synth::gaussian(500, 2, 9);
        let out = run_single_chain(&cfg(1, 300), &data).unwrap();
        assert_eq!(out.samples.len(), 300);
        let mean = out.samples.mean();
        assert!((mean[0] - 1.0).abs() < 0.15, "mean {:?}", mean);
    }

    /// Satellite gate: a tiny configured anneal-cache budget must fall
    /// back to in-place recomputation with **bit-identical** combined
    /// output — the budget is a memory knob, never a result knob — all
    /// the way from the config key through the pipeline.
    #[test]
    fn tiny_combine_cache_budget_is_bit_identical_through_pipeline() {
        let data = synth::gaussian(1000, 2, 21);
        let make = |budget_mb: usize| {
            let mut c = cfg(3, 250);
            c.method = CombineMethod::Semiparametric;
            c.combine_cache_budget_mb = budget_mb;
            run_native(&c, &data).unwrap()
        };
        let default = make(256);
        let tiny = make(0); // floor: a single cached entry
        assert_eq!(
            default.combined.as_slice(),
            tiny.combined.as_slice(),
            "cache budget changed the combined draws"
        );
    }

    /// Tentpole gate: a spill-configured draw plane (any chunk size,
    /// any budget — including "spill everything") must produce
    /// byte-identical combined draws to the dense default, all the way
    /// from the `chunk_rows` / `draw_spill_budget_mb` config keys
    /// through the leader's stores and the store-backed combine, while
    /// the metrics report the spill.
    #[test]
    fn spill_budget_is_bit_identical_through_pipeline() {
        let data = synth::gaussian(1000, 2, 22);
        let make = |budget_mb: Option<usize>, chunk: usize| {
            let mut c = cfg(3, 250);
            c.method = CombineMethod::Semiparametric;
            c.draw_spill_budget_mb = budget_mb;
            c.chunk_rows = chunk;
            run_native(&c, &data).unwrap()
        };
        let dense = make(None, crate::data::store::DEFAULT_CHUNK_ROWS);
        assert_eq!(dense.metrics.draw_spilled_bytes, 0);
        assert_eq!(
            dense.metrics.draw_peak_bytes,
            3 * 250 * 2 * 8,
            "dense peak = every retained scalar resident"
        );
        for (budget_mb, chunk) in [(Some(0), 1), (Some(0), 7), (Some(1), 64)]
        {
            let run = make(budget_mb, chunk);
            assert_eq!(
                dense.combined.as_slice(),
                run.combined.as_slice(),
                "budget {budget_mb:?} chunk {chunk} changed the draws"
            );
        }
        // Budget 0: every sealed chunk spills, so the disk holds all
        // but each machine's unsealed tail and the peak stays bounded.
        let spill = make(Some(0), 7);
        assert!(spill.metrics.draw_spilled_bytes > 0);
        assert!(
            spill.metrics.draw_peak_bytes < dense.metrics.draw_peak_bytes,
            "spill peak {} must undercut dense peak {}",
            spill.metrics.draw_peak_bytes,
            dense.metrics.draw_peak_bytes
        );
    }

    /// Tentpole gate at the pipeline level: the blocked compute kernel
    /// must produce byte-identical retained draws to the naive
    /// reference, all the way from the `combine_backend` config key
    /// through the leader and combiner.
    #[test]
    fn blocked_combine_backend_is_bit_identical_through_pipeline() {
        use crate::kernel::CombineKernelKind;
        let data = synth::gaussian(1_200, 2, 29);
        let make = |backend: CombineKernelKind| {
            let mut c = cfg(3, 250);
            c.method = CombineMethod::Semiparametric;
            c.combine_backend = backend;
            run_native(&c, &data).unwrap()
        };
        let naive = make(CombineKernelKind::Naive);
        let blocked = make(CombineKernelKind::Blocked);
        assert_eq!(
            naive.combined.as_slice(),
            blocked.combined.as_slice(),
            "combine backend changed the combined draws"
        );
    }

    /// `--combine-backend device` offline: a structured error naming
    /// the backend, surfaced *before* the sampling stage (the combine
    /// step would be doomed anyway) — never a panic.
    #[test]
    fn device_combine_backend_offline_is_structured_error() {
        use crate::kernel::CombineKernelKind;
        let data = synth::gaussian(400, 1, 30);
        let mut c = cfg(2, 50);
        c.method = CombineMethod::Semiparametric;
        c.combine_backend = CombineKernelKind::Device;
        let err = run_native(&c, &data).unwrap_err();
        assert!(
            matches!(err, Error::KernelUnavailable { backend: "device", .. }),
            "expected KernelUnavailable, got {err:?}"
        );
    }

    #[test]
    fn run_dir_removes_itself_on_drop() {
        let rd = RunDir::create(123).unwrap();
        let path = rd.path().to_path_buf();
        std::fs::write(path.join("spill.bin"), b"x").unwrap();
        assert!(path.is_dir());
        drop(rd);
        assert!(!path.exists(), "RunDir must clean up recursively");
    }

    // ---- transport-scheduler unit tests over an in-memory transport ----

    use crate::coordinator::transport::{
        Transport, WireMsg, WorkerConnection, WorkerSummary,
    };

    /// Per-machine queues of scripted attempt streams: each `connect`
    /// for a machine pops its next stream, so a retried shard replays
    /// the next scripted attempt. Popping an empty queue — a machine
    /// assigned more times than scripted — is a test bug and panics.
    type ScriptedStreams = Mutex<Vec<VecDeque<Vec<WireMsg>>>>;

    /// In-memory transport: each machine's wire stream is scripted.
    /// Exercises the oversubscription scheduler without spawning
    /// processes (the real endpoints are covered by the
    /// `process_pipeline` / `socket_pipeline` / `fault_injection`
    /// integration tests).
    struct MockTransport {
        slots: usize,
        streams: ScriptedStreams,
    }

    impl MockTransport {
        fn new(slots: usize, streams: Vec<Vec<WireMsg>>) -> MockTransport {
            MockTransport::with_attempts(
                slots,
                streams.into_iter().map(|s| vec![s]).collect(),
            )
        }

        /// Transport whose machine `m` serves `attempts[m][k]` as its
        /// k-th connection's stream — the retry scheduler's harness.
        fn with_attempts(
            slots: usize,
            attempts: Vec<Vec<Vec<WireMsg>>>,
        ) -> MockTransport {
            MockTransport {
                slots,
                streams: Mutex::new(
                    attempts.into_iter().map(Into::into).collect(),
                ),
            }
        }
    }

    struct MockConnection {
        msgs: VecDeque<WireMsg>,
    }

    impl WorkerConnection for MockConnection {
        fn recv(&mut self) -> Result<Option<WireMsg>> {
            Ok(self.msgs.pop_front())
        }

        fn finish(&mut self) -> Result<()> {
            Ok(())
        }
    }

    impl Transport for MockTransport {
        fn name(&self) -> &'static str {
            "mock"
        }

        fn slots(&self) -> usize {
            self.slots
        }

        fn connect(
            &self,
            _slot: usize,
            manifest: &WorkerManifest,
            _manifest_path: &Path,
        ) -> Result<Box<dyn WorkerConnection>> {
            let msgs = self.streams.lock().unwrap()[manifest.machine]
                .pop_front()
                .expect("machine assigned more times than scripted");
            Ok(Box::new(MockConnection { msgs: msgs.into() }))
        }
    }

    /// Scripted healthy stream for one machine: `t` slightly varying
    /// 1-d draws plus a summary.
    fn scripted_stream(machine: usize, t: usize) -> Vec<WireMsg> {
        let mut msgs: Vec<WireMsg> = (0..t)
            .map(|i| {
                WireMsg::Draw(DrawMsg {
                    machine,
                    theta: vec![machine as f64 + 0.25 * i as f64],
                    elapsed: 0.01 * (i + 1) as f64,
                    last: i + 1 == t,
                })
            })
            .collect();
        msgs.push(WireMsg::Summary(WorkerSummary {
            machine,
            accept_rate: 0.5,
            wall_secs: 0.25,
        }));
        msgs
    }

    /// One endpoint, four machines: the scheduler must queue all four
    /// manifests onto the single slot and reassemble every machine's
    /// stream intact.
    #[test]
    fn oversubscribed_single_slot_runs_all_machines() {
        let data = synth::gaussian(400, 1, 31);
        let c = cfg(4, 5);
        let transport = MockTransport::new(
            1,
            (0..4).map(|m| scripted_stream(m, 5)).collect(),
        );
        let out = run_with_transport(&c, &data, &transport).unwrap();
        assert_eq!(out.subposteriors.len(), 4);
        for (m, s) in out.subposteriors.iter().enumerate() {
            assert_eq!(s.machine, m);
            assert_eq!(s.samples.len(), 5);
            assert_eq!(s.samples.row(0)[0], m as f64);
            assert_eq!(s.draw_times.len(), 5);
            assert_eq!(s.accept_rate, 0.5);
        }
        assert_eq!(out.metrics.scalars_transferred, 4 * 5);
        let run_dir =
            out.run_dir.as_ref().expect("transport runs own a RunDir");
        let path = run_dir.path().to_path_buf();
        assert!(
            path.join("shard_0.json").is_file(),
            "spills live until the output drops"
        );
        drop(out);
        assert!(!path.exists(), "RunDir cleaned up with the output");
    }

    /// A stream that ends without a summary frame is a structured
    /// scheduler error naming the machine.
    #[test]
    fn stream_without_summary_is_an_error() {
        let data = synth::gaussian(200, 1, 32);
        let c = cfg(2, 3);
        let mut streams: Vec<Vec<WireMsg>> =
            (0..2).map(|m| scripted_stream(m, 3)).collect();
        streams[1].pop(); // drop machine 1's summary
        let transport = MockTransport::new(2, streams);
        let err = run_with_transport(&c, &data, &transport).unwrap_err();
        assert!(
            err.to_string().contains("without a summary frame"),
            "{err}"
        );
    }

    /// An in-band worker error frame (the socket daemons' failure path)
    /// surfaces as the run's root cause.
    #[test]
    fn remote_error_frame_surfaces_as_root_cause() {
        let data = synth::gaussian(200, 1, 33);
        let c = cfg(2, 3);
        let streams = vec![
            scripted_stream(0, 3),
            vec![WireMsg::Error {
                machine: 1,
                message: "shard unreadable".into(),
            }],
        ];
        let transport = MockTransport::new(2, streams);
        let err = run_with_transport(&c, &data, &transport).unwrap_err();
        let text = err.to_string();
        assert!(
            text.contains("remote failure") && text.contains("shard unreadable"),
            "{text}"
        );
    }

    /// Re-script a per-draw stream as batched binary chunks (batch
    /// size `batch`, tail chunk short), keeping the summary frame.
    fn chunked_stream(machine: usize, t: usize, batch: usize) -> Vec<WireMsg> {
        use crate::coordinator::transport::DrawChunk;
        let mut msgs = Vec::new();
        let mut thetas = Vec::new();
        let mut elapsed = Vec::new();
        let mut last = false;
        for (i, msg) in scripted_stream(machine, t).into_iter().enumerate() {
            match msg {
                WireMsg::Draw(d) => {
                    thetas.extend_from_slice(&d.theta);
                    elapsed.push(d.elapsed);
                    last |= d.last;
                    if elapsed.len() >= batch || i + 1 == t {
                        msgs.push(WireMsg::Chunk(DrawChunk {
                            machine,
                            dim: 1,
                            thetas: std::mem::take(&mut thetas),
                            elapsed: std::mem::take(&mut elapsed),
                            last: std::mem::take(&mut last),
                        }));
                    }
                }
                other => msgs.push(other),
            }
        }
        msgs
    }

    /// Tentpole gate at the scheduler level: a chunked wire stream must
    /// reassemble into byte-identical subposteriors and combined draws
    /// as the per-draw stream it batches — at any batch size, including
    /// one that leaves a short tail chunk.
    #[test]
    fn chunked_streams_match_per_draw_streams() {
        let data = synth::gaussian(400, 1, 35);
        let c = cfg(3, 10);
        let per_draw = run_with_transport(
            &c,
            &data,
            &MockTransport::new(
                2,
                (0..3).map(|m| scripted_stream(m, 10)).collect(),
            ),
        )
        .unwrap();
        for batch in [1usize, 4, 64] {
            let chunked = run_with_transport(
                &c,
                &data,
                &MockTransport::new(
                    2,
                    (0..3).map(|m| chunked_stream(m, 10, batch)).collect(),
                ),
            )
            .unwrap();
            for (a, b) in
                per_draw.subposteriors.iter().zip(&chunked.subposteriors)
            {
                assert_eq!(
                    a.samples.as_slice(),
                    b.samples.as_slice(),
                    "machine {} diverged at batch {batch}",
                    a.machine
                );
                assert_eq!(a.draw_times, b.draw_times);
            }
            assert_eq!(
                per_draw.combined.as_slice(),
                chunked.combined.as_slice(),
                "combined draws diverged at batch {batch}"
            );
            assert_eq!(
                per_draw.metrics.scalars_transferred,
                chunked.metrics.scalars_transferred
            );
        }
    }

    /// A chunk whose dim disagrees with the run must fail the
    /// assignment, not corrupt the matrix.
    #[test]
    fn bad_chunk_dim_is_rejected() {
        use crate::coordinator::transport::DrawChunk;
        let data = synth::gaussian(200, 1, 36);
        let c = cfg(2, 3);
        let streams = vec![
            scripted_stream(0, 3),
            vec![WireMsg::Chunk(DrawChunk {
                machine: 1,
                dim: 2,
                thetas: vec![0.0; 6],
                elapsed: vec![0.1; 3],
                last: true,
            })],
        ];
        let transport = MockTransport::new(2, streams);
        let err = run_with_transport(&c, &data, &transport).unwrap_err();
        assert!(err.to_string().contains("chunk for machine"), "{err}");
    }

    /// Tentpole gate at the scheduler level: a machine killed
    /// mid-stream under `--failure-policy retry` is reset and
    /// re-dispatched, and the retained draws — subposteriors *and*
    /// combined — are byte-identical to a run that never failed. The
    /// retry is visible only in the metrics.
    #[test]
    fn retry_replays_killed_machine_and_matches_clean_run() {
        let data = synth::gaussian(400, 1, 37);
        let clean = run_with_transport(
            &cfg(3, 6),
            &data,
            &MockTransport::new(
                2,
                (0..3).map(|m| scripted_stream(m, 6)).collect(),
            ),
        )
        .unwrap();
        assert_eq!(clean.metrics.shard_retries, 0);

        let mut c = cfg(3, 6);
        c.failure_policy = FailurePolicy::Retry;
        // Machine 1 dies mid-stream on its first attempt (4 draws land,
        // then EOF with no summary), then replays clean.
        let mut first = scripted_stream(1, 6);
        first.truncate(4);
        let out = run_with_transport(
            &c,
            &data,
            &MockTransport::with_attempts(
                2,
                vec![
                    vec![scripted_stream(0, 6)],
                    vec![first, scripted_stream(1, 6)],
                    vec![scripted_stream(2, 6)],
                ],
            ),
        )
        .unwrap();
        for (a, b) in clean.subposteriors.iter().zip(&out.subposteriors) {
            assert_eq!(
                a.samples.as_slice(),
                b.samples.as_slice(),
                "machine {} diverged through the retry",
                a.machine
            );
        }
        assert_eq!(
            clean.combined.as_slice(),
            out.combined.as_slice(),
            "combined draws must not see the failure"
        );
        assert_eq!(
            clean.metrics.scalars_transferred,
            out.metrics.scalars_transferred,
            "reset must rewind the failed attempt's scalar accounting"
        );
        assert_eq!(out.metrics.shard_retries, 1);
        assert_eq!(out.metrics.endpoints_quarantined, 0);
        assert_eq!(out.metrics.heartbeats_missed, 0);
    }

    /// When a machine fails on every attempt, the run fails with a
    /// structured diagnostic naming every attempt — machine, attempt
    /// number, endpoint, and cause.
    #[test]
    fn retries_exhausted_surface_every_attempt() {
        let data = synth::gaussian(200, 1, 38);
        let mut c = cfg(2, 3);
        c.failure_policy = FailurePolicy::Retry;
        c.max_retries = 1;
        let mut dead = scripted_stream(1, 3);
        dead.truncate(1);
        let err = run_with_transport(
            &c,
            &data,
            &MockTransport::with_attempts(
                2,
                vec![vec![scripted_stream(0, 3)], vec![dead.clone(), dead]],
            ),
        )
        .unwrap_err();
        let text = err.to_string();
        assert!(text.contains("retries exhausted"), "{text}");
        assert!(
            text.contains("machine 1 attempt 1/2")
                && text.contains("machine 1 attempt 2/2"),
            "diagnostic must name every attempt: {text}"
        );
        assert!(text.contains("without a summary frame"), "{text}");
    }

    /// A single endpoint that keeps failing is quarantined, and with no
    /// survivors the run fails naming the quarantine — not a hang, not
    /// an opaque worker error.
    #[test]
    fn quarantining_the_last_endpoint_is_a_structured_error() {
        let data = synth::gaussian(200, 1, 39);
        let mut c = cfg(1, 3);
        c.failure_policy = FailurePolicy::Retry;
        c.max_retries = 5; // retries to spare: quarantine must fire first
        let mut dead = scripted_stream(0, 3);
        dead.truncate(2);
        let err = run_with_transport(
            &c,
            &data,
            &MockTransport::with_attempts(1, vec![vec![dead.clone(), dead]]),
        )
        .unwrap_err();
        let text = err.to_string();
        assert!(
            text.contains("endpoints quarantined"),
            "expected the quarantine diagnostic: {text}"
        );
    }

    /// Heartbeat frames are liveness beacons only: interleaving them
    /// with the draw stream changes nothing about the results, and a
    /// beacon tagged for the wrong machine is a protocol violation.
    #[test]
    fn heartbeat_frames_are_liveness_only() {
        let data = synth::gaussian(200, 1, 40);
        let c = cfg(2, 3);
        let plain = run_with_transport(
            &c,
            &data,
            &MockTransport::new(
                2,
                (0..2).map(|m| scripted_stream(m, 3)).collect(),
            ),
        )
        .unwrap();
        let noisy_streams: Vec<Vec<WireMsg>> = (0..2)
            .map(|m| {
                let mut v = Vec::new();
                for msg in scripted_stream(m, 3) {
                    v.push(WireMsg::Heartbeat { machine: m });
                    v.push(msg);
                }
                v
            })
            .collect();
        let noisy = run_with_transport(
            &c,
            &data,
            &MockTransport::new(2, noisy_streams),
        )
        .unwrap();
        for (a, b) in plain.subposteriors.iter().zip(&noisy.subposteriors)
        {
            assert_eq!(a.samples.as_slice(), b.samples.as_slice());
            assert_eq!(a.draw_times, b.draw_times);
        }
        assert_eq!(plain.combined.as_slice(), noisy.combined.as_slice());
        assert_eq!(
            plain.metrics.scalars_transferred,
            noisy.metrics.scalars_transferred,
            "beacons must not count as transferred draw scalars"
        );

        let mut cross = scripted_stream(0, 3);
        cross.insert(1, WireMsg::Heartbeat { machine: 1 });
        let err = run_with_transport(
            &c,
            &data,
            &MockTransport::new(2, vec![cross, scripted_stream(1, 3)]),
        )
        .unwrap_err();
        assert!(err.to_string().contains("heartbeat for machine"), "{err}");
    }

    /// A draw tagged for the wrong machine (an endpoint mixing up
    /// streams) must fail the run, not corrupt another machine's chain.
    #[test]
    fn cross_machine_draw_is_rejected() {
        let data = synth::gaussian(200, 1, 34);
        let c = cfg(2, 3);
        let mut wrong = scripted_stream(0, 3);
        if let WireMsg::Draw(d) = &mut wrong[1] {
            d.machine = 1;
        }
        let transport =
            MockTransport::new(2, vec![wrong, scripted_stream(1, 3)]);
        let err = run_with_transport(&c, &data, &transport).unwrap_err();
        assert!(err.to_string().contains("draw for machine"), "{err}");
    }
}
