//! L3 coordinator: the paper's system contribution.
//!
//! Topology: a [`partition::Partitioner`] splits the N observations onto
//! M logical machines; [`worker`] runs one independent MCMC chain per
//! machine — on an OS thread ([`pipeline::run_native`]), in its own OS
//! process, or on a remote `repro serve` daemon ([`serve`]) — with zero
//! inter-worker communication, the "embarrassingly parallel" property;
//! draws stream unidirectionally (mpsc channel in-thread; out of
//! process, length-prefixed ndjson frames or batched binary `RPDRAW1`
//! chunks over a pluggable [`transport`] — stdout pipes or TCP
//! sockets) to the [`leader`],
//! which folds them into an online combiner and produces full-posterior
//! draws on demand; [`pipeline`] wires the stages end-to-end from a
//! [`crate::config::PipelineConfig`], oversubscribing W < M worker
//! endpoints without changing a byte of output; [`timing`] converts
//! measured per-worker wall-clocks into the paper's cluster-time
//! accounting. [`server`] promotes the leader itself into a service:
//! `repro leaderd` multiplexes many concurrent pipeline *jobs* (each
//! with its own seed-derived RNG root, combiner, and draw plane) over
//! a shared worker fleet, byte-identical per job to a solo CLI run.

pub mod leader;
pub mod metrics;
pub mod partition;
pub mod pipeline;
#[cfg(unix)]
pub mod reactor;
pub mod serve;
pub mod server;
pub mod timing;
pub mod transport;
pub mod worker;

pub use leader::{Leader, LeaderMsg};
pub use partition::Partitioner;
pub use pipeline::{
    run_native, run_process, run_with_transport, PipelineOutput, RunDir,
};
pub use server::{JobSpec, LeaderdOptions, Shutdown};
pub use timing::ClusterTiming;
pub use transport::{
    FaultInjector, FaultSpec, PipeTransport, SocketTransport, Transport,
};
