//! Worker: one machine's independent MCMC chain, streaming draws to the
//! leader.
//!
//! Each worker owns its subposterior model (its data shard never leaves
//! the machine — criterion 1), derives an independent RNG stream from
//! the root seed, runs any [`crate::sampler::Sampler`] (criterion 3) and
//! pushes each post-burn-in draw into an `mpsc` channel (the paper's
//! unidirectional, wait-free communication; section 4).

use std::sync::mpsc::Sender;
use std::time::Instant;

use crate::model::LogDensity;
use crate::rng::Pcg64;
use crate::sampler::{Sampler, State};
use crate::types::{SampleMatrix, SubposteriorSamples};

/// One streamed draw.
///
/// In-process this moves through an `mpsc` channel verbatim. Out of
/// process it is carried either as its own JSON frame
/// ([`crate::coordinator::transport::encode_draw`], `wire_format =
/// json`) or coalesced with its neighbours into a batched binary
/// `RPDRAW1` chunk ([`crate::coordinator::transport::DrawEncoder`],
/// `wire_format = binary`) — the draws are identical either way; only
/// the framing differs.
#[derive(Debug, Clone)]
pub struct DrawMsg {
    pub machine: usize,
    pub theta: Vec<f64>,
    /// Seconds since the worker started (its local clock).
    pub elapsed: f64,
    /// True when this is the worker's final message.
    pub last: bool,
}

/// Run one worker chain to completion, streaming draws through `tx`.
/// Returns the complete per-machine output (also kept locally so batch
/// combiners can run without reassembling from the stream).
pub fn run_worker(
    machine: usize,
    target: &dyn LogDensity,
    sampler: Box<dyn Sampler>,
    n_samples: usize,
    burn_in: usize,
    thin: usize,
    rng: Pcg64,
    tx: Option<&Sender<DrawMsg>>,
) -> SubposteriorSamples {
    run_worker_with(
        machine,
        target,
        sampler,
        n_samples,
        burn_in,
        thin,
        rng,
        // A send failure means the leader hung up; the worker keeps
        // sampling (its local copy is still returned).
        &mut |msg: &DrawMsg| {
            if let Some(tx) = tx {
                let _ = tx.send(msg.clone());
            }
            true
        },
    )
}

/// [`run_worker`] with a caller-supplied sink for the streamed draws —
/// the process-mode worker writes each message straight onto its stdout
/// frame stream instead of into an in-process channel. `emit` returns
/// whether to keep sampling: a `false` (the sink's peer is gone and the
/// rest of the chain would be dead compute — e.g. a socket worker
/// daemon whose leader hung up) aborts the chain immediately, returning
/// the draws retained so far.
#[allow(clippy::too_many_arguments)]
pub fn run_worker_with(
    machine: usize,
    target: &dyn LogDensity,
    sampler: Box<dyn Sampler>,
    n_samples: usize,
    burn_in: usize,
    thin: usize,
    rng: Pcg64,
    emit: &mut dyn FnMut(&DrawMsg) -> bool,
) -> SubposteriorSamples {
    run_worker_with_ticks(
        machine, target, sampler, n_samples, burn_in, thin, rng, emit,
        &mut || true,
    )
}

/// [`run_worker_with`] plus a per-iteration `tick` callback, fired on
/// *every* sampler step — including the whole burn-in stretch, where
/// `emit` never runs. This is the worker-side liveness hook: the
/// process/daemon wrapper uses it to put `RPHB` heartbeat frames on
/// the wire while no draws are flowing, so a leader holding a read
/// deadline can tell "burning in" from "wedged". `tick` returning
/// `false` aborts the chain exactly like `emit` returning `false`
/// (e.g. the heartbeat write failed: the peer is gone). The tick
/// never touches the sampler, RNG, or retained draws, so retained
/// output is byte-identical at any tick cadence.
#[allow(clippy::too_many_arguments)]
pub fn run_worker_with_ticks(
    machine: usize,
    target: &dyn LogDensity,
    mut sampler: Box<dyn Sampler>,
    n_samples: usize,
    burn_in: usize,
    thin: usize,
    mut rng: Pcg64,
    emit: &mut dyn FnMut(&DrawMsg) -> bool,
    tick: &mut dyn FnMut() -> bool,
) -> SubposteriorSamples {
    let start = Instant::now();
    let dim = target.dim();
    // `thin = 0` from a direct library caller would divide by zero in
    // the retention check below; treat it as "no thinning".
    let thin = thin.max(1);
    let mut state = State::init(target, target.init_point(&mut rng));
    // The last retained draw lands at burn_in + (n_samples-1)·thin, so
    // stop there: the `thin - 1` iterations beyond it are pure waste
    // that would also inflate `wall_secs` fed into `ClusterTiming`.
    let total = if n_samples == 0 {
        burn_in
    } else {
        burn_in + (n_samples - 1) * thin + 1
    };
    let mut samples = SampleMatrix::with_capacity(dim, n_samples);
    let mut draw_times = Vec::with_capacity(n_samples);
    let mut accepts = 0usize;
    let mut post = 0usize;

    let mut aborted = false;
    for i in 0..total {
        if !tick() {
            aborted = true;
            break;
        }
        // Freeze adaptation before the first post-burn-in step — also
        // when `burn_in == 0`, where the retained draws start at i = 0
        // (an adaptive sampler mutating its step size during retained
        // draws breaks detailed balance).
        if i == burn_in {
            sampler.finalize_adaptation();
        }
        target.symmetry_move(&mut state.theta, &mut rng);
        let accepted = sampler.step(target, &mut state, &mut rng);
        if i >= burn_in {
            post += 1;
            accepts += usize::from(accepted);
            if (i - burn_in) % thin == 0 && samples.len() < n_samples {
                let elapsed = start.elapsed().as_secs_f64();
                samples.push(&state.theta);
                draw_times.push(elapsed);
                let keep_going = emit(&DrawMsg {
                    machine,
                    theta: state.theta.clone(),
                    elapsed,
                    last: samples.len() == n_samples,
                });
                if !keep_going {
                    aborted = true;
                    break;
                }
            }
        }
    }
    assert!(
        aborted || samples.len() == n_samples,
        "tightened loop bound must retain exactly n_samples draws"
    );

    SubposteriorSamples {
        machine,
        samples,
        accept_rate: if post > 0 {
            accepts as f64 / post as f64
        } else {
            f64::NAN
        },
        wall_secs: start.elapsed().as_secs_f64(),
        draw_times,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::GaussianMean;
    use crate::sampler::{Sampler, SamplerKind, State};
    use crate::types::SampleMatrix;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::mpsc::channel;
    use std::sync::Arc;

    /// Records how many steps had run when `finalize_adaptation` fired
    /// (and in total), so tests can pin down the freeze point exactly.
    struct ProbeSampler {
        steps: usize,
        total_steps: Arc<AtomicUsize>,
        steps_at_finalize: Arc<AtomicUsize>,
    }

    impl ProbeSampler {
        fn boxed() -> (Box<dyn Sampler>, Arc<AtomicUsize>, Arc<AtomicUsize>) {
            let total = Arc::new(AtomicUsize::new(0));
            let at_finalize = Arc::new(AtomicUsize::new(usize::MAX));
            let probe = ProbeSampler {
                steps: 0,
                total_steps: Arc::clone(&total),
                steps_at_finalize: Arc::clone(&at_finalize),
            };
            (Box::new(probe), total, at_finalize)
        }
    }

    impl Sampler for ProbeSampler {
        fn name(&self) -> &'static str {
            "probe"
        }

        fn step(
            &mut self,
            _target: &dyn crate::model::LogDensity,
            _state: &mut State,
            _rng: &mut Pcg64,
        ) -> bool {
            self.steps += 1;
            self.total_steps.store(self.steps, Ordering::SeqCst);
            true
        }

        fn finalize_adaptation(&mut self) {
            self.steps_at_finalize.store(self.steps, Ordering::SeqCst);
        }
    }

    fn gaussian_target() -> GaussianMean {
        GaussianMean::new(SampleMatrix::new(1), 1.0, 1.0, 1.0)
    }

    /// Regression: with `burn_in = 0` adaptation must freeze before the
    /// very first (retained) step — the seed's `i + 1 == burn_in` check
    /// never fired, so adaptive samplers kept mutating their step size
    /// during the retained draws.
    #[test]
    fn adaptation_frozen_before_first_retained_draw_with_zero_burnin() {
        let target = gaussian_target();
        let (probe, _total, at_finalize) = ProbeSampler::boxed();
        let out = run_worker(
            0,
            &target,
            probe,
            20,
            0,
            1,
            Pcg64::seed_from(4),
            None,
        );
        assert_eq!(out.samples.len(), 20);
        assert_eq!(
            at_finalize.load(Ordering::SeqCst),
            0,
            "finalize_adaptation must run before step 0 when burn_in == 0"
        );
    }

    #[test]
    fn adaptation_frozen_exactly_at_burnin_end() {
        let target = gaussian_target();
        let (probe, _total, at_finalize) = ProbeSampler::boxed();
        run_worker(0, &target, probe, 10, 7, 1, Pcg64::seed_from(5), None);
        assert_eq!(at_finalize.load(Ordering::SeqCst), 7);
    }

    /// Regression: the loop used to run `burn_in + n·thin` steps, but
    /// the last retained draw lands at `burn_in + (n-1)·thin`, wasting
    /// `thin - 1` trailing iterations (and inflating `wall_secs`).
    #[test]
    fn thinned_worker_takes_no_wasted_trailing_steps() {
        let target = gaussian_target();
        let (probe, total, _at_finalize) = ProbeSampler::boxed();
        let out = run_worker(
            0,
            &target,
            probe,
            5,
            4,
            3,
            Pcg64::seed_from(6),
            None,
        );
        // Draw count is unchanged by the tightened bound…
        assert_eq!(out.samples.len(), 5);
        assert_eq!(out.draw_times.len(), 5);
        // …but the step count is exactly burn_in + (n-1)·thin + 1 = 17,
        // not the seed's burn_in + n·thin = 19.
        assert_eq!(total.load(Ordering::SeqCst), 17);
    }

    #[test]
    fn zero_samples_runs_burnin_only() {
        let target = gaussian_target();
        let (probe, total, _at_finalize) = ProbeSampler::boxed();
        let out =
            run_worker(0, &target, probe, 0, 6, 2, Pcg64::seed_from(7), None);
        assert_eq!(out.samples.len(), 0);
        assert!(out.accept_rate.is_nan());
        assert_eq!(total.load(Ordering::SeqCst), 6);
    }

    #[test]
    fn worker_streams_every_draw() {
        let data = SampleMatrix::new(1);
        let target = GaussianMean::new(data, 1.0, 1.0, 1.0);
        let (tx, rx) = channel();
        let out = run_worker(
            2,
            &target,
            SamplerKind::Rwm { scale: 1.0 }.build(1),
            100,
            20,
            1,
            Pcg64::seed_from(1),
            Some(&tx),
        );
        drop(tx);
        let msgs: Vec<DrawMsg> = rx.iter().collect();
        assert_eq!(msgs.len(), 100);
        assert_eq!(out.samples.len(), 100);
        assert!(msgs.iter().all(|m| m.machine == 2));
        assert!(msgs.last().unwrap().last);
        assert!(!msgs[0].last);
        // Streamed draws equal stored draws.
        for (msg, row) in msgs.iter().zip(out.samples.rows()) {
            assert_eq!(msg.theta.as_slice(), row);
        }
    }

    #[test]
    fn worker_survives_leader_hangup() {
        let data = SampleMatrix::new(1);
        let target = GaussianMean::new(data, 1.0, 1.0, 1.0);
        let (tx, rx) = channel();
        drop(rx); // leader gone before the worker starts
        let out = run_worker(
            0,
            &target,
            SamplerKind::Rwm { scale: 1.0 }.build(1),
            50,
            10,
            1,
            Pcg64::seed_from(2),
            Some(&tx),
        );
        assert_eq!(out.samples.len(), 50);
    }

    /// The liveness tick fires on every iteration — burn-in included,
    /// where `emit` never runs — never perturbs the retained draws,
    /// and aborts the chain when it returns false.
    #[test]
    fn tick_covers_burnin_and_never_perturbs_draws() {
        let target = gaussian_target();
        let plain = run_worker(
            0,
            &target,
            SamplerKind::Rwm { scale: 1.0 }.build(1),
            10,
            6,
            2,
            Pcg64::seed_from(9),
            None,
        );
        let mut ticks = 0usize;
        let ticked = run_worker_with_ticks(
            0,
            &target,
            SamplerKind::Rwm { scale: 1.0 }.build(1),
            10,
            6,
            2,
            Pcg64::seed_from(9),
            &mut |_msg| true,
            &mut || {
                ticks += 1;
                true
            },
        );
        // total = burn_in + (n-1)·thin + 1 = 6 + 18 + 1 = 25 ticks.
        assert_eq!(ticks, 25, "one tick per sampler iteration");
        assert_eq!(
            plain.samples.as_slice(),
            ticked.samples.as_slice(),
            "ticks must not perturb retained draws"
        );
        // A false tick aborts immediately — even inside burn-in.
        let mut n = 0usize;
        let aborted = run_worker_with_ticks(
            0,
            &target,
            SamplerKind::Rwm { scale: 1.0 }.build(1),
            10,
            6,
            2,
            Pcg64::seed_from(9),
            &mut |_msg| true,
            &mut || {
                n += 1;
                n <= 3
            },
        );
        assert_eq!(aborted.samples.len(), 0, "aborted inside burn-in");
    }

    #[test]
    fn workers_with_different_streams_decorrelate() {
        let data = SampleMatrix::new(1);
        let target = GaussianMean::new(data, 1.0, 1.0, 1.0);
        let mut root = Pcg64::seed_from(3);
        let r0 = root.split(0);
        let r1 = root.split(1);
        let a = run_worker(
            0,
            &target,
            SamplerKind::Rwm { scale: 1.0 }.build(1),
            200,
            50,
            1,
            r0,
            None,
        );
        let b = run_worker(
            1,
            &target,
            SamplerKind::Rwm { scale: 1.0 }.build(1),
            200,
            50,
            1,
            r1,
            None,
        );
        let same = a
            .samples
            .rows()
            .zip(b.samples.rows())
            .filter(|(x, y)| x == y)
            .count();
        assert!(same < 5, "{same} identical draws");
    }
}
