//! Worker: one machine's independent MCMC chain, streaming draws to the
//! leader.
//!
//! Each worker owns its subposterior model (its data shard never leaves
//! the machine — criterion 1), derives an independent RNG stream from
//! the root seed, runs any [`crate::sampler::Sampler`] (criterion 3) and
//! pushes each post-burn-in draw into an `mpsc` channel (the paper's
//! unidirectional, wait-free communication; section 4).

use std::sync::mpsc::Sender;
use std::time::Instant;

use crate::model::LogDensity;
use crate::rng::Pcg64;
use crate::sampler::{Sampler, State};
use crate::types::{SampleMatrix, SubposteriorSamples};

/// One streamed draw.
#[derive(Debug, Clone)]
pub struct DrawMsg {
    pub machine: usize,
    pub theta: Vec<f64>,
    /// Seconds since the worker started (its local clock).
    pub elapsed: f64,
    /// True when this is the worker's final message.
    pub last: bool,
}

/// Run one worker chain to completion, streaming draws through `tx`.
/// Returns the complete per-machine output (also kept locally so batch
/// combiners can run without reassembling from the stream).
pub fn run_worker(
    machine: usize,
    target: &dyn LogDensity,
    mut sampler: Box<dyn Sampler>,
    n_samples: usize,
    burn_in: usize,
    thin: usize,
    mut rng: Pcg64,
    tx: Option<&Sender<DrawMsg>>,
) -> SubposteriorSamples {
    let start = Instant::now();
    let dim = target.dim();
    let mut state = State::init(target, target.init_point(&mut rng));
    let total = burn_in + n_samples * thin;
    let mut samples = SampleMatrix::with_capacity(dim, n_samples);
    let mut draw_times = Vec::with_capacity(n_samples);
    let mut accepts = 0usize;
    let mut post = 0usize;

    for i in 0..total {
        target.symmetry_move(&mut state.theta, &mut rng);
        let accepted = sampler.step(target, &mut state, &mut rng);
        if i + 1 == burn_in {
            sampler.finalize_adaptation();
        }
        if i >= burn_in {
            post += 1;
            accepts += usize::from(accepted);
            if (i - burn_in) % thin == 0 && samples.len() < n_samples {
                let elapsed = start.elapsed().as_secs_f64();
                samples.push(&state.theta);
                draw_times.push(elapsed);
                if let Some(tx) = tx {
                    // A send failure means the leader hung up; the worker
                    // keeps sampling (its local copy is still returned).
                    let _ = tx.send(DrawMsg {
                        machine,
                        theta: state.theta.clone(),
                        elapsed,
                        last: samples.len() == n_samples,
                    });
                }
            }
        }
    }

    SubposteriorSamples {
        machine,
        samples,
        accept_rate: if post > 0 {
            accepts as f64 / post as f64
        } else {
            f64::NAN
        },
        wall_secs: start.elapsed().as_secs_f64(),
        draw_times,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::GaussianMean;
    use crate::sampler::SamplerKind;
    use crate::types::SampleMatrix;
    use std::sync::mpsc::channel;

    #[test]
    fn worker_streams_every_draw() {
        let data = SampleMatrix::new(1);
        let target = GaussianMean::new(data, 1.0, 1.0, 1.0);
        let (tx, rx) = channel();
        let out = run_worker(
            2,
            &target,
            SamplerKind::Rwm { scale: 1.0 }.build(1),
            100,
            20,
            1,
            Pcg64::seed_from(1),
            Some(&tx),
        );
        drop(tx);
        let msgs: Vec<DrawMsg> = rx.iter().collect();
        assert_eq!(msgs.len(), 100);
        assert_eq!(out.samples.len(), 100);
        assert!(msgs.iter().all(|m| m.machine == 2));
        assert!(msgs.last().unwrap().last);
        assert!(!msgs[0].last);
        // Streamed draws equal stored draws.
        for (msg, row) in msgs.iter().zip(out.samples.rows()) {
            assert_eq!(msg.theta.as_slice(), row);
        }
    }

    #[test]
    fn worker_survives_leader_hangup() {
        let data = SampleMatrix::new(1);
        let target = GaussianMean::new(data, 1.0, 1.0, 1.0);
        let (tx, rx) = channel();
        drop(rx); // leader gone before the worker starts
        let out = run_worker(
            0,
            &target,
            SamplerKind::Rwm { scale: 1.0 }.build(1),
            50,
            10,
            1,
            Pcg64::seed_from(2),
            Some(&tx),
        );
        assert_eq!(out.samples.len(), 50);
    }

    #[test]
    fn workers_with_different_streams_decorrelate() {
        let data = SampleMatrix::new(1);
        let target = GaussianMean::new(data, 1.0, 1.0, 1.0);
        let mut root = Pcg64::seed_from(3);
        let r0 = root.split(0);
        let r1 = root.split(1);
        let a = run_worker(
            0,
            &target,
            SamplerKind::Rwm { scale: 1.0 }.build(1),
            200,
            50,
            1,
            r0,
            None,
        );
        let b = run_worker(
            1,
            &target,
            SamplerKind::Rwm { scale: 1.0 }.build(1),
            200,
            50,
            1,
            r1,
            None,
        );
        let same = a
            .samples
            .rows()
            .zip(b.samples.rows())
            .filter(|(x, y)| x == y)
            .count();
        assert!(same < 5, "{same} identical draws");
    }
}
