//! Leader-as-a-service: the `repro leaderd` daemon and its `RPJOB1`
//! job protocol.
//!
//! One CLI invocation = one pipeline run was the repo's shape through
//! PR 9; this module promotes the leader into a persistent server.
//! `repro leaderd --listen <addr>` accepts many concurrent sampling/
//! combine **jobs** over the same length-prefixed frame grammar the
//! worker wire uses. A job arrives as one JSON submit frame carrying
//! the full pipeline config (the flat `key = value` text of
//! [`crate::config::PipelineConfig::to_cfg_string`], re-parsed
//! daemon-side with exactly the validation a `--config` file gets)
//! plus the dataset size; the daemon streams back JSON lifecycle
//! frames — `submitted → running → combining → done|failed` — and, on
//! success, the combined posterior draws as binary `RPDRAW1` chunk
//! frames (bit-exact), then closes the connection.
//!
//! Determinism under multiplexing is the core contract: each job's
//! RNG root is `Pcg64::seed_from(spec seed)` and its combine seed
//! `spec seed ^ 0x5EED` — functions of the spec, never of arrival
//! order, job id, or which jobs run beside it — and each job owns its
//! leader plane (`OnlineCombiner`, `DrawStore`, retry/quarantine
//! state) inside its own pipeline run. Retained draws from a job are
//! therefore byte-identical to the solo `repro pipeline` run of the
//! same spec at any `--max-concurrent-jobs`, interleaving, io-driver,
//! or failure policy — CI's `leaderd-smoke` job `cmp`s exactly that.
//!
//! Wire grammar (all frames length-prefixed, see
//! [`crate::coordinator::transport`]):
//!
//! ```text
//! client → daemon   {"rpjob":1,"type":"submit","cfg":"<cfg text>","n":N,"d":D}
//! daemon → client   {"rpjob":1,"type":"state","job":J,"state":"submitted"}
//!                   {"rpjob":1,"type":"state","job":J,"state":"running",
//!                    "queue_wait_ms":…}
//!                   {"rpjob":1,"type":"state","job":J,"state":"combining"}
//!                   RPDRAW1 chunk frames (combined draws, machine 0)…
//!                   {"rpjob":1,"type":"state","job":J,"state":"done",
//!                    "draws":T,"dim":d,"queue_wait_ms":…,
//!                    "time_to_first_draw_ms":…}
//!            or     {"rpjob":1,"type":"state","job":J,"state":"failed",
//!                    "error":"…"}
//! ```

pub mod client;
pub mod jobs;

use std::fmt;
use std::io::{BufReader, BufWriter, Write};
use std::net::{Shutdown as NetShutdown, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::config::PipelineConfig;
use crate::coordinator::metrics::RunMetrics;
use crate::coordinator::pipeline::RunPhase;
use crate::coordinator::serve::DEFAULT_MANIFEST_TIMEOUT;
use crate::coordinator::transport::{
    write_frame, write_frame_bytes, DrawChunk, FrameReader,
    DEFAULT_MAX_FRAME_BYTES,
};
use crate::error::{Error, Result};
use crate::runtime::json::{obj, Json};
use crate::types::SampleMatrix;

use jobs::JobManager;

/// Everything a job needs to run: the full pipeline config as cfg
/// text (seed, model, partition, combine tuning, worker endpoint list
/// — endpoints may differ between jobs) plus the synthetic dataset
/// size. The daemon re-parses the text with
/// [`PipelineConfig::from_str_cfg`], so a submitted job and a solo
/// `--config` run see identical validation and identical configs.
#[derive(Debug, Clone, PartialEq)]
pub struct JobSpec {
    /// Flat `key = value` pipeline config
    /// ([`PipelineConfig::to_cfg_string`]).
    pub cfg_text: String,
    /// Dataset rows (the CLI's `--n`).
    pub n: usize,
    /// Dataset parameter dimension (the CLI's `--d`; some models
    /// ignore it).
    pub d: usize,
}

impl JobSpec {
    /// Build a spec from an already-validated config.
    pub fn from_config(cfg: &PipelineConfig, n: usize, d: usize) -> JobSpec {
        JobSpec { cfg_text: cfg.to_cfg_string(), n, d }
    }

    /// Parse the embedded config text.
    pub fn config(&self) -> Result<PipelineConfig> {
        PipelineConfig::from_str_cfg(&self.cfg_text)
    }

    /// The submit frame payload.
    pub fn to_frame(&self) -> String {
        obj(vec![
            ("rpjob", Json::Num(1.0)),
            ("type", Json::Str("submit".into())),
            ("cfg", Json::Str(self.cfg_text.clone())),
            ("n", Json::Num(self.n as f64)),
            ("d", Json::Num(self.d as f64)),
        ])
        .render()
    }

    /// Decode a submit frame.
    pub fn from_frame(j: &Json) -> Result<JobSpec> {
        if j.get("rpjob")?.as_f64()? != 1.0 {
            return Err(Error::Parse(
                "unsupported rpjob protocol version".into(),
            ));
        }
        if j.get("type")?.as_str()? != "submit" {
            return Err(Error::Parse(format!(
                "expected a submit frame, got type '{}'",
                j.get("type")?.as_str()?
            )));
        }
        Ok(JobSpec {
            cfg_text: j.get("cfg")?.as_str()?.to_string(),
            n: j.get("n")?.as_usize()?,
            d: j.get("d")?.as_usize()?,
        })
    }
}

/// Job lifecycle states (`RPJOB1` state frames).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JobState {
    Submitted,
    Running,
    Combining,
    Done,
    Failed,
}

impl JobState {
    pub fn name(&self) -> &'static str {
        match self {
            JobState::Submitted => "submitted",
            JobState::Running => "running",
            JobState::Combining => "combining",
            JobState::Done => "done",
            JobState::Failed => "failed",
        }
    }

    pub fn parse(s: &str) -> Result<JobState> {
        Ok(match s {
            "submitted" => JobState::Submitted,
            "running" => JobState::Running,
            "combining" => JobState::Combining,
            "done" => JobState::Done,
            "failed" => JobState::Failed,
            other => {
                return Err(Error::Parse(format!(
                    "unknown job state '{other}'"
                )))
            }
        })
    }
}

/// One `RPJOB1` state frame: a lifecycle transition plus whatever
/// telemetry the state carries.
#[derive(Debug, Clone, PartialEq)]
pub struct JobUpdate {
    pub job: u64,
    pub state: JobState,
    /// Milliseconds queued behind `--max-concurrent-jobs` (from
    /// `running` onward).
    pub queue_wait_ms: Option<f64>,
    /// Per-job time to first draw (on `done`).
    pub time_to_first_draw_ms: Option<f64>,
    /// Combined draw count (on `done`).
    pub draws: Option<usize>,
    /// Parameter dimension (on `done`).
    pub dim: Option<usize>,
    /// Structured failure (on `failed`).
    pub error: Option<String>,
}

impl JobUpdate {
    fn state_only(job: u64, state: JobState) -> JobUpdate {
        JobUpdate {
            job,
            state,
            queue_wait_ms: None,
            time_to_first_draw_ms: None,
            draws: None,
            dim: None,
            error: None,
        }
    }

    fn failed(job: u64, error: &str) -> JobUpdate {
        JobUpdate {
            error: Some(error.to_string()),
            ..JobUpdate::state_only(job, JobState::Failed)
        }
    }

    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("rpjob", Json::Num(1.0)),
            ("type", Json::Str("state".into())),
            ("job", Json::Num(self.job as f64)),
            ("state", Json::Str(self.state.name().into())),
        ];
        if let Some(v) = self.queue_wait_ms {
            fields.push(("queue_wait_ms", Json::Num(v)));
        }
        if let Some(v) = self.time_to_first_draw_ms {
            fields.push(("time_to_first_draw_ms", Json::Num(v)));
        }
        if let Some(v) = self.draws {
            fields.push(("draws", Json::Num(v as f64)));
        }
        if let Some(v) = self.dim {
            fields.push(("dim", Json::Num(v as f64)));
        }
        if let Some(v) = &self.error {
            fields.push(("error", Json::Str(v.clone())));
        }
        obj(fields)
    }

    pub fn from_json(j: &Json) -> Result<JobUpdate> {
        if j.get("rpjob")?.as_f64()? != 1.0
            || j.get("type")?.as_str()? != "state"
        {
            return Err(Error::Parse(
                "expected an rpjob state frame".into(),
            ));
        }
        let o = j.as_obj()?;
        let opt_f64 = |key: &str| -> Result<Option<f64>> {
            o.get(key).map(Json::as_f64).transpose()
        };
        let opt_usize = |key: &str| -> Result<Option<usize>> {
            o.get(key).map(Json::as_usize).transpose()
        };
        Ok(JobUpdate {
            job: j.get("job")?.as_usize()? as u64,
            state: JobState::parse(j.get("state")?.as_str()?)?,
            queue_wait_ms: opt_f64("queue_wait_ms")?,
            time_to_first_draw_ms: opt_f64("time_to_first_draw_ms")?,
            draws: opt_usize("draws")?,
            dim: opt_usize("dim")?,
            error: o
                .get("error")
                .map(|e| e.as_str().map(str::to_string))
                .transpose()?,
        })
    }
}

/// Options for [`leaderd`].
#[derive(Debug, Clone)]
pub struct LeaderdOptions {
    /// Pipelines running at once; further jobs queue FIFO
    /// (`--max-concurrent-jobs`).
    pub max_concurrent_jobs: usize,
    /// Stop accepting after this many connections and exit once they
    /// drain (`--jobs N`; `None` = serve until shut down). The
    /// deterministic-exit knob tests and CI share with `repro serve`.
    pub max_jobs: Option<usize>,
    /// Inbound frame cap (submit frames are small; this guards the
    /// length prefix).
    pub max_frame_bytes: usize,
    /// Bound on a freshly accepted connection delivering its submit
    /// frame — same idle-connection hazard, same default, as the
    /// worker daemon's manifest timeout.
    pub submit_timeout: Duration,
}

impl Default for LeaderdOptions {
    fn default() -> Self {
        LeaderdOptions {
            max_concurrent_jobs: 2,
            max_jobs: None,
            max_frame_bytes: DEFAULT_MAX_FRAME_BYTES,
            submit_timeout: DEFAULT_MANIFEST_TIMEOUT,
        }
    }
}

/// Graceful-shutdown handle for [`leaderd`]: cloneable, signal-safe to
/// observe (one atomic). Triggering makes the daemon refuse new
/// submissions (in-band `failed` frames), drain in-flight jobs, and
/// return its summary — the SIGTERM/ctrl-c path of the CLI.
#[derive(Clone, Default)]
pub struct Shutdown(Arc<AtomicBool>);

impl Shutdown {
    pub fn new() -> Shutdown {
        Shutdown::default()
    }

    pub fn trigger(&self) {
        self.0.store(true, Ordering::SeqCst);
    }

    pub fn is_triggered(&self) -> bool {
        self.0.load(Ordering::SeqCst)
    }
}

/// Per-job summary row in the daemon's exit report.
#[derive(Debug, Clone, PartialEq)]
pub struct JobRow {
    pub job: u64,
    pub state: JobState,
    pub queue_wait_ms: f64,
    pub time_to_first_draw_ms: f64,
}

/// What a daemon lifetime produced: aggregate job metrics (rendered
/// through [`RunMetrics`], whose Display prints the grep-able
/// `jobs_accepted=…` line) plus one row per job.
#[derive(Debug, Clone)]
pub struct DaemonSummary {
    pub metrics: RunMetrics,
    pub jobs: Vec<JobRow>,
}

impl fmt::Display for DaemonSummary {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(
            f,
            "jobs_accepted={} jobs_failed={} job_queue_wait_ms(mean)={:.1}",
            self.metrics.jobs_accepted,
            self.metrics.jobs_failed,
            self.metrics.mean_job_queue_wait_ms()
        )?;
        for row in &self.jobs {
            writeln!(
                f,
                "job {}: state={} queue_wait_ms={:.1} \
                 time_to_first_draw_ms={:.1}",
                row.job,
                row.state.name(),
                row.queue_wait_ms,
                row.time_to_first_draw_ms
            )?;
        }
        Ok(())
    }
}

/// How often the accept loop polls the nonblocking listener and the
/// shutdown flag. Bounds shutdown latency, not job latency — client
/// connections run on their own threads.
const ACCEPT_POLL: Duration = Duration::from_millis(25);

/// Combined draws stream back in chunks of this many rows per RPDRAW1
/// frame — small enough to pipeline, large enough to amortize frame
/// overhead. A display/transport knob only: the bytes are bit-exact
/// regardless.
const RESULT_CHUNK_ROWS: usize = 512;

/// Run the leader daemon: bind `addr`, announce `LISTENING <addr>` on
/// `announce`, serve submit connections each on its own thread with up
/// to `opts.max_concurrent_jobs` pipelines running at once, until
/// `shutdown` triggers (drain, then return the summary) or the
/// `opts.max_jobs` cap is reached. A failed job is reported to its own
/// client in-band; the daemon stays up for the others.
pub fn leaderd(
    addr: &str,
    opts: &LeaderdOptions,
    shutdown: &Shutdown,
    announce: &mut dyn Write,
) -> Result<DaemonSummary> {
    let listener = TcpListener::bind(addr).map_err(|e| {
        Error::Runtime(format!("binding leader daemon to {addr}: {e}"))
    })?;
    let local = listener.local_addr().map_err(Error::Io)?;
    listener.set_nonblocking(true).map_err(|e| {
        Error::Runtime(format!("arming nonblocking accept: {e}"))
    })?;
    writeln!(announce, "LISTENING {local}")?;
    announce.flush()?;

    let manager = JobManager::new(opts.max_concurrent_jobs);
    let mut accepted = 0usize;
    std::thread::scope(|scope| {
        loop {
            let capped =
                opts.max_jobs.is_some_and(|cap| accepted >= cap);
            let draining = shutdown.is_triggered() || capped;
            if draining {
                manager.begin_drain();
                // Keep accepting while clients are active so late
                // submitters get an in-band refusal instead of a
                // hang; once the last client thread exits, stop.
                if manager.active_clients() == 0 {
                    break;
                }
            }
            match listener.accept() {
                Ok((stream, peer)) => {
                    accepted += 1;
                    manager.client_started();
                    let manager = &manager;
                    scope.spawn(move || {
                        if let Err(e) =
                            handle_client(stream, manager, opts)
                        {
                            eprintln!("leaderd: client {peer}: {e}");
                        }
                        manager.client_finished();
                    });
                }
                Err(e)
                    if e.kind() == std::io::ErrorKind::WouldBlock =>
                {
                    std::thread::sleep(ACCEPT_POLL);
                }
                Err(e) => eprintln!("leaderd: accept: {e}"),
            }
        }
    });
    Ok(manager.summary())
}

/// Send one state frame, flushed immediately so the client sees
/// lifecycle progress in real time.
fn send_update(
    out: &Mutex<BufWriter<TcpStream>>,
    update: &JobUpdate,
) -> Result<()> {
    let mut w = out.lock().unwrap();
    write_frame(&mut *w, &update.to_json().render())?;
    w.flush().map_err(Error::Io)
}

/// Stream the combined draw matrix back as binary RPDRAW1 chunk
/// frames (machine 0, `last` on the final chunk). Bit-exact: the
/// chunk encoding round-trips every f64 through `to_bits`, so the
/// client-side CSV is byte-identical to the solo CLI's.
fn stream_combined(
    out: &Mutex<BufWriter<TcpStream>>,
    combined: &SampleMatrix,
) -> Result<()> {
    let total = combined.len();
    let dim = combined.dim();
    let mut frame = Vec::new();
    let mut start = 0usize;
    while start < total {
        let end = (start + RESULT_CHUNK_ROWS).min(total);
        let mut thetas = Vec::with_capacity((end - start) * dim);
        for i in start..end {
            thetas.extend_from_slice(combined.row(i));
        }
        let chunk = DrawChunk {
            machine: 0,
            dim,
            thetas,
            // Combined draws carry no per-draw timing; zeros keep the
            // frame layout uniform.
            elapsed: vec![0.0; end - start],
            last: end == total,
        };
        chunk.encode_into(&mut frame);
        let mut w = out.lock().unwrap();
        write_frame_bytes(&mut *w, &frame)?;
        start = end;
    }
    out.lock().unwrap().flush().map_err(Error::Io)
}

/// One client connection: read the submit frame, run the job through
/// the shared [`JobManager`], stream lifecycle + result frames back.
fn handle_client(
    stream: TcpStream,
    manager: &JobManager,
    opts: &LeaderdOptions,
) -> Result<()> {
    stream.set_nodelay(true).ok();
    // Only the submit frame is read from the client; bounding it keeps
    // an idle connection from pinning a client thread forever.
    stream.set_read_timeout(Some(opts.submit_timeout)).map_err(|e| {
        Error::Runtime(format!(
            "arming the {:?} submit read timeout: {e}",
            opts.submit_timeout
        ))
    })?;
    let reader = stream.try_clone().map_err(Error::Io)?;
    let mut frames = FrameReader::with_max_frame(
        BufReader::new(reader),
        opts.max_frame_bytes,
    );
    let payload = frames.read_frame()?.ok_or_else(|| {
        Error::Runtime("connection closed before a submit frame".into())
    })?;
    let spec = JobSpec::from_frame(&Json::parse(&payload)?)?;
    // Validate the spec up front so a malformed config is refused
    // before it ever occupies a run slot.
    spec.config()?;

    let out = Mutex::new(BufWriter::new(
        stream.try_clone().map_err(Error::Io)?,
    ));
    let result = match manager.submit() {
        None => {
            // Draining: refuse in-band (job id 0 = never admitted).
            let refusal = JobUpdate::failed(
                0,
                "leaderd draining: submission refused",
            );
            send_update(&out, &refusal)
        }
        Some(job) => serve_job(&stream, &out, manager, opts, job, &spec),
    };
    out.lock().unwrap().flush().ok();
    stream.shutdown(NetShutdown::Both).ok();
    result
}

/// Drive one admitted job through its lifecycle.
fn serve_job(
    _stream: &TcpStream,
    out: &Mutex<BufWriter<TcpStream>>,
    manager: &JobManager,
    _opts: &LeaderdOptions,
    job: u64,
    spec: &JobSpec,
) -> Result<()> {
    send_update(out, &JobUpdate::state_only(job, JobState::Submitted))?;
    let wait_t0 = Instant::now();
    let slot = manager.acquire_slot();
    let queue_wait_ms = wait_t0.elapsed().as_secs_f64() * 1e3;
    send_update(
        out,
        &JobUpdate {
            queue_wait_ms: Some(queue_wait_ms),
            ..JobUpdate::state_only(job, JobState::Running)
        },
    )?;
    // Lifecycle hook: surface the combine transition as it happens.
    // Best-effort — a client that stopped reading must not kill the
    // pipeline mid-combine; the final done/failed frame reports the
    // authoritative outcome.
    let on_phase = |phase: RunPhase| {
        if phase == RunPhase::Combining {
            let _ = send_update(
                out,
                &JobUpdate::state_only(job, JobState::Combining),
            );
        }
    };
    let run = jobs::run_job(spec, manager.endpoint_pool(), &on_phase);
    drop(slot);
    match run {
        Ok(output) => {
            let ttfd = output.metrics.time_to_first_draw_ms;
            manager.record_outcome(
                job,
                JobState::Done,
                queue_wait_ms,
                ttfd,
            );
            stream_combined(out, &output.combined)?;
            send_update(
                out,
                &JobUpdate {
                    queue_wait_ms: Some(queue_wait_ms),
                    time_to_first_draw_ms: Some(ttfd),
                    draws: Some(output.combined.len()),
                    dim: Some(output.combined.dim()),
                    ..JobUpdate::state_only(job, JobState::Done)
                },
            )
        }
        Err(e) => {
            manager.record_outcome(
                job,
                JobState::Failed,
                queue_wait_ms,
                0.0,
            );
            send_update(out, &JobUpdate::failed(job, &e.to_string()))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn job_spec_roundtrips_through_the_submit_frame() {
        let cfg = PipelineConfig::builder("gaussian")
            .machines(3)
            .samples_per_machine(50)
            .seed(123)
            .build();
        let spec = JobSpec::from_config(&cfg, 600, 2);
        let back =
            JobSpec::from_frame(&Json::parse(&spec.to_frame()).unwrap())
                .unwrap();
        assert_eq!(back, spec);
        let cfg2 = back.config().unwrap();
        assert_eq!(cfg2.seed, 123);
        assert_eq!(cfg2.machines, 3);
        assert_eq!(cfg2.to_cfg_string(), cfg.to_cfg_string());
    }

    #[test]
    fn job_update_roundtrips_with_optional_fields() {
        let full = JobUpdate {
            job: 7,
            state: JobState::Done,
            queue_wait_ms: Some(12.25),
            time_to_first_draw_ms: Some(3.5),
            draws: Some(100),
            dim: Some(4),
            error: None,
        };
        let back =
            JobUpdate::from_json(&Json::parse(&full.to_json().render())
                .unwrap())
            .unwrap();
        assert_eq!(back, full);
        let failed = JobUpdate::failed(2, "boom");
        let back = JobUpdate::from_json(
            &Json::parse(&failed.to_json().render()).unwrap(),
        )
        .unwrap();
        assert_eq!(back.state, JobState::Failed);
        assert_eq!(back.error.as_deref(), Some("boom"));
        assert_eq!(back.queue_wait_ms, None);
    }

    #[test]
    fn job_state_names_roundtrip() {
        for s in [
            JobState::Submitted,
            JobState::Running,
            JobState::Combining,
            JobState::Done,
            JobState::Failed,
        ] {
            assert_eq!(JobState::parse(s.name()).unwrap(), s);
        }
        assert!(JobState::parse("nope").is_err());
    }

    #[test]
    fn daemon_summary_renders_per_job_rows() {
        let summary = DaemonSummary {
            metrics: RunMetrics {
                jobs_accepted: 2,
                jobs_failed: 1,
                job_queue_wait_ms: vec![0.0, 50.0],
                ..RunMetrics::default()
            },
            jobs: vec![
                JobRow {
                    job: 1,
                    state: JobState::Done,
                    queue_wait_ms: 0.0,
                    time_to_first_draw_ms: 8.5,
                },
                JobRow {
                    job: 2,
                    state: JobState::Failed,
                    queue_wait_ms: 50.0,
                    time_to_first_draw_ms: 0.0,
                },
            ],
        };
        let s = summary.to_string();
        assert!(s.contains("jobs_accepted=2"));
        assert!(s.contains("jobs_failed=1"));
        assert!(s.contains("job_queue_wait_ms(mean)=25.0"));
        assert!(s.contains("job 1: state=done"));
        assert!(s.contains("job 2: state=failed"));
        assert!(s.contains("time_to_first_draw_ms=8.5"));
    }
}
