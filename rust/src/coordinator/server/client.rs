//! `repro submit` client side of the `RPJOB1` protocol.
//!
//! One call = one job: dial the daemon, ship the submit frame, then
//! fold the reply stream — JSON lifecycle frames interleaved with
//! binary `RPDRAW1` result chunks — into a [`SubmitOutcome`]. Progress
//! frames are surfaced through a callback so the CLI can narrate
//! `submitted → running → combining` on stderr while the draw bytes
//! accumulate bit-exact.

use std::io::{BufReader, BufWriter, Write};
use std::net::TcpStream;

use crate::coordinator::transport::{
    write_frame, DrawChunk, FrameReader, DEFAULT_MAX_FRAME_BYTES,
    DRAW_MAGIC,
};
use crate::error::{Error, Result};
use crate::runtime::json::Json;
use crate::types::SampleMatrix;

use super::{JobSpec, JobState, JobUpdate};

/// What a completed job handed back.
#[derive(Debug)]
pub struct SubmitOutcome {
    /// Daemon-assigned job id.
    pub job: u64,
    /// Combined posterior draws, byte-identical to the solo CLI run of
    /// the same spec.
    pub combined: SampleMatrix,
    /// Milliseconds the job queued behind `--max-concurrent-jobs`.
    pub queue_wait_ms: f64,
    /// The job's time-to-first-draw as measured by the daemon.
    pub time_to_first_draw_ms: f64,
}

/// Submit `spec` to the leader daemon at `addr` and block until the
/// job finishes. Every lifecycle frame is passed to `progress` as it
/// arrives; a `failed` frame (including a drain-time refusal) becomes
/// an `Err` carrying the daemon's error text.
pub fn submit(
    addr: &str,
    spec: &JobSpec,
    progress: &mut dyn FnMut(&JobUpdate),
) -> Result<SubmitOutcome> {
    let stream = TcpStream::connect(addr).map_err(|e| {
        Error::Runtime(format!("dialing leader daemon {addr}: {e}"))
    })?;
    stream.set_nodelay(true).ok();
    let mut frames = FrameReader::with_max_frame(
        BufReader::new(stream.try_clone().map_err(Error::Io)?),
        DEFAULT_MAX_FRAME_BYTES,
    );
    let mut w = BufWriter::new(stream);
    write_frame(&mut w, &spec.to_frame())?;
    w.flush().map_err(Error::Io)?;

    let mut job = 0u64;
    let mut queue_wait_ms = 0.0f64;
    let mut combined: Option<SampleMatrix> = None;
    loop {
        let payload = frames.read_frame_bytes()?.ok_or_else(|| {
            Error::Runtime(
                "leaderd closed the connection before a done frame"
                    .into(),
            )
        })?;
        if payload.starts_with(DRAW_MAGIC) {
            let chunk = DrawChunk::decode(&payload)?;
            let m = combined
                .get_or_insert_with(|| SampleMatrix::new(chunk.dim));
            if chunk.dim != m.dim() {
                return Err(Error::Runtime(format!(
                    "result chunk dim {} != {}",
                    chunk.dim,
                    m.dim()
                )));
            }
            m.push_rows(&chunk.thetas);
            continue;
        }
        let text = String::from_utf8(payload).map_err(|e| {
            Error::Parse(format!("non-UTF-8 state frame: {e}"))
        })?;
        let update = JobUpdate::from_json(&Json::parse(&text)?)?;
        progress(&update);
        if update.job != 0 {
            job = update.job;
        }
        if let Some(qw) = update.queue_wait_ms {
            queue_wait_ms = qw;
        }
        match update.state {
            JobState::Failed => {
                return Err(Error::Runtime(format!(
                    "job {} failed: {}",
                    update.job,
                    update.error.as_deref().unwrap_or("unknown error")
                )));
            }
            JobState::Done => {
                let combined = combined.unwrap_or_else(|| {
                    SampleMatrix::new(update.dim.unwrap_or(1))
                });
                if let Some(expect) = update.draws {
                    if combined.len() != expect {
                        return Err(Error::Runtime(format!(
                            "done frame promised {expect} draws, \
                             received {}",
                            combined.len()
                        )));
                    }
                }
                return Ok(SubmitOutcome {
                    job,
                    combined,
                    queue_wait_ms,
                    time_to_first_draw_ms: update
                        .time_to_first_draw_ms
                        .unwrap_or(0.0),
                });
            }
            _ => {}
        }
    }
}
