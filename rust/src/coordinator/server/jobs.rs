//! Job scheduling for the leader daemon: admission control, fair
//! endpoint leasing, and the per-job pipeline runner.
//!
//! Three pieces, each with one isolation job:
//!
//! - [`JobManager`] — the daemon-wide accountant. A FIFO-ticket
//!   semaphore bounds how many pipelines *run* at once
//!   (`--max-concurrent-jobs`); everything else about a job (RNG root,
//!   combiner, draw plane, liveness/retry/quarantine state) lives
//!   inside that job's own pipeline run, so concurrency shares no
//!   sampler state between jobs.
//! - [`EndpointPool`] — fair leasing of the shared worker fleet. A
//!   worker daemon serves one connection at a time, so two jobs
//!   dialing the same endpoint would otherwise serialize in the
//!   endpoint's accept backlog in arrival order; the pool makes that
//!   queue explicit and FIFO per endpoint, so one job's slow shards
//!   delay a competitor by at most the shard in flight — never by an
//!   unbounded backlog jump.
//! - [`run_job`] — one submitted spec → one pipeline run, through
//!   exactly the dispatch a solo CLI run uses. Determinism needs no
//!   help from the scheduler: machine RNG streams are
//!   `Pcg64::seed_from(job seed).split(m)` and the combine seed is
//!   `job seed ^ 0x5EED`, both functions of the spec alone, so
//!   retained draws are byte-identical to the solo run at any
//!   concurrency or interleaving.

use std::collections::{HashMap, VecDeque};
use std::path::Path;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};

use crate::config::IoDriver;
use crate::coordinator::metrics::RunMetrics;
use crate::coordinator::pipeline::{self, PipelineOutput, RunPhase};
use crate::coordinator::transport::{
    Transport, WorkerConnection, WorkerManifest, WireMsg,
};
use crate::data::synth;
use crate::error::Result;

use super::{DaemonSummary, JobRow, JobSpec, JobState};

/// Daemon-wide job accounting and admission control. All methods take
/// `&self`; one manager is shared by every client-connection thread.
pub struct JobManager {
    max_concurrent: usize,
    sched: Mutex<SchedState>,
    sched_cv: Condvar,
    /// Client-connection threads currently alive (submitted or not) —
    /// the accept loop's drain barrier.
    clients: AtomicUsize,
    stats: Mutex<Stats>,
    pool: Arc<EndpointPool>,
}

/// Run-slot semaphore state. FIFO tickets (not a bare counter) so a
/// job that queued first runs first — queue-wait fairness is part of
/// the daemon's contract, not an accident of `Condvar` wakeup order.
struct SchedState {
    running: usize,
    queue: VecDeque<u64>,
    next_ticket: u64,
    draining: bool,
}

#[derive(Default)]
struct Stats {
    accepted: usize,
    failed: usize,
    rows: Vec<JobRow>,
}

impl JobManager {
    pub fn new(max_concurrent_jobs: usize) -> JobManager {
        JobManager {
            max_concurrent: max_concurrent_jobs.max(1),
            sched: Mutex::new(SchedState {
                running: 0,
                queue: VecDeque::new(),
                next_ticket: 0,
                draining: false,
            }),
            sched_cv: Condvar::new(),
            clients: AtomicUsize::new(0),
            stats: Mutex::new(Stats::default()),
            pool: EndpointPool::new(),
        }
    }

    /// The shared endpoint-lease pool jobs dial workers through.
    pub fn endpoint_pool(&self) -> &Arc<EndpointPool> {
        &self.pool
    }

    /// Admit a job: returns its id (1-based, assigned in submission
    /// order — ids label jobs and never feed RNG state), or `None`
    /// when the daemon is draining and refuses new work.
    pub fn submit(&self) -> Option<u64> {
        if self.sched.lock().unwrap().draining {
            return None;
        }
        let mut stats = self.stats.lock().unwrap();
        stats.accepted += 1;
        let job = stats.accepted as u64;
        stats.rows.push(JobRow {
            job,
            state: JobState::Submitted,
            queue_wait_ms: 0.0,
            time_to_first_draw_ms: 0.0,
        });
        Some(job)
    }

    /// Stop admitting new jobs; queued and running jobs finish
    /// normally. Idempotent.
    pub fn begin_drain(&self) {
        self.sched.lock().unwrap().draining = true;
        self.sched_cv.notify_all();
    }

    /// Block until a run slot is free (FIFO across waiting jobs); the
    /// guard releases the slot on drop. The block is the job's queue
    /// wait — measured by the caller, reported per job.
    pub fn acquire_slot(&self) -> SlotGuard<'_> {
        let ticket = {
            let mut s = self.sched.lock().unwrap();
            let t = s.next_ticket;
            s.next_ticket += 1;
            s.queue.push_back(t);
            t
        };
        let mut s = self.sched.lock().unwrap();
        while s.queue.front() != Some(&ticket)
            || s.running >= self.max_concurrent
        {
            s = self.sched_cv.wait(s).unwrap();
        }
        s.queue.pop_front();
        s.running += 1;
        SlotGuard { mgr: self }
    }

    /// A client-connection thread came up / went away — the accept
    /// loop drains by waiting for this to hit zero.
    pub fn client_started(&self) {
        self.clients.fetch_add(1, Ordering::SeqCst);
    }

    pub fn client_finished(&self) {
        self.clients.fetch_sub(1, Ordering::SeqCst);
    }

    pub fn active_clients(&self) -> usize {
        self.clients.load(Ordering::SeqCst)
    }

    /// Record a job's terminal state plus its per-job metric row.
    pub fn record_outcome(
        &self,
        job: u64,
        state: JobState,
        queue_wait_ms: f64,
        time_to_first_draw_ms: f64,
    ) {
        let mut stats = self.stats.lock().unwrap();
        if state == JobState::Failed {
            stats.failed += 1;
        }
        if let Some(row) = stats.rows.iter_mut().find(|r| r.job == job) {
            row.state = state;
            row.queue_wait_ms = queue_wait_ms;
            row.time_to_first_draw_ms = time_to_first_draw_ms;
        }
    }

    /// The daemon's lifetime summary: job counters folded into a
    /// [`RunMetrics`] (whose Display prints the grep-able
    /// `jobs_accepted=…` line) plus the per-job rows.
    pub fn summary(&self) -> DaemonSummary {
        let stats = self.stats.lock().unwrap();
        let metrics = RunMetrics {
            jobs_accepted: stats.accepted,
            jobs_failed: stats.failed,
            job_queue_wait_ms: stats
                .rows
                .iter()
                .map(|r| r.queue_wait_ms)
                .collect(),
            ..RunMetrics::default()
        };
        DaemonSummary { metrics, jobs: stats.rows.clone() }
    }
}

/// RAII run slot from [`JobManager::acquire_slot`].
pub struct SlotGuard<'a> {
    mgr: &'a JobManager,
}

impl Drop for SlotGuard<'_> {
    fn drop(&mut self) {
        self.mgr.sched.lock().unwrap().running -= 1;
        self.mgr.sched_cv.notify_all();
    }
}

/// Fair, per-endpoint connection leasing over the shared worker fleet.
/// Keyed by endpoint address so two jobs whose specs name overlapping
/// endpoint lists contend exactly on the shared addresses and nowhere
/// else — per-job endpoint lists are first-class.
pub struct EndpointPool {
    eps: Mutex<HashMap<String, EpState>>,
    cv: Condvar,
}

#[derive(Default)]
struct EpState {
    busy: bool,
    queue: VecDeque<u64>,
    next_ticket: u64,
}

impl EndpointPool {
    pub fn new() -> Arc<EndpointPool> {
        Arc::new(EndpointPool {
            eps: Mutex::new(HashMap::new()),
            cv: Condvar::new(),
        })
    }

    /// Block until `addr` is free, FIFO among waiters. The returned
    /// lease releases on drop — connection teardown included, since
    /// the leased connection owns it.
    pub fn acquire(self: &Arc<Self>, addr: &str) -> EndpointLease {
        let ticket = {
            let mut eps = self.eps.lock().unwrap();
            let ep = eps.entry(addr.to_string()).or_default();
            let t = ep.next_ticket;
            ep.next_ticket += 1;
            ep.queue.push_back(t);
            t
        };
        let mut eps = self.eps.lock().unwrap();
        loop {
            let ep = eps.get_mut(addr).expect("endpoint entry exists");
            if ep.queue.front() == Some(&ticket) && !ep.busy {
                ep.queue.pop_front();
                ep.busy = true;
                return EndpointLease {
                    pool: Arc::clone(self),
                    addr: addr.to_string(),
                };
            }
            eps = self.cv.wait(eps).unwrap();
        }
    }
}

/// Exclusive use of one endpoint address; released on drop.
pub struct EndpointLease {
    pool: Arc<EndpointPool>,
    addr: String,
}

impl Drop for EndpointLease {
    fn drop(&mut self) {
        let mut eps = self.pool.eps.lock().unwrap();
        if let Some(ep) = eps.get_mut(&self.addr) {
            ep.busy = false;
        }
        drop(eps);
        self.pool.cv.notify_all();
    }
}

/// A [`Transport`] wrapper that takes an [`EndpointPool`] lease before
/// each dial and holds it for the connection's lifetime. The inner
/// scheduler is unchanged — oversubscription, retry, quarantine all
/// behave as in a solo run — the lease only gates *when* the dial
/// happens, which cannot change any job's retained draws (byte-identity
/// is endpoint- and timing-independent by construction).
pub(crate) struct LeasedTransport {
    inner: crate::coordinator::transport::SocketTransport,
    pool: Arc<EndpointPool>,
    addrs: Vec<String>,
}

impl LeasedTransport {
    pub(crate) fn new(
        inner: crate::coordinator::transport::SocketTransport,
        pool: Arc<EndpointPool>,
        addrs: Vec<String>,
    ) -> LeasedTransport {
        LeasedTransport { inner, pool, addrs }
    }
}

impl Transport for LeasedTransport {
    fn name(&self) -> &'static str {
        "leased-socket"
    }

    fn slots(&self) -> usize {
        self.inner.slots()
    }

    fn connect(
        &self,
        slot: usize,
        manifest: &WorkerManifest,
        manifest_path: &Path,
    ) -> Result<Box<dyn WorkerConnection>> {
        let lease = self.pool.acquire(&self.addrs[slot]);
        // Dial only after the lease: on failure the lease drops here
        // and the endpoint frees for the next waiter immediately.
        let conn = self.inner.connect(slot, manifest, manifest_path)?;
        Ok(Box::new(LeasedConnection { conn, _lease: lease }))
    }

    fn max_frame_bytes(&self) -> usize {
        self.inner.max_frame_bytes()
    }

    fn wants_inline_shard(&self) -> bool {
        self.inner.wants_inline_shard()
    }

    fn cancel_all(&self) {
        self.inner.cancel_all();
    }
}

struct LeasedConnection {
    conn: Box<dyn WorkerConnection>,
    _lease: EndpointLease,
}

impl WorkerConnection for LeasedConnection {
    fn recv(&mut self) -> Result<Option<WireMsg>> {
        self.conn.recv()
    }

    fn finish(&mut self) -> Result<()> {
        self.conn.finish()
    }
}

/// Run one submitted job spec end-to-end, returning the same
/// [`PipelineOutput`] a solo CLI run produces for that spec.
///
/// Dispatch mirrors `repro pipeline` exactly — dataset from the spec's
/// model/n/d seeded by the *job's* seed, then [`pipeline::run_process_events`]
/// over the spec's worker list, process mode, or in-thread workers —
/// with one insertion: socket jobs under the threads io-driver dial
/// through a [`LeasedTransport`] so concurrent jobs share the fleet
/// fairly. Reactor jobs keep their unleased dial: the reactor's whole
/// point is nonblocking multiplexing, and worker daemons already
/// serialize at one connection a time, so fairness costs at most the
/// accept-backlog FIFO the OS provides. Both paths are byte-identical
/// to the solo run by the RNG-root argument above.
pub fn run_job(
    spec: &JobSpec,
    pool: &Arc<EndpointPool>,
    on_phase: &(dyn Fn(RunPhase) + Sync),
) -> Result<PipelineOutput> {
    let cfg = spec.config()?;
    if cfg.use_runtime {
        return Err(crate::error::Error::Config(
            "use_runtime jobs need a local artifact directory; run \
             them via `repro pipeline`, not a leader daemon"
                .into(),
        ));
    }
    let data = synth::by_name(&cfg.model, spec.n, spec.d, cfg.seed)?;
    if !cfg.workers.is_empty() && cfg.io_driver == IoDriver::Threads {
        let inner = pipeline::build_socket_transport(&cfg)?;
        let addrs: Vec<String> = cfg
            .workers
            .split(',')
            .map(|a| a.trim().to_string())
            .filter(|a| !a.is_empty())
            .collect();
        let transport =
            LeasedTransport::new(inner, Arc::clone(pool), addrs);
        return pipeline::run_with_transport_events(
            &cfg, &data, &transport, on_phase,
        );
    }
    if cfg.process_mode || !cfg.workers.is_empty() {
        return pipeline::run_process_events(&cfg, &data, on_phase);
    }
    pipeline::run_native_events(&cfg, &data, on_phase)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    /// The run-slot semaphore really caps concurrency and wakes FIFO:
    /// with one slot and three queued jobs, completions hand the slot
    /// over in submission order.
    #[test]
    fn slot_semaphore_is_fifo_and_bounded() {
        let mgr = Arc::new(JobManager::new(1));
        let order = Arc::new(Mutex::new(Vec::new()));
        let first = mgr.acquire_slot();
        let mut handles = Vec::new();
        for i in 0..3 {
            let mgr = Arc::clone(&mgr);
            let order = Arc::clone(&order);
            handles.push(std::thread::spawn(move || {
                // Stagger queue entry so ticket order is deterministic.
                std::thread::sleep(Duration::from_millis(30 * (i + 1)));
                let guard = mgr.acquire_slot();
                order.lock().unwrap().push(i);
                drop(guard);
            }));
        }
        // Let all three park behind the held slot, then release it.
        std::thread::sleep(Duration::from_millis(200));
        assert!(order.lock().unwrap().is_empty(), "slot cap violated");
        drop(first);
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(*order.lock().unwrap(), vec![0, 1, 2]);
    }

    /// Endpoint leases are exclusive per address, independent across
    /// addresses, and FIFO among waiters on one address.
    #[test]
    fn endpoint_pool_is_exclusive_and_fifo() {
        let pool = EndpointPool::new();
        let a = pool.acquire("host:1");
        // A different address is immediately available.
        let b = pool.acquire("host:2");
        drop(b);
        let order = Arc::new(Mutex::new(Vec::new()));
        let mut handles = Vec::new();
        for i in 0..3 {
            let pool = Arc::clone(&pool);
            let order = Arc::clone(&order);
            handles.push(std::thread::spawn(move || {
                std::thread::sleep(Duration::from_millis(30 * (i + 1)));
                let lease = pool.acquire("host:1");
                order.lock().unwrap().push(i);
                drop(lease);
            }));
        }
        std::thread::sleep(Duration::from_millis(200));
        assert!(
            order.lock().unwrap().is_empty(),
            "lease exclusivity violated"
        );
        drop(a);
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(*order.lock().unwrap(), vec![0, 1, 2]);
    }

    /// Draining refuses new submissions but leaves ids and counters of
    /// already-accepted jobs intact.
    #[test]
    fn drain_refuses_new_submissions() {
        let mgr = JobManager::new(2);
        assert_eq!(mgr.submit(), Some(1));
        assert_eq!(mgr.submit(), Some(2));
        mgr.begin_drain();
        assert_eq!(mgr.submit(), None);
        mgr.record_outcome(1, JobState::Done, 5.0, 1.0);
        mgr.record_outcome(2, JobState::Failed, 15.0, 0.0);
        let summary = mgr.summary();
        assert_eq!(summary.metrics.jobs_accepted, 2);
        assert_eq!(summary.metrics.jobs_failed, 1);
        assert_eq!(summary.metrics.job_queue_wait_ms, vec![5.0, 15.0]);
        assert_eq!(summary.jobs.len(), 2);
        assert_eq!(summary.jobs[0].state, JobState::Done);
        assert_eq!(summary.jobs[1].state, JobState::Failed);
    }
}
